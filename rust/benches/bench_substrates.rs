//! Substrate micro-benchmarks: graph generation, mixing matrices, the Jacobi
//! eigensolver, the EHR generator, the netsim, and t-SNE — the from-scratch
//! infrastructure everything else stands on.
//!
//!     cargo bench --bench bench_substrates

use decfl::benchutil::{bench, report, section};
use decfl::data::{generate, DataConfig};
use decfl::graph::{Graph, Topology};
use decfl::linalg::sym_eig;
use decfl::mixing::{build, validate, Scheme};
use decfl::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    section("graph + mixing (N = 20, paper scale)");
    report("RGG(20) build", &bench(1.0, || {
        let g = Graph::build(&Topology::RandomGeometric { radius: 0.35 }, 20, &mut Pcg64::seed(7)).unwrap();
        std::hint::black_box(g.edge_count());
    }));
    let g = Graph::build(&Topology::RandomGeometric { radius: 0.35 }, 20, &mut Pcg64::seed(7))?;
    report("metropolis weights", &bench(1.0, || {
        std::hint::black_box(build(&g, Scheme::Metropolis));
    }));
    let w = build(&g, Scheme::Metropolis);
    report("assumption-1 validation (jacobi eig)", &bench(1.0, || {
        std::hint::black_box(validate(&w).second_eig);
    }));

    section("eigensolver scaling");
    for n in [20usize, 50, 100] {
        let mut rng = Pcg64::seed(n as u64);
        let mut a = decfl::linalg::Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        report(&format!("sym_eig {n}x{n}"), &bench(1.0, || {
            std::hint::black_box(sym_eig(&a).values[0]);
        }));
    }

    section("EHR generator");
    report("cohort 20 x 500 (paper scale)", &bench(3.0, || {
        let ds = generate(&DataConfig::default()).unwrap();
        std::hint::black_box(ds.total_records());
    }));

    section("netsim gossip round (20 nodes, P=1409 payload)");
    report("channel round (threads)", &bench(3.0, || {
        let g = Graph::build(&Topology::Ring, 20, &mut Pcg64::seed(0)).unwrap();
        let (eps, _stats) = decfl::netsim::build(&g, decfl::netsim::LinkModel::default(), 1);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    let p =
                        std::sync::Arc::new(decfl::netsim::Payload::Dense(vec![0.0f32; 1409]));
                    ep.broadcast(0, decfl::netsim::PayloadKind::Params, &p).unwrap();
                    ep.gather(0, decfl::netsim::PayloadKind::Params).unwrap().len()
                })
            })
            .collect();
        for h in handles {
            std::hint::black_box(h.join().unwrap());
        }
    }));

    section("t-SNE (150 points, 42-d)");
    let ds = generate(&DataConfig::default())?;
    let mut rows = Vec::new();
    for i in 0..150 {
        rows.push(ds.shards[0].row(i).iter().map(|&v| v as f64).collect::<Vec<_>>());
    }
    let x = decfl::linalg::Mat::from_rows(&rows);
    report("tsne 150x42 (100 iters)", &bench(5.0, || {
        let e = decfl::tsne::tsne(
            &x,
            &decfl::tsne::TsneConfig { iterations: 100, perplexity: 20.0, ..Default::default() },
        )
        .unwrap();
        std::hint::black_box(e.data[0]);
    }));
    Ok(())
}
