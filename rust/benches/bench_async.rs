//! EXP-AS1 bench: the wall-clock-vs-accuracy frontier — synchronous barrier
//! vs asynchronous event-driven gossip under a lognormal straggler plan, one
//! shared base network, fused mode, native backend.
//!
//! Reports each driver's *simulated* time to the sync oracle's final
//! accuracy − 1 point (the BENCH_7.json quantity) and the host wall-clock
//! per run.  Async runs under the matched simulated-time budget
//! (`sim_budget_s = sync.sim_time_s`): the barrier-free driver gets the
//! wall-clock the barriered run spent and spends it on more, cheaper,
//! stale-mixed cycles.  The structural claim is asserted, not just printed:
//! async must reach the target strictly inside the horizon the sync run
//! needed to produce it — the barrier pays Σ_r max_i (every round as slow
//! as its slowest participant) while the event clock pays each node only
//! its own work.
//!
//!     cargo bench --bench bench_async
//!     DECFL_FULL=1  cargo bench --bench bench_async   # acceptance scale, n=200
//!     DECFL_SMOKE=1 cargo bench --bench bench_async   # CI compile+run check

use decfl::benchutil::{bench, budget, full_scale, report, section, smoke};
use decfl::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use decfl::coordinator::{assemble, run_on};
use decfl::experiments::asynchrony;

fn main() -> anyhow::Result<()> {
    let (n, steps, q) = if full_scale() {
        (200, 3_200, 32) // the n ≥ 200 acceptance frontier (100 rounds)
    } else if smoke() {
        (6, 384, 32)
    } else {
        (48, 1_920, 32)
    };

    let mut cfg = ExperimentConfig::default();
    cfg.backend = Backend::Native;
    cfg.mode = Mode::Fused;
    cfg.algo = AlgoKind::FdDsgd;
    cfg.n = n;
    cfg.hidden = 16;
    cfg.m = 10;
    cfg.q = q;
    cfg.total_steps = steps;
    cfg.eval_every = 1; // per-checkpoint accuracy: the time-to-target axis
    cfg.records_per_hospital = 120;
    cfg.topology = "er".into();
    cfg.compute_plan = "lognormal".into();
    // q·s_step (32 ms) dominates delivery latency and σ=1.5 gives the
    // lognormal tail real weight — the regime where the barrier bites
    // (DESIGN.md §13)
    cfg.compute_sigma = 1.5;

    println!(
        "sync barrier vs async event clock, fd-dsgd fused/native, lognormal σ={}: \
         n={n} steps={steps} q={q} ({} rounds)",
        cfg.compute_sigma,
        steps.div_ceil(q)
    );

    // ---- the frontier itself (shared cohort, shared base network) ----
    let rows = asynchrony::run(&cfg, &[0.0], &[cfg.topology.clone()])?;
    asynchrony::print_table(&rows);
    for f in asynchrony::findings(&rows) {
        println!("finding: {f}");
    }
    let (sync_row, async_row) = (&rows[0], &rows[1]);
    assert!(
        async_row.t_to_target_s < sync_row.sim_time_s,
        "async {}s must reach sync-final − 1pt inside the sync run's {}s horizon",
        async_row.t_to_target_s,
        sync_row.sim_time_s
    );
    assert!(
        async_row.final_accuracy >= sync_row.final_accuracy - 0.0151,
        "async final accuracy {} fell more than 1.5pt below sync's {}",
        async_row.final_accuracy,
        sync_row.final_accuracy
    );
    println!(
        "matched-budget frontier: async hits the target {:.2}x inside sync's horizon \
         (async {:.2}s vs sync run {:.2}s; sync's own time-to-target {:.2}s)",
        sync_row.sim_time_s / async_row.t_to_target_s,
        async_row.t_to_target_s,
        sync_row.sim_time_s,
        sync_row.t_to_target_s
    );

    // ---- host wall-clock per driver (event-queue overhead check) ----
    let asm = assemble(&cfg)?;
    for driver in ["sync", "async"] {
        let mut c = cfg.clone();
        c.driver = driver.into();
        c.eval_every = usize::MAX / 2; // time the rounds, not eval
        section(&format!("driver {driver}"));
        let t = bench(budget(0.5), || {
            std::hint::black_box(run_on(&c, &asm).unwrap());
        });
        report(&format!("{driver} full run ({} rounds)", steps.div_ceil(q)), &t);
    }

    // optional frozen-baseline dump (BENCH_7.json convention)
    if let Ok(path) = std::env::var("DECFL_BENCH_JSON") {
        let json = asynchrony::rows_json(&rows);
        std::fs::write(&path, json.to_string())?;
        println!("wrote frontier rows to {path}");
    }
    Ok(())
}
