//! EXP-A2: topology ablation — consensus quality vs the mixing matrix's
//! spectral gap (Assumption 1's quantitative content).
//!
//!     cargo bench --bench bench_topology

use decfl::benchutil::{full_scale, section};
use decfl::experiments::sweeps;

fn main() -> anyhow::Result<()> {
    let steps = if full_scale() { 4_000 } else { 1_200 };
    section(&format!("EXP-A2: topology sweep (FD-DSGT, Q=10, T={steps})"));
    let rows = sweeps::topology_sweep(&["path", "ring", "rgg", "er", "torus", "complete"], steps, 7)?;
    sweeps::print_topology_table(&rows);
    println!(
        "\npaper-vs-ours: larger spectral gap (denser graph) ⇒ smaller consensus \
         error at equal budget; the paper's RGG sits between ring and ER."
    );
    Ok(())
}
