//! EXP-P3: kernel + gossip microbenches for the zero-allocation refactor.
//!
//! Three tiers, matching the §Perf claims in DESIGN.md:
//!
//! 1. **grad kernel** — allocating `loss_and_grad` vs workspace-reusing
//!    `loss_and_grad_into` (cache-blocked either way; the delta is pure
//!    allocator traffic).
//! 2. **combine** — dense n-length row scan vs degree-sparse `(nbr, w)`
//!    lists over a sparse (knn) hospital graph at n ∈ {10, 200, 1000}: the
//!    O(n·p) → O(deg·p) drop that makes large cohorts feasible.
//! 3. **full round** — one fused FD-DSGD round (local phase + gossip
//!    update) through the double-buffered `_into` path, the number the
//!    ≥ 2× acceptance bar tracks; recorded to BENCH_3.json.
//! 4. **sparse network stack** — graph build, CSR-first W construction,
//!    power-iteration λ₂, and per-round dynamic views at n = 10⁴ without
//!    any n×n array (BENCH_6.json tracks this tier).
//!
//!     cargo bench --bench bench_kernels
//!     DECFL_BENCH_JSON=../BENCH_3.json cargo bench --bench bench_kernels
//!
//! `DECFL_SMOKE=1` shrinks sizes/budgets to a CI compile-and-run check.

use decfl::algo::native::{NativeModel, Workspace};
use decfl::benchutil::{bench, budget, report, section, smoke, Timing};
use decfl::coordinator::compute::MixView;
use decfl::coordinator::{Compute, NativeCompute};
use decfl::graph::{Graph, Topology};
use decfl::mixing::{self, Scheme, SparseW};
use decfl::rng::Pcg64;

fn rand_vec(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

fn rand_labels(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 }).collect()
}

struct Row {
    name: String,
    t: Timing,
}

fn push(rows: &mut Vec<Row>, name: &str, t: Timing) {
    report(name, &t);
    rows.push(Row { name: name.into(), t });
}

fn main() -> anyhow::Result<()> {
    let (d, h, m, local) = (42usize, 32usize, 20usize, 4usize);
    let model = NativeModel::new(d, h);
    let p = model.p();
    println!("kernel/gossip microbenches, d={d} h={h} p={p} m={m}");
    let mut rows: Vec<Row> = Vec::new();

    // ---- 1. grad kernel: allocating vs workspace-reusing ----
    section("grad kernel (one batch)");
    let mut rng = Pcg64::seed(3);
    let theta = rand_vec(&mut rng, p, 0.2);
    let x = rand_vec(&mut rng, m * d, 1.0);
    let y = rand_labels(&mut rng, m);
    let t = bench(budget(0.5), || {
        std::hint::black_box(model.loss_and_grad(&theta, &x, &y));
    });
    push(&mut rows, "loss_and_grad (alloc)", t);
    let mut ws = Workspace::new();
    let mut gbuf = vec![0.0f32; p];
    let t = bench(budget(0.5), || {
        std::hint::black_box(model.loss_and_grad_into(&theta, &x, &y, &mut gbuf, &mut ws));
    });
    push(&mut rows, "loss_and_grad_into (workspace)", t);

    // ---- 2 + 3. combine dense-vs-sparse and the full round, per n ----
    let sizes: &[usize] = if smoke() { &[10] } else { &[10, 200, 1000] };
    for &n in sizes {
        let mut rng = Pcg64::seed(7 + n as u64);
        let g = Graph::build(&Topology::KNearest { k: 3 }, n, &mut rng)?;
        let w = mixing::build(&g, Scheme::Metropolis);
        let dense = mixing::to_f32(&w);
        let sparse = SparseW::from_mat(&w);
        let mean_deg = sparse.nnz() as f64 / n as f64 - 1.0;
        let thetas = rand_vec(&mut rng, n * p, 0.3);

        section(&format!("combine          n={n} (knn graph, mean deg {mean_deg:.1})"));
        let i = n / 2;
        let wrow = &dense[i * n..(i + 1) * n];
        let (idx, val) = sparse.row(i);
        let mut out = vec![0.0f32; p];
        let t = bench(budget(0.5), || {
            model.combine_into(wrow, &thetas, &mut out, &mut ws);
            std::hint::black_box(&out);
        });
        push(&mut rows, &format!("combine dense n={n}"), t);
        let t = bench(budget(0.5), || {
            model.combine_sparse_into(idx, val, &thetas, &mut out, &mut ws);
            std::hint::black_box(&out);
        });
        push(&mut rows, &format!("combine sparse n={n}"), t);

        section(&format!("full fd-dsgd round n={n} ({local} local steps)"));
        let compute = NativeCompute::new(d, h, n, m); // threads: auto
        let serial = NativeCompute::new(d, h, n, m).with_threads(1);
        let lrs: Vec<f32> = (1..=local).map(|r| 0.02 / (r as f32).sqrt()).collect();
        let lx = rand_vec(&mut rng, n * local * m * d, 1.0);
        let ly = rand_labels(&mut rng, n * local * m);
        let cx = rand_vec(&mut rng, n * m * d, 1.0);
        let cy = rand_labels(&mut rng, n * m);
        let mix = MixView { dense: Some(&dense), sparse: &sparse };
        let mut front = thetas.clone();
        let mut back = vec![0.0f32; n * p];
        let mut local_losses = vec![0.0f64; n * local];
        let mut comm_losses = vec![0.0f64; n];
        for (label, c) in [("serial (threads=1)", &serial), ("threaded (auto)", &compute)] {
            let t = bench(budget(1.0), || {
                c.local_steps_all_into(&front, &lx, &ly, &lrs, &mut back, &mut local_losses)
                    .unwrap();
                std::mem::swap(&mut front, &mut back);
                c.dsgd_round_into(&mix, &front, &cx, &cy, 0.02, &mut back, &mut comm_losses)
                    .unwrap();
                std::mem::swap(&mut front, &mut back);
                std::hint::black_box(&front);
            });
            push(&mut rows, &format!("round n={n} {label}"), t);
        }
    }

    // ---- 4. sparse network stack: the graph/W/schedule axis at scale ----
    // No n×n array exists anywhere in this tier (Mat::zeros would trip its
    // debug guard): CSR-first W construction, power-iteration λ₂, and
    // per-round view derivation all run in O(E).
    {
        let n = if smoke() { 1_000 } else { 10_000 };
        section(&format!("sparse network stack n={n} (knn graph)"));
        let mut rng = Pcg64::seed(41);
        let t = bench(budget(1.0), || {
            let mut r = Pcg64::seed(41);
            std::hint::black_box(Graph::build(&Topology::KNearest { k: 3 }, n, &mut r).unwrap());
        });
        push(&mut rows, &format!("graph build knn n={n}"), t);

        let g = Graph::build(&Topology::KNearest { k: 3 }, n, &mut rng)?;
        let mut w = SparseW::empty();
        let t = bench(budget(1.0), || {
            mixing::build_sparse_into(&g, Scheme::Metropolis, &mut w);
            std::hint::black_box(&w);
        });
        push(&mut rows, &format!("build_sparse n={n}"), t);

        let t = bench(budget(1.0), || {
            std::hint::black_box(w.second_eig_magnitude());
        });
        push(&mut rows, &format!("lambda2 power-iter n={n}"), t);

        let mut cfg = decfl::config::ExperimentConfig::default();
        cfg.n = n;
        cfg.net_plan = "edge-drop".into();
        cfg.edge_drop = 0.05;
        let sched = decfl::graph::NetworkSchedule::from_config(&cfg, g, w.clone())?;
        let mut scratch = decfl::graph::ViewScratch::new();
        let mut round = 0usize;
        let t = bench(budget(1.0), || {
            round += 1;
            let v = sched.view_into(round, &mut scratch).unwrap();
            std::hint::black_box(v.active_directed_edges());
        });
        push(&mut rows, &format!("edge-drop view n={n}"), t);
    }

    // ---- optional JSON record (BENCH_3.json baseline) ----
    if let Ok(path) = std::env::var("DECFL_BENCH_JSON") {
        let mut out = String::from("{\n  \"bench\": \"bench_kernels\",\n  \"rows\": [\n");
        for (i, r) in rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"p50_s\": {:.9}, \"per_sec\": {:.3}}}{}\n",
                r.name,
                r.t.p50_s,
                r.t.per_sec(),
                if i + 1 < rows.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(&path, out)?;
        println!("\nwrote {} rows to {path}", rows.len());
    }
    Ok(())
}
