//! EXP-A3: DSGT's advantage over DSGD as a function of data heterogeneity —
//! the paper's core motivation for gradient tracking ("DSGT has the
//! advantages of dealing with non-identical datasets compared with DSGD").
//!
//!     cargo bench --bench bench_hetero

use decfl::benchutil::{full_scale, section};
use decfl::experiments::sweeps;

fn main() -> anyhow::Result<()> {
    let (steps, seeds): (usize, Vec<u64>) =
        if full_scale() { (2_000, vec![7, 8, 9]) } else { (600, vec![7, 8]) };
    section(&format!("EXP-A3: heterogeneity sweep (Q=1, T={steps})"));
    let rows = sweeps::hetero_sweep(&[0.0, 0.3, 0.6, 1.0], steps, &seeds)?;
    sweeps::print_hetero_table(&rows);
    let iid = rows.first().unwrap().advantage;
    let noniid = rows.last().unwrap().advantage;
    println!(
        "\npaper-vs-ours: the tracker cancels the heterogeneity-driven consensus \
         bias — DSGD/DSGT consensus-error ratio goes from {iid:.2}x (iid) to \
         {noniid:.2}x (het=1.0); the shared stationarity term stays equal, \
         matching the paper's 'the difference ... will be diminishing \
         asymptotically'."
    );
    Ok(())
}
