//! EXP-C1 bench: round-engine throughput and wire cost under every gossip
//! compressor — dense, identity (plumbing overhead), q8, q4, top-k — on one
//! shared base network, fused mode, native backend.
//!
//!     cargo bench --bench bench_compress
//!     DECFL_FULL=1  cargo bench --bench bench_compress   # paper-scale
//!     DECFL_SMOKE=1 cargo bench --bench bench_compress   # CI compile+run check

use decfl::benchutil::{bench, budget, full_scale, report, section, smoke};
use decfl::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use decfl::coordinator::{assemble, run_on};

fn main() -> anyhow::Result<()> {
    let (n, steps, q) = if full_scale() {
        (20, 2_000, 50)
    } else if smoke() {
        (6, 30, 3)
    } else {
        (12, 240, 6)
    };

    let mut cfg = ExperimentConfig::default();
    cfg.backend = Backend::Native;
    cfg.mode = Mode::Fused;
    cfg.algo = AlgoKind::FdDsgt; // two payload kinds — the expensive case
    cfg.n = n;
    cfg.hidden = 16;
    cfg.m = 10;
    cfg.q = q;
    cfg.total_steps = steps;
    cfg.eval_every = usize::MAX / 2; // final row only: time the rounds, not eval
    cfg.records_per_hospital = 120;
    cfg.topology = "er".into();

    println!(
        "gossip compression, fd-dsgt fused/native: n={n} steps={steps} q={q} ({} rounds)",
        steps.div_ceil(q)
    );

    cfg.compress = "none".into();
    let asm = assemble(&cfg)?; // shared base graph + cohort for every arm
    let mut dense_bytes = 0u64;
    for (comp, frac) in
        [("none", 0.1), ("identity", 0.1), ("q8", 0.1), ("q4", 0.1), ("topk", 0.1), ("topk", 0.05)]
    {
        cfg.compress = comp.into();
        cfg.topk_frac = frac;
        let label = decfl::compress::Spec::parse(comp, frac)?.label();
        let log = run_on(&cfg, &asm)?;
        let last = log.rows.last().unwrap();
        if comp == "none" {
            dense_bytes = last.bytes;
        }
        section(&format!("compress {label}"));
        let t = bench(budget(0.5), || {
            std::hint::black_box(run_on(&cfg, &asm).unwrap());
        });
        report(&format!("{label} ({} rounds)", last.comm_rounds), &t);
        let reduction =
            if last.bytes > 0 { dense_bytes as f64 / last.bytes as f64 } else { 1.0 };
        println!(
            "wire: {:.2} MB ({:.1}x vs dense), {} msgs, sim {:.2}s | final loss {:.4} acc {:.3}",
            last.bytes as f64 / 1e6,
            reduction,
            last.messages,
            last.sim_time_s,
            last.loss,
            last.accuracy,
        );
    }
    Ok(())
}
