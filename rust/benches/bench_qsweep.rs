//! EXP-A1: communication savings vs the local period Q (§2.3's motivation:
//! "communication rounds ... can be saved significantly without loss of
//! optimality").
//!
//!     cargo bench --bench bench_qsweep

use decfl::benchutil::{full_scale, section};
use decfl::experiments::sweeps;

fn main() -> anyhow::Result<()> {
    let (steps, qs): (usize, Vec<usize>) = if full_scale() {
        (10_000, vec![1, 5, 20, 100, 500])
    } else {
        (2_000, vec![1, 5, 20, 100])
    };
    let target = 0.45;

    section(&format!("EXP-A1: Q sweep (FD-DSGT, T={steps} local steps)"));
    let rows = sweeps::q_sweep(&qs, steps, target, 7)?;
    sweeps::print_q_table(&rows, target);

    // shape check vs the paper: larger Q ⇒ far fewer comm rounds/bytes at
    // (nearly) the same final loss
    let q1 = rows.first().unwrap();
    let qmax = rows.last().unwrap();
    println!(
        "\npaper-vs-ours: Q={} uses {:.0}x fewer bytes than Q=1 ({:.2} vs {:.2} MB), \
         final loss {:.4} vs {:.4} (paper: savings 'without loss of optimality')",
        qmax.q,
        q1.bytes as f64 / qmax.bytes as f64,
        qmax.bytes as f64 / 1e6,
        q1.bytes as f64 / 1e6,
        qmax.final_loss,
        q1.final_loss
    );
    std::fs::create_dir_all("out")?;
    std::fs::write(
        "out/qsweep.json",
        sweeps::rows_to_json(&rows, sweeps::q_row_json).to_string(),
    )?;
    Ok(())
}
