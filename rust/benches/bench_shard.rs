//! EXP-SH1 bench: sharded spill-backed node state vs resident stacks —
//! per-round throughput, pool traffic (loads/spills/hits), and the flat
//! hot-set residency as the fleet grows.
//!
//!     cargo bench --bench bench_shard
//!     DECFL_FULL=1  cargo bench --bench bench_shard   # paper-scale fleets
//!     DECFL_SMOKE=1 cargo bench --bench bench_shard   # CI compile+run check

use decfl::benchutil::{bench, budget, full_scale, report, section, smoke};
use decfl::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use decfl::coordinator::{assemble, run_on};
use decfl::engine::{RoundEngine, ShardedSync};

fn main() -> anyhow::Result<()> {
    let (ns, steps, q, shard_nodes, hot) = if full_scale() {
        (vec![256usize, 1024, 4096], 200, 20, 64, 4)
    } else if smoke() {
        (vec![8], 12, 3, 3, 2)
    } else {
        (vec![32, 128], 60, 6, 16, 2)
    };

    let mut cfg = ExperimentConfig::default();
    cfg.backend = Backend::Native;
    cfg.mode = Mode::Fused;
    cfg.algo = AlgoKind::FdDsgt;
    cfg.hidden = 16;
    cfg.m = 10;
    cfg.q = q;
    cfg.total_steps = steps;
    cfg.eval_every = usize::MAX / 2; // final row only: time the sweep, not eval
    cfg.records_per_hospital = 60;
    cfg.topology = "ring".into();
    if smoke() {
        // CI compose check (PR-10): the sharded sweep must run the encode
        // pipeline + robust combine + straggler schedule, not just the
        // honest mean path
        cfg.compress = "q8".into();
        cfg.robust_rule = "trimmed-mean".into();
        cfg.robust_trim = 0.4;
        cfg.compute_plan = "lognormal".into();
        cfg.compute_sigma = 0.7;
    }

    println!(
        "sharded node state, fd-dsgt fused/native: k={shard_nodes} hot={hot} steps={steps} q={q} ({} rounds)",
        steps.div_ceil(q)
    );

    for &n in &ns {
        cfg.n = n;
        cfg.shard_nodes = 0;
        let asm = assemble(&cfg)?; // shared cohort + graph for both drivers

        section(&format!("n={n} resident"));
        let t = bench(budget(0.5), || {
            std::hint::black_box(run_on(&cfg, &asm).unwrap());
        });
        report(&format!("resident n={n}"), &t);

        cfg.shard_nodes = shard_nodes;
        cfg.hot_shards = hot;
        section(&format!("n={n} sharded k={shard_nodes} h={hot}"));
        let t = bench(budget(0.5), || {
            std::hint::black_box(run_on(&cfg, &asm).unwrap());
        });
        report(&format!("sharded n={n}"), &t);

        // one instrumented run for the pool counters + residency bound
        let engine = RoundEngine::from_config(&cfg);
        let mut drv = ShardedSync::new(&cfg, &asm.ds, &asm.graph, &asm.w)?;
        engine.run(&mut drv)?;
        let st = drv.pool_stats();
        println!(
            "pool: {} resident rows (bound {}), {} loads, {} spills ({} writebacks), {} hits",
            drv.resident_rows(),
            shard_nodes * hot,
            st.loads,
            st.spills,
            st.writebacks,
            st.hits
        );
        cfg.shard_nodes = 0;
    }
    Ok(())
}
