//! EXP-N1 bench: round-engine throughput and wire cost under every
//! network plan — static, rewire, edge dropout, node churn — on one shared
//! base network, fused mode, native backend.
//!
//!     cargo bench --bench bench_churn
//!     DECFL_FULL=1  cargo bench --bench bench_churn   # paper-scale
//!     DECFL_SMOKE=1 cargo bench --bench bench_churn   # CI compile+run check

use decfl::benchutil::{bench, budget, full_scale, report, section, smoke};
use decfl::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use decfl::coordinator::{assemble, run_on};

fn main() -> anyhow::Result<()> {
    let (n, steps, q) = if full_scale() {
        (20, 2_000, 50)
    } else if smoke() {
        (6, 30, 3)
    } else {
        (12, 240, 6)
    };

    let mut cfg = ExperimentConfig::default();
    cfg.backend = Backend::Native;
    cfg.mode = Mode::Fused;
    cfg.algo = AlgoKind::FdDsgt;
    cfg.n = n;
    cfg.hidden = 16;
    cfg.m = 10;
    cfg.q = q;
    cfg.total_steps = steps;
    cfg.eval_every = usize::MAX / 2; // final row only: time the rounds, not eval
    cfg.records_per_hospital = 120;
    cfg.topology = "er".into();
    cfg.rewire_every = 3;
    cfg.edge_drop = 0.3;
    cfg.churn = 0.2;

    println!(
        "time-varying network plans, fd-dsgt fused/native: n={n} steps={steps} q={q} ({} rounds)",
        steps.div_ceil(q)
    );

    cfg.net_plan = "static".into();
    let asm = assemble(&cfg)?; // shared base graph + cohort for every plan
    for plan in ["static", "rewire", "edge-drop", "churn"] {
        cfg.net_plan = plan.into();
        let log = run_on(&cfg, &asm)?;
        let last = log.rows.last().unwrap();
        section(&format!("plan {plan}"));
        let t = bench(budget(0.5), || {
            std::hint::black_box(run_on(&cfg, &asm).unwrap());
        });
        report(&format!("{plan} ({} rounds)", last.comm_rounds), &t);
        println!(
            "wire: {:.2} MB, {} msgs, sim {:.2}s | final loss {:.4}",
            last.bytes as f64 / 1e6,
            last.messages,
            last.sim_time_s,
            last.loss
        );
    }
    Ok(())
}
