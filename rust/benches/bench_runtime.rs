//! EXP-P: runtime micro-benchmarks — the §Perf measurement harness.
//!
//! Times every PJRT artifact call, the native twin, and a full fused
//! communication round, so the §Perf log in EXPERIMENTS.md has stable
//! numbers to cite.  Skips PJRT sections when artifacts are absent.
//!
//!     cargo bench --bench bench_runtime

use decfl::benchutil::{bench, report, section};
use decfl::coordinator::{Compute, NativeCompute, PjrtCompute};
use decfl::rng::Pcg64;

fn rand_vec(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

fn rand_labels(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 }).collect()
}

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    let mut rng = Pcg64::seed(0);

    let pjrt = if dir.join("manifest.json").exists() {
        Some(PjrtCompute::load(&dir)?)
    } else {
        eprintln!("artifacts missing — PJRT sections skipped");
        None
    };

    // shapes (paper config)
    let (n, d, h, m, shard) = pjrt
        .as_ref()
        .map(|p| {
            let s = p.engine().shapes();
            (s.n, s.d, s.hidden, s.m, s.shard)
        })
        .unwrap_or((20, 42, 32, 20, 500));
    let native = NativeCompute::new(d, h, n, m);
    let p = native.dims().2;

    let theta = rand_vec(&mut rng, p, 0.2);
    let x = rand_vec(&mut rng, m * d, 1.0);
    let y = rand_labels(&mut rng, m);
    let big_theta = rand_vec(&mut rng, n * p, 0.2);
    let wrow = vec![1.0f32 / n as f32; n];
    let g = decfl::graph::Graph::build(
        &decfl::graph::Topology::RandomGeometric { radius: 0.35 },
        n,
        &mut Pcg64::seed(1),
    )?;
    let w = decfl::mixing::to_f32(&decfl::mixing::build(&g, decfl::mixing::Scheme::Metropolis));
    let bx = rand_vec(&mut rng, n * m * d, 1.0);
    let by = rand_labels(&mut rng, n * m);
    let y_tr = rand_vec(&mut rng, n * p, 0.1);
    let g_old = rand_vec(&mut rng, n * p, 0.1);

    let ds = decfl::data::generate(&decfl::data::DataConfig {
        n_hospitals: n,
        records_per_hospital: shard,
        records_jitter: 0,
        ..Default::default()
    })?
    .resampled_to(shard);

    if let Some(pjrt) = &pjrt {
        section("PJRT artifact call latency (paper shapes: N=20, P=1409, m=20)");
        pjrt.engine().warmup(&["grad_step", "combine", "local_steps", "dsgd_round", "dsgt_round", "eval_full"])?;
        report("grad_step", &bench(2.0, || {
            std::hint::black_box(pjrt.grad_step(&theta, &x, &y).unwrap());
        }));
        report("combine (1 node gossip mix)", &bench(2.0, || {
            std::hint::black_box(pjrt.combine(&wrow, &big_theta).unwrap());
        }));
        let ql = pjrt.local_steps_len().unwrap();
        let lbx = rand_vec(&mut rng, ql * m * d, 1.0);
        let lby = rand_labels(&mut rng, ql * m);
        let lrs: Vec<f32> = (1..=ql).map(|r| 0.02 / (r as f32).sqrt()).collect();
        report(&format!("local_steps (Q-1 = {ql} scan)"), &bench(3.0, || {
            std::hint::black_box(pjrt.local_steps(&theta, &lbx, &lby, &lrs).unwrap());
        }));
        let lbx_all = rand_vec(&mut rng, n * ql * m * d, 1.0);
        let lby_all = rand_labels(&mut rng, n * ql * m);
        report(&format!("local_steps_all artifact ({ql} steps)"), &bench(5.0, || {
            std::hint::black_box(
                pjrt.engine().execute("local_steps_all", &[&big_theta, &lbx_all, &lby_all, &lrs]).unwrap(),
            );
        }));
        report("dsgd_round (whole network)", &bench(3.0, || {
            std::hint::black_box(pjrt.dsgd_round(&w, &big_theta, &bx, &by, 0.02).unwrap());
        }));
        report("dsgt_round (whole network)", &bench(3.0, || {
            std::hint::black_box(
                pjrt.dsgt_round(&w, &big_theta, &y_tr, &g_old, &bx, &by, 0.02).unwrap(),
            );
        }));
        report("eval_full (20 x 500 records)", &bench(3.0, || {
            std::hint::black_box(pjrt.eval_full(&big_theta, &ds.shards).unwrap());
        }));
    }

    section("native twin (same ops, pure rust)");
    report("grad_step", &bench(2.0, || {
        std::hint::black_box(native.grad_step(&theta, &x, &y).unwrap());
    }));
    report("combine", &bench(2.0, || {
        std::hint::black_box(native.combine(&wrow, &big_theta).unwrap());
    }));
    report("dsgd_round", &bench(2.0, || {
        std::hint::black_box(native.dsgd_round(&w, &big_theta, &bx, &by, 0.02).unwrap());
    }));
    report("dsgt_round", &bench(2.0, || {
        std::hint::black_box(
            native.dsgt_round(&w, &big_theta, &y_tr, &g_old, &bx, &by, 0.02).unwrap(),
        );
    }));
    report("eval_full", &bench(2.0, || {
        std::hint::black_box(native.eval_full(&big_theta, &ds.shards).unwrap());
    }));

    section("end-to-end round throughput (FD-DSGT, fused driver)");
    for backend in ["pjrt", "native"] {
        if backend == "pjrt" && pjrt.is_none() {
            continue;
        }
        let mut cfg = decfl::config::ExperimentConfig::default();
        cfg.backend = if backend == "pjrt" {
            decfl::config::Backend::Pjrt
        } else {
            decfl::config::Backend::Native
        };
        cfg.total_steps = 300; // 3 comm rounds per iteration
        cfg.eval_every = 1000; // no intermediate evals: time the hot loop
        let asm = decfl::coordinator::assemble(&cfg)?;
        let compute = decfl::coordinator::make_compute(&cfg)?;
        let t = bench(10.0, || {
            let log = decfl::coordinator::fused::train(&cfg, compute.as_ref(), &asm.ds, &asm.graph, &asm.w).unwrap();
            std::hint::black_box(log.rows.len());
        });
        println!(
            "{backend:<8} 3 rounds (300 local steps): p50 {} → {:.1} local steps/s",
            decfl::benchutil::fmt_s(t.p50_s),
            300.0 / t.p50_s
        );
    }
    Ok(())
}
