//! EXP-F1L + EXP-F1R: regenerate both panels of the paper's Figure 1 and
//! time the substrates involved (graph build, layout, t-SNE).
//!
//!     cargo bench --bench bench_fig1            # reduced t-SNE size
//!     DECFL_FULL=1 cargo bench --bench bench_fig1

use decfl::benchutil::{bench, full_scale, report, section};
use decfl::config::ExperimentConfig;
use decfl::experiments::fig1;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    // Fig. 1R of the paper shows strongly separated hospitals; regenerate at
    // the heterogeneity level that matches that visual (training default 0.6)
    cfg.heterogeneity = 1.0;
    let per = if full_scale() { 150 } else { 100 };

    section("EXP-F1L: hospital network (paper Fig. 1 left)");
    let rep = fig1::hospital_graph(&cfg)?;
    rep.print_summary();
    std::fs::create_dir_all("out")?;
    std::fs::write("out/fig1_graph.json", rep.to_json().to_string())?;
    let t = bench(1.0, || {
        let r = fig1::hospital_graph(&cfg).unwrap();
        std::hint::black_box(r.spectral_gap);
    });
    report("graph + layout + spectra", &t);

    section("EXP-F1R: t-SNE of 3 hospitals (paper Fig. 1 right)");
    let rep = fig1::tsne_hospitals(&cfg, &[0, 1, 2], per, 30.0)?;
    rep.print_summary();
    std::fs::write("out/fig1_tsne.json", rep.to_json().to_string())?;
    println!(
        "paper-vs-ours: paper shows visibly separated per-hospital clusters; \
         our silhouette = {:.3} ({} pts/hospital) — separated iff > ~0.25",
        rep.silhouette, per
    );
    let t = bench(3.0, || {
        let r = fig1::tsne_hospitals(&cfg, &[0, 1, 2], per.min(60), 20.0).unwrap();
        std::hint::black_box(r.silhouette);
    });
    report(&format!("t-SNE ({} pts)", 3 * per.min(60)), &t);
    Ok(())
}
