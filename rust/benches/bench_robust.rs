//! EXP-R1 bench: round-engine throughput under the adversarial axes —
//! honest baseline, sign-flip attack screened by each robust combine rule,
//! and the DP clip+noise layer — on one shared base network, fused mode,
//! native backend.  Shows what each defense costs in wall time relative to
//! the pinned plain-mean path.
//!
//!     cargo bench --bench bench_robust
//!     DECFL_FULL=1  cargo bench --bench bench_robust   # paper-scale
//!     DECFL_SMOKE=1 cargo bench --bench bench_robust   # CI compile+run check

use decfl::benchutil::{bench, budget, full_scale, report, section, smoke};
use decfl::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use decfl::coordinator::{assemble, run_on};

fn main() -> anyhow::Result<()> {
    let (n, steps, q) = if full_scale() {
        (20, 2_000, 50)
    } else if smoke() {
        (8, 32, 4)
    } else {
        (12, 240, 6)
    };

    let mut cfg = ExperimentConfig::default();
    cfg.backend = Backend::Native;
    cfg.mode = Mode::Fused;
    cfg.algo = AlgoKind::FdDsgt;
    cfg.n = n;
    cfg.hidden = 16;
    cfg.m = 10;
    cfg.q = q;
    cfg.total_steps = steps;
    cfg.eval_every = usize::MAX / 2; // final row only: time the rounds, not eval
    cfg.records_per_hospital = 120;
    cfg.topology = "er".into();

    println!(
        "adversarial axes, fd-dsgt fused/native: n={n} steps={steps} q={q} ({} rounds)",
        steps.div_ceil(q)
    );

    let asm = assemble(&cfg)?; // shared base graph + cohort for every cell
    let cells: Vec<(&str, ExperimentConfig)> = {
        let mut v = vec![("honest mean (pinned)", cfg.clone())];
        for rule in ["mean", "trimmed-mean", "median", "krum"] {
            let mut c = cfg.clone();
            c.attack_plan = "sign-flip".into();
            c.attack_frac = 0.25;
            c.robust_rule = rule.into();
            v.push(("under sign-flip f=0.25", c));
        }
        let mut c = cfg.clone();
        c.dp = "gaussian".into();
        c.dp_clip = 10.0;
        v.push(("dp gaussian clip=10", c));
        v
    };

    for (what, c) in &cells {
        let log = run_on(c, &asm)?;
        let last = log.rows.last().unwrap();
        section(&format!("{} · {what}", c.robust_rule));
        let t = bench(budget(0.5), || {
            std::hint::black_box(run_on(c, &asm).unwrap());
        });
        report(&format!("{} · {what} ({} rounds)", c.robust_rule, last.comm_rounds), &t);
        println!(
            "wire {:.2} MB | quarantined {} | dp_eps {:.3} | final loss {:.4} acc {:.3}",
            last.bytes as f64 / 1e6,
            last.quarantined,
            last.dp_epsilon,
            last.loss,
            last.accuracy
        );
    }
    Ok(())
}
