//! EXP-P2: serial vs threaded native whole-network ops — the round engine's
//! hot path (`local_steps_all`, plus `dsgd_round` / `eval_full`) at growing
//! node counts.  Per-node work is embarrassingly parallel over disjoint
//! `[i*p..(i+1)*p]` slices; the bench verifies bitwise-equal outputs, then
//! records the speedup.
//!
//!     cargo bench --bench bench_engine

use decfl::benchutil::{bench, budget, report, section, smoke};
use decfl::coordinator::{Compute, NativeCompute};
use decfl::rng::Pcg64;

fn rand_vec(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

fn rand_labels(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 }).collect()
}

fn main() -> anyhow::Result<()> {
    let (d, h, m, local) = (42usize, 32usize, 20usize, 4usize);
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    println!("native whole-network ops, serial vs threaded ({cores} cores), d={d} h={h} m={m}");

    let sizes: &[usize] = if smoke() { &[10] } else { &[10, 50, 200] };
    for &n in sizes {
        let serial = NativeCompute::new(d, h, n, m).with_threads(1);
        let threaded = NativeCompute::new(d, h, n, m); // 0 = auto: one per core
        let p = serial.dims().2;
        let mut rng = Pcg64::seed(7);
        let theta = rand_vec(&mut rng, n * p, 0.2);
        let lx = rand_vec(&mut rng, n * local * m * d, 1.0);
        let ly = rand_labels(&mut rng, n * local * m);
        let lrs: Vec<f32> = (1..=local).map(|r| 0.02 / (r as f32).sqrt()).collect();
        let cx = rand_vec(&mut rng, n * m * d, 1.0);
        let cy = rand_labels(&mut rng, n * m);
        let w = vec![1.0f32 / n as f32; n * n];

        // determinism pin before timing anything
        let a = serial.local_steps_all(&theta, &lx, &ly, &lrs)?;
        let b = threaded.local_steps_all(&theta, &lx, &ly, &lrs)?;
        anyhow::ensure!(a.0 == b.0 && a.1 == b.1, "threaded result differs at n={n}");

        section(&format!("local_steps_all  n={n} ({local} steps/node)"));
        let ts = bench(budget(1.0), || {
            std::hint::black_box(serial.local_steps_all(&theta, &lx, &ly, &lrs).unwrap());
        });
        let tp = bench(budget(1.0), || {
            std::hint::black_box(threaded.local_steps_all(&theta, &lx, &ly, &lrs).unwrap());
        });
        report("serial (threads=1)", &ts);
        report(&format!("threaded (auto, {cores} cores)"), &tp);
        println!("speedup: {:.2}x", ts.p50_s / tp.p50_s);

        section(&format!("dsgd_round       n={n}"));
        let ts = bench(budget(0.5), || {
            std::hint::black_box(serial.dsgd_round(&w, &theta, &cx, &cy, 0.02).unwrap());
        });
        let tp = bench(budget(0.5), || {
            std::hint::black_box(threaded.dsgd_round(&w, &theta, &cx, &cy, 0.02).unwrap());
        });
        report("serial (threads=1)", &ts);
        report(&format!("threaded (auto, {cores} cores)"), &tp);
        println!("speedup: {:.2}x", ts.p50_s / tp.p50_s);
    }

    // eval_full over real shards at one representative size
    let n = if smoke() { 10 } else { 50 };
    let ds = decfl::data::generate(&decfl::data::DataConfig {
        n_hospitals: n,
        records_per_hospital: 200,
        records_jitter: 0,
        heterogeneity: 0.5,
        ..decfl::data::DataConfig::default()
    })?;
    let serial = NativeCompute::new(ds.d, h, n, m).with_threads(1);
    let threaded = NativeCompute::new(ds.d, h, n, m);
    let p = serial.dims().2;
    let mut rng = Pcg64::seed(9);
    let theta = rand_vec(&mut rng, n * p, 0.2);
    let a = serial.eval_full(&theta, &ds.shards)?;
    let b = threaded.eval_full(&theta, &ds.shards)?;
    anyhow::ensure!(a == b, "threaded eval_full differs");
    section(&format!("eval_full        n={n} (200 records/shard)"));
    let ts = bench(budget(0.5), || {
        std::hint::black_box(serial.eval_full(&theta, &ds.shards).unwrap());
    });
    let tp = bench(budget(0.5), || {
        std::hint::black_box(threaded.eval_full(&theta, &ds.shards).unwrap());
    });
    report("serial (threads=1)", &ts);
    report(&format!("threaded (auto, {cores} cores)"), &tp);
    println!("speedup: {:.2}x", ts.p50_s / tp.p50_s);

    Ok(())
}
