//! EXP-P2: serial vs threaded native whole-network ops — the round engine's
//! hot path (`local_steps_all`, plus `dsgd_round` / `eval_full`) at growing
//! node counts.  Per-node work is embarrassingly parallel over disjoint
//! `[i*p..(i+1)*p]` slices; the bench verifies bitwise-equal outputs, then
//! records the speedup.
//!
//!     cargo bench --bench bench_engine

use decfl::benchutil::{bench, budget, report, section, smoke};
use decfl::coordinator::{Compute, NativeCompute};
use decfl::rng::Pcg64;

fn rand_vec(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

fn rand_labels(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 }).collect()
}

fn main() -> anyhow::Result<()> {
    let (d, h, m, local) = (42usize, 32usize, 20usize, 4usize);
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    println!("native whole-network ops, serial vs threaded ({cores} cores), d={d} h={h} m={m}");

    let sizes: &[usize] = if smoke() { &[10] } else { &[10, 50, 200] };
    for &n in sizes {
        let serial = NativeCompute::new(d, h, n, m).with_threads(1);
        let threaded = NativeCompute::new(d, h, n, m); // 0 = auto: one per core
        let p = serial.dims().2;
        let mut rng = Pcg64::seed(7);
        let theta = rand_vec(&mut rng, n * p, 0.2);
        let lx = rand_vec(&mut rng, n * local * m * d, 1.0);
        let ly = rand_labels(&mut rng, n * local * m);
        let lrs: Vec<f32> = (1..=local).map(|r| 0.02 / (r as f32).sqrt()).collect();
        let cx = rand_vec(&mut rng, n * m * d, 1.0);
        let cy = rand_labels(&mut rng, n * m);
        let w = vec![1.0f32 / n as f32; n * n];

        // determinism pin before timing anything
        let a = serial.local_steps_all(&theta, &lx, &ly, &lrs)?;
        let b = threaded.local_steps_all(&theta, &lx, &ly, &lrs)?;
        anyhow::ensure!(a.0 == b.0 && a.1 == b.1, "threaded result differs at n={n}");

        section(&format!("local_steps_all  n={n} ({local} steps/node)"));
        let ts = bench(budget(1.0), || {
            std::hint::black_box(serial.local_steps_all(&theta, &lx, &ly, &lrs).unwrap());
        });
        let tp = bench(budget(1.0), || {
            std::hint::black_box(threaded.local_steps_all(&theta, &lx, &ly, &lrs).unwrap());
        });
        report("serial (threads=1)", &ts);
        report(&format!("threaded (auto, {cores} cores)"), &tp);
        println!("speedup: {:.2}x", ts.p50_s / tp.p50_s);

        section(&format!("dsgd_round       n={n}"));
        let ts = bench(budget(0.5), || {
            std::hint::black_box(serial.dsgd_round(&w, &theta, &cx, &cy, 0.02).unwrap());
        });
        let tp = bench(budget(0.5), || {
            std::hint::black_box(threaded.dsgd_round(&w, &theta, &cx, &cy, 0.02).unwrap());
        });
        report("serial (threads=1)", &ts);
        report(&format!("threaded (auto, {cores} cores)"), &tp);
        println!("speedup: {:.2}x", ts.p50_s / tp.p50_s);
    }

    // network-axis case: a full dsgd gossip round at n = 10⁴ through the
    // sparse-only MixView (dense: None) — no n×n buffer exists; this is the
    // shape BENCH_6.json tracks.  Schedule refresh included: every round
    // derives a fresh edge-drop view from the grow-only scratch.
    {
        let n = if smoke() { 1_000 } else { 10_000 };
        let m_net = 4usize; // smaller batch: the axis under test is n, not m
        let mut rng = Pcg64::seed(23);
        let g = decfl::graph::Graph::build(
            &decfl::graph::Topology::KNearest { k: 3 },
            n,
            &mut rng,
        )?;
        let w = decfl::mixing::build_sparse(&g, decfl::mixing::Scheme::Metropolis);
        let mut cfg = decfl::config::ExperimentConfig::default();
        cfg.n = n;
        cfg.net_plan = "edge-drop".into();
        cfg.edge_drop = 0.05;
        let sched = decfl::graph::NetworkSchedule::from_config(&cfg, g, w)?;
        let mut scratch = decfl::graph::ViewScratch::new();

        let serial = NativeCompute::new(d, h, n, m_net).with_threads(1);
        let threaded = NativeCompute::new(d, h, n, m_net);
        let p = serial.dims().2;
        let theta = rand_vec(&mut rng, n * p, 0.2);
        let cx = rand_vec(&mut rng, n * m_net * d, 1.0);
        let cy = rand_labels(&mut rng, n * m_net);
        let mut out = vec![0.0f32; n * p];
        let mut losses = vec![0.0f64; n];
        section(&format!("sparse gossip round n={n} (knn graph, edge-drop views)"));
        let mut round = 0usize;
        let mut run = |c: &NativeCompute| {
            round += 1;
            let v = sched.view_into(round, &mut scratch).unwrap();
            let mix = decfl::coordinator::compute::MixView { dense: None, sparse: v.w };
            c.dsgd_round_into(&mix, &theta, &cx, &cy, 0.02, &mut out, &mut losses).unwrap();
            std::hint::black_box(&out);
        };
        let ts = bench(budget(1.0), || run(&serial));
        let tp = bench(budget(1.0), || run(&threaded));
        report("serial (threads=1)", &ts);
        report(&format!("threaded (auto, {cores} cores)"), &tp);
        println!("speedup: {:.2}x", ts.p50_s / tp.p50_s);
    }

    // eval_full over real shards at one representative size
    let n = if smoke() { 10 } else { 50 };
    let ds = decfl::data::generate(&decfl::data::DataConfig {
        n_hospitals: n,
        records_per_hospital: 200,
        records_jitter: 0,
        heterogeneity: 0.5,
        ..decfl::data::DataConfig::default()
    })?;
    let serial = NativeCompute::new(ds.d, h, n, m).with_threads(1);
    let threaded = NativeCompute::new(ds.d, h, n, m);
    let p = serial.dims().2;
    let mut rng = Pcg64::seed(9);
    let theta = rand_vec(&mut rng, n * p, 0.2);
    let a = serial.eval_full(&theta, &ds.shards)?;
    let b = threaded.eval_full(&theta, &ds.shards)?;
    anyhow::ensure!(a == b, "threaded eval_full differs");
    section(&format!("eval_full        n={n} (200 records/shard)"));
    let ts = bench(budget(0.5), || {
        std::hint::black_box(serial.eval_full(&theta, &ds.shards).unwrap());
    });
    let tp = bench(budget(0.5), || {
        std::hint::black_box(threaded.eval_full(&theta, &ds.shards).unwrap());
    });
    report("serial (threads=1)", &ts);
    report(&format!("threaded (auto, {cores} cores)"), &tp);
    println!("speedup: {:.2}x", ts.p50_s / tp.p50_s);

    Ok(())
}
