//! EXP-T1: Theorem 1 — linear speedup of DSGT (Q=1) in the number of nodes.
//!
//!     cargo bench --bench bench_speedup
//!     DECFL_FULL=1 cargo bench --bench bench_speedup   # larger T, more seeds

use decfl::benchutil::{full_scale, section};
use decfl::experiments::speedup;

fn main() -> anyhow::Result<()> {
    let (t_steps, seeds): (usize, Vec<u64>) = if full_scale() {
        (1_000, vec![7, 8, 9, 10, 11])
    } else {
        (300, vec![7, 8, 9])
    };
    let ns = [4usize, 8, 16, 32];

    section(&format!("EXP-T1: Theorem 1 speedup (T={t_steps}, {} seeds)", seeds.len()));
    let res = speedup::run(&ns, t_steps, &seeds)?;
    res.print_table();
    println!(
        "linear-speedup consistent: {}",
        if res.supports_linear_speedup() { "YES" } else { "NO" }
    );
    std::fs::create_dir_all("out")?;
    std::fs::write("out/speedup.json", res.to_json().to_string())?;
    println!("wrote out/speedup.json");
    Ok(())
}
