//! EXP-F2: regenerate the paper's Figure 2 — convergence of DSGD, DSGT,
//! FD-DSGD, FD-DSGT vs communication rounds (N=20, m=20, Q=100,
//! α_r = 0.02/√r).
//!
//! Default: reduced budget on the PJRT artifacts when present (falls back to
//! native).  `DECFL_FULL=1` runs the paper-scale budget (10,000 local steps,
//! 100 comm rounds for the FD variants).
//!
//!     cargo bench --bench bench_fig2

use decfl::benchutil::{full_scale, section};
use decfl::config::{Backend, ExperimentConfig};
use decfl::experiments::fig2;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    let have_artifacts =
        std::path::Path::new(&cfg.artifacts_dir).join("manifest.json").exists();
    if !have_artifacts {
        cfg.backend = Backend::Native;
    }
    if full_scale() {
        cfg.total_steps = 10_000; // paper budget: 100 comm rounds at Q=100
        cfg.eval_every = 2;
    } else {
        cfg.total_steps = 3_000; // 30 comm rounds — same shape, faster
        cfg.eval_every = 1;
    }

    section(&format!(
        "EXP-F2 (backend {:?}, T={}, Q={})",
        cfg.backend, cfg.total_steps, cfg.q
    ));
    let wall = std::time::Instant::now();
    let res = fig2::run(&cfg)?;
    res.print_table();
    println!();
    for f in res.findings() {
        println!("finding: {f}");
    }
    println!(
        "\npaper-vs-ours (shape checks): FD curves must dominate classic per comm \
         round (paper: 'FD algorithms converge much faster ... in terms of \
         communication rounds'); DSGT gap ≤ DSGD gap (paper: 'DSGT in general can \
         achieve a smaller optimality gap')."
    );
    std::fs::create_dir_all("out")?;
    std::fs::write("out/fig2.json", res.to_json().to_string())?;
    println!("wrote out/fig2.json ({:.1}s total)", wall.elapsed().as_secs_f64());
    Ok(())
}
