//! EXP-A4: fully decentralized FD-DSGT vs star-network FedAvg vs the
//! fictitious fusion center (§1's comparison).
//!
//!     cargo bench --bench bench_baselines

use decfl::benchutil::{full_scale, section};
use decfl::experiments::sweeps;

fn main() -> anyhow::Result<()> {
    let steps = if full_scale() { 5_000 } else { 1_500 };
    let q = 25;
    section(&format!("EXP-A4: baselines (T={steps}, Q={q})"));
    let rows = sweeps::baseline_compare(steps, q, 7)?;
    sweeps::print_baseline_table(&rows);
    println!(
        "\npaper-vs-ours: all three reach comparable loss at equal step budget; \
         the fusion center pays zero communication but requires pooling patient \
         records (HIPAA-infeasible — the paper's premise); FedAvg requires a \
         trusted server; FD-DSGT needs neither."
    );
    Ok(())
}
