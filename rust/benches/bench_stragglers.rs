//! EXP-S1 bench: round-engine throughput, true local work, and
//! straggler-aware simulated time under every compute plan — uniform,
//! fixed tiers, lognormal speeds, dropout preemption — on one shared base
//! network, fused mode, native backend.
//!
//!     cargo bench --bench bench_stragglers
//!     DECFL_FULL=1  cargo bench --bench bench_stragglers   # paper-scale
//!     DECFL_SMOKE=1 cargo bench --bench bench_stragglers   # CI compile+run check

use decfl::benchutil::{bench, budget, full_scale, report, section, smoke};
use decfl::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use decfl::coordinator::{assemble, run_on};

fn main() -> anyhow::Result<()> {
    let (n, steps, q) = if full_scale() {
        (20, 2_000, 50)
    } else if smoke() {
        (6, 30, 3)
    } else {
        (12, 240, 6)
    };

    let mut cfg = ExperimentConfig::default();
    cfg.backend = Backend::Native;
    cfg.mode = Mode::Fused;
    cfg.algo = AlgoKind::FdDsgt;
    cfg.n = n;
    cfg.hidden = 16;
    cfg.m = 10;
    cfg.q = q;
    cfg.total_steps = steps;
    cfg.eval_every = usize::MAX / 2; // final row only: time the rounds, not eval
    cfg.records_per_hospital = 120;
    cfg.topology = "er".into();
    cfg.compute_tiers = "1.0,0.5,0.25".into();
    cfg.compute_sigma = 0.6;
    cfg.slow_frac = 0.3;

    println!(
        "straggler compute plans, fd-dsgt fused/native: n={n} steps={steps} q={q} ({} rounds)",
        steps.div_ceil(q)
    );

    cfg.compute_plan = "uniform".into();
    let asm = assemble(&cfg)?; // shared base graph + cohort for every plan
    for plan in ["uniform", "fixed-tiers", "lognormal", "dropout"] {
        cfg.compute_plan = plan.into();
        let log = run_on(&cfg, &asm)?;
        let last = log.rows.last().unwrap();
        section(&format!("plan {plan}"));
        let t = bench(budget(0.5), || {
            std::hint::black_box(run_on(&cfg, &asm).unwrap());
        });
        report(&format!("{plan} ({} rounds)", last.comm_rounds), &t);
        println!(
            "work: {} local steps/node, sim {:.2}s | wire {:.2} MB | final loss {:.4} acc {:.3}",
            last.local_steps,
            last.sim_time_s,
            last.bytes as f64 / 1e6,
            last.loss,
            last.accuracy
        );
    }
    Ok(())
}
