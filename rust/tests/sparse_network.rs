//! Acceptance: the network axis is sparse-native at 10⁵ nodes.
//!
//! Builds a 100 000-node kNN hospital graph, its Metropolis mixing matrix,
//! and a time-varying schedule, then derives three per-round views — all in
//! O(E).  No n×n array can exist anywhere on this path: `Mat::zeros` carries
//! a debug guard that panics on any square allocation past 8192 nodes, and
//! integration tests run with debug assertions on, so merely completing this
//! test certifies the dense matrix was never materialized.

use decfl::config::ExperimentConfig;
use decfl::graph::{Graph, NetworkSchedule, Topology, ViewScratch};
use decfl::mixing::{self, Scheme};
use decfl::rng::Pcg64;

const N: usize = 100_000;

fn setup(plan: &str, p: f64) -> (NetworkSchedule, usize) {
    let mut rng = Pcg64::new(9, 0x6EA9);
    let graph = Graph::build(&Topology::KNearest { k: 3 }, N, &mut rng).unwrap();
    let w = mixing::build_sparse(&graph, Scheme::Metropolis);
    let base_nnz = w.nnz();
    let mut cfg = ExperimentConfig::default();
    cfg.n = N;
    cfg.net_plan = plan.into();
    cfg.edge_drop = if plan == "edge-drop" { p } else { 0.0 };
    cfg.churn = if plan == "churn" { p } else { 0.0 };
    (NetworkSchedule::from_config(&cfg, graph, w).unwrap(), base_nnz)
}

/// Structural checks a per-round view must satisfy, applied to a stride of
/// sampled rows (full-row scans at every node would dominate the test).
fn check_view(view: &decfl::graph::NetView, base_nnz: usize) {
    assert_eq!(view.n(), N);
    let directed = view.active_directed_edges();
    assert!(directed > 0, "round view lost every edge");
    // dropping edges or nodes only removes entries, never adds
    let nnz: usize = (0..N).map(|i| view.sparse_row(i).0.len()).sum();
    assert!(nnz <= base_nnz, "round nnz {nnz} exceeds base {base_nnz}");
    for i in (0..N).step_by(9973) {
        let (idx, val) = view.sparse_row(i);
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "row {i} not ascending");
        if !view.online[i] {
            assert_eq!((idx, val), (&[i as u32][..], &[1.0f32][..]));
            continue;
        }
        // row-stochastic within f32 accumulation error
        let sum: f64 = val.iter().map(|&v| v as f64).sum();
        assert!((sum - 1.0).abs() < 1e-4, "row {i} sums to {sum}");
        assert!(idx.binary_search(&(i as u32)).is_ok(), "row {i} lost its diagonal");
        // symmetric bitwise: surviving off-diagonal weights are untouched
        // base entries, so W[i,j] and W[j,i] agree exactly
        for (&j, &v) in idx.iter().zip(val) {
            if j as usize == i {
                continue;
            }
            let (jdx, jval) = view.sparse_row(j as usize);
            let pos = jdx.binary_search(&(i as u32)).expect("asymmetric support");
            assert_eq!(jval[pos].to_bits(), v.to_bits(), "W[{i},{j}] != W[{j},{i}]");
        }
    }
}

#[test]
fn hundred_thousand_nodes_edge_dropout_three_rounds() {
    let (sched, base_nnz) = setup("edge-drop", 0.01);
    assert_eq!(sched.base_nnz(), base_nnz);
    let mut scratch = ViewScratch::new();
    for round in 1..=3 {
        let view = sched.view_into(round, &mut scratch).unwrap();
        check_view(&view, base_nnz);
        // deterministic in (seed, round): a fresh scratch re-derives the
        // identical CSR payload
        let row = {
            let (idx, val) = view.sparse_row(N / 2);
            (idx.to_vec(), val.to_vec())
        };
        let mut fresh = ViewScratch::new();
        let again = sched.view_into(round, &mut fresh).unwrap();
        let (idx2, val2) = again.sparse_row(N / 2);
        assert_eq!((&row.0[..], &row.1[..]), (idx2, val2), "round {round} not replayable");
    }
}

#[test]
fn hundred_thousand_nodes_node_churn_three_rounds() {
    let (sched, base_nnz) = setup("churn", 0.01);
    let mut scratch = ViewScratch::new();
    for round in 1..=3 {
        let view = sched.view_into(round, &mut scratch).unwrap();
        check_view(&view, base_nnz);
        // every online row references only online partners
        for i in (0..N).step_by(9973) {
            if !view.online[i] {
                continue;
            }
            let (idx, _) = view.sparse_row(i);
            for &j in idx {
                assert!(view.online[j as usize], "online row {i} gossips with offline {j}");
            }
        }
    }
}
