//! Integration: compressed-gossip correctness pins.
//!
//! Three contracts from DESIGN.md §10:
//! 1. **Lossless plumbing** — routing a run through the full compressed
//!    machinery with the `identity` compressor (EF on or off) is
//!    bitwise-identical to the uncompressed fast path, so the compressed
//!    code path provably adds no numerics of its own.
//! 2. **Difference-form convergence** — lossy compressors (q8, q4, top-k)
//!    under the mean-preserving difference update reach the uncompressed
//!    run's final loss/accuracy to a tight tolerance on the synthetic
//!    cohort, while shipping a fraction of the bytes.
//! 3. **Determinism** — a compressed run is exactly reproducible: the
//!    stochastic-rounding noise is keyed by `(seed, round, node, kind)`,
//!    never by call order or wall clock.

mod common;

use common::ScenarioBuilder;
use decfl::config::{AlgoKind, ExperimentConfig};
use decfl::coordinator::{assemble, run_on};
use decfl::metrics::RunLog;

fn cfg_with(algo: AlgoKind, compress: &str, steps: usize) -> ExperimentConfig {
    ScenarioBuilder::gossip(algo)
        .rounds(4, steps)
        .eval_every(2)
        .tweak(|c| c.compress = compress.into())
        .build()
}

fn run(cfg: &ExperimentConfig) -> RunLog {
    run_on(cfg, &assemble(cfg).unwrap()).unwrap()
}

#[test]
fn identity_compressor_bitwise_equals_uncompressed_fast_path() {
    for algo in [AlgoKind::FdDsgd, AlgoKind::FdDsgt] {
        let dense = run(&cfg_with(algo, "none", 24));
        for ef in [true, false] {
            let mut c = cfg_with(algo, "identity", 24);
            c.error_feedback = ef;
            let ident = run(&c);
            assert_eq!(dense.rows.len(), ident.rows.len(), "{algo:?} ef={ef}");
            for (rd, ri) in dense.rows.iter().zip(&ident.rows) {
                assert_eq!(
                    rd.loss.to_bits(),
                    ri.loss.to_bits(),
                    "{algo:?} ef={ef} round {}: identity must be a lossless no-op",
                    rd.comm_rounds
                );
                assert_eq!(rd.accuracy.to_bits(), ri.accuracy.to_bits(), "{algo:?} ef={ef}");
                assert_eq!(rd.consensus.to_bits(), ri.consensus.to_bits(), "{algo:?} ef={ef}");
                assert_eq!(
                    rd.stationarity.to_bits(),
                    ri.stationarity.to_bits(),
                    "{algo:?} ef={ef}"
                );
            }
            // identity ships dense f32, so the byte accounting agrees too
            assert_eq!(
                dense.rows.last().unwrap().bytes,
                ident.rows.last().unwrap().bytes,
                "{algo:?} ef={ef}"
            );
        }
    }
}

#[test]
fn difference_form_keeps_compressed_dsgd_at_the_uncompressed_loss() {
    // the acceptance pin: lossy compressors under the mean-preserving
    // difference update reach the uncompressed final accuracy (q8: within
    // 1 point) while shipping far fewer bytes
    let dense = run(&cfg_with(AlgoKind::FdDsgd, "none", 400));
    let dl = dense.rows.last().unwrap();
    // (compressor, topk_frac, min bytes reduction, accuracy tol, rel loss tol)
    // — q8 carries the headline "within 1% of uncompressed" pin; the
    // aggressive biased sparsifiers get a wider band (their perturbation is
    // mean-zero but consensus-noisy; see DESIGN.md §10)
    for (compress, frac, min_reduction, acc_tol, loss_tol) in [
        ("q8", 0.1, 3.5, 0.01, 0.05),
        ("q4", 0.1, 7.0, 0.02, 0.12),
        ("topk", 0.1, 4.5, 0.04, 0.25),
        ("topk", 0.05, 8.0, 0.04, 0.25),
    ] {
        let mut c = cfg_with(AlgoKind::FdDsgd, compress, 400);
        c.topk_frac = frac;
        let comp = run(&c);
        let cl = comp.rows.last().unwrap();
        assert!(
            (cl.accuracy - dl.accuracy).abs() <= acc_tol + 1e-12,
            "{compress}@{frac}: accuracy {} vs uncompressed {}",
            cl.accuracy,
            dl.accuracy
        );
        assert!(
            (cl.loss - dl.loss).abs() <= loss_tol * dl.loss.abs() + 1e-3,
            "{compress}@{frac}: loss {} vs uncompressed {}",
            cl.loss,
            dl.loss
        );
        let reduction = dl.bytes as f64 / cl.bytes as f64;
        assert!(
            reduction >= min_reduction,
            "{compress}@{frac}: only {reduction:.1}x fewer bytes (want >= {min_reduction})"
        );
    }
}

#[test]
fn compressed_dsgt_stays_convergent() {
    // DSGT compresses two payload streams (θ and ϑ), each with its own
    // difference-form correction — both must stay convergent
    let dense = run(&cfg_with(AlgoKind::FdDsgt, "none", 400));
    let dl = dense.rows.last().unwrap();
    let comp = run(&cfg_with(AlgoKind::FdDsgt, "q8", 400));
    let cl = comp.rows.last().unwrap();
    assert!(
        (cl.accuracy - dl.accuracy).abs() <= 0.01 + 1e-12,
        "q8 dsgt: accuracy {} vs uncompressed {}",
        cl.accuracy,
        dl.accuracy
    );
    assert!(cl.loss.is_finite() && cl.loss < comp.rows.first().unwrap().loss);
}

#[test]
fn compressed_runs_are_exactly_reproducible() {
    for compress in ["q8", "q4", "topk"] {
        let a = run(&cfg_with(AlgoKind::FdDsgd, compress, 40));
        let b = run(&cfg_with(AlgoKind::FdDsgd, compress, 40));
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{compress}");
            assert_eq!(ra.consensus.to_bits(), rb.consensus.to_bits(), "{compress}");
        }
        assert_eq!(a.rows.last().unwrap().bytes, b.rows.last().unwrap().bytes, "{compress}");
    }
}

#[test]
fn compressed_threaded_training_bitwise_equal_serial() {
    // the EF pass runs on the driver thread; the compressed round kernels
    // fan out — thread count must not move a single bit
    let mut cfg = cfg_with(AlgoKind::FdDsgt, "q4", 32);
    cfg.threads = 1;
    let serial = run(&cfg);
    cfg.threads = 4;
    let threaded = run(&cfg);
    for (rs, rt) in serial.rows.iter().zip(&threaded.rows) {
        assert_eq!(rs.loss.to_bits(), rt.loss.to_bits());
        assert_eq!(rs.consensus.to_bits(), rt.consensus.to_bits());
    }
}

#[test]
fn enabling_error_feedback_changes_the_trajectory_but_not_the_bytes() {
    // the opt-in EF residual is a numerics knob, not a wire-format knob
    let mut with_ef = cfg_with(AlgoKind::FdDsgd, "q8", 60);
    with_ef.error_feedback = true;
    let a = run(&with_ef);
    let mut no_ef = with_ef.clone();
    no_ef.error_feedback = false;
    let b = run(&no_ef);
    assert_eq!(
        a.rows.last().unwrap().bytes,
        b.rows.last().unwrap().bytes,
        "EF must not change what crosses the wire"
    );
    assert_ne!(
        a.rows.last().unwrap().loss.to_bits(),
        b.rows.last().unwrap().loss.to_bits(),
        "EF must change the numerics under a lossy compressor"
    );
    // with an unbiased quantizer EF stays benign — both converge
    assert!(a.rows.last().unwrap().loss.is_finite());
    assert!(b.rows.last().unwrap().loss.is_finite());
}
