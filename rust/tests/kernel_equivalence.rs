//! Integration: degree-sparse gossip is bitwise-equal to dense gossip.
//!
//! The perf refactor (§Perf in DESIGN.md) replaced the dense n-length
//! combine scan with per-node `(neighbor, weight)` lists.  These pins hold
//! the whole claim together: for every topology family × mixing scheme, and
//! for every network plan's per-round views, the sparse representation
//! names exactly the nonzero entries of the dense f32 row in ascending
//! order, and combining over it is bitwise-identical to the zero-skipping
//! dense loop.

use decfl::algo::native::{NativeModel, Workspace};
use decfl::config::ExperimentConfig;
use decfl::graph::{Graph, NetworkSchedule, Topology, ViewScratch};
use decfl::mixing::{self, Scheme, SparseW};
use decfl::rng::Pcg64;

fn rand_vec(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

fn families(n: usize) -> Vec<Topology> {
    let mut out = vec![
        Topology::Ring,
        Topology::Path,
        Topology::Complete,
        Topology::Star,
        Topology::Torus { rows: 0, cols: 0 },
        Topology::ErdosRenyi { p: 0.3 },
        Topology::RandomGeometric { radius: 0.35 },
        Topology::KNearest { k: 3 },
    ];
    if n > 5 {
        out.push(Topology::SmallWorld { k: 4, beta: 0.2 });
    }
    out
}

#[test]
fn sparse_combine_bitwise_equals_dense_for_every_family_and_scheme() {
    let model = NativeModel::new(7, 5);
    let p = model.p();
    let mut ws = Workspace::new();
    for (ti, topo) in families(12).iter().enumerate() {
        for scheme in [Scheme::Metropolis, Scheme::LazyMetropolis, Scheme::MaxDegree] {
            let n = 12;
            let mut rng = Pcg64::seed(100 + ti as u64);
            let g = Graph::build(topo, n, &mut rng).unwrap();
            let w = mixing::build(&g, scheme);
            let dense = mixing::to_f32(&w);
            let sparse = SparseW::from_mat(&w);
            assert_eq!(sparse.n(), n);
            // the CSR-first builder must agree bitwise with the dense route
            assert_eq!(mixing::build_sparse(&g, scheme), sparse, "{topo:?} {scheme:?}");
            let thetas = rand_vec(&mut rng, n * p, 0.5);
            for i in 0..n {
                let (idx, val) = sparse.row(i);
                // the sparse row is exactly the dense row's nonzeros, ascending
                let expect: Vec<(u32, f32)> = dense[i * n..(i + 1) * n]
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(j, &v)| (j as u32, v))
                    .collect();
                let got: Vec<(u32, f32)> = idx.iter().copied().zip(val.iter().copied()).collect();
                assert_eq!(got, expect, "{topo:?} {scheme:?} row {i}");
                // gossip degree, not network size: self + graph neighbors
                assert!(idx.len() <= g.degree(i) + 1, "{topo:?} {scheme:?} row {i}");

                let a = model.combine(&dense[i * n..(i + 1) * n], &thetas);
                let mut b = vec![0.0f32; p];
                model.combine_sparse_into(idx, val, &thetas, &mut b, &mut ws);
                assert_eq!(a, b, "{topo:?} {scheme:?} row {i}: sparse != dense");
            }
        }
    }
}

#[test]
fn schedule_sparse_rows_match_dense_views_for_every_plan() {
    // every per-round view a NetworkSchedule emits must agree between its
    // dense f32 form (SyncDriver) and its per-node sparse rows (actors)
    for plan in ["static", "rewire", "edge-drop", "churn"] {
        let mut cfg = ExperimentConfig::default();
        cfg.n = 10;
        cfg.topology = "er".into();
        cfg.net_plan = plan.into();
        cfg.rewire_every = 2;
        cfg.edge_drop = 0.3;
        cfg.churn = 0.3;
        let mut rng = Pcg64::seed(5);
        let g = Graph::build(&Topology::ErdosRenyi { p: 0.4 }, cfg.n, &mut rng).unwrap();
        let w = mixing::build_sparse(&g, Scheme::Metropolis);
        let sched = NetworkSchedule::from_config(&cfg, g, w).unwrap();
        let mut scratch = ViewScratch::new();
        for round in 1..=8 {
            let view = sched.view_into(round, &mut scratch).unwrap();
            let dense = view.wf();
            let sparse = SparseW::from_dense(cfg.n, &dense);
            for i in 0..cfg.n {
                let (vi, vv) = view.sparse_row(i);
                let (si, sv) = sparse.row(i);
                assert_eq!(vi, si, "{plan} round {round} row {i}: indices");
                assert_eq!(vv, sv, "{plan} round {round} row {i}: weights");
                // offline nodes collapse to the identity row
                if !view.online[i] {
                    assert_eq!(vi, &[i as u32][..], "{plan} round {round} row {i}");
                    assert_eq!(vv, &[1.0f32][..], "{plan} round {round} row {i}");
                }
            }
        }
    }
}
