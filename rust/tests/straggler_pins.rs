//! Integration: straggler-scenario regressions — the honest-clock pin
//! (per-round `sim_time_s` = slowest participant's compute + link time),
//! convergence under every plan, and the τ-weighted work accounting.

mod common;

use common::ScenarioBuilder;
use decfl::config::{AlgoKind, ExperimentConfig};
use decfl::coordinator::{assemble, run_on};
use decfl::engine::ComputeSchedule;

fn straggler_cfg(algo: AlgoKind, plan: &str) -> ExperimentConfig {
    ScenarioBuilder::gossip(algo).compute(plan).build()
}

#[test]
fn sim_time_per_round_is_slowest_participant_plus_link_time() {
    // fused analytic accounting: with eval_every = 1, consecutive rows
    // bracket exactly one round, whose sim-time delta must equal the
    // schedule's max_i τ_i·s/speed_i plus one link transfer per payload
    // kind (DSGD ships θ; DSGT ships θ and the tracker ϑ)
    for (algo, kinds) in [(AlgoKind::FdDsgd, 1u32), (AlgoKind::FdDsgt, 2u32)] {
        for plan in ["fixed-tiers", "lognormal", "dropout"] {
            let cfg = straggler_cfg(algo, plan);
            let csched = ComputeSchedule::from_config(&cfg).unwrap();
            let asm = assemble(&cfg).unwrap();
            let log = run_on(&cfg, &asm).unwrap();
            let p = decfl::algo::native::NativeModel::new(cfg.d, cfg.hidden).p();
            let link_s = (cfg.latency_s + 4.0 * p as f64 / cfg.bandwidth_bps) * kinds as f64;
            assert!(log.rows.len() >= 3, "{plan}/{algo:?}");
            for pair in log.rows.windows(2) {
                let round = pair[1].comm_rounds as usize;
                let delta = pair[1].sim_time_s - pair[0].sim_time_s;
                let expect = csched.round_compute_s(round, cfg.compute_s_per_step) + link_s;
                assert!(
                    (delta - expect).abs() < 1e-9 * (1.0 + expect),
                    "{plan}/{algo:?} round {round}: sim-time delta {delta} vs \
                     max-participant {expect}"
                );
            }
        }
    }
}

#[test]
fn straggler_runs_converge_and_report_reduced_work() {
    for plan in ["fixed-tiers", "lognormal", "dropout"] {
        let mut cfg = straggler_cfg(AlgoKind::FdDsgt, plan);
        cfg.total_steps = 80;
        let asm = assemble(&cfg).unwrap();
        let log = run_on(&cfg, &asm).unwrap();
        let first = log.rows.first().unwrap();
        let last = log.rows.last().unwrap();
        assert!(last.loss.is_finite() && last.loss < first.loss, "{plan}");
        // the work axis reflects the schedule, not a uniform round·Q
        let csched = ComputeSchedule::from_config(&cfg).unwrap();
        let expect: u64 = (1..=last.comm_rounds as usize)
            .map(|r| csched.local_work(r))
            .sum::<u64>()
            / cfg.n as u64;
        assert_eq!(last.local_steps, expect, "{plan}: work accounting");
        assert!(last.local_steps <= last.comm_rounds * cfg.q as u64, "{plan}");
    }
}

#[test]
fn tau_weighted_gossip_tracks_the_uniform_fixed_point() {
    // unbiasedness sanity: a fixed-tiers run must land in the same loss
    // neighborhood as the uniform run (τ-weighting re-centers the fixed
    // point), not diverge toward the fast nodes' private minimizers
    let mut uni = straggler_cfg(AlgoKind::FdDsgd, "uniform");
    uni.total_steps = 200;
    let asm = assemble(&uni).unwrap();
    let log_u = run_on(&uni, &asm).unwrap();
    let mut tiers = uni.clone();
    tiers.compute_plan = "fixed-tiers".into();
    let log_t = run_on(&tiers, &asm).unwrap();
    let (lu, lt) = (log_u.rows.last().unwrap().loss, log_t.rows.last().unwrap().loss);
    assert!(lt.is_finite());
    // stragglers do less work, so some loss gap is expected — but bounded
    assert!(
        (lt - lu).abs() < 0.25 * (1.0 + lu.abs()),
        "tiers fixed point drifted: uniform {lu} vs tiers {lt}"
    );
}

#[test]
fn pjrt_backend_rejects_straggler_plans_loudly() {
    // AOT artifacts scan a fixed Q−1 steps; a straggler plan cannot run on
    // them and must be rejected before training starts.  The bail fires in
    // the engine's driver constructor, so it needs no artifacts on disk —
    // a mock compute with a fixed local_steps_len stands in for PJRT.
    use anyhow::Result;
    use decfl::coordinator::Compute;
    use decfl::data::Shard;

    struct FixedScan(decfl::coordinator::NativeCompute);
    impl Compute for FixedScan {
        fn dims(&self) -> (usize, usize, usize) {
            self.0.dims()
        }
        fn local_steps_len(&self) -> Option<usize> {
            Some(3) // artifact specialized to Q−1 = 3
        }
        fn grad_step(&self, t: &[f32], x: &[f32], y: &[f32]) -> Result<(f64, Vec<f32>)> {
            self.0.grad_step(t, x, y)
        }
        fn local_steps(
            &self,
            t: &[f32],
            bx: &[f32],
            by: &[f32],
            lrs: &[f32],
        ) -> Result<(Vec<f32>, Vec<f64>)> {
            self.0.local_steps(t, bx, by, lrs)
        }
        fn combine(&self, w: &[f32], t: &[f32]) -> Result<Vec<f32>> {
            self.0.combine(w, t)
        }
        fn dsgd_round(
            &self,
            w: &[f32],
            t: &[f32],
            bx: &[f32],
            by: &[f32],
            lr: f32,
        ) -> Result<(Vec<f32>, Vec<f64>)> {
            self.0.dsgd_round(w, t, bx, by, lr)
        }
        fn dsgt_round(
            &self,
            w: &[f32],
            t: &[f32],
            y: &[f32],
            g: &[f32],
            bx: &[f32],
            by: &[f32],
            lr: f32,
        ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f64>)> {
            self.0.dsgt_round(w, t, y, g, bx, by, lr)
        }
        fn eval_full(&self, t: &[f32], s: &[Shard]) -> Result<(f64, f64, f64, f64)> {
            self.0.eval_full(t, s)
        }
        fn predict(&self, t: &[f32], x: &[f32]) -> Result<Vec<f32>> {
            self.0.predict(t, x)
        }
    }

    let cfg = straggler_cfg(AlgoKind::FdDsgd, "dropout");
    let asm = assemble(&cfg).unwrap();
    let mock = FixedScan(decfl::coordinator::NativeCompute::new(
        cfg.d, cfg.hidden, cfg.n, cfg.m,
    ));
    let err = decfl::engine::train_decentralized(&cfg, &mock, &asm.ds, &asm.graph, &asm.w)
        .unwrap_err();
    assert!(err.to_string().contains("--backend native"), "{err}");
}
