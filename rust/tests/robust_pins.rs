//! Integration: Byzantine-robustness regressions — the honest-path pins
//! (defaults build no perturbation pipeline; identity message routing is
//! bitwise-invisible in every driver), adversary-schedule determinism
//! across threads, the small-scale mean-collapses/robust-holds frontier,
//! and the per-run (ε, δ) report against the accountant.

mod common;

use common::ScenarioBuilder;
use decfl::config::{AlgoKind, ExperimentConfig, Mode};
use decfl::coordinator::{assemble, run_on, Compute as _};
use decfl::engine::{AttackSchedule, MsgPerturb};

fn base_cfg(algo: AlgoKind) -> ExperimentConfig {
    // the robust pins run a slightly larger fleet than the gossip base so
    // a 25% attack fraction yields ≥ 2 attackers
    ScenarioBuilder::gossip(algo).n(8).rounds(4, 48).build()
}

#[test]
fn honest_defaults_build_no_perturbation_pipeline() {
    let cfg = ExperimentConfig::default();
    assert!(!decfl::engine::adversary::perturb_active(&cfg));
    assert!(MsgPerturb::from_config(&cfg).unwrap().is_none());
    // the default strings are exactly the pinned honest path
    assert_eq!(cfg.attack_plan, "none");
    assert_eq!(cfg.robust_rule, "mean");
    assert_eq!(cfg.dp, "off");
}

#[test]
fn identity_routing_is_bitwise_invisible_in_every_driver() {
    // the perturbation pipeline rides the compressor slot (an Identity
    // codec is installed when no real compressor is configured), so the
    // identity wire path must reproduce the dense honest trajectory
    // bit-for-bit in all three drivers
    for (mode, driver) in [
        (Mode::Fused, "sync"),
        (Mode::Actors, "sync"),
        (Mode::Fused, "async"),
    ] {
        let dense = ScenarioBuilder::gossip(AlgoKind::FdDsgt)
            .n(8)
            .rounds(4, 48)
            .mode(mode)
            .driver(driver)
            .build();
        let asm = assemble(&dense).unwrap();
        let log_dense = run_on(&dense, &asm).unwrap();

        let mut ident = dense.clone();
        ident.compress = "identity".into();
        let log_ident = run_on(&ident, &asm).unwrap();

        assert_eq!(
            log_dense.rows.len(),
            log_ident.rows.len(),
            "{mode:?}/{driver}"
        );
        for (a, b) in log_dense.rows.iter().zip(&log_ident.rows) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{mode:?}/{driver}");
            assert_eq!(
                a.consensus.to_bits(),
                b.consensus.to_bits(),
                "{mode:?}/{driver}"
            );
            assert_eq!(a.bytes, b.bytes, "{mode:?}/{driver}: identity is dense-sized");
        }
    }
}

#[test]
fn attack_schedule_and_perturbation_are_identical_across_threads() {
    let mut cfg = base_cfg(AlgoKind::Dsgd);
    cfg.n = 20;
    cfg.seed = 11;
    cfg.attack_plan = "scaled-noise".into();
    cfg.attack_frac = 0.3;
    cfg.attack_scale = 2.0;
    cfg.dp = "gaussian".into();
    cfg.dp_clip = 5.0;

    let results: Vec<(Vec<bool>, Vec<f32>)> = (0..8)
        .map(|_| {
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                let s = AttackSchedule::from_config(&cfg).unwrap();
                let mem: Vec<bool> = (0..cfg.n).map(|i| s.is_attacker(i)).collect();
                let mut pb = MsgPerturb::from_config(&cfg).unwrap().unwrap();
                let mut buf = vec![0.25f32; 32];
                pb.apply(5, 3, 1, &mut buf);
                (mem, buf)
            })
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.join().unwrap())
        .collect();
    for r in &results[1..] {
        assert_eq!(r.0, results[0].0, "membership must not depend on the thread");
        assert_eq!(r.1, results[0].1, "perturbation draws must not depend on the thread");
    }
}

#[test]
fn robust_rules_are_thread_count_deterministic() {
    for rule in ["trimmed-mean", "median", "krum"] {
        let mut one = base_cfg(AlgoKind::Dsgd);
        one.attack_plan = "sign-flip".into();
        one.attack_frac = 0.25;
        one.robust_rule = rule.into();
        one.threads = 1;
        let asm = assemble(&one).unwrap();
        let log_one = run_on(&one, &asm).unwrap();
        let mut four = one.clone();
        four.threads = 4;
        let log_four = run_on(&four, &asm).unwrap();
        for (a, b) in log_one.rows.iter().zip(&log_four.rows) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{rule}");
            assert_eq!(a.consensus.to_bits(), b.consensus.to_bits(), "{rule}");
        }
    }
}

#[test]
fn fused_and_actors_agree_under_robust_rule_and_attack() {
    // tolerance, not the bitwise `pin_fused_eq_actors`: the coordinate-wise
    // median's scratch layout differs between the whole-stack fused pass
    // and the per-node actor step, which may legally reorder f64 rounding
    let mut cfg = base_cfg(AlgoKind::Dsgt);
    cfg.attack_plan = "sign-flip".into();
    cfg.attack_frac = 0.25;
    cfg.robust_rule = "median".into();
    let asm = assemble(&cfg).unwrap();
    let log_f = run_on(&cfg, &asm).unwrap();
    let mut act = cfg.clone();
    act.mode = Mode::Actors;
    let log_a = run_on(&act, &asm).unwrap();
    assert_eq!(log_f.rows.len(), log_a.rows.len());
    for (f, a) in log_f.rows.iter().zip(&log_a.rows) {
        assert!((f.loss - a.loss).abs() < 1e-9, "{} vs {}", f.loss, a.loss);
        assert!((f.consensus - a.consensus).abs() < 1e-9);
    }
}

#[test]
fn mean_collapses_where_robust_rules_hold() {
    // the EXP-R1 acceptance shape at test scale: 20% sign-flip attackers on
    // an ER graph wreck the plain-mean combine while trimmed-mean and the
    // coordinate-wise median keep training
    let base = ScenarioBuilder::gossip(AlgoKind::Dsgd)
        .n(10)
        .rounds(4, 160)
        .eval_every(8)
        .topology("er")
        .build();
    let asm = assemble(&base).unwrap();
    let log_base = run_on(&base, &asm).unwrap();
    let base_last = log_base.rows.last().unwrap();
    assert!(base_last.loss.is_finite());
    assert!(base_last.loss < log_base.rows.first().unwrap().loss);

    let attacked = |rule: &str| {
        let mut c = base.clone();
        c.attack_plan = "sign-flip".into();
        c.attack_frac = 0.2;
        c.robust_rule = rule.into();
        // ⌊trim·k⌋ trims nothing below trim = 1/3 on the sparsest ER rows
        // (k = 3 participants): raise the trim so trimmed-mean actually
        // screens at this graph's degree
        c.robust_trim = 0.4;
        run_on(&c, &asm).unwrap()
    };

    let mean_last_loss = attacked("mean").rows.last().unwrap().loss;
    assert!(
        !mean_last_loss.is_finite() || mean_last_loss > base_last.loss + 0.05,
        "plain mean should collapse under 20% sign-flip: {} vs honest {}",
        mean_last_loss,
        base_last.loss
    );

    for rule in ["trimmed-mean", "median"] {
        let log = attacked(rule);
        let last = log.rows.last().unwrap();
        assert!(last.loss.is_finite(), "{rule}");
        assert!(
            !mean_last_loss.is_finite() || last.loss < mean_last_loss,
            "{rule}: {} not better than collapsed mean {}",
            last.loss,
            mean_last_loss
        );
        assert!(
            last.accuracy >= base_last.accuracy - 0.10,
            "{rule}: accuracy {} fell more than 10 pts from honest {}",
            last.accuracy,
            base_last.accuracy
        );
    }
}

#[test]
fn metrics_are_honest_subfleet_under_attack() {
    // under an active attack the logged metrics are record-weighted over
    // the honest nodes only (DESIGN.md §14) — an attacker's model is
    // adversarial software, not a hospital.  Pinned bitwise against a
    // hand-filtered eval of the final θ stack.
    let mut cfg = base_cfg(AlgoKind::Dsgd);
    cfg.attack_plan = "sign-flip".into();
    cfg.attack_frac = 0.25;
    cfg.robust_rule = "median".into();
    let asm = assemble(&cfg).unwrap();
    let compute = decfl::coordinator::make_compute(&cfg).unwrap();
    let (log, theta) = decfl::engine::train_decentralized(
        &cfg,
        compute.as_ref(),
        &asm.ds,
        &asm.graph,
        &asm.w,
    )
    .unwrap();
    let sched = AttackSchedule::from_config(&cfg).unwrap();
    let p = theta.len() / cfg.n;
    let mut th = Vec::new();
    let mut sh = Vec::new();
    for i in 0..cfg.n {
        if !sched.is_attacker(i) {
            th.extend_from_slice(&theta[i * p..(i + 1) * p]);
            sh.push(asm.ds.shards[i].clone());
        }
    }
    assert!(!sh.is_empty() && sh.len() < cfg.n, "attack must split the fleet");
    let want = compute.eval_full(&th, &sh).unwrap();
    let last = log.rows.last().unwrap();
    assert_eq!(last.loss.to_bits(), want.0.to_bits(), "honest-subfleet loss");
    assert_eq!(last.accuracy.to_bits(), want.1.to_bits(), "honest-subfleet accuracy");
}

#[test]
fn reported_epsilon_matches_the_accountant() {
    // the per-row ε column is exactly DpPlan::epsilon at (kinds × rounds)
    // releases — 1 payload kind for DSGD, 2 for the tracker algorithms
    for (algo, kinds) in [(AlgoKind::Dsgd, 1u64), (AlgoKind::Dsgt, 2u64)] {
        let mut cfg = base_cfg(algo);
        cfg.dp = "gaussian".into();
        cfg.dp_clip = 20.0;
        cfg.dp_sigma = 1.0;
        let dp = decfl::engine::adversary::dp_from_config(&cfg).unwrap();
        let asm = assemble(&cfg).unwrap();
        let log = run_on(&cfg, &asm).unwrap();
        let mut prev = -1.0f64;
        for row in &log.rows {
            let want = dp.epsilon(kinds * row.comm_rounds);
            assert_eq!(
                row.dp_epsilon.to_bits(),
                want.to_bits(),
                "{algo:?} round {}: {} vs accountant {}",
                row.comm_rounds,
                row.dp_epsilon,
                want
            );
            assert!(row.dp_epsilon >= prev, "{algo:?}: ε must be nondecreasing");
            prev = row.dp_epsilon;
        }
        assert!(prev > 0.0, "{algo:?}: final ε must be positive with DP on");
    }
}
