//! Shared helpers for integration tests.
//!
//! [`ScenarioBuilder`] is the one place the scenario axes (topology ×
//! network plan × compressor × compute plan × driver × state sharding)
//! compose into an `ExperimentConfig`, so every pin file exercises the same
//! shaped configs instead of hand-rolling drifting copies.
//! [`pin_fused_eq_actors`] is the shared bitwise driver-equivalence
//! assertion.
//!
//! All PJRT integration tests need the AOT artifacts (`make artifacts`).
//! If they are missing we *skip* (pass with a loud message) so plain
//! `cargo test` still works in a fresh checkout; `make test` always builds
//! artifacts first.
//!
//! Each integration-test binary compiles this module separately and uses
//! its own subset of the helpers, so the unused remainder is expected.
#![allow(dead_code)]

use decfl::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use decfl::coordinator::{assemble, run_on};
use decfl::metrics::RunLog;
use std::path::PathBuf;

pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "SKIP: artifacts/manifest.json not found — run `make artifacts` for full coverage"
        );
        None
    }
}

/// Relative+absolute closeness for f32 buffers crossing the PJRT boundary.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0 + x.abs().max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}[{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

/// Composable scenario axes over one native-backend gossip base config
/// (n=5, d=42, hidden=8, m=8, ring, eval every round).  Each axis setter
/// also applies the pinned test shaping for that axis (rewire cadence,
/// drop/churn probabilities, tier table, ...) so the pin files agree on
/// what, say, "the churn plan" means.
pub struct ScenarioBuilder {
    cfg: ExperimentConfig,
}

impl ScenarioBuilder {
    /// Gossip base: fused sync native, small fleet, every round evaluated.
    pub fn gossip(algo: AlgoKind) -> Self {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = Backend::Native;
        cfg.mode = Mode::Fused;
        cfg.algo = algo;
        cfg.n = 5;
        cfg.d = 42;
        cfg.hidden = 8;
        cfg.m = 8;
        cfg.q = 4;
        cfg.total_steps = 32;
        cfg.eval_every = 1;
        cfg.records_per_hospital = 60;
        cfg.heterogeneity = 0.5;
        cfg.topology = "ring".into();
        ScenarioBuilder { cfg }
    }

    /// Fleet size.
    pub fn n(mut self, n: usize) -> Self {
        self.cfg.n = n;
        self
    }

    /// Local period and total local iterations.
    pub fn rounds(mut self, q: usize, steps: usize) -> Self {
        self.cfg.q = q;
        self.cfg.total_steps = steps;
        self
    }

    /// Evaluation cadence in comm rounds.
    pub fn eval_every(mut self, k: usize) -> Self {
        self.cfg.eval_every = k;
        self
    }

    /// Base topology.
    pub fn topology(mut self, t: &str) -> Self {
        self.cfg.topology = t.into();
        self
    }

    /// Dynamic network plan with the pinned test shaping
    /// (rewire every 2, edge-drop 0.4, churn 0.3).
    pub fn plan(mut self, p: &str) -> Self {
        self.cfg.net_plan = p.into();
        self.cfg.rewire_every = 2;
        self.cfg.edge_drop = 0.4;
        self.cfg.churn = 0.3;
        self
    }

    /// Gossip compressor (+ top-k fraction and the opt-in EF residual).
    pub fn compressor(mut self, c: &str, frac: f64, ef: bool) -> Self {
        self.cfg.compress = c.into();
        self.cfg.topk_frac = frac;
        self.cfg.error_feedback = ef;
        self
    }

    /// Straggler compute plan with the pinned test shaping
    /// (tiers 1.0/0.5/0.25, σ=0.7, slow-frac 0.4).
    pub fn compute(mut self, plan: &str) -> Self {
        self.cfg.compute_plan = plan.into();
        self.cfg.compute_tiers = "1.0,0.5,0.25".into();
        self.cfg.compute_sigma = 0.7;
        self.cfg.slow_frac = 0.4;
        self
    }

    /// Run driver (`sync`/`async`).
    pub fn driver(mut self, d: &str) -> Self {
        self.cfg.driver = d.into();
        self
    }

    /// Execution mode (fused vs actors).
    pub fn mode(mut self, m: Mode) -> Self {
        self.cfg.mode = m;
        self
    }

    /// Byzantine attack axis.
    pub fn attack(mut self, plan: &str, frac: f64) -> Self {
        self.cfg.attack_plan = plan.into();
        self.cfg.attack_frac = frac;
        self
    }

    /// Robust combine rule (trim pinned high enough to engage on
    /// degree-2 rows; see `decfl robust`).
    pub fn robust_rule(mut self, rule: &str) -> Self {
        self.cfg.robust_rule = rule.into();
        self.cfg.robust_trim = 0.4;
        self
    }

    /// Spill-backed node-state sharding (`state.shard_nodes` / hot-set).
    pub fn sharded(mut self, shard_nodes: usize, hot_shards: usize) -> Self {
        self.cfg.shard_nodes = shard_nodes;
        self.cfg.hot_shards = hot_shards;
        self
    }

    /// Escape hatch for per-test fields with no axis semantics.
    pub fn tweak(mut self, f: impl FnOnce(&mut ExperimentConfig)) -> Self {
        f(&mut self.cfg);
        self
    }

    /// Finish into the config.
    pub fn build(self) -> ExperimentConfig {
        self.cfg
    }
}

/// Every evaluation row of `a` and `b` must agree BITWISE on the metric
/// axes (loss, accuracy, stationarity, consensus) plus the round/work
/// counters.  Totals that race ahead on intermediate actor rows (bytes,
/// messages) are compared on the final row only.
pub fn assert_logs_bitwise(a: &RunLog, b: &RunLog, label: &str) {
    assert_eq!(a.rows.len(), b.rows.len(), "{label}: row count");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.comm_rounds, rb.comm_rounds, "{label}");
        assert_eq!(
            ra.loss.to_bits(),
            rb.loss.to_bits(),
            "{label} round {}: loss {} vs {}",
            ra.comm_rounds,
            ra.loss,
            rb.loss
        );
        assert_eq!(ra.accuracy.to_bits(), rb.accuracy.to_bits(), "{label}: accuracy");
        assert_eq!(
            ra.stationarity.to_bits(),
            rb.stationarity.to_bits(),
            "{label}: stationarity"
        );
        assert_eq!(ra.consensus.to_bits(), rb.consensus.to_bits(), "{label}: consensus");
        assert_eq!(ra.local_steps, rb.local_steps, "{label}: work accounting");
    }
    let (fa, fb) = (a.rows.last().unwrap(), b.rows.last().unwrap());
    assert_eq!(fa.bytes, fb.bytes, "{label}: byte accounting");
    assert_eq!(fa.messages, fb.messages, "{label}: message accounting");
}

/// The driver-equivalence pin: one assembled network, the same config
/// through the fused driver and the actor driver, bitwise-identical logs.
pub fn pin_fused_eq_actors(cfg: &ExperimentConfig, label: &str) {
    let asm = assemble(cfg).unwrap();
    let mut f = cfg.clone();
    f.mode = Mode::Fused;
    let fused = run_on(&f, &asm).unwrap();
    let mut ac = cfg.clone();
    ac.mode = Mode::Actors;
    let actors = run_on(&ac, &asm).unwrap();
    assert_logs_bitwise(&fused, &actors, label);
}
