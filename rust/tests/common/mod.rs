//! Shared helpers for integration tests.
//!
//! All PJRT integration tests need the AOT artifacts (`make artifacts`).
//! If they are missing we *skip* (pass with a loud message) so plain
//! `cargo test` still works in a fresh checkout; `make test` always builds
//! artifacts first.

use std::path::PathBuf;

pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "SKIP: artifacts/manifest.json not found — run `make artifacts` for full coverage"
        );
        None
    }
}

/// Relative+absolute closeness for f32 buffers crossing the PJRT boundary.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0 + x.abs().max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}[{i}]: {x} vs {y} (tol {tol})"
        );
    }
}
