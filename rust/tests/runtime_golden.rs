//! Integration: the compiled HLO artifacts reproduce the golden values the
//! python compile path recorded in `manifest.json` — closing the loop
//! python-jit ↔ HLO-text ↔ rust-PJRT numerically.

mod common;

use decfl::runtime::{golden, Engine};

fn engine() -> Option<Engine> {
    common::artifacts_dir().map(|d| Engine::load(&d).expect("engine load"))
}

#[test]
fn manifest_shapes_sane() {
    let Some(eng) = engine() else { return };
    let s = eng.shapes();
    assert_eq!(s.d, 42, "paper problem dimension");
    assert_eq!(s.p, s.d * s.hidden + 2 * s.hidden + 1);
    for name in ["grad_step", "local_steps", "local_steps_all", "combine", "dsgd_round", "dsgt_round", "eval_full", "predict"] {
        assert!(eng.manifest().spec(name).is_ok(), "missing artifact {name}");
    }
}

#[test]
fn grad_step_matches_golden() {
    let Some(eng) = engine() else { return };
    let s = eng.shapes();
    let theta = golden::golden_vec(0, s.p, 0.2);
    let x = golden::golden_vec(s.p as u64, s.m * s.d, 2.0);
    let y = golden::golden_labels((s.p + s.m * s.d) as u64, s.m);

    let out = eng.execute("grad_step", &[&theta, &x, &y]).unwrap();
    let loss = out[0][0] as f64;
    let grad = &out[1];

    let g = eng.manifest().goldens.get("grad_step").unwrap();
    let want_loss = g.get("loss").unwrap().as_f64().unwrap();
    let want_norm = g.get("grad_norm").unwrap().as_f64().unwrap();
    let want_head = g.get("grad_head").unwrap().as_f64_vec().unwrap();

    assert!((loss - want_loss).abs() < 1e-5 * (1.0 + want_loss.abs()), "loss {loss} vs {want_loss}");
    let norm = decfl::algo::l2_norm(grad);
    assert!((norm - want_norm).abs() < 1e-4 * (1.0 + want_norm), "norm {norm} vs {want_norm}");
    for (i, w) in want_head.iter().enumerate() {
        assert!((grad[i] as f64 - w).abs() < 1e-6 + 1e-4 * w.abs(), "grad[{i}] {} vs {w}", grad[i]);
    }
}

#[test]
fn combine_matches_golden() {
    let Some(eng) = engine() else { return };
    let s = eng.shapes();
    let wrow = vec![1.0f32 / s.n as f32; s.n];
    let big = golden::golden_vec(1000, s.n * s.p, 0.2);
    let out = eng.execute("combine", &[&wrow, &big]).unwrap();
    let g = eng.manifest().goldens.get("combine").unwrap();
    let want_norm = g.get("out_norm").unwrap().as_f64().unwrap();
    let want_head = g.get("out_head").unwrap().as_f64_vec().unwrap();
    let norm = decfl::algo::l2_norm(&out[0]);
    assert!((norm - want_norm).abs() < 1e-4 * (1.0 + want_norm), "norm {norm} vs {want_norm}");
    for (i, w) in want_head.iter().enumerate() {
        assert!((out[0][i] as f64 - w).abs() < 1e-6 + 1e-4 * w.abs());
    }
}

#[test]
fn local_steps_matches_golden() {
    let Some(eng) = engine() else { return };
    let s = eng.shapes();
    // goldens were computed with the full-Q shape in aot.py
    let q = eng.manifest().spec("local_steps").unwrap().inputs[3][0];
    let theta = golden::golden_vec(0, s.p, 0.2);
    let bx = golden::golden_vec(2000, q * s.m * s.d, 2.0);
    let by = golden::golden_labels((2000 + q * s.m * s.d) as u64, q * s.m);
    let lrs: Vec<f32> = (1..=q).map(|r| 0.02 / (r as f32).sqrt()).collect();
    let out = eng.execute("local_steps", &[&theta, &bx, &by, &lrs]).unwrap();

    let g = eng.manifest().goldens.get("local_steps").unwrap();
    let want_theta_norm = g.get("theta_norm").unwrap().as_f64().unwrap();
    let want_first = g.get("loss_first").unwrap().as_f64().unwrap();
    let want_last = g.get("loss_last").unwrap().as_f64().unwrap();

    let theta_norm = decfl::algo::l2_norm(&out[0]);
    assert!(
        (theta_norm - want_theta_norm).abs() < 1e-3 * (1.0 + want_theta_norm),
        "theta norm {theta_norm} vs {want_theta_norm}"
    );
    let losses = &out[1];
    assert!((losses[0] as f64 - want_first).abs() < 1e-4 * (1.0 + want_first));
    assert!((losses[q - 1] as f64 - want_last).abs() < 1e-3 * (1.0 + want_last));
    // (no monotonicity assertion: golden inputs are hash noise, not learnable)
}

#[test]
fn execute_rejects_wrong_shapes() {
    let Some(eng) = engine() else { return };
    let s = eng.shapes();
    let theta = vec![0.0f32; s.p];
    // wrong arity
    assert!(eng.execute("grad_step", &[&theta]).is_err());
    // wrong element count
    let bad_x = vec![0.0f32; 3];
    let y = vec![0.0f32; s.m];
    assert!(eng.execute("grad_step", &[&theta, &bad_x, &y]).is_err());
    // unknown artifact
    assert!(eng.execute("nope", &[]).is_err());
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(dir) = common::artifacts_dir() else { return };
    let eng = Engine::load(&dir).unwrap();
    let t0 = std::time::Instant::now();
    eng.warmup(&["grad_step"]).unwrap();
    let cold = t0.elapsed();
    let t1 = std::time::Instant::now();
    eng.warmup(&["grad_step"]).unwrap();
    let warm = t1.elapsed();
    assert!(warm < cold / 10, "cache miss? cold {cold:?} warm {warm:?}");
}
