//! Integration: the PJRT artifacts and the native rust twin agree on every
//! artifact-level operation — the strongest evidence that the three-layer
//! stack computes what the paper's equations say.

mod common;

use decfl::coordinator::{Compute, NativeCompute, PjrtCompute};
use decfl::rng::Pcg64;

fn backends() -> Option<(PjrtCompute, NativeCompute)> {
    let dir = common::artifacts_dir()?;
    let pjrt = PjrtCompute::load(&dir).expect("pjrt load");
    let s = pjrt.engine().shapes();
    Some((pjrt, NativeCompute::new(s.d, s.hidden, s.n, s.m)))
}

fn rand_vec(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

fn rand_labels(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 }).collect()
}

#[test]
fn grad_step_agrees() {
    let Some((pjrt, native)) = backends() else { return };
    let (d, _, p) = pjrt.dims();
    let s = pjrt.engine().shapes();
    let mut rng = Pcg64::seed(1);
    let theta = rand_vec(&mut rng, p, 0.2);
    let x = rand_vec(&mut rng, s.m * d, 1.0);
    let y = rand_labels(&mut rng, s.m);
    let (lp, gp) = pjrt.grad_step(&theta, &x, &y).unwrap();
    let (ln_, gn) = native.grad_step(&theta, &x, &y).unwrap();
    assert!((lp - ln_).abs() < 1e-5 * (1.0 + ln_.abs()), "loss {lp} vs {ln_}");
    common::assert_close(&gp, &gn, 1e-4, "grad");
}

#[test]
fn combine_agrees() {
    let Some((pjrt, native)) = backends() else { return };
    let (_, _, p) = pjrt.dims();
    let s = pjrt.engine().shapes();
    let mut rng = Pcg64::seed(2);
    // a real metropolis row, not uniform weights
    let g = decfl::graph::Graph::build(
        &decfl::graph::Topology::RandomGeometric { radius: 0.35 },
        s.n,
        &mut Pcg64::seed(3),
    )
    .unwrap();
    let w = decfl::mixing::build(&g, decfl::mixing::Scheme::Metropolis);
    let wrow: Vec<f32> = w.row(0).iter().map(|&v| v as f32).collect();
    let thetas = rand_vec(&mut rng, s.n * p, 0.3);
    let cp = pjrt.combine(&wrow, &thetas).unwrap();
    let cn = native.combine(&wrow, &thetas).unwrap();
    common::assert_close(&cp, &cn, 1e-5, "combine");
}

#[test]
fn dsgd_round_agrees() {
    let Some((pjrt, native)) = backends() else { return };
    let (d, _, p) = pjrt.dims();
    let s = pjrt.engine().shapes();
    let mut rng = Pcg64::seed(4);
    let g = decfl::graph::Graph::build(
        &decfl::graph::Topology::Ring,
        s.n,
        &mut Pcg64::seed(5),
    )
    .unwrap();
    let w = decfl::mixing::to_f32(&decfl::mixing::build(&g, decfl::mixing::Scheme::Metropolis));
    let theta = rand_vec(&mut rng, s.n * p, 0.3);
    let bx = rand_vec(&mut rng, s.n * s.m * d, 1.0);
    let by = rand_labels(&mut rng, s.n * s.m);
    let (tp, lp) = pjrt.dsgd_round(&w, &theta, &bx, &by, 0.02).unwrap();
    let (tn, ln_) = native.dsgd_round(&w, &theta, &bx, &by, 0.02).unwrap();
    common::assert_close(&tp, &tn, 1e-4, "dsgd theta");
    for (a, b) in lp.iter().zip(&ln_) {
        assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()), "losses {a} vs {b}");
    }
}

#[test]
fn dsgt_round_agrees() {
    let Some((pjrt, native)) = backends() else { return };
    let (d, _, p) = pjrt.dims();
    let s = pjrt.engine().shapes();
    let mut rng = Pcg64::seed(6);
    let g = decfl::graph::Graph::build(
        &decfl::graph::Topology::Ring,
        s.n,
        &mut Pcg64::seed(7),
    )
    .unwrap();
    let w = decfl::mixing::to_f32(&decfl::mixing::build(&g, decfl::mixing::Scheme::Metropolis));
    let theta = rand_vec(&mut rng, s.n * p, 0.3);
    let y_tr = rand_vec(&mut rng, s.n * p, 0.1);
    let g_old = rand_vec(&mut rng, s.n * p, 0.1);
    let bx = rand_vec(&mut rng, s.n * s.m * d, 1.0);
    let by = rand_labels(&mut rng, s.n * s.m);
    let (t1, y1, g1, l1) = pjrt.dsgt_round(&w, &theta, &y_tr, &g_old, &bx, &by, 0.02).unwrap();
    let (t2, y2, g2, l2) = native.dsgt_round(&w, &theta, &y_tr, &g_old, &bx, &by, 0.02).unwrap();
    common::assert_close(&t1, &t2, 1e-4, "dsgt theta");
    common::assert_close(&y1, &y2, 1e-4, "dsgt tracker");
    common::assert_close(&g1, &g2, 1e-4, "dsgt grads");
    for (a, b) in l1.iter().zip(&l2) {
        assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()));
    }
}

#[test]
fn eval_and_predict_agree() {
    let Some((pjrt, native)) = backends() else { return };
    let (_, _, p) = pjrt.dims();
    let s = pjrt.engine().shapes();
    let mut rng = Pcg64::seed(8);
    let ds = decfl::data::generate(&decfl::data::DataConfig {
        n_hospitals: s.n,
        records_per_hospital: s.shard,
        records_jitter: 0,
        ..decfl::data::DataConfig::default()
    })
    .unwrap();
    // exact-shard sizes so native and pjrt see identical data
    let ds = ds.resampled_to(s.shard);
    let theta = rand_vec(&mut rng, s.n * p, 0.3);
    let ep = pjrt.eval_full(&theta, &ds.shards).unwrap();
    let en = native.eval_full(&theta, &ds.shards).unwrap();
    assert!((ep.0 - en.0).abs() < 1e-4 * (1.0 + en.0.abs()), "loss {} vs {}", ep.0, en.0);
    assert!((ep.1 - en.1).abs() < 1e-6, "acc {} vs {}", ep.1, en.1);
    assert!((ep.2 - en.2).abs() < 1e-5 * (1.0 + en.2.abs()), "stat {} vs {}", ep.2, en.2);
    assert!((ep.3 - en.3).abs() < 1e-4 * (1.0 + en.3.abs()), "cons {} vs {}", ep.3, en.3);

    let probs_p = pjrt.predict(&theta[..p], &ds.test.x[..s.shard.min(ds.test.n) * s.d]).unwrap();
    let probs_n = native.predict(&theta[..p], &ds.test.x[..s.shard.min(ds.test.n) * s.d]).unwrap();
    common::assert_close(&probs_p, &probs_n, 1e-4, "predict");
}

#[test]
fn eval_agrees_on_uneven_shards() {
    // The masked eval_full pin: shards SMALLER than the artifact's
    // specialized row count are cycle-padded on the host but masked in the
    // artifact, so PJRT must match the native oracle's exact record-weighted
    // metrics — the pre-mask artifact was biased here (its padded mean
    // over-weighted the first shard%n rows).
    let Some((pjrt, native)) = backends() else { return };
    let (_, _, p) = pjrt.dims();
    let s = pjrt.engine().shapes();
    if pjrt.engine().manifest().spec("eval_full").unwrap().inputs.len() < 4 {
        eprintln!("skipping: artifact set predates the masked eval_full (re-run `make artifacts`)");
        return;
    }
    let mut rng = Pcg64::seed(9);
    // jittered cohort: every shard strictly below the artifact capacity,
    // sizes differing across nodes (the record-weighting matters)
    let base = s.shard - s.shard.div_ceil(4);
    let ds = decfl::data::generate(&decfl::data::DataConfig {
        n_hospitals: s.n,
        records_per_hospital: base,
        records_jitter: s.shard / 10,
        ..decfl::data::DataConfig::default()
    })
    .unwrap();
    assert!(ds.shards.iter().all(|sh| sh.n < s.shard), "shards must need padding");
    assert!(
        ds.shards.iter().any(|sh| sh.n != ds.shards[0].n),
        "shards must be uneven for the weighting to matter"
    );
    let theta = rand_vec(&mut rng, s.n * p, 0.3);
    let ep = pjrt.eval_full(&theta, &ds.shards).unwrap();
    let en = native.eval_full(&theta, &ds.shards).unwrap();
    assert!((ep.0 - en.0).abs() < 1e-4 * (1.0 + en.0.abs()), "loss {} vs {}", ep.0, en.0);
    assert!((ep.1 - en.1).abs() < 1e-6, "acc {} vs {}", ep.1, en.1);
    assert!((ep.2 - en.2).abs() < 1e-5 * (1.0 + en.2.abs()), "stat {} vs {}", ep.2, en.2);
    assert!((ep.3 - en.3).abs() < 1e-4 * (1.0 + en.3.abs()), "cons {} vs {}", ep.3, en.3);
}
