//! Integration: end-to-end training through the PJRT artifacts at the
//! paper's configuration (N=20, d=42, m=20, Q=100) — fused and actor modes,
//! plus the PJRT-vs-native trajectory cross-check.

mod common;

use decfl::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use decfl::coordinator::{assemble, run_on};

fn paper_cfg(steps: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.backend = Backend::Pjrt;
    cfg.total_steps = steps;
    cfg.eval_every = 1;
    cfg
}

#[test]
fn fused_fd_dsgt_three_rounds() {
    let Some(_) = common::artifacts_dir() else { return };
    let cfg = paper_cfg(300); // 3 comm rounds at Q=100
    let asm = assemble(&cfg).unwrap();
    let log = run_on(&cfg, &asm).unwrap();
    assert_eq!(log.rows.last().unwrap().comm_rounds, 3);
    assert_eq!(log.rows.last().unwrap().local_steps, 300);
    let first = log.rows.first().unwrap().loss;
    let last = log.rows.last().unwrap().loss;
    assert!(last < first, "loss {first} -> {last}");
    // DSGT bytes: 2 payloads per round over 2|E| directed edges
    let e = asm.graph.edge_count() as u64;
    let p = 1409u64;
    assert_eq!(log.rows.last().unwrap().bytes, 3 * 2 * (2 * e) * p * 4);
}

#[test]
fn fused_fd_dsgd_three_rounds() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = paper_cfg(300);
    cfg.algo = AlgoKind::FdDsgd;
    let asm = assemble(&cfg).unwrap();
    let log = run_on(&cfg, &asm).unwrap();
    let first = log.rows.first().unwrap().loss;
    let last = log.rows.last().unwrap().loss;
    assert!(last < first, "loss {first} -> {last}");
}

#[test]
fn pjrt_and_native_trajectories_agree() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = paper_cfg(200); // 2 rounds
    cfg.algo = AlgoKind::FdDsgt;
    let asm = assemble(&cfg).unwrap();
    let log_pjrt = run_on(&cfg, &asm).unwrap();
    let mut cfg_n = cfg.clone();
    cfg_n.backend = Backend::Native;
    let log_native = run_on(&cfg_n, &asm).unwrap();
    assert_eq!(log_pjrt.rows.len(), log_native.rows.len());
    for (rp, rn) in log_pjrt.rows.iter().zip(&log_native.rows) {
        // 200 sequential f32 updates: modest divergence tolerance
        assert!(
            (rp.loss - rn.loss).abs() < 5e-3 * (1.0 + rn.loss.abs()),
            "round {}: pjrt {} vs native {}",
            rp.comm_rounds,
            rp.loss,
            rn.loss
        );
        assert_eq!(rp.bytes, rn.bytes, "accounting must be identical");
    }
}

#[test]
fn actor_mode_pjrt_small_rounds() {
    let Some(_) = common::artifacts_dir() else { return };
    // actor mode compiles one engine per node thread — keep it to 1 round
    let mut cfg = paper_cfg(100);
    cfg.mode = Mode::Actors;
    let asm = assemble(&cfg).unwrap();
    let log = run_on(&cfg, &asm).unwrap();
    assert_eq!(log.rows.last().unwrap().comm_rounds, 1);
    assert!(log.rows.last().unwrap().bytes > 0);
    assert!(log.rows.last().unwrap().loss.is_finite());
}

#[test]
fn config_mismatch_is_diagnosed() {
    let Some(_) = common::artifacts_dir() else { return };
    let mut cfg = paper_cfg(100);
    cfg.q = 7; // artifacts were built with Q=100
    let asm = assemble(&cfg).unwrap();
    let err = run_on(&cfg, &asm).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}
