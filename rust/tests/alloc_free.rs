//! Integration: steady-state training rounds are allocation-free.
//!
//! The perf refactor (§Perf in DESIGN.md) promises that once a run is warm —
//! slabs sized, workspaces grown, network view cached — a serial
//! (`threads = 1`) fused round performs ZERO heap allocations across the
//! kernel/gossip path: batch sampling, the local phase, and the
//! communication update.  This test pins that with a counting global
//! allocator.
//!
//! The counter is **per-thread** (a `const`-initialized `thread_local`
//! `Cell`, which itself never allocates), so concurrently running tests in
//! this binary cannot pollute the measurement; the measured region runs
//! entirely on this test's thread because the compute is built with
//! `threads = 1`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use decfl::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use decfl::coordinator::{assemble, NativeCompute};
use decfl::engine::{Driver, RoundEngine, ShardedSync, SyncDriver};

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn bump() {
    ALLOCS.with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_here() -> u64 {
    ALLOCS.with(|c| c.get())
}

fn steady_round_allocs(algo: AlgoKind, net_plan: &str) -> u64 {
    let mut cfg = ExperimentConfig::default();
    cfg.n = 6;
    cfg.d = 42;
    cfg.hidden = 8;
    cfg.m = 8;
    cfg.q = 4;
    cfg.algo = algo;
    cfg.total_steps = 40;
    cfg.eval_every = 1000; // observe() is cadence work, not round work
    cfg.backend = Backend::Native;
    cfg.threads = 1;
    cfg.records_per_hospital = 60;
    cfg.net_plan = net_plan.into();
    cfg.edge_drop = if net_plan == "edge-drop" { 0.25 } else { 0.0 };
    cfg.churn = if net_plan == "churn" { 0.25 } else { 0.0 };
    let asm = assemble(&cfg).unwrap();
    let compute = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m).with_threads(1);
    let engine = RoundEngine::from_config(&cfg);
    let mut driver =
        SyncDriver::decentralized(&cfg, &compute, &asm.ds, &asm.graph, &asm.w).unwrap();
    driver.begin().unwrap();

    // warm-up round: sizes the sampler scratch, the thread's kernel
    // workspace, and the cached (static) network view
    let local = engine.plan.local_per_round;
    let lrs1 = engine.sched.local_lrs(1, engine.q, local);
    driver.local_phase(1, &lrs1).unwrap();
    driver.comm_phase(1, engine.sched.comm_lr(1, engine.q)).unwrap();

    // steady-state rounds: must not touch the allocator at all
    let lrs2 = engine.sched.local_lrs(2, engine.q, local);
    let lrs3 = engine.sched.local_lrs(3, engine.q, local);
    let before = allocs_here();
    driver.local_phase(2, &lrs2).unwrap();
    driver.comm_phase(2, engine.sched.comm_lr(2, engine.q)).unwrap();
    driver.local_phase(3, &lrs3).unwrap();
    driver.comm_phase(3, engine.sched.comm_lr(3, engine.q)).unwrap();
    allocs_here() - before
}

#[test]
fn steady_state_dsgd_round_is_allocation_free() {
    let n = steady_round_allocs(AlgoKind::FdDsgd, "static");
    assert_eq!(n, 0, "fd-dsgd steady round performed {n} heap allocations");
}

#[test]
fn steady_state_dsgt_round_is_allocation_free() {
    let n = steady_round_allocs(AlgoKind::FdDsgt, "static");
    assert_eq!(n, 0, "fd-dsgt steady round performed {n} heap allocations");
}

// The sparse network stack's warm-path claim: even when every round derives
// a FRESH view (edge dropout / node churn re-absorb CSR rows each round),
// the grow-only ViewScratch + reserved CSR cache keep steady rounds off the
// allocator entirely — the round-1 warm-up sizes everything once.
#[test]
fn steady_state_rounds_under_edge_dropout_are_allocation_free() {
    let n = steady_round_allocs(AlgoKind::FdDsgd, "edge-drop");
    assert_eq!(n, 0, "edge-drop steady round performed {n} heap allocations");
}

#[test]
fn steady_state_rounds_under_node_churn_are_allocation_free() {
    let n = steady_round_allocs(AlgoKind::FdDsgt, "churn");
    assert_eq!(n, 0, "churn steady round performed {n} heap allocations");
}

/// Warm sharded sweep: (allocations over two measured rounds, resident slab
/// rows afterwards, spill-file writes during the measured rounds).
///
/// The spill-backed pool preallocates every frame and I/O staging buffer at
/// construction and the sweep scratch is grow-only, so once round 1 has
/// sized everything, a full shard sweep — gather, halo reads, kernels,
/// write-backs, LRU evictions with their file traffic — must never touch
/// the heap, even while shards actively spill and reload.
fn steady_sharded_sweep_allocs(
    algo: AlgoKind,
    shard_nodes: usize,
    hot_shards: usize,
    tweak: impl FnOnce(&mut ExperimentConfig),
) -> (u64, usize, u64) {
    let mut cfg = ExperimentConfig::default();
    cfg.n = 6;
    cfg.d = 42;
    cfg.hidden = 8;
    cfg.m = 8;
    cfg.q = 4;
    cfg.algo = algo;
    cfg.total_steps = 40;
    cfg.eval_every = 1000; // observe() is cadence work, not round work
    cfg.backend = Backend::Native;
    cfg.mode = Mode::Fused;
    cfg.threads = 1;
    cfg.records_per_hospital = 60;
    cfg.shard_nodes = shard_nodes;
    cfg.hot_shards = hot_shards;
    tweak(&mut cfg);
    let asm = assemble(&cfg).unwrap();
    let engine = RoundEngine::from_config(&cfg);
    let mut driver = ShardedSync::new(&cfg, &asm.ds, &asm.graph, &asm.w).unwrap();
    driver.begin().unwrap();

    // warm-up round: sizes the sampler scratch, the kernel workspace, the
    // cached network view, and the halo/gather sweep buffers
    let local = engine.plan.local_per_round;
    let lrs1 = engine.sched.local_lrs(1, engine.q, local);
    driver.local_phase(1, &lrs1).unwrap();
    driver.comm_phase(1, engine.sched.comm_lr(1, engine.q)).unwrap();

    let lrs2 = engine.sched.local_lrs(2, engine.q, local);
    let lrs3 = engine.sched.local_lrs(3, engine.q, local);
    let spills_before = driver.pool_stats().spills;
    let before = allocs_here();
    driver.local_phase(2, &lrs2).unwrap();
    driver.comm_phase(2, engine.sched.comm_lr(2, engine.q)).unwrap();
    driver.local_phase(3, &lrs3).unwrap();
    driver.comm_phase(3, engine.sched.comm_lr(3, engine.q)).unwrap();
    let allocs = allocs_here() - before;
    let spilled = driver.pool_stats().spills - spills_before;
    (allocs, driver.resident_rows(), spilled)
}

// n = 6 in shards of 2 with a 2-frame hot set: every sweep cycles 3 shards
// through 2 frames, so the measured rounds continuously evict dirty frames
// to the spill file — the warm path must stay allocation-free THROUGH that
// file traffic, and the resident rows must stay at the hot-set bound.
#[test]
fn steady_state_sharded_dsgd_sweep_is_allocation_free_and_bounded() {
    let (n, resident, spilled) = steady_sharded_sweep_allocs(AlgoKind::FdDsgd, 2, 2, |_| {});
    assert_eq!(n, 0, "sharded fd-dsgd sweep performed {n} heap allocations");
    assert!(resident <= 2 * 2, "resident rows {resident} exceed hot_shards × shard_nodes");
    assert!(spilled > 0, "measured rounds must actually exercise the spill path");
}

#[test]
fn steady_state_sharded_dsgt_sweep_is_allocation_free_and_bounded() {
    let (n, resident, spilled) = steady_sharded_sweep_allocs(AlgoKind::FdDsgt, 2, 2, |_| {});
    assert_eq!(n, 0, "sharded fd-dsgt sweep performed {n} heap allocations");
    assert!(resident <= 2 * 2, "resident rows {resident} exceed hot_shards × shard_nodes");
    assert!(spilled > 0, "measured rounds must actually exercise the spill path");
}

// PR-10: the compressed sharded sweep — encode sweep (q8 + error-feedback
// residuals through the pooled X̂/Ŷ and EF quantities), the quarantine flag
// scan, gather over the decoded stacks, and the rule kernels — must also
// stay allocation-free once warm, WHILE those extra pooled quantities churn
// through spill evictions (3 shards through 2 frames every sweep).
#[test]
fn steady_state_sharded_q8_ef_sweep_is_allocation_free_through_spills() {
    let (n, resident, spilled) = steady_sharded_sweep_allocs(AlgoKind::FdDsgt, 2, 2, |c| {
        c.compress = "q8".into();
        c.error_feedback = true;
    });
    assert_eq!(n, 0, "sharded q8+EF sweep performed {n} heap allocations");
    assert!(resident <= 2 * 2, "resident rows {resident} exceed hot_shards × shard_nodes");
    assert!(spilled > 0, "q8+EF slabs must live through real evictions");
}
