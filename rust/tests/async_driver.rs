//! Integration: the asynchronous event-driven driver — replay determinism
//! (same seed ⇒ identical event order, final θ, and sim_time, across runs
//! and across native thread counts), the staleness-bound property, the
//! per-message accounting identity against the sync per-round totals, and
//! the headline claim: under a lognormal straggler plan the async virtual
//! clock beats the synchronous barrier to the same accuracy.

use decfl::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use decfl::coordinator::compute::NativeCompute;
use decfl::coordinator::{assemble, run_on};
use decfl::engine::asynchrony::{train_report, AsyncReport};

fn async_cfg(algo: AlgoKind, plan: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.n = 6;
    cfg.d = 42;
    cfg.hidden = 8;
    cfg.m = 8;
    cfg.q = 4;
    cfg.algo = algo;
    cfg.total_steps = 48;
    cfg.eval_every = 1;
    cfg.mode = Mode::Fused;
    cfg.backend = Backend::Native;
    cfg.driver = "async".into();
    cfg.records_per_hospital = 60;
    cfg.heterogeneity = 0.5;
    cfg.topology = "ring".into();
    cfg.compute_plan = plan.into();
    cfg.compute_sigma = 0.7;
    cfg.slow_frac = 0.4;
    cfg
}

fn report_with_threads(cfg: &ExperimentConfig, threads: usize) -> AsyncReport {
    let asm = assemble(cfg).unwrap();
    let compute =
        NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m).with_threads(threads);
    train_report(cfg, &compute, &asm.ds, &asm.graph, &asm.w).unwrap()
}

fn assert_reports_bitwise_equal(a: &AsyncReport, b: &AsyncReport, what: &str) {
    assert_eq!(a.trace_hash, b.trace_hash, "{what}: event order diverged");
    assert_eq!(a.theta, b.theta, "{what}: final θ diverged");
    assert_eq!(a.final_t_us, b.final_t_us, "{what}: virtual clock diverged");
    assert_eq!(a.log.rows.len(), b.log.rows.len(), "{what}");
    for (ra, rb) in a.log.rows.iter().zip(&b.log.rows) {
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{what}");
        assert_eq!(ra.accuracy.to_bits(), rb.accuracy.to_bits(), "{what}");
        assert_eq!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits(), "{what}");
        assert_eq!(ra.bytes, rb.bytes, "{what}");
        assert_eq!(ra.messages, rb.messages, "{what}");
    }
}

#[test]
fn replay_is_bitwise_deterministic_across_runs_and_thread_counts() {
    // the event loop is serial by construction; the native backend's
    // fan-out ops are pinned bitwise at any pool size — so the whole
    // async trajectory must be too, for DSGD and DSGT alike
    for algo in [AlgoKind::FdDsgd, AlgoKind::FdDsgt] {
        let cfg = async_cfg(algo, "lognormal");
        let serial = report_with_threads(&cfg, 1);
        let replay = report_with_threads(&cfg, 1);
        assert_reports_bitwise_equal(&serial, &replay, "serial replay");
        let threaded = report_with_threads(&cfg, 3);
        assert_reports_bitwise_equal(&serial, &threaded, "threads=1 vs threads=3");
        assert!(serial.applied > 0, "{algo:?}: no neighbor state ever applied");
    }
}

#[test]
fn run_on_routes_async_and_stays_deterministic() {
    // the coordinator path (run.driver = "async") must reproduce itself
    // bitwise too — this is what `decfl train --driver async` executes
    let mut cfg = async_cfg(AlgoKind::FdDsgt, "lognormal");
    cfg.threads = 1;
    let asm = assemble(&cfg).unwrap();
    let a = run_on(&cfg, &asm).unwrap();
    cfg.threads = 2;
    let b = run_on(&cfg, &asm).unwrap();
    assert_eq!(a.rows.len(), b.rows.len());
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
        assert_eq!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits());
    }
}

#[test]
fn staleness_bound_holds_across_caps_and_seeds() {
    // property: no applied neighbor state is ever older than the cap, at
    // any cap and seed; capping only ever folds *more* weight into self
    for seed in [7u64, 11, 23] {
        let mut free = async_cfg(AlgoKind::FdDsgd, "lognormal");
        free.seed = seed;
        let uncapped = report_with_threads(&free, 1);
        assert!(uncapped.applied > 0, "seed {seed}");
        for cap_s in [0.5f64, 0.05, 0.005] {
            let mut cfg = free.clone();
            cfg.staleness_s = cap_s;
            let rep = report_with_threads(&cfg, 1);
            let cap_us = (cap_s * 1e6).round() as u64;
            assert!(
                rep.max_applied_age_us <= cap_us,
                "seed {seed} cap {cap_s}: applied age {}µs exceeds cap {}µs",
                rep.max_applied_age_us,
                cap_us
            );
            assert!(
                rep.folded >= uncapped.folded,
                "seed {seed} cap {cap_s}: folded {} < uncapped {}",
                rep.folded,
                uncapped.folded
            );
            assert!(rep.theta.iter().all(|v| v.is_finite()), "seed {seed} cap {cap_s}");
        }
    }
}

#[test]
fn async_byte_and_message_totals_match_the_sync_round_accounting() {
    // satellite regression: the async driver charges through the
    // accountant's per-message path; on a static all-online plan its
    // byte/message totals must equal the sync per-round totals exactly —
    // the encoded-wire-size logic is shared, not duplicated
    for (algo, compressor) in
        [(AlgoKind::FdDsgd, "none"), (AlgoKind::FdDsgt, "none"), (AlgoKind::FdDsgd, "q8")]
    {
        let mut sync_cfg = async_cfg(algo, "uniform");
        sync_cfg.driver = "sync".into();
        sync_cfg.compress = compressor.into();
        let asm = assemble(&sync_cfg).unwrap();
        let sync_log = run_on(&sync_cfg, &asm).unwrap();
        let mut acfg = sync_cfg.clone();
        acfg.driver = "async".into();
        let async_log = run_on(&acfg, &asm).unwrap();
        let (s, a) = (sync_log.rows.last().unwrap(), async_log.rows.last().unwrap());
        assert_eq!(s.bytes, a.bytes, "{algo:?}/{compressor}: byte totals diverged");
        assert_eq!(s.messages, a.messages, "{algo:?}/{compressor}: message counts diverged");
        assert_eq!(s.comm_rounds, a.comm_rounds, "{algo:?}/{compressor}");
    }
}

#[test]
fn async_beats_the_sync_barrier_to_target_accuracy_under_lognormal() {
    // the acceptance frontier at test scale, under the matched-time budget:
    // given the simulated wall-clock the barriered run spent, async must
    // reach the sync driver's final accuracy − 1 point with time to spare,
    // and end within a point of the sync final.  Regime note (DESIGN.md
    // §13): cycle compute (q·s_step) must dominate delivery latency, and
    // the lognormal tail must be heavy enough that the barrier hurts —
    // hence q=32 and σ=1.5.
    let mut sync_cfg = async_cfg(AlgoKind::FdDsgd, "lognormal");
    sync_cfg.driver = "sync".into();
    sync_cfg.n = 24;
    sync_cfg.q = 32;
    sync_cfg.total_steps = 1920; // 60 sync rounds
    sync_cfg.eval_every = 2;
    sync_cfg.compute_sigma = 1.5;
    sync_cfg.topology = "er".into();
    let asm = assemble(&sync_cfg).unwrap();
    let sync_log = run_on(&sync_cfg, &asm).unwrap();
    let sync_last = sync_log.rows.last().unwrap();
    let target = sync_last.accuracy - 0.01;
    let horizon = sync_last.sim_time_s;

    let mut acfg = sync_cfg.clone();
    acfg.driver = "async".into();
    acfg.sim_budget_s = horizon;
    let async_log = run_on(&acfg, &asm).unwrap();
    let t_async = async_log
        .rows
        .iter()
        .find(|r| r.accuracy >= target)
        .unwrap_or_else(|| panic!("async never reached sync final − 1pt ({target})"))
        .sim_time_s;
    assert!(
        t_async < horizon,
        "async reached accuracy {target} at {t_async}s but the sync run needed its whole \
         {horizon}s horizon to produce it"
    );
    let async_final = async_log.rows.last().unwrap().accuracy;
    assert!(
        async_final >= sync_last.accuracy - 0.0151,
        "async final accuracy {async_final} fell more than 1.5pt below sync's {}",
        sync_last.accuracy
    );
}
