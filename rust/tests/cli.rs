//! Integration: the `decfl` binary end-to-end (help, graph, native train,
//! info, error paths).  PJRT-independent subcommands run unconditionally.

mod common;

use std::process::Command;

fn decfl(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_decfl"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn decfl")
}

#[test]
fn help_lists_subcommands() {
    let out = decfl(&["help"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for sub in ["train", "fig2", "graph", "tsne", "speedup", "qsweep", "baselines"] {
        assert!(text.contains(sub), "help missing `{sub}`");
    }
}

#[test]
fn unknown_subcommand_fails() {
    let out = decfl(&["frobnicate"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn unknown_flag_fails_loudly() {
    let out = decfl(&["train", "--bogus-flag", "1"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--bogus-flag"));
}

#[test]
fn graph_subcommand_prints_spectral_stats() {
    let out = decfl(&["graph", "--seed", "3"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("spectral gap"));
    assert!(text.contains("20 nodes"));
}

#[test]
fn native_train_csv_and_json() {
    let json_path = std::env::temp_dir().join(format!("decfl_cli_{}.json", std::process::id()));
    let out = decfl(&[
        "train", "--backend", "native", "--algo", "fd-dsgd", "--steps", "60",
        "--q", "10", "--eval-every", "2",
        "--out", json_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.starts_with("comm_rounds,"), "csv header missing");
    assert!(text.lines().count() >= 4);
    let dumped = std::fs::read_to_string(&json_path).unwrap();
    let j = decfl::jsonl::Json::parse(&dumped).unwrap();
    assert_eq!(j.get("algo").unwrap().as_str().unwrap(), "fd-dsgd");
    std::fs::remove_file(&json_path).ok();
}

#[test]
fn info_requires_artifacts() {
    let out = decfl(&["info", "--artifacts", "/nonexistent"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("make artifacts"));
}

#[test]
fn info_with_artifacts() {
    if common::artifacts_dir().is_none() {
        return;
    }
    let out = decfl(&["info"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("P=1409"), "{text}");
    assert!(text.contains("dsgt_round"));
}
