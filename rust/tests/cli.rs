//! Integration: the `decfl` binary end-to-end (help, graph, native train,
//! info, error paths).  PJRT-independent subcommands run unconditionally.

mod common;

use std::process::Command;

fn decfl(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_decfl"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn decfl")
}

#[test]
fn help_lists_subcommands() {
    let out = decfl(&["help"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for sub in
        ["train", "fig2", "graph", "tsne", "speedup", "qsweep", "baselines", "churn", "compress"]
    {
        assert!(text.contains(sub), "help missing `{sub}`");
    }
    for flag in [
        "--net-plan",
        "--rewire-every",
        "--edge-drop",
        "--churn",
        "--compress",
        "--topk-frac",
        "--compute-plan",
        "--tiers",
        "--slow-frac",
        "--sigma",
        "--driver",
        "--staleness-s",
        "--net-validate",
        "--attack-plan",
        "--attack-frac",
        "--robust-rule",
        "--robust-trim",
        "--dp",
        "--dp-clip",
        "--dp-sigma",
    ] {
        assert!(text.contains(flag), "help missing `{flag}`");
    }
    assert!(text.contains("stragglers"), "help missing `stragglers`");
    assert!(text.contains("async"), "help missing `async`");
    assert!(text.contains("robust"), "help missing `robust`");
}

#[test]
fn unknown_subcommand_fails() {
    let out = decfl(&["frobnicate"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn unknown_flag_fails_loudly() {
    let out = decfl(&["train", "--bogus-flag", "1"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--bogus-flag"));
}

#[test]
fn graph_subcommand_prints_spectral_stats() {
    let out = decfl(&["graph", "--seed", "3"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("spectral gap"));
    assert!(text.contains("20 nodes"));
}

#[test]
fn native_train_csv_and_json() {
    let json_path = std::env::temp_dir().join(format!("decfl_cli_{}.json", std::process::id()));
    let out = decfl(&[
        "train", "--backend", "native", "--algo", "fd-dsgd", "--steps", "60",
        "--q", "10", "--eval-every", "2",
        "--out", json_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.starts_with("comm_rounds,"), "csv header missing");
    assert!(text.lines().count() >= 4);
    let dumped = std::fs::read_to_string(&json_path).unwrap();
    let j = decfl::jsonl::Json::parse(&dumped).unwrap();
    assert_eq!(j.get("algo").unwrap().as_str().unwrap(), "fd-dsgd");
    std::fs::remove_file(&json_path).ok();
}

#[test]
fn dynamic_plan_train_runs_natively() {
    let out = decfl(&[
        "train", "--backend", "native", "--algo", "fd-dsgd", "--steps", "40",
        "--q", "10", "--eval-every", "2", "--net-plan", "churn", "--churn", "0.2",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.starts_with("comm_rounds,"), "csv header missing");
}

#[test]
fn churn_subcommand_sweeps_all_plans() {
    let out = decfl(&[
        "churn", "--backend", "native", "--steps", "40", "--q", "10",
        "--eval-every", "2", "--drops", "0.3", "--churns", "0.2",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for label in ["static", "rewire@", "edge-drop 0.30", "churn 0.20"] {
        assert!(text.contains(label), "churn table missing `{label}`:\n{text}");
    }
    assert!(text.contains("finding:"), "{text}");
}

#[test]
fn churn_subcommand_rejects_plan_axis_flags() {
    // the sweep owns the plan axis: passing --net-plan/--edge-drop/--churn
    // must fail loudly instead of being silently overwritten
    let out = decfl(&["churn", "--backend", "native", "--steps", "20", "--net-plan", "rewire"]);
    assert!(!out.status.success(), "churn --net-plan must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--net-plan"), "{err}");
    assert!(err.contains("--drops"), "{err}");

    let out = decfl(&["churn", "--backend", "native", "--steps", "20", "--algo", "fedavg"]);
    assert!(!out.status.success(), "churn --algo fedavg must fail");
    assert!(String::from_utf8_lossy(&out.stderr).contains("gossip"), "no gossip hint");
}

#[test]
fn sweep_subcommands_reject_plan_flags() {
    // sweeps build their own configs: plan flags would be silently ignored
    for sub in ["baselines", "qsweep", "hetero"] {
        let out = decfl(&[sub, "--steps", "20", "--net-plan", "churn"]);
        assert!(!out.status.success(), "{sub} --net-plan must fail");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("--net-plan"), "{sub}: {err}");
        assert!(err.contains("silently ignore"), "{sub}: {err}");
    }
    // the same plan arriving through --config TOML is caught too
    let toml = std::env::temp_dir().join(format!("decfl_plan_{}.toml", std::process::id()));
    std::fs::write(&toml, "[net]\nplan = \"churn\"\n").unwrap();
    let out = decfl(&["baselines", "--steps", "20", "--config", toml.to_str().unwrap()]);
    assert!(!out.status.success(), "baselines with TOML net.plan must fail");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("net.plan"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::remove_file(&toml).ok();
}

#[test]
fn baselines_reject_network_flags_loudly() {
    let out = decfl(&[
        "train", "--backend", "native", "--algo", "fedavg", "--steps", "20",
        "--topology", "ring",
    ]);
    assert!(!out.status.success(), "fedavg --topology must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--topology"), "{err}");
    assert!(err.contains("silently ignore"), "{err}");

    let out = decfl(&[
        "train", "--backend", "native", "--algo", "centralized", "--steps", "20",
        "--net-plan", "churn",
    ]);
    assert!(!out.status.success(), "centralized --net-plan must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--net-plan"), "{err}");
}

#[test]
fn straggler_train_runs_natively() {
    let out = decfl(&[
        "train", "--backend", "native", "--algo", "fd-dsgd", "--steps", "40",
        "--q", "10", "--eval-every", "2", "--compute-plan", "dropout", "--slow-frac", "0.3",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).starts_with("comm_rounds,"));
}

#[test]
fn stragglers_subcommand_sweeps_the_frontier() {
    let out = decfl(&[
        "stragglers", "--backend", "native", "--steps", "40", "--q", "10",
        "--eval-every", "2", "--plans", "fixed-tiers,dropout", "--tiers", "1.0,0.5",
        "--slow-frac", "0.4",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for label in ["uniform", "tiers[1.00,0.50]", "dropout 0.40", "sim_time_s"] {
        assert!(text.contains(label), "frontier table missing `{label}`:\n{text}");
    }
    assert!(text.contains("finding:"), "{text}");
}

#[test]
fn stragglers_subcommand_rejects_plan_axis_flags() {
    let out = decfl(&[
        "stragglers", "--backend", "native", "--steps", "20", "--compute-plan", "dropout",
    ]);
    assert!(!out.status.success(), "stragglers --compute-plan must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--plans"), "{err}");

    let out = decfl(&["stragglers", "--backend", "native", "--steps", "20", "--algo", "fedavg"]);
    assert!(!out.status.success(), "stragglers --algo fedavg must fail");
    assert!(String::from_utf8_lossy(&out.stderr).contains("gossip"), "no gossip hint");
}

#[test]
fn async_subcommand_sweeps_the_driver_frontier() {
    let out = decfl(&[
        "async", "--backend", "native", "--steps", "64", "--q", "16",
        "--eval-every", "1", "--topology", "ring", "--stalenesses", "0,0.5",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for label in ["sync", "async uncapped", "async s=0.50", "t_to_target_s"] {
        assert!(text.contains(label), "frontier table missing `{label}`:\n{text}");
    }
    assert!(text.contains("finding:"), "{text}");
}

#[test]
fn async_subcommand_owns_the_driver_axis() {
    let out = decfl(&[
        "async", "--backend", "native", "--steps", "20", "--driver", "async",
    ]);
    assert!(!out.status.success(), "async --driver must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--stalenesses"), "{err}");

    let out = decfl(&["async", "--backend", "native", "--steps", "20", "--algo", "fedavg"]);
    assert!(!out.status.success(), "async --algo fedavg must fail");
    assert!(String::from_utf8_lossy(&out.stderr).contains("gossip"), "no gossip hint");
}

#[test]
fn train_routes_the_async_driver() {
    let out = decfl(&[
        "train", "--backend", "native", "--algo", "fd-dsgd", "--driver", "async",
        "--steps", "40", "--q", "10", "--eval-every", "2", "--compute-plan", "lognormal",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).starts_with("comm_rounds,"));
}

#[test]
fn sweeps_and_baselines_reject_compute_plan_flags() {
    // sweeps build their own configs: straggler flags would be ignored
    let out = decfl(&["qsweep", "--steps", "20", "--compute-plan", "dropout"]);
    assert!(!out.status.success(), "qsweep --compute-plan must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--compute-plan"), "{err}");
    assert!(err.contains("uniform Q"), "{err}");
    // FedAvg runs the synchronous baseline: no fleet to straggle
    let out = decfl(&[
        "train", "--backend", "native", "--algo", "fedavg", "--steps", "20",
        "--compute-plan", "dropout",
    ]);
    assert!(!out.status.success(), "fedavg --compute-plan must fail");
    assert!(String::from_utf8_lossy(&out.stderr).contains("--compute-plan"));
    // the same plan arriving through --config TOML is caught too
    let toml = std::env::temp_dir().join(format!("decfl_cplan_{}.toml", std::process::id()));
    std::fs::write(&toml, "[compute]\nplan = \"dropout\"\n").unwrap();
    let out = decfl(&["baselines", "--steps", "20", "--config", toml.to_str().unwrap()]);
    assert!(!out.status.success(), "baselines with TOML compute.plan must fail");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("compute.plan"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::remove_file(&toml).ok();
}

#[test]
fn compressed_train_runs_natively() {
    let out = decfl(&[
        "train", "--backend", "native", "--algo", "fd-dsgd", "--steps", "40",
        "--q", "10", "--eval-every", "2", "--compress", "q8",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).starts_with("comm_rounds,"));
}

#[test]
fn compress_subcommand_sweeps_the_frontier() {
    let out = decfl(&[
        "compress", "--backend", "native", "--steps", "40", "--q", "10",
        "--eval-every", "2", "--compressors", "q8,q4", "--fracs", "0.1",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for label in ["none", "q8", "q4", "topk@0.10", "reduction"] {
        assert!(text.contains(label), "frontier table missing `{label}`:\n{text}");
    }
    assert!(text.contains("finding:"), "{text}");
}

#[test]
fn compress_subcommand_rejects_compressor_axis_flags() {
    let out = decfl(&["compress", "--backend", "native", "--steps", "20", "--compress", "q8"]);
    assert!(!out.status.success(), "compress --compress must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--compressors"), "{err}");

    let out = decfl(&["compress", "--backend", "native", "--steps", "20", "--algo", "fedavg"]);
    assert!(!out.status.success(), "compress --algo fedavg must fail");
    assert!(String::from_utf8_lossy(&out.stderr).contains("gossip"));
}

#[test]
fn sweeps_and_baselines_reject_compression_flags() {
    // sweeps build their own configs: compression flags would be ignored
    let out = decfl(&["qsweep", "--steps", "20", "--compress", "q8"]);
    assert!(!out.status.success(), "qsweep --compress must fail");
    assert!(String::from_utf8_lossy(&out.stderr).contains("--compress"));
    // FedAvg has no gossip messages to compress
    let out = decfl(&[
        "train", "--backend", "native", "--algo", "fedavg", "--steps", "20",
        "--compress", "q8",
    ]);
    assert!(!out.status.success(), "fedavg --compress must fail");
    assert!(String::from_utf8_lossy(&out.stderr).contains("--compress"));
}

#[test]
fn adversarial_train_runs_natively() {
    let out = decfl(&[
        "train", "--backend", "native", "--algo", "fd-dsgd", "--steps", "40",
        "--q", "10", "--eval-every", "2", "--attack-plan", "sign-flip",
        "--attack-frac", "0.2", "--robust-rule", "trimmed-mean",
        "--dp", "gaussian", "--dp-clip", "10",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.starts_with("comm_rounds,"), "csv header missing");
    assert!(text.contains("quarantined,dp_epsilon"), "adversarial columns missing:\n{text}");
}

#[test]
fn robust_subcommand_sweeps_the_frontier() {
    let out = decfl(&[
        "robust", "--backend", "native", "--steps", "40", "--q", "10",
        "--eval-every", "2", "--rules", "mean,median", "--fracs", "0.25",
        "--topos", "ring",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for label in ["none", "sign-flip f=0.25", "median", "quarantined"] {
        assert!(text.contains(label), "frontier table missing `{label}`:\n{text}");
    }
    assert!(text.contains("finding:"), "{text}");
}

#[test]
fn robust_subcommand_owns_the_attack_axes() {
    let out = decfl(&[
        "robust", "--backend", "native", "--steps", "20", "--attack-frac", "0.3",
    ]);
    assert!(!out.status.success(), "robust --attack-frac must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--rules"), "{err}");

    let out = decfl(&[
        "robust", "--backend", "native", "--steps", "20", "--robust-rule", "median",
    ]);
    assert!(!out.status.success(), "robust --robust-rule must fail");
    assert!(String::from_utf8_lossy(&out.stderr).contains("--fracs"));

    let out = decfl(&["robust", "--backend", "native", "--steps", "20", "--algo", "fedavg"]);
    assert!(!out.status.success(), "robust --algo fedavg must fail");
    assert!(String::from_utf8_lossy(&out.stderr).contains("gossip"), "no gossip hint");
}

#[test]
fn sweeps_and_baselines_reject_adversarial_flags() {
    // sweeps build their own configs: adversarial flags would be ignored
    let out = decfl(&["qsweep", "--steps", "20", "--attack-plan", "sign-flip"]);
    assert!(!out.status.success(), "qsweep --attack-plan must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--attack-plan"), "{err}");
    assert!(err.contains("decfl robust"), "{err}");
    let out = decfl(&["baselines", "--steps", "20", "--dp", "gaussian"]);
    assert!(!out.status.success(), "baselines --dp must fail");
    assert!(String::from_utf8_lossy(&out.stderr).contains("--dp"));
    // FedAvg and centralized have no gossip messages to attack or clip
    for algo in ["fedavg", "centralized"] {
        let out = decfl(&[
            "train", "--backend", "native", "--algo", algo, "--steps", "20",
            "--robust-rule", "median",
        ]);
        assert!(!out.status.success(), "{algo} --robust-rule must fail");
        assert!(String::from_utf8_lossy(&out.stderr).contains("--robust-rule"));
    }
    // the same settings arriving through --config TOML are caught too
    let toml = std::env::temp_dir().join(format!("decfl_attack_{}.toml", std::process::id()));
    std::fs::write(&toml, "[attack]\nplan = \"sign-flip\"\nfrac = 0.2\n").unwrap();
    let out = decfl(&["baselines", "--steps", "20", "--config", toml.to_str().unwrap()]);
    assert!(!out.status.success(), "baselines with TOML attack.plan must fail");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("attack.plan"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::remove_file(&toml).ok();
}

#[test]
fn info_requires_artifacts() {
    let out = decfl(&["info", "--artifacts", "/nonexistent"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("make artifacts"));
}

#[test]
fn info_with_artifacts() {
    if common::artifacts_dir().is_none() {
        return;
    }
    let out = decfl(&["info"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("P=1409"), "{text}");
    assert!(text.contains("dsgt_round"));
}
