//! Integration: driver equivalence — the refactor's correctness pin.
//!
//! The same config must produce BITWISE-identical loss trajectories through
//! (a) the fused sync driver vs the actor driver (one thread per hospital,
//! gossip over the channel netsim) — for the static network AND for every
//! dynamic `NetPlan`, (b) serial vs threaded native compute, and (c) the
//! `Static` schedule vs a hand-rolled replica of the pre-schedule
//! single-graph loop (W captured once, no per-round views).  All pins also
//! guard the parallel fan-out against nondeterministic reduction order.
//!
//! Scenario configs come from `common::ScenarioBuilder`; the fused-vs-actors
//! assertion is `common::pin_fused_eq_actors`.

mod common;

use common::{pin_fused_eq_actors, ScenarioBuilder};
use decfl::algo::LrSchedule;
use decfl::config::{AlgoKind, Mode};
use decfl::coordinator::sampler::{init_thetas, NodeSampler};
use decfl::coordinator::{assemble, run_on, Compute, NativeCompute};
use decfl::rng::Pcg64;

#[test]
fn fused_and_actor_drivers_bitwise_identical() {
    for (algo, q, steps) in [
        (AlgoKind::Dsgd, 1, 10),
        (AlgoKind::FdDsgd, 4, 24),
        (AlgoKind::Dsgt, 1, 10),
        (AlgoKind::FdDsgt, 4, 24),
    ] {
        let cfg = ScenarioBuilder::gossip(algo).rounds(q, steps).build();
        pin_fused_eq_actors(&cfg, &format!("{algo:?}"));
    }
}

#[test]
fn dynamic_plans_fused_and_actor_drivers_bitwise_identical() {
    // (plan, base topology, algo) — every dynamic NetPlan through both
    // drivers, DSGD and DSGT flavors, with per-round byte accounting
    // matching the channel netsim on lossless links.  With edge counts
    // varying every round, the byte totals only agree if every round was
    // charged its own edge count.
    for (plan, topo, algo) in [
        ("rewire", "er", AlgoKind::FdDsgd),
        ("rewire", "er", AlgoKind::FdDsgt),
        ("edge-drop", "complete", AlgoKind::FdDsgd),
        ("edge-drop", "complete", AlgoKind::FdDsgt),
        ("churn", "ring", AlgoKind::FdDsgd),
        ("churn", "ring", AlgoKind::FdDsgt),
    ] {
        let cfg = ScenarioBuilder::gossip(algo)
            .rounds(3, 30)
            .topology(topo)
            .plan(plan)
            .build();
        pin_fused_eq_actors(&cfg, &format!("{plan}/{algo:?}"));
    }
}

#[test]
fn compressed_gossip_fused_and_actor_drivers_bitwise_identical() {
    // every compressor, both algorithm families: the fused driver's
    // whole-stack EF pass and the actor driver's per-node EF step must
    // produce the identical decoded stacks — and therefore bitwise-equal
    // trajectories — with the analytic accountant matching the channel
    // netsim's *encoded* byte charges message for message.
    for (algo, compress, frac, ef) in [
        (AlgoKind::FdDsgd, "identity", 0.1, false),
        (AlgoKind::FdDsgd, "q8", 0.1, false),
        (AlgoKind::FdDsgd, "q8", 0.1, true), // opt-in EF residual path
        (AlgoKind::FdDsgd, "q4", 0.1, false),
        (AlgoKind::FdDsgd, "topk", 0.1, false),
        (AlgoKind::FdDsgt, "identity", 0.1, true),
        (AlgoKind::FdDsgt, "q8", 0.1, false),
        (AlgoKind::FdDsgt, "q8", 0.1, true),
        (AlgoKind::FdDsgt, "q4", 0.1, false),
        (AlgoKind::FdDsgt, "topk", 0.05, false),
    ] {
        let cfg = ScenarioBuilder::gossip(algo)
            .rounds(3, 18)
            .compressor(compress, frac, ef)
            .build();
        pin_fused_eq_actors(&cfg, &format!("{algo:?}/{compress}"));
    }
}

#[test]
fn compressed_gossip_under_churn_drivers_bitwise_identical() {
    // compression composes with a dynamic plan: offline nodes skip the EF
    // step entirely (residuals carry), and both drivers must agree on it
    let cfg = ScenarioBuilder::gossip(AlgoKind::FdDsgd)
        .rounds(3, 24)
        .plan("churn")
        .compressor("q8", 0.1, false)
        .build();
    pin_fused_eq_actors(&cfg, "churn+q8");
}

#[test]
fn straggler_plans_fused_and_actor_drivers_bitwise_identical() {
    // every straggler ComputePlan through both drivers, DSGD and DSGT
    // flavors: per-node τ-truncated local phases and the FedNova-style
    // τ-weighted rescale must agree bit for bit (including the
    // schedule-derived true local work), and stragglers never change
    // gossip participation, so bytes/messages match exactly too
    for (plan, algo) in [
        ("fixed-tiers", AlgoKind::FdDsgd),
        ("fixed-tiers", AlgoKind::FdDsgt),
        ("lognormal", AlgoKind::FdDsgd),
        ("lognormal", AlgoKind::FdDsgt),
        ("dropout", AlgoKind::FdDsgd),
        ("dropout", AlgoKind::FdDsgt),
    ] {
        let cfg = ScenarioBuilder::gossip(algo).compute(plan).build();
        pin_fused_eq_actors(&cfg, &format!("{plan}/{algo:?}"));
    }
}

#[test]
fn straggler_plan_composed_with_churn_and_compression_bitwise_identical() {
    // the three scenario axes compose: a dropout compute plan under node
    // churn with q8-compressed gossip — both drivers must still agree bit
    // for bit (offline nodes skip comm, stragglers truncate local work,
    // and the compression streams stay (seed, round, node, kind)-keyed)
    for algo in [AlgoKind::FdDsgd, AlgoKind::FdDsgt] {
        let cfg = ScenarioBuilder::gossip(algo)
            .rounds(3, 24)
            .compute("dropout")
            .tweak(|c| c.slow_frac = 0.3)
            .plan("churn")
            .compressor("q8", 0.1, false)
            .build();
        pin_fused_eq_actors(&cfg, &format!("dropout+churn+q8/{algo:?}"));
    }
}

#[test]
fn uniform_compute_plan_is_the_legacy_path_bitwise() {
    // zero behavior change by default: an explicit `uniform` plan and the
    // untouched default config produce identical logs through both drivers
    for mode in [Mode::Fused, Mode::Actors] {
        let cfg = ScenarioBuilder::gossip(AlgoKind::FdDsgt)
            .rounds(4, 24)
            .mode(mode)
            .build();
        assert_eq!(cfg.compute_plan, "uniform", "default plan is uniform");
        let asm = assemble(&cfg).unwrap();
        let default_log = run_on(&cfg, &asm).unwrap();
        let mut explicit = cfg.clone();
        explicit.compute_plan = "uniform".into();
        let explicit_log = run_on(&explicit, &asm).unwrap();
        assert_eq!(default_log.rows.len(), explicit_log.rows.len());
        for (a, b) in default_log.rows.iter().zip(&explicit_log.rows) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{mode:?}");
            assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{mode:?}");
            assert_eq!(a.local_steps, b.local_steps, "{mode:?}");
            assert_eq!(a.bytes, b.bytes, "{mode:?}");
        }
    }
}

#[test]
fn static_schedule_reproduces_pre_refactor_single_graph_loop() {
    // Hand-rolled replica of the pre-schedule trainer: W captured once as
    // f32, the same round structure inlined, no NetworkSchedule anywhere.
    // The engine's Static plan must match it bit for bit.
    let cfg = ScenarioBuilder::gossip(AlgoKind::FdDsgd).rounds(4, 24).build();
    assert_eq!(cfg.net_plan, "static", "default plan is static");
    let asm = assemble(&cfg).unwrap();
    let engine_log = run_on(&cfg, &asm).unwrap();

    let compute = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
    let model = decfl::algo::native::NativeModel::new(cfg.d, cfg.hidden);
    let wf: Vec<f32> = asm.w.to_dense(); // captured once, pre-refactor style
    let q = cfg.algo.effective_q(cfg.q);
    let local = q - 1;
    let rounds = cfg.total_steps.div_ceil(q);
    let (n, m, d) = (cfg.n, cfg.m, cfg.d);
    let sched = LrSchedule::new(cfg.alpha0);

    let mut theta = init_thetas(cfg.seed, n, &model);
    let mut samplers: Vec<NodeSampler> =
        (0..n).map(|i| NodeSampler::new(cfg.seed, i, m)).collect();
    let mut lx = vec![0.0f32; n * local * m * d];
    let mut ly = vec![0.0f32; n * local * m];
    let mut cx = vec![0.0f32; n * m * d];
    let mut cy = vec![0.0f32; n * m];

    let mut evals = vec![compute.eval_full(&theta, &asm.ds.shards).unwrap()];
    for round in 1..=rounds {
        let lrs = sched.local_lrs(round, q, local);
        for (i, s) in samplers.iter_mut().enumerate() {
            s.batches(
                &asm.ds.shards[i],
                local,
                &mut lx[i * local * m * d..(i + 1) * local * m * d],
                &mut ly[i * local * m..(i + 1) * local * m],
            );
        }
        theta = compute.local_steps_all(&theta, &lx, &ly, &lrs).unwrap().0;
        for (i, s) in samplers.iter_mut().enumerate() {
            s.batch(
                &asm.ds.shards[i],
                &mut cx[i * m * d..(i + 1) * m * d],
                &mut cy[i * m..(i + 1) * m],
            );
        }
        theta = compute
            .dsgd_round(&wf, &theta, &cx, &cy, sched.comm_lr(round, q))
            .unwrap()
            .0;
        evals.push(compute.eval_full(&theta, &asm.ds.shards).unwrap());
    }

    assert_eq!(engine_log.rows.len(), evals.len(), "eval_every=1 logs every round");
    // Tolerance, not bitwise: the engine path runs the cache-blocked `_into`
    // kernels and degree-sparse gossip (PR 3), and future kernel loop
    // reorders may legally shift f32 summation order relative to this
    // hand-rolled pre-refactor replica.  The replica pins the ROUND
    // STRUCTURE (schedule, sampler streams, update sequence), so a tight
    // tolerance is the right contract here — while fused==actors above
    // stays strictly bitwise, because both drivers share whatever kernels
    // exist.
    let tol = |a: f64, b: f64| (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()));
    for (row, &(loss, acc, stat, cons)) in engine_log.rows.iter().zip(&evals) {
        assert!(tol(row.loss, loss), "round {}: {} vs {loss}", row.comm_rounds, row.loss);
        assert!(tol(row.accuracy, acc), "round {}: accuracy", row.comm_rounds);
        assert!(tol(row.stationarity, stat), "round {}: stationarity", row.comm_rounds);
        assert!(tol(row.consensus, cons), "round {}: consensus", row.comm_rounds);
    }
}

#[test]
fn threaded_training_bitwise_equal_serial() {
    for algo in [AlgoKind::FdDsgd, AlgoKind::FdDsgt] {
        let mut cfg = ScenarioBuilder::gossip(algo).rounds(4, 24).build();
        cfg.threads = 1;
        let serial = run_on(&cfg, &assemble(&cfg).unwrap()).unwrap();
        cfg.threads = 4;
        let threaded = run_on(&cfg, &assemble(&cfg).unwrap()).unwrap();
        assert_eq!(serial.rows.len(), threaded.rows.len());
        for (rs, rt) in serial.rows.iter().zip(&threaded.rows) {
            assert_eq!(rs.loss.to_bits(), rt.loss.to_bits(), "{algo:?}");
            assert_eq!(rs.consensus.to_bits(), rt.consensus.to_bits(), "{algo:?}");
        }
    }
}

#[test]
fn threaded_round_ops_bitwise_equal_serial() {
    // direct op-level pin at an n that doesn't divide the pool evenly
    let (d, h, n, m, local) = (11, 6, 7, 5, 3);
    let serial = NativeCompute::new(d, h, n, m).with_threads(1);
    let threaded = NativeCompute::new(d, h, n, m).with_threads(3);
    let p = serial.dims().2;
    let mut rng = Pcg64::seed(42);
    let mut vec_of = |len: usize, scale: f64| -> Vec<f32> {
        (0..len).map(|_| (rng.normal() * scale) as f32).collect()
    };
    let theta = vec_of(n * p, 0.3);
    let y_tr = vec_of(n * p, 0.1);
    let g_old = vec_of(n * p, 0.1);
    let lx = vec_of(n * local * m * d, 1.0);
    let ly: Vec<f32> = (0..n * local * m).map(|i| (i % 2) as f32).collect();
    let cx = vec_of(n * m * d, 1.0);
    let cy: Vec<f32> = (0..n * m).map(|i| (i % 3 == 0) as u32 as f32).collect();
    let lrs = vec![0.05f32; local];
    let w = vec![1.0f32 / n as f32; n * n];

    let a = serial.local_steps_all(&theta, &lx, &ly, &lrs).unwrap();
    let b = threaded.local_steps_all(&theta, &lx, &ly, &lrs).unwrap();
    assert_eq!(a.0, b.0, "local_steps_all theta");
    assert_eq!(a.1, b.1, "local_steps_all losses");

    let a = serial.dsgd_round(&w, &theta, &cx, &cy, 0.05).unwrap();
    let b = threaded.dsgd_round(&w, &theta, &cx, &cy, 0.05).unwrap();
    assert_eq!(a.0, b.0, "dsgd_round theta");
    assert_eq!(a.1, b.1, "dsgd_round losses");

    let a = serial.dsgt_round(&w, &theta, &y_tr, &g_old, &cx, &cy, 0.05).unwrap();
    let b = threaded.dsgt_round(&w, &theta, &y_tr, &g_old, &cx, &cy, 0.05).unwrap();
    assert_eq!(a.0, b.0, "dsgt_round theta");
    assert_eq!(a.1, b.1, "dsgt_round tracker");
    assert_eq!(a.2, b.2, "dsgt_round grads");
    assert_eq!(a.3, b.3, "dsgt_round losses");

    // eval_full needs real shards
    let ds = decfl::data::generate(&decfl::data::DataConfig {
        n_hospitals: n,
        records_per_hospital: 40,
        records_jitter: 0,
        heterogeneity: 0.5,
        ..decfl::data::DataConfig::default()
    })
    .unwrap();
    let serial_ds = NativeCompute::new(ds.d, h, n, m).with_threads(1);
    let threaded_ds = NativeCompute::new(ds.d, h, n, m).with_threads(3);
    let pd = serial_ds.dims().2;
    let theta_ds: Vec<f32> = {
        let mut r2 = Pcg64::seed(7);
        (0..n * pd).map(|_| (r2.normal() * 0.3) as f32).collect()
    };
    let a = serial_ds.eval_full(&theta_ds, &ds.shards).unwrap();
    let b = threaded_ds.eval_full(&theta_ds, &ds.shards).unwrap();
    assert_eq!(a.0.to_bits(), b.0.to_bits(), "eval loss");
    assert_eq!(a.1.to_bits(), b.1.to_bits(), "eval accuracy");
    assert_eq!(a.2.to_bits(), b.2.to_bits(), "eval stationarity");
    assert_eq!(a.3.to_bits(), b.3.to_bits(), "eval consensus");
}

#[test]
fn baselines_run_through_the_same_engine_cadence() {
    // FedAvg and centralized share the engine loop: same round axis and
    // row cadence as a decentralized run with the same schedule
    let cfg = ScenarioBuilder::gossip(AlgoKind::FdDsgd)
        .rounds(4, 24)
        .eval_every(2)
        .build();
    let asm = assemble(&cfg).unwrap();
    let fd = run_on(&cfg, &asm).unwrap();
    let mut fa_cfg = cfg.clone();
    fa_cfg.algo = AlgoKind::FedAvg;
    let fa = run_on(&fa_cfg, &asm).unwrap();
    let mut ct_cfg = cfg.clone();
    ct_cfg.algo = AlgoKind::Centralized;
    let ct = run_on(&ct_cfg, &asm).unwrap();
    let rounds: Vec<u64> = fd.rows.iter().map(|r| r.comm_rounds).collect();
    assert_eq!(rounds, fa.rows.iter().map(|r| r.comm_rounds).collect::<Vec<_>>());
    assert_eq!(rounds, ct.rows.iter().map(|r| r.comm_rounds).collect::<Vec<_>>());
}
