//! Integration: sharded node-state correctness pins (DESIGN.md §15).
//!
//! The spill-backed shard sweep (`engine::shard`) is an execution-layout
//! change, not an algorithm change, so its contract is BITWISE equality
//! with the resident fused driver:
//! 1. sharded == resident across the supported scenario matrix (algorithm
//!    family × topology × dynamic network plan), logs AND final θ stack;
//! 2. shard-count invariance — 1 shard, k shards, and unsharded agree
//!    exactly, including a hot-set smaller than the shard count (real
//!    spill/reload traffic) and single-node shards;
//! 3. the streaming two-pass eval is a pure left fold in node order, so
//!    ANY contiguous shard partition reproduces the resident
//!    `eval_reduce` bit for bit — property-tested over random boundaries,
//!    plus the 1-vs-999-record skew oracle for the record weighting and
//!    the honest-subfleet filter from the Byzantine layer;
//! 4. (PR 10) the FULL scenario matrix is shard-native: compression
//!    (q8/q4/top-k, EF on/off) × robust combine rule × attack plan × DP ×
//!    straggler compute plan all route through the shared message pipeline
//!    and the quantity-registry pool, and every composition — including a
//!    hot-set smaller than the shard count, so the new pooled quantities
//!    (X̂/Ŷ, EF residuals, replay slots) live through spill evictions —
//!    stays bitwise-equal to the resident fused driver.

mod common;

use common::{assert_logs_bitwise, ScenarioBuilder};
use decfl::algo::native::NativeModel;
use decfl::config::AlgoKind;
use decfl::coordinator::{assemble, make_compute, run_on};
use decfl::data::Shard;
use decfl::engine::{shard, AttackSchedule};
use decfl::metrics::StreamingEval;
use decfl::rng::Pcg64;

#[test]
fn sharded_equals_resident_bitwise_across_scenarios() {
    // n = 9 with shard_nodes = 4 → shards of 4, 4, 1 (uneven tail) and a
    // hot-set smaller than the shard count, so every round spills and
    // reloads through the pool while the trajectory must not move a bit
    for (algo, topo, plan, q, steps) in [
        (AlgoKind::Dsgd, "ring", "static", 1, 10),
        (AlgoKind::Dsgt, "complete", "static", 1, 10),
        (AlgoKind::FdDsgd, "er", "static", 4, 24),
        (AlgoKind::FdDsgt, "ring", "static", 4, 24),
        (AlgoKind::FdDsgd, "ring", "churn", 3, 24),
        (AlgoKind::FdDsgt, "er", "rewire", 3, 24),
        (AlgoKind::FdDsgt, "complete", "edge-drop", 3, 24),
    ] {
        let label = format!("{algo:?}/{topo}/{plan}");
        let mut b = ScenarioBuilder::gossip(algo).n(9).rounds(q, steps).topology(topo);
        if plan != "static" {
            b = b.plan(plan);
        }
        let resident_cfg = b.build();
        let asm = assemble(&resident_cfg).unwrap();
        let compute = make_compute(&resident_cfg).unwrap();
        let (res_log, res_theta) = decfl::engine::train_decentralized(
            &resident_cfg,
            compute.as_ref(),
            &asm.ds,
            &asm.graph,
            &asm.w,
        )
        .unwrap();

        let mut sharded_cfg = resident_cfg.clone();
        sharded_cfg.shard_nodes = 4;
        sharded_cfg.hot_shards = 2;
        let (sh_log, sh_theta) =
            shard::train(&sharded_cfg, &asm.ds, &asm.graph, &asm.w).unwrap();

        assert_logs_bitwise(&res_log, &sh_log, &label);
        assert_eq!(res_theta, sh_theta, "{label}: final θ stack");
    }
}

#[test]
fn shard_count_is_invariant_one_equals_k_equals_unsharded() {
    let cfg = ScenarioBuilder::gossip(AlgoKind::FdDsgt).n(9).build();
    let asm = assemble(&cfg).unwrap();
    let compute = make_compute(&cfg).unwrap();
    let (res_log, res_theta) = decfl::engine::train_decentralized(
        &cfg,
        compute.as_ref(),
        &asm.ds,
        &asm.graph,
        &asm.w,
    )
    .unwrap();

    // one whole-fleet shard, a 4/4/1 split, pairs, and single-node shards
    // with a 2-frame hot set (maximal spill churn) — all identical
    for (k, hot) in [(9, 1), (4, 2), (2, 1), (1, 2)] {
        let mut c = cfg.clone();
        c.shard_nodes = k;
        c.hot_shards = hot;
        let (log, theta) = shard::train(&c, &asm.ds, &asm.graph, &asm.w).unwrap();
        assert_logs_bitwise(&res_log, &log, &format!("shard_nodes={k} hot={hot}"));
        assert_eq!(res_theta, theta, "shard_nodes={k} hot={hot}: final θ stack");
    }
}

#[test]
fn sharded_equals_resident_bitwise_across_message_pipeline_matrix() {
    // PR-10 tentpole pin: every message-shaping axis — compressor × EF ×
    // robust rule × attack plan × DP × compute plan — runs shard-native
    // through the one extracted pipeline, bitwise-equal to the resident
    // fused driver.  n = 9, shard_nodes = 4, hot_shards = 2: three shards
    // through two frames, so the compressed/adversarial quantities (X̂/Ŷ,
    // EF residuals, replay slots) spill and reload every single sweep.
    type Axis = (
        &'static str,                     // label
        AlgoKind,
        (&'static str, f64, bool),        // compressor (name, topk_frac, ef)
        &'static str,                     // robust rule ("" = mean)
        (&'static str, f64),              // attack (plan, frac); "" = none
        &'static str,                     // dp ("" = off)
        &'static str,                     // compute plan ("" = uniform)
    );
    let cases: [Axis; 10] = [
        ("q8", AlgoKind::FdDsgd, ("q8", 0.0, false), "", ("", 0.0), "", ""),
        ("q8+ef/dsgt", AlgoKind::FdDsgt, ("q8", 0.0, true), "", ("", 0.0), "", ""),
        ("q4+ef", AlgoKind::FdDsgd, ("q4", 0.0, true), "", ("", 0.0), "", ""),
        ("topk+ef/dsgt", AlgoKind::FdDsgt, ("top-k", 0.25, true), "", ("", 0.0), "", ""),
        ("median uncompressed", AlgoKind::FdDsgd, ("none", 0.0, false), "median", ("", 0.0), "", ""),
        (
            "q8+trim+signflip",
            AlgoKind::FdDsgd,
            ("q8", 0.0, false),
            "trimmed-mean",
            ("sign-flip", 0.25),
            "",
            "",
        ),
        (
            "replay uncompressed/dsgt",
            AlgoKind::FdDsgt,
            ("none", 0.0, false),
            "",
            ("stale-replay", 0.25),
            "",
            "",
        ),
        ("q8+ef+replay", AlgoKind::FdDsgd, ("q8", 0.0, true), "", ("stale-replay", 0.25), "", ""),
        ("q8+dp", AlgoKind::FdDsgd, ("q8", 0.0, false), "", ("", 0.0), "gaussian", ""),
        (
            "grand compose",
            AlgoKind::FdDsgt,
            ("q8", 0.0, true),
            "trimmed-mean",
            ("sign-flip", 0.25),
            "gaussian",
            "lognormal",
        ),
    ];
    for (label, algo, (comp, frac, ef), rule, (attack, afrac), dp, cplan) in cases {
        let mut b = ScenarioBuilder::gossip(algo).n(9).rounds(3, 18);
        if comp != "none" {
            b = b.compressor(comp, frac, ef);
        }
        if !rule.is_empty() {
            b = b.robust_rule(rule);
        }
        if !attack.is_empty() {
            b = b.attack(attack, afrac);
        }
        if !dp.is_empty() {
            b = b.tweak(|c| c.dp = "gaussian".into());
        }
        if !cplan.is_empty() {
            b = b.compute(cplan);
        }
        let resident_cfg = b.build();
        let asm = assemble(&resident_cfg).unwrap();
        let compute = make_compute(&resident_cfg).unwrap();
        let (res_log, res_theta) = decfl::engine::train_decentralized(
            &resident_cfg,
            compute.as_ref(),
            &asm.ds,
            &asm.graph,
            &asm.w,
        )
        .unwrap();

        let mut sharded_cfg = resident_cfg.clone();
        sharded_cfg.shard_nodes = 4;
        sharded_cfg.hot_shards = 2;
        let (sh_log, sh_theta) =
            shard::train(&sharded_cfg, &asm.ds, &asm.graph, &asm.w).unwrap();
        assert_logs_bitwise(&res_log, &sh_log, label);
        assert_eq!(res_theta, sh_theta, "{label}: final θ stack");

        // the run log surfaces real pool traffic on the sharded side only,
        // and the (ε, δ) accountant agrees across drivers
        let (shr, rr) = (sh_log.rows.last().unwrap(), res_log.rows.last().unwrap());
        assert!(shr.pool_loads > 0, "{label}: sharded run must report pool loads");
        assert!(shr.pool_spills > 0, "{label}: hot < shards must report evictions");
        assert_eq!(rr.pool_loads, 0, "{label}: resident runs have no pool traffic");
        assert_eq!(shr.dp_epsilon.to_bits(), rr.dp_epsilon.to_bits(), "{label}: dp ε");
    }
}

#[test]
fn coordinator_routes_sharded_runs_and_rejects_server_algos() {
    // run_on must hand a shard_nodes > 0 gossip config to the sharded
    // driver (same log as calling it directly) and refuse the server-state
    // baselines loudly instead of silently running them resident
    let mut cfg = ScenarioBuilder::gossip(AlgoKind::FdDsgd)
        .rounds(3, 18)
        .sharded(2, 2)
        .build();
    let asm = assemble(&cfg).unwrap();
    let routed = run_on(&cfg, &asm).unwrap();
    let direct = shard::train_log(&cfg, &asm.ds, &asm.graph, &asm.w).unwrap();
    assert_logs_bitwise(&routed, &direct, "run_on routing");

    cfg.algo = AlgoKind::FedAvg;
    let err = run_on(&cfg, &asm).unwrap_err().to_string();
    assert!(err.contains("co-resident server state"), "{err}");
}

#[test]
fn streaming_eval_over_random_shard_boundaries_matches_eval_reduce_bitwise() {
    // property test: the two-pass streaming eval is a pure left fold in
    // node order, so ANY contiguous partition of the fleet — including
    // ragged random ones — must reproduce the resident reduction exactly
    let ds = decfl::data::generate(&decfl::data::DataConfig {
        n_hospitals: 13,
        records_per_hospital: 30,
        records_jitter: 7,
        heterogeneity: 0.6,
        ..decfl::data::DataConfig::default()
    })
    .unwrap();
    let model = NativeModel::new(ds.d, 6);
    let p = model.p();
    let n = ds.shards.len();
    let mut rng = Pcg64::seed(424242);
    let theta: Vec<f32> = (0..n * p).map(|_| (rng.normal() * 0.3) as f32).collect();
    let want = model.eval_full(&theta, &ds.shards);

    let per: Vec<(f64, Vec<f32>, usize, usize)> = ds
        .shards
        .iter()
        .enumerate()
        .map(|(i, s)| model.eval_node(&theta[i * p..(i + 1) * p], s))
        .collect();

    for trial in 0..10 {
        let mut bounds = vec![0usize];
        while *bounds.last().unwrap() < n {
            let next = (bounds.last().unwrap() + rng.range(1, 5)).min(n);
            bounds.push(next);
        }
        let mut se = StreamingEval::new(p);
        for w in bounds.windows(2) {
            for i in w[0]..w[1] {
                let (loss, grad, c, t) = &per[i];
                se.push_node(*loss, grad, *c, *t, &theta[i * p..(i + 1) * p]);
            }
        }
        let mut cp = se.into_consensus_pass();
        for w in bounds.windows(2) {
            for i in w[0]..w[1] {
                cp.push_row(&theta[i * p..(i + 1) * p]);
            }
        }
        let got = cp.finish();
        assert_eq!(got.0.to_bits(), want.0.to_bits(), "trial {trial} {bounds:?}: loss");
        assert_eq!(got.1.to_bits(), want.1.to_bits(), "trial {trial}: accuracy");
        assert_eq!(got.2.to_bits(), want.2.to_bits(), "trial {trial}: stationarity");
        assert_eq!(got.3.to_bits(), want.3.to_bits(), "trial {trial}: consensus");
    }
}

#[test]
fn record_weighted_loss_pins_the_1_vs_999_skew_oracle() {
    // a 1-record node next to a 999-record node: the global loss must be
    // the pooled-record mean (node 0 carries weight 1/1000), not the naive
    // node mean that lets a single record swing the fleet metric
    let (d, h) = (6usize, 4usize);
    let model = NativeModel::new(d, h);
    let p = model.p();
    let mut rng = Pcg64::seed(7);
    let mk = |records: usize, scale: f64, rng: &mut Pcg64| -> Shard {
        Shard {
            n: records,
            d,
            x: (0..records * d).map(|_| (rng.normal() * scale) as f32).collect(),
            y: (0..records).map(|i| (i % 2) as f32).collect(),
        }
    };
    // outsized features on the singleton push its loss away from the bulk
    let shards = vec![mk(1, 5.0, &mut rng), mk(999, 1.0, &mut rng)];
    let theta: Vec<f32> = (0..2 * p).map(|_| (rng.normal() * 0.5) as f32).collect();

    let per: Vec<(f64, Vec<f32>, usize, usize)> = shards
        .iter()
        .enumerate()
        .map(|(i, s)| model.eval_node(&theta[i * p..(i + 1) * p], s))
        .collect();
    let (l1, l2) = (per[0].0, per[1].0);
    assert!((l1 - l2).abs() > 1e-3, "oracle needs distinct node losses: {l1} vs {l2}");

    let got = model.eval_full(&theta, &shards);
    let want = (l1 + l2 * 999.0) / 1000.0;
    assert!(
        (got.0 - want).abs() <= 1e-12 * (1.0 + want.abs()),
        "record weighting: {} vs oracle {want}",
        got.0
    );
    // ... and is ~500x less sensitive to the singleton than the node mean
    let naive = (l1 + l2) / 2.0;
    assert!((got.0 - l2).abs() < (got.0 - naive).abs());

    // the streaming fold with a shard boundary between the two nodes
    // reproduces it bitwise
    let mut se = StreamingEval::new(p);
    for (i, (loss, grad, c, t)) in per.iter().enumerate() {
        se.push_node(*loss, grad, *c, *t, &theta[i * p..(i + 1) * p]);
    }
    let mut cp = se.into_consensus_pass();
    for i in 0..2 {
        cp.push_row(&theta[i * p..(i + 1) * p]);
    }
    assert_eq!(cp.finish().0.to_bits(), got.0.to_bits(), "streaming skew fold");
}

#[test]
fn honest_subfleet_streaming_filter_matches_hand_filtered_eval_bitwise() {
    // the Byzantine layer evaluates honest nodes only (DESIGN.md §14); the
    // streaming fold must support that filter without a resident stack —
    // skipping attacker rows in BOTH passes equals a hand-packed
    // eval_full over the honest sub-stack, bit for bit
    let cfg = ScenarioBuilder::gossip(AlgoKind::Dsgd)
        .n(8)
        .attack("sign-flip", 0.25)
        .build();
    let asm = assemble(&cfg).unwrap();
    let sched = AttackSchedule::from_config(&cfg).unwrap();
    let model = NativeModel::new(cfg.d, cfg.hidden);
    let p = model.p();
    let mut rng = Pcg64::seed(99);
    let theta: Vec<f32> = (0..cfg.n * p).map(|_| (rng.normal() * 0.3) as f32).collect();

    let mut th = Vec::new();
    let mut sh = Vec::new();
    for i in 0..cfg.n {
        if !sched.is_attacker(i) {
            th.extend_from_slice(&theta[i * p..(i + 1) * p]);
            sh.push(asm.ds.shards[i].clone());
        }
    }
    assert!(!sh.is_empty() && sh.len() < cfg.n, "attack must split the fleet");
    let want = model.eval_full(&th, &sh);

    let mut se = StreamingEval::new(p);
    for (i, s) in asm.ds.shards.iter().enumerate() {
        if sched.is_attacker(i) {
            continue;
        }
        let (loss, grad, c, t) = model.eval_node(&theta[i * p..(i + 1) * p], s);
        se.push_node(loss, &grad, c, t, &theta[i * p..(i + 1) * p]);
    }
    let mut cp = se.into_consensus_pass();
    for i in 0..cfg.n {
        if sched.is_attacker(i) {
            continue;
        }
        cp.push_row(&theta[i * p..(i + 1) * p]);
    }
    let got = cp.finish();
    assert_eq!(got.0.to_bits(), want.0.to_bits(), "honest loss");
    assert_eq!(got.1.to_bits(), want.1.to_bits(), "honest accuracy");
    assert_eq!(got.2.to_bits(), want.2.to_bits(), "honest stationarity");
    assert_eq!(got.3.to_bits(), want.3.to_bits(), "honest consensus");
}
