//! TOML-subset parser: `[section]`, `key = value`, `#` comments.
//!
//! Values: double-quoted strings, booleans, integers, floats.  Keys are
//! exposed flattened as `section.key`.  This covers every config file in the
//! repo; anything fancier (arrays, tables-of-tables, dates) is rejected
//! loudly rather than misparsed.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// One parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// Double-quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
}

/// A parsed document: flattened `section.key` → value.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse a TOML-subset document from text.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section header", lineno + 1))?
                    .trim();
                if name.is_empty() || !name.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '.' || c == '-') {
                    bail!("line {}: bad section name `{name}`", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() || !key.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '-') {
                bail!("line {}: bad key `{key}`", lineno + 1);
            }
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let parsed = parse_value(val.trim())
                .with_context(|| format!("line {}: bad value for `{full}`", lineno + 1))?;
            if doc.values.insert(full.clone(), parsed).is_some() {
                bail!("line {}: duplicate key `{full}`", lineno + 1);
            }
        }
        Ok(doc)
    }

    /// Parse a TOML-subset file from disk.
    pub fn parse_file(path: &std::path::Path) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Raw value at a flattened `section.key`, if present.
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    /// All flattened keys in the document.
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }

    /// String value at `key` (None if absent or another type).
    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.values.get(key) {
            Some(TomlValue::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// Non-negative integer at `key`; errors on a type mismatch.
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.values.get(key) {
            None => Ok(None),
            Some(TomlValue::Int(i)) if *i >= 0 => Ok(Some(*i as usize)),
            Some(v) => bail!("`{key}` must be a non-negative integer, got {v:?}"),
        }
    }

    /// Float (or integer) at `key`; errors on a type mismatch.
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.values.get(key) {
            None => Ok(None),
            Some(TomlValue::Float(x)) => Ok(Some(*x)),
            Some(TomlValue::Int(i)) => Ok(Some(*i as f64)),
            Some(v) => bail!("`{key}` must be a number, got {v:?}"),
        }
    }

    /// Boolean at `key`; errors on a type mismatch.
    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        match self.values.get(key) {
            None => Ok(None),
            Some(TomlValue::Bool(b)) => Ok(Some(*b)),
            Some(v) => bail!("`{key}` must be a boolean, got {v:?}"),
        }
    }
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').context("unterminated string")?;
        if inner.contains('"') {
            bail!("embedded quote (escapes unsupported in this subset)");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    // integer first (no dot/exponent), then float
    if !s.contains(['.', 'e', 'E']) {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse `{s}` (strings need double quotes)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
# experiment
top = "level"
[model]
n = 20
alpha = 0.02          # paper lr
[algo]
name = "fd-dsgt"
fused = true
big = 1_000_000
neg = -4
sci = 1e-3
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("top"), Some("level"));
        assert_eq!(doc.get_usize("model.n").unwrap(), Some(20));
        assert_eq!(doc.get_f64("model.alpha").unwrap(), Some(0.02));
        assert_eq!(doc.get_str("algo.name"), Some("fd-dsgt"));
        assert_eq!(doc.get_bool("algo.fused").unwrap(), Some(true));
        assert_eq!(doc.get_usize("algo.big").unwrap(), Some(1_000_000));
        assert_eq!(doc.get("algo.neg"), Some(&TomlValue::Int(-4)));
        assert_eq!(doc.get_f64("algo.sci").unwrap(), Some(1e-3));
    }

    #[test]
    fn missing_keys_are_none() {
        let doc = TomlDoc::parse("[a]\nx = 1\n").unwrap();
        assert_eq!(doc.get_usize("a.y").unwrap(), None);
        assert_eq!(doc.get_str("b.z"), None);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse("k = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_str("k"), Some("a#b"));
    }

    #[test]
    fn type_mismatch_errors() {
        let doc = TomlDoc::parse("[a]\nx = \"str\"\nneg = -2\n").unwrap();
        assert!(doc.get_usize("a.x").is_err());
        assert!(doc.get_usize("a.neg").is_err());
        assert!(doc.get_bool("a.x").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unclosed\nx=1").is_err());
        assert!(TomlDoc::parse("just a line").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = unquoted").is_err());
        assert!(TomlDoc::parse("k = 1\nk = 2").is_err());
        assert!(TomlDoc::parse("[s]\nk=1\n[s2]\nk = \"x\ny\"").is_err());
    }

    #[test]
    fn duplicate_across_sections_ok() {
        let doc = TomlDoc::parse("[a]\nk = 1\n[b]\nk = 2\n").unwrap();
        assert_eq!(doc.get_usize("a.k").unwrap(), Some(1));
        assert_eq!(doc.get_usize("b.k").unwrap(), Some(2));
    }
}
