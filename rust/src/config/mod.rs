//! Experiment configuration: a TOML-subset file format + typed config.
//!
//! The offline build has no serde/toml crates, so `toml.rs` implements the
//! subset the configs need: `[section]` headers, `key = value` with string /
//! integer / float / boolean values, `#` comments.  CLI flags override file
//! values; defaults below reproduce the paper's §3 setup exactly
//! (N=20, m=20, Q=100, α_r = 0.02/√r, d=42).

pub mod toml;

pub use toml::TomlDoc;

use anyhow::{bail, Result};

/// Which optimizer drives training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoKind {
    /// Classic decentralized SGD (eq. 2 every iteration; Q forced to 1).
    Dsgd,
    /// Classic gradient tracking (eq. 3 every iteration; Q forced to 1).
    Dsgt,
    /// Federated DSGD: Q local steps (eq. 4) between eq. 2 rounds.
    FdDsgd,
    /// Federated DSGT: Q local steps between eq. 3 rounds.
    FdDsgt,
    /// Star-network FedAvg baseline (server mean every Q steps).
    FedAvg,
    /// Fictitious fusion center: plain SGD on pooled data.
    Centralized,
}

impl AlgoKind {
    /// Parse a CLI/TOML algorithm name.
    pub fn parse(s: &str) -> Result<AlgoKind> {
        Ok(match s {
            "dsgd" => AlgoKind::Dsgd,
            "dsgt" => AlgoKind::Dsgt,
            "fd-dsgd" | "fddsgd" => AlgoKind::FdDsgd,
            "fd-dsgt" | "fddsgt" => AlgoKind::FdDsgt,
            "fedavg" => AlgoKind::FedAvg,
            "centralized" | "sgd" => AlgoKind::Centralized,
            other => bail!("unknown algo `{other}` (dsgd|dsgt|fd-dsgd|fd-dsgt|fedavg|centralized)"),
        })
    }

    /// Canonical display/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::Dsgd => "dsgd",
            AlgoKind::Dsgt => "dsgt",
            AlgoKind::FdDsgd => "fd-dsgd",
            AlgoKind::FdDsgt => "fd-dsgt",
            AlgoKind::FedAvg => "fedavg",
            AlgoKind::Centralized => "centralized",
        }
    }

    /// Does this algorithm use the gradient tracker (2x gossip bytes)?
    pub fn uses_tracker(&self) -> bool {
        matches!(self, AlgoKind::Dsgt | AlgoKind::FdDsgt)
    }

    /// Effective local period: classic variants communicate every step.
    pub fn effective_q(&self, q: usize) -> usize {
        match self {
            AlgoKind::Dsgd | AlgoKind::Dsgt => 1,
            _ => q.max(1),
        }
    }
}

/// Compute backend selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// AOT artifacts through PJRT — the production path.
    Pjrt,
    /// Pure-rust twin (`algo::native`) — oracle + shape-free sweeps.
    Native,
}

impl Backend {
    /// Parse a CLI/TOML backend name.
    pub fn parse(s: &str) -> Result<Backend> {
        Ok(match s {
            "pjrt" => Backend::Pjrt,
            "native" => Backend::Native,
            other => bail!("unknown backend `{other}` (pjrt|native)"),
        })
    }
}

/// Execution mode for decentralized algorithms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// One OS thread per hospital, gossip through the netsim (fidelity).
    Actors,
    /// Whole-network fused rounds, one PJRT call per round (throughput).
    Fused,
}

impl Mode {
    /// Parse a CLI/TOML execution-mode name.
    pub fn parse(s: &str) -> Result<Mode> {
        Ok(match s {
            "actors" => Mode::Actors,
            "fused" => Mode::Fused,
            other => bail!("unknown mode `{other}` (actors|fused)"),
        })
    }
}

/// Everything an experiment run needs.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    // -- model / artifact shapes (must match `make artifacts`) --
    /// Hospital count N (stack rows).
    pub n: usize,
    /// Input feature dimension (the EHR schema's 42).
    pub d: usize,
    /// Hidden-layer width of the shallow MLP.
    pub hidden: usize,
    /// Minibatch size m per node per step.
    pub m: usize,
    /// Local period Q (eq.-4 steps between communication rounds).
    pub q: usize,
    /// Records per shard the AOT eval artifact is specialized to.
    pub shard: usize,
    /// Directory holding the AOT artifact set (`make artifacts`).
    pub artifacts_dir: String,

    // -- algorithm --
    /// Which optimizer drives training.
    pub algo: AlgoKind,
    /// α_r = alpha0 / sqrt(r) (paper: 0.02).
    pub alpha0: f64,
    /// Total local iterations T (comm rounds = T / Q for FD variants).
    pub total_steps: usize,
    /// Evaluate metrics every this many *communication* rounds.
    pub eval_every: usize,
    /// Execution driver: fused whole-network rounds or per-node actors.
    pub mode: Mode,

    // -- round driver (see engine::asynchrony) --
    /// `sync` (the pinned oracle: global round barrier) or `async`
    /// (event-driven: each node gossips on its own simulated clock,
    /// applying possibly-stale neighbor states — AD-PSGD-style).
    pub driver: String,
    /// Async staleness cap in simulated seconds: a cached neighbor state
    /// older than this at apply time is dropped (its mixing weight folds
    /// into the receiver's self-weight).  0 = uncapped, the AD-PSGD default.
    pub staleness_s: f64,
    /// Async simulated-time budget in seconds: when > 0, nodes keep cycling
    /// until the *next* cycle would finish past this virtual-clock horizon
    /// (instead of stopping after `total_steps / q` cycles).  This is the
    /// matched-wall-clock frontier comparison: give the barrier-free driver
    /// the same simulated time the barriered run spent, not the same cycle
    /// count.  0 = cycle-count budget (the default).
    pub sim_budget_s: f64,
    /// Assumption-1 validation effort at assembly: full|approx|skip
    /// (`mixing::ValidateLevel`).  Exact symmetry / row-sum / non-negativity
    /// checks run at every level; only the |λ₂| estimate is budgeted or
    /// skipped — the BENCH_6 large-n construction cost.
    pub net_validate: String,

    // -- topology / mixing --
    /// Hospital-graph family (`graph::Topology::parse`).
    pub topology: String,
    /// Mixing-matrix scheme (`mixing::Scheme::parse`).
    pub mixing: String,

    // -- network schedule (time-varying topology; see graph::schedule) --
    /// Per-round network plan: static|rewire|edge-drop|churn.
    pub net_plan: String,
    /// Rewire cadence in communication rounds (plan = rewire).
    pub rewire_every: usize,
    /// Per-edge drop probability per round (plan = edge-drop).
    pub edge_drop: f64,
    /// Per-node offline probability per round (plan = churn).
    pub churn: f64,

    // -- heterogeneous compute (per-node local work; see engine::stragglers) --
    /// Per-round local-work plan: uniform|fixed-tiers|lognormal|dropout.
    pub compute_plan: String,
    /// Comma-separated tier speeds in (0, 1] (plan = fixed-tiers); node `i`
    /// runs at `tiers[i % len]`.
    pub compute_tiers: String,
    /// Per-round preemption probability in [0, 1) (plan = dropout).
    pub slow_frac: f64,
    /// Lognormal σ of the per-round speed draw (plan = lognormal).
    pub compute_sigma: f64,

    // -- communication compression (see `compress`) --
    /// Gossip-payload compressor: none|identity|q8|q4|topk.
    pub compress: String,
    /// Kept fraction for `compress = "topk"`, in (0, 1].
    pub topk_frac: f64,
    /// Opt-in error-feedback residuals on the compressed message streams.
    /// Default off: the difference-form update already preserves the mean
    /// iterate exactly, and stacking EF on top of it destabilizes
    /// aggressive sparsifiers (DESIGN.md §10).
    pub error_feedback: bool,

    // -- adversary / robustness / privacy (see engine::adversary, DESIGN.md §14) --
    /// Attack plan Byzantine senders follow: none|sign-flip|scaled-noise|
    /// stale-replay.  Applied at the message-encode boundary, so it composes
    /// with compression, churn, stragglers, and the async driver.
    pub attack_plan: String,
    /// Fraction of nodes that are Byzantine, in (0, 1] when a plan is active
    /// (membership is static per run, sampled from the seed).
    pub attack_frac: f64,
    /// Noise magnitude multiplier for `attack.plan = "scaled-noise"`.
    pub attack_scale: f64,
    /// Replay age in rounds for `attack.plan = "stale-replay"` (>= 2).
    pub attack_age: usize,
    /// Gossip aggregation rule: mean|trimmed-mean|median|krum.  `mean` is
    /// the pinned mixing-weighted combine; the robust rules screen the CSR
    /// neighborhood and forfeit mean preservation (DESIGN.md §14).
    pub robust_rule: String,
    /// Trim / screening fraction for trimmed-mean and krum, in [0, 0.5).
    pub robust_trim: f64,
    /// Differential-privacy mode on outgoing messages: off|gaussian.
    pub dp: String,
    /// L2 clipping bound C on each outgoing message (dp = gaussian).
    pub dp_clip: f64,
    /// Gaussian noise multiplier σ — noise stddev is σ·C per coordinate.
    pub dp_sigma: f64,
    /// Target δ the (ε, δ)-accountant reports ε at.
    pub dp_delta: f64,

    // -- node-state residency --
    /// Nodes per state shard for the spill-backed slab pool
    /// (`engine::shard`).  0 = unsharded resident slabs — the pinned
    /// default; the resident code path is byte-for-byte untouched.
    pub shard_nodes: usize,
    /// Resident shard frames in the LRU hot-set (used only when
    /// `shard_nodes > 0`); peak slab residency is `hot_shards · shard_nodes`
    /// rows regardless of fleet size.
    pub hot_shards: usize,

    // -- data --
    /// Shard non-iidness in [0, 1] (Dirichlet mixing of site profiles).
    pub heterogeneity: f64,
    /// Mean records per hospital shard.
    pub records_per_hospital: usize,
    /// Global AD label prevalence of the synthetic cohort.
    pub ad_prevalence: f64,

    // -- network model --
    /// One-way link latency per message, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Frame-loss probability per link (actor mode only; frames retransmit).
    pub drop_prob: f64,
    /// Modeled per-local-step compute time (drives the simulated clock).
    pub compute_s_per_step: f64,

    /// Compute backend: PJRT artifacts (production) or native rust (sweeps).
    pub backend: Backend,

    /// Worker threads for the native backend's whole-network ops
    /// (`local_steps_all` / `dsgd_round` / `dsgt_round` / `eval_full`).
    /// 0 = auto (one per available core).  Results are bitwise-identical
    /// at every thread count — nodes are disjoint `[i*p..(i+1)*p]` slices.
    pub threads: usize,

    /// Root RNG seed every deterministic stream derives from.
    pub seed: u64,
    /// Optional JSON metrics dump path.
    pub out: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            n: 20,
            d: 42,
            hidden: 32,
            m: 20,
            q: 100,
            shard: 500,
            artifacts_dir: "artifacts".into(),
            algo: AlgoKind::FdDsgt,
            alpha0: 0.02,
            total_steps: 10_000,
            eval_every: 1,
            mode: Mode::Fused,
            driver: "sync".into(),
            staleness_s: 0.0,
            sim_budget_s: 0.0,
            net_validate: "full".into(),
            topology: "knn".into(),
            mixing: "metropolis".into(),
            net_plan: "static".into(),
            rewire_every: 5,
            edge_drop: 0.2,
            churn: 0.1,
            compute_plan: "uniform".into(),
            compute_tiers: "1.0,0.5".into(),
            slow_frac: 0.25,
            compute_sigma: 0.5,
            compress: "none".into(),
            topk_frac: 0.1,
            error_feedback: false,
            attack_plan: "none".into(),
            attack_frac: 0.0,
            attack_scale: 3.0,
            attack_age: 5,
            robust_rule: "mean".into(),
            robust_trim: 0.2,
            dp: "off".into(),
            dp_clip: 1.0,
            dp_sigma: 1.0,
            dp_delta: 1e-5,
            shard_nodes: 0,
            hot_shards: 4,
            heterogeneity: 0.6,
            records_per_hospital: 500,
            ad_prevalence: 0.21,
            latency_s: 0.010,
            bandwidth_bps: 12_500_000.0,
            drop_prob: 0.0,
            compute_s_per_step: 1e-3,
            backend: Backend::Pjrt,
            threads: 0,
            seed: 7,
            out: None,
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML-subset file, keeping defaults for missing keys.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let doc = TomlDoc::parse_file(path)?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply_doc(&doc)?;
        Ok(cfg)
    }

    /// Overlay values from a parsed document.
    pub fn apply_doc(&mut self, doc: &TomlDoc) -> Result<()> {
        if let Some(v) = doc.get_usize("model.n")? { self.n = v; }
        if let Some(v) = doc.get_usize("model.d")? { self.d = v; }
        if let Some(v) = doc.get_usize("model.hidden")? { self.hidden = v; }
        if let Some(v) = doc.get_usize("model.m")? { self.m = v; }
        if let Some(v) = doc.get_usize("model.q")? { self.q = v; }
        if let Some(v) = doc.get_usize("model.shard")? { self.shard = v; }
        if let Some(v) = doc.get_str("model.artifacts_dir") { self.artifacts_dir = v.to_string(); }
        if let Some(v) = doc.get_str("algo.name") { self.algo = AlgoKind::parse(v)?; }
        if let Some(v) = doc.get_f64("algo.alpha0")? { self.alpha0 = v; }
        if let Some(v) = doc.get_usize("algo.total_steps")? { self.total_steps = v; }
        if let Some(v) = doc.get_usize("algo.eval_every")? { self.eval_every = v; }
        if let Some(v) = doc.get_str("algo.mode") { self.mode = Mode::parse(v)?; }
        if let Some(v) = doc.get_str("run.driver") { self.driver = v.to_string(); }
        if let Some(v) = doc.get_f64("run.staleness_s")? { self.staleness_s = v; }
        if let Some(v) = doc.get_f64("run.sim_budget_s")? { self.sim_budget_s = v; }
        if let Some(v) = doc.get_str("net.validate") { self.net_validate = v.to_string(); }
        if let Some(v) = doc.get_str("graph.topology") { self.topology = v.to_string(); }
        if let Some(v) = doc.get_str("graph.mixing") { self.mixing = v.to_string(); }
        if let Some(v) = doc.get_str("net.plan") { self.net_plan = v.to_string(); }
        if let Some(v) = doc.get_usize("net.rewire_every")? { self.rewire_every = v; }
        if let Some(v) = doc.get_f64("net.edge_drop")? { self.edge_drop = v; }
        if let Some(v) = doc.get_f64("net.churn")? { self.churn = v; }
        if let Some(v) = doc.get_str("compute.plan") { self.compute_plan = v.to_string(); }
        if let Some(v) = doc.get_str("compute.tiers") { self.compute_tiers = v.to_string(); }
        if let Some(v) = doc.get_f64("compute.slow_frac")? { self.slow_frac = v; }
        if let Some(v) = doc.get_f64("compute.sigma")? { self.compute_sigma = v; }
        if let Some(v) = doc.get_str("comm.compress") { self.compress = v.to_string(); }
        if let Some(v) = doc.get_f64("comm.topk_frac")? { self.topk_frac = v; }
        if let Some(v) = doc.get_bool("comm.error_feedback")? { self.error_feedback = v; }
        if let Some(v) = doc.get_str("attack.plan") { self.attack_plan = v.to_string(); }
        if let Some(v) = doc.get_f64("attack.frac")? { self.attack_frac = v; }
        if let Some(v) = doc.get_f64("attack.scale")? { self.attack_scale = v; }
        if let Some(v) = doc.get_usize("attack.age")? { self.attack_age = v; }
        if let Some(v) = doc.get_str("robust.rule") { self.robust_rule = v.to_string(); }
        if let Some(v) = doc.get_f64("robust.trim")? { self.robust_trim = v; }
        if let Some(v) = doc.get_str("dp.mode") { self.dp = v.to_string(); }
        if let Some(v) = doc.get_f64("dp.clip")? { self.dp_clip = v; }
        if let Some(v) = doc.get_f64("dp.sigma")? { self.dp_sigma = v; }
        if let Some(v) = doc.get_f64("dp.delta")? { self.dp_delta = v; }
        if let Some(v) = doc.get_usize("state.shard_nodes")? { self.shard_nodes = v; }
        if let Some(v) = doc.get_usize("state.hot_shards")? { self.hot_shards = v; }
        if let Some(v) = doc.get_f64("data.heterogeneity")? { self.heterogeneity = v; }
        if let Some(v) = doc.get_usize("data.records_per_hospital")? { self.records_per_hospital = v; }
        if let Some(v) = doc.get_f64("data.ad_prevalence")? { self.ad_prevalence = v; }
        if let Some(v) = doc.get_f64("net.latency_s")? { self.latency_s = v; }
        if let Some(v) = doc.get_f64("net.bandwidth_bps")? { self.bandwidth_bps = v; }
        if let Some(v) = doc.get_f64("net.drop_prob")? { self.drop_prob = v; }
        if let Some(v) = doc.get_f64("net.compute_s_per_step")? { self.compute_s_per_step = v; }
        if let Some(v) = doc.get_str("algo.backend") { self.backend = Backend::parse(v)?; }
        if let Some(v) = doc.get_usize("run.threads")? { self.threads = v; }
        if let Some(v) = doc.get_usize("run.seed")? { self.seed = v as u64; }
        if let Some(v) = doc.get_str("run.out") { self.out = Some(v.to_string()); }
        Ok(())
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.n == 0 || self.m == 0 || self.total_steps == 0 {
            bail!("n, m, total_steps must be positive");
        }
        if self.alpha0 <= 0.0 {
            bail!("alpha0 must be positive");
        }
        if !(0.0..=1.0).contains(&self.heterogeneity) {
            bail!("heterogeneity in [0,1]");
        }
        if self.q == 0 {
            bail!("q must be >= 1");
        }
        match self.driver.as_str() {
            "sync" | "async" => {}
            other => bail!("unknown run.driver `{other}` (sync|async)"),
        }
        if !self.staleness_s.is_finite() || self.staleness_s < 0.0 {
            bail!("staleness_s must be a finite value >= 0 (0 = uncapped)");
        }
        if !self.sim_budget_s.is_finite() || self.sim_budget_s < 0.0 {
            bail!("sim_budget_s must be a finite value >= 0 (0 = cycle-count budget)");
        }
        if self.sim_budget_s > 0.0 && self.driver != "async" {
            bail!("sim_budget_s only applies to run.driver = async (the sync oracle is round-bounded)");
        }
        crate::graph::Topology::parse(&self.topology)?;
        crate::mixing::Scheme::parse(&self.mixing)?;
        crate::mixing::ValidateLevel::parse(&self.net_validate)?;
        crate::graph::schedule::plan_from_config(self)?;
        crate::engine::stragglers::plan_from_config(self)?;
        crate::compress::Spec::parse(&self.compress, self.topk_frac)?;
        crate::engine::adversary::plan_from_config(self)?;
        crate::engine::adversary::dp_from_config(self)?;
        crate::algo::RobustRule::parse(&self.robust_rule, self.robust_trim)?;
        if self.shard_nodes > 0 && self.hot_shards == 0 {
            bail!("state.hot_shards must be >= 1 when state.shard_nodes > 0");
        }
        Ok(())
    }

    /// The paper's learning-rate schedule α_r = α₀ / √r (r is 1-based).
    pub fn lr_at(&self, step: usize) -> f64 {
        self.alpha0 / ((step.max(1)) as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.n, 20);
        assert_eq!(c.d, 42);
        assert_eq!(c.m, 20);
        assert_eq!(c.q, 100);
        assert!((c.alpha0 - 0.02).abs() < 1e-12);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn lr_schedule() {
        let c = ExperimentConfig::default();
        assert!((c.lr_at(1) - 0.02).abs() < 1e-12);
        assert!((c.lr_at(4) - 0.01).abs() < 1e-12);
        assert!((c.lr_at(0) - 0.02).abs() < 1e-12); // clamped
    }

    #[test]
    fn algo_parse_and_q() {
        assert_eq!(AlgoKind::parse("fd-dsgt").unwrap(), AlgoKind::FdDsgt);
        assert_eq!(AlgoKind::Dsgd.effective_q(100), 1);
        assert_eq!(AlgoKind::FdDsgd.effective_q(100), 100);
        assert!(AlgoKind::Dsgt.uses_tracker());
        assert!(!AlgoKind::FdDsgd.uses_tracker());
        assert!(AlgoKind::parse("nope").is_err());
    }

    #[test]
    fn file_overlay() {
        let dir = std::env::temp_dir().join(format!("decfl_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.toml");
        std::fs::write(
            &path,
            "# fig2 config\n[model]\nq = 50\n[algo]\nname = \"dsgd\"\nalpha0 = 0.05\n[run]\nseed = 99\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(cfg.q, 50);
        assert_eq!(cfg.algo, AlgoKind::Dsgd);
        assert!((cfg.alpha0 - 0.05).abs() < 1e-12);
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.n, 20); // untouched default
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validate_rejects_bad() {
        let mut c = ExperimentConfig::default();
        c.q = 0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.topology = "bogus".into();
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.alpha0 = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.net_plan = "bogus".into();
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.net_plan = "edge-drop".into();
        c.edge_drop = 1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn comm_compress_overlay_and_validation() {
        let c = ExperimentConfig::default();
        assert_eq!(c.compress, "none");
        assert!((c.topk_frac - 0.1).abs() < 1e-12);
        assert!(!c.error_feedback, "EF is opt-in (DESIGN.md §10)");
        assert!(c.validate().is_ok());
        let dir = std::env::temp_dir().join(format!("decfl_comm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("comm.toml");
        std::fs::write(
            &path,
            "[comm]\ncompress = \"topk\"\ntopk_frac = 0.05\nerror_feedback = true\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(cfg.compress, "topk");
        assert!((cfg.topk_frac - 0.05).abs() < 1e-12);
        assert!(cfg.error_feedback);
        assert!(cfg.validate().is_ok());
        std::fs::remove_dir_all(&dir).ok();
        // bad compressor names and top-k fractions are rejected at validate
        let mut c = ExperimentConfig::default();
        c.compress = "gzip".into();
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.compress = "topk".into();
        c.topk_frac = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn compute_plan_overlay_and_validation() {
        let c = ExperimentConfig::default();
        assert_eq!(c.compute_plan, "uniform");
        assert!(c.validate().is_ok());
        let dir = std::env::temp_dir().join(format!("decfl_comp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("compute.toml");
        std::fs::write(
            &path,
            "[compute]\nplan = \"fixed-tiers\"\ntiers = \"1.0,0.25\"\nslow_frac = 0.4\nsigma = 0.8\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(cfg.compute_plan, "fixed-tiers");
        assert_eq!(cfg.compute_tiers, "1.0,0.25");
        assert!((cfg.slow_frac - 0.4).abs() < 1e-12);
        assert!((cfg.compute_sigma - 0.8).abs() < 1e-12);
        assert!(cfg.validate().is_ok());
        std::fs::remove_dir_all(&dir).ok();
        // bad plans / parameters are rejected at validate
        let mut c = ExperimentConfig::default();
        c.compute_plan = "bogus".into();
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.compute_plan = "dropout".into();
        c.slow_frac = 1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.compute_plan = "fixed-tiers".into();
        c.compute_tiers = "0.5,2.0".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn state_sharding_overlay_and_validation() {
        // default: unsharded resident slabs — the byte-for-byte pinned path
        let c = ExperimentConfig::default();
        assert_eq!(c.shard_nodes, 0);
        assert_eq!(c.hot_shards, 4);
        assert!(c.validate().is_ok());
        let dir = std::env::temp_dir().join(format!("decfl_state_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.toml");
        std::fs::write(&path, "[state]\nshard_nodes = 256\nhot_shards = 2\n").unwrap();
        let cfg = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(cfg.shard_nodes, 256);
        assert_eq!(cfg.hot_shards, 2);
        assert!(cfg.validate().is_ok());
        std::fs::remove_dir_all(&dir).ok();
        // a sharded pool with zero resident frames can never make progress
        let mut c = ExperimentConfig::default();
        c.shard_nodes = 64;
        c.hot_shards = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn driver_and_validate_overlay_and_defaults() {
        // defaults: the pinned sync oracle, uncapped staleness, full checks
        let c = ExperimentConfig::default();
        assert_eq!(c.driver, "sync");
        assert_eq!(c.staleness_s, 0.0);
        assert_eq!(c.net_validate, "full");
        assert!(c.validate().is_ok());
        let dir = std::env::temp_dir().join(format!("decfl_drv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("drv.toml");
        std::fs::write(
            &path,
            "[run]\ndriver = \"async\"\nstaleness_s = 0.5\n[net]\nvalidate = \"approx\"\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(cfg.driver, "async");
        assert!((cfg.staleness_s - 0.5).abs() < 1e-12);
        assert_eq!(cfg.net_validate, "approx");
        assert!(cfg.validate().is_ok());
        std::fs::remove_dir_all(&dir).ok();
        // bad values are rejected at validate
        let mut c = ExperimentConfig::default();
        c.driver = "turbo".into();
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.staleness_s = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.net_validate = "fast".into();
        assert!(c.validate().is_err());
        // a time budget is an async-driver knob; silently ignoring it on the
        // sync oracle would misreport the frontier
        let mut c = ExperimentConfig::default();
        c.sim_budget_s = 1.0;
        assert!(c.validate().is_err());
        c.driver = "async".into();
        assert!(c.validate().is_ok());
    }

    #[test]
    fn adversary_robust_dp_overlay_and_validation() {
        // honest defaults: no attack, mean combine, DP off (the pinned path)
        let c = ExperimentConfig::default();
        assert_eq!(c.attack_plan, "none");
        assert_eq!(c.attack_frac, 0.0);
        assert_eq!(c.robust_rule, "mean");
        assert_eq!(c.dp, "off");
        assert!(c.validate().is_ok());
        let dir = std::env::temp_dir().join(format!("decfl_adv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("adv.toml");
        std::fs::write(
            &path,
            "[attack]\nplan = \"sign-flip\"\nfrac = 0.2\n\
             [robust]\nrule = \"trimmed-mean\"\ntrim = 0.25\n\
             [dp]\nmode = \"gaussian\"\nclip = 0.5\nsigma = 2.0\ndelta = 1e-6\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(cfg.attack_plan, "sign-flip");
        assert!((cfg.attack_frac - 0.2).abs() < 1e-12);
        assert_eq!(cfg.robust_rule, "trimmed-mean");
        assert!((cfg.robust_trim - 0.25).abs() < 1e-12);
        assert_eq!(cfg.dp, "gaussian");
        assert!((cfg.dp_clip - 0.5).abs() < 1e-12);
        assert!((cfg.dp_sigma - 2.0).abs() < 1e-12);
        assert!((cfg.dp_delta - 1e-6).abs() < 1e-18);
        assert!(cfg.validate().is_ok());
        std::fs::remove_dir_all(&dir).ok();
        // bad values are rejected at validate
        let c = ExperimentConfig { attack_plan: "emp".into(), ..Default::default() };
        assert!(c.validate().is_err());
        let c = ExperimentConfig {
            attack_plan: "sign-flip".into(),
            attack_frac: 0.0,
            ..Default::default()
        };
        assert!(c.validate().is_err(), "an active plan needs attackers");
        let c = ExperimentConfig { robust_rule: "geometric".into(), ..Default::default() };
        assert!(c.validate().is_err());
        let c = ExperimentConfig {
            robust_rule: "trimmed-mean".into(),
            robust_trim: 0.5,
            ..Default::default()
        };
        assert!(c.validate().is_err(), "trim must stay below 0.5");
        let c = ExperimentConfig { dp: "laplace".into(), ..Default::default() };
        assert!(c.validate().is_err());
        let c = ExperimentConfig { dp: "gaussian".into(), dp_sigma: 0.0, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn net_plan_overlay_and_defaults() {
        let c = ExperimentConfig::default();
        assert_eq!(c.net_plan, "static");
        assert!(c.validate().is_ok());
        let dir = std::env::temp_dir().join(format!("decfl_net_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("net.toml");
        std::fs::write(&path, "[net]\nplan = \"churn\"\nchurn = 0.25\nrewire_every = 3\n").unwrap();
        let cfg = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(cfg.net_plan, "churn");
        assert!((cfg.churn - 0.25).abs() < 1e-12);
        assert_eq!(cfg.rewire_every, 3);
        assert!(cfg.validate().is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}
