//! Simulated gossip network between hospital nodes.
//!
//! The paper's x-axis is *communication rounds*: one synchronous exchange of
//! the common-interest parameters with all graph neighbors.  This module
//! gives the node actors a real message-passing substrate (std mpsc channels,
//! one mailbox per node) with the accounting a deployment would care about:
//!
//! - **bytes on the wire** per message / per round (DSGT sends θ *and* the
//!   tracker ϑ, i.e. 2x DSGD's bytes — the comm-cost benches report this),
//! - **simulated wall time** from a per-edge latency + bandwidth model with
//!   causal clocks (receiver time = max(local, arrival)),
//! - **loss injection** modeled as deterministic retransmission (a dropped
//!   frame costs extra bytes + latency but the round still completes —
//!   synchronous gossip cannot tolerate silent loss).
//!
//! Messages carry a typed [`Payload`]: either a dense f32 vector or a
//! compressed message from the `compress` subsystem (top-k indices+values,
//! packed u8/u4 quantization codes), and every message is charged at its
//! *encoded* wire size — so turning compression on changes the byte
//! accounting exactly as it would change a deployment's NIC counters.
//! Every payload byte is accounted even though in-process delivery shares an
//! `Arc` — the simulator charges what a real NIC would move.

pub mod analytic;

use crate::compress::Encoded;
use crate::graph::Graph;
use crate::rng::Pcg64;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Per-edge link model.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// One-way propagation latency per message, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Probability a frame is lost and must be retransmitted.
    pub drop_prob: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // hospital-WAN-ish defaults: 20 ms RTT/2, 100 Mbit/s, lossless
        LinkModel { latency_s: 0.010, bandwidth_bps: 12_500_000.0, drop_prob: 0.0 }
    }
}

/// What a gossip message carries (DSGT rounds exchange two payload kinds).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum PayloadKind {
    /// Model parameters θ.
    Params,
    /// Gradient tracker ϑ (DSGT only).
    Tracker,
}

impl PayloadKind {
    /// Stable small integer tag (mailbox routing keys, compression keys).
    pub fn tag(self) -> u8 {
        match self {
            PayloadKind::Params => 0,
            PayloadKind::Tracker => 1,
        }
    }
}

/// The body of one gossip message — what actually crosses the simulated
/// wire, charged at its encoded size.
pub enum Payload {
    /// Uncompressed f32 vector: `4·len` bytes.
    Dense(Vec<f32>),
    /// Compressed message (`compress::Encoded`): charged at the encoding's
    /// exact wire size (top-k indices+values, packed u8/u4 codes, ...).
    Compressed(Encoded),
}

impl Payload {
    /// Exact bytes this payload occupies on the wire.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Dense(v) => (v.len() * std::mem::size_of::<f32>()) as u64,
            Payload::Compressed(e) => e.wire_bytes(),
        }
    }

    /// Decoded f32 length of this payload.
    pub fn decoded_len(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::Compressed(e) => e.decoded_len(),
        }
    }

    /// Does every value this payload decodes to come out finite?  The
    /// ingest quarantine (DESIGN.md §14) classifies a neighbor message as
    /// poisoned with this — exact, and one decode cheaper than scanning the
    /// reconstructed vector (see [`Encoded::is_finite`]).
    pub fn is_finite(&self) -> bool {
        match self {
            Payload::Dense(v) => v.iter().all(|x| x.is_finite()),
            Payload::Compressed(e) => e.is_finite(),
        }
    }

    /// Reconstruct the carried vector into `out` (copy or decode) — the
    /// receiver side of the deterministic decode every party shares.
    /// Malformed wire bytes error loudly (DESIGN.md §14); on error `out`
    /// is poisoned and the caller must quarantine the message.
    pub fn decode_into(&self, out: &mut [f32]) -> Result<()> {
        match self {
            Payload::Dense(v) => {
                anyhow::ensure!(
                    v.len() == out.len(),
                    "dense payload carries {} elements for a {}-element decode",
                    v.len(),
                    out.len()
                );
                out.copy_from_slice(v);
                Ok(())
            }
            Payload::Compressed(e) => crate::compress::decode_into(e, out),
        }
    }

    /// Borrow the dense vector (None for compressed payloads).
    pub fn as_dense(&self) -> Option<&[f32]> {
        match self {
            Payload::Dense(v) => Some(v),
            Payload::Compressed(_) => None,
        }
    }
}

/// One in-flight message.
struct Msg {
    from: usize,
    round: u64,
    kind: PayloadKind,
    /// Shared payload; bytes are charged per edge regardless of sharing.
    payload: Arc<Payload>,
    /// Sender's causal clock at arrival time (send clock + link delay).
    arrival_time: f64,
}

/// Network-wide counters (shared across node threads).
#[derive(Default)]
pub struct NetStats {
    /// Messages sent (per directed edge, per payload kind).
    pub messages: AtomicU64,
    /// Bytes moved, at encoded wire size, including retransmissions.
    pub bytes: AtomicU64,
    /// Frames that were lost and resent (lossy links only).
    pub retransmissions: AtomicU64,
    /// Neighbor payloads quarantined at ingest — malformed wire bytes or
    /// non-finite values folded into the receiver's self-weight instead of
    /// entering θ/ϑ (DESIGN.md §14).
    pub quarantined: AtomicU64,
    /// Completed gossip rounds (bumped by the driver).
    pub rounds: AtomicU64,
    /// max causal clock over nodes, in microseconds (atomic max).
    sim_time_us: AtomicU64,
}

impl NetStats {
    /// Plain-data copy of the counters at this instant.
    pub fn snapshot(&self) -> NetSnapshot {
        NetSnapshot {
            messages: self.messages.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            retransmissions: self.retransmissions.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            rounds: self.rounds.load(Ordering::Relaxed),
            sim_time_s: self.sim_time_us.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }

    fn bump_time(&self, t_s: f64) {
        let us = (t_s * 1e6) as u64;
        self.sim_time_us.fetch_max(us, Ordering::Relaxed);
    }
}

/// Plain-data view of [`NetStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NetSnapshot {
    /// Messages sent so far.
    pub messages: u64,
    /// Bytes moved so far (encoded wire size, retransmissions included).
    pub bytes: u64,
    /// Frames lost and resent so far.
    pub retransmissions: u64,
    /// Neighbor payloads quarantined at ingest (malformed or non-finite).
    pub quarantined: u64,
    /// Completed gossip rounds.
    pub rounds: u64,
    /// Simulated wall time (max causal clock over nodes), seconds.
    pub sim_time_s: f64,
}

/// One node's handle onto the network.
pub struct Endpoint {
    /// This node's id (graph vertex).
    pub id: usize,
    /// Wired neighbors (the union graph's adjacency).
    pub neighbors: Vec<usize>,
    link: LinkModel,
    senders: BTreeMap<usize, Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// Buffered out-of-order messages keyed by (round, kind, from).
    held: BTreeMap<(u64, u8, usize), Msg>,
    stats: Arc<NetStats>,
    rng: Pcg64,
    /// Causal clock, seconds.
    pub clock_s: f64,
}

impl Endpoint {
    /// Send `payload` to every wired neighbor, tagged with the gossip round.
    /// Returns the per-edge transmission delay applied.
    pub fn broadcast(&mut self, round: u64, kind: PayloadKind, payload: &Arc<Payload>) -> Result<f64> {
        let neighbor_ids: Vec<usize> = self.neighbors.clone();
        self.send_to(&neighbor_ids, round, kind, payload)
    }

    /// Send `payload` to a subset of the wired neighbors — the per-round
    /// neighbor mask of a time-varying network (`graph::schedule`).  Each
    /// message is charged at the payload's *encoded* wire size.
    /// Returns the per-edge transmission delay applied.
    pub fn send_to(
        &mut self,
        targets: &[usize],
        round: u64,
        kind: PayloadKind,
        payload: &Arc<Payload>,
    ) -> Result<f64> {
        let bytes = payload.wire_bytes();
        let mut max_delay = 0.0f64;
        for &nb in targets {
            // retransmission loop: deterministic count from this node's rng
            let mut tries = 1u64;
            while self.link.drop_prob > 0.0 && self.rng.bernoulli(self.link.drop_prob) {
                tries += 1;
                if tries > 64 {
                    bail!("link to {nb} failed 64 retransmissions");
                }
            }
            let tx = self.link.latency_s + bytes as f64 / self.link.bandwidth_bps;
            let delay = tx * tries as f64;
            max_delay = max_delay.max(delay);
            self.stats.messages.fetch_add(1, Ordering::Relaxed);
            self.stats.bytes.fetch_add(bytes * tries, Ordering::Relaxed);
            self.stats.retransmissions.fetch_add(tries - 1, Ordering::Relaxed);
            let msg = Msg {
                from: self.id,
                round,
                kind,
                payload: Arc::clone(payload),
                arrival_time: self.clock_s + delay,
            };
            self.senders
                .get(&nb)
                .context("missing sender")?
                .send(msg)
                .map_err(|_| anyhow::anyhow!("neighbor {nb} hung up"))?;
        }
        Ok(max_delay)
    }

    /// Block until one `(round, kind)` message from *every* wired neighbor
    /// has arrived; returns them ordered by sender id.  Out-of-order
    /// messages (future rounds, other kinds) are buffered, not lost.
    pub fn gather(&mut self, round: u64, kind: PayloadKind) -> Result<Vec<(usize, Arc<Payload>)>> {
        let want: Vec<usize> = self.neighbors.clone();
        self.gather_from(&want, round, kind)
    }

    /// Block until one `(round, kind)` message from each of `sources` has
    /// arrived — the per-round neighbor mask of a time-varying network.
    /// Messages from other senders or rounds are buffered, not lost.
    pub fn gather_from(
        &mut self,
        sources: &[usize],
        round: u64,
        kind: PayloadKind,
    ) -> Result<Vec<(usize, Arc<Payload>)>> {
        let tag = kind.tag();
        let mut have: BTreeMap<usize, Msg> = BTreeMap::new();

        // drain previously-buffered matches
        let keys: Vec<_> = self
            .held
            .keys()
            .filter(|(r, k, from)| *r == round && *k == tag && sources.contains(from))
            .copied()
            .collect();
        for key in keys {
            let msg = self.held.remove(&key).unwrap();
            have.insert(msg.from, msg);
        }

        while have.len() < sources.len() {
            let msg = self
                .inbox
                .recv()
                .map_err(|_| anyhow::anyhow!("network shut down while node {} waits", self.id))?;
            if msg.round == round && msg.kind.tag() == tag && sources.contains(&msg.from) {
                have.insert(msg.from, msg);
            } else {
                self.held.insert((msg.round, msg.kind.tag(), msg.from), msg);
            }
        }

        // causal clock: the round completes when the last message lands
        for msg in have.values() {
            self.clock_s = self.clock_s.max(msg.arrival_time);
        }
        self.stats.bump_time(self.clock_s);

        Ok(have.into_iter().map(|(from, m)| (from, m.payload)).collect())
    }

    /// Record `n` quarantined neighbor payloads (malformed or non-finite
    /// ingest folded into the self-weight, never into θ/ϑ).
    pub fn report_quarantine(&self, n: u64) {
        self.stats.quarantined.fetch_add(n, Ordering::Relaxed);
    }

    /// Advance the local clock by `secs` of compute (local SGD steps).
    pub fn spend_compute(&mut self, secs: f64) {
        self.clock_s += secs;
        self.stats.bump_time(self.clock_s);
    }
}

/// Build one endpoint per node over `g` plus the shared stats handle.
pub fn build(g: &Graph, link: LinkModel, seed: u64) -> (Vec<Endpoint>, Arc<NetStats>) {
    let n = g.n();
    let stats = Arc::new(NetStats::default());
    let mut txs: Vec<Sender<Msg>> = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(Some(rx));
    }
    let endpoints = (0..n)
        .map(|i| {
            let neighbors: Vec<usize> = g.neighbors(i).to_vec();
            let senders: BTreeMap<usize, Sender<Msg>> =
                neighbors.iter().map(|&j| (j, txs[j].clone())).collect();
            Endpoint {
                id: i,
                neighbors,
                link,
                senders,
                inbox: rxs[i].take().unwrap(),
                held: BTreeMap::new(),
                stats: Arc::clone(&stats),
                rng: Pcg64::new(seed, 0x4E7 + i as u64),
                clock_s: 0.0,
            }
        })
        .collect();
    (endpoints, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;

    fn ring(n: usize) -> Graph {
        Graph::build(&Topology::Ring, n, &mut Pcg64::seed(0)).unwrap()
    }

    /// Run one synchronous gossip round over node threads; every node
    /// broadcasts its id-vector and averages what it gathers.
    fn one_round(n: usize, link: LinkModel) -> (Vec<f32>, NetSnapshot) {
        let g = ring(n);
        let (endpoints, stats) = build(&g, link, 42);
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    let payload = Arc::new(Payload::Dense(vec![ep.id as f32; 4]));
                    ep.broadcast(0, PayloadKind::Params, &payload).unwrap();
                    let got = ep.gather(0, PayloadKind::Params).unwrap();
                    let mut acc = ep.id as f32;
                    for (_, p) in &got {
                        acc += p.as_dense().unwrap()[0];
                    }
                    acc / (got.len() + 1) as f32
                })
            })
            .collect();
        let results: Vec<f32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        stats.rounds.fetch_add(1, Ordering::Relaxed);
        let snap = stats.snapshot();
        (results, snap)
    }

    #[test]
    fn ring_gossip_averages_neighbors() {
        let (results, _) = one_round(5, LinkModel::default());
        // node i averages {i-1, i, i+1} mod 5
        for (i, &r) in results.iter().enumerate() {
            let l = ((i + 4) % 5) as f32;
            let rgt = ((i + 1) % 5) as f32;
            let expect = (l + i as f32 + rgt) / 3.0;
            assert!((r - expect).abs() < 1e-6, "node {i}: {r} vs {expect}");
        }
    }

    #[test]
    fn byte_accounting_exact() {
        let n = 6;
        let (_, snap) = one_round(n, LinkModel::default());
        // each node sends 2 messages of 4 f32 = 16 bytes
        assert_eq!(snap.messages, (n * 2) as u64);
        assert_eq!(snap.bytes, (n * 2 * 16) as u64);
        assert_eq!(snap.retransmissions, 0);
        assert_eq!(snap.rounds, 1);
    }

    #[test]
    fn sim_time_reflects_link_model() {
        let slow = LinkModel { latency_s: 0.5, bandwidth_bps: 1e9, drop_prob: 0.0 };
        let (_, snap) = one_round(4, slow);
        assert!(snap.sim_time_s >= 0.5, "{}", snap.sim_time_s);
        assert!(snap.sim_time_s < 1.0, "{}", snap.sim_time_s);
    }

    #[test]
    fn drops_cause_retransmission_bytes() {
        let lossy = LinkModel { drop_prob: 0.3, ..LinkModel::default() };
        let (results, snap) = one_round(8, lossy);
        assert!(snap.retransmissions > 0, "expected retransmissions");
        assert!(snap.bytes > 8 * 2 * 16);
        // результат still correct: gossip completes despite loss
        assert_eq!(results.len(), 8);
    }

    #[test]
    fn out_of_order_rounds_are_buffered() {
        // node 0 sends rounds 0 and 1 before node 1 gathers round 0
        let g = ring(3);
        let (mut eps, _) = build(&g, LinkModel::default(), 0);
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let p0 = Arc::new(Payload::Dense(vec![1.0f32]));
        let p1 = Arc::new(Payload::Dense(vec![2.0f32]));
        e0.broadcast(0, PayloadKind::Params, &p0).unwrap();
        e0.broadcast(1, PayloadKind::Params, &p1).unwrap();
        e2.broadcast(0, PayloadKind::Params, &p0).unwrap();
        e2.broadcast(1, PayloadKind::Params, &p1).unwrap();
        // node 1 neighbors are {0, 2}: both rounds complete, in order
        let r0 = e1.gather(0, PayloadKind::Params).unwrap();
        assert_eq!(r0.len(), 2);
        assert_eq!(r0[0].1.as_dense().unwrap(), &[1.0]);
        let r1 = e1.gather(1, PayloadKind::Params).unwrap();
        assert_eq!(r1[0].1.as_dense().unwrap(), &[2.0]);
    }

    #[test]
    fn tracker_and_params_kinds_do_not_mix() {
        let g = ring(3);
        let (mut eps, _) = build(&g, LinkModel::default(), 0);
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let theta = Arc::new(Payload::Dense(vec![1.0f32]));
        let tracker = Arc::new(Payload::Dense(vec![9.0f32]));
        e0.broadcast(0, PayloadKind::Tracker, &tracker).unwrap();
        e0.broadcast(0, PayloadKind::Params, &theta).unwrap();
        e2.broadcast(0, PayloadKind::Tracker, &tracker).unwrap();
        e2.broadcast(0, PayloadKind::Params, &theta).unwrap();
        let params = e1.gather(0, PayloadKind::Params).unwrap();
        assert!(params.iter().all(|(_, p)| p.as_dense().unwrap()[0] == 1.0));
        let trackers = e1.gather(0, PayloadKind::Tracker).unwrap();
        assert!(trackers.iter().all(|(_, p)| p.as_dense().unwrap()[0] == 9.0));
    }

    #[test]
    fn compressed_payloads_charge_encoded_bytes_and_decode_on_receive() {
        use crate::compress::{Compressor, MsgKey, TopK};
        let g = ring(3);
        let (mut eps, stats) = build(&g, LinkModel::default(), 0);
        let e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        drop(e2);
        // 10 elements, keep 2: wire size is 2·8 = 16 bytes, not 40
        let v: Vec<f32> = (0..10).map(|i| if i == 3 { 5.0 } else { 0.25 }).collect();
        let comp = TopK { frac: 0.2 };
        let enc = comp.encode(&v, MsgKey::new(7, 1, 0, PayloadKind::Params));
        let payload = Arc::new(Payload::Compressed(enc));
        assert_eq!(payload.wire_bytes(), 16);
        assert_eq!(payload.decoded_len(), 10);
        e0.send_to(&[1], 1, PayloadKind::Params, &payload).unwrap();
        e1.send_to(&[0], 1, PayloadKind::Params, &payload).unwrap();
        let got = e1.gather_from(&[0], 1, PayloadKind::Params).unwrap();
        let mut out = vec![9.0f32; 10];
        got[0].1.decode_into(&mut out).unwrap();
        assert_eq!(out[3], 5.0, "kept entry survives the wire");
        assert_eq!(out[1], 0.0, "dropped entries decode to zero");
        assert_eq!(stats.snapshot().bytes, 2 * 16, "charged at encoded size");
    }

    #[test]
    fn per_round_subset_send_and_gather() {
        // wired as a triangle, but this round only the 0-1 link is active
        let g = Graph::build(&Topology::Complete, 3, &mut Pcg64::seed(0)).unwrap();
        let (mut eps, stats) = build(&g, LinkModel::default(), 0);
        let e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let p = Arc::new(Payload::Dense(vec![5.0f32, 6.0]));
        e0.send_to(&[1], 0, PayloadKind::Params, &p).unwrap();
        e1.send_to(&[0], 0, PayloadKind::Params, &p).unwrap();
        let got = e0.gather_from(&[1], 0, PayloadKind::Params).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, 1);
        let got = e1.gather_from(&[0], 0, PayloadKind::Params).unwrap();
        assert_eq!(got.len(), 1);
        // node 2 sat the round out entirely; only the active edge was billed
        drop(e2);
        assert_eq!(stats.snapshot().messages, 2);
        assert_eq!(stats.snapshot().bytes, 2 * 8);
    }

    #[test]
    fn compute_time_advances_clock() {
        let g = ring(3);
        let (mut eps, stats) = build(&g, LinkModel::default(), 0);
        eps[0].spend_compute(2.5);
        assert!((eps[0].clock_s - 2.5).abs() < 1e-12);
        assert!(stats.snapshot().sim_time_s >= 2.5);
    }

    #[test]
    fn star_topology_hub_gathers_all() {
        let g = Graph::build(&Topology::Star, 5, &mut Pcg64::seed(0)).unwrap();
        let (eps, _) = build(&g, LinkModel::default(), 0);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    let payload = Arc::new(Payload::Dense(vec![ep.id as f32]));
                    ep.broadcast(0, PayloadKind::Params, &payload).unwrap();
                    ep.gather(0, PayloadKind::Params).unwrap().len()
                })
            })
            .collect();
        let counts: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(counts[0], 4); // hub hears all spokes
        assert!(counts[1..].iter().all(|&c| c == 1));
    }
}
