//! Simulated gossip network between hospital nodes.
//!
//! The paper's x-axis is *communication rounds*: one synchronous exchange of
//! the common-interest parameters with all graph neighbors.  This module
//! gives the node actors a real message-passing substrate (std mpsc channels,
//! one mailbox per node) with the accounting a deployment would care about:
//!
//! - **bytes on the wire** per message / per round (DSGT sends θ *and* the
//!   tracker ϑ, i.e. 2x DSGD's bytes — the comm-cost benches report this),
//! - **simulated wall time** from a per-edge latency + bandwidth model with
//!   causal clocks (receiver time = max(local, arrival)),
//! - **loss injection** modeled as deterministic retransmission (a dropped
//!   frame costs extra bytes + latency but the round still completes —
//!   synchronous gossip cannot tolerate silent loss).
//!
//! Every payload byte is accounted even though in-process delivery shares an
//! `Arc` — the simulator charges what a real NIC would move.

pub mod analytic;

use crate::graph::Graph;
use crate::rng::Pcg64;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Per-edge link model.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// One-way propagation latency per message, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Probability a frame is lost and must be retransmitted.
    pub drop_prob: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // hospital-WAN-ish defaults: 20 ms RTT/2, 100 Mbit/s, lossless
        LinkModel { latency_s: 0.010, bandwidth_bps: 12_500_000.0, drop_prob: 0.0 }
    }
}

/// What a gossip message carries (DSGT rounds exchange two payload kinds).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum PayloadKind {
    /// Model parameters θ.
    Params,
    /// Gradient tracker ϑ (DSGT only).
    Tracker,
}

/// One in-flight message.
struct Msg {
    from: usize,
    round: u64,
    kind: PayloadKind,
    /// Shared payload; bytes are charged per edge regardless of sharing.
    payload: Arc<Vec<f32>>,
    /// Sender's causal clock at arrival time (send clock + link delay).
    arrival_time: f64,
}

/// Network-wide counters (shared across node threads).
#[derive(Default)]
pub struct NetStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
    pub retransmissions: AtomicU64,
    pub rounds: AtomicU64,
    /// max causal clock over nodes, in microseconds (atomic max).
    sim_time_us: AtomicU64,
}

impl NetStats {
    pub fn snapshot(&self) -> NetSnapshot {
        NetSnapshot {
            messages: self.messages.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            retransmissions: self.retransmissions.load(Ordering::Relaxed),
            rounds: self.rounds.load(Ordering::Relaxed),
            sim_time_s: self.sim_time_us.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }

    fn bump_time(&self, t_s: f64) {
        let us = (t_s * 1e6) as u64;
        self.sim_time_us.fetch_max(us, Ordering::Relaxed);
    }
}

/// Plain-data view of [`NetStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NetSnapshot {
    pub messages: u64,
    pub bytes: u64,
    pub retransmissions: u64,
    pub rounds: u64,
    pub sim_time_s: f64,
}

/// One node's handle onto the network.
pub struct Endpoint {
    pub id: usize,
    pub neighbors: Vec<usize>,
    link: LinkModel,
    senders: BTreeMap<usize, Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// Buffered out-of-order messages keyed by (round, kind, from).
    held: BTreeMap<(u64, u8, usize), Msg>,
    stats: Arc<NetStats>,
    rng: Pcg64,
    /// Causal clock, seconds.
    pub clock_s: f64,
}

fn kind_tag(k: PayloadKind) -> u8 {
    match k {
        PayloadKind::Params => 0,
        PayloadKind::Tracker => 1,
    }
}

impl Endpoint {
    /// Send `payload` to every wired neighbor, tagged with the gossip round.
    /// Returns the per-edge transmission delay applied.
    pub fn broadcast(&mut self, round: u64, kind: PayloadKind, payload: &Arc<Vec<f32>>) -> Result<f64> {
        let neighbor_ids: Vec<usize> = self.neighbors.clone();
        self.send_to(&neighbor_ids, round, kind, payload)
    }

    /// Send `payload` to a subset of the wired neighbors — the per-round
    /// neighbor mask of a time-varying network (`graph::schedule`).
    /// Returns the per-edge transmission delay applied.
    pub fn send_to(
        &mut self,
        targets: &[usize],
        round: u64,
        kind: PayloadKind,
        payload: &Arc<Vec<f32>>,
    ) -> Result<f64> {
        let bytes = (payload.len() * std::mem::size_of::<f32>()) as u64;
        let mut max_delay = 0.0f64;
        for &nb in targets {
            // retransmission loop: deterministic count from this node's rng
            let mut tries = 1u64;
            while self.link.drop_prob > 0.0 && self.rng.bernoulli(self.link.drop_prob) {
                tries += 1;
                if tries > 64 {
                    bail!("link to {nb} failed 64 retransmissions");
                }
            }
            let tx = self.link.latency_s + bytes as f64 / self.link.bandwidth_bps;
            let delay = tx * tries as f64;
            max_delay = max_delay.max(delay);
            self.stats.messages.fetch_add(1, Ordering::Relaxed);
            self.stats.bytes.fetch_add(bytes * tries, Ordering::Relaxed);
            self.stats.retransmissions.fetch_add(tries - 1, Ordering::Relaxed);
            let msg = Msg {
                from: self.id,
                round,
                kind,
                payload: Arc::clone(payload),
                arrival_time: self.clock_s + delay,
            };
            self.senders
                .get(&nb)
                .context("missing sender")?
                .send(msg)
                .map_err(|_| anyhow::anyhow!("neighbor {nb} hung up"))?;
        }
        Ok(max_delay)
    }

    /// Block until one `(round, kind)` message from *every* wired neighbor
    /// has arrived; returns them ordered by sender id.  Out-of-order
    /// messages (future rounds, other kinds) are buffered, not lost.
    pub fn gather(&mut self, round: u64, kind: PayloadKind) -> Result<Vec<(usize, Arc<Vec<f32>>)>> {
        let want: Vec<usize> = self.neighbors.clone();
        self.gather_from(&want, round, kind)
    }

    /// Block until one `(round, kind)` message from each of `sources` has
    /// arrived — the per-round neighbor mask of a time-varying network.
    /// Messages from other senders or rounds are buffered, not lost.
    pub fn gather_from(
        &mut self,
        sources: &[usize],
        round: u64,
        kind: PayloadKind,
    ) -> Result<Vec<(usize, Arc<Vec<f32>>)>> {
        let tag = kind_tag(kind);
        let mut have: BTreeMap<usize, Msg> = BTreeMap::new();

        // drain previously-buffered matches
        let keys: Vec<_> = self
            .held
            .keys()
            .filter(|(r, k, from)| *r == round && *k == tag && sources.contains(from))
            .copied()
            .collect();
        for key in keys {
            let msg = self.held.remove(&key).unwrap();
            have.insert(msg.from, msg);
        }

        while have.len() < sources.len() {
            let msg = self
                .inbox
                .recv()
                .map_err(|_| anyhow::anyhow!("network shut down while node {} waits", self.id))?;
            if msg.round == round && kind_tag(msg.kind) == tag && sources.contains(&msg.from) {
                have.insert(msg.from, msg);
            } else {
                self.held.insert((msg.round, kind_tag(msg.kind), msg.from), msg);
            }
        }

        // causal clock: the round completes when the last message lands
        for msg in have.values() {
            self.clock_s = self.clock_s.max(msg.arrival_time);
        }
        self.stats.bump_time(self.clock_s);

        Ok(have.into_iter().map(|(from, m)| (from, m.payload)).collect())
    }

    /// Advance the local clock by `secs` of compute (local SGD steps).
    pub fn spend_compute(&mut self, secs: f64) {
        self.clock_s += secs;
        self.stats.bump_time(self.clock_s);
    }
}

/// Build one endpoint per node over `g` plus the shared stats handle.
pub fn build(g: &Graph, link: LinkModel, seed: u64) -> (Vec<Endpoint>, Arc<NetStats>) {
    let n = g.n();
    let stats = Arc::new(NetStats::default());
    let mut txs: Vec<Sender<Msg>> = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(Some(rx));
    }
    let endpoints = (0..n)
        .map(|i| {
            let neighbors: Vec<usize> = g.neighbors(i).to_vec();
            let senders: BTreeMap<usize, Sender<Msg>> =
                neighbors.iter().map(|&j| (j, txs[j].clone())).collect();
            Endpoint {
                id: i,
                neighbors,
                link,
                senders,
                inbox: rxs[i].take().unwrap(),
                held: BTreeMap::new(),
                stats: Arc::clone(&stats),
                rng: Pcg64::new(seed, 0x4E7 + i as u64),
                clock_s: 0.0,
            }
        })
        .collect();
    (endpoints, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;

    fn ring(n: usize) -> Graph {
        Graph::build(&Topology::Ring, n, &mut Pcg64::seed(0)).unwrap()
    }

    /// Run one synchronous gossip round over node threads; every node
    /// broadcasts its id-vector and averages what it gathers.
    fn one_round(n: usize, link: LinkModel) -> (Vec<f32>, NetSnapshot) {
        let g = ring(n);
        let (endpoints, stats) = build(&g, link, 42);
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    let payload = Arc::new(vec![ep.id as f32; 4]);
                    ep.broadcast(0, PayloadKind::Params, &payload).unwrap();
                    let got = ep.gather(0, PayloadKind::Params).unwrap();
                    let mut acc = payload[0];
                    for (_, p) in &got {
                        acc += p[0];
                    }
                    acc / (got.len() + 1) as f32
                })
            })
            .collect();
        let results: Vec<f32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        stats.rounds.fetch_add(1, Ordering::Relaxed);
        let snap = stats.snapshot();
        (results, snap)
    }

    #[test]
    fn ring_gossip_averages_neighbors() {
        let (results, _) = one_round(5, LinkModel::default());
        // node i averages {i-1, i, i+1} mod 5
        for (i, &r) in results.iter().enumerate() {
            let l = ((i + 4) % 5) as f32;
            let rgt = ((i + 1) % 5) as f32;
            let expect = (l + i as f32 + rgt) / 3.0;
            assert!((r - expect).abs() < 1e-6, "node {i}: {r} vs {expect}");
        }
    }

    #[test]
    fn byte_accounting_exact() {
        let n = 6;
        let (_, snap) = one_round(n, LinkModel::default());
        // each node sends 2 messages of 4 f32 = 16 bytes
        assert_eq!(snap.messages, (n * 2) as u64);
        assert_eq!(snap.bytes, (n * 2 * 16) as u64);
        assert_eq!(snap.retransmissions, 0);
        assert_eq!(snap.rounds, 1);
    }

    #[test]
    fn sim_time_reflects_link_model() {
        let slow = LinkModel { latency_s: 0.5, bandwidth_bps: 1e9, drop_prob: 0.0 };
        let (_, snap) = one_round(4, slow);
        assert!(snap.sim_time_s >= 0.5, "{}", snap.sim_time_s);
        assert!(snap.sim_time_s < 1.0, "{}", snap.sim_time_s);
    }

    #[test]
    fn drops_cause_retransmission_bytes() {
        let lossy = LinkModel { drop_prob: 0.3, ..LinkModel::default() };
        let (results, snap) = one_round(8, lossy);
        assert!(snap.retransmissions > 0, "expected retransmissions");
        assert!(snap.bytes > 8 * 2 * 16);
        // результат still correct: gossip completes despite loss
        assert_eq!(results.len(), 8);
    }

    #[test]
    fn out_of_order_rounds_are_buffered() {
        // node 0 sends rounds 0 and 1 before node 1 gathers round 0
        let g = ring(3);
        let (mut eps, _) = build(&g, LinkModel::default(), 0);
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let p0 = Arc::new(vec![1.0f32]);
        let p1 = Arc::new(vec![2.0f32]);
        e0.broadcast(0, PayloadKind::Params, &p0).unwrap();
        e0.broadcast(1, PayloadKind::Params, &p1).unwrap();
        e2.broadcast(0, PayloadKind::Params, &p0).unwrap();
        e2.broadcast(1, PayloadKind::Params, &p1).unwrap();
        // node 1 neighbors are {0, 2}: both rounds complete, in order
        let r0 = e1.gather(0, PayloadKind::Params).unwrap();
        assert_eq!(r0.len(), 2);
        assert_eq!(*r0[0].1, vec![1.0]);
        let r1 = e1.gather(1, PayloadKind::Params).unwrap();
        assert_eq!(*r1[0].1, vec![2.0]);
    }

    #[test]
    fn tracker_and_params_kinds_do_not_mix() {
        let g = ring(3);
        let (mut eps, _) = build(&g, LinkModel::default(), 0);
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let theta = Arc::new(vec![1.0f32]);
        let tracker = Arc::new(vec![9.0f32]);
        e0.broadcast(0, PayloadKind::Tracker, &tracker).unwrap();
        e0.broadcast(0, PayloadKind::Params, &theta).unwrap();
        e2.broadcast(0, PayloadKind::Tracker, &tracker).unwrap();
        e2.broadcast(0, PayloadKind::Params, &theta).unwrap();
        let params = e1.gather(0, PayloadKind::Params).unwrap();
        assert!(params.iter().all(|(_, p)| p[0] == 1.0));
        let trackers = e1.gather(0, PayloadKind::Tracker).unwrap();
        assert!(trackers.iter().all(|(_, p)| p[0] == 9.0));
    }

    #[test]
    fn per_round_subset_send_and_gather() {
        // wired as a triangle, but this round only the 0-1 link is active
        let g = Graph::build(&Topology::Complete, 3, &mut Pcg64::seed(0)).unwrap();
        let (mut eps, stats) = build(&g, LinkModel::default(), 0);
        let e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let p = Arc::new(vec![5.0f32, 6.0]);
        e0.send_to(&[1], 0, PayloadKind::Params, &p).unwrap();
        e1.send_to(&[0], 0, PayloadKind::Params, &p).unwrap();
        let got = e0.gather_from(&[1], 0, PayloadKind::Params).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, 1);
        let got = e1.gather_from(&[0], 0, PayloadKind::Params).unwrap();
        assert_eq!(got.len(), 1);
        // node 2 sat the round out entirely; only the active edge was billed
        drop(e2);
        assert_eq!(stats.snapshot().messages, 2);
        assert_eq!(stats.snapshot().bytes, 2 * 8);
    }

    #[test]
    fn compute_time_advances_clock() {
        let g = ring(3);
        let (mut eps, stats) = build(&g, LinkModel::default(), 0);
        eps[0].spend_compute(2.5);
        assert!((eps[0].clock_s - 2.5).abs() < 1e-12);
        assert!(stats.snapshot().sim_time_s >= 2.5);
    }

    #[test]
    fn star_topology_hub_gathers_all() {
        let g = Graph::build(&Topology::Star, 5, &mut Pcg64::seed(0)).unwrap();
        let (eps, _) = build(&g, LinkModel::default(), 0);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    let payload = Arc::new(vec![ep.id as f32]);
                    ep.broadcast(0, PayloadKind::Params, &payload).unwrap();
                    ep.gather(0, PayloadKind::Params).unwrap().len()
                })
            })
            .collect();
        let counts: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(counts[0], 4); // hub hears all spokes
        assert!(counts[1..].iter().all(|&c| c == 1));
    }
}
