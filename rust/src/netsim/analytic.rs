//! Analytic communication accounting for the fused driver.
//!
//! The fused execution mode computes a whole gossip round in one PJRT call,
//! so no real messages flow — but the experiment still needs the exact
//! communication cost a deployment would pay.  This accountant charges the
//! same quantities the channel-based netsim measures: per directed edge and
//! payload kind, one message at that kind's *encoded* wire size (dense f32,
//! or whatever the configured `compress` scheme ships); per round, simulated
//! time advances by the local-compute phase plus the slowest link transfer
//! (synchronous gossip = max over edges), with payload kinds pipelined
//! sequentially (DSGT sends θ then ϑ).  Kinds are charged individually —
//! DSGT's two payloads each at their own true size — never as
//! `payload × kinds` flat.
//!
//! The network is a per-round quantity (`graph::schedule`), so the caller
//! passes each round's directed active-edge count — the accountant holds no
//! frozen graph.  With a lossless link this matches [`super::NetStats`]
//! byte-for-byte on every plan (integration-tested); loss injection is an
//! actor-mode-only feature.

use super::{LinkModel, NetSnapshot};

/// Deterministic mirror of the netsim counters for fused execution.
#[derive(Clone, Debug)]
pub struct Accountant {
    link: LinkModel,
    snap: NetSnapshot,
}

impl Accountant {
    /// Fresh accountant over the given link model (zero counters).
    pub fn new(link: LinkModel) -> Self {
        Accountant { link, snap: NetSnapshot::default() }
    }

    /// Charge a local-compute phase: all nodes run `steps` SGD steps in
    /// parallel, each costing `secs_per_step`.
    pub fn local_compute(&mut self, steps: u64, secs_per_step: f64) {
        self.snap.sim_time_s += steps as f64 * secs_per_step;
    }

    /// Charge raw compute seconds — the straggler path: a heterogeneous
    /// round costs the slowest participant's `τ_i · s_step / speed_i`
    /// (`engine::stragglers::ComputeSchedule::round_compute_s`), not a
    /// uniform per-step count.
    pub fn compute_seconds(&mut self, secs: f64) {
        self.snap.sim_time_s += secs;
    }

    /// Charge `messages` wire messages of one payload kind at its *encoded*
    /// size, advancing the serialized clock by one latency plus one transfer
    /// — the shared arithmetic both the per-round and per-message paths go
    /// through, so their totals can never drift apart.
    fn charge_kind(&mut self, messages: u64, bytes_each: u64, latency_s: f64) -> f64 {
        self.snap.messages += messages;
        self.snap.bytes += messages * bytes_each;
        let dt = latency_s + bytes_each as f64 / self.link.bandwidth_bps;
        self.snap.sim_time_s += dt;
        dt
    }

    /// Charge one synchronous gossip round: for each payload kind,
    /// `directed_edges` messages (both directions of every active edge this
    /// round) at that kind's *encoded* wire size — `kind_bytes` holds one
    /// entry per kind (DSGT passes `[θ_bytes, ϑ_bytes]`), so differently
    /// encoded payloads are each charged at their true size, and kinds
    /// pipeline sequentially on the simulated clock.
    pub fn comm_round(&mut self, directed_edges: u64, kind_bytes: &[u64]) {
        for &bytes in kind_bytes {
            self.charge_kind(directed_edges, bytes, self.link.latency_s);
        }
        self.snap.rounds += 1;
    }

    /// Charge one *asynchronous* point-to-point message train (the async
    /// driver's unit of accounting): each payload kind ships once at its
    /// encoded wire size, kinds pipelined sequentially over the link.
    /// Returns the in-flight duration — `latency_s` per kind plus the
    /// transfer times — which the event queue uses as the delivery offset.
    ///
    /// Note the serialized `sim_time_s` this adds is the *link occupancy*,
    /// not wall-clock: concurrent async messages overlap, so the async
    /// driver reports virtual time from its event clock and keeps only the
    /// byte/message counters from this accountant.
    pub fn comm_message(&mut self, kind_bytes: &[u64], latency_s: f64) -> f64 {
        let mut dt = 0.0;
        for &bytes in kind_bytes {
            dt += self.charge_kind(1, bytes, latency_s);
        }
        dt
    }

    /// Charge a star-network round (FedAvg): every client uploads and
    /// downloads one payload to/from the server.
    pub fn star_round(&mut self, n_clients: usize, payload_elems: usize) {
        let bytes = (payload_elems * std::mem::size_of::<f32>()) as u64;
        let msgs = 2 * n_clients as u64;
        self.snap.messages += msgs;
        self.snap.bytes += msgs * bytes;
        self.snap.rounds += 1;
        // upload (parallel) + download (parallel)
        self.snap.sim_time_s += 2.0 * (self.link.latency_s + bytes as f64 / self.link.bandwidth_bps);
    }

    /// Record `n` quarantined neighbor payloads — the fused driver's mirror
    /// of [`super::Endpoint::report_quarantine`] (non-finite rows folded into
    /// the self-weight, DESIGN.md §14).
    pub fn report_quarantine(&mut self, n: u64) {
        self.snap.quarantined += n;
    }

    /// Plain-data copy of the counters so far.
    pub fn snapshot(&self) -> NetSnapshot {
        self.snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, Topology};
    use crate::rng::Pcg64;

    #[test]
    fn matches_channel_netsim_counters() {
        // run one real gossip round over channels and compare byte counts
        let g = Graph::build(&Topology::Ring, 6, &mut Pcg64::seed(0)).unwrap();
        let link = LinkModel::default();
        let payload = 128usize;

        let (endpoints, stats) = super::super::build(&g, link, 1);
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    let p =
                        std::sync::Arc::new(super::super::Payload::Dense(vec![0.0f32; 128]));
                    ep.broadcast(0, super::super::PayloadKind::Params, &p).unwrap();
                    ep.gather(0, super::super::PayloadKind::Params).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        stats.rounds.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let real = stats.snapshot();

        let mut acct = Accountant::new(link);
        acct.comm_round(2 * g.edge_count() as u64, &[4 * payload as u64]);
        let model = acct.snapshot();

        assert_eq!(model.messages, real.messages);
        assert_eq!(model.bytes, real.bytes);
        assert_eq!(model.rounds, real.rounds);
    }

    #[test]
    fn dsgt_pays_double() {
        let g = Graph::build(&Topology::Ring, 4, &mut Pcg64::seed(0)).unwrap();
        let edges = 2 * g.edge_count() as u64;
        let mut a = Accountant::new(LinkModel::default());
        let mut b = Accountant::new(LinkModel::default());
        a.comm_round(edges, &[400]);
        b.comm_round(edges, &[400, 400]);
        assert_eq!(b.snapshot().bytes, 2 * a.snapshot().bytes);
        assert!(b.snapshot().sim_time_s > a.snapshot().sim_time_s);
    }

    #[test]
    fn kinds_are_charged_at_their_own_encoded_sizes() {
        // regression for the old `payload_elems × kinds` flat charge: two
        // payload kinds with different wire sizes (dense θ, compressed ϑ)
        // must be billed individually, not as 2× either size
        let mut a = Accountant::new(LinkModel::default());
        a.comm_round(4, &[1000, 24]);
        let s = a.snapshot();
        assert_eq!(s.messages, 8, "one message per edge per kind");
        assert_eq!(s.bytes, 4 * 1000 + 4 * 24);
        assert_eq!(s.rounds, 1);
        // and the flat model would have been wrong in both directions
        assert_ne!(s.bytes, 2 * 4 * 1000);
        assert_ne!(s.bytes, 2 * 4 * 24);
        // sim time pipelines the kinds sequentially
        let link = LinkModel::default();
        let expect = 2.0 * link.latency_s + (1000.0 + 24.0) / link.bandwidth_bps;
        assert!((s.sim_time_s - expect).abs() < 1e-12);
    }

    #[test]
    fn comm_message_matches_comm_round_totals() {
        // E per-message charges must reproduce one round's byte/message
        // totals exactly — the async driver reuses the encoded-wire-size
        // logic instead of duplicating it
        let link = LinkModel::default();
        let edges = 6u64;
        let kinds = [1000u64, 24u64];

        let mut per_round = Accountant::new(link);
        per_round.comm_round(edges, &kinds);

        let mut per_msg = Accountant::new(link);
        let mut dt = 0.0;
        for _ in 0..edges {
            dt = per_msg.comm_message(&kinds, link.latency_s);
        }
        assert_eq!(per_msg.snapshot().messages, per_round.snapshot().messages);
        assert_eq!(per_msg.snapshot().bytes, per_round.snapshot().bytes);
        // the returned in-flight duration pipelines the kinds sequentially
        let expect = 2.0 * link.latency_s + (1000.0 + 24.0) / link.bandwidth_bps;
        assert!((dt - expect).abs() < 1e-12);
        // serialized link occupancy: per-message pays latency per message,
        // per-round pays it once per kind (parallel edges) — documented gap
        assert!(per_msg.snapshot().sim_time_s > per_round.snapshot().sim_time_s);
        // rounds counter is a sync concept; messages never touch it
        assert_eq!(per_msg.snapshot().rounds, 0);
    }

    #[test]
    fn comm_round_totals_unchanged_by_refactor() {
        // regression pin for the charge_kind extraction: the sync per-round
        // totals must match the pre-refactor arithmetic bit for bit
        let link = LinkModel { latency_s: 0.010, bandwidth_bps: 12_500_000.0, drop_prob: 0.0 };
        let mut a = Accountant::new(link);
        a.comm_round(10, &[4096, 128]);
        a.comm_round(6, &[4096, 128]);
        let s = a.snapshot();
        assert_eq!(s.messages, 32);
        assert_eq!(s.bytes, 16 * 4096 + 16 * 128);
        assert_eq!(s.rounds, 2);
        let mut expect = 0.0f64;
        for _ in 0..2 {
            expect += link.latency_s + 4096.0 / link.bandwidth_bps;
            expect += link.latency_s + 128.0 / link.bandwidth_bps;
        }
        assert_eq!(s.sim_time_s.to_bits(), expect.to_bits());
    }

    #[test]
    fn per_round_edge_counts_accumulate() {
        // a churn-style schedule: 8, then 4, then 8 directed edges
        let mut a = Accountant::new(LinkModel::default());
        a.comm_round(8, &[400]);
        a.comm_round(4, &[400]);
        a.comm_round(8, &[400]);
        let s = a.snapshot();
        assert_eq!(s.messages, 20);
        assert_eq!(s.bytes, 20 * 400);
        assert_eq!(s.rounds, 3);
    }

    #[test]
    fn compute_time_accumulates() {
        let mut a = Accountant::new(LinkModel::default());
        a.local_compute(100, 1e-3);
        assert!((a.snapshot().sim_time_s - 0.1).abs() < 1e-12);
    }

    #[test]
    fn star_round_counts() {
        let mut a = Accountant::new(LinkModel::default());
        a.star_round(4, 100);
        let s = a.snapshot();
        assert_eq!(s.messages, 8);
        assert_eq!(s.bytes, 8 * 400);
    }
}
