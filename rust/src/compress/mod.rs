//! Lossy message compression for gossip rounds — quantization and top-k
//! sparsification with error-feedback residuals.
//!
//! The paper's axis is communication *rounds*; this module attacks the
//! complementary axis the FL communication surveys emphasize: the *bytes*
//! each round moves.  Every gossip payload is a `p`-element f32 vector
//! (θ, and the DSGT tracker ϑ).  A [`Compressor`] turns that vector into a
//! compact wire message ([`Encoded`]) whose exact byte size both the channel
//! netsim and the analytic accountant charge, and whose decoded value every
//! participant reconstructs bit-for-bit:
//!
//! - [`Identity`] — a plain f32 copy (4p bytes).  Exists so the *entire*
//!   compressed code path can be pinned bitwise against the uncompressed
//!   fast path in tests.
//! - [`QuantizeQ8`] / [`QuantizeQ4`] — absmax linear quantization to 8/4-bit
//!   codes with **deterministic stochastic rounding**: the rounding offsets
//!   come from a PCG stream keyed by `(seed, round, node, payload kind)`
//!   ([`MsgKey`]), so the sender, every receiver, and both execution drivers
//!   derive the identical codes with no coordination (§7 determinism).
//! - [`TopK`] — magnitude sparsification: keep the `⌈frac·p⌉` largest-|v|
//!   entries (ties broken by index, fully deterministic), shipped as
//!   `(u32 index, f32 value)` pairs.
//!
//! **Convergence mechanism** — the drivers apply compressed messages
//! through the CHOCO-style *difference form* (DESIGN.md §10):
//! `θ′_i = θ_i + [(W X̂)_i − x̂_i] − α ∇g_i`.  A node's own parameters never
//! pass through the compressor — only the consensus direction does — and
//! under a doubly stochastic `W` the compression perturbations cancel in
//! the network average exactly (`Σ_h [(W X̂)_h − x̂_h] = 0`), so lossy
//! messages never bias the mean iterate, for unbiased quantizers and biased
//! sparsifiers alike.  An **opt-in error-feedback residual**
//! (`comm.error_feedback`) additionally error-compensates the outgoing
//! message (`v = x + e`, `e ← v − D(C(v))`); it is off by default — the
//! difference form already preserves the mean, and stacking EF on top
//! destabilizes aggressive top-k (measured; see §10).  The residual slabs
//! live with the engine state (fused driver) or the node actor — the
//! compressor itself is stateless and pure.
//!
//! Wire-size contract: [`Compressor::wire_bytes`] is an exact function of
//! `p`, and [`Encoded::wire_bytes`] of the actual message always agrees —
//! that is what lets the fused driver's analytic accountant and the channel
//! netsim charge identical byte totals (integration-tested).

use crate::config::ExperimentConfig;
use crate::netsim::PayloadKind;
use crate::rng::Pcg64;
use anyhow::{bail, ensure, Result};

/// RNG stream tag for quantization noise (disjoint from every other stream
/// constant in the crate — see `graph::schedule`, `coordinator::sampler`).
const STREAM_COMPRESS: u64 = 0xC0_4B12_55E0;

/// splitmix64 finalizer — mixes `(round, node, kind)` into one stream id so
/// distinct messages draw decorrelated rounding noise.
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic identity of one gossip message: which run (`seed`), which
/// communication round, which sending node, and which payload kind (θ or the
/// DSGT tracker).  Quantizers derive their stochastic-rounding stream from
/// this key alone, so any party — the sender, a receiver, the fused driver's
/// whole-network loop, a test — reconstructs the identical encoded message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MsgKey {
    /// Experiment seed (`cfg.seed`).
    pub seed: u64,
    /// 1-based communication round.
    pub round: u64,
    /// Sending node id.
    pub node: u64,
    /// Payload kind (θ vs tracker) — DSGT compresses two streams per round.
    pub kind: PayloadKind,
}

impl MsgKey {
    /// Build a key from the driver-side quantities.
    pub fn new(seed: u64, round: usize, node: usize, kind: PayloadKind) -> Self {
        MsgKey { seed, round: round as u64, node: node as u64, kind }
    }

    /// The keyed rounding-noise generator for this message.
    pub fn rng(&self) -> Pcg64 {
        let z = self
            .round
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(self.node.wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
            .wrapping_add(self.kind.tag() as u64);
        Pcg64::new(self.seed, STREAM_COMPRESS ^ mix64(z))
    }
}

/// One compressed gossip message — the exact wire format whose byte size the
/// netsim and the analytic accountant charge.
#[derive(Clone, Debug, PartialEq)]
pub enum Encoded {
    /// Uncompressed f32 copy ([`Identity`]): `4·len` bytes.
    Dense(Vec<f32>),
    /// Magnitude top-k ([`TopK`]): ascending indices + their f32 values,
    /// `8·k` bytes (u32 index + f32 value per kept entry).  `len` is the
    /// decoded vector length (absent entries decode to zero).
    TopK {
        /// Decoded vector length `p`.
        len: u32,
        /// Kept indices, ascending.
        idx: Vec<u32>,
        /// Values parallel to `idx`.
        val: Vec<f32>,
    },
    /// 8-bit absmax quantization ([`QuantizeQ8`]): one i8 code per element
    /// (stored two's-complement in a `u8`), plus the f32 scale — `4 + len`
    /// bytes.
    Q8 {
        /// Dequantization scale (absmax / 127; 0 for the zero vector).
        scale: f32,
        /// i8 codes in [-127, 127], one per element.
        codes: Vec<u8>,
    },
    /// 4-bit absmax quantization ([`QuantizeQ4`]): two codes packed per byte
    /// (low nibble first, nibble = code + 8), plus the f32 scale —
    /// `4 + ⌈len/2⌉` bytes.
    Q4 {
        /// Dequantization scale (absmax / 7; 0 for the zero vector).
        scale: f32,
        /// Decoded vector length `p` (the last nibble may be padding).
        len: u32,
        /// Packed nibble codes.
        codes: Vec<u8>,
    },
}

impl Encoded {
    /// Exact bytes this message occupies on the simulated wire.  Always
    /// equals [`Compressor::wire_bytes`] of the compressor that produced it.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Encoded::Dense(v) => 4 * v.len() as u64,
            Encoded::TopK { idx, .. } => 8 * idx.len() as u64,
            Encoded::Q8 { codes, .. } => 4 + codes.len() as u64,
            Encoded::Q4 { codes, .. } => 4 + codes.len() as u64,
        }
    }

    /// Decoded vector length `p` of this message.
    pub fn decoded_len(&self) -> usize {
        match self {
            Encoded::Dense(v) => v.len(),
            Encoded::TopK { len, .. } => *len as usize,
            Encoded::Q8 { codes, .. } => codes.len(),
            Encoded::Q4 { len, .. } => *len as usize,
        }
    }

    /// Does every value this message decodes to come out finite?  Exact
    /// without decoding: quantized codes are bounded integers, so finiteness
    /// is carried entirely by the f32 scale (q8/q4) or by the kept values
    /// (top-k/dense).  The ingest quarantine (DESIGN.md §14) uses this to
    /// classify a neighbor payload as poisoned at the same semantics as a
    /// scan of the decoded vector, one payload-sized pass cheaper.
    pub fn is_finite(&self) -> bool {
        match self {
            Encoded::Dense(v) => v.iter().all(|x| x.is_finite()),
            Encoded::TopK { val, .. } => val.iter().all(|x| x.is_finite()),
            Encoded::Q8 { scale, .. } => scale.is_finite(),
            Encoded::Q4 { scale, .. } => scale.is_finite(),
        }
    }
}

/// Decode a message into `out[p]` — a pure function of the wire bytes, so
/// the sender (updating its residual), every receiver, and the fused driver
/// all reconstruct the identical f32 vector.
///
/// Adversarial bytes exist on the wire (DESIGN.md §14), so a malformed
/// message — truncated code buffers, index/value length skew, out-of-range
/// or unsorted top-k indices — is a **loud error**, never a panic or a
/// silent garbage read.  On error `out` may be partially written; callers
/// must treat the buffer as poisoned and drop the message.  Non-finite
/// *values* (a NaN/Inf scale or payload) are structurally well-formed and
/// decode successfully — classifying and quarantining them is the ingest
/// guard's job ([`Encoded::is_finite`]), because an attacked-but-honest
/// pipeline must survive them, not abort.
pub fn decode_into(enc: &Encoded, out: &mut [f32]) -> Result<()> {
    ensure!(
        out.len() == enc.decoded_len(),
        "decode buffer holds {} elements, message decodes to {}",
        out.len(),
        enc.decoded_len()
    );
    match enc {
        Encoded::Dense(v) => out.copy_from_slice(v),
        Encoded::TopK { len, idx, val } => {
            ensure!(
                idx.len() == val.len(),
                "top-k message carries {} indices but {} values",
                idx.len(),
                val.len()
            );
            ensure!(
                idx.len() <= *len as usize,
                "top-k message keeps {} of only {len} entries",
                idx.len()
            );
            let mut prev: Option<u32> = None;
            for &i in idx {
                ensure!(i < *len, "top-k index {i} out of range for length {len}");
                if let Some(p) = prev {
                    ensure!(i > p, "top-k indices must be strictly ascending ({p} then {i})");
                }
                prev = Some(i);
            }
            out.fill(0.0);
            for (&i, &v) in idx.iter().zip(val) {
                out[i as usize] = v;
            }
        }
        Encoded::Q8 { scale, codes } => {
            ensure!(
                codes.len() == out.len(),
                "q8 message carries {} codes for {} elements",
                codes.len(),
                out.len()
            );
            for (o, &c) in out.iter_mut().zip(codes) {
                *o = (c as i8) as f32 * scale;
            }
        }
        Encoded::Q4 { scale, len, codes } => {
            ensure!(
                codes.len() == (*len as usize).div_ceil(2),
                "q4 message carries {} code bytes for length {len} (want {})",
                codes.len(),
                (*len as usize).div_ceil(2)
            );
            for (i, o) in out.iter_mut().enumerate() {
                let nib = (codes[i / 2] >> ((i % 2) * 4)) & 0x0F;
                *o = (nib as i32 - 8) as f32 * scale;
            }
        }
    }
    Ok(())
}

/// A lossy message compressor: a pure function from a `p`-element f32 vector
/// (plus the deterministic [`MsgKey`]) to a compact wire message.
///
/// Contract (what the convergence and equivalence tests pin):
/// - **Determinism** — `encode(v, key)` is a pure function: same vector and
///   key → the identical [`Encoded`], across drivers, threads, and runs.
/// - **Fixed wire size** — every message of length `p` occupies exactly
///   [`Compressor::wire_bytes`]`(p)` bytes, so analytic accounting matches
///   the channel netsim byte-for-byte.
/// - **Unbiasedness / contraction** — quantizers are unbiased (stochastic
///   rounding); top-k is a contraction. Either property combines with the
///   mean-preserving difference-form update (see the module docs) to keep
///   DSGD/DSGT convergent.
///
/// # Examples
///
/// ```
/// use decfl::compress::{decode_into, Compressor, MsgKey, QuantizeQ8};
/// use decfl::netsim::PayloadKind;
///
/// let c = QuantizeQ8;
/// let v = vec![0.5f32, -1.0, 0.25, 0.0];
/// let key = MsgKey::new(7, 3, 0, PayloadKind::Params);
/// let enc = c.encode(&v, key);
/// assert_eq!(enc.wire_bytes(), c.wire_bytes(v.len())); // exact wire size
///
/// let mut xhat = vec![0.0f32; 4];
/// decode_into(&enc, &mut xhat).unwrap(); // every party reconstructs this bitwise
/// assert_eq!(c.encode(&v, key), enc); // same key → identical message
/// ```
pub trait Compressor: Send + Sync {
    /// Short display label (`q8`, `topk@0.10`, ...).
    fn label(&self) -> String;

    /// Exact encoded size in bytes of one `p`-element message.
    fn wire_bytes(&self, p: usize) -> u64;

    /// Encode `v` under `key` into `out`, salvaging `out`'s existing
    /// heap buffers when the variant matches (a warm caller that feeds the
    /// previous message back in encodes allocation-free).  The produced
    /// message is identical to [`Compressor::encode`] — buffer reuse never
    /// changes a single wire byte.
    fn encode_into(&self, v: &[f32], key: MsgKey, out: &mut Encoded);

    /// Encode `v` under `key` (pure: no internal state advances).
    fn encode(&self, v: &[f32], key: MsgKey) -> Encoded {
        let mut out = Encoded::Dense(Vec::new());
        self.encode_into(v, key, &mut out);
        out
    }
}

// ----------------------------------------------------------- identity ----

/// The no-op compressor: ships the full f32 vector.  Routing a run through
/// the compressed machinery with `Identity` must be bitwise-identical to the
/// uncompressed fast path — the pin that proves the plumbing is lossless.
pub struct Identity;

impl Compressor for Identity {
    fn label(&self) -> String {
        "identity".into()
    }

    fn wire_bytes(&self, p: usize) -> u64 {
        4 * p as u64
    }

    fn encode_into(&self, v: &[f32], _key: MsgKey, out: &mut Encoded) {
        let mut buf = match std::mem::replace(out, Encoded::Dense(Vec::new())) {
            Encoded::Dense(b) => b,
            _ => Vec::new(),
        };
        buf.clear();
        buf.extend_from_slice(v);
        *out = Encoded::Dense(buf);
    }
}

// -------------------------------------------------------- quantization ----

/// Stochastically round `x / scale` to an integer in `[-qmax, qmax]` using
/// one uniform draw: `⌊x/scale + u⌋` is unbiased for `x/scale`.
fn stoch_round(x: f32, scale: f32, qmax: i32, rng: &mut Pcg64) -> i32 {
    let t = x as f64 / scale as f64 + rng.next_f64();
    (t.floor() as i32).clamp(-qmax, qmax)
}

/// Largest |v| entry (the quantization range); 0 for an empty/zero vector.
fn absmax(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
}

/// 8-bit absmax quantizer: codes in [-127, 127], scale = absmax/127, with
/// deterministic stochastic rounding keyed by the message's [`MsgKey`].
/// Wire size `4 + p` bytes — ~4x below dense f32.
pub struct QuantizeQ8;

impl Compressor for QuantizeQ8 {
    fn label(&self) -> String {
        "q8".into()
    }

    fn wire_bytes(&self, p: usize) -> u64 {
        4 + p as u64
    }

    fn encode_into(&self, v: &[f32], key: MsgKey, out: &mut Encoded) {
        let mut codes = match std::mem::replace(out, Encoded::Dense(Vec::new())) {
            Encoded::Q8 { codes, .. } => codes,
            _ => Vec::new(),
        };
        codes.clear();
        let amax = absmax(v);
        if amax == 0.0 {
            codes.resize(v.len(), 0u8);
            *out = Encoded::Q8 { scale: 0.0, codes };
            return;
        }
        let scale = amax / 127.0;
        let mut rng = key.rng();
        codes.extend(v.iter().map(|&x| stoch_round(x, scale, 127, &mut rng) as i8 as u8));
        *out = Encoded::Q8 { scale, codes };
    }
}

/// 4-bit absmax quantizer: codes in [-7, 7] packed two per byte, scale =
/// absmax/7, deterministic stochastic rounding.  Wire size `4 + ⌈p/2⌉`
/// bytes — ~8x below dense f32.
pub struct QuantizeQ4;

impl Compressor for QuantizeQ4 {
    fn label(&self) -> String {
        "q4".into()
    }

    fn wire_bytes(&self, p: usize) -> u64 {
        4 + p.div_ceil(2) as u64
    }

    fn encode_into(&self, v: &[f32], key: MsgKey, out: &mut Encoded) {
        let len = v.len() as u32;
        let nbytes = v.len().div_ceil(2);
        let mut codes = match std::mem::replace(out, Encoded::Dense(Vec::new())) {
            Encoded::Q4 { codes, .. } => codes,
            _ => Vec::new(),
        };
        codes.clear();
        let amax = absmax(v);
        if amax == 0.0 {
            // nibble 8 encodes the code 0
            codes.resize(nbytes, 0x88u8);
            *out = Encoded::Q4 { scale: 0.0, len, codes };
            return;
        }
        codes.resize(nbytes, 0u8);
        let scale = amax / 7.0;
        let mut rng = key.rng();
        for (i, &x) in v.iter().enumerate() {
            let nib = (stoch_round(x, scale, 7, &mut rng) + 8) as u8;
            codes[i / 2] |= nib << ((i % 2) * 4);
        }
        // pad a trailing odd nibble with code 0 (nibble 8) for a clean decode
        if v.len() % 2 == 1 {
            if let Some(last) = codes.last_mut() {
                *last |= 0x80;
            }
        }
        *out = Encoded::Q4 { scale, len, codes };
    }
}

// ------------------------------------------------------------- top-k -----

/// Magnitude sparsification: keep the `⌈frac·p⌉` largest-|v| entries.
/// Selection is fully deterministic — entries are ordered by `(|v| desc,
/// index asc)` so ties cannot reorder across runs or drivers.  Wire size
/// `8·k` bytes (u32 index + f32 value per kept entry).
pub struct TopK {
    /// Fraction of entries kept, in (0, 1].
    pub frac: f64,
}

impl TopK {
    /// Kept entries for a `p`-element message: `⌈frac·p⌉`, at least 1.
    pub fn k(&self, p: usize) -> usize {
        ((self.frac * p as f64).ceil() as usize).clamp(1, p.max(1))
    }
}

impl Compressor for TopK {
    fn label(&self) -> String {
        format!("topk@{:.2}", self.frac)
    }

    fn wire_bytes(&self, p: usize) -> u64 {
        8 * self.k(p) as u64
    }

    fn encode_into(&self, v: &[f32], _key: MsgKey, out: &mut Encoded) {
        let p = v.len();
        let k = self.k(p);
        let (mut order, mut val) = match std::mem::replace(out, Encoded::Dense(Vec::new())) {
            Encoded::TopK { idx, val, .. } => (idx, val),
            _ => (Vec::new(), Vec::new()),
        };
        order.clear();
        order.extend(0..p as u32);
        // strict total order: |v| descending, index ascending on ties (and a
        // total_cmp so non-finite values cannot panic the sort)
        let by_mag = |&a: &u32, &b: &u32| {
            v[b as usize]
                .abs()
                .total_cmp(&v[a as usize].abs())
                .then(a.cmp(&b))
        };
        if k < p {
            order.select_nth_unstable_by(k - 1, by_mag);
            order.truncate(k);
        }
        order.sort_unstable();
        val.clear();
        val.extend(order.iter().map(|&i| v[i as usize]));
        *out = Encoded::TopK { len: p as u32, idx: order, val };
    }
}

// ----------------------------------------------------- error feedback ----

/// `vbuf ← x + e`: the error-compensated message of EF-SGD/CHOCO-SGD.  Both
/// drivers build the outgoing vector through this one helper so the f32
/// arithmetic (and therefore the trajectory) is bitwise-identical.
pub fn add_residual(x: &[f32], e: &[f32], vbuf: &mut [f32]) {
    for ((o, &a), &b) in vbuf.iter_mut().zip(x).zip(e) {
        *o = a + b;
    }
}

/// `e_out ← v − x̂`: the residual the next round re-injects (the compression
/// error that would otherwise be lost).  Shared by both drivers.
pub fn residual_update(v: &[f32], xhat: &[f32], e_out: &mut [f32]) {
    for ((o, &a), &b) in e_out.iter_mut().zip(v).zip(xhat) {
        *o = a - b;
    }
}

// ------------------------------------------------------------- config ----

/// Parsed `comm.compress` config value — which compressor a run gossips
/// through (`None` = the uncompressed fast path, zero new work per round).
#[derive(Clone, Debug, PartialEq)]
pub enum Spec {
    /// No compression: the pre-existing dense kernels, untouched.
    None,
    /// Ship dense f32 through the compressed machinery (test pin).
    Identity,
    /// 8-bit absmax quantization.
    Q8,
    /// 4-bit absmax quantization.
    Q4,
    /// Magnitude top-k with the given kept fraction.
    TopK {
        /// Fraction of entries kept, in (0, 1].
        frac: f64,
    },
}

impl Spec {
    /// Parse a `comm.compress` / `--compress` value; `topk_frac` shapes the
    /// top-k arm.
    pub fn parse(name: &str, topk_frac: f64) -> Result<Spec> {
        Ok(match name {
            "none" => Spec::None,
            "identity" => Spec::Identity,
            "q8" => Spec::Q8,
            "q4" => Spec::Q4,
            "topk" | "top-k" => {
                if !(topk_frac > 0.0 && topk_frac <= 1.0) {
                    bail!("topk_frac must be in (0, 1], got {topk_frac}");
                }
                Spec::TopK { frac: topk_frac }
            }
            other => bail!("unknown compressor `{other}` (none|identity|q8|q4|topk)"),
        })
    }

    /// Is this the uncompressed fast path?
    pub fn is_none(&self) -> bool {
        *self == Spec::None
    }

    /// Instantiate the compressor (`None` for the uncompressed fast path).
    pub fn build(&self) -> Option<Box<dyn Compressor>> {
        match self {
            Spec::None => None,
            Spec::Identity => Some(Box::new(Identity)),
            Spec::Q8 => Some(Box::new(QuantizeQ8)),
            Spec::Q4 => Some(Box::new(QuantizeQ4)),
            Spec::TopK { frac } => Some(Box::new(TopK { frac: *frac })),
        }
    }

    /// Display label (experiment tables, logs).
    pub fn label(&self) -> String {
        match self {
            Spec::None => "none".into(),
            other => other.build().unwrap().label(),
        }
    }
}

/// Per-run gossip-compression context a communication strategy (or a node
/// actor) carries: the compressor, whether error feedback is on, and the run
/// seed the message keys derive from.
pub struct GossipComm {
    /// The compressor, or `None` for the uncompressed fast path.
    pub comp: Option<Box<dyn Compressor>>,
    /// Opt-in error-feedback residuals (`comm.error_feedback`; default
    /// false — see the module docs).
    pub error_feedback: bool,
    /// Run seed — [`MsgKey`]s are `(seed, round, node, kind)`.
    pub seed: u64,
}

impl GossipComm {
    /// Build from a validated config.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<GossipComm> {
        Ok(GossipComm {
            comp: Spec::parse(&cfg.compress, cfg.topk_frac)?.build(),
            error_feedback: cfg.error_feedback,
            seed: cfg.seed,
        })
    }

    /// The uncompressed context (baseline strategies, tests).
    pub fn none(seed: u64) -> GossipComm {
        GossipComm { comp: None, error_feedback: false, seed }
    }

    /// Is a compressor active (i.e. must the compressed code path run)?
    pub fn enabled(&self) -> bool {
        self.comp.is_some()
    }

    /// Wire bytes of one `p`-element gossip message under this context
    /// (dense f32 when uncompressed).
    pub fn msg_bytes(&self, p: usize) -> u64 {
        match &self.comp {
            Some(c) => c.wire_bytes(p),
            None => 4 * p as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(round: usize, node: usize) -> MsgKey {
        MsgKey::new(7, round, node, PayloadKind::Params)
    }

    fn sample_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed(seed);
        (0..n).map(|_| (rng.normal() * 0.5) as f32).collect()
    }

    #[test]
    fn identity_roundtrip_is_exact() {
        let v = sample_vec(33, 1);
        let enc = Identity.encode(&v, key(1, 0));
        assert_eq!(enc.wire_bytes(), 4 * 33);
        let mut out = vec![0.0f32; 33];
        decode_into(&enc, &mut out).unwrap();
        assert_eq!(out, v);
    }

    #[test]
    fn encode_is_deterministic_in_key_and_varies_across_keys() {
        let v = sample_vec(64, 2);
        for comp in [&QuantizeQ8 as &dyn Compressor, &QuantizeQ4, &TopK { frac: 0.2 }, &Identity] {
            let a = comp.encode(&v, key(3, 1));
            let b = comp.encode(&v, key(3, 1));
            assert_eq!(a, b, "{}: same key must give the identical message", comp.label());
        }
        // quantizers draw rounding noise from the key: round/node/kind move it
        let a = QuantizeQ8.encode(&v, key(3, 1));
        assert_ne!(a, QuantizeQ8.encode(&v, key(4, 1)), "round must move the noise");
        assert_ne!(a, QuantizeQ8.encode(&v, key(3, 2)), "node must move the noise");
        let tk = MsgKey::new(7, 3, 1, PayloadKind::Tracker);
        assert_ne!(a, QuantizeQ8.encode(&v, tk), "payload kind must move the noise");
    }

    #[test]
    fn encode_into_reuses_buffers_without_changing_a_byte() {
        // the warm path feeds the previous round's message back in as the
        // output buffer; salvaged capacity must never leak into the new
        // message — whatever variant the buffer held before
        let comps: [&dyn Compressor; 4] =
            [&Identity, &QuantizeQ8, &QuantizeQ4, &TopK { frac: 0.3 }];
        for c in comps {
            for seed in 0..4u64 {
                let prev = sample_vec(40, seed * 2 + 100);
                let v = sample_vec(24, seed * 2 + 101);
                for stale in comps {
                    // a stale message from ANY compressor (variant mismatch
                    // forces the fallback path) and from the same one
                    // (variant match exercises the salvage path)
                    let mut out = stale.encode(&prev, key(1, 0));
                    c.encode_into(&v, key(2, 1), &mut out);
                    assert_eq!(
                        out,
                        c.encode(&v, key(2, 1)),
                        "{} reusing a {} buffer",
                        c.label(),
                        stale.label()
                    );
                }
                // zero vector through a dirty same-variant buffer
                let zeros = vec![0.0f32; 24];
                let mut out = c.encode(&prev, key(3, 0));
                c.encode_into(&zeros, key(3, 1), &mut out);
                assert_eq!(out, c.encode(&zeros, key(3, 1)), "{} zero reuse", c.label());
            }
        }
    }

    #[test]
    fn q8_error_bounded_by_one_step() {
        let v = sample_vec(200, 3);
        let enc = QuantizeQ8.encode(&v, key(1, 0));
        let scale = match &enc {
            Encoded::Q8 { scale, .. } => *scale,
            _ => unreachable!(),
        };
        let mut out = vec![0.0f32; v.len()];
        decode_into(&enc, &mut out).unwrap();
        for (&x, &xh) in v.iter().zip(&out) {
            assert!((x - xh).abs() <= scale * 1.0001, "{x} vs {xh} (scale {scale})");
        }
    }

    #[test]
    fn q4_roundtrip_odd_and_even_lengths() {
        for n in [1usize, 2, 7, 8, 33] {
            let v = sample_vec(n, n as u64);
            let enc = QuantizeQ4.encode(&v, key(2, 0));
            assert_eq!(enc.wire_bytes(), QuantizeQ4.wire_bytes(n));
            let scale = match &enc {
                Encoded::Q4 { scale, .. } => *scale,
                _ => unreachable!(),
            };
            let mut out = vec![0.0f32; n];
            decode_into(&enc, &mut out).unwrap();
            for (&x, &xh) in v.iter().zip(&out) {
                assert!((x - xh).abs() <= scale * 1.0001, "n={n}: {x} vs {xh}");
            }
        }
    }

    #[test]
    fn stochastic_rounding_is_unbiased_on_average() {
        // average the decode over many message keys: must approach the input
        let v = sample_vec(16, 9);
        let mut acc = vec![0.0f64; v.len()];
        let rounds = 4000;
        for r in 1..=rounds {
            let enc = QuantizeQ8.encode(&v, key(r, 0));
            let mut out = vec![0.0f32; v.len()];
            decode_into(&enc, &mut out).unwrap();
            for (a, &x) in acc.iter_mut().zip(&out) {
                *a += x as f64;
            }
        }
        let amax = v.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let tol = 3.0 * (amax as f64 / 127.0) / (rounds as f64).sqrt() + 1e-6;
        for (&x, &mean) in v.iter().zip(&acc) {
            let m = mean / rounds as f64;
            assert!((m - x as f64).abs() < tol, "{x} vs mean {m} (tol {tol})");
        }
    }

    #[test]
    fn topk_keeps_the_largest_magnitudes_ascending_indices() {
        let v = vec![0.1f32, -5.0, 0.0, 3.0, -0.2, 3.0];
        let c = TopK { frac: 0.5 };
        assert_eq!(c.k(6), 3);
        let enc = c.encode(&v, key(1, 0));
        match &enc {
            Encoded::TopK { idx, val, len } => {
                assert_eq!(*len, 6);
                // |−5| > |3| = |3| (tie → lower index wins)
                assert_eq!(idx, &[1, 3, 5]);
                assert_eq!(val, &[-5.0, 3.0, 3.0]);
            }
            _ => unreachable!(),
        }
        let mut out = vec![9.0f32; 6];
        decode_into(&enc, &mut out).unwrap();
        assert_eq!(out, vec![0.0, -5.0, 0.0, 3.0, 0.0, 3.0]);
    }

    #[test]
    fn topk_tie_break_is_deterministic_across_orderings() {
        // all-equal magnitudes: the kept set must be the lowest indices
        let v = vec![1.0f32; 10];
        let enc = TopK { frac: 0.3 }.encode(&v, key(1, 0));
        match enc {
            Encoded::TopK { idx, .. } => assert_eq!(idx, vec![0, 1, 2]),
            _ => unreachable!(),
        }
    }

    #[test]
    fn wire_bytes_contract_holds_for_every_compressor() {
        for p in [1usize, 2, 31, 64, 1409] {
            let v = sample_vec(p, p as u64);
            let comps: Vec<Box<dyn Compressor>> = vec![
                Box::new(Identity),
                Box::new(QuantizeQ8),
                Box::new(QuantizeQ4),
                Box::new(TopK { frac: 0.1 }),
                Box::new(TopK { frac: 1.0 }),
            ];
            for c in &comps {
                let enc = c.encode(&v, key(1, 0));
                assert_eq!(
                    enc.wire_bytes(),
                    c.wire_bytes(p),
                    "{} at p={p}: encoded size must match the analytic size",
                    c.label()
                );
                assert_eq!(enc.decoded_len(), p);
            }
        }
    }

    #[test]
    fn zero_vector_encodes_to_zero() {
        let v = vec![0.0f32; 9];
        for c in [&QuantizeQ8 as &dyn Compressor, &QuantizeQ4] {
            let enc = c.encode(&v, key(1, 0));
            let mut out = vec![1.0f32; 9];
            decode_into(&enc, &mut out).unwrap();
            assert_eq!(out, v, "{}", c.label());
        }
    }

    #[test]
    fn malformed_messages_error_loudly_instead_of_panicking() {
        let mut out = vec![0.0f32; 8];
        // wrong decode-buffer length for every variant
        let v = sample_vec(9, 1);
        for c in
            [&Identity as &dyn Compressor, &QuantizeQ8, &QuantizeQ4, &TopK { frac: 0.5 }]
        {
            let enc = c.encode(&v, key(1, 0));
            assert!(decode_into(&enc, &mut out).is_err(), "{}: buffer mismatch", c.label());
        }
        // top-k: out-of-range index, unsorted/duplicate indices, idx/val skew,
        // and a kept count above the decoded length
        let bad = [
            Encoded::TopK { len: 8, idx: vec![0, 8], val: vec![1.0, 2.0] },
            Encoded::TopK { len: 8, idx: vec![3, 1], val: vec![1.0, 2.0] },
            Encoded::TopK { len: 8, idx: vec![2, 2], val: vec![1.0, 2.0] },
            Encoded::TopK { len: 8, idx: vec![0, 1], val: vec![1.0] },
            Encoded::TopK { len: 8, idx: (0..9).collect(), val: vec![1.0; 9] },
        ];
        for enc in &bad {
            assert!(decode_into(enc, &mut out).is_err(), "{enc:?} must be rejected");
        }
        // quantizers: truncated code buffers
        assert!(decode_into(&Encoded::Q8 { scale: 1.0, codes: vec![0; 7] }, &mut out).is_err());
        assert!(
            decode_into(&Encoded::Q4 { scale: 1.0, len: 8, codes: vec![0x88; 3] }, &mut out)
                .is_err()
        );
    }

    #[test]
    fn non_finite_payloads_decode_but_classify_as_poisoned() {
        // a NaN/Inf scale is well-formed wire data (an attacked q8 message
        // produces exactly this): decode must succeed — the ingest guard, not
        // the decoder, quarantines it — and is_finite() must flag it without
        // decoding
        let mut out = vec![0.0f32; 8];
        let q8 = Encoded::Q8 { scale: f32::NAN, codes: vec![1; 8] };
        assert!(!q8.is_finite());
        decode_into(&q8, &mut out).unwrap();
        assert!(out.iter().all(|v| !v.is_finite()));
        let q4 = Encoded::Q4 { scale: f32::INFINITY, len: 8, codes: vec![0x11; 4] };
        assert!(!q4.is_finite());
        decode_into(&q4, &mut out).unwrap();
        assert!(out.iter().any(|v| !v.is_finite()));
        let tk = Encoded::TopK { len: 8, idx: vec![2], val: vec![f32::NEG_INFINITY] };
        assert!(!tk.is_finite());
        decode_into(&tk, &mut out).unwrap();
        assert!(out[2].is_infinite() && out[0] == 0.0);
        assert!(!Encoded::Dense(vec![0.0, f32::NAN]).is_finite());
        // and the payload-level check agrees with the decoded-vector scan on
        // honest messages too
        let v = sample_vec(8, 5);
        for c in [&Identity as &dyn Compressor, &QuantizeQ8, &QuantizeQ4, &TopK { frac: 0.5 }] {
            let enc = c.encode(&v, key(1, 0));
            assert!(enc.is_finite(), "{}", c.label());
        }
    }

    #[test]
    fn mutated_wire_buffers_never_panic_property() {
        // adversarial fuzz: take honest messages and mutate one structural
        // field at a time — every decode must return (Ok or Err), not panic
        let mut rng = Pcg64::seed(99);
        for trial in 0..200u64 {
            let p = 1 + (rng.next_u64() % 40) as usize;
            let v = sample_vec(p, trial);
            let comps: [&dyn Compressor; 4] =
                [&Identity, &QuantizeQ8, &QuantizeQ4, &TopK { frac: 0.3 }];
            let c = comps[(rng.next_u64() % 4) as usize];
            let mut enc = c.encode(&v, key(trial as usize + 1, 0));
            match &mut enc {
                Encoded::Dense(d) => {
                    if !d.is_empty() {
                        d.truncate(d.len() - 1);
                    }
                }
                Encoded::TopK { len, idx, val } => match rng.next_u64() % 4 {
                    0 => {
                        if let Some(i) = idx.last_mut() {
                            *i = *len + (rng.next_u64() % 5) as u32;
                        }
                    }
                    1 => idx.reverse(),
                    2 => val.push(0.0),
                    _ => *len = len.saturating_sub(1),
                },
                Encoded::Q8 { scale, codes } => match rng.next_u64() % 3 {
                    0 => codes.truncate(codes.len().saturating_sub(1)),
                    1 => codes.push(0),
                    _ => *scale = f32::NAN,
                },
                Encoded::Q4 { scale, len, codes } => match rng.next_u64() % 3 {
                    0 => codes.push(0),
                    1 => *len += 3,
                    _ => *scale = f32::INFINITY,
                },
            }
            let mut out = vec![0.0f32; p];
            let _ = decode_into(&enc, &mut out); // must not panic
        }
    }

    #[test]
    fn residual_helpers_do_the_ef_arithmetic() {
        let x = vec![1.0f32, 2.0, -3.0];
        let e = vec![0.5f32, -0.25, 0.0];
        let mut v = vec![0.0f32; 3];
        add_residual(&x, &e, &mut v);
        assert_eq!(v, vec![1.5, 1.75, -3.0]);
        let xhat = vec![1.0f32, 2.0, -3.0];
        let mut e2 = vec![0.0f32; 3];
        residual_update(&v, &xhat, &mut e2);
        assert_eq!(e2, vec![0.5, -0.25, 0.0]);
    }

    #[test]
    fn ef_recursion_identity_leaves_zero_residual() {
        // with Identity the decode is exact, so the EF residual stays zero
        let x = sample_vec(12, 4);
        let e = vec![0.0f32; 12];
        let mut v = vec![0.0f32; 12];
        add_residual(&x, &e, &mut v);
        let enc = Identity.encode(&v, key(1, 0));
        let mut xhat = vec![0.0f32; 12];
        decode_into(&enc, &mut xhat).unwrap();
        let mut e2 = vec![1.0f32; 12];
        residual_update(&v, &xhat, &mut e2);
        assert!(e2.iter().all(|&r| r == 0.0));
    }

    #[test]
    fn spec_parse_build_and_labels() {
        assert!(Spec::parse("none", 0.1).unwrap().is_none());
        assert_eq!(Spec::parse("identity", 0.1).unwrap(), Spec::Identity);
        assert_eq!(Spec::parse("q8", 0.1).unwrap(), Spec::Q8);
        assert_eq!(Spec::parse("q4", 0.1).unwrap(), Spec::Q4);
        assert_eq!(Spec::parse("topk", 0.05).unwrap(), Spec::TopK { frac: 0.05 });
        assert_eq!(Spec::parse("topk", 0.05).unwrap().label(), "topk@0.05");
        assert!(Spec::parse("topk", 0.0).is_err());
        assert!(Spec::parse("topk", 1.5).is_err());
        assert!(Spec::parse("gzip", 0.1).is_err());
        assert!(Spec::parse("none", 0.1).unwrap().build().is_none());
        assert_eq!(Spec::parse("q4", 0.1).unwrap().label(), "q4");
    }

    #[test]
    fn gossip_comm_msg_bytes() {
        let none = GossipComm::none(7);
        assert!(!none.enabled());
        assert_eq!(none.msg_bytes(100), 400);
        let q4 = GossipComm { comp: Spec::Q4.build(), error_feedback: true, seed: 7 };
        assert_eq!(q4.msg_bytes(100), 4 + 50);
        // the headline reductions the compress experiment reports (p = 1409)
        let p = 1409usize;
        assert!(4 * p as u64 / QuantizeQ8.wire_bytes(p) >= 3);
        assert!(4 * p as u64 / QuantizeQ4.wire_bytes(p) >= 7);
        assert!(4 * p as u64 / TopK { frac: 0.05 }.wire_bytes(p) >= 9);
    }
}
