//! EXP-R1: Byzantine robustness — the accuracy-vs-attacker-fraction
//! frontier across robust combine rules × topologies.
//!
//! Every run on one topology shares the same dataset, base graph, mixing
//! matrix, seed, and round schedule; only the attacker fraction and the
//! combine rule vary, so each block isolates what an adversary costs each
//! defense.  The block always leads with the attack-free plain-mean
//! baseline — the paper's pinned trajectory — and the interesting read is
//! the collapse pattern: under sign-flip attacks the W-weighted mean is
//! dragged arbitrarily far (one poisoned row entry pollutes every
//! neighbor), while trimmed-mean and coordinate-wise median hold within a
//! couple of accuracy points up to their breakdown fraction.
//!
//! The attack plan (`sign-flip` by default), noise scale, replay age, and
//! any DP layer come from the config's `attack.*` / `dp.*` knobs and apply
//! uniformly to every attacked cell, so the frontier also answers "what
//! does clip+noise cost on top of the defense".

use crate::algo::RobustRule;
use crate::config::ExperimentConfig;
use crate::coordinator::{assemble, run_on, Assembled};
use crate::jsonl::{self, Json};
use anyhow::{bail, Result};

/// One (rule, attacker-fraction, topology) cell of the EXP-R1 frontier.
#[derive(Clone, Debug)]
pub struct RobustRow {
    /// Combine-rule label (`mean`, `trimmed 0.20`, `median`, `krum 0.20`).
    pub rule: String,
    /// Attack label (`none` for the baseline, else `sign-flip f=0.20`, …).
    pub attack: String,
    /// Attacker fraction (0 for the baseline row).
    pub attack_frac: f64,
    /// Base topology the block ran on.
    pub topology: String,
    /// Final record-weighted training loss.
    pub final_loss: f64,
    /// Final record-weighted training accuracy.
    pub final_accuracy: f64,
    /// Final consensus error.
    pub final_consensus: f64,
    /// Communication rounds run.
    pub comm_rounds: u64,
    /// Total bytes on the wire.
    pub bytes: u64,
    /// Neighbor payloads quarantined at ingest (non-finite after decode).
    pub quarantined: u64,
    /// Reported (ε, δ)-DP ε at the final eval (0 when `dp = off`).
    pub dp_epsilon: f64,
}

fn run_one(cfg: &ExperimentConfig, asm: &Assembled, topo: &str) -> Result<RobustRow> {
    cfg.validate()?;
    let rule = RobustRule::parse(&cfg.robust_rule, cfg.robust_trim)?.label();
    let attack = if cfg.attack_plan == "none" {
        "none".to_string()
    } else {
        format!("{} f={:.2}", cfg.attack_plan, cfg.attack_frac)
    };
    let log = run_on(cfg, asm)?;
    let last = log.rows.last().expect("run produced no metric rows");
    Ok(RobustRow {
        rule,
        attack,
        attack_frac: cfg.attack_frac,
        topology: topo.to_string(),
        final_loss: last.loss,
        final_accuracy: last.accuracy,
        final_consensus: last.consensus,
        comm_rounds: last.comm_rounds,
        bytes: last.bytes,
        quarantined: last.quarantined,
        dp_epsilon: last.dp_epsilon,
    })
}

/// Sweep combine rules × attacker fractions × topologies against the
/// attack-free plain-mean baseline.  The attack plan, noise scale, replay
/// age, and DP layer come from the config's `attack.*` / `dp.*` knobs; each
/// topology gets its own assembled base network and always leads with the
/// honest baseline row.
pub fn run(
    cfg: &ExperimentConfig,
    rules: &[String],
    fracs: &[f64],
    topos: &[String],
) -> Result<Vec<RobustRow>> {
    if cfg.attack_plan == "none" {
        bail!("EXP-R1 needs an attack plan; set attack.plan (sign-flip|scaled-noise|stale-replay)");
    }
    if fracs.iter().any(|&f| f <= 0.0) {
        bail!("the attack-free baseline row is always included; list only positive attacker fractions");
    }
    let mut rows = Vec::new();
    for topo in topos {
        let mut base = cfg.clone();
        base.topology = topo.clone();
        base.attack_plan = "none".into();
        base.attack_frac = 0.0;
        base.robust_rule = "mean".into();
        base.validate()?;
        let asm = assemble(&base)?;
        rows.push(run_one(&base, &asm, topo)?);
        for rule in rules {
            if !RobustRule::parse(rule, cfg.robust_trim)?.is_mean() {
                // the rule's own attack-free ceiling: robust combines
                // forfeit mean preservation, so they pay a rule cost even
                // with no adversary (drastic on low-degree graphs — a
                // median-of-3 cannot diffuse a monotone heterogeneity
                // profile); the frontier separates that structural cost
                // from what the attacker adds on top
                let mut h = base.clone();
                h.robust_rule = rule.clone();
                rows.push(run_one(&h, &asm, topo)?);
            }
            for &frac in fracs {
                let mut c = base.clone();
                c.attack_plan = cfg.attack_plan.clone();
                c.attack_frac = frac;
                c.robust_rule = rule.clone();
                rows.push(run_one(&c, &asm, topo)?);
            }
        }
    }
    Ok(rows)
}

/// Print the frontier table.
pub fn print_table(rows: &[RobustRow]) {
    println!("EXP-R1 — robust combine rules × attacker fractions × topologies");
    println!(
        "{:<14} {:<20} {:<10} {:>10} {:>8} {:>12} {:>11} {:>10}",
        "rule", "attack", "topology", "final_loss", "acc", "comm_rounds", "quarantined", "dp_eps"
    );
    for r in rows {
        println!(
            "{:<14} {:<20} {:<10} {:>10.4} {:>8.3} {:>12} {:>11} {:>10.3}",
            r.rule,
            r.attack,
            r.topology,
            r.final_loss,
            r.final_accuracy,
            r.comm_rounds,
            r.quarantined,
            r.dp_epsilon
        );
    }
}

/// Human-readable observations relative to each topology's attack-free
/// plain-mean baseline row and, where present, the rule's own attack-free
/// ceiling — the second delta isolates what the *adversary* costs a rule
/// from what the rule costs by itself (large on low-degree graphs).
pub fn findings(rows: &[RobustRow]) -> Vec<String> {
    let mut out = Vec::new();
    for r in rows.iter().filter(|r| r.attack != "none") {
        let Some(base) = rows
            .iter()
            .find(|b| b.attack == "none" && b.topology == r.topology)
        else {
            continue;
        };
        let own = rows
            .iter()
            .find(|b| b.attack == "none" && b.topology == r.topology && b.rule == r.rule)
            .unwrap_or(base);
        let acc_pts = 100.0 * (r.final_accuracy - base.final_accuracy);
        let own_pts = 100.0 * (r.final_accuracy - own.final_accuracy);
        let verdict = if !r.final_loss.is_finite() || acc_pts < -10.0 && own_pts < -10.0 {
            "collapsed"
        } else if acc_pts > -3.0 || own_pts > -3.0 {
            "held"
        } else {
            "degraded"
        };
        out.push(format!(
            "{} under {} on {}: accuracy {acc_pts:+.1} pts vs attack-free mean, \
             {own_pts:+.1} pts vs the rule's own attack-free ceiling ({verdict}), \
             {} payloads quarantined",
            r.rule, r.attack, r.topology, r.quarantined
        ));
    }
    out
}

/// JSON dump of the sweep.
pub fn rows_json(rows: &[RobustRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                jsonl::obj(vec![
                    ("rule", jsonl::s(&r.rule)),
                    ("attack", jsonl::s(&r.attack)),
                    ("attack_frac", jsonl::num(r.attack_frac)),
                    ("topology", jsonl::s(&r.topology)),
                    ("final_loss", jsonl::num(r.final_loss)),
                    ("final_accuracy", jsonl::num(r.final_accuracy)),
                    ("final_consensus", jsonl::num(r.final_consensus)),
                    ("comm_rounds", jsonl::num(r.comm_rounds as f64)),
                    ("bytes", jsonl::num(r.bytes as f64)),
                    ("quarantined", jsonl::num(r.quarantined as f64)),
                    ("dp_epsilon", jsonl::num(r.dp_epsilon)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoKind, Backend, Mode};

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = Backend::Native;
        cfg.mode = Mode::Fused;
        cfg.algo = AlgoKind::Dsgd;
        cfg.n = 8;
        cfg.hidden = 8;
        cfg.m = 8;
        cfg.q = 4;
        cfg.total_steps = 32;
        cfg.eval_every = 2;
        cfg.records_per_hospital = 60;
        cfg.attack_plan = "sign-flip".into();
        cfg
    }

    #[test]
    fn sweep_leads_with_attack_free_baseline_per_topology() {
        let rules = vec!["mean".to_string(), "median".to_string()];
        let fracs = vec![0.25];
        let topos = vec!["ring".to_string(), "er".to_string()];
        let rows = run(&tiny_cfg(), &rules, &fracs, &topos).unwrap();
        // per topology: mean/none baseline, mean attacked, median/none
        // ceiling, median attacked
        assert_eq!(rows.len(), 8);
        for topo in ["ring", "er"] {
            let block: Vec<_> = rows.iter().filter(|r| r.topology == topo).collect();
            assert_eq!(block.len(), 4, "{topo}");
            assert_eq!(block[0].attack, "none", "{topo} leads with the baseline");
            assert_eq!(block[0].rule, "mean");
            assert!(block[0].final_loss.is_finite());
            assert_eq!(block[1].attack, "sign-flip f=0.25");
            assert_eq!(block[2].attack, "none", "{topo}: the rule's own ceiling");
            assert_eq!(block[2].rule, "median");
            assert_eq!(block[3].attack, "sign-flip f=0.25");
            assert_eq!(block[3].rule, "median");
            for r in &block[1..] {
                assert_eq!(r.comm_rounds, block[0].comm_rounds);
                assert!(r.bytes > 0);
            }
        }
        assert_eq!(findings(&rows).len(), 4);
    }

    #[test]
    fn zero_fraction_and_missing_plan_are_rejected() {
        let err = run(
            &tiny_cfg(),
            &["mean".to_string()],
            &[0.0],
            &["ring".to_string()],
        )
        .unwrap_err();
        assert!(err.to_string().contains("baseline"), "{err}");

        let mut cfg = tiny_cfg();
        cfg.attack_plan = "none".into();
        let err = run(&cfg, &["mean".to_string()], &[0.25], &["ring".to_string()]).unwrap_err();
        assert!(err.to_string().contains("attack plan"), "{err}");
    }
}
