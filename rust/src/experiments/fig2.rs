//! EXP-F2: the paper's Figure 2 — convergence of DSGD, DSGT, FD-DSGD and
//! FD-DSGT *with respect to communication rounds* on the heterogeneous
//! hospital cohort (paper §3: N=20, m=20, Q=100, α_r = 0.02/√r).
//!
//! All four algorithms share one dataset, graph and mixing matrix; the FD
//! variants spend Q local steps per communication round, the classic ones
//! communicate every step.  The expected *shape* (paper): per communication
//! round the FD curves drop far faster, and DSGT ends at a smaller
//! optimality gap than DSGD on non-identical shards.

use crate::config::{AlgoKind, ExperimentConfig};
use crate::coordinator::{assemble, run_on, Assembled};
use crate::jsonl::{self, Json};
use crate::metrics::RunLog;
use anyhow::Result;

/// The four curves of Fig. 2, in paper order.
pub const FIG2_ALGOS: [AlgoKind; 4] =
    [AlgoKind::Dsgd, AlgoKind::Dsgt, AlgoKind::FdDsgd, AlgoKind::FdDsgt];

/// The four Fig. 2 curves plus the shared network's spectral gap.
pub struct Fig2Result {
    /// One metric log per algorithm, in [`FIG2_ALGOS`] order.
    pub logs: Vec<RunLog>,
    /// `1 − |λ₂|` of the shared mixing matrix.
    pub spectral_gap: f64,
}

/// Run the full Fig. 2 comparison.  `cfg.total_steps` bounds the *local
/// iteration* budget shared by every algorithm, so the classic variants get
/// the same number of gradient evaluations as the FD ones.
pub fn run(cfg: &ExperimentConfig) -> Result<Fig2Result> {
    let asm = assemble(cfg)?;
    run_with(cfg, &asm)
}

/// Run the Fig. 2 comparison on pre-assembled pieces (shared cohort).
pub fn run_with(cfg: &ExperimentConfig, asm: &Assembled) -> Result<Fig2Result> {
    let mut logs = Vec::with_capacity(FIG2_ALGOS.len());
    for algo in FIG2_ALGOS {
        let mut c = cfg.clone();
        c.algo = algo;
        // classic variants communicate every step: evaluating all of them is
        // O(total_steps) evals — thin the eval grid to keep runs comparable
        if algo.effective_q(c.q) == 1 {
            let fd_rounds = cfg.total_steps.div_ceil(cfg.q.max(1));
            c.eval_every = (cfg.total_steps / fd_rounds.max(1)).max(1) * cfg.eval_every.max(1);
        }
        logs.push(run_on(&c, asm)?);
    }
    Ok(Fig2Result { logs, spectral_gap: asm.spectral_gap })
}

impl Fig2Result {
    /// JSON dump of all four curves.
    pub fn to_json(&self) -> Json {
        jsonl::obj(vec![
            ("spectral_gap", jsonl::num(self.spectral_gap)),
            ("curves", Json::Arr(self.logs.iter().map(RunLog::to_json).collect())),
        ])
    }

    /// Print the series the paper plots, at a readable number of rows.
    pub fn print_table(&self) {
        println!("Fig.2 — convergence vs communication rounds (spectral gap {:.4})", self.spectral_gap);
        println!(
            "{:<10} {:>11} {:>12} {:>12} {:>14} {:>14} {:>12}",
            "algo", "comm_rounds", "local_steps", "loss", "stationarity", "consensus", "MBytes"
        );
        for log in &self.logs {
            let pick = pick_rows(&log.rows, 6);
            for r in pick {
                println!(
                    "{:<10} {:>11} {:>12} {:>12.5} {:>14.3e} {:>14.3e} {:>12.2}",
                    log.algo,
                    r.comm_rounds,
                    r.local_steps,
                    r.loss,
                    r.stationarity,
                    r.consensus,
                    r.bytes as f64 / 1e6
                );
            }
        }
    }

    /// The paper's qualitative claims, checked numerically.  Returns
    /// human-readable findings (used by the bench harness and EXPERIMENTS.md).
    pub fn findings(&self) -> Vec<String> {
        let by_name = |name: &str| self.logs.iter().find(|l| l.algo == name).unwrap();
        let dsgd = by_name("dsgd");
        let dsgt = by_name("dsgt");
        let fd_dsgd = by_name("fd-dsgd");
        let fd_dsgt = by_name("fd-dsgt");
        let mut out = Vec::new();

        // claim 1: at equal comm rounds, FD ≫ classic
        let budget = fd_dsgt.rows.last().unwrap().comm_rounds;
        let at = |log: &RunLog, rounds: u64| -> f64 {
            log.rows
                .iter()
                .filter(|r| r.comm_rounds <= rounds)
                .next_back()
                .unwrap()
                .loss
        };
        out.push(format!(
            "at {budget} comm rounds: FD-DSGT loss {:.4} vs DSGT {:.4} (ratio {:.2}x); \
             FD-DSGD {:.4} vs DSGD {:.4}",
            at(fd_dsgt, budget),
            at(dsgt, budget),
            at(dsgt, budget) / at(fd_dsgt, budget),
            at(fd_dsgd, budget),
            at(dsgd, budget),
        ));

        // claim 2: DSGT beats DSGD on optimality gap (non-identical data)
        out.push(format!(
            "final optimality gap: DSGT {:.3e} vs DSGD {:.3e}; FD-DSGT {:.3e} vs FD-DSGD {:.3e}",
            dsgt.rows.last().unwrap().optimality_gap(),
            dsgd.rows.last().unwrap().optimality_gap(),
            fd_dsgt.rows.last().unwrap().optimality_gap(),
            fd_dsgd.rows.last().unwrap().optimality_gap(),
        ));

        // claim 3: comm savings in bytes at equal local steps
        let steps = fd_dsgt.rows.last().unwrap().local_steps;
        let bytes_at = |log: &RunLog| {
            log.rows
                .iter()
                .filter(|r| r.local_steps <= steps)
                .next_back()
                .unwrap()
                .bytes as f64
                / 1e6
        };
        out.push(format!(
            "bytes to spend {steps} local steps: DSGT {:.1} MB vs FD-DSGT {:.1} MB \
             ({:.0}x saving)",
            bytes_at(dsgt),
            bytes_at(fd_dsgt),
            bytes_at(dsgt) / bytes_at(fd_dsgt).max(1e-9),
        ));
        out
    }
}

fn pick_rows(rows: &[crate::metrics::RoundMetrics], k: usize) -> Vec<&crate::metrics::RoundMetrics> {
    if rows.len() <= k {
        return rows.iter().collect();
    }
    (0..k)
        .map(|i| &rows[i * (rows.len() - 1) / (k - 1)])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Backend;

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = Backend::Native;
        cfg.n = 5;
        cfg.hidden = 8;
        cfg.m = 8;
        cfg.q = 10;
        cfg.total_steps = 200;
        cfg.eval_every = 1;
        cfg.records_per_hospital = 60;
        cfg.heterogeneity = 0.7;
        cfg
    }

    #[test]
    fn fig2_reproduces_paper_shape() {
        let res = run(&small_cfg()).unwrap();
        assert_eq!(res.logs.len(), 4);

        // every curve decreases
        for log in &res.logs {
            let first = log.rows.first().unwrap().loss;
            let last = log.rows.last().unwrap().loss;
            assert!(last < first, "{}: {first} -> {last}", log.algo);
        }

        // paper claim: FD-DSGT beats DSGT at equal comm rounds
        let find = |n: &str| res.logs.iter().find(|l| l.algo == n).unwrap();
        let budget = find("fd-dsgt").rows.last().unwrap().comm_rounds;
        let classic_at = find("dsgt")
            .rows
            .iter()
            .filter(|r| r.comm_rounds <= budget)
            .next_back()
            .unwrap()
            .loss;
        let fd_final = find("fd-dsgt").rows.last().unwrap().loss;
        assert!(
            fd_final < classic_at,
            "FD-DSGT {fd_final} should beat DSGT {classic_at} at {budget} rounds"
        );

        // findings render without panicking and mention the budget
        let f = res.findings();
        assert_eq!(f.len(), 3);
        assert!(f[0].contains("comm rounds"));
    }

    #[test]
    fn json_dump_has_four_curves() {
        let res = run(&small_cfg()).unwrap();
        let j = Json::parse(&res.to_json().to_string()).unwrap();
        assert_eq!(j.get("curves").unwrap().as_arr().unwrap().len(), 4);
    }
}
