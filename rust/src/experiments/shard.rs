//! EXP-SH1: node-state residency at scale — spill-backed sharded slabs vs
//! resident stacks, over fleet size.
//!
//! Every fleet size runs the same honest gossip config through the sharded
//! driver (`engine::shard::ShardedSync`) and reports the pool's measured
//! peak residency, spill traffic, and per-round wall time.  Up to
//! `compare_max` nodes the resident fused driver runs alongside and the two
//! metric trajectories are checked **bitwise** — above it the resident run
//! is skipped (that is the point: at 10⁵–10⁶ nodes the resident stacks do
//! not fit, while the sharded pool holds `hot_shards · shard_nodes` rows no
//! matter the fleet).  The headline scaling numbers for the README live in
//! `BENCH_9.json`; this harness is the in-repo, always-runnable miniature.

use crate::config::ExperimentConfig;
use crate::coordinator::{assemble, run_on};
use crate::engine::{QuantitySet, RoundEngine, ShardedSync};
use crate::jsonl::{self, Json};
use anyhow::{bail, Result};

/// One (fleet size, driver) cell of the EXP-SH1 residency sweep.
#[derive(Clone, Debug)]
pub struct ShardRow {
    /// Fleet size.
    pub n: usize,
    /// Driver label (`resident`, or `sharded k=<shard_nodes> h=<hot_shards>`).
    pub mode: String,
    /// Peak resident slab rows: `n` for the resident driver, at most
    /// `hot_shards · shard_nodes` for the sharded pool.
    pub resident_rows: usize,
    /// Peak resident slab bytes (`resident_rows · nq · p · 4`).
    pub slab_bytes: u64,
    /// Spill-file extent on disk (0 for the resident driver).
    pub spill_bytes: u64,
    /// Shard loads from the spill file.
    pub loads: u64,
    /// Frame evictions under hot-set pressure.
    pub spills: u64,
    /// Dirty evictions written back to the spill file (`≤ spills`).
    pub writebacks: u64,
    /// Pool acquires served by a resident frame.
    pub hits: u64,
    /// Wall-clock seconds per communication round.
    pub round_time_s: f64,
    /// Final record-weighted training loss.
    pub final_loss: f64,
    /// `Some(true)` iff the metric trajectory is bitwise identical to the
    /// resident run at this fleet size (`None` above `compare_max`, and for
    /// the resident rows themselves).
    pub matches_resident: Option<bool>,
}

/// Quantity rows per node under `cfg`'s axes — derived from the same
/// [`QuantitySet`] registration the sharded driver makes (θ front/back,
/// the DSGT pairs, decoded X̂/Ŷ rows, EF residuals, replay slots), so the
/// residency figures track exactly what the pool actually holds.
fn nq_of(cfg: &ExperimentConfig) -> Result<u64> {
    let (reg, _) = QuantitySet::for_config(cfg)?;
    Ok(reg.count() as u64)
}

/// Bitwise comparison of two metric trajectories: every evaluation row's
/// loss, accuracy, consensus, and stationarity must agree to the bit.
fn logs_bitwise_equal(a: &crate::metrics::RunLog, b: &crate::metrics::RunLog) -> bool {
    a.rows.len() == b.rows.len()
        && a.rows.iter().zip(&b.rows).all(|(x, y)| {
            x.comm_rounds == y.comm_rounds
                && x.loss.to_bits() == y.loss.to_bits()
                && x.accuracy.to_bits() == y.accuracy.to_bits()
                && x.consensus.to_bits() == y.consensus.to_bits()
                && x.stationarity.to_bits() == y.stationarity.to_bits()
        })
}

/// Sweep fleet sizes: one sharded row per `n` (using `cfg.shard_nodes` /
/// `cfg.hot_shards`; `shard_nodes = 0` defaults to 64), plus a resident
/// comparison row for every `n ≤ compare_max` with the bitwise verdict on
/// the sharded row.
pub fn run(cfg: &ExperimentConfig, ns: &[usize], compare_max: usize) -> Result<Vec<ShardRow>> {
    if ns.is_empty() {
        bail!("need at least one fleet size (--ns)");
    }
    let mut rows = Vec::new();
    for &n in ns {
        let mut c = cfg.clone();
        c.n = n;
        c.shard_nodes = if cfg.shard_nodes == 0 { 64 } else { cfg.shard_nodes };
        c.validate()?;
        let asm = assemble(&c)?;
        let p = crate::algo::native::NativeModel::new(c.d, c.hidden).p() as u64;
        let nq = nq_of(&c)?;

        // sharded run, driven directly so the pool counters stay readable
        let engine = RoundEngine::from_config(&c);
        let mut drv = ShardedSync::new(&c, &asm.ds, &asm.graph, &asm.w)?;
        engine.run(&mut drv)?;
        let stats = drv.pool_stats();
        let resident_rows = drv.resident_rows();
        let sharded_log = drv.into_log();
        let last = sharded_log.rows.last().expect("run produced no metric rows");
        let mut sharded = ShardRow {
            n,
            mode: format!("sharded k={} h={}", c.shard_nodes, c.hot_shards),
            resident_rows,
            slab_bytes: resident_rows as u64 * nq * p * 4,
            spill_bytes: (n.div_ceil(c.shard_nodes) * c.shard_nodes) as u64 * nq * p * 4,
            loads: stats.loads,
            spills: stats.spills,
            writebacks: stats.writebacks,
            hits: stats.hits,
            round_time_s: last.wall_time_s / (last.comm_rounds.max(1) as f64),
            final_loss: last.loss,
            matches_resident: None,
        };

        if n <= compare_max {
            let mut r = c.clone();
            r.shard_nodes = 0;
            let resident_log = run_on(&r, &asm)?;
            let rl = resident_log.rows.last().expect("run produced no metric rows");
            sharded.matches_resident = Some(logs_bitwise_equal(&sharded_log, &resident_log));
            rows.push(ShardRow {
                n,
                mode: "resident".into(),
                resident_rows: n,
                slab_bytes: n as u64 * nq * p * 4,
                spill_bytes: 0,
                loads: 0,
                spills: 0,
                writebacks: 0,
                hits: 0,
                round_time_s: rl.wall_time_s / (rl.comm_rounds.max(1) as f64),
                final_loss: rl.loss,
                matches_resident: None,
            });
        }
        rows.push(sharded);
    }
    Ok(rows)
}

/// Print the residency table.
pub fn print_table(rows: &[ShardRow]) {
    println!("EXP-SH1 — node-state residency: sharded spill-backed slabs vs resident stacks");
    println!(
        "{:<8} {:<20} {:>12} {:>12} {:>12} {:>8} {:>8} {:>8} {:>12} {:>10} {:>8}",
        "n", "mode", "res_rows", "slab_MB", "spill_MB", "loads", "spills", "wbacks", "round_s", "loss", "bitwise"
    );
    for r in rows {
        println!(
            "{:<8} {:<20} {:>12} {:>12.2} {:>12.2} {:>8} {:>8} {:>8} {:>12.4} {:>10.4} {:>8}",
            r.n,
            r.mode,
            r.resident_rows,
            r.slab_bytes as f64 / 1e6,
            r.spill_bytes as f64 / 1e6,
            r.loads,
            r.spills,
            r.writebacks,
            r.round_time_s,
            r.final_loss,
            match r.matches_resident {
                Some(true) => "==",
                Some(false) => "DIVERGED",
                None => "-",
            }
        );
    }
}

/// Human-readable observations: the hot-set bound and the bitwise verdicts.
pub fn findings(rows: &[ShardRow]) -> Vec<String> {
    let mut out = Vec::new();
    for r in rows.iter().filter(|r| r.mode != "resident") {
        if let Some(resident) = rows.iter().find(|s| s.mode == "resident" && s.n == r.n) {
            let ratio = resident.slab_bytes as f64 / r.slab_bytes.max(1) as f64;
            out.push(format!(
                "n={}: sharded slab residency {:.2} MB vs resident {:.2} MB ({ratio:.1}x), \
                 trajectories {}",
                r.n,
                r.slab_bytes as f64 / 1e6,
                resident.slab_bytes as f64 / 1e6,
                match r.matches_resident {
                    Some(true) => "bitwise identical".to_string(),
                    Some(false) => "DIVERGED — pinned contract broken".to_string(),
                    None => "not compared".to_string(),
                }
            ));
        } else {
            out.push(format!(
                "n={}: sharded slab residency {:.2} MB (resident would need {:.2} MB; \
                 not run at this size)",
                r.n,
                r.slab_bytes as f64 / 1e6,
                (r.n as u64 * (r.slab_bytes / r.resident_rows.max(1) as u64)) as f64 / 1e6,
            ));
        }
    }
    out
}

/// JSON dump of the sweep.
pub fn rows_json(rows: &[ShardRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                jsonl::obj(vec![
                    ("n", jsonl::num(r.n as f64)),
                    ("mode", jsonl::s(&r.mode)),
                    ("resident_rows", jsonl::num(r.resident_rows as f64)),
                    ("slab_bytes", jsonl::num(r.slab_bytes as f64)),
                    ("spill_bytes", jsonl::num(r.spill_bytes as f64)),
                    ("loads", jsonl::num(r.loads as f64)),
                    ("spills", jsonl::num(r.spills as f64)),
                    ("writebacks", jsonl::num(r.writebacks as f64)),
                    ("hits", jsonl::num(r.hits as f64)),
                    ("round_time_s", jsonl::num(r.round_time_s)),
                    ("final_loss", jsonl::num(r.final_loss)),
                    (
                        "matches_resident",
                        match r.matches_resident {
                            Some(b) => Json::Bool(b),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoKind, Backend, Mode};

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = Backend::Native;
        cfg.mode = Mode::Fused;
        cfg.algo = AlgoKind::FdDsgt;
        cfg.hidden = 8;
        cfg.m = 8;
        cfg.q = 5;
        cfg.total_steps = 40;
        cfg.eval_every = 2;
        cfg.records_per_hospital = 40;
        cfg.records_jitter = 5;
        cfg.shard_nodes = 3;
        cfg.hot_shards = 2;
        cfg
    }

    #[test]
    fn sweep_reports_bitwise_match_and_bounded_residency() {
        let rows = run(&tiny_cfg(), &[8, 12], 8).unwrap();
        // n=8 compared (resident + sharded rows), n=12 sharded only
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].mode, "resident");
        assert_eq!(rows[1].matches_resident, Some(true), "pinned contract broken");
        assert_eq!(rows[2].matches_resident, None);
        for r in rows.iter().filter(|r| r.mode != "resident") {
            assert!(r.resident_rows <= 2 * 3, "hot-set bound: {}", r.resident_rows);
            assert!(r.loads > 0, "a 2-frame pool over >2 shards must load");
            assert!(r.writebacks <= r.spills, "clean evictions cost no I/O");
            assert!(r.final_loss.is_finite());
        }
        // residency stays flat as n grows — that is the whole experiment
        assert_eq!(rows[1].slab_bytes, rows[2].slab_bytes);
        let f = findings(&rows);
        assert_eq!(f.len(), 2);
        assert!(f[0].contains("bitwise identical"), "{}", f[0]);
        let json = rows_json(&rows).to_string();
        assert!(json.contains("\"matches_resident\""), "{json}");
    }

    #[test]
    fn nq_tracks_registered_quantities() {
        // the residency math follows the quantity registry: compression
        // and EF add pooled rows, and the table must bill for them
        let mut cfg = tiny_cfg();
        assert_eq!(nq_of(&cfg).unwrap(), 6, "fd-dsgt: θ/ϑ/G front+back");
        cfg.compress = "q8".into();
        cfg.error_feedback = true;
        assert_eq!(nq_of(&cfg).unwrap(), 10, "+ X̂/Ŷ + EF residual pair");
    }

    #[test]
    fn empty_fleet_list_is_rejected() {
        let err = run(&tiny_cfg(), &[], 0).unwrap_err();
        assert!(err.to_string().contains("fleet size"), "{err}");
    }
}
