//! EXP-N1: time-varying networks — the dynamic `NetPlan`s against the
//! static baseline on ONE assembled base network and cohort.
//!
//! Every run shares the same dataset, base graph, mixing matrix, seed, and
//! round schedule; only `net.plan` varies, so the table isolates what the
//! network dynamics cost (or save): final loss / consensus, bytes on the
//! wire, and simulated wall time.  Byte accounting is exact on lossless
//! links in both execution modes — the analytic accountant charges each
//! round's *active* edges, matching the channel netsim message for message
//! (pinned by `tests/driver_equivalence.rs`).

use crate::config::ExperimentConfig;
use crate::coordinator::{assemble, run_on, Assembled};
use crate::graph::Topology;
use crate::jsonl::{self, Json};
use anyhow::Result;

/// One network plan's outcome on the shared base network.
#[derive(Clone, Debug)]
pub struct ChurnRow {
    /// Plan label (`static`, `rewire@5`, `edge-drop 0.30`, ...).
    pub plan: String,
    /// Final training loss.
    pub final_loss: f64,
    /// Final consensus error.
    pub final_consensus: f64,
    /// Communication rounds run.
    pub comm_rounds: u64,
    /// Total bytes on the wire.
    pub bytes: u64,
    /// Simulated wall time, seconds.
    pub sim_time_s: f64,
}

fn run_one(cfg: &ExperimentConfig, asm: &Assembled, label: &str) -> Result<ChurnRow> {
    cfg.validate()?;
    let log = run_on(cfg, asm)?;
    let last = log.rows.last().expect("run produced no metric rows");
    Ok(ChurnRow {
        plan: label.to_string(),
        final_loss: last.loss,
        final_consensus: last.consensus,
        comm_rounds: last.comm_rounds,
        bytes: last.bytes,
        sim_time_s: last.sim_time_s,
    })
}

/// Sweep the dynamic plans against the static baseline.  `drops` and
/// `churns` are the edge-drop / node-offline probabilities to try; the
/// rewire cadence comes from `cfg.rewire_every`.
pub fn run(cfg: &ExperimentConfig, drops: &[f64], churns: &[f64]) -> Result<Vec<ChurnRow>> {
    let mut stat = cfg.clone();
    stat.net_plan = "static".into();
    stat.validate()?;
    let asm = assemble(&stat)?;

    let mut rows = vec![run_one(&stat, &asm, "static")?];
    if Topology::parse(&stat.topology)?.is_randomized() {
        let mut rw = stat.clone();
        rw.net_plan = "rewire".into();
        rows.push(run_one(&rw, &asm, &format!("rewire@{}", rw.rewire_every))?);
    } else {
        // rewiring a deterministic family rebuilds the identical graph every
        // epoch — that row would just duplicate `static`, so say so loudly
        eprintln!(
            "note: skipping the rewire row — topology `{}` is deterministic, every \
             epoch would rebuild the identical graph (use er|rgg|smallworld|knn)",
            stat.topology
        );
    }
    for &p in drops {
        let mut c = stat.clone();
        c.net_plan = "edge-drop".into();
        c.edge_drop = p;
        rows.push(run_one(&c, &asm, &format!("edge-drop {p:.2}"))?);
    }
    for &p in churns {
        let mut c = stat.clone();
        c.net_plan = "churn".into();
        c.churn = p;
        rows.push(run_one(&c, &asm, &format!("churn {p:.2}"))?);
    }
    Ok(rows)
}

/// Print the plan-vs-static table.
pub fn print_table(rows: &[ChurnRow]) {
    println!("EXP-N1 — time-varying networks vs the static baseline (shared base graph)");
    println!(
        "{:<16} {:>12} {:>16} {:>12} {:>12} {:>12}",
        "plan", "final_loss", "final_consensus", "comm_rounds", "MBytes", "sim_time_s"
    );
    for r in rows {
        println!(
            "{:<16} {:>12.4} {:>16.4e} {:>12} {:>12.2} {:>12.2}",
            r.plan,
            r.final_loss,
            r.final_consensus,
            r.comm_rounds,
            r.bytes as f64 / 1e6,
            r.sim_time_s
        );
    }
}

/// Human-readable observations relative to the static row.
pub fn findings(rows: &[ChurnRow]) -> Vec<String> {
    let mut out = Vec::new();
    let Some(stat) = rows.iter().find(|r| r.plan == "static") else {
        return out;
    };
    for r in rows.iter().filter(|r| r.plan != "static") {
        let loss_pct = if stat.final_loss.abs() > 1e-12 {
            100.0 * (r.final_loss - stat.final_loss) / stat.final_loss
        } else {
            0.0
        };
        let bytes_pct = if stat.bytes > 0 {
            100.0 * (r.bytes as f64 - stat.bytes as f64) / stat.bytes as f64
        } else {
            0.0
        };
        out.push(format!(
            "{}: final loss {:+.1}% vs static, wire bytes {:+.1}%",
            r.plan, loss_pct, bytes_pct
        ));
    }
    out
}

/// JSON dump of the sweep.
pub fn rows_json(rows: &[ChurnRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                jsonl::obj(vec![
                    ("plan", jsonl::s(&r.plan)),
                    ("final_loss", jsonl::num(r.final_loss)),
                    ("final_consensus", jsonl::num(r.final_consensus)),
                    ("comm_rounds", jsonl::num(r.comm_rounds as f64)),
                    ("bytes", jsonl::num(r.bytes as f64)),
                    ("sim_time_s", jsonl::num(r.sim_time_s)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoKind, Backend, Mode};

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = Backend::Native;
        cfg.mode = Mode::Fused;
        cfg.algo = AlgoKind::FdDsgt;
        cfg.n = 5;
        cfg.hidden = 8;
        cfg.m = 8;
        cfg.q = 4;
        cfg.total_steps = 32;
        cfg.eval_every = 2;
        cfg.records_per_hospital = 60;
        cfg.rewire_every = 2; // topology stays the default randomized knn
        cfg
    }

    #[test]
    fn sweep_covers_all_plans_and_static_baseline() {
        let rows = run(&tiny_cfg(), &[0.3], &[0.3]).unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].plan, "static");
        assert!(rows.iter().any(|r| r.plan.starts_with("rewire@")));
        assert!(rows.iter().any(|r| r.plan.starts_with("edge-drop")));
        assert!(rows.iter().any(|r| r.plan.starts_with("churn")));
        for r in &rows {
            assert!(r.final_loss.is_finite(), "{}", r.plan);
            assert!(r.bytes > 0, "{}", r.plan);
            assert_eq!(r.comm_rounds, 8, "{}", r.plan);
        }
        // findings compare every dynamic plan to static
        assert_eq!(findings(&rows).len(), 3);
    }

    #[test]
    fn rewire_row_skipped_for_deterministic_family() {
        let mut cfg = tiny_cfg();
        cfg.topology = "ring".into();
        let rows = run(&cfg, &[], &[]).unwrap();
        assert_eq!(rows.len(), 1, "only the static row");
        assert_eq!(rows[0].plan, "static");
    }

    #[test]
    fn dynamic_rounds_never_cost_more_bytes_than_static() {
        let rows = run(&tiny_cfg(), &[0.4], &[0.3]).unwrap();
        let stat = rows[0].bytes;
        for r in &rows[1..] {
            if r.plan.starts_with("edge-drop") || r.plan.starts_with("churn") {
                assert!(r.bytes <= stat, "{}: {} > static {stat}", r.plan, r.bytes);
            }
        }
    }
}
