//! EXP-C1: the accuracy-vs-bytes frontier — gossip compressors × topologies
//! on ONE shared cohort per topology.
//!
//! Every row trains the same algorithm, schedule, and seed; only the
//! compressor (and the base topology) varies, so the table isolates what
//! lossy messaging costs in final loss/accuracy against what it saves on the
//! wire.  The `none` row of each topology is the dense-f32 anchor the
//! reduction factors and accuracy deltas are measured against.  Byte counts
//! are the analytic accountant's *encoded* charges, which match the channel
//! netsim message for message (pinned by `tests/driver_equivalence.rs`).

use crate::config::ExperimentConfig;
use crate::coordinator::{assemble, run_on};
use crate::jsonl::{self, Json};
use anyhow::Result;

/// One (topology, compressor) cell of the frontier.
#[derive(Clone, Debug)]
pub struct CompressRow {
    /// Base topology of this arm.
    pub topology: String,
    /// Compressor label (`none`, `q8`, `q4`, `topk@0.05`, ...).
    pub compressor: String,
    /// Final training loss.
    pub final_loss: f64,
    /// Final training accuracy.
    pub final_acc: f64,
    /// Final consensus error.
    pub final_consensus: f64,
    /// Communication rounds run.
    pub comm_rounds: u64,
    /// Total bytes on the wire (encoded sizes).
    pub bytes: u64,
    /// Dense-f32 bytes of the same topology's `none` row (the anchor).
    pub dense_bytes: u64,
}

impl CompressRow {
    /// Bytes-on-wire reduction factor vs the dense anchor (1.0 for `none`).
    pub fn reduction(&self) -> f64 {
        if self.bytes == 0 {
            return 1.0;
        }
        self.dense_bytes as f64 / self.bytes as f64
    }
}

fn run_one(
    cfg: &ExperimentConfig,
    topology: &str,
    compressor: &str,
    topk_frac: f64,
) -> Result<CompressRow> {
    let mut c = cfg.clone();
    c.topology = topology.to_string();
    c.compress = compressor.to_string();
    c.topk_frac = topk_frac;
    c.validate()?;
    let asm = assemble(&c)?;
    let log = run_on(&c, &asm)?;
    let last = log.rows.last().expect("run produced no metric rows");
    let label = crate::compress::Spec::parse(&c.compress, c.topk_frac)?.label();
    Ok(CompressRow {
        topology: topology.to_string(),
        compressor: label,
        final_loss: last.loss,
        final_acc: last.accuracy,
        final_consensus: last.consensus,
        comm_rounds: last.comm_rounds,
        bytes: last.bytes,
        dense_bytes: 0, // filled by the caller from the `none` anchor
    })
}

/// Sweep `compressors` (plus one top-k arm per entry of `fracs`) against the
/// dense baseline on every topology of `topos`.  The same cohort, seed, and
/// round schedule back every row of one topology.
pub fn run(
    cfg: &ExperimentConfig,
    compressors: &[String],
    fracs: &[f64],
    topos: &[String],
) -> Result<Vec<CompressRow>> {
    let mut rows = Vec::new();
    for topo in topos {
        let anchor = run_one(cfg, topo, "none", cfg.topk_frac)?;
        let dense_bytes = anchor.bytes;
        let mut topo_rows = vec![anchor];
        for comp in compressors {
            if comp == "none" {
                continue; // the anchor row already covers it
            }
            if comp == "topk" || comp == "top-k" {
                continue; // the --fracs axis owns the top-k arms
            }
            topo_rows.push(run_one(cfg, topo, comp, cfg.topk_frac)?);
        }
        for &frac in fracs {
            topo_rows.push(run_one(cfg, topo, "topk", frac)?);
        }
        for r in &mut topo_rows {
            r.dense_bytes = dense_bytes;
        }
        rows.extend(topo_rows);
    }
    Ok(rows)
}

/// Print the frontier table.
pub fn print_table(rows: &[CompressRow]) {
    println!("EXP-C1 — accuracy-vs-bytes frontier (shared cohort per topology)");
    println!(
        "{:<10} {:<12} {:>10} {:>9} {:>14} {:>10} {:>10}",
        "topology", "compressor", "final_loss", "final_acc", "consensus", "MBytes", "reduction"
    );
    for r in rows {
        println!(
            "{:<10} {:<12} {:>10.4} {:>9.3} {:>14.4e} {:>10.2} {:>9.1}x",
            r.topology,
            r.compressor,
            r.final_loss,
            r.final_acc,
            r.final_consensus,
            r.bytes as f64 / 1e6,
            r.reduction()
        );
    }
}

/// Human-readable observations: per compressor, the wire savings and the
/// accuracy cost relative to the same topology's dense anchor.
pub fn findings(rows: &[CompressRow]) -> Vec<String> {
    let mut out = Vec::new();
    for r in rows.iter().filter(|r| r.compressor != "none") {
        let Some(anchor) = rows
            .iter()
            .find(|a| a.compressor == "none" && a.topology == r.topology)
        else {
            continue;
        };
        let acc_delta = 100.0 * (r.final_acc - anchor.final_acc);
        out.push(format!(
            "{} on {}: {:.1}x fewer bytes, accuracy {:+.2}% vs uncompressed",
            r.compressor,
            r.topology,
            r.reduction(),
            acc_delta
        ));
    }
    out
}

/// JSON dump of the frontier.
pub fn rows_json(rows: &[CompressRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                jsonl::obj(vec![
                    ("topology", jsonl::s(&r.topology)),
                    ("compressor", jsonl::s(&r.compressor)),
                    ("final_loss", jsonl::num(r.final_loss)),
                    ("final_acc", jsonl::num(r.final_acc)),
                    ("final_consensus", jsonl::num(r.final_consensus)),
                    ("comm_rounds", jsonl::num(r.comm_rounds as f64)),
                    ("bytes", jsonl::num(r.bytes as f64)),
                    ("reduction", jsonl::num(r.reduction())),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoKind, Backend, Mode};

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = Backend::Native;
        cfg.mode = Mode::Fused;
        cfg.algo = AlgoKind::FdDsgd;
        cfg.n = 5;
        cfg.hidden = 8;
        cfg.m = 8;
        cfg.q = 4;
        cfg.total_steps = 32;
        cfg.eval_every = 4;
        cfg.records_per_hospital = 60;
        cfg
    }

    #[test]
    fn frontier_covers_compressors_and_topologies() {
        let rows = run(
            &tiny_cfg(),
            &["q8".into(), "q4".into()],
            &[0.1],
            &["ring".into(), "er".into()],
        )
        .unwrap();
        // per topology: none + q8 + q4 + topk@0.1
        assert_eq!(rows.len(), 8);
        for topo in ["ring", "er"] {
            let anchor = rows
                .iter()
                .find(|r| r.topology == topo && r.compressor == "none")
                .unwrap();
            assert_eq!(anchor.reduction(), 1.0);
            for r in rows.iter().filter(|r| r.topology == topo && r.compressor != "none") {
                assert!(r.final_loss.is_finite(), "{}/{}", r.topology, r.compressor);
                assert!(r.bytes < anchor.bytes, "{}/{}", r.topology, r.compressor);
                assert!(r.reduction() > 3.0, "{}/{}: {}", r.topology, r.compressor, r.reduction());
                assert_eq!(r.comm_rounds, anchor.comm_rounds);
            }
        }
        // findings: one line per compressed row
        assert_eq!(findings(&rows).len(), 6);
    }

    #[test]
    fn topk_fracs_drive_the_frontier_ends() {
        let rows = run(&tiny_cfg(), &[], &[0.1, 0.05], &["ring".into()]).unwrap();
        assert_eq!(rows.len(), 3);
        let r10 = rows.iter().find(|r| r.compressor == "topk@0.10").unwrap();
        let r05 = rows.iter().find(|r| r.compressor == "topk@0.05").unwrap();
        assert!(r05.bytes < r10.bytes, "sparser top-k ships fewer bytes");
        assert!(r05.reduction() >= 8.0, "top-k 5% crosses the 8x mark: {}", r05.reduction());
    }
}
