//! EXP-AS1: the wall-clock-vs-accuracy frontier — synchronous barrier vs
//! asynchronous event-driven gossip under straggler compute plans.
//!
//! Every block on one topology shares the same dataset, base graph, mixing
//! matrix, seed, and compute plan; only `run.driver` (and the async
//! staleness cap) varies.  The sync row is the pinned oracle: its final
//! accuracy minus one point defines the *target*, and its total simulated
//! time defines the *budget* — each async row runs with
//! `sim_budget_s = sync.sim_time_s`, i.e. the barrier-free driver gets the
//! same simulated wall-clock the barriered run spent, not the same cycle
//! count.  That is the fair frontier: under a lognormal straggler plan the
//! synchronous barrier pays every round's slowest participant (Σ_r max_i)
//! while an async node only pays its own work, so in the same window the
//! fleet completes more (stale-mixed) cycles.  Each row reports the
//! simulated time at which its trajectory first reaches the target; the
//! headline comparison is that time against the sync run's full horizon
//! (matching accuracy with time to spare), with the ratio to sync's own
//! time-to-target reported alongside.

use crate::config::ExperimentConfig;
use crate::coordinator::{assemble, run_on, Assembled};
use crate::jsonl::{self, Json};
use anyhow::{bail, Result};

/// One (driver, staleness, topology) cell of the EXP-AS1 frontier.
#[derive(Clone, Debug)]
pub struct AsyncRow {
    /// Driver label (`sync`, or `async s=<cap>` / `async uncapped`).
    pub driver: String,
    /// Async staleness cap in simulated seconds (0 = uncapped; 0 for sync).
    pub staleness_s: f64,
    /// Base topology the block ran on.
    pub topology: String,
    /// Final record-weighted training loss.
    pub final_loss: f64,
    /// Final record-weighted training accuracy.
    pub final_accuracy: f64,
    /// Final consensus error.
    pub final_consensus: f64,
    /// Communication rounds (sync) or fleet-min cycles (async) completed.
    pub comm_rounds: u64,
    /// Total bytes on the wire.
    pub bytes: u64,
    /// Simulated wall-clock at the end of the run, seconds.
    pub sim_time_s: f64,
    /// Non-finite payloads quarantined at the combine boundary over the
    /// whole run (0 on honest convergent runs; nonzero is the audit trail
    /// that an exploding or adversarial message was dropped, not mixed).
    pub quarantined: u64,
    /// First simulated time at which accuracy reached the sync oracle's
    /// final accuracy − 1 point (NaN if the trajectory never got there).
    pub t_to_target_s: f64,
}

/// Driver label for a row.
fn label(driver: &str, staleness_s: f64) -> String {
    match driver {
        "sync" => "sync".into(),
        _ if staleness_s > 0.0 => format!("async s={staleness_s:.2}"),
        _ => "async uncapped".into(),
    }
}

/// Earliest `sim_time_s` whose checkpoint accuracy reaches `target`.
fn time_to(log: &crate::metrics::RunLog, target: f64) -> f64 {
    log.rows
        .iter()
        .find(|r| r.accuracy >= target)
        .map_or(f64::NAN, |r| r.sim_time_s)
}

fn run_one(
    cfg: &ExperimentConfig,
    asm: &Assembled,
    topo: &str,
    target: Option<f64>,
) -> Result<(AsyncRow, crate::metrics::RunLog)> {
    cfg.validate()?;
    let log = run_on(cfg, asm)?;
    let last = log.rows.last().expect("run produced no metric rows");
    let row = AsyncRow {
        driver: label(&cfg.driver, cfg.staleness_s),
        staleness_s: if cfg.driver == "sync" { 0.0 } else { cfg.staleness_s },
        topology: topo.to_string(),
        final_loss: last.loss,
        final_accuracy: last.accuracy,
        final_consensus: last.consensus,
        comm_rounds: last.comm_rounds,
        bytes: last.bytes,
        sim_time_s: last.sim_time_s,
        quarantined: last.quarantined,
        t_to_target_s: target.map_or(f64::NAN, |t| time_to(&log, t)),
    };
    Ok((row, log))
}

/// Sweep the driver axis: one sync oracle row per topology, then one async
/// row per staleness cap (seconds; 0 = uncapped), all sharing the assembled
/// base network, seed, and the config's compute plan.  Async rows run under
/// the matched simulated-time budget (`sim_budget_s = sync.sim_time_s`).
/// `t_to_target_s` is measured against each topology's own sync final
/// accuracy − 1 point (including for the sync row itself, so the speedup
/// reads off directly).
pub fn run(cfg: &ExperimentConfig, stalenesses: &[f64], topos: &[String]) -> Result<Vec<AsyncRow>> {
    if stalenesses.is_empty() {
        bail!("need at least one async staleness cap (0 = uncapped)");
    }
    let mut rows = Vec::new();
    for topo in topos {
        let mut base = cfg.clone();
        base.topology = topo.clone();
        base.driver = "sync".into();
        base.staleness_s = 0.0;
        base.validate()?;
        let asm = assemble(&base)?;
        // oracle first: its final accuracy − 1 point is the shared target,
        // and its own t_to_target comes from the same (single) run's log
        let (mut sync_row, sync_log) = run_one(&base, &asm, topo, None)?;
        let target = sync_row.final_accuracy - 0.01;
        sync_row.t_to_target_s = time_to(&sync_log, target);
        let budget = sync_row.sim_time_s;
        rows.push(sync_row);
        for &s in stalenesses {
            let mut c = base.clone();
            c.driver = "async".into();
            c.staleness_s = s;
            c.sim_budget_s = budget;
            rows.push(run_one(&c, &asm, topo, Some(target))?.0);
        }
    }
    Ok(rows)
}

/// Print the frontier table.
pub fn print_table(rows: &[AsyncRow]) {
    println!("EXP-AS1 — sync barrier vs async event-driven gossip (wall-clock frontier)");
    println!(
        "{:<16} {:<10} {:>10} {:>8} {:>8} {:>10} {:>12} {:>14}",
        "driver", "topology", "final_loss", "acc", "rounds", "MBytes", "sim_time_s", "t_to_target_s"
    );
    for r in rows {
        println!(
            "{:<16} {:<10} {:>10.4} {:>8.3} {:>8} {:>10.2} {:>12.2} {:>14.2}",
            r.driver,
            r.topology,
            r.final_loss,
            r.final_accuracy,
            r.comm_rounds,
            r.bytes as f64 / 1e6,
            r.sim_time_s,
            r.t_to_target_s
        );
    }
}

/// Human-readable observations relative to each topology's sync oracle row.
pub fn findings(rows: &[AsyncRow]) -> Vec<String> {
    let mut out = Vec::new();
    for r in rows.iter().filter(|r| r.driver != "sync") {
        let Some(sync) = rows.iter().find(|s| s.driver == "sync" && s.topology == r.topology)
        else {
            continue;
        };
        let acc_pts = 100.0 * (r.final_accuracy - sync.final_accuracy);
        if r.t_to_target_s.is_nan() {
            out.push(format!(
                "{} on {}: never reached sync final accuracy − 1 pt within the matched \
                 time budget (accuracy {acc_pts:+.1} pts at the end)",
                r.driver, r.topology
            ));
            continue;
        }
        let vs_horizon = sync.sim_time_s / r.t_to_target_s;
        let vs_target = sync.t_to_target_s / r.t_to_target_s;
        out.push(format!(
            "{} on {}: sync-final−1pt accuracy at sim {:.2}s — {vs_horizon:.2}x inside \
             sync's {:.2}s horizon ({vs_target:.2}x sync's own time-to-target), final \
             accuracy {acc_pts:+.1} pts",
            r.driver, r.topology, r.t_to_target_s, sync.sim_time_s
        ));
    }
    out
}

/// JSON dump of the sweep.
pub fn rows_json(rows: &[AsyncRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                jsonl::obj(vec![
                    ("driver", jsonl::s(&r.driver)),
                    ("staleness_s", jsonl::num(r.staleness_s)),
                    ("topology", jsonl::s(&r.topology)),
                    ("final_loss", jsonl::num(r.final_loss)),
                    ("final_accuracy", jsonl::num(r.final_accuracy)),
                    ("final_consensus", jsonl::num(r.final_consensus)),
                    ("comm_rounds", jsonl::num(r.comm_rounds as f64)),
                    ("bytes", jsonl::num(r.bytes as f64)),
                    ("sim_time_s", jsonl::num(r.sim_time_s)),
                    ("quarantined", jsonl::num(r.quarantined as f64)),
                    ("t_to_target_s", jsonl::num(r.t_to_target_s)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoKind, Backend, Mode};

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = Backend::Native;
        cfg.mode = Mode::Fused;
        cfg.algo = AlgoKind::FdDsgt;
        cfg.n = 6;
        cfg.hidden = 8;
        cfg.m = 8;
        // cycle compute (q·s_step = 32 ms) must dominate delivery latency
        // (~20 ms for DSGT) or staleness drag swamps the barrier saving —
        // the regime DESIGN.md §13 calls out
        cfg.q = 32;
        cfg.total_steps = 768; // 24 sync rounds
        cfg.eval_every = 1;
        cfg.records_per_hospital = 60;
        cfg.compute_plan = "lognormal".into();
        cfg.compute_sigma = 1.5;
        cfg
    }

    #[test]
    fn sweep_leads_with_sync_and_async_beats_it_to_target() {
        let rows = run(&tiny_cfg(), &[0.0], &["ring".to_string()]).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].driver, "sync");
        assert_eq!(rows[1].driver, "async uncapped");
        for r in &rows {
            assert!(r.final_loss.is_finite(), "{}", r.driver);
            assert!(r.bytes > 0, "{}", r.driver);
        }
        assert_eq!(rows[0].comm_rounds, 24);
        // matched-time budget: the async fleet keeps cycling through sync's
        // whole horizon, so it completes at least as many (cheaper) cycles
        assert!(rows[1].comm_rounds >= rows[0].comm_rounds, "async {} cycles", rows[1].comm_rounds);
        assert!(rows[1].sim_time_s <= rows[0].sim_time_s + 1e-6);
        // the acceptance criterion in miniature: async matches the sync
        // oracle's final accuracy (±1 pt) and reaches sync-final−1pt
        // strictly inside the simulated time sync needed for its full run
        assert!(!rows[1].t_to_target_s.is_nan(), "async never reached target");
        assert!(
            rows[1].t_to_target_s < rows[0].sim_time_s,
            "async {} vs sync horizon {}",
            rows[1].t_to_target_s,
            rows[0].sim_time_s
        );
        assert!(
            rows[1].final_accuracy >= rows[0].final_accuracy - 0.0101,
            "async final {} vs sync {}",
            rows[1].final_accuracy,
            rows[0].final_accuracy
        );
        let f = findings(&rows);
        assert_eq!(f.len(), 1);
        assert!(f[0].contains("inside"), "{}", f[0]);
    }

    #[test]
    fn staleness_axis_adds_one_row_per_cap() {
        let rows = run(&tiny_cfg(), &[0.0, 0.5], &["ring".to_string()]).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[1].driver, "async uncapped");
        assert_eq!(rows[2].driver, "async s=0.50");
        assert!((rows[2].staleness_s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn poisoned_payload_quarantine_surfaces_in_rows_and_json() {
        // regression: the combine-boundary quarantine counter used to stop
        // at RoundMetrics — EXP-AS1 rows and their JSON dump dropped it, so
        // a poisoned frontier run was indistinguishable from an honest one
        let mut cfg = tiny_cfg();
        cfg.compute_plan = "uniform".into();
        cfg.attack_plan = "scaled-noise".into();
        cfg.attack_frac = 0.2;
        cfg.attack_scale = 1e39; // overflows f32 → Inf payloads on the wire
        let rows = run(&cfg, &[0.0], &["ring".to_string()]).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.quarantined > 0, "{}: poisoned payloads must surface", r.driver);
            assert!(r.final_loss.is_finite(), "{}: the poison must never mix", r.driver);
        }
        let json = rows_json(&rows).to_string();
        assert!(json.contains("\"quarantined\""), "{json}");
        // honest runs keep the counter at zero — the column is an audit
        // trail, not noise
        let honest = run(&tiny_cfg(), &[0.0], &["ring".to_string()]).unwrap();
        assert!(honest.iter().all(|r| r.quarantined == 0));
    }

    #[test]
    fn empty_staleness_list_is_rejected() {
        let err = run(&tiny_cfg(), &[], &["ring".to_string()]).unwrap_err();
        assert!(err.to_string().contains("staleness"), "{err}");
    }
}
