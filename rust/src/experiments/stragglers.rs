//! EXP-S1: heterogeneous compute / stragglers — the accuracy-vs-sim-time
//! frontier across straggler plans × topologies.
//!
//! Every run on one topology shares the same dataset, base graph, mixing
//! matrix, seed, and round schedule; only `compute.plan` varies, so each
//! block isolates what compute heterogeneity costs (or doesn't): final
//! loss/accuracy, the true local work performed, and the straggler-aware
//! simulated wall time (every round as slow as its slowest participant —
//! `engine::stragglers`).  The interesting read is the *frontier*: dropout
//! stragglers shave local work but a synchronous round still waits out its
//! deadline, so accuracy-per-sim-second degrades — exactly the deviation
//! DeceFL and the communication-perspective survey flag.

use crate::config::ExperimentConfig;
use crate::coordinator::{assemble, run_on, Assembled};
use crate::jsonl::{self, Json};
use anyhow::{bail, Result};

/// One (plan, topology) cell of the EXP-S1 frontier.
#[derive(Clone, Debug)]
pub struct StragglerRow {
    /// Compute-plan label (`uniform`, `tiers[…]`, `lognormal σ=…`, …).
    pub plan: String,
    /// Base topology the block ran on.
    pub topology: String,
    /// Final record-weighted training loss.
    pub final_loss: f64,
    /// Final record-weighted training accuracy.
    pub final_accuracy: f64,
    /// Final consensus error.
    pub final_consensus: f64,
    /// Communication rounds run.
    pub comm_rounds: u64,
    /// True mean per-node local work performed (Σ_r Σ_i τ_i / N).
    pub local_steps: u64,
    /// Total bytes on the wire.
    pub bytes: u64,
    /// Straggler-aware simulated wall time, seconds.
    pub sim_time_s: f64,
}

fn run_one(cfg: &ExperimentConfig, asm: &Assembled, topo: &str) -> Result<StragglerRow> {
    cfg.validate()?;
    let label = crate::engine::stragglers::plan_from_config(cfg)?.label();
    let log = run_on(cfg, asm)?;
    let last = log.rows.last().expect("run produced no metric rows");
    Ok(StragglerRow {
        plan: label,
        topology: topo.to_string(),
        final_loss: last.loss,
        final_accuracy: last.accuracy,
        final_consensus: last.consensus,
        comm_rounds: last.comm_rounds,
        local_steps: last.local_steps,
        bytes: last.bytes,
        sim_time_s: last.sim_time_s,
    })
}

/// Sweep straggler plans × topologies against the uniform baseline.  The
/// tier speeds, lognormal σ, and dropout fraction come from the config's
/// `compute.*` knobs; each topology gets its own assembled base network and
/// always leads with its uniform row.
pub fn run(cfg: &ExperimentConfig, plans: &[String], topos: &[String]) -> Result<Vec<StragglerRow>> {
    if plans.iter().any(|p| p == "uniform") {
        bail!("the uniform baseline row is always included; list only straggler plans");
    }
    let mut rows = Vec::new();
    for topo in topos {
        let mut base = cfg.clone();
        base.topology = topo.clone();
        base.compute_plan = "uniform".into();
        base.validate()?;
        let asm = assemble(&base)?;
        rows.push(run_one(&base, &asm, topo)?);
        for plan in plans {
            let mut c = base.clone();
            c.compute_plan = plan.clone();
            rows.push(run_one(&c, &asm, topo)?);
        }
    }
    Ok(rows)
}

/// Print the frontier table.
pub fn print_table(rows: &[StragglerRow]) {
    println!("EXP-S1 — straggler plans × topologies (accuracy / sim-time frontier)");
    println!(
        "{:<22} {:<10} {:>10} {:>8} {:>12} {:>12} {:>10} {:>12}",
        "plan", "topology", "final_loss", "acc", "local_steps", "comm_rounds", "MBytes", "sim_time_s"
    );
    for r in rows {
        println!(
            "{:<22} {:<10} {:>10.4} {:>8.3} {:>12} {:>12} {:>10.2} {:>12.2}",
            r.plan,
            r.topology,
            r.final_loss,
            r.final_accuracy,
            r.local_steps,
            r.comm_rounds,
            r.bytes as f64 / 1e6,
            r.sim_time_s
        );
    }
}

/// Human-readable observations relative to each topology's uniform row.
pub fn findings(rows: &[StragglerRow]) -> Vec<String> {
    let mut out = Vec::new();
    for r in rows.iter().filter(|r| r.plan != "uniform") {
        let Some(uni) = rows
            .iter()
            .find(|u| u.plan == "uniform" && u.topology == r.topology)
        else {
            continue;
        };
        let acc_pts = 100.0 * (r.final_accuracy - uni.final_accuracy);
        let work_pct = if uni.local_steps > 0 {
            100.0 * (r.local_steps as f64 - uni.local_steps as f64) / uni.local_steps as f64
        } else {
            0.0
        };
        let time_pct = if uni.sim_time_s > 0.0 {
            100.0 * (r.sim_time_s - uni.sim_time_s) / uni.sim_time_s
        } else {
            0.0
        };
        out.push(format!(
            "{} on {}: accuracy {acc_pts:+.1} pts vs uniform, local work {work_pct:+.1}%, \
             sim time {time_pct:+.1}%",
            r.plan, r.topology
        ));
    }
    out
}

/// JSON dump of the sweep.
pub fn rows_json(rows: &[StragglerRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                jsonl::obj(vec![
                    ("plan", jsonl::s(&r.plan)),
                    ("topology", jsonl::s(&r.topology)),
                    ("final_loss", jsonl::num(r.final_loss)),
                    ("final_accuracy", jsonl::num(r.final_accuracy)),
                    ("final_consensus", jsonl::num(r.final_consensus)),
                    ("comm_rounds", jsonl::num(r.comm_rounds as f64)),
                    ("local_steps", jsonl::num(r.local_steps as f64)),
                    ("bytes", jsonl::num(r.bytes as f64)),
                    ("sim_time_s", jsonl::num(r.sim_time_s)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoKind, Backend, Mode};

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = Backend::Native;
        cfg.mode = Mode::Fused;
        cfg.algo = AlgoKind::FdDsgt;
        cfg.n = 5;
        cfg.hidden = 8;
        cfg.m = 8;
        cfg.q = 4;
        cfg.total_steps = 32;
        cfg.eval_every = 2;
        cfg.records_per_hospital = 60;
        cfg.compute_tiers = "1.0,0.5".into();
        cfg.slow_frac = 0.4;
        cfg.compute_sigma = 0.6;
        cfg
    }

    #[test]
    fn sweep_covers_plans_with_uniform_baseline_per_topology() {
        let plans = vec!["fixed-tiers".to_string(), "dropout".to_string()];
        let topos = vec!["ring".to_string(), "er".to_string()];
        let rows = run(&tiny_cfg(), &plans, &topos).unwrap();
        assert_eq!(rows.len(), 6);
        for topo in ["ring", "er"] {
            let block: Vec<_> = rows.iter().filter(|r| r.topology == topo).collect();
            assert_eq!(block.len(), 3, "{topo}");
            assert_eq!(block[0].plan, "uniform", "{topo} leads with uniform");
            for r in &block {
                assert!(r.final_loss.is_finite(), "{}/{}", r.plan, topo);
                assert!(r.bytes > 0);
                assert_eq!(r.comm_rounds, 8);
            }
            // straggler plans do less (or equal) local work than uniform
            for r in &block[1..] {
                assert!(
                    r.local_steps <= block[0].local_steps,
                    "{}: {} > uniform {}",
                    r.plan,
                    r.local_steps,
                    block[0].local_steps
                );
            }
        }
        assert_eq!(findings(&rows).len(), 4);
    }

    #[test]
    fn uniform_in_plan_list_is_rejected() {
        let err = run(&tiny_cfg(), &["uniform".to_string()], &["ring".to_string()]).unwrap_err();
        assert!(err.to_string().contains("baseline"), "{err}");
    }
}
