//! EXP-A1..A4: ablation sweeps over the design dimensions DESIGN.md calls
//! out — local period Q, graph topology (spectral gap), data heterogeneity
//! (DSGD vs DSGT), and decentralized-vs-star-vs-centralized baselines.

use crate::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use crate::coordinator::{assemble, run_on};
use crate::jsonl::{self, Json};
use crate::metrics::RunLog;
use anyhow::Result;

fn sweep_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.backend = Backend::Native;
    cfg.mode = Mode::Fused;
    cfg.hidden = 16;
    cfg.records_per_hospital = 200;
    cfg
}

// -------------------------------------------------------------- EXP-A1 ----

/// One Q's outcome in the local-period sweep.
#[derive(Clone, Debug)]
pub struct QRow {
    /// Local period Q.
    pub q: usize,
    /// Final training loss.
    pub final_loss: f64,
    /// Communication rounds spent.
    pub comm_rounds: u64,
    /// Total bytes on the wire.
    pub bytes: u64,
    /// First round reaching the target loss (None = never).
    pub rounds_to_target: Option<u64>,
}

/// Q sweep: same local-iteration budget, varying the communication period.
pub fn q_sweep(qs: &[usize], total_steps: usize, target_loss: f64, seed: u64) -> Result<Vec<QRow>> {
    let mut rows = Vec::new();
    for &q in qs {
        let mut cfg = sweep_base();
        cfg.algo = AlgoKind::FdDsgt;
        cfg.q = q;
        cfg.total_steps = total_steps;
        cfg.eval_every = 1;
        cfg.seed = seed;
        let log = run_on(&cfg, &assemble(&cfg)?)?;
        let last = log.rows.last().unwrap();
        rows.push(QRow {
            q,
            final_loss: last.loss,
            comm_rounds: last.comm_rounds,
            bytes: last.bytes,
            rounds_to_target: log.rounds_to_loss(target_loss),
        });
    }
    Ok(rows)
}

/// Print the Q-sweep table.
pub fn print_q_table(rows: &[QRow], target: f64) {
    println!("EXP-A1 — local period Q (FD-DSGT, equal local-step budget)");
    println!("{:>6} {:>12} {:>12} {:>12} {:>18}", "Q", "final_loss", "comm_rounds", "MBytes", format!("rounds→loss≤{target}"));
    for r in rows {
        println!(
            "{:>6} {:>12.4} {:>12} {:>12.2} {:>18}",
            r.q,
            r.final_loss,
            r.comm_rounds,
            r.bytes as f64 / 1e6,
            r.rounds_to_target.map(|v| v.to_string()).unwrap_or_else(|| "-".into())
        );
    }
}

// -------------------------------------------------------------- EXP-A2 ----

/// One topology's outcome in the spectral-gap sweep.
#[derive(Clone, Debug)]
pub struct TopologyRow {
    /// Topology family name.
    pub topology: String,
    /// `1 − |λ₂|` of its mixing matrix.
    pub spectral_gap: f64,
    /// Final consensus error.
    pub final_consensus: f64,
    /// Final training loss.
    pub final_loss: f64,
}

/// Topology sweep: consensus quality vs spectral gap at fixed budget.
pub fn topology_sweep(topologies: &[&str], total_steps: usize, seed: u64) -> Result<Vec<TopologyRow>> {
    let mut rows = Vec::new();
    for &topo in topologies {
        let mut cfg = sweep_base();
        cfg.algo = AlgoKind::FdDsgt;
        cfg.q = 10;
        cfg.total_steps = total_steps;
        cfg.eval_every = 5;
        cfg.topology = topo.to_string();
        cfg.seed = seed;
        let asm = assemble(&cfg)?;
        let log = run_on(&cfg, &asm)?;
        let last = log.rows.last().unwrap();
        rows.push(TopologyRow {
            topology: topo.to_string(),
            spectral_gap: asm.spectral_gap,
            final_consensus: last.consensus,
            final_loss: last.loss,
        });
    }
    Ok(rows)
}

/// Print the topology-sweep table.
pub fn print_topology_table(rows: &[TopologyRow]) {
    println!("EXP-A2 — topology / spectral gap (FD-DSGT)");
    println!("{:<12} {:>13} {:>16} {:>12}", "topology", "spectral_gap", "final_consensus", "final_loss");
    for r in rows {
        println!(
            "{:<12} {:>13.4} {:>16.4e} {:>12.4}",
            r.topology, r.spectral_gap, r.final_consensus, r.final_loss
        );
    }
}

// -------------------------------------------------------------- EXP-A3 ----

/// One heterogeneity level's DSGD-vs-DSGT outcome.
#[derive(Clone, Debug)]
pub struct HeteroRow {
    /// The swept non-iidness level in [0, 1].
    pub heterogeneity: f64,
    /// Seed-averaged DSGD tail optimality gap.
    pub dsgd_gap: f64,
    /// Seed-averaged DSGT tail optimality gap.
    pub dsgt_gap: f64,
    /// Seed-averaged DSGD tail consensus error.
    pub dsgd_consensus: f64,
    /// Seed-averaged DSGT tail consensus error.
    pub dsgt_consensus: f64,
    /// consensus-error ratio DSGD/DSGT; > 1 means gradient tracking wins.
    /// (The gap's stationarity term is shared noise — the tracker's win is
    /// cancelling the heterogeneity-driven consensus bias, so that is the
    /// observable this sweep reports.)
    pub advantage: f64,
}

/// Heterogeneity sweep: DSGD vs DSGT optimality gap as shards de-correlate.
/// The paper's §3 claim: GT handles non-identical data better.
pub fn hetero_sweep(hets: &[f64], total_steps: usize, seeds: &[u64]) -> Result<Vec<HeteroRow>> {
    let mut rows = Vec::new();
    for &het in hets {
        let mut dsgd_gap = 0.0;
        let mut dsgt_gap = 0.0;
        let mut dsgd_cons = 0.0;
        let mut dsgt_cons = 0.0;
        for &seed in seeds {
            let mut cfg = sweep_base();
            cfg.q = 1;
            cfg.total_steps = total_steps;
            cfg.eval_every = total_steps / 4;
            cfg.heterogeneity = het;
            cfg.seed = seed;
            cfg.algo = AlgoKind::Dsgd;
            let asm = assemble(&cfg)?;
            let tail = |log: &RunLog| {
                let rows: Vec<_> = log.rows.iter().rev().take(2).collect();
                let gap = rows.iter().map(|r| r.optimality_gap()).sum::<f64>() / rows.len() as f64;
                let cons = rows.iter().map(|r| r.consensus).sum::<f64>() / rows.len() as f64;
                (gap, cons)
            };
            let (g, c) = tail(&run_on(&cfg, &asm)?);
            dsgd_gap += g;
            dsgd_cons += c;
            cfg.algo = AlgoKind::Dsgt;
            let (g, c) = tail(&run_on(&cfg, &asm)?);
            dsgt_gap += g;
            dsgt_cons += c;
        }
        let k = seeds.len() as f64;
        rows.push(HeteroRow {
            heterogeneity: het,
            dsgd_gap: dsgd_gap / k,
            dsgt_gap: dsgt_gap / k,
            dsgd_consensus: dsgd_cons / k,
            dsgt_consensus: dsgt_cons / k,
            advantage: (dsgd_cons / k) / (dsgt_cons / k).max(1e-18),
        });
    }
    Ok(rows)
}

/// Print the heterogeneity-sweep table.
pub fn print_hetero_table(rows: &[HeteroRow]) {
    println!("EXP-A3 — heterogeneity: DSGD vs DSGT (Q=1)");
    println!(
        "{:>6} {:>13} {:>13} {:>14} {:>14} {:>14}",
        "het", "DSGD gap", "DSGT gap", "DSGD consensus", "DSGT consensus", "cons DSGD/DSGT"
    );
    for r in rows {
        println!(
            "{:>6.2} {:>13.4e} {:>13.4e} {:>14.4e} {:>14.4e} {:>14.2}",
            r.heterogeneity, r.dsgd_gap, r.dsgt_gap, r.dsgd_consensus, r.dsgt_consensus, r.advantage
        );
    }
}

// -------------------------------------------------------------- EXP-A4 ----

/// One algorithm's outcome in the baseline comparison.
#[derive(Clone, Debug)]
pub struct BaselineRow {
    /// Algorithm name.
    pub algo: String,
    /// Final training loss.
    pub final_loss: f64,
    /// Total bytes on the wire.
    pub bytes: u64,
    /// Simulated wall time, seconds.
    pub sim_time_s: f64,
}

/// Decentralized FD-DSGT vs star FedAvg vs centralized SGD at an equal
/// local-step budget.
pub fn baseline_compare(total_steps: usize, q: usize, seed: u64) -> Result<Vec<BaselineRow>> {
    let mut rows = Vec::new();
    for algo in [AlgoKind::FdDsgt, AlgoKind::FedAvg, AlgoKind::Centralized] {
        let mut cfg = sweep_base();
        cfg.algo = algo;
        cfg.q = q;
        cfg.total_steps = total_steps;
        cfg.eval_every = 10;
        cfg.seed = seed;
        let log = run_on(&cfg, &assemble(&cfg)?)?;
        let last = log.rows.last().unwrap();
        rows.push(BaselineRow {
            algo: algo.name().to_string(),
            final_loss: last.loss,
            bytes: last.bytes,
            sim_time_s: last.sim_time_s,
        });
    }
    Ok(rows)
}

/// Print the baseline-comparison table.
pub fn print_baseline_table(rows: &[BaselineRow]) {
    println!("EXP-A4 — decentralized vs star vs fusion center (equal step budget)");
    println!("{:<12} {:>12} {:>12} {:>12}", "algo", "final_loss", "MBytes", "sim_time_s");
    for r in rows {
        println!(
            "{:<12} {:>12.4} {:>12.2} {:>12.2}",
            r.algo,
            r.final_loss,
            r.bytes as f64 / 1e6,
            r.sim_time_s
        );
    }
}

/// JSON dump helpers for the bench harness.
pub fn rows_to_json<T, F: Fn(&T) -> Json>(rows: &[T], f: F) -> Json {
    Json::Arr(rows.iter().map(f).collect())
}

/// JSON shape of one [`QRow`].
pub fn q_row_json(r: &QRow) -> Json {
    jsonl::obj(vec![
        ("q", jsonl::num(r.q as f64)),
        ("final_loss", jsonl::num(r.final_loss)),
        ("comm_rounds", jsonl::num(r.comm_rounds as f64)),
        ("bytes", jsonl::num(r.bytes as f64)),
        (
            "rounds_to_target",
            r.rounds_to_target.map(|v| jsonl::num(v as f64)).unwrap_or(Json::Null),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_sweep_fewer_rounds_with_larger_q() {
        let rows = q_sweep(&[1, 10], 100, 0.5, 7).unwrap();
        assert_eq!(rows[0].comm_rounds, 100);
        assert_eq!(rows[1].comm_rounds, 10);
        assert!(rows[1].bytes < rows[0].bytes);
    }

    #[test]
    fn topology_sweep_gap_ordering() {
        let rows = topology_sweep(&["ring", "complete"], 60, 7).unwrap();
        let ring = &rows[0];
        let complete = &rows[1];
        assert!(complete.spectral_gap > ring.spectral_gap);
        // denser graph reaches (weakly) better consensus
        assert!(complete.final_consensus <= ring.final_consensus * 1.5);
    }

    #[test]
    fn baseline_compare_decentralized_cheaper_than_it_looks() {
        let rows = baseline_compare(60, 10, 7).unwrap();
        assert_eq!(rows.len(), 3);
        let cent = rows.iter().find(|r| r.algo == "centralized").unwrap();
        assert_eq!(cent.bytes, 0);
        for r in &rows {
            assert!(r.final_loss.is_finite());
        }
    }
}
