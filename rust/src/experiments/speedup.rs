//! EXP-T1: numerical verification of Theorem 1 — linear speedup of DSGT.
//!
//! Theorem 1 (Q=1, DSGT, α_r ~ √(N/r)): the averaged optimality gap after T
//! steps is O(σ²/(N√T)) — *linear speedup in N*.  We fix T, sweep N with
//! everything else constant (same per-node shard size, same heterogeneity),
//! and report gap(N)·N, which the theorem predicts to be roughly flat.
//!
//! Uses the native backend: the artifact set is shape-specialized to one N,
//! while this sweep needs many.

use crate::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use crate::coordinator::{assemble, run_on};
use crate::jsonl::{self, Json};
use anyhow::Result;

/// One N's outcome in the Theorem-1 linear-speedup sweep.
#[derive(Clone, Debug)]
pub struct SpeedupRow {
    /// Node count N.
    pub n: usize,
    /// Seed-averaged final stationarity gap.
    pub gap: f64,
    /// `gap × N` — flat under linear speedup.
    pub gap_times_n: f64,
    /// Seed-averaged final loss.
    pub loss: f64,
    /// Variance of the N-node mean stochastic gradient at a fixed point —
    /// the sigma^2/N mechanism behind Theorem 1, measured directly.
    pub grad_var: f64,
    /// `grad_var × N` — flat when the σ²/N mechanism holds.
    pub grad_var_times_n: f64,
}

/// The full sweep over N.
pub struct SpeedupResult {
    /// Local-iteration budget shared by every N.
    pub t_steps: usize,
    /// One row per swept N.
    pub rows: Vec<SpeedupRow>,
}

/// Run the sweep with the paper's fixed schedule α_r = 0.02/√r; the
/// speedup observable is the stationarity noise floor, which Theorem 1
/// bounds by O(σ²/(N√T)).
pub fn run(ns: &[usize], t_steps: usize, seeds: &[u64]) -> Result<SpeedupResult> {
    let mut rows = Vec::with_capacity(ns.len());
    for &n in ns {
        let mut gap_acc = 0.0;
        let mut loss_acc = 0.0;
        for &seed in seeds {
            let mut cfg = ExperimentConfig::default();
            cfg.backend = Backend::Native;
            cfg.mode = Mode::Fused;
            cfg.algo = AlgoKind::Dsgt;
            cfg.q = 1;
            cfg.n = n;
            cfg.hidden = 16;
            // controlled comparison across N: iid shards carved from ONE
            // fixed-size global cohort (same objective for every N), small
            // minibatch + larger lr so the sigma^2/N term dominates
            cfg.m = 2;
            cfg.total_steps = t_steps;
            cfg.alpha0 = 0.1;
            cfg.eval_every = (t_steps / 20).max(1);
            cfg.records_per_hospital = 3200 / n;
            cfg.heterogeneity = 0.0;
            cfg.topology = "ring".into(); // same family for every N
            cfg.seed = seed;
            let log = run_on(&cfg, &assemble(&cfg)?)?;
            // average stationarity over the SECOND HALF of the trajectory:
            // the first half is the N-independent deterministic transient,
            // the tail is where the sigma^2/N noise floor (Theorem 1's
            // speedup term) is visible
            let all: Vec<f64> = log.rows.iter().skip(1).map(|r| r.stationarity).collect();
            let tail = &all[all.len() / 2..];
            gap_acc += tail.iter().sum::<f64>() / tail.len() as f64;
            loss_acc += log.rows.last().unwrap().loss;
        }
        let gap = gap_acc / seeds.len() as f64;
        let grad_var = mean_grad_variance(n, seeds[0])?;
        rows.push(SpeedupRow {
            n,
            gap,
            gap_times_n: gap * n as f64,
            loss: loss_acc / seeds.len() as f64,
            grad_var,
            grad_var_times_n: grad_var * n as f64,
        });
    }
    Ok(SpeedupResult { t_steps, rows })
}

/// Variance of the mean-of-N stochastic gradients at a fixed parameter
/// point, over K resamples — should scale exactly as sigma^2/N for iid
/// shards (Theorem 1's linear-speedup mechanism).
fn mean_grad_variance(n: usize, seed: u64) -> Result<f64> {
    use crate::coordinator::compute::{Compute, NativeCompute};
    use crate::coordinator::sampler::{init_theta, NodeSampler};
    let (d, h, m) = (42usize, 16usize, 2usize);
    let compute = NativeCompute::new(d, h, n, m);
    let model = crate::algo::native::NativeModel::new(d, h);
    let ds = crate::data::generate(&crate::data::DataConfig {
        n_hospitals: n,
        records_per_hospital: 3200 / n,
        records_jitter: 0,
        heterogeneity: 0.0,
        seed,
        ..Default::default()
    })?;
    let theta = init_theta(seed, 0, &model);
    let p = model.p();
    let k_draws = 64usize;
    let mut samplers: Vec<NodeSampler> =
        (0..n).map(|i| NodeSampler::new(seed ^ 0xA5, i, m)).collect();
    let mut bx = vec![0.0f32; m * d];
    let mut by = vec![0.0f32; m];
    let mut draws: Vec<Vec<f64>> = Vec::with_capacity(k_draws);
    for _ in 0..k_draws {
        let mut mean_g = vec![0.0f64; p];
        for i in 0..n {
            samplers[i].batch(&ds.shards[i], &mut bx, &mut by);
            let (_, g) = compute.grad_step(&theta, &bx, &by)?;
            for (acc, &v) in mean_g.iter_mut().zip(&g) {
                *acc += v as f64 / n as f64;
            }
        }
        draws.push(mean_g);
    }
    let mut center = vec![0.0f64; p];
    for dr in &draws {
        for (c, v) in center.iter_mut().zip(dr) {
            *c += v / k_draws as f64;
        }
    }
    let var = draws
        .iter()
        .map(|dr| {
            dr.iter()
                .zip(&center)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
        })
        .sum::<f64>()
        / k_draws as f64;
    Ok(var)
}

impl SpeedupResult {
    /// Print the N-sweep table with the mechanism note.
    pub fn print_table(&self) {
        println!("Theorem 1 — linear speedup of DSGT (Q=1, T={})", self.t_steps);
        println!(
            "{:>6} {:>13} {:>13} {:>9} {:>13} {:>13}",
            "N", "gap", "gap*N", "loss", "var(ḡ)", "var(ḡ)*N"
        );
        for r in &self.rows {
            println!(
                "{:>6} {:>13.4e} {:>13.4e} {:>9.4} {:>13.4e} {:>13.4e}",
                r.n, r.gap, r.gap_times_n, r.loss, r.grad_var, r.grad_var_times_n
            );
        }
        println!(
            "(theorem mechanism: var of the N-node mean gradient ∝ σ²/N ⇒ var·N ≈ const; \
             the end-to-end gap at feasible T is dominated by the N-independent \
             deterministic transient and only trends with 1/N)"
        );
    }

    /// JSON dump of the sweep.
    pub fn to_json(&self) -> Json {
        jsonl::obj(vec![
            ("t_steps", jsonl::num(self.t_steps as f64)),
            ("n", jsonl::arr_f64(&self.rows.iter().map(|r| r.n as f64).collect::<Vec<_>>())),
            ("gap", jsonl::arr_f64(&self.rows.iter().map(|r| r.gap).collect::<Vec<_>>())),
            ("gap_times_n", jsonl::arr_f64(&self.rows.iter().map(|r| r.gap_times_n).collect::<Vec<_>>())),
            ("grad_var", jsonl::arr_f64(&self.rows.iter().map(|r| r.grad_var).collect::<Vec<_>>())),
        ])
    }

    /// Is the scaling consistent with linear speedup?  Judged on the
    /// directly-measured mechanism (variance of the N-node mean gradient),
    /// which Theorem 1 predicts to scale as 1/N: log-log slope within
    /// [0.7, 1.3] of ideal.
    pub fn supports_linear_speedup(&self) -> bool {
        if self.rows.len() < 2 {
            return false;
        }
        let first = &self.rows[0];
        let last = &self.rows[self.rows.len() - 1];
        let measured = (first.grad_var / last.grad_var).ln();
        let ideal = (last.n as f64 / first.n as f64).ln();
        measured > 0.7 * ideal && measured < 1.3 * ideal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_gradient_variance_scales_as_one_over_n() {
        let res = run(&[4, 16], 120, &[7]).unwrap();
        assert_eq!(res.rows.len(), 2);
        assert!(res.supports_linear_speedup(), "{:?}", res.rows);
        // gap must at least not grow with N
        assert!(res.rows[1].gap <= res.rows[0].gap * 1.15, "{:?}", res.rows);
    }
}
