//! EXP-F1L / EXP-F1R: regenerate both panels of the paper's Figure 1.
//!
//! Left panel: the 20-hospital graph — node layout, edges, degrees, and the
//! spectral statistics that drive consensus.  Right panel: t-SNE embedding
//! of samples from three hospitals, with the silhouette score quantifying
//! the cluster separation the paper shows visually.

use crate::config::ExperimentConfig;
use crate::data::{generate, DataConfig};
use crate::graph::{layout::layout, Graph, Topology};
use crate::jsonl::{self, Json};
use crate::linalg::Mat;
use crate::mixing::{self, Scheme};
use crate::rng::Pcg64;
use crate::tsne::{silhouette, tsne, TsneConfig};
use anyhow::Result;

/// Fig. 1 left: the hospital network.
pub struct GraphReport {
    /// The generated hospital graph.
    pub graph: Graph,
    /// Force-directed 2-d layout, one point per node.
    pub coords: Vec<(f64, f64)>,
    /// Graphviz DOT export.
    pub dot: String,
    /// Per-node degrees.
    pub degrees: Vec<usize>,
    /// Graph diameter.
    pub diameter: usize,
    /// `|λ₂|` of the Metropolis mixing matrix.
    pub second_eig: f64,
    /// `1 − |λ₂|`.
    pub spectral_gap: f64,
}

/// Build the Fig. 1L network report from a config.
pub fn hospital_graph(cfg: &ExperimentConfig) -> Result<GraphReport> {
    let topo = Topology::parse(&cfg.topology)?;
    let mut rng = Pcg64::new(cfg.seed, 0x6EA9);
    let graph = Graph::build(&topo, cfg.n, &mut rng)?;
    let w = mixing::build_sparse(&graph, Scheme::parse(&cfg.mixing)?);
    let v = mixing::validate_sparse(&w);
    let coords = layout(&graph, &mut rng, 300);
    let degrees = (0..graph.n()).map(|i| graph.degree(i)).collect();
    Ok(GraphReport {
        dot: graph.to_dot(None),
        coords,
        degrees,
        diameter: graph.diameter(),
        second_eig: v.second_eig,
        spectral_gap: v.spectral_gap,
        graph,
    })
}

impl GraphReport {
    /// JSON dump (edges, layout, spectra) for re-plotting.
    pub fn to_json(&self) -> Json {
        jsonl::obj(vec![
            ("n", jsonl::num(self.graph.n() as f64)),
            ("edges", Json::Arr(
                self.graph
                    .edges()
                    .iter()
                    .map(|&(a, b)| Json::Arr(vec![jsonl::num(a as f64), jsonl::num(b as f64)]))
                    .collect(),
            )),
            ("coords", Json::Arr(
                self.coords
                    .iter()
                    .map(|&(x, y)| Json::Arr(vec![jsonl::num(x), jsonl::num(y)]))
                    .collect(),
            )),
            ("degrees", jsonl::arr_f64(&self.degrees.iter().map(|&d| d as f64).collect::<Vec<_>>())),
            ("diameter", jsonl::num(self.diameter as f64)),
            ("second_eig", jsonl::num(self.second_eig)),
            ("spectral_gap", jsonl::num(self.spectral_gap)),
        ])
    }

    /// Human-readable summary (degrees, diameter, spectra).
    pub fn print_summary(&self) {
        let g = &self.graph;
        println!("Fig.1L — hospital network ({} nodes, {} edges)", g.n(), g.edge_count());
        println!("  degrees: min {} / mean {:.1} / max {}",
            self.degrees.iter().min().unwrap(),
            self.degrees.iter().sum::<usize>() as f64 / g.n() as f64,
            self.degrees.iter().max().unwrap());
        println!("  diameter {}  |λ₂| {:.4}  spectral gap {:.4}",
            self.diameter, self.second_eig, self.spectral_gap);
    }
}

/// Fig. 1 right: t-SNE of `hospitals` (default 3) × `per_hospital` samples.
pub struct TsneReport {
    /// 2-d embedding, one row per sample.
    pub embedding: Mat,
    /// Hospital index of each embedded sample.
    pub labels: Vec<usize>,
    /// Silhouette score of the hospital clusters.
    pub silhouette: f64,
    /// The hospitals that were embedded.
    pub hospitals: Vec<usize>,
}

/// Build the Fig. 1R t-SNE report.
pub fn tsne_hospitals(
    cfg: &ExperimentConfig,
    hospitals: &[usize],
    per_hospital: usize,
    perplexity: f64,
) -> Result<TsneReport> {
    let ds = generate(&DataConfig {
        n_hospitals: cfg.n,
        records_per_hospital: cfg.records_per_hospital,
        records_jitter: cfg.records_per_hospital / 10,
        ad_prevalence: cfg.ad_prevalence,
        heterogeneity: cfg.heterogeneity,
        test_fraction: 0.0,
        seed: cfg.seed,
    })?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut labels = Vec::new();
    for &h in hospitals {
        let s = &ds.shards[h];
        for i in 0..per_hospital.min(s.n) {
            rows.push(s.row(i).iter().map(|&v| v as f64).collect());
            labels.push(h);
        }
    }
    let x = Mat::from_rows(&rows);
    let embedding = tsne(
        &x,
        &TsneConfig { perplexity, iterations: 400, seed: cfg.seed, ..TsneConfig::default() },
    )?;
    let sil = silhouette(&embedding, &labels);
    Ok(TsneReport { embedding, labels, silhouette: sil, hospitals: hospitals.to_vec() })
}

impl TsneReport {
    /// JSON dump (points, labels, silhouette) for re-plotting.
    pub fn to_json(&self) -> Json {
        jsonl::obj(vec![
            ("hospitals", jsonl::arr_f64(&self.hospitals.iter().map(|&h| h as f64).collect::<Vec<_>>())),
            ("labels", jsonl::arr_f64(&self.labels.iter().map(|&l| l as f64).collect::<Vec<_>>())),
            ("points", Json::Arr(
                (0..self.embedding.rows)
                    .map(|i| {
                        Json::Arr(vec![
                            jsonl::num(self.embedding[(i, 0)]),
                            jsonl::num(self.embedding[(i, 1)]),
                        ])
                    })
                    .collect(),
            )),
            ("silhouette", jsonl::num(self.silhouette)),
        ])
    }

    /// Human-readable summary with the silhouette verdict.
    pub fn print_summary(&self) {
        println!(
            "Fig.1R — t-SNE of hospitals {:?}: {} points, silhouette {:.3} \
             (>0.25 ⇒ visibly separated clusters, the paper's heterogeneity argument)",
            self.hospitals,
            self.embedding.rows,
            self.silhouette
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.n = 12;
        c.records_per_hospital = 80;
        c
    }

    #[test]
    fn graph_report_complete() {
        let r = hospital_graph(&cfg()).unwrap();
        assert_eq!(r.coords.len(), 12);
        assert_eq!(r.degrees.len(), 12);
        assert!(r.spectral_gap > 0.0);
        assert!(r.dot.contains("--"));
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 12);
    }

    #[test]
    fn tsne_separates_heterogeneous_hospitals() {
        let mut c = cfg();
        c.heterogeneity = 1.0;
        let r = tsne_hospitals(&c, &[0, 1, 2], 60, 20.0).unwrap();
        assert_eq!(r.labels.len(), r.embedding.rows);
        assert!(
            r.silhouette > 0.15,
            "heterogeneous hospitals should separate: silhouette {}",
            r.silhouette
        );
    }

    #[test]
    fn tsne_iid_hospitals_do_not_separate() {
        let mut c = cfg();
        c.heterogeneity = 0.0;
        let r = tsne_hospitals(&c, &[0, 1, 2], 50, 20.0).unwrap();
        assert!(
            r.silhouette < 0.15,
            "iid hospitals should overlap: silhouette {}",
            r.silhouette
        );
    }
}
