//! Experiment harnesses — one per paper figure/claim (DESIGN.md §5).
//! Filled by the fig1/fig2/speedup/sweep/churn/compress modules; each
//! produces both a human-readable table on stdout and a JSON dump for
//! re-plotting.

pub mod asynchrony;
pub mod churn;
pub mod compress;
pub mod fig1;
pub mod fig2;
pub mod robust;
pub mod shard;
pub mod speedup;
pub mod stragglers;
pub mod sweeps;
