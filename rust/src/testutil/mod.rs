//! Miniature property-based testing harness.
//!
//! `proptest` is unavailable in this offline environment, so invariant tests
//! use this harness instead: run a property over many seeded random cases,
//! and on failure greedily *shrink* the integer case parameters toward
//! minimal reproducers before reporting.  The failing seed is printed so any
//! case can be replayed deterministically.

use crate::rng::Pcg64;

/// Number of cases per property (kept moderate; the suite has many).
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` over `cases` random cases derived from `seed`.
/// The property receives a fresh deterministic RNG per case; returning
/// `Err(msg)` (or panicking) fails the run with the case index + seed.
pub fn check<F>(name: &str, cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg64::seed(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {case_seed}): {msg}");
        }
    }
}

/// Like [`check`] but the property takes an integer size drawn from
/// `[lo, hi)`; on failure the size is shrunk toward `lo` to find a minimal
/// failing size before panicking.
pub fn check_sized<F>(name: &str, cases: usize, seed: u64, lo: usize, hi: usize, mut prop: F)
where
    F: FnMut(&mut Pcg64, usize) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg64::seed(case_seed);
        let size = rng.range(lo, hi);
        if let Err(msg) = prop(&mut rng, size) {
            // shrink: retry smaller sizes with the same stream seed
            let mut min_size = size;
            let mut min_msg = msg;
            let mut s = size;
            while s > lo {
                s = lo + (s - lo) / 2;
                let mut rng2 = Pcg64::seed(case_seed);
                let _ = rng2.range(lo, hi); // consume the size draw as before
                if let Err(m2) = prop(&mut rng2, s) {
                    min_size = s;
                    min_msg = m2;
                } else {
                    break;
                }
            }
            panic!(
                "property `{name}` failed (case {case}, seed {case_seed}, \
                 shrunk size {min_size}): {min_msg}"
            );
        }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0 + x.abs().max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Assert two f64 scalars are close (relative + absolute).
pub fn close64(x: f64, y: f64, tol: f64) -> bool {
    (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 16, 0, |rng| {
            let x = rng.next_f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn check_reports_failure() {
        check("fails", 8, 0, |rng| {
            if rng.next_f64() < 2.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    #[should_panic(expected = "shrunk size 1")]
    fn check_sized_shrinks_to_minimum() {
        // property fails for every size >= 1 → shrinker must reach lo = 1
        check_sized("always-fails", 1, 0, 1, 100, |_rng, _size| Err("nope".into()));
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
    }

    #[test]
    fn assert_close_rejects_different() {
        assert!(assert_close(&[1.0], &[1.1], 1e-6).is_err());
    }

    #[test]
    fn close64_relative() {
        assert!(close64(1e9, 1e9 + 1.0, 1e-6));
        assert!(!close64(1.0, 2.0, 1e-6));
    }
}
