//! `decfl` — fully decentralized federated learning for EHR (CLI).
//!
//! Subcommands map 1:1 to DESIGN.md §5's experiment index; `train` is the
//! general driver.  Run `decfl help` for usage.

use anyhow::{bail, Result};
use decfl::cli::{apply_common_overrides, Args};
use decfl::config::{AlgoKind, ExperimentConfig};
use decfl::experiments::{
    asynchrony, churn, compress, fig1, fig2, robust, shard, speedup, stragglers, sweeps,
};

const HELP: &str = "\
decfl — fully decentralized federated learning for electronic health records
(reproduction of Lu, Zhang, Wang & Mack, 2019)

USAGE: decfl <subcommand> [options]

SUBCOMMANDS
  train       train one algorithm and print/dump the metric log
  fig2        EXP-F2: DSGD vs DSGT vs FD-DSGD vs FD-DSGT per comm round
  graph       EXP-F1L: hospital network (layout, DOT, spectral stats)
  tsne        EXP-F1R: t-SNE of three hospitals + silhouette
  speedup     EXP-T1: Theorem 1 linear-speedup sweep over N (native backend)
  qsweep      EXP-A1: local-period Q sweep
  topology    EXP-A2: topology / spectral-gap sweep
  hetero      EXP-A3: heterogeneity sweep (DSGD vs DSGT)
  baselines   EXP-A4: FD-DSGT vs FedAvg vs centralized
  churn       EXP-N1: time-varying networks (rewire / edge-drop / churn)
              vs the static baseline (--drops, --churns, --rewire-every)
  compress    EXP-C1: accuracy-vs-bytes frontier — gossip compressors
              (q8 / q4 / top-k, difference-form update) × topologies
              (--compressors, --fracs, --topos)
  stragglers  EXP-S1: heterogeneous compute — straggler plans (fixed-tiers /
              lognormal / dropout, τ-weighted gossip) × topologies vs the
              uniform baseline (--plans, --topos, --tiers, --slow-frac,
              --sigma)
  async       EXP-AS1: wall-clock-vs-accuracy frontier — sync barrier vs
              asynchronous event-driven gossip under straggler plans
              (--stalenesses, --topos; compute plan defaults to lognormal)
  robust      EXP-R1: Byzantine robustness — accuracy vs attacker fraction
              × combine rule × topology, with an attack-free plain-mean
              baseline per topology (--rules, --fracs, --topos; the attack
              plan defaults to sign-flip, shape it with --attack-plan /
              --attack-scale / --attack-age, layer DP with --dp-*)
  shard       EXP-SH1: node-state residency vs fleet size — spill-backed
              sharded slabs vs resident stacks, with a bitwise trajectory
              check up to --compare-max nodes (--ns, --shard-nodes,
              --hot-shards)
  export-data write the synthetic cohort as per-hospital CSVs
  info        print artifact manifest + config summary
  help        this text

COMMON OPTIONS (train + experiments)
  --config <file>         TOML config (defaults reproduce the paper: N=20,
                          m=20, Q=100, alpha0=0.02, d=42)
  --algo <name>           dsgd|dsgt|fd-dsgd|fd-dsgt|fedavg|centralized
  --mode <m>              fused|actors          (default fused)
  --driver <d>            sync|async — global round barrier (the pinned
                          oracle) or the event-driven virtual-time runtime
                          (default sync; gossip algorithms only)
  --staleness-s <s>       async staleness cap in simulated seconds: older
                          neighbor states fold into the self-weight
                          (default 0 = uncapped)
  --sim-budget-s <s>      async simulated-time budget: keep cycling until the
                          virtual clock passes this horizon instead of
                          stopping after steps/q cycles (default 0 = off)
  --net-validate <l>      Assumption-1 spectral-check effort at assembly:
                          full|approx|skip (default full; symmetry/row-sum
                          checks always run)
  --backend <b>           pjrt|native           (default pjrt)
  --steps <T>             total local iterations (default 10000)
  --q <Q>                 local period          (default 100)
  --alpha0 <a>            lr scale              (default 0.02)
  --topology <t>          ring|path|torus|complete|star|er|rgg|smallworld
  --mixing <s>            metropolis|lazy|maxdeg
  --net-plan <p>          static|rewire|edge-drop|churn — how the network
                          evolves per round (default static)
  --rewire-every <r>      rewire cadence in comm rounds   (default 5)
  --edge-drop <p>         per-edge drop prob per round    (default 0.2)
  --churn <p>             per-node offline prob per round (default 0.1)
  --drop-prob <p>         frame-loss prob on every link (actors mode only;
                          lost frames are retransmitted)
  --compute-plan <p>      uniform|fixed-tiers|lognormal|dropout — per-node
                          local work per round (default uniform; gossip
                          algorithms + native backend only; non-uniform
                          plans use τ-weighted FedNova-style gossip)
  --tiers <list>          tier speeds in (0,1] for fixed-tiers
                          (default 1.0,0.5; node i runs at tiers[i mod len])
  --slow-frac <p>         per-round preemption prob for dropout (default .25)
  --sigma <s>             lognormal σ of the per-round speed (default 0.5)
  --attack-plan <p>       none|sign-flip|scaled-noise|stale-replay — Byzantine
                          message perturbation at the encode boundary
                          (default none; gossip algorithms + native backend)
  --attack-frac <f>       attacker fraction in [0,1); the attacker set is
                          pure in (seed, round, node) (default 0)
  --attack-scale <s>      noise multiplier for scaled-noise (default 3.0)
  --attack-age <r>        replay age in rounds for stale-replay (default 5)
  --robust-rule <r>       mean|trimmed-mean|median|krum — neighbor combine
                          rule (default mean, the paper's pinned combine)
  --robust-trim <t>       trim fraction in [0,0.5) for trimmed-mean / krum
                          (default 0.2)
  --dp <d>                off|gaussian — per-message L2 clip + calibrated
                          noise with an (ε, δ) accountant reported per eval
                          row (default off)
  --dp-clip <c>           DP L2 clip bound (default 1.0)
  --dp-sigma <s>          DP noise multiplier σ (default 1.0)
  --dp-delta <d>          DP accountant δ (default 1e-5)
  --compress <c>          gossip payload compressor: none|identity|q8|q4|topk
                          (default none; gossip algorithms only; the update
                          uses the mean-preserving difference form)
  --topk-frac <f>         kept fraction for --compress topk (default 0.1)
  --error-feedback        opt-in EF residuals on the message streams
                          (experimental; destabilizes aggressive top-k)
  --shard-nodes <k>       shard per-node state into k-node slabs backed by a
                          spill file, keeping only the hot-set resident
                          (default 0 = unsharded resident stacks, the pinned
                          path; gossip + native + fused sync only; the
                          sharded trajectory is bitwise identical)
  --hot-shards <h>        resident shard frames in the LRU hot-set when
                          --shard-nodes > 0 (default 4; peak slab residency
                          is h·k rows at any fleet size)
  --heterogeneity <h>     data non-iidness in [0,1] (default 0.6)
  --seed <s>              RNG seed (default 7)
  --threads <k>           native-backend worker threads, 0 = one per core
                          (default 0; results identical at any k)
  --eval-every <k>        evaluate every k comm rounds
  --artifacts <dir>       artifact dir (default artifacts/)
  --out <file>            dump metrics/results JSON

EXAMPLES
  decfl train --algo fd-dsgt --steps 10000 --q 100
  decfl train --backend native --net-plan churn --churn 0.2 --steps 2000
  decfl train --backend native --compress q8 --steps 2000
  decfl train --backend native --compute-plan dropout --slow-frac 0.3 --steps 2000
  decfl stragglers --backend native --steps 2000 --q 50 --topos ring,er
  decfl train --backend native --driver async --compute-plan lognormal --steps 2000
  decfl async --backend native --steps 2000 --q 50 --sigma 0.8 --out frontier.json
  decfl train --backend native --attack-plan sign-flip --attack-frac 0.2 \\
              --robust-rule trimmed-mean --steps 2000
  decfl robust --backend native --steps 2000 --q 50 --fracs 0.1,0.2
  decfl train --backend native --dp gaussian --dp-clip 0.5 --steps 2000
  decfl train --backend native --shard-nodes 64 --hot-shards 4 --steps 2000
  decfl shard --backend native --ns 32,128,512 --steps 400 --q 20
  decfl fig2 --backend native --steps 2000 --q 50 --out fig2.json
  decfl churn --backend native --steps 2000 --q 50 --drops 0.2,0.4
  decfl compress --backend native --steps 2000 --q 50 --fracs 0.1,0.05
  decfl speedup --ns 4,8,16,32 --steps 400
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    if args.has_flag("help") || sub == "help" {
        print!("{HELP}");
        return Ok(());
    }

    let mut cfg = ExperimentConfig::default();
    apply_common_overrides(&args, &mut cfg)?;

    match sub.as_str() {
        "train" => {
            args.finish()?;
            cfg.validate()?;
            reject_ignored_network_flags(&args, &cfg)?;
            eprintln!(
                "training {} (mode {:?}, backend {:?}): N={} Q={} T={} on {} topology",
                cfg.algo.name(), cfg.mode, cfg.backend, cfg.n,
                cfg.algo.effective_q(cfg.q), cfg.total_steps, cfg.topology
            );
            let log = decfl::coordinator::run(&cfg)?;
            print!("{}", log.to_csv());
            summary(&log);
            dump(&cfg.out, &log.to_json())?;
        }
        "fig2" => {
            args.finish()?;
            cfg.validate()?;
            let res = fig2::run(&cfg)?;
            res.print_table();
            for f in res.findings() {
                println!("finding: {f}");
            }
            dump(&cfg.out, &res.to_json())?;
        }
        "graph" => {
            reject_plan_flags(&args, &cfg, "graph")?;
            let dot_path = args.get_str("dot").map(str::to_string);
            args.finish()?;
            let rep = fig1::hospital_graph(&cfg)?;
            rep.print_summary();
            if let Some(path) = dot_path {
                std::fs::write(&path, &rep.dot)?;
                eprintln!("wrote DOT to {path}");
            }
            dump(&cfg.out, &rep.to_json())?;
        }
        "tsne" => {
            reject_plan_flags(&args, &cfg, "tsne")?;
            let hospitals = args
                .get_usize_list("hospitals")?
                .unwrap_or_else(|| vec![0, 1, 2]);
            let per = args.get_usize("per-hospital")?.unwrap_or(150);
            let perplexity = args.get_f64("perplexity")?.unwrap_or(30.0);
            args.finish()?;
            let rep = fig1::tsne_hospitals(&cfg, &hospitals, per, perplexity)?;
            rep.print_summary();
            dump(&cfg.out, &rep.to_json())?;
        }
        "speedup" => {
            reject_plan_flags(&args, &cfg, "speedup")?;
            let ns = args.get_usize_list("ns")?.unwrap_or_else(|| vec![4, 8, 16, 32]);
            let seeds = args
                .get_usize_list("seeds")?
                .unwrap_or_else(|| vec![7, 8, 9])
                .into_iter()
                .map(|s| s as u64)
                .collect::<Vec<_>>();
            args.finish()?;
            let res = speedup::run(&ns, cfg.total_steps.min(2000), &seeds)?;
            res.print_table();
            println!(
                "linear-speedup consistent: {}",
                if res.supports_linear_speedup() { "YES" } else { "NO" }
            );
            dump(&cfg.out, &res.to_json())?;
        }
        "qsweep" => {
            reject_plan_flags(&args, &cfg, "qsweep")?;
            let qs = args.get_usize_list("qs")?.unwrap_or_else(|| vec![1, 5, 20, 100, 500]);
            let target = args.get_f64("target")?.unwrap_or(0.45);
            args.finish()?;
            let rows = sweeps::q_sweep(&qs, cfg.total_steps, target, cfg.seed)?;
            sweeps::print_q_table(&rows, target);
            dump(&cfg.out, &sweeps::rows_to_json(&rows, sweeps::q_row_json))?;
        }
        "topology" => {
            reject_plan_flags(&args, &cfg, "topology")?;
            args.finish()?;
            let rows = sweeps::topology_sweep(
                &["path", "ring", "rgg", "er", "torus", "complete"],
                cfg.total_steps,
                cfg.seed,
            )?;
            sweeps::print_topology_table(&rows);
        }
        "hetero" => {
            reject_plan_flags(&args, &cfg, "hetero")?;
            let hets = args.get_f64_list("hets")?.unwrap_or_else(|| vec![0.0, 0.3, 0.6, 1.0]);
            args.finish()?;
            let rows = sweeps::hetero_sweep(&hets, cfg.total_steps, &[cfg.seed, cfg.seed + 1])?;
            sweeps::print_hetero_table(&rows);
        }
        "baselines" => {
            reject_plan_flags(&args, &cfg, "baselines")?;
            args.finish()?;
            let rows = sweeps::baseline_compare(cfg.total_steps, cfg.q, cfg.seed)?;
            sweeps::print_baseline_table(&rows);
        }
        "churn" => {
            let drops = args.get_f64_list("drops")?.unwrap_or_else(|| vec![0.2, 0.4]);
            let churns = args.get_f64_list("churns")?.unwrap_or_else(|| vec![0.1, 0.3]);
            args.finish()?;
            if matches!(cfg.algo, AlgoKind::FedAvg | AlgoKind::Centralized) {
                bail!(
                    "`decfl churn` sweeps gossip network plans, but `{}` has no gossip \
                     network; pick dsgd|dsgt|fd-dsgd|fd-dsgt",
                    cfg.algo.name()
                );
            }
            // the sweep owns the plan axis — these would be silently overwritten
            for key in ["net-plan", "edge-drop", "churn"] {
                if args.provided(key) {
                    bail!(
                        "--{key} was passed, but `decfl churn` sweeps the plan axis \
                         itself and would silently ignore it; shape the sweep with \
                         --drops / --churns / --rewire-every instead"
                    );
                }
            }
            if cfg.net_plan != "static" {
                bail!(
                    "the config sets net.plan = `{}`, but `decfl churn` sweeps the \
                     plan axis itself and would silently ignore it; shape the sweep \
                     with --drops / --churns / --rewire-every instead",
                    cfg.net_plan
                );
            }
            let rows = churn::run(&cfg, &drops, &churns)?;
            churn::print_table(&rows);
            for f in churn::findings(&rows) {
                println!("finding: {f}");
            }
            dump(&cfg.out, &churn::rows_json(&rows))?;
        }
        "compress" => {
            let compressors = args
                .get_str("compressors")
                .map(|v| v.split(',').map(|s| s.trim().to_string()).collect::<Vec<_>>())
                .unwrap_or_else(|| vec!["q8".into(), "q4".into()]);
            let fracs = args.get_f64_list("fracs")?.unwrap_or_else(|| vec![0.1, 0.05]);
            let topos = args
                .get_str("topos")
                .map(|v| v.split(',').map(|s| s.trim().to_string()).collect::<Vec<_>>())
                .unwrap_or_else(|| vec![cfg.topology.clone()]);
            args.finish()?;
            if matches!(cfg.algo, AlgoKind::FedAvg | AlgoKind::Centralized) {
                bail!(
                    "`decfl compress` sweeps gossip compressors, but `{}` has no gossip \
                     messages; pick dsgd|dsgt|fd-dsgd|fd-dsgt",
                    cfg.algo.name()
                );
            }
            // the sweep owns the compressor axis — these would be overwritten
            for key in ["compress", "topk-frac"] {
                if args.provided(key) {
                    bail!(
                        "--{key} was passed, but `decfl compress` sweeps the compressor \
                         axis itself and would silently ignore it; shape the sweep with \
                         --compressors / --fracs / --topos instead"
                    );
                }
            }
            if cfg.compress != "none" {
                bail!(
                    "the config sets comm.compress = `{}`, but `decfl compress` sweeps \
                     the compressor axis itself and would silently ignore it; shape the \
                     sweep with --compressors / --fracs / --topos instead",
                    cfg.compress
                );
            }
            let rows = compress::run(&cfg, &compressors, &fracs, &topos)?;
            compress::print_table(&rows);
            for f in compress::findings(&rows) {
                println!("finding: {f}");
            }
            dump(&cfg.out, &compress::rows_json(&rows))?;
        }
        "stragglers" => {
            let plans = args
                .get_str("plans")
                .map(|v| v.split(',').map(|s| s.trim().to_string()).collect::<Vec<_>>())
                .unwrap_or_else(|| {
                    vec!["fixed-tiers".into(), "lognormal".into(), "dropout".into()]
                });
            let topos = args
                .get_str("topos")
                .map(|v| v.split(',').map(|s| s.trim().to_string()).collect::<Vec<_>>())
                .unwrap_or_else(|| vec![cfg.topology.clone()]);
            args.finish()?;
            if matches!(cfg.algo, AlgoKind::FedAvg | AlgoKind::Centralized) {
                bail!(
                    "`decfl stragglers` sweeps gossip compute plans, but `{}` runs the \
                     paper's synchronous baseline; pick dsgd|dsgt|fd-dsgd|fd-dsgt",
                    cfg.algo.name()
                );
            }
            // the sweep owns the plan axis — this would be silently overwritten
            if args.provided("compute-plan") {
                bail!(
                    "--compute-plan was passed, but `decfl stragglers` sweeps the plan \
                     axis itself and would silently ignore it; shape the sweep with \
                     --plans / --tiers / --slow-frac / --sigma instead"
                );
            }
            if cfg.compute_plan != "uniform" {
                bail!(
                    "the config sets compute.plan = `{}`, but `decfl stragglers` sweeps \
                     the plan axis itself and would silently ignore it; shape the sweep \
                     with --plans / --tiers / --slow-frac / --sigma instead",
                    cfg.compute_plan
                );
            }
            let rows = stragglers::run(&cfg, &plans, &topos)?;
            stragglers::print_table(&rows);
            for f in stragglers::findings(&rows) {
                println!("finding: {f}");
            }
            dump(&cfg.out, &stragglers::rows_json(&rows))?;
        }
        "async" => {
            let stalenesses = args.get_f64_list("stalenesses")?.unwrap_or_else(|| vec![0.0]);
            let topos = args
                .get_str("topos")
                .map(|v| v.split(',').map(|s| s.trim().to_string()).collect::<Vec<_>>())
                .unwrap_or_else(|| vec![cfg.topology.clone()]);
            let plan_shaped = args.provided("compute-plan");
            args.finish()?;
            if matches!(cfg.algo, AlgoKind::FedAvg | AlgoKind::Centralized) {
                bail!(
                    "`decfl async` compares gossip drivers, but `{}` runs the paper's \
                     synchronous baseline protocol; pick dsgd|dsgt|fd-dsgd|fd-dsgt",
                    cfg.algo.name()
                );
            }
            // the sweep owns the driver axis — these would be overwritten
            for key in ["driver", "staleness-s", "sim-budget-s"] {
                if args.provided(key) {
                    bail!(
                        "--{key} was passed, but `decfl async` sweeps the driver axis \
                         itself and would silently ignore it; shape the sweep with \
                         --stalenesses / --topos instead"
                    );
                }
            }
            if cfg.driver != "sync" || cfg.staleness_s != 0.0 || cfg.sim_budget_s != 0.0 {
                bail!(
                    "the config sets run.driver/staleness, but `decfl async` sweeps the \
                     driver axis itself and would silently ignore it; shape the sweep \
                     with --stalenesses / --topos instead"
                );
            }
            // the frontier is only interesting under heterogeneous compute:
            // default the plan to lognormal unless the user shaped it
            if !plan_shaped && cfg.compute_plan == "uniform" {
                cfg.compute_plan = "lognormal".into();
            }
            let rows = asynchrony::run(&cfg, &stalenesses, &topos)?;
            asynchrony::print_table(&rows);
            for f in asynchrony::findings(&rows) {
                println!("finding: {f}");
            }
            dump(&cfg.out, &asynchrony::rows_json(&rows))?;
        }
        "robust" => {
            let rules = args
                .get_str("rules")
                .map(|v| v.split(',').map(|s| s.trim().to_string()).collect::<Vec<_>>())
                .unwrap_or_else(|| {
                    vec!["mean".into(), "trimmed-mean".into(), "median".into()]
                });
            let fracs = args.get_f64_list("fracs")?.unwrap_or_else(|| vec![0.1, 0.2]);
            let topos = args
                .get_str("topos")
                .map(|v| v.split(',').map(|s| s.trim().to_string()).collect::<Vec<_>>())
                .unwrap_or_else(|| vec!["er".into(), "ring".into()]);
            let trim_shaped = args.provided("robust-trim");
            args.finish()?;
            if matches!(cfg.algo, AlgoKind::FedAvg | AlgoKind::Centralized) {
                bail!(
                    "`decfl robust` sweeps gossip combine rules, but `{}` has no gossip \
                     combine; pick dsgd|dsgt|fd-dsgd|fd-dsgt",
                    cfg.algo.name()
                );
            }
            // the sweep owns the attacker-fraction and rule axes — these
            // would be silently overwritten
            for key in ["attack-frac", "robust-rule"] {
                if args.provided(key) {
                    bail!(
                        "--{key} was passed, but `decfl robust` sweeps that axis itself \
                         and would silently ignore it; shape the sweep with \
                         --rules / --fracs / --topos instead"
                    );
                }
            }
            if cfg.attack_frac != 0.0 || cfg.robust_rule != "mean" {
                bail!(
                    "the config sets attack.frac = {} / robust.rule = `{}`, but \
                     `decfl robust` sweeps those axes itself and would silently \
                     ignore them; shape the sweep with --rules / --fracs / --topos",
                    cfg.attack_frac,
                    cfg.robust_rule
                );
            }
            // the frontier needs an adversary: default to sign-flip unless
            // the user shaped the attack
            if cfg.attack_plan == "none" {
                cfg.attack_plan = "sign-flip".into();
            }
            // ⌊trim·k⌋ trims nothing below trim = 1/3 on degree-2 rows
            // (ring rows mix k = 3 participants): default the trim high
            // enough to engage everywhere unless the user shaped it
            if !trim_shaped && cfg.robust_trim == 0.2 {
                cfg.robust_trim = 0.4;
            }
            let rows = robust::run(&cfg, &rules, &fracs, &topos)?;
            robust::print_table(&rows);
            for f in robust::findings(&rows) {
                println!("finding: {f}");
            }
            dump(&cfg.out, &robust::rows_json(&rows))?;
        }
        "shard" => {
            let ns = args.get_usize_list("ns")?.unwrap_or_else(|| vec![32, 128, 512]);
            let compare_max = args.get_usize("compare-max")?.unwrap_or(128);
            args.finish()?;
            if matches!(cfg.algo, AlgoKind::FedAvg | AlgoKind::Centralized) {
                bail!(
                    "`decfl shard` sweeps sharded gossip state, but `{}` keeps \
                     co-resident server state; pick dsgd|dsgt|fd-dsgd|fd-dsgt",
                    cfg.algo.name()
                );
            }
            let rows = shard::run(&cfg, &ns, compare_max)?;
            shard::print_table(&rows);
            for f in shard::findings(&rows) {
                println!("finding: {f}");
            }
            dump(&cfg.out, &shard::rows_json(&rows))?;
        }
        "export-data" => {
            reject_plan_flags(&args, &cfg, "export-data")?;
            let dir = args.get_str("dir").unwrap_or("out/cohort").to_string();
            args.finish()?;
            let asm = decfl::coordinator::assemble(&cfg)?;
            asm.ds.export_csv(std::path::Path::new(&dir))?;
            println!(
                "wrote {} hospitals ({} records, prevalence {:.3}, site divergence {:.3}) to {dir}",
                asm.ds.n_hospitals(),
                asm.ds.total_records(),
                asm.ds.global_prevalence(),
                asm.ds.site_divergence()
            );
        }
        "info" => {
            reject_plan_flags(&args, &cfg, "info")?;
            args.finish()?;
            let manifest =
                decfl::runtime::Manifest::load(std::path::Path::new(&cfg.artifacts_dir))?;
            let s = manifest.shapes;
            println!("artifacts: {}", cfg.artifacts_dir);
            println!("  model: d={} hidden={} P={} | N={} m={} Q={} shard={}",
                s.d, s.hidden, s.p, s.n, s.m, s.q, s.shard);
            for (name, spec) in &manifest.artifacts {
                println!("  {name:12} {} in → {} out  ({})",
                    spec.inputs.len(), spec.outputs.len(), spec.file);
            }
        }
        other => bail!("unknown subcommand `{other}` (try `decfl help`)"),
    }
    Ok(())
}

/// The sweep/report subcommands build their own per-run configs and would
/// silently run static uncompressed networks no matter what plan or
/// compression settings arrived — fail loudly, whether the setting came as a
/// CLI flag or through `--config` TOML, and point at the subcommands that do
/// honor them.
fn reject_plan_flags(args: &Args, cfg: &ExperimentConfig, sub: &str) -> Result<()> {
    for key in ["net-plan", "rewire-every", "edge-drop", "churn"] {
        if args.provided(key) {
            bail!(
                "--{key} was passed, but `decfl {sub}` runs its own fixed network \
                 setup and would silently ignore it; network plans apply to \
                 `decfl train` and `decfl churn`"
            );
        }
    }
    if cfg.net_plan != "static" {
        bail!(
            "the config sets net.plan = `{}`, but `decfl {sub}` runs its own fixed \
             network setup and would silently ignore it; network plans apply to \
             `decfl train` and `decfl churn`",
            cfg.net_plan
        );
    }
    for key in ["compress", "topk-frac", "error-feedback"] {
        if args.provided(key) {
            bail!(
                "--{key} was passed, but `decfl {sub}` builds its own per-run configs \
                 and would silently gossip dense f32; compression applies to \
                 `decfl train`, `decfl fig2`, `decfl churn`, and `decfl compress`"
            );
        }
    }
    if cfg.compress != "none" {
        bail!(
            "the config sets comm.compress = `{}`, but `decfl {sub}` builds its own \
             per-run configs and would silently gossip dense f32; compression applies \
             to `decfl train`, `decfl fig2`, `decfl churn`, and `decfl compress`",
            cfg.compress
        );
    }
    for key in ["compute-plan", "tiers", "slow-frac", "sigma"] {
        if args.provided(key) {
            bail!(
                "--{key} was passed, but `decfl {sub}` builds its own per-run configs \
                 and would silently run every node at uniform Q; straggler plans apply \
                 to `decfl train`, `decfl churn`, `decfl compress`, and `decfl stragglers`"
            );
        }
    }
    for key in ["driver", "staleness-s", "sim-budget-s"] {
        if args.provided(key) {
            bail!(
                "--{key} was passed, but `decfl {sub}` builds its own per-run configs \
                 and would silently run the synchronous driver; the async runtime \
                 applies to `decfl train` and `decfl async`"
            );
        }
    }
    if cfg.driver != "sync" {
        bail!(
            "the config sets run.driver = `{}`, but `decfl {sub}` builds its own \
             per-run configs and would silently run the synchronous driver; the async \
             runtime applies to `decfl train` and `decfl async`",
            cfg.driver
        );
    }
    if cfg.compute_plan != "uniform" {
        bail!(
            "the config sets compute.plan = `{}`, but `decfl {sub}` builds its own \
             per-run configs and would silently run every node at uniform Q; straggler \
             plans apply to `decfl train`, `decfl churn`, `decfl compress`, and \
             `decfl stragglers`",
            cfg.compute_plan
        );
    }
    for key in [
        "attack-plan",
        "attack-frac",
        "attack-scale",
        "attack-age",
        "robust-rule",
        "robust-trim",
        "dp",
        "dp-clip",
        "dp-sigma",
        "dp-delta",
    ] {
        if args.provided(key) {
            bail!(
                "--{key} was passed, but `decfl {sub}` builds its own per-run configs \
                 and would silently run honest plain-mean gossip; the adversarial and \
                 DP axes apply to `decfl train` and `decfl robust`"
            );
        }
    }
    if cfg.attack_plan != "none" || cfg.robust_rule != "mean" || cfg.dp != "off" {
        bail!(
            "the config sets attack.plan/robust.rule/dp = `{}`/`{}`/`{}`, but \
             `decfl {sub}` builds its own per-run configs and would silently run \
             honest plain-mean gossip; the adversarial and DP axes apply to \
             `decfl train` and `decfl robust`",
            cfg.attack_plan,
            cfg.robust_rule,
            cfg.dp
        );
    }
    Ok(())
}

/// FedAvg runs a fixed star and the fusion center has no network at all —
/// network-shaping flags would be silently ignored there, so fail loudly
/// instead (mirrors the engine-level `drop_prob` / `net_plan` bails, which
/// cannot see whether a flag was explicitly passed).
fn reject_ignored_network_flags(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    if !matches!(cfg.algo, AlgoKind::FedAvg | AlgoKind::Centralized) {
        return Ok(());
    }
    let what = match cfg.algo {
        AlgoKind::FedAvg => "a fixed star network",
        _ => "a fusion center with no gossip network",
    };
    for key in [
        "topology",
        "mixing",
        "net-plan",
        "rewire-every",
        "edge-drop",
        "churn",
        "compress",
        "topk-frac",
        "error-feedback",
        "compute-plan",
        "tiers",
        "slow-frac",
        "sigma",
        "driver",
        "staleness-s",
        "sim-budget-s",
        "attack-plan",
        "attack-frac",
        "attack-scale",
        "attack-age",
        "robust-rule",
        "robust-trim",
        "dp",
        "dp-clip",
        "dp-sigma",
        "dp-delta",
    ] {
        if args.provided(key) {
            bail!(
                "--{key} was passed, but `{}` runs {what} and would silently ignore it; \
                 drop the flag or pick a gossip algorithm (dsgd|dsgt|fd-dsgd|fd-dsgt)",
                cfg.algo.name()
            );
        }
    }
    Ok(())
}

fn summary(log: &decfl::metrics::RunLog) {
    if let Some(last) = log.last() {
        eprintln!(
            "final: round {} | loss {:.4} acc {:.3} | stationarity {:.3e} consensus {:.3e} | {:.1} MB, {} msgs, sim {:.1}s, wall {:.1}s",
            last.comm_rounds,
            last.loss,
            last.accuracy,
            last.stationarity,
            last.consensus,
            last.bytes as f64 / 1e6,
            last.messages,
            last.sim_time_s,
            last.wall_time_s,
        );
    }
}

fn dump(out: &Option<String>, json: &decfl::jsonl::Json) -> Result<()> {
    if let Some(path) = out {
        std::fs::write(path, json.to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}
