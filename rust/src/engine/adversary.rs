//! Adversarial gossip: Byzantine attack plans, the per-message perturbation
//! pipeline, and the (ε, δ) differential-privacy accountant.
//!
//! Every scenario axis so far (net plan × compression × compute plan ×
//! driver) assumes neighbors are honest and finite.  A hospital federation
//! cannot: DeceFL treats robustness to faulty/malicious participants as a
//! first-class property of decentralized FL, and formal privacy is table
//! stakes for health data.  This module adds the adversarial axis the same
//! way `graph::schedule` added the network axis and `engine::stragglers`
//! added the compute axis — as a deterministic scheduled quantity derived
//! purely from `(seed, round, node)`, so every driver (fused, actors, async)
//! reconstructs the identical adversary independently (§7 determinism
//! contract).
//!
//! **Attack surface.**  Attacks are applied at the *message-encode boundary*
//! — the last point a node touches its outgoing payload before it hits the
//! wire (pre-quantization, so they compose with q8/q4/top-k exactly like a
//! real malicious sender would).  The attacker corrupts what it *sends*, and
//! — like the CHOCO x̂ semantics — its own combine consumes the corrupted
//! copy too: a Byzantine node drinks its own poison.  Honest nodes' local
//! dynamics are untouched.
//!
//! Plans ([`AttackPlan`]):
//!
//! - `none` — today's behavior; the perturbation pipeline is never built and
//!   every code path stays byte-for-byte identical to the honest engine.
//! - `sign-flip` — attackers broadcast `−θ` (resp. `−ϑ`): the classic
//!   gradient-reversal Byzantine model.
//! - `scaled-noise` — attackers add `scale · N(0, I)` to each outgoing
//!   message, drawn from a `(seed, round, node, kind)`-keyed stream.
//! - `stale-replay` — attackers re-send their message from up to `age − 1`
//!   rounds ago, refreshing the replayed copy every `age` rounds.
//!
//! Attacker membership is *static*: exactly `max(1, round(frac · n))` nodes
//! are Byzantine for the whole run, sampled once from the seed (a persistent
//! adversary, the model Krum-style screening is designed for; a per-round
//! membership redraw would let every rule trivially outvote the attacker).
//!
//! **DP layer ([`DpPlan`]).**  Orthogonal to the attack axis: with
//! `dp.mode = gaussian` every outgoing message is L2-clipped to `dp.clip`
//! and perturbed with `N(0, (σ·clip)²·I)` noise from a
//! `(seed, round, node, kind)`-keyed stream (deterministic like the
//! quantizers' stochastic rounding, so runs replay bitwise).  The privacy
//! loss of the composed releases is reported per run by
//! [`DpPlan::epsilon`], the *analytic Gaussian mechanism* accountant
//! (Balle & Wang, 2018): `k` releases at noise multiplier σ compose to a
//! single Gaussian mechanism at `σ/√k`, whose exact (ε, δ) curve is
//! inverted by bisection.  It sits next to the byte accountant: bytes tell
//! you what a run cost the network, ε tells you what it cost the patients.
//!
//! **What stays pinned.**  `attack.plan = none` + `dp = off` (the defaults)
//! build no [`MsgPerturb`] at all — [`MsgPerturb::from_config`] returns
//! `None` and the drivers keep their legacy paths bitwise.  Any active
//! adversary or DP mode is allowed to move the trajectory, but is
//! replay-deterministic across runs and thread counts.

use crate::config::ExperimentConfig;
use crate::rng::Pcg64;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// RNG stream tag for the one-time attacker-membership draw.
const STREAM_ATTACK_MEMBER: u64 = 0xB12A_170C_4E01;
/// RNG stream tag for per-`(round, node, kind)` attack perturbation draws.
const STREAM_ATTACK_DRAW: u64 = 0xB12A_170C_4E02;
/// RNG stream tag for per-`(round, node, kind)` DP noise draws.
const STREAM_DP: u64 = 0xD9_057A_7E00;
/// Odd multiplier decorrelating the round index inside a stream tag.
const ROUND_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// What a Byzantine node does to its outgoing messages.
#[derive(Clone, Debug, PartialEq)]
pub enum AttackPlan {
    /// No adversary: the honest engine, byte for byte.
    None,
    /// Attackers broadcast the negated message (gradient reversal).
    SignFlip,
    /// Attackers add `scale · N(0, I)` to each outgoing message.
    ScaledNoise {
        /// Per-coordinate noise scale (> 0).
        scale: f64,
    },
    /// Attackers re-send a stale copy of their own message, refreshed every
    /// `age` rounds (so replayed payloads are up to `age − 1` rounds old).
    StaleReplay {
        /// Refresh period in rounds (≥ 2; `age = 1` would replay nothing).
        age: usize,
    },
}

impl AttackPlan {
    /// Short display label (experiment tables, logs).
    pub fn label(&self) -> String {
        match self {
            AttackPlan::None => "none".into(),
            AttackPlan::SignFlip => "sign-flip".into(),
            AttackPlan::ScaledNoise { scale } => format!("scaled-noise {scale:.1}"),
            AttackPlan::StaleReplay { age } => format!("stale-replay @{age}"),
        }
    }
}

/// Cheap non-validating predicate: does the config request *any*
/// perturbation (attack or DP)?  Drivers consult this when sizing the
/// encode-path slabs, before the validated pipeline is built.
pub fn perturb_active(cfg: &ExperimentConfig) -> bool {
    cfg.attack_plan != "none" || cfg.dp != "off"
}

/// Parse the `attack.*` section of a config (shared by
/// `ExperimentConfig::validate` and [`AttackSchedule::from_config`]).
pub fn plan_from_config(cfg: &ExperimentConfig) -> Result<AttackPlan> {
    let plan = match cfg.attack_plan.as_str() {
        "none" => {
            if cfg.attack_frac != 0.0 {
                bail!(
                    "attack.frac = {} but attack.plan = none; set a plan or drop the fraction",
                    cfg.attack_frac
                );
            }
            return Ok(AttackPlan::None);
        }
        "sign-flip" | "signflip" => AttackPlan::SignFlip,
        "scaled-noise" | "noise" => {
            if !cfg.attack_scale.is_finite() || cfg.attack_scale <= 0.0 {
                bail!("attack.scale must be > 0, got {}", cfg.attack_scale);
            }
            AttackPlan::ScaledNoise { scale: cfg.attack_scale }
        }
        "stale-replay" | "replay" => {
            if cfg.attack_age < 2 {
                bail!(
                    "attack.age must be >= 2 (age 1 replays nothing), got {}",
                    cfg.attack_age
                );
            }
            AttackPlan::StaleReplay { age: cfg.attack_age }
        }
        other => bail!("unknown attack plan `{other}` (none|sign-flip|scaled-noise|stale-replay)"),
    };
    if !(cfg.attack_frac > 0.0 && cfg.attack_frac <= 1.0) {
        bail!(
            "attack.plan = {} needs attack.frac in (0, 1], got {}",
            cfg.attack_plan,
            cfg.attack_frac
        );
    }
    Ok(plan)
}

/// Deterministic Byzantine-membership schedule over `n` nodes.  Pure
/// function of `(seed, plan, frac, n)`: every caller — the sync driver, each
/// actor node thread, the async simulator, a test — derives the identical
/// attacker set and identical per-round perturbation draws.
///
/// # Examples
///
/// ```
/// use decfl::engine::{AttackPlan, AttackSchedule};
///
/// let s = AttackSchedule::new(AttackPlan::SignFlip, 0.2, 10, 7).unwrap();
/// assert_eq!(s.attackers(), 2);                     // exactly round(0.2·10)
/// let again = AttackSchedule::new(AttackPlan::SignFlip, 0.2, 10, 7).unwrap();
/// assert_eq!(
///     (0..10).filter(|&i| s.is_attacker(i)).count(),
///     (0..10).filter(|&i| again.is_attacker(i)).count(),
/// );
/// ```
#[derive(Clone, Debug)]
pub struct AttackSchedule {
    plan: AttackPlan,
    n: usize,
    seed: u64,
    byzantine: Vec<bool>,
}

impl AttackSchedule {
    /// Schedule over `n` nodes with attacker fraction `frac` under `plan`;
    /// `seed` keys the membership draw and every per-round perturbation.
    /// Non-none plans sample exactly `max(1, round(frac · n))` attackers —
    /// a stated fraction > 0 always yields at least one Byzantine node
    /// (silently attacking nobody would misreport the scenario).
    pub fn new(plan: AttackPlan, frac: f64, n: usize, seed: u64) -> Result<Self> {
        if n == 0 {
            bail!("attack schedule over zero nodes");
        }
        let mut byzantine = vec![false; n];
        if plan != AttackPlan::None {
            if !(frac > 0.0 && frac <= 1.0) {
                bail!("attack fraction must be in (0, 1], got {frac}");
            }
            let k = ((frac * n as f64).round() as usize).clamp(1, n);
            let mut rng = Pcg64::new(seed, STREAM_ATTACK_MEMBER);
            for i in rng.sample_indices(n, k) {
                byzantine[i] = true;
            }
        }
        Ok(AttackSchedule { plan, n, seed, byzantine })
    }

    /// Build from a config's `attack.*` section (plan, fraction, n, seed).
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        let plan = plan_from_config(cfg)?;
        AttackSchedule::new(plan, cfg.attack_frac, cfg.n, cfg.seed)
    }

    /// The configured plan.
    pub fn plan(&self) -> &AttackPlan {
        &self.plan
    }

    /// Node count the schedule covers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Is any node Byzantine at all (false ⇔ `plan = none`)?
    pub fn active(&self) -> bool {
        self.plan != AttackPlan::None
    }

    /// Number of Byzantine nodes.
    pub fn attackers(&self) -> usize {
        self.byzantine.iter().filter(|&&b| b).count()
    }

    /// Is node `i` Byzantine?  Membership is static for the whole run.
    pub fn is_attacker(&self, i: usize) -> bool {
        self.byzantine[i]
    }

    /// Fresh RNG for node `i`'s perturbation of `(round, kind)` — one
    /// short-lived stream per `(seed, round, node, kind)`, like the
    /// schedule streams of `graph::schedule` and `engine::stragglers`.
    fn draw_rng(&self, round: usize, i: usize, kind: u8) -> Pcg64 {
        let stream = STREAM_ATTACK_DRAW
            ^ (round as u64).wrapping_mul(ROUND_MIX)
            ^ ((i as u64) << 1)
            ^ ((kind as u64) << 48);
        Pcg64::new(self.seed, stream)
    }
}

/// Per-attacker stale-message store for [`AttackPlan::StaleReplay`].  Keyed
/// by `(node, kind)` and allocated lazily on an attacker's first send, so
/// honest nodes and non-replay plans never touch it.
#[derive(Clone, Debug, Default)]
struct ReplayCache {
    cache: BTreeMap<(usize, u8), Vec<f32>>,
}

impl ReplayCache {
    /// Refresh-or-replay `data` for `(node, kind)` at `round` (1-based):
    /// on refresh rounds (`round % age == 0`) and on the very first send the
    /// current message is stored and sent fresh; otherwise `data` is
    /// overwritten with the stored stale copy.
    fn step(&mut self, node: usize, kind: u8, round: usize, age: usize, data: &mut [f32]) {
        let slot = self.cache.entry((node, kind)).or_default();
        if slot.is_empty() || round % age == 0 {
            slot.clear();
            slot.extend_from_slice(data);
        } else {
            data.copy_from_slice(slot);
        }
    }
}

/// Differential-privacy configuration: per-message L2 clipping plus
/// calibrated Gaussian noise, with the analytic (ε, δ) accountant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DpPlan {
    /// Is the Gaussian mechanism on (`dp.mode = gaussian`)?
    pub on: bool,
    /// L2 clipping norm C applied to every outgoing message (> 0).
    pub clip: f64,
    /// Noise multiplier σ: per-coordinate noise stddev is `σ · C` (> 0).
    pub sigma: f64,
    /// Target δ of the (ε, δ) guarantee, in (0, 1).
    pub delta: f64,
}

impl DpPlan {
    /// The inactive plan (`dp.mode = off`) — ε is identically 0.
    pub fn off() -> Self {
        DpPlan { on: false, clip: 1.0, sigma: 1.0, delta: 1e-5 }
    }

    /// Short display label (experiment tables, logs).
    pub fn label(&self) -> String {
        if self.on {
            format!("gaussian C={:.2} σ={:.2}", self.clip, self.sigma)
        } else {
            "off".into()
        }
    }

    /// Privacy loss ε after `releases` composed Gaussian releases at this
    /// plan's noise multiplier, at the configured δ — the *analytic
    /// Gaussian mechanism* (Balle & Wang, 2018) inverted by bisection.
    ///
    /// `k`-fold composition of the Gaussian mechanism at multiplier σ is
    /// exactly one Gaussian mechanism at `σ′ = σ/√k` (Gaussian noise adds in
    /// variance while the k identical releases add in sensitivity²), whose
    /// privacy curve is
    /// `δ(ε) = Φ(1/(2σ′) − εσ′) − e^ε · Φ(−1/(2σ′) − εσ′)`,
    /// continuous and strictly decreasing in ε.  Returns 0 when the target
    /// δ already covers the curve at ε = 0, and ∞ when the composed noise
    /// is too weak for any finite ε (privacy exhausted).
    pub fn epsilon(&self, releases: u64) -> f64 {
        if !self.on || releases == 0 {
            return 0.0;
        }
        let se = self.sigma / (releases as f64).sqrt();
        let delta_of = |eps: f64| gaussian_mechanism_delta(se, eps);
        if delta_of(0.0) <= self.delta {
            return 0.0;
        }
        let mut hi = 1.0;
        while delta_of(hi) > self.delta {
            hi *= 2.0;
            if hi > 1e12 {
                return f64::INFINITY;
            }
        }
        let (mut lo, mut hi) = (hi / 2.0, hi);
        // δ is monotone: ~200 halvings pin ε to machine precision, far
        // inside the 1e-6 oracle-agreement budget.
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if delta_of(mid) > self.delta {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }
}

/// Parse the `dp.*` section of a config (shared by
/// `ExperimentConfig::validate` and [`MsgPerturb::from_config`]).
pub fn dp_from_config(cfg: &ExperimentConfig) -> Result<DpPlan> {
    let on = match cfg.dp.as_str() {
        "off" => false,
        "gaussian" => true,
        other => bail!("unknown dp mode `{other}` (off|gaussian)"),
    };
    if on {
        if !cfg.dp_clip.is_finite() || cfg.dp_clip <= 0.0 {
            bail!("dp.clip must be > 0, got {}", cfg.dp_clip);
        }
        if !cfg.dp_sigma.is_finite() || cfg.dp_sigma <= 0.0 {
            bail!("dp.sigma must be > 0, got {}", cfg.dp_sigma);
        }
        if !(cfg.dp_delta > 0.0 && cfg.dp_delta < 1.0) {
            bail!("dp.delta must be in (0, 1), got {}", cfg.dp_delta);
        }
    }
    Ok(DpPlan { on, clip: cfg.dp_clip, sigma: cfg.dp_sigma, delta: cfg.dp_delta })
}

/// `δ(ε)` of a single Gaussian mechanism at noise multiplier `sigma`
/// (Balle & Wang, 2018, Theorem 8).  The large-ε tail guards against
/// `e^ε · 0` turning into NaN: once the second Φ underflows the term is
/// exactly 0.
fn gaussian_mechanism_delta(sigma: f64, eps: f64) -> f64 {
    let a = phi(1.0 / (2.0 * sigma) - eps * sigma);
    let p = phi(-1.0 / (2.0 * sigma) - eps * sigma);
    if p == 0.0 {
        a
    } else {
        a - eps.exp() * p
    }
}

/// Standard normal CDF via the Numerical-Recipes erfc approximation
/// (|relative error| < 1.2e-7 — both the accountant and its test oracle go
/// through this same Φ, so their agreement is set by the bisection, not by
/// the approximation).
fn phi(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Complementary error function (Numerical Recipes §6.2 Chebyshev fit).
fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
            .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// The per-message perturbation pipeline every driver applies at its
/// encode boundary: Byzantine attack first (the attacker corrupts its
/// payload), then the DP mechanism (clip + noise on whatever is sent —
/// which means an active DP layer also *bounds* attack magnitudes, exactly
/// as it would in a deployment where the DP module sits below the
/// application).  Built only when something is active:
/// [`MsgPerturb::from_config`] returns `None` for the honest defaults, so
/// the legacy paths never see it.
#[derive(Clone, Debug)]
pub struct MsgPerturb {
    /// The Byzantine membership + perturbation schedule.
    pub attack: AttackSchedule,
    /// The DP clipping/noise configuration.
    pub dp: DpPlan,
    replay: ReplayCache,
}

impl MsgPerturb {
    /// Build the pipeline from a config, or `None` when both the attack
    /// plan and the DP mode are off (the pinned honest path).
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Option<Self>> {
        let attack = AttackSchedule::from_config(cfg)?;
        let dp = dp_from_config(cfg)?;
        if !attack.active() && !dp.on {
            return Ok(None);
        }
        Ok(Some(MsgPerturb { attack, dp, replay: ReplayCache::default() }))
    }

    /// Perturb node `i`'s outgoing `(round, kind)` message in place.
    /// Deterministic in `(seed, round, node, kind)`; honest nodes with DP
    /// off pass through untouched.
    pub fn apply(&mut self, round: usize, i: usize, kind: u8, data: &mut [f32]) {
        if self.attack.is_attacker(i) {
            if let AttackPlan::StaleReplay { age } = self.attack.plan {
                self.replay.step(i, kind, round, age, data);
            } else {
                self.attack_stateless(round, i, kind, data);
            }
        }
        self.dp_noise(round, i, kind, data);
    }

    /// [`MsgPerturb::apply`] with the stale-replay state held by the caller
    /// instead of the internal cache: `slot` is node `i`'s persistent replay
    /// row for this payload kind and `stored` its has-a-copy flag.  A
    /// spill-backed driver keeps both in its slab pool (the replay row is
    /// just another registered quantity), so a 10⁶-node fleet of replay
    /// attackers needs no resident `BTreeMap`.  Bitwise-identical to
    /// `apply` — both route through the same stateless attack and DP arms,
    /// and the replay refresh grid is the same arithmetic.
    pub fn apply_pooled(
        &self,
        round: usize,
        i: usize,
        kind: u8,
        data: &mut [f32],
        slot: &mut [f32],
        stored: &mut bool,
    ) {
        if self.attack.is_attacker(i) {
            if let AttackPlan::StaleReplay { age } = self.attack.plan {
                if !*stored || round % age == 0 {
                    slot.copy_from_slice(data);
                    *stored = true;
                } else {
                    data.copy_from_slice(slot);
                }
            } else {
                self.attack_stateless(round, i, kind, data);
            }
        }
        self.dp_noise(round, i, kind, data);
    }

    /// Does node `i` need a caller-managed replay slot under
    /// [`MsgPerturb::apply_pooled`] (i.e. is it a stale-replay attacker)?
    pub fn wants_replay(&self, i: usize) -> bool {
        matches!(self.attack.plan, AttackPlan::StaleReplay { .. }) && self.attack.is_attacker(i)
    }

    /// The stateless attack arms (sign-flip / scaled-noise) shared by
    /// [`MsgPerturb::apply`] and [`MsgPerturb::apply_pooled`]; `None` and
    /// stale-replay are handled by the callers.
    fn attack_stateless(&self, round: usize, i: usize, kind: u8, data: &mut [f32]) {
        match self.attack.plan {
            AttackPlan::None | AttackPlan::StaleReplay { .. } => {}
            AttackPlan::SignFlip => {
                for v in data.iter_mut() {
                    *v = -*v;
                }
            }
            AttackPlan::ScaledNoise { scale } => {
                let mut rng = self.attack.draw_rng(round, i, kind);
                for v in data.iter_mut() {
                    *v += (scale * rng.normal()) as f32;
                }
            }
        }
    }

    /// The DP clip + keyed Gaussian noise stage shared by both apply paths.
    fn dp_noise(&self, round: usize, i: usize, kind: u8, data: &mut [f32]) {
        if self.dp.on {
            let norm = crate::algo::l2_norm(data);
            if norm > self.dp.clip {
                let s = (self.dp.clip / norm) as f32;
                for v in data.iter_mut() {
                    *v *= s;
                }
            }
            let std = self.dp.sigma * self.dp.clip;
            let stream = STREAM_DP
                ^ (round as u64).wrapping_mul(ROUND_MIX)
                ^ ((i as u64) << 1)
                ^ ((kind as u64) << 48);
            let mut rng = Pcg64::new(self.attack.seed, stream);
            for v in data.iter_mut() {
                *v += (std * rng.normal()) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_with(plan: &str, frac: f64) -> ExperimentConfig {
        ExperimentConfig {
            attack_plan: plan.into(),
            attack_frac: frac,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn membership_is_exact_static_and_deterministic() {
        for (frac, n, expect) in [(0.2, 10, 2), (0.25, 8, 2), (0.05, 5, 1), (1.0, 6, 6)] {
            let a = AttackSchedule::new(AttackPlan::SignFlip, frac, n, 42).unwrap();
            let b = AttackSchedule::new(AttackPlan::SignFlip, frac, n, 42).unwrap();
            assert_eq!(a.attackers(), expect, "frac={frac} n={n}");
            for i in 0..n {
                assert_eq!(a.is_attacker(i), b.is_attacker(i));
            }
        }
        // different seeds move the set (not a fixed prefix)
        let sets: Vec<Vec<usize>> = (0..8)
            .map(|seed| {
                let s = AttackSchedule::new(AttackPlan::SignFlip, 0.3, 20, seed).unwrap();
                (0..20).filter(|&i| s.is_attacker(i)).collect()
            })
            .collect();
        assert!(sets.windows(2).any(|w| w[0] != w[1]), "membership ignores the seed");
        // plan = none marks nobody
        let none = AttackSchedule::new(AttackPlan::None, 0.0, 10, 1).unwrap();
        assert!(!none.active());
        assert_eq!(none.attackers(), 0);
    }

    #[test]
    fn sign_flip_negates_only_attacker_messages() {
        let mut cfg = cfg_with("sign-flip", 0.25);
        cfg.n = 8;
        let mut pb = MsgPerturb::from_config(&cfg).unwrap().unwrap();
        let attacker = (0..8).find(|&i| pb.attack.is_attacker(i)).unwrap();
        let honest = (0..8).find(|&i| !pb.attack.is_attacker(i)).unwrap();
        let mut a = vec![1.0f32, -2.0, 3.0];
        let mut h = a.clone();
        pb.apply(1, attacker, 0, &mut a);
        pb.apply(1, honest, 0, &mut h);
        assert_eq!(a, vec![-1.0, 2.0, -3.0]);
        assert_eq!(h, vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn scaled_noise_is_replay_deterministic_and_kind_keyed() {
        let mut cfg = cfg_with("scaled-noise", 0.5);
        cfg.n = 4;
        cfg.attack_scale = 2.0;
        let mut p1 = MsgPerturb::from_config(&cfg).unwrap().unwrap();
        let mut p2 = MsgPerturb::from_config(&cfg).unwrap().unwrap();
        let attacker = (0..4).find(|&i| p1.attack.is_attacker(i)).unwrap();
        let base = vec![0.5f32; 16];
        let (mut a, mut b, mut c) = (base.clone(), base.clone(), base.clone());
        p1.apply(3, attacker, 0, &mut a);
        p2.apply(3, attacker, 0, &mut b);
        p1.apply(3, attacker, 1, &mut c);
        assert_eq!(a, b, "same (round, node, kind) must replay bitwise");
        assert_ne!(a, base, "noise must move the payload");
        assert_ne!(a, c, "kinds must draw from disjoint streams");
    }

    #[test]
    fn stale_replay_refreshes_on_the_age_grid() {
        let mut cfg = cfg_with("stale-replay", 0.5);
        cfg.n = 2;
        cfg.attack_age = 3;
        let mut pb = MsgPerturb::from_config(&cfg).unwrap().unwrap();
        let attacker = (0..2).find(|&i| pb.attack.is_attacker(i)).unwrap();
        let msg = |r: usize| vec![r as f32; 4];
        // round 1: first send stored + sent fresh
        let mut m = msg(1);
        pb.apply(1, attacker, 0, &mut m);
        assert_eq!(m, msg(1));
        // round 2: replays round 1's payload
        let mut m = msg(2);
        pb.apply(2, attacker, 0, &mut m);
        assert_eq!(m, msg(1));
        // round 3: 3 % 3 == 0 → refresh, sent fresh
        let mut m = msg(3);
        pb.apply(3, attacker, 0, &mut m);
        assert_eq!(m, msg(3));
        // rounds 4, 5 replay round 3
        for r in [4, 5] {
            let mut m = msg(r);
            pb.apply(r, attacker, 0, &mut m);
            assert_eq!(m, msg(3), "round {r}");
        }
    }

    #[test]
    fn apply_pooled_matches_apply_bitwise_for_every_plan() {
        // the pooled variant externalizes only the replay storage; the wire
        // bytes must match the internal-cache path exactly, round by round,
        // for every plan × DP combination
        for (plan, dp_on) in [
            ("sign-flip", false),
            ("scaled-noise", false),
            ("stale-replay", false),
            ("sign-flip", true),
            ("stale-replay", true),
            ("none", true),
        ] {
            let mut cfg = cfg_with(plan, if plan == "none" { 0.0 } else { 0.5 });
            cfg.n = 4;
            cfg.attack_scale = 2.0;
            cfg.attack_age = 3;
            if dp_on {
                cfg.dp = "gaussian".into();
                cfg.dp_clip = 1.0;
                cfg.dp_sigma = 0.4;
            }
            let mut inline = MsgPerturb::from_config(&cfg).unwrap().unwrap();
            let pooled = MsgPerturb::from_config(&cfg).unwrap().unwrap();
            // one external slot per (node, kind), mirroring a pooled driver
            let p = 6usize;
            let mut slots = vec![vec![0.0f32; p]; 4 * 2];
            let mut stored = vec![false; 4 * 2];
            for round in 1..=7 {
                for i in 0..4 {
                    for kind in 0..2u8 {
                        let msg: Vec<f32> =
                            (0..p).map(|j| (round * 10 + i * 2 + j) as f32 * 0.1).collect();
                        let (mut a, mut b) = (msg.clone(), msg);
                        inline.apply(round, i, kind, &mut a);
                        let s = i * 2 + kind as usize;
                        pooled.apply_pooled(
                            round,
                            i,
                            kind,
                            &mut b,
                            &mut slots[s],
                            &mut stored[s],
                        );
                        assert_eq!(a, b, "{plan} dp={dp_on} r={round} i={i} k={kind}");
                        assert_eq!(
                            pooled.wants_replay(i),
                            plan == "stale-replay" && pooled.attack.is_attacker(i),
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dp_clips_to_the_l2_ball_and_noise_replays() {
        let cfg = ExperimentConfig {
            dp: "gaussian".into(),
            dp_clip: 1.0,
            dp_sigma: 0.5,
            ..ExperimentConfig::default()
        };
        let mut p1 = MsgPerturb::from_config(&cfg).unwrap().unwrap();
        let mut p2 = MsgPerturb::from_config(&cfg).unwrap().unwrap();
        let big = vec![10.0f32; 64];
        let (mut a, mut b) = (big.clone(), big.clone());
        p1.apply(2, 0, 0, &mut a);
        p2.apply(2, 0, 0, &mut b);
        assert_eq!(a, b, "DP noise must be (seed, round, node, kind)-replayable");
        // after clipping, the payload is clip-norm + bounded noise: with
        // σ·C = 0.5 over 64 coords the norm can't be anywhere near ‖big‖=80
        assert!(crate::algo::l2_norm(&a) < 10.0, "{}", crate::algo::l2_norm(&a));
        // clip without noise: verify the ball directly through a tiny σ
        let mut cfg2 = cfg.clone();
        cfg2.dp_sigma = 1e-9;
        let mut p3 = MsgPerturb::from_config(&cfg2).unwrap().unwrap();
        let mut c = big.clone();
        p3.apply(2, 0, 0, &mut c);
        assert!((crate::algo::l2_norm(&c) - 1.0).abs() < 1e-3);
    }

    #[test]
    fn epsilon_matches_the_analytic_gaussian_oracle_to_1e6() {
        // independent oracle: direct δ(ε) evaluation + its own bisection
        fn oracle_eps(sigma: f64, releases: u64, delta: f64) -> f64 {
            let se = sigma / (releases as f64).sqrt();
            let d = |eps: f64| {
                let a = phi(1.0 / (2.0 * se) - eps * se);
                let p = phi(-1.0 / (2.0 * se) - eps * se);
                if p == 0.0 {
                    a
                } else {
                    a - eps.exp() * p
                }
            };
            let (mut lo, mut hi) = (0.0f64, 1.0f64);
            while d(hi) > delta {
                hi *= 2.0;
            }
            while hi - lo > 1e-12 * hi.max(1.0) {
                let mid = 0.5 * (lo + hi);
                if d(mid) > delta {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            0.5 * (lo + hi)
        }
        for (sigma, releases, delta) in [
            (1.0, 1, 1e-5),
            (1.0, 100, 1e-5),
            (2.0, 64, 1e-6),
            (4.0, 1000, 1e-5),
            (0.8, 10, 1e-4),
        ] {
            let plan = DpPlan { on: true, clip: 1.0, sigma, delta };
            let got = plan.epsilon(releases);
            let want = oracle_eps(sigma, releases, delta);
            assert!(
                (got - want).abs() <= 1e-6 * want.max(1.0),
                "σ={sigma} k={releases} δ={delta}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn epsilon_composition_grows_and_off_is_zero() {
        let plan = DpPlan { on: true, clip: 1.0, sigma: 1.0, delta: 1e-5 };
        assert_eq!(plan.epsilon(0), 0.0);
        let e1 = plan.epsilon(1);
        let e10 = plan.epsilon(10);
        let e100 = plan.epsilon(100);
        assert!(e1 > 0.0 && e10 > e1 && e100 > e10, "{e1} {e10} {e100}");
        // 100 releases at σ compose to one release at σ/10, exactly
        let tenth = DpPlan { sigma: 0.1, ..plan };
        assert!((plan.epsilon(100) - tenth.epsilon(1)).abs() < 1e-9);
        let off = DpPlan { on: false, ..plan };
        assert_eq!(off.epsilon(100), 0.0);
    }

    #[test]
    fn plan_parsing_from_config() {
        let cfg = ExperimentConfig::default();
        assert_eq!(plan_from_config(&cfg).unwrap(), AttackPlan::None);
        assert!(MsgPerturb::from_config(&cfg).unwrap().is_none());

        let mut cfg = cfg_with("sign-flip", 0.2);
        assert_eq!(plan_from_config(&cfg).unwrap(), AttackPlan::SignFlip);
        cfg.attack_frac = 0.0;
        assert!(plan_from_config(&cfg).is_err(), "non-none plan needs frac > 0");
        cfg.attack_frac = 1.5;
        assert!(plan_from_config(&cfg).is_err());

        let mut cfg = cfg_with("none", 0.3);
        assert!(plan_from_config(&cfg).is_err(), "frac without a plan is a config bug");
        cfg.attack_frac = 0.0;
        assert!(plan_from_config(&cfg).is_ok());

        let mut cfg = cfg_with("scaled-noise", 0.2);
        cfg.attack_scale = 0.0;
        assert!(plan_from_config(&cfg).is_err());
        cfg.attack_scale = 3.0;
        assert_eq!(plan_from_config(&cfg).unwrap(), AttackPlan::ScaledNoise { scale: 3.0 });

        let mut cfg = cfg_with("stale-replay", 0.2);
        cfg.attack_age = 1;
        assert!(plan_from_config(&cfg).is_err());
        cfg.attack_age = 5;
        assert_eq!(plan_from_config(&cfg).unwrap(), AttackPlan::StaleReplay { age: 5 });

        assert!(plan_from_config(&cfg_with("bogus", 0.2)).is_err());

        let mut cfg =
            ExperimentConfig { dp: "gaussian".into(), ..ExperimentConfig::default() };
        assert!(dp_from_config(&cfg).unwrap().on);
        cfg.dp_sigma = -1.0;
        assert!(dp_from_config(&cfg).is_err());
        cfg.dp_sigma = 1.0;
        cfg.dp_clip = 0.0;
        assert!(dp_from_config(&cfg).is_err());
        cfg.dp_clip = 1.0;
        cfg.dp_delta = 0.0;
        assert!(dp_from_config(&cfg).is_err());
        cfg.dp = "bogus".into();
        assert!(dp_from_config(&cfg).is_err());
    }
}
