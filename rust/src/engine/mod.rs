//! The unified round engine — ONE implementation of Algorithm 1's loop.
//!
//! Every trainer in this crate (fused DSGD/DSGT, the actor driver, FedAvg,
//! the centralized fusion-center baseline) runs the same round structure:
//! Q−1 local eq.-4 updates, then one update that consumes a gradient (eq. 2,
//! eq. 3, a server average, or a plain SGD step), then metrics on an eval
//! cadence.  Historically that loop was copy-pasted four times; this module
//! owns it once and splits the two axes of variation into two traits:
//!
//! - [`CommStrategy`] (strategy.rs) — *what* the communication update does:
//!   Dsgd / Dsgt / FedAvg / Centralized.  Strategies operate on the shared
//!   [`EngineState`] (θ stack, per-node samplers, batch scratch) through the
//!   [`Compute`] backend, so they are backend-agnostic, and receive each
//!   round's network as a [`RoundNet`] view — the network is a scheduled
//!   per-round quantity (`graph::schedule`), never captured state.
//! - [`Driver`] — *where* the phases execute: [`SyncDriver`] runs whole-
//!   network phases in-process with analytic communication accounting (the
//!   fused path and both baselines); the actor driver implements [`Driver`]
//!   per node over the channel netsim (`coordinator::actors`).
//!
//! [`RoundEngine::run`] is the only round loop in the crate.  It is
//! deliberately tiny: schedule + cadence, nothing else, so a new scenario
//! (stragglers, checkpointing) is a new `CommStrategy`, a `Driver` hook, or
//! a `NetPlan` — never a fifth copy of the loop.  The straggler scenario
//! landed exactly that way: per-node local work is a scheduled quantity
//! ([`stragglers::ComputeSchedule`], `(seed, round, node)`-keyed like the
//! network schedule) consulted by the drivers' phase bodies, and the loop
//! itself never changed.
//!
//! Determinism contract: batch order per node-sampler stream, float-op order
//! per node, eval cadence, the `(seed, round)`-keyed network views, the
//! `(seed, round, node)`-keyed compute schedule (`stragglers`), and the
//! `(seed, round, node, kind)`-keyed compression streams (`compress`) are
//! identical across drivers and thread counts, so trajectories are
//! bitwise-reproducible (pinned by the `driver_equivalence` integration
//! test, for static and dynamic network plans, every compressor, and every
//! straggler plan alike).

pub mod adversary;
pub mod asynchrony;
pub mod pipeline;
pub mod shard;
pub mod stragglers;
pub mod strategy;

pub use adversary::{AttackPlan, AttackSchedule, DpPlan, MsgPerturb};
pub use pipeline::RoundNet;
pub use shard::{NodeSlabPool, PoolStats, QuantityRegistry, QuantitySet, ShardSpec, ShardedSync};
pub use stragglers::{ComputePlan, ComputeSchedule};
pub use strategy::{
    CentralizedStrategy, CommCost, CommStrategy, DsgdStrategy, DsgtStrategy, FedAvgStrategy,
};

use crate::algo::native::NativeModel;
use crate::algo::{scale_displacement, LrSchedule, RoundPlan};
use crate::config::{AlgoKind, ExperimentConfig};
use crate::coordinator::compute::Compute;
use crate::mixing::SparseW;
use crate::coordinator::sampler::{init_theta, init_thetas, NodeSampler};
use crate::data::{FederatedDataset, Shard};
use crate::graph::{Graph, NetworkSchedule, ViewScratch};
use crate::metrics::{round_metrics, RunLog};
use crate::netsim::{analytic::Accountant, LinkModel};
use anyhow::{bail, Result};
use std::borrow::Cow;

// ------------------------------------------------------------- engine ----

/// The round schedule of Algorithm 1: local period, lr schedule, round count,
/// eval cadence.  Shared verbatim by every driver (the actor driver builds
/// one per node thread; all nodes derive the identical schedule).
#[derive(Clone, Copy, Debug)]
pub struct RoundEngine {
    /// Effective local period Q.
    pub q: usize,
    /// Derived per-round step layout (Q−1 local + 1 communication).
    pub plan: RoundPlan,
    /// The paper's α_r = α₀/√r learning-rate schedule.
    pub sched: LrSchedule,
    /// Total communication rounds to run.
    pub rounds: usize,
    /// Metric-eval cadence in communication rounds.
    pub eval_every: usize,
}

impl RoundEngine {
    /// Derive the round schedule from a config.
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        let q = cfg.algo.effective_q(cfg.q);
        let plan = RoundPlan::new(q);
        RoundEngine {
            q,
            plan,
            sched: LrSchedule::new(cfg.alpha0),
            rounds: plan.rounds_for(cfg.total_steps),
            eval_every: cfg.eval_every.max(1),
        }
    }

    /// THE round loop.  `begin` → per round: local phase (Q−1 steps),
    /// communication phase (1 step), observation on the eval cadence.
    /// The lr buffer is allocated once and refilled per round, so the loop
    /// itself adds nothing to the steady-state allocation count (§Perf).
    pub fn run<D: Driver>(&self, driver: &mut D) -> Result<()> {
        driver.begin()?;
        let mut lrs = vec![0.0f32; self.plan.local_per_round];
        for round in 1..=self.rounds {
            if self.plan.local_per_round > 0 {
                self.sched.local_lrs_into(round, self.q, &mut lrs);
                driver.local_phase(round, &lrs)?;
            }
            driver.comm_phase(round, self.sched.comm_lr(round, self.q))?;
            if round % self.eval_every == 0 || round == self.rounds {
                driver.observe(round as u64, (round * self.q) as u64)?;
            }
        }
        Ok(())
    }
}

/// Execution substrate for one engine run: how each phase actually executes.
///
/// Implementations: [`SyncDriver`] (whole-network, in-process) and the
/// per-node actor driver in `coordinator::actors`.
pub trait Driver {
    /// Pre-loop hook: auxiliary-state init (e.g. DSGT's Y⁰ = G⁰ = ∇g(θ⁰))
    /// and the round-0 observation where the driver owns metrics.
    fn begin(&mut self) -> Result<()>;
    /// The Q−1 eq.-4 local updates of `round` (1-based), one lr per step.
    fn local_phase(&mut self, round: usize, lrs: &[f32]) -> Result<()>;
    /// The communication update of `round` (consumes one gradient per node).
    fn comm_phase(&mut self, round: usize, lr: f32) -> Result<()>;
    /// Eval-cadence hook with the round index and cumulative local steps.
    fn observe(&mut self, round: u64, local_steps: u64) -> Result<()>;
}

// -------------------------------------------------------------- state ----

/// The machinery every strategy shares: the parameter stack, per-node
/// samplers, the data shards backing them, and reusable batch scratch
/// (no allocation in the hot loop).
pub struct EngineState<'a> {
    /// Rows in the θ stack (hospitals; 1 for the centralized baseline).
    pub n: usize,
    /// Input feature dimension.
    pub d: usize,
    /// Flat parameter count per row.
    pub p: usize,
    /// Minibatch size per row per step.
    pub m: usize,
    /// Stacked parameters `[n, p]`.
    pub theta: Vec<f32>,
    /// Back buffer for the θ stack: whole-network `_into` calls write here,
    /// then the buffers swap — double-buffered rounds never allocate.
    pub theta_back: Vec<f32>,
    /// Per-row batch samplers — streams keyed by (seed, row) only, so every
    /// driver — and every network plan — draws identical batches (the
    /// determinism contract).
    pub samplers: Vec<NodeSampler>,
    /// Data shard per row (borrowed federated shards, or the owned pooled
    /// cohort for the centralized baseline).
    pub shards: Cow<'a, [Shard]>,
    /// Local-phase batch scratch `[n, local, m, d]`.
    pub lx: Vec<f32>,
    /// Local-phase label scratch `[n, local, m]`.
    pub ly: Vec<f32>,
    /// Communication-step batch scratch `[n, m, d]`.
    pub cx: Vec<f32>,
    /// Communication-step label scratch `[n, m]`.
    pub cy: Vec<f32>,
    /// Per-step local-phase loss slab `[n, local]`.
    pub local_losses: Vec<f64>,
    /// Per-node communication-step loss slab `[n]`.
    pub comm_losses: Vec<f64>,
    /// Decoded gossip stack X̂ `[n, p]` — what compressed rounds mix
    /// (empty when `comm.compress = "none"`).
    pub xhat: Vec<f32>,
    /// θ-stream error-feedback residuals `[n, p]` + back buffer, swapped
    /// per round like the θ stack (empty unless compressing with EF).
    pub ef_theta: Vec<f32>,
    /// Back buffer for [`EngineState::ef_theta`].
    pub ef_theta_back: Vec<f32>,
    /// Per-row encode scratch `[p]` (the error-compensated message v).
    pub vbuf: Vec<f32>,
}

impl<'a> EngineState<'a> {
    /// Allocate every slab a run needs up front (θ stacks, batch scratch,
    /// loss slabs, and — when `comm.compress` is active — the decoded
    /// gossip stack and error-feedback residual slabs), so steady-state
    /// rounds never touch the allocator.
    pub fn new(
        cfg: &ExperimentConfig,
        compute: &dyn Compute,
        shards: Cow<'a, [Shard]>,
        theta: Vec<f32>,
    ) -> Self {
        let (d, _h, p) = compute.dims();
        let n = shards.len();
        let m = cfg.m;
        let local = RoundPlan::new(cfg.algo.effective_q(cfg.q)).local_per_round;
        // perturbed runs (attack/DP) route through the encode path even when
        // no compressor is configured (the driver installs Identity), so the
        // decoded-stack slabs must exist for them too
        let compressing = cfg.compress != "none" || adversary::perturb_active(cfg);
        let ef = compressing && cfg.error_feedback;
        EngineState {
            n,
            d,
            p,
            m,
            theta_back: vec![0.0f32; theta.len()],
            theta,
            samplers: (0..n).map(|i| NodeSampler::new(cfg.seed, i, m)).collect(),
            shards,
            lx: vec![0.0f32; n * local * m * d],
            ly: vec![0.0f32; n * local * m],
            cx: vec![0.0f32; n * m * d],
            cy: vec![0.0f32; n * m],
            local_losses: vec![0.0f64; n * local],
            comm_losses: vec![0.0f64; n],
            xhat: vec![0.0f32; if compressing { n * p } else { 0 }],
            ef_theta: vec![0.0f32; if ef { n * p } else { 0 }],
            ef_theta_back: vec![0.0f32; if ef { n * p } else { 0 }],
            vbuf: vec![0.0f32; if compressing { p } else { 0 }],
        }
    }

    /// Draw one fresh batch per row into the communication scratch.
    pub fn draw_comm_batches(&mut self) {
        let (m, d) = (self.m, self.d);
        let shards = &self.shards;
        for (i, s) in self.samplers.iter_mut().enumerate() {
            s.batch(
                &shards[i],
                &mut self.cx[i * m * d..(i + 1) * m * d],
                &mut self.cy[i * m..(i + 1) * m],
            );
        }
    }

    /// Row `i` of the θ stack.
    pub fn theta_row(&self, i: usize) -> &[f32] {
        &self.theta[i * self.p..(i + 1) * self.p]
    }

    /// Communication batch of row `i` (valid after [`Self::draw_comm_batches`]).
    pub fn comm_batch(&self, i: usize) -> (&[f32], &[f32]) {
        (
            &self.cx[i * self.m * self.d..(i + 1) * self.m * self.d],
            &self.cy[i * self.m..(i + 1) * self.m],
        )
    }
}

// -------------------------------------------------------- sync driver ----

/// Whole-network in-process driver: each phase is (at most) one `Compute`
/// call covering all nodes, with communication charged analytically.  This
/// is the throughput path (`--mode fused`) and the substrate for both
/// baselines.  Gossip strategies see the network through a per-round
/// [`NetworkSchedule`] view, cached across rounds with an unchanged key.
pub struct SyncDriver<'a> {
    compute: &'a dyn Compute,
    strategy: Box<dyn CommStrategy + 'a>,
    st: EngineState<'a>,
    acct: Option<Accountant>,
    compute_s_per_step: f64,
    /// Per-round, per-node local-work schedule (`engine::stragglers`).
    /// Uniform plans take the legacy code paths verbatim.
    csched: ComputeSchedule,
    /// Per-round τ scratch `[n]` (non-uniform plans only).
    taus: Vec<usize>,
    /// Per-round τ-weight scratch `[n]` (non-uniform plans only).
    tau_ws: Vec<f32>,
    /// Cumulative Σ_i τ_i over completed rounds (non-uniform plans only) —
    /// the true local-work counter behind `RoundMetrics::local_steps`.
    work_done: u64,
    /// Per-round network schedule (gossip strategies only).
    net: Option<NetworkSchedule>,
    /// Grow-only workspace the schedule materializes per-round views into
    /// (CSR edits in place — steady-state refreshes allocate nothing).
    scratch: ViewScratch,
    /// Cached view of the current round: degree-sparse CSR W, online mask,
    /// active edges.  `wf` is the dense scatter of the same matrix, built
    /// only for backends that report `wants_dense_w` (the AOT artifacts) —
    /// the sparse-native path leaves it empty at any n.
    wf: Vec<f32>,
    wsp: SparseW,
    online: Vec<bool>,
    round_edges: u64,
    wf_key: Option<u64>,
    /// The run's DP plan — drives the per-row (ε, δ) report (`DpPlan::off()`
    /// for non-gossip baselines and honest runs: ε ≡ 0).
    dp: DpPlan,
    /// Gaussian releases per node per round (1 for DSGD's θ, 2 for DSGT's
    /// θ + ϑ).  The reported ε after round r composes `dp_kinds · r`
    /// releases — an upper bound under churn, where offline rounds release
    /// nothing (documented in DESIGN.md §14).
    dp_kinds: u64,
    /// Quarantine events already forwarded to the accountant (the strategy
    /// counter is cumulative; the accountant wants per-round deltas).
    q_reported: u64,
    log: RunLog,
    started: std::time::Instant,
}

impl<'a> SyncDriver<'a> {
    /// Gossip trainer (DSGD / DSGT and their federated variants) over an
    /// explicit base graph + mixing matrix; `cfg.net_plan` decides how the
    /// network evolves per round (static keeps `(graph, w)` frozen and is
    /// bitwise-identical to the pre-schedule behavior).
    pub fn decentralized(
        cfg: &'a ExperimentConfig,
        compute: &'a dyn Compute,
        ds: &'a FederatedDataset,
        graph: &Graph,
        w: &SparseW,
    ) -> Result<Self> {
        let (d, h, p) = compute.dims();
        if d != ds.d {
            bail!("backend d={d} vs dataset d={}", ds.d);
        }
        let q = cfg.algo.effective_q(cfg.q);
        let plan = RoundPlan::new(q);
        if let Some(want) = compute.local_steps_len() {
            if plan.local_per_round > 0 && plan.local_per_round != want {
                bail!(
                    "artifacts were lowered for Q={} (local phase {want}), config wants Q={q}; \
                     re-run `make artifacts Q={q}` or use --backend native",
                    want + 1
                );
            }
        }
        if cfg.drop_prob > 0.0 {
            bail!(
                "drop_prob={} requested, but fused execution charges communication \
                 analytically over lossless links; use `--mode actors` for loss injection",
                cfg.drop_prob
            );
        }
        let csched = ComputeSchedule::from_config(cfg)?;
        csched.ensure_runnable(ds.n_hospitals(), compute.local_steps_len())?;
        let net = NetworkSchedule::from_config(cfg, graph.clone(), w.clone())?;
        // adversarial axis: the perturbation pipeline (attack and/or DP) is
        // None on the pinned honest defaults; when active the run is routed
        // through the encode path (Identity compressor if none configured,
        // bitwise-equal to dense and charged at the same 4p wire bytes) so
        // the pipeline always sits at the message-encode boundary
        let perturb = MsgPerturb::from_config(cfg)?;
        let dp = adversary::dp_from_config(cfg)?;
        let mut comm = crate::compress::GossipComm::from_config(cfg)?;
        if perturb.is_some() && comm.comp.is_none() {
            comm.comp = Some(Box::new(crate::compress::Identity));
        }
        // compression context: the compressor, EF toggle, and seed the
        // per-message keys derive from — identical in the actor driver
        let strategy: Box<dyn CommStrategy> = match cfg.algo {
            AlgoKind::Dsgd | AlgoKind::FdDsgd => {
                Box::new(DsgdStrategy::new(comm, p).with_perturb(perturb))
            }
            AlgoKind::Dsgt | AlgoKind::FdDsgt => {
                Box::new(DsgtStrategy::new(comm, p).with_perturb(perturb))
            }
            other => bail!("{other:?} is not a decentralized gossip algorithm"),
        };
        let model = NativeModel::new(d, h);
        let theta = init_thetas(cfg.seed, ds.n_hospitals(), &model);
        let link = LinkModel {
            latency_s: cfg.latency_s,
            bandwidth_bps: cfg.bandwidth_bps,
            drop_prob: 0.0, // enforced lossless above
        };
        let acct = Accountant::new(link);
        let mut driver = Self::build(
            cfg,
            compute,
            Cow::Borrowed(&ds.shards[..]),
            theta,
            strategy,
            Some(acct),
            Some(net),
            csched,
            cfg.algo.name(),
        );
        driver.dp = dp;
        driver.dp_kinds =
            if matches!(cfg.algo, AlgoKind::Dsgt | AlgoKind::FdDsgt) { 2 } else { 1 };
        Ok(driver)
    }

    /// Star-network FedAvg baseline: every row of the stack starts from the
    /// server parameters each round; the strategy averages after the final
    /// local gradient.
    pub fn fedavg(
        cfg: &'a ExperimentConfig,
        compute: &'a dyn Compute,
        ds: &'a FederatedDataset,
    ) -> Result<Self> {
        let (d, h, _p) = compute.dims();
        if d != ds.d {
            bail!("backend d={d} vs dataset d={}", ds.d);
        }
        if cfg.drop_prob > 0.0 {
            bail!(
                "drop_prob={} requested, but the FedAvg baseline charges its star \
                 network analytically over lossless links",
                cfg.drop_prob
            );
        }
        if cfg.net_plan != "static" {
            bail!(
                "net plan `{}` requested, but the FedAvg baseline runs a fixed star \
                 network and would silently ignore it; dynamic plans apply to gossip \
                 algorithms (dsgd|dsgt|fd-dsgd|fd-dsgt)",
                cfg.net_plan
            );
        }
        if cfg.compress != "none" {
            bail!(
                "compress `{}` requested, but the FedAvg baseline's star exchange is \
                 outside the gossip compression subsystem and would silently ship dense \
                 f32; compression applies to dsgd|dsgt|fd-dsgd|fd-dsgt",
                cfg.compress
            );
        }
        if cfg.compute_plan != "uniform" {
            bail!(
                "compute plan `{}` requested, but the FedAvg baseline runs the paper's \
                 synchronous server rounds and would silently ignore it; straggler \
                 plans apply to gossip algorithms (dsgd|dsgt|fd-dsgd|fd-dsgt)",
                cfg.compute_plan
            );
        }
        if adversary::perturb_active(cfg) || cfg.robust_rule != "mean" {
            bail!(
                "adversarial settings (attack.plan={}, robust.rule={}, dp={}) requested, \
                 but the FedAvg baseline has no gossip messages to attack, screen, or \
                 privatize and would silently ignore them; the adversarial axis applies \
                 to gossip algorithms (dsgd|dsgt|fd-dsgd|fd-dsgt)",
                cfg.attack_plan,
                cfg.robust_rule,
                cfg.dp
            );
        }
        let n = ds.n_hospitals();
        let model = NativeModel::new(d, h);
        // server init = node-0 init (a shared broadcast start, as FedAvg assumes)
        let server = init_theta(cfg.seed, 0, &model);
        let mut theta = Vec::with_capacity(n * model.p());
        for _ in 0..n {
            theta.extend_from_slice(&server);
        }
        // The star family never reads its rng (deterministic hub-and-spoke),
        // but construction stays seed-threaded for uniformity with every
        // other Graph::build in the crate; the assert pins the
        // one-link-per-client shape that `star_round`'s 2n-message charge
        // assumes.
        let star = Graph::build(
            &crate::graph::Topology::Star,
            n + 1,
            &mut crate::rng::Pcg64::new(cfg.seed, 0x57A2),
        )?;
        debug_assert_eq!(star.edge_count(), n, "star network has one link per client");
        let link = LinkModel {
            latency_s: cfg.latency_s,
            bandwidth_bps: cfg.bandwidth_bps,
            drop_prob: 0.0,
        };
        let acct = Accountant::new(link);
        Ok(Self::build(
            cfg,
            compute,
            Cow::Borrowed(&ds.shards[..]),
            theta,
            Box::new(FedAvgStrategy::new()),
            Some(acct),
            None,
            ComputeSchedule::from_config(cfg)?,
            "fedavg",
        ))
    }

    /// Fictitious fusion center: plain SGD on the pooled cohort (one stack
    /// row, zero communication by construction).
    pub fn centralized(
        cfg: &'a ExperimentConfig,
        compute: &'a dyn Compute,
        ds: &FederatedDataset,
    ) -> Result<Self> {
        let (d, h, _p) = compute.dims();
        if d != ds.d {
            bail!("backend d={d} vs dataset d={}", ds.d);
        }
        if cfg.net_plan != "static" {
            bail!(
                "net plan `{}` requested, but the centralized baseline has no network \
                 at all and would silently ignore it; dynamic plans apply to gossip \
                 algorithms (dsgd|dsgt|fd-dsgd|fd-dsgt)",
                cfg.net_plan
            );
        }
        if cfg.compress != "none" {
            bail!(
                "compress `{}` requested, but the centralized baseline has no gossip \
                 messages to compress and would silently ignore it; compression applies \
                 to dsgd|dsgt|fd-dsgd|fd-dsgt",
                cfg.compress
            );
        }
        if cfg.compute_plan != "uniform" {
            bail!(
                "compute plan `{}` requested, but the centralized baseline is a single \
                 fusion center with no per-node fleet to straggle and would silently \
                 ignore it; straggler plans apply to gossip algorithms \
                 (dsgd|dsgt|fd-dsgd|fd-dsgt)",
                cfg.compute_plan
            );
        }
        if adversary::perturb_active(cfg) || cfg.robust_rule != "mean" {
            bail!(
                "adversarial settings (attack.plan={}, robust.rule={}, dp={}) requested, \
                 but the centralized baseline is a single fusion center with no neighbors \
                 to attack, screen, or privatize and would silently ignore them; the \
                 adversarial axis applies to gossip algorithms (dsgd|dsgt|fd-dsgd|fd-dsgt)",
                cfg.attack_plan,
                cfg.robust_rule,
                cfg.dp
            );
        }
        let model = NativeModel::new(d, h);
        let theta = init_theta(cfg.seed, 0, &model);
        Ok(Self::build(
            cfg,
            compute,
            Cow::Owned(vec![ds.pooled()]),
            theta,
            Box::new(CentralizedStrategy::new(model)),
            None,
            None,
            ComputeSchedule::from_config(cfg)?,
            "centralized",
        ))
    }

    #[allow(clippy::too_many_arguments)]
    fn build(
        cfg: &ExperimentConfig,
        compute: &'a dyn Compute,
        shards: Cow<'a, [Shard]>,
        theta: Vec<f32>,
        strategy: Box<dyn CommStrategy + 'a>,
        acct: Option<Accountant>,
        net: Option<NetworkSchedule>,
        csched: ComputeSchedule,
        name: &str,
    ) -> Self {
        let st = EngineState::new(cfg, compute, shards, theta);
        let n = st.n;
        SyncDriver {
            compute,
            strategy,
            st,
            acct,
            compute_s_per_step: cfg.compute_s_per_step,
            taus: vec![0; if csched.is_uniform() { 0 } else { n }],
            tau_ws: vec![0.0; if csched.is_uniform() { 0 } else { n }],
            csched,
            work_done: 0,
            net,
            scratch: ViewScratch::new(),
            wf: Vec::new(),
            wsp: SparseW::empty(),
            online: vec![true; n],
            round_edges: 0,
            wf_key: None,
            dp: DpPlan::off(),
            dp_kinds: 1,
            q_reported: 0,
            log: RunLog::new(name),
            started: std::time::Instant::now(),
        }
    }

    /// Refresh the cached network view for `round` (no-op while the
    /// schedule's view key is unchanged — every round for static plans).
    /// The view is materialized into the driver's grow-only scratch and
    /// copied into the reusable CSR cache, so warm refreshes never allocate;
    /// the dense scatter happens only for `wants_dense_w` backends.
    fn refresh_net(&mut self, round: usize) -> Result<()> {
        let Some(net) = &self.net else {
            return Ok(());
        };
        let key = net.view_key(round);
        if self.wf_key == Some(key) {
            return Ok(());
        }
        // per-round nnz never exceeds the base matrix (drop/churn only
        // remove entries), so one reservation keeps every later copy warm
        self.wsp.reserve_rows_nnz(net.n(), net.base_nnz());
        let view = net.view_into(round, &mut self.scratch)?;
        self.wsp.copy_from(view.w);
        self.round_edges = view.active_directed_edges();
        self.online.clear();
        self.online.extend_from_slice(view.online);
        if self.compute.wants_dense_w() {
            self.wf = view.wf(); // gated small-n conversion (AOT artifacts)
        }
        self.wf_key = Some(key);
        Ok(())
    }

    fn net_snapshot(&self) -> crate::netsim::NetSnapshot {
        self.acct.as_ref().map(|a| a.snapshot()).unwrap_or_default()
    }

    /// Consume the driver: the metric log and the final θ stack of the SAME
    /// run — no deterministic replay required.
    pub fn into_result(self) -> (RunLog, Vec<f32>) {
        (self.log, self.st.theta)
    }
}

impl Driver for SyncDriver<'_> {
    fn begin(&mut self) -> Result<()> {
        self.strategy.init(&mut self.st, self.compute)?;
        let eval = self.strategy.eval(&self.st, self.compute)?;
        let net = self.net_snapshot();
        self.log
            .push(round_metrics(0, 0, eval, net, self.started.elapsed().as_secs_f64()));
        Ok(())
    }

    fn local_phase(&mut self, round: usize, lrs: &[f32]) -> Result<()> {
        let st = &mut self.st;
        let (m, d, local, n, p) = (st.m, st.d, lrs.len(), st.n, st.p);
        let shards = &st.shards;
        // Every row draws its full Q−1 batches regardless of the compute
        // plan — stragglers use only their prefix, so the (seed, row)-keyed
        // sampler streams stay plan-independent (§7).
        for (i, s) in st.samplers.iter_mut().enumerate() {
            s.batches(
                &shards[i],
                local,
                &mut st.lx[i * local * m * d..(i + 1) * local * m * d],
                &mut st.ly[i * local * m..(i + 1) * local * m],
            );
        }
        if self.csched.is_uniform() {
            // legacy path, byte for byte: the whole-network op writes the
            // back slab, then the stacks swap — no allocation in the steady
            // state
            self.compute.local_steps_all_into(
                &st.theta,
                &st.lx,
                &st.ly,
                lrs,
                &mut st.theta_back,
                &mut st.local_losses,
            )?;
            std::mem::swap(&mut st.theta, &mut st.theta_back);
            if let Some(acct) = self.acct.as_mut() {
                acct.local_compute(local as u64, self.compute_s_per_step);
            }
            return Ok(());
        }
        // heterogeneous plan: per-node τ-truncated local steps, then the
        // FedNova-style τ-weighted displacement rescale (stragglers.rs) so
        // the gossip fixed point stays unbiased; the round's compute time is
        // charged once in comm_phase (slowest participant).
        self.csched.taus_into(round, &mut self.taus);
        self.compute.local_steps_hetero_into(
            &st.theta,
            &st.lx,
            &st.ly,
            lrs,
            &self.taus,
            &mut st.theta_back,
            &mut st.local_losses,
        )?;
        self.csched.tau_weights_into(&self.taus, &mut self.tau_ws);
        for i in 0..n {
            let w = self.tau_ws[i];
            if w != 1.0 {
                scale_displacement(
                    &mut st.theta_back[i * p..(i + 1) * p],
                    &st.theta[i * p..(i + 1) * p],
                    w,
                );
            }
        }
        std::mem::swap(&mut st.theta, &mut st.theta_back);
        Ok(())
    }

    fn comm_phase(&mut self, round: usize, lr: f32) -> Result<()> {
        self.refresh_net(round)?;
        let dense_w = if self.wf.is_empty() { None } else { Some(&self.wf[..]) };
        self.strategy.comm_update(
            &mut self.st,
            self.compute,
            &RoundNet { w: dense_w, sparse: &self.wsp, online: &self.online },
            round,
            lr,
        )?;
        // forward this round's quarantine events (non-finite ingest guard,
        // DESIGN.md §14) to the accountant — the strategy counter is
        // cumulative, the accountant wants the delta
        let q_total = self.strategy.quarantined();
        if q_total > self.q_reported {
            if let Some(acct) = self.acct.as_mut() {
                acct.report_quarantine(q_total - self.q_reported);
            }
            self.q_reported = q_total;
        }
        if !self.csched.is_uniform() {
            // true per-node local work of this round (drives the
            // `local_steps` metric; the uniform path keeps the engine's
            // legacy round·Q accounting untouched).  The τ scratch was
            // filled for this round by local_phase — non-uniform plans
            // always have a local phase (Q ≥ 2 enforced) — so the sum needs
            // no fresh schedule draws.
            self.work_done += self.taus.iter().map(|&t| t as u64).sum::<u64>();
        }
        if let Some(acct) = self.acct.as_mut() {
            match self.strategy.cost() {
                CommCost::Gossip { kinds, kind_bytes } => {
                    if self.csched.is_uniform() {
                        acct.local_compute(1, self.compute_s_per_step);
                    } else {
                        // synchronous gossip waits for the slowest
                        // participant: charge the round's whole compute
                        // phase (local steps + comm gradient) at the
                        // straggler-aware maximum, reusing this round's τ
                        // scratch
                        acct.compute_seconds(self.csched.round_compute_s_from(
                            round,
                            &self.taus,
                            self.compute_s_per_step,
                        ));
                    }
                    // per-kind encoded sizes — compressed runs charge the
                    // bytes that actually cross the wire, matching the
                    // channel netsim message for message
                    acct.comm_round(self.round_edges, &kind_bytes[..kinds as usize]);
                }
                CommCost::Star => {
                    acct.local_compute(1, self.compute_s_per_step);
                    acct.star_round(self.st.n, self.st.p);
                }
                CommCost::None => {}
            }
        }
        Ok(())
    }

    fn observe(&mut self, round: u64, local_steps: u64) -> Result<()> {
        let eval = self.strategy.eval(&self.st, self.compute)?;
        let net = self.net_snapshot();
        // Heterogeneous plans report the TRUE mean per-node work done
        // (Σ_r Σ_i τ_i(r) / n) instead of the engine's uniform round·Q —
        // Fig.-1-style x-axes stay correct when stragglers contribute less.
        let steps = if self.csched.is_uniform() {
            local_steps
        } else {
            self.work_done / self.csched.n() as u64
        };
        let mut m =
            round_metrics(round, steps, eval, net, self.started.elapsed().as_secs_f64());
        // (ε, δ) so far: dp_kinds releases per node per round, composed by
        // the analytic Gaussian accountant (0 when DP is off)
        m.dp_epsilon = self.dp.epsilon(self.dp_kinds * round);
        self.log.push(m);
        Ok(())
    }
}

// ------------------------------------------------------- entry points ----

/// Train a gossip algorithm (DSGD/DSGT/FD-*) through the sync driver;
/// returns the metric log and the final θ stack of the same run.
pub fn train_decentralized(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &SparseW,
) -> Result<(RunLog, Vec<f32>)> {
    let engine = RoundEngine::from_config(cfg);
    let mut driver = SyncDriver::decentralized(cfg, compute, ds, graph, w)?;
    engine.run(&mut driver)?;
    Ok(driver.into_result())
}

/// Train the star-network FedAvg baseline through the sync driver.
pub fn train_fedavg(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
) -> Result<(RunLog, Vec<f32>)> {
    let engine = RoundEngine::from_config(cfg);
    let mut driver = SyncDriver::fedavg(cfg, compute, ds)?;
    engine.run(&mut driver)?;
    Ok(driver.into_result())
}

/// Train the centralized fusion-center baseline through the sync driver.
pub fn train_centralized(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
) -> Result<(RunLog, Vec<f32>)> {
    let engine = RoundEngine::from_config(cfg);
    let mut driver = SyncDriver::centralized(cfg, compute, ds)?;
    engine.run(&mut driver)?;
    Ok(driver.into_result())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Backend, Mode};
    use crate::coordinator::compute::NativeCompute;
    use crate::data::{generate, DataConfig};
    use crate::graph::Topology;
    use crate::mixing::{build_sparse, Scheme};
    use crate::rng::Pcg64;

    fn setup(
        algo: AlgoKind,
    ) -> (ExperimentConfig, NativeCompute, FederatedDataset, Graph, SparseW) {
        let mut cfg = ExperimentConfig::default();
        cfg.n = 4;
        cfg.d = 42;
        cfg.hidden = 8;
        cfg.m = 8;
        cfg.q = 5;
        cfg.algo = algo;
        cfg.total_steps = 40;
        cfg.eval_every = 2;
        cfg.mode = Mode::Fused;
        cfg.backend = Backend::Native;
        cfg.records_per_hospital = 60;
        let ds = generate(&DataConfig {
            n_hospitals: cfg.n,
            records_per_hospital: 60,
            records_jitter: 0,
            heterogeneity: 0.5,
            ..DataConfig::default()
        })
        .unwrap();
        let graph = Graph::build(&Topology::Ring, cfg.n, &mut Pcg64::seed(1)).unwrap();
        let w = build_sparse(&graph, Scheme::Metropolis);
        let compute = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
        (cfg, compute, ds, graph, w)
    }

    #[test]
    fn engine_schedule_matches_config() {
        let (cfg, ..) = setup(AlgoKind::FdDsgt);
        let e = RoundEngine::from_config(&cfg);
        assert_eq!(e.q, 5);
        assert_eq!(e.rounds, 8);
        assert_eq!(e.plan.local_per_round, 4);
        // classic variants force Q = 1
        let mut classic = cfg;
        classic.algo = AlgoKind::Dsgd;
        assert_eq!(RoundEngine::from_config(&classic).q, 1);
    }

    #[test]
    fn returned_theta_is_the_logged_trajectory_endpoint() {
        let (cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgt);
        let (log, theta) = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap();
        let eval = compute.eval_full(&theta, &ds.shards).unwrap();
        assert_eq!(eval.0, log.rows.last().unwrap().loss);
    }

    #[test]
    fn fused_drop_prob_bails_loudly() {
        let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgd);
        cfg.drop_prob = 0.1;
        let err = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap_err();
        assert!(err.to_string().contains("actors"), "{err}");
    }

    #[test]
    fn baselines_reject_net_plans_loudly() {
        let (mut cfg, compute, ds, ..) = setup(AlgoKind::FedAvg);
        cfg.net_plan = "churn".into();
        let err = train_fedavg(&cfg, &compute, &ds).unwrap_err();
        assert!(err.to_string().contains("star"), "{err}");
        cfg.algo = AlgoKind::Centralized;
        let err = train_centralized(&cfg, &compute, &ds).unwrap_err();
        assert!(err.to_string().contains("no network"), "{err}");
    }

    #[test]
    fn dynamic_plans_train_end_to_end() {
        for plan in ["rewire", "edge-drop", "churn"] {
            let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgt);
            cfg.net_plan = plan.into();
            cfg.rewire_every = 2;
            cfg.edge_drop = 0.3;
            cfg.churn = 0.3;
            cfg.total_steps = 60;
            let (log, theta) = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap();
            let first = log.rows.first().unwrap().loss;
            let last = log.rows.last().unwrap().loss;
            assert!(last.is_finite(), "{plan}");
            assert!(last < first, "{plan}: loss {first} -> {last}");
            assert!(theta.iter().all(|v| v.is_finite()), "{plan}");
            assert!(log.rows.last().unwrap().bytes > 0, "{plan}");
        }
    }

    #[test]
    fn churn_rounds_charge_fewer_bytes_than_static() {
        let (cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgd);
        let (stat, _) = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap();
        let mut churn_cfg = cfg.clone();
        churn_cfg.net_plan = "churn".into();
        churn_cfg.churn = 0.4;
        let (churn, _) = train_decentralized(&churn_cfg, &compute, &ds, &graph, &w).unwrap();
        assert!(
            churn.rows.last().unwrap().bytes < stat.rows.last().unwrap().bytes,
            "churn {} vs static {}",
            churn.rows.last().unwrap().bytes,
            stat.rows.last().unwrap().bytes
        );
    }

    #[test]
    fn compressed_runs_train_and_charge_encoded_bytes() {
        for (algo, compress) in [
            (AlgoKind::FdDsgd, "q8"),
            (AlgoKind::FdDsgd, "q4"),
            (AlgoKind::FdDsgd, "topk"),
            (AlgoKind::FdDsgt, "q8"),
            (AlgoKind::FdDsgt, "topk"),
        ] {
            let (mut cfg, compute, ds, graph, w) = setup(algo);
            cfg.total_steps = 60;
            let (dense, _) = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap();
            cfg.compress = compress.into();
            cfg.topk_frac = 0.1;
            let (comp, _) = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap();
            let first = comp.rows.first().unwrap().loss;
            let last = comp.rows.last().unwrap().loss;
            assert!(last.is_finite() && last < first, "{algo:?}/{compress}: {first} -> {last}");
            let (bd, bc) =
                (dense.rows.last().unwrap().bytes, comp.rows.last().unwrap().bytes);
            assert!(bc < bd / 3, "{algo:?}/{compress}: {bc} vs dense {bd}");
        }
    }

    #[test]
    fn straggler_plans_train_end_to_end() {
        for (plan, algo) in [
            ("fixed-tiers", AlgoKind::FdDsgd),
            ("lognormal", AlgoKind::FdDsgt),
            ("dropout", AlgoKind::FdDsgt),
        ] {
            let (mut cfg, compute, ds, graph, w) = setup(algo);
            cfg.compute_plan = plan.into();
            cfg.compute_tiers = "1.0,0.5,0.25".into();
            cfg.compute_sigma = 0.6;
            cfg.slow_frac = 0.4;
            cfg.total_steps = 80;
            let (log, theta) = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap();
            let first = log.rows.first().unwrap().loss;
            let last = log.rows.last().unwrap().loss;
            assert!(last.is_finite() && last < first, "{plan}: loss {first} -> {last}");
            assert!(theta.iter().all(|v| v.is_finite()), "{plan}");
            // straggler rounds did strictly less local work than uniform Q
            let rows = &log.rows;
            let uniform_steps = rows.last().unwrap().comm_rounds * cfg.q as u64;
            assert!(
                rows.last().unwrap().local_steps <= uniform_steps,
                "{plan}: {} > uniform {uniform_steps}",
                rows.last().unwrap().local_steps
            );
            if plan == "dropout" {
                assert!(
                    rows.last().unwrap().local_steps < uniform_steps,
                    "{plan}: slow_frac=0.4 must shave off local work"
                );
            }
        }
    }

    #[test]
    fn degenerate_single_tier_is_bitwise_uniform() {
        // tiers = "1.0" routes through the heterogeneous code path (hetero
        // kernel + τ-weights), but every τ = Q and every weight is exactly
        // 1.0 — the trajectory must match the legacy uniform path bit for
        // bit (sim_time is charged through a different arithmetic path and
        // is exempt)
        let (cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgt);
        let (uni, theta_u) = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap();
        let mut tiers = cfg.clone();
        tiers.compute_plan = "fixed-tiers".into();
        tiers.compute_tiers = "1.0".into();
        let (tier, theta_t) = train_decentralized(&tiers, &compute, &ds, &graph, &w).unwrap();
        assert_eq!(theta_u, theta_t, "θ stacks diverged");
        assert_eq!(uni.rows.len(), tier.rows.len());
        for (a, b) in uni.rows.iter().zip(&tier.rows) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
            assert_eq!(a.bytes, b.bytes);
            assert_eq!(a.local_steps, b.local_steps);
        }
    }

    #[test]
    fn baselines_reject_compute_plans_loudly() {
        let (mut cfg, compute, ds, ..) = setup(AlgoKind::FedAvg);
        cfg.compute_plan = "dropout".into();
        let err = train_fedavg(&cfg, &compute, &ds).unwrap_err();
        assert!(err.to_string().contains("synchronous"), "{err}");
        cfg.algo = AlgoKind::Centralized;
        let err = train_centralized(&cfg, &compute, &ds).unwrap_err();
        assert!(err.to_string().contains("fusion center"), "{err}");
    }

    #[test]
    fn classic_q1_rejects_straggler_plans() {
        let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::Dsgd);
        cfg.compute_plan = "dropout".into();
        let err = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap_err();
        assert!(err.to_string().contains("local phase"), "{err}");
    }

    #[test]
    fn baselines_reject_compression_loudly() {
        let (mut cfg, compute, ds, ..) = setup(AlgoKind::FedAvg);
        cfg.compress = "q8".into();
        let err = train_fedavg(&cfg, &compute, &ds).unwrap_err();
        assert!(err.to_string().contains("compress"), "{err}");
        cfg.algo = AlgoKind::Centralized;
        let err = train_centralized(&cfg, &compute, &ds).unwrap_err();
        assert!(err.to_string().contains("compress"), "{err}");
    }

    #[test]
    fn baselines_reject_adversarial_axes_loudly() {
        let (mut cfg, compute, ds, ..) = setup(AlgoKind::FedAvg);
        cfg.attack_plan = "sign-flip".into();
        cfg.attack_frac = 0.25;
        let err = train_fedavg(&cfg, &compute, &ds).unwrap_err();
        assert!(err.to_string().contains("gossip"), "{err}");
        cfg.attack_plan = "none".into();
        cfg.attack_frac = 0.0;
        cfg.dp = "gaussian".into();
        let err = train_fedavg(&cfg, &compute, &ds).unwrap_err();
        assert!(err.to_string().contains("dp=gaussian"), "{err}");
        cfg.dp = "off".into();
        cfg.robust_rule = "median".into();
        cfg.algo = AlgoKind::Centralized;
        let err = train_centralized(&cfg, &compute, &ds).unwrap_err();
        assert!(err.to_string().contains("fusion center"), "{err}");
    }

    #[test]
    fn attacked_runs_replay_bitwise_and_move_the_trajectory() {
        let (cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgd);
        let (honest, _) = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap();
        let mut acfg = cfg.clone();
        acfg.attack_plan = "sign-flip".into();
        acfg.attack_frac = 0.25;
        let (a, ta) = train_decentralized(&acfg, &compute, &ds, &graph, &w).unwrap();
        let (_b, tb) = train_decentralized(&acfg, &compute, &ds, &graph, &w).unwrap();
        assert_eq!(ta, tb, "attacked runs must replay bitwise");
        assert_ne!(
            a.rows.last().unwrap().loss.to_bits(),
            honest.rows.last().unwrap().loss.to_bits(),
            "a 25% sign-flip adversary must move the trajectory"
        );
        // wire accounting is untouched by the Identity routing: same bytes
        assert_eq!(a.rows.last().unwrap().bytes, honest.rows.last().unwrap().bytes);
    }

    #[test]
    fn dp_runs_report_a_growing_epsilon() {
        let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgd);
        cfg.dp = "gaussian".into();
        cfg.dp_clip = 50.0;
        cfg.dp_sigma = 1.0;
        let (log, theta) = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap();
        assert!(theta.iter().all(|v| v.is_finite()));
        let rows = &log.rows;
        assert_eq!(rows[0].dp_epsilon, 0.0, "round 0 releases nothing");
        let eps: Vec<f64> = rows[1..].iter().map(|r| r.dp_epsilon).collect();
        assert!(eps.iter().all(|&e| e > 0.0), "{eps:?}");
        assert!(eps.windows(2).all(|w| w[1] > w[0]), "ε must compose upward: {eps:?}");
        // and it matches the plan's accountant exactly (1 release/round for DSGD)
        let plan = DpPlan { on: true, clip: 50.0, sigma: 1.0, delta: cfg.dp_delta };
        let last = rows.last().unwrap();
        assert_eq!(last.dp_epsilon, plan.epsilon(last.comm_rounds));
        // honest rows report ε ≡ 0
        let (h, _) = train_decentralized(
            &{
                let mut c = cfg.clone();
                c.dp = "off".into();
                c
            },
            &compute,
            &ds,
            &graph,
            &w,
        )
        .unwrap();
        assert!(h.rows.iter().all(|r| r.dp_epsilon == 0.0));
    }

    #[test]
    fn non_finite_payloads_are_quarantined_not_mixed() {
        let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgd);
        cfg.attack_plan = "scaled-noise".into();
        cfg.attack_frac = 0.25;
        cfg.attack_scale = 1e39; // overflows f32 → ±Inf payload rows
        let (log, theta) = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap();
        assert!(
            log.rows.last().unwrap().quarantined > 0,
            "Inf payloads must be counted as quarantined"
        );
        // every honest node's parameters stay finite — the poison never mixed
        let sched = AttackSchedule::new(
            AttackPlan::ScaledNoise { scale: 1e39 },
            0.25,
            cfg.n,
            cfg.seed,
        )
        .unwrap();
        let p = theta.len() / cfg.n;
        for i in 0..cfg.n {
            if !sched.is_attacker(i) {
                assert!(
                    theta[i * p..(i + 1) * p].iter().all(|v| v.is_finite()),
                    "honest row {i} was poisoned"
                );
            }
        }
    }

    #[test]
    fn strategies_share_one_loop_and_all_train() {
        let (cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgd);
        let (dsgd, _) = train_decentralized(&cfg, &compute, &ds, &graph, &w).unwrap();
        let mut c2 = cfg.clone();
        c2.algo = AlgoKind::FedAvg;
        let (fa, _) = train_fedavg(&c2, &compute, &ds).unwrap();
        let mut c3 = cfg.clone();
        c3.algo = AlgoKind::Centralized;
        let (ct, _) = train_centralized(&c3, &compute, &ds).unwrap();
        for log in [&dsgd, &fa, &ct] {
            let first = log.rows.first().unwrap().loss;
            let last = log.rows.last().unwrap().loss;
            assert!(last < first, "{}: loss {first} -> {last}", log.algo);
        }
        // same cadence from the same engine
        assert_eq!(dsgd.rows.len(), fa.rows.len());
        assert_eq!(dsgd.rows.len(), ct.rows.len());
        // centralized pays zero bytes; fedavg pays star bytes
        assert_eq!(ct.rows.last().unwrap().bytes, 0);
        assert!(fa.rows.last().unwrap().bytes > 0);
    }
}
