//! Asynchronous event-driven gossip runtime — training without the global
//! round barrier (DESIGN.md §13).
//!
//! The synchronous engine advances every hospital in lockstep: round `r`
//! cannot start until the slowest participant of round `r − 1` arrives, so
//! under heterogeneous compute the whole fleet pays `max_i τ_i·s/speed_i`
//! per round.  This module drops that barrier.  Each node runs on its own
//! simulated clock: after finishing its τ_i local steps it *gossips and
//! moves on* — it broadcasts its current θ (and the DSGT tracker ϑ) to the
//! neighbors its round-`g` network view names, applies whatever neighbor
//! states have already *arrived* (possibly stale, AD-PSGD-style), and
//! immediately starts its next cycle.  Nobody ever waits for anybody.
//!
//! **Virtual-time event queue.**  The runtime is a discrete-event simulator:
//! a binary min-heap of events keyed by `(t_us, node, seq)` where `t_us` is
//! integer microseconds of virtual time, `node` the acting/receiving node,
//! and `seq` a globally monotone sequence number assigned in deterministic
//! push order.  The integer key makes the ordering total (no f64 ties), and
//! the seq tie-break makes replays *bitwise*-deterministic: the same seed
//! pops the same events in the same order, so the same f32 arithmetic runs
//! in the same sequence — across runs and across native-backend thread
//! counts alike (pinned by `tests/async_driver.rs`).
//!
//! **Clock model.**  Node `i`'s cycle `g` (1-based, the async analogue of a
//! communication round) occupies `τ_i(g)·s_step/speed_i(g)` virtual seconds
//! of compute — the same `(seed, round, node)`-keyed [`ComputeSchedule`]
//! quantities the sync drivers consult, so a plan means the same thing under
//! either driver.  A message put on the wire at `t` arrives at
//! `t + latency + wire_bytes/bandwidth`: per-message delivery latency from
//! the same [`LinkModel`] the analytic accountant charges.  Bytes and
//! message counts come from the accountant's new per-message charge path
//! ([`Accountant::comm_message`]); the *reported* `sim_time_s` is the event
//! clock itself (links run in parallel; the accountant's serialized
//! link-occupancy total is not wall-clock here).
//!
//! **Staleness semantics.**  A receiver keeps only the latest message per
//! neighbor.  At mix time the compacted CSR row is re-weighted: neighbors
//! whose newest state is missing or older than `run.staleness_s` fold their
//! weight into the receiver's self-weight — exactly how churn's offline rows
//! collapse to identity — so every applied row stays row-stochastic and the
//! fixed point stays a consensus.  `staleness_s = 0` (the default) means
//! uncapped: any received state is usable.  The update equations are the
//! sync strategies' own (eq. 2/3 with the CHOCO difference form under
//! compression), with two deliberate differences.  First, there is **no
//! FedNova τ-reweighting** — τ-weights normalize per-*round* displacement
//! against a shared barrier, and without a barrier each node's clock already
//! charges its true work (DESIGN.md §13 discusses why reweighting is moot
//! here).  Second, the **learning rate keys on the AD-PSGD global iteration
//! counter** (`fleet cycles done / n + 1`), not the node's own cycle count:
//! a per-node schedule lets a rare heavy-tail straggler hold α near α₀
//! forever and re-inject fresh-start gradient noise into an otherwise
//! converged fleet.  Under uniform compute the two counters coincide
//! exactly (lockstep completion, node-order tie-break), so this only
//! changes heterogeneous runs.
//!
//! **Cycle budget vs time budget.**  By default every node runs
//! `total_steps / q` cycles — the sync round count, the apples-to-apples
//! *per-cycle* comparison.  With `run.sim_budget_s > 0` nodes instead keep
//! cycling until the *next* cycle would finish past that virtual-clock
//! horizon.  This is the matched-wall-clock frontier (EXP-AS1): give the
//! barrier-free driver the simulated time the barriered run spent and let
//! it spend the window on more, cheaper, stale-mixed cycles.  Per-cycle
//! async progress is *worse* than a sync round's (stale neighbor states
//! propagate gradient information late); the barrier-free clock buys back
//! more than the difference when q·s_step dominates delivery latency and
//! the straggler tail is heavy — and not otherwise, which is why the
//! frontier experiment pins the regime explicitly.
//!
//! **What is pinned, what is movable.**  The synchronous engine remains the
//! oracle: `run.driver = "sync"` (the default) never routes through this
//! module, and every default trajectory stays bitwise-identical.  The async
//! axis composes with the net plan (per-cycle views by `view_key`), the
//! compression subsystem (`(seed, cycle, node, kind)`-keyed messages,
//! error feedback included), and the compute plan (per-cycle τ and speed).
//! Evaluation samples the *whole fleet's* θ stack at virtual-time
//! checkpoints: when the minimum completed-cycle count crosses the eval
//! cadence — the async analogue of "round r finished everywhere".

use crate::algo::native::NativeModel;
use crate::algo::{add_diff, axpy};
use crate::compress::GossipComm;
use crate::config::{ExperimentConfig, Mode};
use crate::coordinator::compute::Compute;
use crate::coordinator::sampler::{init_theta, NodeSampler};
use crate::data::FederatedDataset;
use crate::graph::{Graph, NetworkSchedule, ViewScratch};
use crate::metrics::{round_metrics, RunLog};
use crate::mixing::SparseW;
use crate::netsim::{analytic::Accountant, LinkModel, PayloadKind};
use anyhow::{bail, Result};
use std::cmp::Ordering;
use std::collections::{BTreeMap, BinaryHeap};
use std::rc::Rc;

use super::adversary::{dp_from_config, DpPlan, MsgPerturb};
use super::pipeline::{encode_row_owned, RowPerturb};
use super::{ComputeSchedule, RoundEngine};

/// Virtual seconds → integer microseconds (the heap's total-order clock).
fn to_us(s: f64) -> u64 {
    (s * 1e6).round() as u64
}

// ------------------------------------------------------------- events ----

/// What an event does when it fires.
enum Action {
    /// Node `node` finishes its next cycle's compute: run the local steps,
    /// mix whatever neighbor states have arrived, update, and broadcast.
    Cycle,
    /// A gossip message from `from` arrives at `node`.
    Deliver {
        /// Sending node.
        from: usize,
        /// Decoded θ payload (what every receiver would decode from the
        /// wire — x̂ under compression, the true θ otherwise).  `Rc` so one
        /// broadcast allocates once, not once per neighbor.
        theta: Rc<Vec<f32>>,
        /// Decoded tracker payload (DSGT only).
        tracker: Option<Rc<Vec<f32>>>,
        /// Virtual send time — staleness is measured from here.
        sent_us: u64,
    },
}

/// One heap entry.  Ordering is on `(t_us, node, seq)` only — `seq` is
/// assigned in deterministic single-threaded push order, so ties at equal
/// virtual time break identically on every replay.
struct Event {
    t_us: u64,
    node: u32,
    seq: u64,
    action: Action,
}

impl Event {
    fn key(&self) -> (u64, u32, u64) {
        (self.t_us, self.node, self.seq)
    }
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    /// Reversed: `BinaryHeap` is a max-heap, we want earliest-first.
    fn cmp(&self, other: &Self) -> Ordering {
        other.key().cmp(&self.key())
    }
}

// -------------------------------------------------------------- nodes ----

/// Latest state received from one neighbor (newer sends replace older).
struct InMsg {
    theta: Rc<Vec<f32>>,
    tracker: Option<Rc<Vec<f32>>>,
    sent_us: u64,
}

/// One hospital's training state on its own clock.
struct Node {
    theta: Vec<f32>,
    /// DSGT tracker ϑ and previous gradient (empty for DSGD).
    y_tr: Vec<f32>,
    g_prev: Vec<f32>,
    sampler: NodeSampler,
    /// Error-feedback residuals (empty unless compressing with EF).
    e_theta: Vec<f32>,
    e_y: Vec<f32>,
    /// Cycles completed so far; the next cycle is `done + 1`.
    done: u64,
    /// Newest message per sending neighbor.
    inbox: BTreeMap<usize, InMsg>,
    /// Cached slice of the node's current network view (same caching as the
    /// sync drivers' `refresh_net`, keyed per node because nodes sit in
    /// different rounds).
    net_key: Option<u64>,
    online_now: bool,
    nbrs: Vec<usize>,
    widx: Vec<u32>,
    wval: Vec<f32>,
}

/// Everything [`train`] returns plus the replay/staleness instrumentation
/// the determinism and staleness-bound tests pin.
pub struct AsyncReport {
    /// The metric log (what [`train`] returns).
    pub log: RunLog,
    /// Final θ stack `[n, p]`.
    pub theta: Vec<f32>,
    /// Running FNV-style hash over every popped event key `(t_us, node,
    /// seq)` — two runs that pop the same events in the same order agree.
    pub trace_hash: u64,
    /// Oldest neighbor state ever applied, in virtual µs (0 if none).
    pub max_applied_age_us: u64,
    /// Neighbor states applied across all cycles.
    pub applied: u64,
    /// Row entries folded into self-weight (missing or over the cap).
    pub folded: u64,
    /// Virtual time of the last completed cycle, µs.
    pub final_t_us: u64,
}

// ---------------------------------------------------------- simulator ----

/// Reusable per-event scratch (one copy for the whole fleet — the event
/// loop is single-threaded, so nothing here is per-node).
struct Scratch {
    lrs: Vec<f32>,
    lx: Vec<f32>,
    ly: Vec<f32>,
    bx: Vec<f32>,
    by: Vec<f32>,
    /// Stacked neighbor states `[n, p]` the sparse combine reads.
    stacked: Vec<f32>,
    /// Per-row-entry keep flags for the current compaction.
    keep: Vec<bool>,
    /// The compacted (fresh-neighbors-only) mixing row.
    cw_idx: Vec<u32>,
    cw_val: Vec<f32>,
    vbuf: Vec<f32>,
    xhat_own: Vec<f32>,
    yhat_own: Vec<f32>,
    view: ViewScratch,
    eval_stack: Vec<f32>,
}

struct Sim<'a> {
    cfg: &'a ExperimentConfig,
    compute: &'a dyn Compute,
    ds: &'a FederatedDataset,
    net: NetworkSchedule,
    csched: ComputeSchedule,
    comm: GossipComm,
    /// Attack/DP perturbation pipeline (`engine::adversary`), applied at the
    /// encode boundary — `None` on the pinned honest path.
    perturb: Option<MsgPerturb>,
    /// DP accountant inputs: the (ε, δ) plan and releases per cycle (2 for
    /// DSGT's θ+ϑ streams, 1 otherwise).
    dp: DpPlan,
    dp_kinds: u64,
    acct: Accountant,
    nodes: Vec<Node>,
    scratch: Scratch,
    heap: BinaryHeap<Event>,
    seq: u64,
    n: usize,
    p: usize,
    q: usize,
    local: usize,
    rounds: u64,
    eval_every: u64,
    use_tracker: bool,
    sched: crate::algo::LrSchedule,
    /// Per-kind encoded wire sizes (θ, and ϑ for DSGT).
    kind_bytes: Vec<u64>,
    /// Staleness cap in virtual µs (`None` = uncapped).
    cap_us: Option<u64>,
    /// Simulated-time budget in virtual µs (`None` = cycle-count budget).
    budget_us: Option<u64>,
    /// Fleet-total completed cycles — the AD-PSGD global iteration counter
    /// that keys the learning-rate schedule (`events / n + 1`).  Under
    /// uniform compute every node's `events / n + 1` equals its own cycle
    /// count exactly (lockstep completion, node-order tie-break), so the
    /// global counter is bitwise-identical to per-node counting there; it
    /// only diverges under heterogeneous plans, where it stops rare slow
    /// nodes from re-injecting α₀-scale gradient noise forever.
    events: u64,
    // --- checkpointing ---
    min_done: u64,
    at_min: usize,
    /// Σ_{g ≤ min_done} Σ_i τ_i(g) — the hetero `local_steps` metric.
    work_through: u64,
    log: RunLog,
    started: std::time::Instant,
    // --- instrumentation ---
    trace_hash: u64,
    max_applied_age_us: u64,
    applied: u64,
    folded: u64,
    final_t_us: u64,
}

impl Sim<'_> {
    /// Refresh node `i`'s cached view for its cycle `round` (no-op while the
    /// schedule's view key is unchanged — the per-node twin of the sync
    /// drivers' `refresh_net`).
    fn refresh_net(&mut self, i: usize, round: usize) -> Result<()> {
        let key = self.net.view_key(round);
        if self.nodes[i].net_key == Some(key) {
            return Ok(());
        }
        let view = self.net.view_into(round, &mut self.scratch.view)?;
        let node = &mut self.nodes[i];
        node.online_now = view.online[i];
        view.active_neighbors_into(i, &mut node.nbrs);
        let (widx, wval) = view.sparse_row(i);
        node.widx.clear();
        node.widx.extend_from_slice(widx);
        node.wval.clear();
        node.wval.extend_from_slice(wval);
        node.net_key = Some(key);
        Ok(())
    }

    /// Virtual seconds node `i`'s cycle `g` spends computing: τ gradient
    /// steps at the node's scheduled speed — the per-node quantity whose
    /// *maximum* a synchronous round charges.
    fn cycle_s(&self, g: usize, i: usize) -> f64 {
        self.csched.tau(g, i) as f64 * self.cfg.compute_s_per_step / self.csched.speed(g, i)
    }

    fn push(&mut self, t_us: u64, node: usize, action: Action) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { t_us, node: node as u32, seq, action });
    }

    /// Evaluate the whole fleet at virtual time `t_us`, logged as checkpoint
    /// `m` (the cycle count every node has completed).
    fn eval_at(&mut self, m: u64, t_us: u64) -> Result<()> {
        let p = self.p;
        for (i, node) in self.nodes.iter().enumerate() {
            self.scratch.eval_stack[i * p..(i + 1) * p].copy_from_slice(&node.theta);
        }
        // honest-sub-fleet metrics under an active attack (DESIGN.md §14),
        // same masking as the sync drivers
        let eval = crate::engine::pipeline::eval_honest_subset(
            self.perturb.as_ref().map(|pb| &pb.attack),
            &self.scratch.eval_stack,
            &self.ds.shards,
            p,
            self.compute,
        )?;
        let mut snap = self.acct.snapshot();
        // the event clock IS the wall clock here; the accountant's
        // serialized total is link occupancy (see the module docs)
        snap.sim_time_s = t_us as f64 / 1e6;
        let steps = if self.csched.is_uniform() {
            m * self.q as u64
        } else {
            self.work_through / self.n as u64
        };
        let mut row = round_metrics(m, steps, eval, snap, self.started.elapsed().as_secs_f64());
        // (ε, δ) upper bound at this checkpoint: without a barrier the
        // fleet's release counts diverge, so report the *fastest* node's
        // (kinds × its completed cycles) — conservative for every node
        let max_done = self.nodes.iter().map(|nd| nd.done).max().unwrap_or(0);
        row.dp_epsilon = self.dp.epsilon(self.dp_kinds * max_done);
        self.log.push(row);
        Ok(())
    }

    /// Advance the fleet-minimum cycle counter after node `i` finished a
    /// cycle at `t_us`, firing eval checkpoints for every cadence crossing.
    fn advance_min(&mut self, old_done: u64, t_us: u64) -> Result<()> {
        if old_done != self.min_done {
            return Ok(());
        }
        self.at_min -= 1;
        while self.at_min == 0 && self.min_done < self.rounds {
            self.min_done += 1;
            if !self.csched.is_uniform() {
                self.work_through += self.csched.local_work(self.min_done as usize);
            }
            if self.min_done % self.eval_every == 0 || self.min_done == self.rounds {
                self.eval_at(self.min_done, t_us)?;
                self.final_t_us = t_us;
            }
            let m = self.min_done;
            self.at_min = self.nodes.iter().filter(|nd| nd.done == m).count();
        }
        Ok(())
    }

    /// Encode one outgoing payload stream of cycle `g` and return what the
    /// wire delivers.  Under compression this is the per-stream twin of the
    /// sync drivers' encode step — same helpers, same `(seed, cycle, node,
    /// kind)` key, and the same attack/DP perturbation applied to the
    /// message before encoding (so an attacker's own mix row drinks its own
    /// poison here too) — writing the node's own mix row into `hat`;
    /// uncompressed sends ship the raw vector (only reachable unperturbed:
    /// [`train_report`] routes perturbed runs through `Identity`).
    #[allow(clippy::too_many_arguments)]
    fn encode_stream(
        comm: &GossipComm,
        perturb: Option<&mut MsgPerturb>,
        g: usize,
        i: usize,
        kind: PayloadKind,
        data: &[f32],
        e: &mut [f32],
        vbuf: &mut [f32],
        hat: &mut [f32],
    ) -> Result<Rc<Vec<f32>>> {
        match &comm.comp {
            Some(comp) => {
                let rp = match perturb {
                    Some(pb) => RowPerturb::Inline(pb),
                    None => RowPerturb::Off,
                };
                encode_row_owned(
                    comp.as_ref(),
                    comm.error_feedback,
                    comm.seed,
                    g,
                    i,
                    kind,
                    data,
                    e,
                    vbuf,
                    hat,
                    rp,
                )?;
                Ok(Rc::new(hat.to_vec()))
            }
            None => {
                anyhow::ensure!(
                    perturb.is_none(),
                    "perturbation pipeline active without a compressor — node {i} misrouted",
                );
                Ok(Rc::new(data.to_vec()))
            }
        }
    }

    /// Node `i` finishes cycle `g = done + 1` at virtual time `t_us`:
    /// local steps → mix arrived neighbor states → eq. 2/3 update →
    /// fire-and-forget broadcast → schedule the next cycle.
    fn cycle(&mut self, i: usize, t_us: u64) -> Result<()> {
        let m = self.cfg.m;
        let d = self.ds.d;
        let g = (self.nodes[i].done + 1) as usize;
        // learning rate keys on the *global* iteration counter (AD-PSGD);
        // samplers, τ/speed, net views and message keys stay per-node `g`
        let g_lr = (self.events / self.n as u64) as usize + 1;
        self.events += 1;

        // ---- local phase: the same Q−1 batches every driver draws ----
        if self.local > 0 {
            self.sched.local_lrs_into(g_lr, self.q, &mut self.scratch.lrs);
            let node = &mut self.nodes[i];
            node.sampler.batches(
                &self.ds.shards[i],
                self.local,
                &mut self.scratch.lx,
                &mut self.scratch.ly,
            );
            // stragglers use only their τ_i − 1 prefix (sampler streams stay
            // plan-independent, §7); no τ-weight rescale — each node's clock
            // already charges its true work (module docs)
            let li = if self.csched.is_uniform() {
                self.local
            } else {
                (self.csched.tau(g, i) - 1).min(self.local)
            };
            if li > 0 {
                let (t2, _) = self.compute.local_steps(
                    &node.theta,
                    &self.scratch.lx[..li * m * d],
                    &self.scratch.ly[..li * m],
                    &self.scratch.lrs[..li],
                )?;
                self.nodes[i].theta = t2;
            }
        }

        self.refresh_net(i, g)?;
        let lr = self.sched.comm_lr(g_lr, self.q);

        if !self.nodes[i].online_now {
            // offline this cycle (node churn): draw-and-discard the comm
            // batch so the (seed, row)-keyed sampler stream stays aligned
            // with every other driver and plan (§7), skip the exchange
            let node = &mut self.nodes[i];
            node.sampler.batch(&self.ds.shards[i], &mut self.scratch.bx, &mut self.scratch.by);
        } else {
            self.exchange(i, g, t_us, lr)?;
        }

        // ---- bookkeeping: cycle done, checkpoint, next cycle ----
        let old_done = self.nodes[i].done;
        self.nodes[i].done = old_done + 1;
        self.advance_min(old_done, t_us)?;
        let next = t_us + to_us(self.cycle_s(g + 1, i));
        let more = match self.budget_us {
            // matched-time frontier: cycle while the next completion still
            // lands inside the simulated-time budget
            Some(b) => next <= b,
            None => self.nodes[i].done < self.rounds,
        };
        if more {
            self.push(next, i, Action::Cycle);
        }
        Ok(())
    }

    /// The online communication step of cycle `g`: encode/broadcast, fold
    /// stale-or-missing neighbors into the self-weight, mix through the
    /// compacted CSR row, and apply the eq. 2/3 update (difference form
    /// under compression) — the sync strategies' arithmetic, verbatim.
    fn exchange(&mut self, i: usize, g: usize, t_us: u64, lr: f32) -> Result<()> {
        let p = self.p;
        let compressing = self.comm.enabled();

        // ---- encode the outgoing payloads (own mix rows under compression) ----
        let (theta_pl, tracker_pl) = {
            let node = &mut self.nodes[i];
            let theta_pl = Self::encode_stream(
                &self.comm,
                self.perturb.as_mut(),
                g,
                i,
                PayloadKind::Params,
                &node.theta,
                &mut node.e_theta,
                &mut self.scratch.vbuf,
                &mut self.scratch.xhat_own,
            )?;
            let tracker_pl = if self.use_tracker {
                Some(Self::encode_stream(
                    &self.comm,
                    self.perturb.as_mut(),
                    g,
                    i,
                    PayloadKind::Tracker,
                    &node.y_tr,
                    &mut node.e_y,
                    &mut self.scratch.vbuf,
                    &mut self.scratch.yhat_own,
                )?)
            } else {
                None
            };
            (theta_pl, tracker_pl)
        };

        // ---- compact the row: stale/missing neighbors fold into self ----
        {
            let node = &self.nodes[i];
            let row_len = node.widx.len();
            self.scratch.keep.clear();
            self.scratch.keep.resize(row_len, false);
            let mut self_w = 0.0f32;
            for (k, &ju) in node.widx.iter().enumerate() {
                let j = ju as usize;
                if j == i {
                    self_w += node.wval[k];
                    continue;
                }
                let fresh = node
                    .inbox
                    .get(&j)
                    .map_or(false, |msg| self.cap_us.map_or(true, |cap| t_us - msg.sent_us <= cap));
                if fresh {
                    self.scratch.keep[k] = true;
                } else {
                    self_w += node.wval[k];
                    self.folded += 1;
                }
            }
            self.scratch.cw_idx.clear();
            self.scratch.cw_val.clear();
            let mut pushed_self = false;
            for (k, &ju) in node.widx.iter().enumerate() {
                let j = ju as usize;
                if j == i {
                    self.scratch.cw_idx.push(ju);
                    self.scratch.cw_val.push(self_w);
                    pushed_self = true;
                    continue;
                }
                if !pushed_self && j > i {
                    self.scratch.cw_idx.push(i as u32);
                    self.scratch.cw_val.push(self_w);
                    pushed_self = true;
                }
                if self.scratch.keep[k] {
                    self.scratch.cw_idx.push(ju);
                    self.scratch.cw_val.push(node.wval[k]);
                    let msg = &node.inbox[&j];
                    self.scratch.stacked[j * p..(j + 1) * p].copy_from_slice(&msg.theta);
                    let age = t_us - msg.sent_us;
                    self.max_applied_age_us = self.max_applied_age_us.max(age);
                    self.applied += 1;
                }
            }
            if !pushed_self {
                self.scratch.cw_idx.push(i as u32);
                self.scratch.cw_val.push(self_w);
            }
            // own mix row: the decoded x̂ under compression (what the
            // neighbors decode from the wire), the true θ otherwise
            if compressing {
                self.scratch.stacked[i * p..(i + 1) * p].copy_from_slice(&self.scratch.xhat_own);
            } else {
                self.scratch.stacked[i * p..(i + 1) * p].copy_from_slice(&self.nodes[i].theta);
            }
        }
        let mixed =
            self.compute.combine_sparse(i as u32, &self.scratch.cw_idx, &self.scratch.cw_val, &self.scratch.stacked)?;

        // ---- eq. 2 / eq. 3 update (the sync strategies' arithmetic) ----
        // Byzantine nodes broadcast poison but don't follow the update
        // rule: an attacker runs the cycle like everyone else (keeping the
        // sampler and compressor streams aligned) and then discards the
        // result, ending the cycle at its post-local state — the async
        // image of the sync drivers' `restore_attacker_rows`.
        let byzantine = self
            .perturb
            .as_ref()
            .is_some_and(|pb| pb.attack.active() && pb.attack.is_attacker(i));
        {
            let node = &mut self.nodes[i];
            node.sampler.batch(&self.ds.shards[i], &mut self.scratch.bx, &mut self.scratch.by);
        }
        if self.use_tracker {
            // second combine over the SAME compacted row: tracker rows
            {
                let node = &self.nodes[i];
                for &ju in self.scratch.cw_idx.iter() {
                    let j = ju as usize;
                    if j == i {
                        continue;
                    }
                    let msg = &node.inbox[&j];
                    let tr = msg.tracker.as_ref().expect("DSGT peers always ship a tracker");
                    self.scratch.stacked[j * p..(j + 1) * p].copy_from_slice(tr);
                }
                if compressing {
                    self.scratch.stacked[i * p..(i + 1) * p].copy_from_slice(&self.scratch.yhat_own);
                } else {
                    self.scratch.stacked[i * p..(i + 1) * p].copy_from_slice(&node.y_tr);
                }
            }
            let mixed_y = self.compute.combine_sparse(
                i as u32,
                &self.scratch.cw_idx,
                &self.scratch.cw_val,
                &self.scratch.stacked,
            )?;
            let node = &mut self.nodes[i];
            // θ⁺ = Σ W θ̂ (+ own full-precision correction, §10) − α ϑ
            let mut theta_next = mixed;
            if compressing {
                add_diff(&mut theta_next, &node.theta, &self.scratch.xhat_own);
            }
            axpy(&mut theta_next, -lr, &node.y_tr);
            // ϑ⁺ = Σ W ϑ̂ (+ correction) + ∇g(θ⁺) − ∇g(θ)
            let (_, g_new) =
                self.compute.grad_step(&theta_next, &self.scratch.bx, &self.scratch.by)?;
            let mut y_next = mixed_y;
            if compressing {
                add_diff(&mut y_next, &node.y_tr, &self.scratch.yhat_own);
            }
            axpy(&mut y_next, 1.0, &g_new);
            axpy(&mut y_next, -1.0, &node.g_prev);
            if !byzantine {
                node.theta = theta_next;
                node.y_tr = y_next;
                node.g_prev = g_new;
            }
        } else {
            let node = &mut self.nodes[i];
            // θ⁺ = Σ W θ̂ (+ correction) − α ∇g(θ): gradient at pre-mix θ
            let (_, grad) = self.compute.grad_step(&node.theta, &self.scratch.bx, &self.scratch.by)?;
            let mut theta_next = mixed;
            if compressing {
                add_diff(&mut theta_next, &node.theta, &self.scratch.xhat_own);
            }
            axpy(&mut theta_next, -lr, &grad);
            if !byzantine {
                node.theta = theta_next;
            }
        }

        // ---- fire-and-forget broadcast: one Deliver event per neighbor ----
        // each directed edge is its own link, so deliveries run in parallel;
        // the accountant charges every message's bytes and occupancy
        let nbrs = std::mem::take(&mut self.nodes[i].nbrs);
        for &j in &nbrs {
            let dt = self.acct.comm_message(&self.kind_bytes, self.cfg.latency_s);
            self.push(
                t_us + to_us(dt),
                j,
                Action::Deliver {
                    from: i,
                    theta: Rc::clone(&theta_pl),
                    tracker: tracker_pl.as_ref().map(Rc::clone),
                    sent_us: t_us,
                },
            );
        }
        self.nodes[i].nbrs = nbrs;
        Ok(())
    }
}

/// FNV-style fold for the event-trace hash.
fn fold(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
}

/// Run the asynchronous driver and return the full report (log + final θ +
/// replay/staleness instrumentation).  [`train`] is the coordinator-facing
/// wrapper that keeps only the log.
pub fn train_report(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &SparseW,
) -> Result<AsyncReport> {
    let (d, h, p) = compute.dims();
    if d != ds.d {
        bail!("backend d={d} vs dataset d={}", ds.d);
    }
    let n = ds.n_hospitals();
    if graph.n() != n {
        bail!("graph has {} nodes, dataset has {n}", graph.n());
    }
    if matches!(cfg.mode, Mode::Actors) {
        bail!(
            "run.driver=async is its own virtual-time event loop and would silently \
             ignore `--mode actors`; drop the mode flag (the sync driver keeps both modes)"
        );
    }
    if cfg.drop_prob > 0.0 {
        bail!(
            "drop_prob={} requested, but async delivery is charged analytically over \
             lossless links; use `--mode actors` with the sync driver for loss injection",
            cfg.drop_prob
        );
    }
    let eng = RoundEngine::from_config(cfg);
    if let Some(want) = compute.local_steps_len() {
        if eng.plan.local_per_round > 0 && eng.plan.local_per_round != want {
            bail!(
                "artifacts were lowered for Q={} (local phase {want}), config wants Q={}; \
                 re-run `make artifacts Q={}` or use --backend native",
                want + 1,
                eng.q,
                eng.q
            );
        }
    }
    let csched = ComputeSchedule::from_config(cfg)?;
    csched.ensure_runnable(n, compute.local_steps_len())?;
    let net = NetworkSchedule::from_config(cfg, graph.clone(), w.clone())?;
    let mut comm = GossipComm::from_config(cfg)?;
    // adversarial/DP perturbation lives at the encode boundary: a perturbed
    // run with no compressor routes through Identity (same dense bytes,
    // same decoded values) — the same routing every other driver makes
    let perturb = MsgPerturb::from_config(cfg)?;
    if perturb.is_some() && comm.comp.is_none() {
        comm.comp = Some(Box::new(crate::compress::Identity));
    }
    let dp = dp_from_config(cfg)?;
    let use_tracker = cfg.algo.uses_tracker();
    let kinds = if use_tracker { 2 } else { 1 };
    let kind_bytes = vec![comm.msg_bytes(p); kinds];
    let compressing = comm.enabled();
    let ef = compressing && comm.error_feedback;
    let link = LinkModel {
        latency_s: cfg.latency_s,
        bandwidth_bps: cfg.bandwidth_bps,
        drop_prob: 0.0, // enforced lossless above
    };
    let model = NativeModel::new(d, h);
    let local = eng.plan.local_per_round;
    let m = cfg.m;

    let nodes: Vec<Node> = (0..n)
        .map(|i| Node {
            theta: init_theta(cfg.seed, i, &model),
            y_tr: Vec::new(),
            g_prev: Vec::new(),
            sampler: NodeSampler::new(cfg.seed, i, m),
            e_theta: vec![0.0f32; if ef { p } else { 0 }],
            e_y: vec![0.0f32; if ef && use_tracker { p } else { 0 }],
            done: 0,
            inbox: BTreeMap::new(),
            net_key: None,
            online_now: true,
            nbrs: Vec::new(),
            widx: Vec::new(),
            wval: Vec::new(),
        })
        .collect();

    let mut sim = Sim {
        cfg,
        compute,
        ds,
        net,
        csched,
        comm,
        perturb,
        dp,
        dp_kinds: kinds as u64,
        acct: Accountant::new(link),
        nodes,
        scratch: Scratch {
            lrs: vec![0.0f32; local],
            lx: vec![0.0f32; local * m * d],
            ly: vec![0.0f32; local * m],
            bx: vec![0.0f32; m * d],
            by: vec![0.0f32; m],
            stacked: vec![0.0f32; n * p],
            keep: Vec::new(),
            cw_idx: Vec::new(),
            cw_val: Vec::new(),
            vbuf: vec![0.0f32; if compressing { p } else { 0 }],
            xhat_own: vec![0.0f32; if compressing { p } else { 0 }],
            yhat_own: vec![0.0f32; if compressing && use_tracker { p } else { 0 }],
            view: ViewScratch::new(),
            eval_stack: vec![0.0f32; n * p],
        },
        heap: BinaryHeap::new(),
        seq: 0,
        n,
        p,
        q: eng.q,
        local,
        rounds: if cfg.sim_budget_s > 0.0 { u64::MAX } else { eng.rounds as u64 },
        eval_every: eng.eval_every as u64,
        use_tracker,
        sched: eng.sched,
        kind_bytes,
        cap_us: if cfg.staleness_s > 0.0 { Some(to_us(cfg.staleness_s)) } else { None },
        budget_us: if cfg.sim_budget_s > 0.0 { Some(to_us(cfg.sim_budget_s)) } else { None },
        events: 0,
        min_done: 0,
        at_min: n,
        work_through: 0,
        log: RunLog::new(cfg.algo.name()),
        started: std::time::Instant::now(),
        trace_hash: 0xCBF2_9CE4_8422_2325, // FNV offset basis
        max_applied_age_us: 0,
        applied: 0,
        folded: 0,
        final_t_us: 0,
    };

    // DSGT init: Y⁰ = G⁰ = ∇g(θ⁰) on a fresh batch, same stream position as
    // every other driver
    if use_tracker {
        for i in 0..n {
            let node = &mut sim.nodes[i];
            node.sampler.batch(&ds.shards[i], &mut sim.scratch.bx, &mut sim.scratch.by);
            let (_, g0) = compute.grad_step(&node.theta, &sim.scratch.bx, &sim.scratch.by)?;
            sim.nodes[i].y_tr = g0.clone();
            sim.nodes[i].g_prev = g0;
        }
    }

    // round-0 observation (virtual time 0), then seed every node's first
    // cycle-completion event in node order — the deterministic tie-break
    sim.eval_at(0, 0)?;
    if sim.rounds > 0 {
        for i in 0..n {
            let t = to_us(sim.cycle_s(1, i));
            sim.push(t, i, Action::Cycle);
        }
        let mut last_cycle_us = 0u64;
        while let Some(ev) = sim.heap.pop() {
            sim.trace_hash = fold(fold(fold(sim.trace_hash, ev.t_us), ev.node as u64), ev.seq);
            match ev.action {
                Action::Cycle => {
                    last_cycle_us = ev.t_us;
                    sim.cycle(ev.node as usize, ev.t_us)?;
                }
                Action::Deliver { from, theta, tracker, sent_us } => {
                    // non-finite ingest guard (DESIGN.md §14): a poisoned
                    // payload never enters the inbox, and any state already
                    // banked from the same sender is evicted — at mix time
                    // the sender's weight then folds into the receiver's
                    // self-weight (the same compaction stale entries take)
                    // until a clean message arrives
                    let poisoned = theta.iter().any(|v| !v.is_finite())
                        || tracker
                            .as_ref()
                            .is_some_and(|tr| tr.iter().any(|v| !v.is_finite()));
                    let inbox = &mut sim.nodes[ev.node as usize].inbox;
                    if poisoned {
                        inbox.remove(&from);
                        sim.acct.report_quarantine(1);
                    } else {
                        // keep only the newest state per neighbor (equal-size
                        // messages can't reorder, but the guard costs nothing)
                        let newer = inbox.get(&from).map_or(true, |old| old.sent_us <= sent_us);
                        if newer {
                            inbox.insert(from, InMsg { theta, tracker, sent_us });
                        }
                    }
                }
            }
        }
        // time-budget runs stop by the clock, not a round count, so the
        // final fleet state needs its own observation (the cadence only
        // fires on fleet-min crossings)
        if sim.budget_us.is_some() && last_cycle_us > sim.final_t_us {
            let m = sim.min_done;
            sim.eval_at(m, last_cycle_us)?;
            sim.final_t_us = last_cycle_us;
        }
    }

    let mut theta = vec![0.0f32; n * p];
    for (i, node) in sim.nodes.iter().enumerate() {
        theta[i * p..(i + 1) * p].copy_from_slice(&node.theta);
    }
    Ok(AsyncReport {
        log: sim.log,
        theta,
        trace_hash: sim.trace_hash,
        max_applied_age_us: sim.max_applied_age_us,
        applied: sim.applied,
        folded: sim.folded,
        final_t_us: sim.final_t_us,
    })
}

/// Train a gossip algorithm through the asynchronous event-driven driver
/// (`run.driver = "async"`); returns the metric log.
pub fn train(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &SparseW,
) -> Result<RunLog> {
    train_report(cfg, compute, ds, graph, w).map(|r| r.log)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoKind, Backend, ExperimentConfig, Mode};
    use crate::coordinator::compute::NativeCompute;
    use crate::data::{generate, DataConfig};
    use crate::graph::Topology;
    use crate::mixing::{build_sparse, Scheme};
    use crate::rng::Pcg64;

    fn setup(
        algo: AlgoKind,
        q: usize,
        steps: usize,
    ) -> (ExperimentConfig, NativeCompute, FederatedDataset, Graph, SparseW) {
        let mut cfg = ExperimentConfig::default();
        cfg.n = 5;
        cfg.hidden = 8;
        cfg.m = 8;
        cfg.q = q;
        cfg.algo = algo;
        cfg.total_steps = steps;
        cfg.eval_every = 2;
        cfg.backend = Backend::Native;
        cfg.driver = "async".into();
        cfg.records_per_hospital = 60;
        let ds = generate(&DataConfig {
            n_hospitals: cfg.n,
            records_per_hospital: 60,
            records_jitter: 0,
            heterogeneity: 0.5,
            ..DataConfig::default()
        })
        .unwrap();
        let graph = Graph::build(&Topology::Ring, cfg.n, &mut Pcg64::seed(1)).unwrap();
        let w = build_sparse(&graph, Scheme::Metropolis);
        let compute = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
        (cfg, compute, ds, graph, w)
    }

    #[test]
    fn event_heap_pops_in_time_node_seq_order() {
        let mut heap: BinaryHeap<Event> = BinaryHeap::new();
        // pushed out of order, including full ties on t and (t, node)
        for (t, node, seq) in [(5u64, 1u32, 9u64), (5, 0, 8), (3, 2, 7), (5, 0, 2), (3, 2, 1)] {
            heap.push(Event { t_us: t, node, seq, action: Action::Cycle });
        }
        let mut keys = Vec::new();
        while let Some(e) = heap.pop() {
            keys.push(e.key());
        }
        assert_eq!(keys, vec![(3, 2, 1), (3, 2, 7), (5, 0, 2), (5, 0, 8), (5, 1, 9)]);
    }

    #[test]
    fn async_trains_dsgd_and_dsgt() {
        for (algo, q, steps) in [(AlgoKind::FdDsgd, 4, 48), (AlgoKind::FdDsgt, 4, 48)] {
            let (cfg, compute, ds, graph, w) = setup(algo, q, steps);
            let rep = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
            let first = rep.log.rows.first().unwrap().loss;
            let last = rep.log.rows.last().unwrap().loss;
            assert!(last < first, "{algo:?}: loss {first} -> {last}");
            assert!(rep.log.rows.last().unwrap().bytes > 0, "{algo:?}");
            assert!(rep.applied > 0, "{algo:?}: neighbor states never applied");
            // virtual time advanced and was reported as sim_time
            assert!(rep.log.rows.last().unwrap().sim_time_s > 0.0, "{algo:?}");
        }
    }

    #[test]
    fn async_replay_is_bitwise_deterministic() {
        let (cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgt, 4, 48);
        let a = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        let b = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        assert_eq!(a.trace_hash, b.trace_hash, "event order diverged");
        assert_eq!(a.theta, b.theta, "final θ diverged");
        assert_eq!(a.log.rows.len(), b.log.rows.len());
        for (ra, rb) in a.log.rows.iter().zip(&b.log.rows) {
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
            assert_eq!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits());
            assert_eq!(ra.bytes, rb.bytes);
        }
    }

    #[test]
    fn staleness_cap_bounds_applied_age_and_folds_the_rest() {
        let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgd, 4, 48);
        // uncapped run applies whatever arrived
        let free = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        assert!(free.applied > 0);
        // a cap tighter than one cycle folds everything stale into self
        cfg.staleness_s = 1e-9;
        let capped = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        assert!(capped.max_applied_age_us <= to_us(1e-9));
        assert!(capped.folded > free.folded, "cap must fold more entries");
    }

    #[test]
    fn async_mode_actors_and_drop_prob_bail_loudly() {
        let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgd, 4, 24);
        cfg.mode = Mode::Actors;
        let err = train_report(&cfg, &compute, &ds, &graph, &w).unwrap_err();
        assert!(err.to_string().contains("actors"), "{err}");
        cfg.mode = Mode::Fused;
        cfg.drop_prob = 0.1;
        let err = train_report(&cfg, &compute, &ds, &graph, &w).unwrap_err();
        assert!(err.to_string().contains("lossless"), "{err}");
    }

    #[test]
    fn async_composes_with_net_compression_and_compute_plans() {
        for (net_plan, compress, compute_plan) in [
            ("churn", "none", "uniform"),
            ("rewire", "q8", "uniform"),
            ("static", "topk", "lognormal"),
            ("edge-drop", "none", "dropout"),
        ] {
            let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgt, 4, 48);
            cfg.net_plan = net_plan.into();
            cfg.rewire_every = 2;
            cfg.edge_drop = 0.3;
            cfg.churn = 0.3;
            cfg.compress = compress.into();
            cfg.topk_frac = 0.2;
            cfg.compute_plan = compute_plan.into();
            cfg.compute_sigma = 0.6;
            cfg.slow_frac = 0.4;
            let rep = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
            let first = rep.log.rows.first().unwrap().loss;
            let last = rep.log.rows.last().unwrap().loss;
            assert!(
                last.is_finite() && last < first,
                "{net_plan}/{compress}/{compute_plan}: loss {first} -> {last}"
            );
            assert!(rep.theta.iter().all(|v| v.is_finite()), "{net_plan}/{compress}/{compute_plan}");
        }
    }

    #[test]
    fn virtual_clock_beats_the_synchronous_barrier_under_stragglers() {
        // async finishes when the slowest node's OWN work is done; sync waits
        // out every round's slowest participant — async must be strictly
        // faster on the simulated clock under a lognormal straggler plan
        let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgt, 4, 64);
        cfg.compute_plan = "lognormal".into();
        cfg.compute_sigma = 0.8;
        let rep = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        let t_async = rep.log.rows.last().unwrap().sim_time_s;
        let mut sync_cfg = cfg.clone();
        sync_cfg.driver = "sync".into();
        let (sync_log, _) =
            crate::engine::train_decentralized(&sync_cfg, &compute, &ds, &graph, &w).unwrap();
        let t_sync = sync_log.rows.last().unwrap().sim_time_s;
        assert!(
            t_async < t_sync,
            "async sim time {t_async} must beat the sync barrier {t_sync}"
        );
        // same rounds, same per-round byte totals: the frontier is time-only
        assert_eq!(
            rep.log.rows.last().unwrap().comm_rounds,
            sync_log.rows.last().unwrap().comm_rounds
        );
    }

    #[test]
    fn sim_budget_extends_cycles_to_the_virtual_horizon() {
        let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgt, 4, 48);
        cfg.compute_plan = "lognormal".into();
        cfg.compute_sigma = 1.0;
        let counted = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        let t_counted = counted.log.rows.last().unwrap().sim_time_s;
        // give the fleet 3x the cycle-counted horizon: it must keep cycling
        // past steps/q cycles, stay inside the budget, and log a final
        // observation at the true end of the run
        cfg.sim_budget_s = 3.0 * t_counted;
        let budgeted = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        let last = budgeted.log.rows.last().unwrap();
        assert!(
            last.comm_rounds > counted.log.rows.last().unwrap().comm_rounds,
            "budget run stopped at {} fleet-min cycles",
            last.comm_rounds
        );
        assert!(last.sim_time_s <= cfg.sim_budget_s + 1e-9);
        assert!(last.sim_time_s > t_counted, "budget run ended at {}", last.sim_time_s);
        assert!(last.loss.is_finite());
        // and the budget replay is as deterministic as the counted one
        let again = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        assert_eq!(budgeted.trace_hash, again.trace_hash);
        assert_eq!(budgeted.theta, again.theta);
    }

    #[test]
    fn async_attack_and_dp_replay_bitwise_and_report_epsilon() {
        let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgd, 4, 48);
        let honest = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        cfg.attack_plan = "sign-flip".into();
        cfg.attack_frac = 0.2;
        cfg.dp = "gaussian".into();
        cfg.dp_clip = 50.0;
        let a = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        let b = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        // the adversarial axis keeps the event-driven replay bitwise
        assert_eq!(a.trace_hash, b.trace_hash, "event order diverged under attack");
        assert_eq!(a.theta, b.theta, "final θ diverged under attack");
        // ...while actually moving the trajectory off the honest one
        assert_ne!(a.theta, honest.theta, "attack + DP must move the trajectory");
        // bytes unchanged: the Identity routing ships the same dense f32s
        assert_eq!(
            a.log.rows.last().unwrap().bytes,
            honest.log.rows.last().unwrap().bytes
        );
        // the (ε, δ) accountant reports a growing, positive ε; honest runs 0
        let eps: Vec<f64> = a.log.rows.iter().map(|r| r.dp_epsilon).collect();
        assert_eq!(eps[0], 0.0);
        assert!(*eps.last().unwrap() > 0.0);
        assert!(eps.windows(2).all(|w| w[0] <= w[1]), "ε must be monotone: {eps:?}");
        assert!(honest.log.rows.iter().all(|r| r.dp_epsilon == 0.0));
    }

    #[test]
    fn async_quarantines_poisoned_deliveries() {
        let (mut cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgt, 4, 48);
        cfg.attack_plan = "scaled-noise".into();
        cfg.attack_frac = 0.2;
        cfg.attack_scale = 1e39; // overflows f32 → Inf payloads on the wire
        let rep = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        assert!(
            rep.log.rows.last().unwrap().quarantined > 0,
            "poisoned deliveries must be quarantined"
        );
        // every honest node's final θ stays finite — the poison never mixed
        let sched = crate::engine::AttackSchedule::from_config(&cfg).unwrap();
        let p = rep.theta.len() / cfg.n;
        for i in 0..cfg.n {
            if !sched.is_attacker(i) {
                assert!(
                    rep.theta[i * p..(i + 1) * p].iter().all(|v| v.is_finite()),
                    "honest node {i} was poisoned"
                );
            }
        }
    }

    #[test]
    fn compressed_async_charges_encoded_bytes() {
        let (cfg, compute, ds, graph, w) = setup(AlgoKind::FdDsgd, 4, 48);
        let dense = train_report(&cfg, &compute, &ds, &graph, &w).unwrap();
        let mut c = cfg.clone();
        c.compress = "q8".into();
        let comp = train_report(&c, &compute, &ds, &graph, &w).unwrap();
        let (bd, bc) =
            (dense.log.rows.last().unwrap().bytes, comp.log.rows.last().unwrap().bytes);
        assert!(bc < bd / 3, "q8 bytes {bc} vs dense {bd}");
    }
}
