//! Heterogeneous compute & stragglers: the per-round, per-node local-work
//! schedule τ_i(r).
//!
//! The paper's Algorithm 1 assumes every hospital performs exactly Q local
//! eq.-4 updates between communication rounds — a synchronous, homogeneous
//! fleet.  Real hospital networks are compute-heterogeneous: sites run on
//! different hardware, share machines with clinical workloads, and get
//! preempted.  DeceFL (Yuan et al.) and the communication-perspective survey
//! (Le et al.) both flag stragglers and unequal local work as the dominant
//! practical deviation from the synchronous model.  This module turns the
//! local-step count from a global constant into a first-class scheduled
//! quantity, exactly as `graph::schedule` did for the network: a
//! [`ComputeSchedule`] yields a deterministic per-node gradient-step count
//! τ_i(r) ∈ [1, Q] for every communication round, derived purely from
//! `(seed, round, node)` so every driver — and every node thread of the
//! actor driver — reconstructs the identical schedule independently (the
//! §7 determinism contract).
//!
//! Plans:
//!
//! - [`ComputePlan::Uniform`] — today's behavior: τ_i = Q for everyone.
//!   The drivers keep their legacy code paths byte for byte, so the default
//!   is bitwise-identical to the pre-straggler engine.
//! - [`ComputePlan::FixedTiers`] — a static speed tier per node (node `i`
//!   gets `speeds[i % speeds.len()]` ∈ (0, 1]); a tier-`s` node completes
//!   `clamp(round(Q·s), 2, Q)` gradient steps inside the round deadline.
//!   Models a fleet with known hardware classes.
//! - [`ComputePlan::Lognormal`] — each `(round, node)` draws a lognormal
//!   speed `min(1, exp(σ·z))`, `z ~ N(0,1)`; τ_i = `clamp(⌊Q·speed⌋, 2, Q)`.
//!   Models transient slowdowns (shared machines, preemption) with a heavy
//!   straggler tail.
//! - [`ComputePlan::Dropout`] — with probability `slow_frac` a node is
//!   preempted for the round and contributes only one local step plus the
//!   communication gradient (τ_i = 2); otherwise it runs the full Q.  The
//!   classic straggler-dropout model.
//!
//! Non-uniform plans emit τ_i ∈ [2, Q], never 1: the τ-weighted rescale
//! below normalizes the *local-phase displacement*, and a node with zero
//! local steps has nothing to rescale — its missing contribution would
//! permanently bias the consensus fixed point away from its shard (FedNova
//! likewise requires every participant to take at least one normalizable
//! step; validated numerically — with a τ=1 tier the fixed-point bias
//! plateaus at `L̄·‖c̄−c_slow‖ / (L̄(N−1)+N)` instead of vanishing with α_r).
//!
//! **τ-weighted gossip (FedNova-style normalization).**  With unequal τ_i a
//! plain eq.-2/3 round is biased toward fast nodes: the consensus fixed
//! point drifts toward the minimizers of whoever took the most local steps.
//! Following FedNova (Wang et al., 2020), each node's local-phase
//! *displacement* is rescaled before gossip: node `i` with `L_i = τ_i − 1`
//! local steps applies `θ_i ← θ_i^pre + (L̄/L_i)·(θ_i^post − θ_i^pre)`,
//! where `L̄ = (1/N) Σ_j L_j` is the round's mean local work.  Every
//! participating node then contributes the same *effective* number of local
//! steps L̄, which removes the fast-node bias while preserving the total
//! represented work.  Under the uniform plan every weight is exactly 1 and
//! the rescale is skipped entirely — no float op is ever applied, keeping
//! the default bitwise-identical.  The communication-step gradient (the one
//! eq. 2/3 consumes) is never rescaled: every node always takes exactly one.
//!
//! **Latency model.**  A tier-`s` node spends `s_per_step / s` simulated
//! seconds per gradient step, so its round compute time is `τ_i·s_step/s_i`.
//! A synchronous gossip round completes when the slowest participant
//! arrives, so the fused driver charges `max_i τ_i·s_step/speed_i` per round
//! ([`ComputeSchedule::round_compute_s`]) — wall-clock-vs-accuracy curves
//! are honest about what stragglers cost.  (Dropout preemption is modeled
//! as the node being taken off the job, not as a slow CPU: the straggler's
//! two steps run at nominal speed.)
//!
//! Sampler streams stay plan-independent: nodes draw the full Q−1 local
//! batches every round and a straggler simply *uses* only its first
//! `τ_i − 1` of them, mirroring how churn's offline nodes draw-and-discard
//! their communication batch (§7).

use crate::config::ExperimentConfig;
use crate::rng::Pcg64;
use anyhow::{bail, Result};

/// RNG stream tag for per-(round, node) compute draws (disjoint from the
/// graph/schedule/sampler/init/netsim streams, which all live below 2³²).
const STREAM_COMPUTE: u64 = 0x7A_0C09_717E_0000;
/// Odd multiplier decorrelating the round index inside the stream tag.
const ROUND_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// How much local work each node performs per communication round.
#[derive(Clone, Debug, PartialEq)]
pub enum ComputePlan {
    /// Every node runs the full Q gradient steps every round (the paper's
    /// synchronous model; the engine's legacy code path, bitwise-unchanged).
    Uniform,
    /// Static per-node speed tiers: node `i` runs at `speeds[i % len]`.
    FixedTiers {
        /// Relative speeds in (0, 1], one per tier.
        speeds: Vec<f64>,
    },
    /// Per-(round, node) lognormal speed `min(1, exp(σ·z))`, `z ~ N(0,1)`.
    Lognormal {
        /// Lognormal σ of the per-round speed draw (> 0).
        sigma: f64,
    },
    /// Each round each node is preempted with probability `slow_frac` and
    /// contributes only one local step plus the communication gradient
    /// (τ = 2 — see the module docs for why never 1).
    Dropout {
        /// Per-round preemption probability in [0, 1).
        slow_frac: f64,
    },
}

impl ComputePlan {
    /// Short display label (experiment tables, logs).
    pub fn label(&self) -> String {
        match self {
            ComputePlan::Uniform => "uniform".into(),
            ComputePlan::FixedTiers { speeds } => {
                let tiers: Vec<String> = speeds.iter().map(|s| format!("{s:.2}")).collect();
                format!("tiers[{}]", tiers.join(","))
            }
            ComputePlan::Lognormal { sigma } => format!("lognormal σ={sigma:.2}"),
            ComputePlan::Dropout { slow_frac } => format!("dropout {slow_frac:.2}"),
        }
    }
}

/// Parse the `compute.*` section of a config (shared by
/// `ExperimentConfig::validate` and [`ComputeSchedule::from_config`]).
pub fn plan_from_config(cfg: &ExperimentConfig) -> Result<ComputePlan> {
    match cfg.compute_plan.as_str() {
        "uniform" => Ok(ComputePlan::Uniform),
        "fixed-tiers" | "tiers" => {
            let speeds: Vec<f64> = cfg
                .compute_tiers
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("compute.tiers: bad entry `{t}`"))
                })
                .collect::<Result<_>>()?;
            if speeds.is_empty() {
                bail!("compute.tiers must name at least one speed");
            }
            for &s in &speeds {
                if s.is_nan() || s <= 0.0 || s > 1.0 {
                    bail!("compute.tiers entries must be in (0, 1], got {s}");
                }
            }
            Ok(ComputePlan::FixedTiers { speeds })
        }
        "lognormal" | "lognormal-speed" => {
            if !cfg.compute_sigma.is_finite() || cfg.compute_sigma <= 0.0 {
                bail!("compute.sigma must be > 0, got {}", cfg.compute_sigma);
            }
            Ok(ComputePlan::Lognormal { sigma: cfg.compute_sigma })
        }
        "dropout" | "dropout-straggler" => {
            if !(0.0..1.0).contains(&cfg.slow_frac) {
                bail!("compute.slow_frac must be in [0, 1), got {}", cfg.slow_frac);
            }
            Ok(ComputePlan::Dropout { slow_frac: cfg.slow_frac })
        }
        other => bail!(
            "unknown compute plan `{other}` (uniform|fixed-tiers|lognormal|dropout)"
        ),
    }
}

/// Deterministic per-round local-work schedule over `n` nodes with local
/// period `q`.  Pure function of `(seed, round, node)`: every caller — the
/// sync driver, each actor node thread, the metrics observer, a test —
/// derives the identical τ, speed, and τ-weight values.
///
/// # Examples
///
/// ```
/// use decfl::engine::{ComputePlan, ComputeSchedule};
///
/// let sched = ComputeSchedule::new(
///     ComputePlan::Dropout { slow_frac: 0.5 }, 8, 5, 7,
/// ).unwrap();
/// let tau = sched.tau(3, 2);                 // pure in (seed, round, node)
/// assert!(tau == 2 || tau == 5);             // preempted or full Q
/// assert_eq!(tau, sched.tau(3, 2));          // any caller re-derives it
/// assert!(sched.local_work(3) >= 16);        // Σ_i τ_i: every node takes ≥ 2
/// ```
#[derive(Clone, Debug)]
pub struct ComputeSchedule {
    plan: ComputePlan,
    n: usize,
    q: usize,
    seed: u64,
}

impl ComputeSchedule {
    /// Schedule for `n` nodes at local period `q` under `plan`; `seed` keys
    /// every per-round draw.  Non-uniform plans require `q >= 2`: with
    /// `q = 1` there is no local phase to vary, and silently degenerating to
    /// uniform would misreport the scenario.
    pub fn new(plan: ComputePlan, n: usize, q: usize, seed: u64) -> Result<Self> {
        if n == 0 {
            bail!("compute schedule over zero nodes");
        }
        if q == 0 {
            bail!("local period q must be >= 1");
        }
        if plan != ComputePlan::Uniform && q < 2 {
            bail!(
                "compute plan `{}` varies the local phase, but Q=1 (classic \
                 dsgd/dsgt) has no local phase — every node would silently run \
                 the identical single step; use an fd-* algorithm with Q >= 2",
                plan.label()
            );
        }
        Ok(ComputeSchedule { plan, n, q, seed })
    }

    /// Build from a config's `compute.*` section (n, effective Q, seed).
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        let plan = plan_from_config(cfg)?;
        ComputeSchedule::new(plan, cfg.n, cfg.algo.effective_q(cfg.q), cfg.seed)
    }

    /// Driver precondition, shared by the fused and actor paths: a
    /// non-uniform plan cannot run on a backend whose local phase is a
    /// fixed-length scan (`fixed_scan` = `Compute::local_steps_len()`, Some
    /// for the AOT artifacts), and the schedule must cover exactly the
    /// dataset's nodes.  One source of truth so the two drivers' error
    /// behavior can never desync.
    pub fn ensure_runnable(&self, n_hospitals: usize, fixed_scan: Option<usize>) -> Result<()> {
        if !self.is_uniform() && fixed_scan.is_some() {
            bail!(
                "compute plan `{}` varies per-node local steps, but the AOT artifacts \
                 are specialized to a fixed Q-step scan; straggler plans need \
                 `--backend native`",
                self.plan.label()
            );
        }
        if self.n != n_hospitals {
            bail!("compute schedule covers {} nodes, dataset has {n_hospitals}", self.n);
        }
        Ok(())
    }

    /// The configured plan.
    pub fn plan(&self) -> &ComputePlan {
        &self.plan
    }

    /// Node count the schedule covers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Local period Q the plan truncates against.
    pub fn q(&self) -> usize {
        self.q
    }

    /// Does every node run the full Q every round (the legacy fast path)?
    pub fn is_uniform(&self) -> bool {
        self.plan == ComputePlan::Uniform
    }

    /// Fresh RNG for node `i`'s draw of round `round` — one short-lived
    /// stream per `(seed, round, node)`, like the schedule streams of
    /// `graph::schedule`.
    fn draw_rng(&self, round: usize, i: usize) -> Pcg64 {
        let stream = STREAM_COMPUTE
            ^ (round as u64).wrapping_mul(ROUND_MIX)
            ^ ((i as u64) << 1);
        Pcg64::new(self.seed, stream)
    }

    /// Node `i`'s relative speed in round `round`, in (0, 1].  Uniform and
    /// dropout nodes run at nominal speed (dropout models preemption, not a
    /// slow CPU); tiers are static per node; lognormal redraws per round.
    pub fn speed(&self, round: usize, i: usize) -> f64 {
        match &self.plan {
            ComputePlan::Uniform | ComputePlan::Dropout { .. } => 1.0,
            ComputePlan::FixedTiers { speeds } => speeds[i % speeds.len()],
            ComputePlan::Lognormal { sigma } => {
                let z = self.draw_rng(round, i).normal();
                (sigma * z).exp().min(1.0)
            }
        }
    }

    /// Total gradient evaluations node `i` performs in round `round`
    /// (1-based): `τ_i − 1` local eq.-4 steps plus the one communication
    /// gradient every node always takes.  Uniform plans return Q;
    /// non-uniform plans clamp to `[2, Q]` so every participant has at
    /// least one local step for the τ-weighted rescale to normalize (see
    /// the module docs — a τ=1 node would bias the fixed point).
    pub fn tau(&self, round: usize, i: usize) -> usize {
        match &self.plan {
            ComputePlan::Uniform => self.q,
            ComputePlan::FixedTiers { speeds } => {
                let s = speeds[i % speeds.len()];
                ((self.q as f64 * s).round() as usize).clamp(2, self.q)
            }
            ComputePlan::Lognormal { .. } => {
                let s = self.speed(round, i);
                ((self.q as f64 * s).floor() as usize).clamp(2, self.q)
            }
            ComputePlan::Dropout { slow_frac } => {
                if self.draw_rng(round, i).bernoulli(*slow_frac) {
                    2
                } else {
                    self.q
                }
            }
        }
    }

    /// τ for every node of `round`, written into `out[n]`.
    pub fn taus_into(&self, round: usize, out: &mut [usize]) {
        assert_eq!(out.len(), self.n);
        for (i, t) in out.iter_mut().enumerate() {
            *t = self.tau(round, i);
        }
    }

    /// Σ_i τ_i of `round` — the true summed local work the metrics report
    /// (the legacy accounting assumed a uniform `n·Q` per round).
    pub fn local_work(&self, round: usize) -> u64 {
        (0..self.n).map(|i| self.tau(round, i) as u64).sum()
    }

    /// One node's weight from the round's exact local-step sum (`Σ_j L_j`
    /// as an integer — no float-order dependence) and its own `L_i`.
    fn weight_from(&self, total_l: u64, li: usize) -> f32 {
        if li == 0 {
            return 1.0;
        }
        let lbar = total_l as f64 / self.n as f64;
        (lbar / li as f64) as f32
    }

    /// FedNova-style τ-weight of node `i` in `round`: `L̄ / L_i` over the
    /// local-step counts `L_j = τ_j − 1`, computed with an exact integer sum
    /// so every driver derives the identical f32.  Exactly 1.0 under the
    /// uniform plan, for nodes with no local steps this round (nothing to
    /// rescale), and whenever `L_i` happens to equal `L̄` — callers skip the
    /// rescale on 1.0, so degenerate plans stay bitwise-clean.  (The actor
    /// driver's per-node O(n) call; the fused driver batches the sum once
    /// through [`Self::tau_weights_into`].)
    pub fn tau_weight(&self, round: usize, i: usize) -> f32 {
        if self.is_uniform() {
            return 1.0;
        }
        let li = self.tau(round, i) - 1;
        let total: u64 = (0..self.n).map(|j| (self.tau(round, j) - 1) as u64).sum();
        self.weight_from(total, li)
    }

    /// Whole-network τ-weights from the round's already-derived `taus`
    /// (what [`Self::taus_into`] filled): one O(n) integer sum instead of
    /// the O(n²) per-node recomputation, bitwise-identical to calling
    /// [`Self::tau_weight`] per node because τ is a pure function and the
    /// sum is integer-exact.
    pub fn tau_weights_into(&self, taus: &[usize], out: &mut [f32]) {
        assert_eq!(taus.len(), self.n);
        assert_eq!(out.len(), self.n);
        if self.is_uniform() {
            for w in out.iter_mut() {
                *w = 1.0;
            }
            return;
        }
        let total: u64 = taus.iter().map(|&t| (t - 1) as u64).sum();
        for (w, &t) in out.iter_mut().zip(taus) {
            *w = self.weight_from(total, t - 1);
        }
    }

    /// Round `round`'s compute time on the simulated clock: the slowest
    /// participant's `τ_i · s_per_step / speed_i`.  A synchronous gossip
    /// round cannot complete before its slowest node finishes.
    pub fn round_compute_s(&self, round: usize, s_per_step: f64) -> f64 {
        (0..self.n)
            .map(|i| self.tau(round, i) as f64 * s_per_step / self.speed(round, i))
            .fold(0.0, f64::max)
    }

    /// [`Self::round_compute_s`] over the round's already-derived `taus`
    /// (what [`Self::taus_into`] filled) — skips re-deriving τ per node on
    /// the fused driver's hot path; identical result because τ is pure.
    pub fn round_compute_s_from(&self, round: usize, taus: &[usize], s_per_step: f64) -> f64 {
        assert_eq!(taus.len(), self.n);
        taus.iter()
            .enumerate()
            .map(|(i, &t)| t as f64 * s_per_step / self.speed(round, i))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(plan: ComputePlan, n: usize, q: usize, seed: u64) -> ComputeSchedule {
        ComputeSchedule::new(plan, n, q, seed).unwrap()
    }

    fn plans() -> Vec<ComputePlan> {
        vec![
            ComputePlan::Uniform,
            ComputePlan::FixedTiers { speeds: vec![1.0, 0.5, 0.25] },
            ComputePlan::Lognormal { sigma: 0.6 },
            ComputePlan::Dropout { slow_frac: 0.4 },
        ]
    }

    #[test]
    fn taus_are_deterministic_and_in_range() {
        for plan in plans() {
            let a = sched(plan.clone(), 9, 8, 42);
            let b = sched(plan.clone(), 9, 8, 42);
            for round in 1..=12 {
                for i in 0..9 {
                    let t = a.tau(round, i);
                    assert!((2..=8).contains(&t), "{} τ={t}", plan.label());
                    assert_eq!(t, b.tau(round, i), "{}", plan.label());
                    assert!(a.speed(round, i) > 0.0 && a.speed(round, i) <= 1.0);
                }
                assert_eq!(a.local_work(round), b.local_work(round));
            }
        }
    }

    #[test]
    fn uniform_is_full_q_with_unit_weights() {
        let s = sched(ComputePlan::Uniform, 5, 7, 3);
        assert!(s.is_uniform());
        for round in 1..=5 {
            for i in 0..5 {
                assert_eq!(s.tau(round, i), 7);
                assert_eq!(s.tau_weight(round, i), 1.0);
            }
            assert_eq!(s.local_work(round), 35);
            assert!((s.round_compute_s(round, 1e-3) - 7e-3).abs() < 1e-15);
        }
    }

    #[test]
    fn fixed_tiers_map_nodes_round_robin_and_are_static() {
        let s = sched(ComputePlan::FixedTiers { speeds: vec![1.0, 0.5] }, 4, 10, 1);
        for round in 1..=6 {
            assert_eq!(s.tau(round, 0), 10);
            assert_eq!(s.tau(round, 1), 5);
            assert_eq!(s.tau(round, 2), 10);
            assert_eq!(s.tau(round, 3), 5);
        }
        // slow tier pays the same wall time per round: 5 steps at half speed
        let c = s.round_compute_s(1, 1e-3);
        assert!((c - 10e-3).abs() < 1e-15, "{c}");
    }

    #[test]
    fn dropout_preempts_some_rounds_but_never_below_two_steps() {
        // τ = 2, never 1: a preempted node still has one local step for the
        // τ-weighted rescale to normalize (module docs)
        let s = sched(ComputePlan::Dropout { slow_frac: 0.5 }, 6, 8, 11);
        let (mut slow, mut fulls) = (0, 0);
        for round in 1..=20 {
            for i in 0..6 {
                match s.tau(round, i) {
                    2 => slow += 1,
                    8 => fulls += 1,
                    t => panic!("dropout τ must be 2 or Q, got {t}"),
                }
            }
        }
        assert!(slow > 20 && fulls > 20, "slow={slow} fulls={fulls}");
    }

    #[test]
    fn lognormal_produces_a_straggler_tail() {
        let s = sched(ComputePlan::Lognormal { sigma: 0.8 }, 10, 20, 5);
        let mut below_full = 0;
        for round in 1..=10 {
            for i in 0..10 {
                if s.tau(round, i) < 20 {
                    below_full += 1;
                }
            }
        }
        assert!(below_full > 20, "σ=0.8 produced almost no stragglers: {below_full}");
    }

    #[test]
    fn tau_weights_preserve_total_represented_work() {
        // Σ_i w_i·L_i == n·L̄ == Σ_i L_i for every non-degenerate plan/round
        for plan in plans().into_iter().skip(1) {
            let s = sched(plan.clone(), 8, 12, 9);
            for round in 1..=6 {
                let total: f64 = (0..8).map(|i| (s.tau(round, i) - 1) as f64).sum();
                let weighted: f64 = (0..8)
                    .map(|i| s.tau_weight(round, i) as f64 * (s.tau(round, i) - 1) as f64)
                    .sum();
                assert!(
                    (weighted - total).abs() < 1e-3 * total.max(1.0),
                    "{} round {round}: {weighted} vs {total}",
                    plan.label()
                );
            }
        }
    }

    #[test]
    fn batched_weights_match_per_node_weights_bitwise() {
        // the fused driver's O(n) batched path and the actor driver's
        // per-node path must derive the identical f32 weights
        for plan in plans() {
            let s = sched(plan.clone(), 7, 9, 21);
            let mut taus = vec![0usize; 7];
            let mut ws = vec![0.0f32; 7];
            for round in 1..=6 {
                s.taus_into(round, &mut taus);
                s.tau_weights_into(&taus, &mut ws);
                for i in 0..7 {
                    assert_eq!(
                        ws[i].to_bits(),
                        s.tau_weight(round, i).to_bits(),
                        "{} round {round} node {i}",
                        plan.label()
                    );
                }
                // the scratch-reusing latency path is identical too
                assert_eq!(
                    s.round_compute_s_from(round, &taus, 1e-3).to_bits(),
                    s.round_compute_s(round, 1e-3).to_bits(),
                    "{} round {round}",
                    plan.label()
                );
            }
        }
    }

    #[test]
    fn ensure_runnable_gates_fixed_scan_backends_and_node_counts() {
        let s = sched(ComputePlan::Dropout { slow_frac: 0.3 }, 5, 8, 1);
        assert!(s.ensure_runnable(5, None).is_ok());
        let err = s.ensure_runnable(5, Some(7)).unwrap_err();
        assert!(err.to_string().contains("--backend native"), "{err}");
        let err = s.ensure_runnable(6, None).unwrap_err();
        assert!(err.to_string().contains("6"), "{err}");
        // uniform plans run on fixed-scan backends unchanged
        let u = sched(ComputePlan::Uniform, 5, 8, 1);
        assert!(u.ensure_runnable(5, Some(7)).is_ok());
    }

    #[test]
    fn round_compute_is_the_slowest_participant() {
        let s = sched(ComputePlan::FixedTiers { speeds: vec![1.0, 0.25] }, 2, 8, 2);
        // node 1: τ=2 steps at speed 0.25 → 8·s; node 0: τ=8 at 1.0 → 8·s
        let c = s.round_compute_s(1, 1e-3);
        let expect = (0..2)
            .map(|i| s.tau(1, i) as f64 * 1e-3 / s.speed(1, i))
            .fold(0.0, f64::max);
        assert_eq!(c, expect);
        // dropout: preempted nodes cost two nominal steps; survivors full Q
        let d = sched(ComputePlan::Dropout { slow_frac: 0.3 }, 6, 8, 2);
        for round in 1..=8 {
            let c = d.round_compute_s(round, 1e-3);
            let any_full = (0..6).any(|i| d.tau(round, i) == 8);
            if any_full {
                assert!((c - 8e-3).abs() < 1e-15, "round {round}: {c}");
            }
        }
    }

    #[test]
    fn plan_parsing_from_config() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(plan_from_config(&cfg).unwrap(), ComputePlan::Uniform);
        cfg.compute_plan = "fixed-tiers".into();
        cfg.compute_tiers = "1.0, 0.5,0.25".into();
        assert_eq!(
            plan_from_config(&cfg).unwrap(),
            ComputePlan::FixedTiers { speeds: vec![1.0, 0.5, 0.25] }
        );
        cfg.compute_plan = "lognormal".into();
        cfg.compute_sigma = 0.7;
        assert_eq!(plan_from_config(&cfg).unwrap(), ComputePlan::Lognormal { sigma: 0.7 });
        cfg.compute_plan = "dropout".into();
        cfg.slow_frac = 0.3;
        assert_eq!(plan_from_config(&cfg).unwrap(), ComputePlan::Dropout { slow_frac: 0.3 });
        cfg.compute_plan = "bogus".into();
        assert!(plan_from_config(&cfg).is_err());
        cfg.compute_plan = "dropout".into();
        cfg.slow_frac = 1.0;
        assert!(plan_from_config(&cfg).is_err());
        cfg.compute_plan = "fixed-tiers".into();
        cfg.compute_tiers = "0.5,1.5".into();
        assert!(plan_from_config(&cfg).is_err());
        cfg.compute_tiers = "".into();
        assert!(plan_from_config(&cfg).is_err());
        cfg.compute_plan = "lognormal".into();
        cfg.compute_sigma = 0.0;
        assert!(plan_from_config(&cfg).is_err());
    }

    #[test]
    fn non_uniform_plans_reject_classic_q1() {
        let err =
            ComputeSchedule::new(ComputePlan::Dropout { slow_frac: 0.2 }, 4, 1, 0).unwrap_err();
        assert!(err.to_string().contains("local phase"), "{err}");
        assert!(ComputeSchedule::new(ComputePlan::Uniform, 4, 1, 0).is_ok());
    }
}
