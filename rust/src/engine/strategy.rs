//! Communication strategies — the pluggable eq.-2/3/averaging/SGD update
//! the [`RoundEngine`](super::RoundEngine) applies once per round.
//!
//! A strategy owns the algorithm-specific auxiliary state (the DSGT tracker,
//! nothing for the others) and performs the whole-network communication
//! update on the shared [`EngineState`] through the [`Compute`] backend.
//! The network is NOT captured at construction: every round the driver hands
//! the strategy a [`RoundNet`] — that round's mixing matrix and online mask
//! from the `graph::schedule` layer — so time-varying topologies (rewire,
//! edge dropout, node churn) flow through without the strategy changing.
//! What a strategy does NOT own: the round loop, the lr schedule, batch
//! sampling streams, or metrics — those are engine machinery, identical for
//! every algorithm.  Adding an algorithm = implementing this trait; the
//! loop, both drivers, the CLI, and the benches pick it up unchanged.

use super::EngineState;
use crate::algo::axpy;
use crate::algo::native::NativeModel;
use crate::coordinator::compute::{Compute, MixView};
use crate::mixing::SparseW;
use anyhow::Result;

/// What one communication round costs on the wire (drives the analytic
/// accountant of the sync driver; the actor driver measures instead).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommCost {
    /// Synchronous gossip over every *active* edge of the round's network
    /// view, `kinds` payloads per edge (1 = θ only, 2 = θ and the DSGT
    /// tracker ϑ).  The per-round edge count comes from the schedule.
    Gossip { kinds: u32 },
    /// Star-network client↑/server↓ exchange (FedAvg).
    Star,
    /// No communication (fusion-center baseline).
    None,
}

/// The network of ONE communication round, as the schedule emitted it.
pub struct RoundNet<'a> {
    /// Row-major f32 mixing matrix `[n, n]` for this round (doubly
    /// stochastic; offline rows are identity under churn).
    pub w: &'a [f32],
    /// Degree-sparse CSR view of the same matrix (per-node `(neighbor,
    /// weight)` rows, ascending) — what the native gossip kernels consume.
    pub sparse: &'a SparseW,
    /// Per-node participation mask (all `true` except under node churn).
    pub online: &'a [bool],
}

impl RoundNet<'_> {
    pub fn all_online(&self) -> bool {
        self.online.iter().all(|&b| b)
    }

    /// Both W forms, packaged for the compute layer.
    pub fn mix(&self) -> MixView<'_> {
        MixView { dense: self.w, sparse: self.sparse }
    }
}

/// Overwrite the stack rows of offline nodes with their previous values —
/// an offline node skips the communication update entirely (exactly what
/// its actor-driver counterpart does by not gossiping that round).
fn restore_offline_rows(next: &mut [f32], prev: &[f32], online: &[bool], p: usize) {
    for (i, &on) in online.iter().enumerate() {
        if !on {
            next[i * p..(i + 1) * p].copy_from_slice(&prev[i * p..(i + 1) * p]);
        }
    }
}

/// The communication update of Algorithm 1 — eq. 2, eq. 3, a server
/// average, or a plain SGD step — plus its wire cost and the metric eval.
/// (The run-log label is the driver's concern — `cfg.algo.name()` — so
/// strategies carry no display name.)
pub trait CommStrategy {
    fn cost(&self) -> CommCost;

    /// Pre-loop initialization (e.g. DSGT's Y⁰ = G⁰ = ∇g(θ⁰) on a fresh
    /// batch).  Default: nothing.
    fn init(&mut self, _st: &mut EngineState, _compute: &dyn Compute) -> Result<()> {
        Ok(())
    }

    /// Apply the communication update at learning rate `lr` over this
    /// round's network view, consuming one gradient per stack row.
    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        lr: f32,
    ) -> Result<()>;

    /// Full-shard metrics → (loss, accuracy, stationarity, consensus).
    /// Default: whole-stack eval over the training shards.
    fn eval(&self, st: &EngineState, compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        compute.eval_full(&st.theta, &st.shards)
    }
}

// --------------------------------------------------------------- DSGD ----

/// Eq. 2: `θ_i ← Σ_j w_ij θ_j − α ∇g_i(θ_i)` (covers DSGD and FD-DSGD —
/// the local period lives in the engine, not here; the round's `W` arrives
/// through [`RoundNet`]).
pub struct DsgdStrategy;

impl DsgdStrategy {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        DsgdStrategy
    }
}

impl CommStrategy for DsgdStrategy {
    fn cost(&self) -> CommCost {
        CommCost::Gossip { kinds: 1 }
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        lr: f32,
    ) -> Result<()> {
        // Every row draws its batch every round — the sampler streams stay
        // keyed by (seed, row) alone (§7), independent of the network plan;
        // offline rows discard theirs below.
        st.draw_comm_batches();
        compute.dsgd_round_into(
            &net.mix(),
            &st.theta,
            &st.cx,
            &st.cy,
            lr,
            &mut st.theta_back,
            &mut st.comm_losses,
        )?;
        if !net.all_online() {
            restore_offline_rows(&mut st.theta_back, &st.theta, net.online, st.p);
        }
        std::mem::swap(&mut st.theta, &mut st.theta_back);
        Ok(())
    }
}

// --------------------------------------------------------------- DSGT ----

/// Eq. 3 with gradient tracking: mixes θ and the tracker ϑ, then refreshes
/// the tracker with the gradient difference (covers DSGT and FD-DSGT).
/// Offline rounds leave a node's θ, ϑ, and G untouched.  The tracker and
/// gradient stacks are double-buffered like the engine's θ stack, so a
/// steady-state round allocates nothing.
pub struct DsgtStrategy {
    /// Tracker stack Y `[n, p]` + its back buffer.
    y: Vec<f32>,
    y_back: Vec<f32>,
    /// Previous-gradient stack G `[n, p]` + its back buffer.
    g: Vec<f32>,
    g_back: Vec<f32>,
}

impl DsgtStrategy {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        DsgtStrategy { y: Vec::new(), y_back: Vec::new(), g: Vec::new(), g_back: Vec::new() }
    }
}

impl CommStrategy for DsgtStrategy {
    fn cost(&self) -> CommCost {
        CommCost::Gossip { kinds: 2 } // θ and ϑ
    }

    fn init(&mut self, st: &mut EngineState, compute: &dyn Compute) -> Result<()> {
        st.draw_comm_batches();
        let (n, p) = (st.n, st.p);
        let mut g0 = vec![0.0f32; n * p];
        for i in 0..n {
            let (bx, by) = st.comm_batch(i);
            let (_, gi) = compute.grad_step(st.theta_row(i), bx, by)?;
            g0[i * p..(i + 1) * p].copy_from_slice(&gi);
        }
        self.y = g0.clone();
        self.g = g0;
        self.y_back = vec![0.0f32; n * p];
        self.g_back = vec![0.0f32; n * p];
        Ok(())
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        lr: f32,
    ) -> Result<()> {
        st.draw_comm_batches();
        compute.dsgt_round_into(
            &net.mix(),
            &st.theta,
            &self.y,
            &self.g,
            &st.cx,
            &st.cy,
            lr,
            &mut st.theta_back,
            &mut self.y_back,
            &mut self.g_back,
            &mut st.comm_losses,
        )?;
        if !net.all_online() {
            restore_offline_rows(&mut st.theta_back, &st.theta, net.online, st.p);
            restore_offline_rows(&mut self.y_back, &self.y, net.online, st.p);
            restore_offline_rows(&mut self.g_back, &self.g, net.online, st.p);
        }
        std::mem::swap(&mut st.theta, &mut st.theta_back);
        std::mem::swap(&mut self.y, &mut self.y_back);
        std::mem::swap(&mut self.g, &mut self.g_back);
        Ok(())
    }
}

// ------------------------------------------------------------- FedAvg ----

/// Star-network FedAvg (McMahan et al., 2017): the engine's local phase runs
/// every client from the server parameters (all stack rows are identical
/// after each round); this update takes the final local gradient and
/// replaces every row with the client average.
pub struct FedAvgStrategy;

impl FedAvgStrategy {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        FedAvgStrategy
    }
}

impl CommStrategy for FedAvgStrategy {
    fn cost(&self) -> CommCost {
        CommCost::Star
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        _net: &RoundNet,
        lr: f32,
    ) -> Result<()> {
        let (n, p) = (st.n, st.p);
        let mut mean = vec![0.0f64; p];
        for i in 0..n {
            // final local step of the round (keeps total gradient count = Q)
            {
                let (m, d) = (st.m, st.d);
                let shard = &st.shards[i];
                st.samplers[i].batch(
                    shard,
                    &mut st.cx[i * m * d..(i + 1) * m * d],
                    &mut st.cy[i * m..(i + 1) * m],
                );
            }
            let (bx, by) = st.comm_batch(i);
            let (_, grad) = compute.grad_step(st.theta_row(i), bx, by)?;
            let row = &mut st.theta[i * p..(i + 1) * p];
            axpy(row, -lr, &grad);
            for (acc, &t) in mean.iter_mut().zip(row.iter()) {
                *acc += t as f64;
            }
        }
        let server: Vec<f32> = mean.into_iter().map(|acc| (acc / n as f64) as f32).collect();
        for i in 0..n {
            st.theta[i * p..(i + 1) * p].copy_from_slice(&server);
        }
        Ok(())
    }
}

// -------------------------------------------------------- centralized ----

/// The fictitious fusion center the paper argues is infeasible: plain SGD
/// on the pooled cohort.  One stack row, no communication; the engine's
/// round axis advances every Q steps so curves align with FD runs.
pub struct CentralizedStrategy {
    /// Native twin for metrics — the pooled shard does not match the AOT
    /// artifacts' per-hospital eval shapes, so eval runs in-process.
    model: NativeModel,
}

impl CentralizedStrategy {
    pub fn new(model: NativeModel) -> Self {
        CentralizedStrategy { model }
    }
}

impl CommStrategy for CentralizedStrategy {
    fn cost(&self) -> CommCost {
        CommCost::None
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        _net: &RoundNet,
        lr: f32,
    ) -> Result<()> {
        st.draw_comm_batches();
        let (bx, by) = st.comm_batch(0);
        let (_, grad) = compute.grad_step(&st.theta, bx, by)?;
        axpy(&mut st.theta, -lr, &grad);
        Ok(())
    }

    fn eval(&self, st: &EngineState, _compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        Ok(self.model.eval_full(&st.theta, &st.shards))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_match_payload_kinds() {
        assert_eq!(DsgdStrategy::new().cost(), CommCost::Gossip { kinds: 1 });
        assert_eq!(DsgtStrategy::new().cost(), CommCost::Gossip { kinds: 2 });
        assert_eq!(FedAvgStrategy::new().cost(), CommCost::Star);
        assert_eq!(CentralizedStrategy::new(NativeModel::new(4, 2)).cost(), CommCost::None);
    }

    #[test]
    fn restore_offline_rows_is_row_exact() {
        let prev = vec![1.0f32, 1.0, 2.0, 2.0, 3.0, 3.0];
        let mut next = vec![9.0f32, 9.0, 8.0, 8.0, 7.0, 7.0];
        restore_offline_rows(&mut next, &prev, &[true, false, true], 2);
        assert_eq!(next, vec![9.0, 9.0, 2.0, 2.0, 7.0, 7.0]);
    }
}
