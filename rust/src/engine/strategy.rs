//! Communication strategies — the pluggable eq.-2/3/averaging/SGD update
//! the [`RoundEngine`](super::RoundEngine) applies once per round.
//!
//! A strategy owns the algorithm-specific auxiliary state (the DSGT tracker,
//! nothing for the others) and performs the whole-network communication
//! update on the shared [`EngineState`] through the [`Compute`] backend.
//! The network is NOT captured at construction: every round the driver hands
//! the strategy a [`RoundNet`] — that round's mixing matrix and online mask
//! from the `graph::schedule` layer — so time-varying topologies (rewire,
//! edge dropout, node churn) flow through without the strategy changing.
//! Gossip strategies also carry the run's [`GossipComm`] compression
//! context: when a compressor is configured every outgoing row is encoded
//! under its `(seed, round, node, kind)` key and the round applies the
//! **difference-form** update — mix the *decoded* stack, then add back each
//! node's own full-precision correction (DESIGN.md §10) — exactly mirroring
//! what the actor driver puts on the channel netsim, so fused and actor
//! trajectories stay bitwise-equal under every compressor.  The opt-in
//! error-feedback residual (`comm.error_feedback`) additionally
//! error-compensates the outgoing messages.
//! What a strategy does NOT own: the round loop, the lr schedule, batch
//! sampling streams, or metrics — those are engine machinery, identical for
//! every algorithm.  Adding an algorithm = implementing this trait; the
//! loop, both drivers, the CLI, and the benches pick it up unchanged.

use super::EngineState;
use crate::algo::axpy;
use crate::algo::native::NativeModel;
use crate::compress::{add_residual, decode_into, residual_update, Compressor, GossipComm, MsgKey};
use crate::coordinator::compute::{Compute, MixView};
use crate::mixing::SparseW;
use crate::netsim::PayloadKind;
use anyhow::Result;

/// What one communication round costs on the wire (drives the analytic
/// accountant of the sync driver; the actor driver measures instead).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommCost {
    /// Synchronous gossip over every *active* edge of the round's network
    /// view.  `kinds` payloads per edge (1 = θ only, 2 = θ and the DSGT
    /// tracker ϑ); `kind_bytes[..kinds]` holds each payload's *encoded*
    /// wire size, so compressed runs are charged at what actually crosses
    /// the wire.  The per-round edge count comes from the schedule.
    Gossip {
        /// Payload kinds per edge (1 = θ, 2 = θ + ϑ).
        kinds: u32,
        /// Encoded bytes of each kind (entries past `kinds` are unused).
        kind_bytes: [u64; 2],
    },
    /// Star-network client↑/server↓ exchange (FedAvg).
    Star,
    /// No communication (fusion-center baseline).
    None,
}

/// The network of ONE communication round, as the schedule emitted it.
pub struct RoundNet<'a> {
    /// Row-major dense f32 mixing matrix `[n, n]` for this round — present
    /// only when the backend asked for it (`Compute::wants_dense_w`); the
    /// sparse-native path never materializes it (n×n is 40 GB at n = 10⁵).
    pub w: Option<&'a [f32]>,
    /// Degree-sparse CSR view of the round's mixing matrix (per-node
    /// `(neighbor, weight)` rows, ascending) — always present; what the
    /// native gossip kernels consume.
    pub sparse: &'a SparseW,
    /// Per-node participation mask (all `true` except under node churn).
    pub online: &'a [bool],
}

impl RoundNet<'_> {
    /// Is every node participating this round (no churn)?
    pub fn all_online(&self) -> bool {
        self.online.iter().all(|&b| b)
    }

    /// Both W forms, packaged for the compute layer.
    pub fn mix(&self) -> MixView<'_> {
        MixView { dense: self.w, sparse: self.sparse }
    }
}

/// Overwrite the stack rows of offline nodes with their previous values —
/// an offline node skips the communication update entirely (exactly what
/// its actor-driver counterpart does by not gossiping that round).
fn restore_offline_rows(next: &mut [f32], prev: &[f32], online: &[bool], p: usize) {
    for (i, &on) in online.iter().enumerate() {
        if !on {
            next[i * p..(i + 1) * p].copy_from_slice(&prev[i * p..(i + 1) * p]);
        }
    }
}

/// Error-feedback-compress one whole payload stack for this round: per
/// *online* row `i`, build the error-compensated message `v = x_i + e_i`,
/// encode it under the deterministic `(seed, round, i, kind)` key, decode
/// the wire message into the `xhat` row (what neighbors — and the node
/// itself — mix), and write the new residual `v − x̂` into the residual back
/// slab.  Offline rows carry their residual forward untouched; their
/// `xhat` row is left stale — online neighbors never mix it (absorbed
/// weights are zero), and while the offline node's own kernel row does
/// read it through its identity self-weight, that whole output row is
/// discarded by `restore_offline_rows` right after the round.
///
/// This is the fused twin of the per-node EF step the actor driver runs
/// before broadcasting — both call the same `compress::{add_residual,
/// residual_update}` helpers and the same encode/decode, so the decoded
/// stacks (and therefore the trajectories) agree bitwise.
#[allow(clippy::too_many_arguments)]
fn ef_compress_stack(
    comp: &dyn Compressor,
    ef: bool,
    seed: u64,
    round: usize,
    kind: PayloadKind,
    stack: &[f32],
    online: &[bool],
    p: usize,
    e: &[f32],
    e_back: &mut [f32],
    xhat: &mut [f32],
    vbuf: &mut [f32],
) {
    let n = stack.len() / p;
    for i in 0..n {
        let row = i * p..(i + 1) * p;
        if !online[i] {
            if ef {
                e_back[row.clone()].copy_from_slice(&e[row]);
            }
            continue;
        }
        if ef {
            add_residual(&stack[row.clone()], &e[row.clone()], vbuf);
        } else {
            vbuf.copy_from_slice(&stack[row.clone()]);
        }
        let enc = comp.encode(vbuf, MsgKey::new(seed, round, i, kind));
        decode_into(&enc, &mut xhat[row.clone()]);
        if ef {
            residual_update(vbuf, &xhat[row.clone()], &mut e_back[row]);
        }
    }
}

/// The communication update of Algorithm 1 — eq. 2, eq. 3, a server
/// average, or a plain SGD step — plus its wire cost and the metric eval.
/// (The run-log label is the driver's concern — `cfg.algo.name()` — so
/// strategies carry no display name.)
///
/// # Examples
///
/// Strategies are selected by the config's algorithm and run through the
/// engine's entry points — a minimal end-to-end DSGD round sequence:
///
/// ```
/// use decfl::config::{AlgoKind, Backend, ExperimentConfig};
/// use decfl::coordinator::{assemble, run_on};
///
/// let mut cfg = ExperimentConfig::default();
/// cfg.backend = Backend::Native;
/// cfg.algo = AlgoKind::FdDsgd;   // → DsgdStrategy under the round engine
/// cfg.n = 4;
/// cfg.hidden = 8;
/// cfg.m = 4;
/// cfg.q = 2;
/// cfg.total_steps = 4;           // two communication rounds
/// cfg.records_per_hospital = 40;
/// let asm = assemble(&cfg).unwrap();
/// let log = run_on(&cfg, &asm).unwrap();
/// assert!(log.rows.last().unwrap().loss.is_finite());
/// ```
pub trait CommStrategy {
    /// Wire cost of one communication round (per-kind encoded sizes).
    fn cost(&self) -> CommCost;

    /// Pre-loop initialization (e.g. DSGT's Y⁰ = G⁰ = ∇g(θ⁰) on a fresh
    /// batch).  Default: nothing.
    fn init(&mut self, _st: &mut EngineState, _compute: &dyn Compute) -> Result<()> {
        Ok(())
    }

    /// Apply the communication update of round `round` (1-based) at learning
    /// rate `lr` over this round's network view, consuming one gradient per
    /// stack row.  The round index keys the deterministic compression
    /// streams (`compress::MsgKey`).
    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        round: usize,
        lr: f32,
    ) -> Result<()>;

    /// Full-shard metrics → (loss, accuracy, stationarity, consensus).
    /// Default: whole-stack eval over the training shards.
    fn eval(&self, st: &EngineState, compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        compute.eval_full(&st.theta, &st.shards)
    }
}

// --------------------------------------------------------------- DSGD ----

/// Eq. 2: `θ_i ← Σ_j w_ij θ_j − α ∇g_i(θ_i)` (covers DSGD and FD-DSGD —
/// the local period lives in the engine, not here; the round's `W` arrives
/// through [`RoundNet`]).  With a configured compressor the round runs the
/// difference-form update over the decoded stack (see the module docs).
pub struct DsgdStrategy {
    comm: GossipComm,
    msg_bytes: u64,
}

impl DsgdStrategy {
    /// Build for parameter size `p` under the given compression context.
    pub fn new(comm: GossipComm, p: usize) -> Self {
        let msg_bytes = comm.msg_bytes(p);
        DsgdStrategy { comm, msg_bytes }
    }
}

impl CommStrategy for DsgdStrategy {
    fn cost(&self) -> CommCost {
        CommCost::Gossip { kinds: 1, kind_bytes: [self.msg_bytes, 0] }
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        round: usize,
        lr: f32,
    ) -> Result<()> {
        // Every row draws its batch every round — the sampler streams stay
        // keyed by (seed, row) alone (§7), independent of the network plan;
        // offline rows discard theirs below.
        st.draw_comm_batches();
        if let Some(comp) = &self.comm.comp {
            let ef = self.comm.error_feedback;
            ef_compress_stack(
                comp.as_ref(),
                ef,
                self.comm.seed,
                round,
                PayloadKind::Params,
                &st.theta,
                net.online,
                st.p,
                &st.ef_theta,
                &mut st.ef_theta_back,
                &mut st.xhat,
                &mut st.vbuf,
            );
            if ef {
                std::mem::swap(&mut st.ef_theta, &mut st.ef_theta_back);
            }
            compute.dsgd_round_compressed_into(
                &net.mix(),
                &st.xhat,
                &st.theta,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut st.comm_losses,
            )?;
        } else {
            compute.dsgd_round_into(
                &net.mix(),
                &st.theta,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut st.comm_losses,
            )?;
        }
        if !net.all_online() {
            restore_offline_rows(&mut st.theta_back, &st.theta, net.online, st.p);
        }
        std::mem::swap(&mut st.theta, &mut st.theta_back);
        Ok(())
    }
}

// --------------------------------------------------------------- DSGT ----

/// Eq. 3 with gradient tracking: mixes θ and the tracker ϑ, then refreshes
/// the tracker with the gradient difference (covers DSGT and FD-DSGT).
/// Offline rounds leave a node's θ, ϑ, and G untouched.  The tracker and
/// gradient stacks are double-buffered like the engine's θ stack, so a
/// steady-state round allocates nothing.  Under compression both payload
/// streams (θ and ϑ) are encoded independently, each with its own
/// `(seed, round, node, kind)` noise stream, difference-form correction,
/// and (when EF is opted in) residual slabs.
pub struct DsgtStrategy {
    /// Tracker stack Y `[n, p]` + its back buffer.
    y: Vec<f32>,
    y_back: Vec<f32>,
    /// Previous-gradient stack G `[n, p]` + its back buffer.
    g: Vec<f32>,
    g_back: Vec<f32>,
    /// Decoded tracker stack Ŷ `[n, p]` (compressed runs only).
    yhat: Vec<f32>,
    /// Tracker-stream EF residuals + back buffer (compressed + EF only).
    ef_y: Vec<f32>,
    ef_y_back: Vec<f32>,
    comm: GossipComm,
    msg_bytes: u64,
}

impl DsgtStrategy {
    /// Build for parameter size `p` under the given compression context.
    pub fn new(comm: GossipComm, p: usize) -> Self {
        let msg_bytes = comm.msg_bytes(p);
        DsgtStrategy {
            y: Vec::new(),
            y_back: Vec::new(),
            g: Vec::new(),
            g_back: Vec::new(),
            yhat: Vec::new(),
            ef_y: Vec::new(),
            ef_y_back: Vec::new(),
            comm,
            msg_bytes,
        }
    }
}

impl CommStrategy for DsgtStrategy {
    fn cost(&self) -> CommCost {
        // θ and ϑ, each charged at its own encoded size
        CommCost::Gossip { kinds: 2, kind_bytes: [self.msg_bytes, self.msg_bytes] }
    }

    fn init(&mut self, st: &mut EngineState, compute: &dyn Compute) -> Result<()> {
        st.draw_comm_batches();
        let (n, p) = (st.n, st.p);
        let mut g0 = vec![0.0f32; n * p];
        for i in 0..n {
            let (bx, by) = st.comm_batch(i);
            let (_, gi) = compute.grad_step(st.theta_row(i), bx, by)?;
            g0[i * p..(i + 1) * p].copy_from_slice(&gi);
        }
        self.y = g0.clone();
        self.g = g0;
        self.y_back = vec![0.0f32; n * p];
        self.g_back = vec![0.0f32; n * p];
        if self.comm.enabled() {
            self.yhat = vec![0.0f32; n * p];
            if self.comm.error_feedback {
                self.ef_y = vec![0.0f32; n * p];
                self.ef_y_back = vec![0.0f32; n * p];
            }
        }
        Ok(())
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        round: usize,
        lr: f32,
    ) -> Result<()> {
        st.draw_comm_batches();
        if let Some(comp) = &self.comm.comp {
            let ef = self.comm.error_feedback;
            ef_compress_stack(
                comp.as_ref(),
                ef,
                self.comm.seed,
                round,
                PayloadKind::Params,
                &st.theta,
                net.online,
                st.p,
                &st.ef_theta,
                &mut st.ef_theta_back,
                &mut st.xhat,
                &mut st.vbuf,
            );
            ef_compress_stack(
                comp.as_ref(),
                ef,
                self.comm.seed,
                round,
                PayloadKind::Tracker,
                &self.y,
                net.online,
                st.p,
                &self.ef_y,
                &mut self.ef_y_back,
                &mut self.yhat,
                &mut st.vbuf,
            );
            if ef {
                std::mem::swap(&mut st.ef_theta, &mut st.ef_theta_back);
                std::mem::swap(&mut self.ef_y, &mut self.ef_y_back);
            }
            compute.dsgt_round_compressed_into(
                &net.mix(),
                &st.xhat,
                &self.yhat,
                &st.theta,
                &self.y,
                &self.g,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut self.y_back,
                &mut self.g_back,
                &mut st.comm_losses,
            )?;
        } else {
            compute.dsgt_round_into(
                &net.mix(),
                &st.theta,
                &self.y,
                &self.g,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut self.y_back,
                &mut self.g_back,
                &mut st.comm_losses,
            )?;
        }
        if !net.all_online() {
            restore_offline_rows(&mut st.theta_back, &st.theta, net.online, st.p);
            restore_offline_rows(&mut self.y_back, &self.y, net.online, st.p);
            restore_offline_rows(&mut self.g_back, &self.g, net.online, st.p);
        }
        std::mem::swap(&mut st.theta, &mut st.theta_back);
        std::mem::swap(&mut self.y, &mut self.y_back);
        std::mem::swap(&mut self.g, &mut self.g_back);
        Ok(())
    }
}

// ------------------------------------------------------------- FedAvg ----

/// Star-network FedAvg (McMahan et al., 2017): the engine's local phase runs
/// every client from the server parameters (all stack rows are identical
/// after each round); this update takes the final local gradient and
/// replaces every row with the client average.
pub struct FedAvgStrategy;

impl FedAvgStrategy {
    /// The (stateless) FedAvg update.
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        FedAvgStrategy
    }
}

impl CommStrategy for FedAvgStrategy {
    fn cost(&self) -> CommCost {
        CommCost::Star
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        _net: &RoundNet,
        _round: usize,
        lr: f32,
    ) -> Result<()> {
        let (n, p) = (st.n, st.p);
        let mut mean = vec![0.0f64; p];
        for i in 0..n {
            // final local step of the round (keeps total gradient count = Q)
            {
                let (m, d) = (st.m, st.d);
                let shard = &st.shards[i];
                st.samplers[i].batch(
                    shard,
                    &mut st.cx[i * m * d..(i + 1) * m * d],
                    &mut st.cy[i * m..(i + 1) * m],
                );
            }
            let (bx, by) = st.comm_batch(i);
            let (_, grad) = compute.grad_step(st.theta_row(i), bx, by)?;
            let row = &mut st.theta[i * p..(i + 1) * p];
            axpy(row, -lr, &grad);
            for (acc, &t) in mean.iter_mut().zip(row.iter()) {
                *acc += t as f64;
            }
        }
        let server: Vec<f32> = mean.into_iter().map(|acc| (acc / n as f64) as f32).collect();
        for i in 0..n {
            st.theta[i * p..(i + 1) * p].copy_from_slice(&server);
        }
        Ok(())
    }
}

// -------------------------------------------------------- centralized ----

/// The fictitious fusion center the paper argues is infeasible: plain SGD
/// on the pooled cohort.  One stack row, no communication; the engine's
/// round axis advances every Q steps so curves align with FD runs.
pub struct CentralizedStrategy {
    /// Native twin for metrics — the pooled shard does not match the AOT
    /// artifacts' per-hospital eval shapes, so eval runs in-process.
    model: NativeModel,
}

impl CentralizedStrategy {
    /// Fusion-center SGD evaluated through the given native twin.
    pub fn new(model: NativeModel) -> Self {
        CentralizedStrategy { model }
    }
}

impl CommStrategy for CentralizedStrategy {
    fn cost(&self) -> CommCost {
        CommCost::None
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        _net: &RoundNet,
        _round: usize,
        lr: f32,
    ) -> Result<()> {
        st.draw_comm_batches();
        let (bx, by) = st.comm_batch(0);
        let (_, grad) = compute.grad_step(&st.theta, bx, by)?;
        axpy(&mut st.theta, -lr, &grad);
        Ok(())
    }

    fn eval(&self, st: &EngineState, _compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        Ok(self.model.eval_full(&st.theta, &st.shards))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Spec;

    #[test]
    fn costs_match_payload_kinds_at_encoded_sizes() {
        let p = 100usize;
        let dsgd = DsgdStrategy::new(GossipComm::none(0), p);
        assert_eq!(dsgd.cost(), CommCost::Gossip { kinds: 1, kind_bytes: [400, 0] });
        let dsgt = DsgtStrategy::new(GossipComm::none(0), p);
        assert_eq!(dsgt.cost(), CommCost::Gossip { kinds: 2, kind_bytes: [400, 400] });
        assert_eq!(FedAvgStrategy::new().cost(), CommCost::Star);
        assert_eq!(CentralizedStrategy::new(NativeModel::new(4, 2)).cost(), CommCost::None);
        // compressed strategies charge the encoded wire size per kind
        let q4 = GossipComm { comp: Spec::Q4.build(), error_feedback: true, seed: 0 };
        let dsgd_q4 = DsgdStrategy::new(q4, p);
        assert_eq!(dsgd_q4.cost(), CommCost::Gossip { kinds: 1, kind_bytes: [54, 0] });
        let tk = GossipComm {
            comp: Spec::TopK { frac: 0.1 }.build(),
            error_feedback: true,
            seed: 0,
        };
        let dsgt_tk = DsgtStrategy::new(tk, p);
        assert_eq!(dsgt_tk.cost(), CommCost::Gossip { kinds: 2, kind_bytes: [80, 80] });
    }

    #[test]
    fn restore_offline_rows_is_row_exact() {
        let prev = vec![1.0f32, 1.0, 2.0, 2.0, 3.0, 3.0];
        let mut next = vec![9.0f32, 9.0, 8.0, 8.0, 7.0, 7.0];
        restore_offline_rows(&mut next, &prev, &[true, false, true], 2);
        assert_eq!(next, vec![9.0, 9.0, 2.0, 2.0, 7.0, 7.0]);
    }

    #[test]
    fn ef_compress_stack_identity_reconstructs_and_zeroes_residual() {
        use crate::compress::Identity;
        let (n, p) = (3usize, 4usize);
        let stack: Vec<f32> = (0..n * p).map(|i| i as f32 * 0.25 - 1.0).collect();
        let online = vec![true, false, true];
        let e: Vec<f32> = vec![0.5f32; n * p];
        let mut e_back = vec![0.0f32; n * p];
        let mut xhat = vec![0.0f32; n * p];
        let mut vbuf = vec![0.0f32; p];
        ef_compress_stack(
            &Identity, true, 7, 2, PayloadKind::Params, &stack, &online, p, &e, &mut e_back,
            &mut xhat, &mut vbuf,
        );
        // online rows: x̂ = θ + e exactly, residual collapses to zero
        for i in [0usize, 2] {
            for j in 0..p {
                assert_eq!(xhat[i * p + j], stack[i * p + j] + 0.5);
                assert_eq!(e_back[i * p + j], 0.0);
            }
        }
        // offline row: residual carried forward untouched
        assert!(e_back[p..2 * p].iter().all(|&r| r == 0.5));
    }
}
