//! Communication strategies — the pluggable eq.-2/3/averaging/SGD update
//! the [`RoundEngine`](super::RoundEngine) applies once per round.
//!
//! A strategy owns the algorithm-specific auxiliary state (the DSGT tracker,
//! nothing for the others) and performs the whole-network communication
//! update on the shared [`EngineState`] through the [`Compute`] backend.
//! The network is NOT captured at construction: every round the driver hands
//! the strategy a [`RoundNet`] — that round's mixing matrix and online mask
//! from the `graph::schedule` layer — so time-varying topologies (rewire,
//! edge dropout, node churn) flow through without the strategy changing.
//! Gossip strategies also carry the run's [`GossipComm`] compression
//! context: when a compressor is configured every outgoing row is encoded
//! under its `(seed, round, node, kind)` key and the round applies the
//! **difference-form** update — mix the *decoded* stack, then add back each
//! node's own full-precision correction (DESIGN.md §10) — exactly mirroring
//! what the actor driver puts on the channel netsim, so fused and actor
//! trajectories stay bitwise-equal under every compressor.  The opt-in
//! error-feedback residual (`comm.error_feedback`) additionally
//! error-compensates the outgoing messages.
//! What a strategy does NOT own: the round loop, the lr schedule, batch
//! sampling streams, or metrics — those are engine machinery, identical for
//! every algorithm.  Adding an algorithm = implementing this trait; the
//! loop, both drivers, the CLI, and the benches pick it up unchanged.

use super::adversary::{AttackSchedule, MsgPerturb};
use super::EngineState;
use crate::algo::axpy;
use crate::algo::native::NativeModel;
use crate::compress::{add_residual, decode_into, residual_update, Compressor, GossipComm, MsgKey};
use crate::coordinator::compute::{Compute, MixView};
use crate::data::Shard;
use crate::mixing::SparseW;
use crate::netsim::PayloadKind;
use anyhow::{ensure, Result};

/// What one communication round costs on the wire (drives the analytic
/// accountant of the sync driver; the actor driver measures instead).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommCost {
    /// Synchronous gossip over every *active* edge of the round's network
    /// view.  `kinds` payloads per edge (1 = θ only, 2 = θ and the DSGT
    /// tracker ϑ); `kind_bytes[..kinds]` holds each payload's *encoded*
    /// wire size, so compressed runs are charged at what actually crosses
    /// the wire.  The per-round edge count comes from the schedule.
    Gossip {
        /// Payload kinds per edge (1 = θ, 2 = θ + ϑ).
        kinds: u32,
        /// Encoded bytes of each kind (entries past `kinds` are unused).
        kind_bytes: [u64; 2],
    },
    /// Star-network client↑/server↓ exchange (FedAvg).
    Star,
    /// No communication (fusion-center baseline).
    None,
}

/// The network of ONE communication round, as the schedule emitted it.
pub struct RoundNet<'a> {
    /// Row-major dense f32 mixing matrix `[n, n]` for this round — present
    /// only when the backend asked for it (`Compute::wants_dense_w`); the
    /// sparse-native path never materializes it (n×n is 40 GB at n = 10⁵).
    pub w: Option<&'a [f32]>,
    /// Degree-sparse CSR view of the round's mixing matrix (per-node
    /// `(neighbor, weight)` rows, ascending) — always present; what the
    /// native gossip kernels consume.
    pub sparse: &'a SparseW,
    /// Per-node participation mask (all `true` except under node churn).
    pub online: &'a [bool],
}

impl RoundNet<'_> {
    /// Is every node participating this round (no churn)?
    pub fn all_online(&self) -> bool {
        self.online.iter().all(|&b| b)
    }

    /// Both W forms, packaged for the compute layer.
    pub fn mix(&self) -> MixView<'_> {
        MixView { dense: self.w, sparse: self.sparse }
    }
}

/// Overwrite the stack rows of offline nodes with their previous values —
/// an offline node skips the communication update entirely (exactly what
/// its actor-driver counterpart does by not gossiping that round).
fn restore_offline_rows(next: &mut [f32], prev: &[f32], online: &[bool], p: usize) {
    for (i, &on) in online.iter().enumerate() {
        if !on {
            next[i * p..(i + 1) * p].copy_from_slice(&prev[i * p..(i + 1) * p]);
        }
    }
}

/// Byzantine nodes follow their own protocol, not ours: they train honestly
/// on their local shard (the engine's local phase) and broadcast perturbed
/// payloads, but never *apply* the communication update — their row reverts
/// to its pre-comm state after every round (DESIGN.md §14).  This keeps the
/// attack calibrated: a sign-flip attacker broadcasts `−θ` at the honest
/// parameter scale, instead of mixing its own poison back in and growing
/// its state by `(2 − w_ii)` per round until it overflows — an attacker
/// whose payload dwarfs the fleet by 10²⁰ is trivially screened and says
/// nothing about a rule's robustness.  No-op when the attack plan is off.
fn restore_attacker_rows(next: &mut [f32], prev: &[f32], attack: &AttackSchedule, p: usize) {
    if !attack.active() {
        return;
    }
    for i in 0..next.len() / p {
        if attack.is_attacker(i) {
            next[i * p..(i + 1) * p].copy_from_slice(&prev[i * p..(i + 1) * p]);
        }
    }
}

/// Is *online* sender `i`'s row non-finite in any of the given payload
/// stacks?  (A sender poisons all its payload kinds at once — one bad kind
/// quarantines the node from both θ and ϑ mixing.)
fn bad_sender(stacks: &[&[f32]], online: &[bool], p: usize, i: usize) -> bool {
    online[i] && stacks.iter().any(|s| s[i * p..(i + 1) * p].iter().any(|v| !v.is_finite()))
}

/// Non-finite ingest guard (DESIGN.md §14): if any online sender's payload
/// row carries NaN/Inf, build a quarantine-compacted copy of the round's
/// CSR mixing matrix — every receiver drops its entries from bad senders
/// and folds their weights into its self-weight (the same row compaction
/// the async driver applies to stale/missing neighbors), so honest nodes
/// never mix a non-finite value and row sums are preserved.  Returns the
/// compacted W plus the number of dropped directed entries, or `None` on
/// the clean path — which scans allocation-free, preserving the
/// steady-state zero-alloc contract (`tests/alloc_free.rs`).
fn quarantine_compact(
    net: &RoundNet,
    stacks: &[&[f32]],
    p: usize,
) -> Result<Option<(SparseW, u64)>> {
    let n = net.online.len();
    if !(0..n).any(|i| bad_sender(stacks, net.online, p, i)) {
        return Ok(None);
    }
    ensure!(
        net.w.is_none(),
        "non-finite neighbor payloads detected, but this backend mixes a dense W; \
         quarantine (folding bad senders into the self-weight, DESIGN.md §14) is \
         sparse-native only — rerun on the native backend"
    );
    let bad: Vec<bool> = (0..n).map(|i| bad_sender(stacks, net.online, p, i)).collect();
    let src = net.sparse;
    let mut wq = SparseW::empty();
    wq.reset(n);
    wq.reserve_rows_nnz(n, src.nnz());
    let mut dropped = 0u64;
    for i in 0..n {
        let (idx, val) = src.row(i);
        // Fold the quarantined neighbors' weights in CSR (ascending-column)
        // order — the actor driver sums in the same order, so the
        // fused==actors bitwise pin survives an active quarantine.
        let mut folded = 0.0f32;
        for (&j, &v) in idx.iter().zip(val) {
            if j as usize != i && bad[j as usize] {
                folded += v;
                dropped += 1;
            }
        }
        let mut diag_done = false;
        for (&j, &v) in idx.iter().zip(val) {
            let ju = j as usize;
            if !diag_done && ju > i {
                // the source row had no self-weight: materialize one to
                // receive the folded mass, keeping columns ascending
                wq.push_entry(i as u32, folded);
                diag_done = true;
            }
            if ju == i {
                wq.push_entry(j, v + folded);
                diag_done = true;
            } else if !bad[ju] {
                wq.push_entry(j, v);
            }
        }
        if !diag_done {
            wq.push_entry(i as u32, folded);
        }
        wq.seal_row();
    }
    Ok(Some((wq, dropped)))
}

/// Error-feedback-compress one whole payload stack for this round: per
/// *online* row `i`, build the error-compensated message `v = x_i + e_i`,
/// encode it under the deterministic `(seed, round, i, kind)` key, decode
/// the wire message into the `xhat` row (what neighbors — and the node
/// itself — mix), and write the new residual `v − x̂` into the residual back
/// slab.  Offline rows carry their residual forward untouched; their
/// `xhat` row is left stale — online neighbors never mix it (absorbed
/// weights are zero), and while the offline node's own kernel row does
/// read it through its identity self-weight, that whole output row is
/// discarded by `restore_offline_rows` right after the round.
///
/// This is the fused twin of the per-node EF step the actor driver runs
/// before broadcasting — both call the same `compress::{add_residual,
/// residual_update}` helpers and the same encode/decode, so the decoded
/// stacks (and therefore the trajectories) agree bitwise.
///
/// When a [`MsgPerturb`] pipeline is active (Byzantine attack and/or DP,
/// `engine::adversary`), it is applied to the error-compensated message
/// *before* encoding — the attacker/DP layer corrupts what actually hits
/// the wire, pre-quantization.  The sender's own `xhat` row decodes the
/// corrupted copy too, but an attacker's comm-update output is discarded
/// afterwards ([`restore_attacker_rows`]): Byzantine nodes broadcast
/// poison, they don't follow the update rule.
#[allow(clippy::too_many_arguments)]
fn ef_compress_stack(
    comp: &dyn Compressor,
    ef: bool,
    seed: u64,
    round: usize,
    kind: PayloadKind,
    stack: &[f32],
    online: &[bool],
    p: usize,
    e: &[f32],
    e_back: &mut [f32],
    xhat: &mut [f32],
    vbuf: &mut [f32],
    mut perturb: Option<&mut MsgPerturb>,
) -> Result<()> {
    let n = stack.len() / p;
    for i in 0..n {
        let row = i * p..(i + 1) * p;
        if !online[i] {
            if ef {
                e_back[row.clone()].copy_from_slice(&e[row]);
            }
            continue;
        }
        if ef {
            add_residual(&stack[row.clone()], &e[row.clone()], vbuf);
        } else {
            vbuf.copy_from_slice(&stack[row.clone()]);
        }
        if let Some(pb) = perturb.as_deref_mut() {
            pb.apply(round, i, kind.tag(), vbuf);
        }
        let enc = comp.encode(vbuf, MsgKey::new(seed, round, i, kind));
        decode_into(&enc, &mut xhat[row.clone()])?;
        if ef {
            residual_update(vbuf, &xhat[row.clone()], &mut e_back[row]);
        }
    }
    Ok(())
}

/// The communication update of Algorithm 1 — eq. 2, eq. 3, a server
/// average, or a plain SGD step — plus its wire cost and the metric eval.
/// (The run-log label is the driver's concern — `cfg.algo.name()` — so
/// strategies carry no display name.)
///
/// # Examples
///
/// Strategies are selected by the config's algorithm and run through the
/// engine's entry points — a minimal end-to-end DSGD round sequence:
///
/// ```
/// use decfl::config::{AlgoKind, Backend, ExperimentConfig};
/// use decfl::coordinator::{assemble, run_on};
///
/// let mut cfg = ExperimentConfig::default();
/// cfg.backend = Backend::Native;
/// cfg.algo = AlgoKind::FdDsgd;   // → DsgdStrategy under the round engine
/// cfg.n = 4;
/// cfg.hidden = 8;
/// cfg.m = 4;
/// cfg.q = 2;
/// cfg.total_steps = 4;           // two communication rounds
/// cfg.records_per_hospital = 40;
/// let asm = assemble(&cfg).unwrap();
/// let log = run_on(&cfg, &asm).unwrap();
/// assert!(log.rows.last().unwrap().loss.is_finite());
/// ```
pub trait CommStrategy {
    /// Wire cost of one communication round (per-kind encoded sizes).
    fn cost(&self) -> CommCost;

    /// Pre-loop initialization (e.g. DSGT's Y⁰ = G⁰ = ∇g(θ⁰) on a fresh
    /// batch).  Default: nothing.
    fn init(&mut self, _st: &mut EngineState, _compute: &dyn Compute) -> Result<()> {
        Ok(())
    }

    /// Apply the communication update of round `round` (1-based) at learning
    /// rate `lr` over this round's network view, consuming one gradient per
    /// stack row.  The round index keys the deterministic compression
    /// streams (`compress::MsgKey`).
    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        round: usize,
        lr: f32,
    ) -> Result<()>;

    /// Full-shard metrics → (loss, accuracy, stationarity, consensus).
    /// Default: whole-stack eval over the training shards.
    fn eval(&self, st: &EngineState, compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        compute.eval_full(&st.theta, &st.shards)
    }

    /// Cumulative count of quarantined neighbor payloads (non-finite rows
    /// folded into the receiver's self-weight) across all rounds so far.
    /// Default 0 — only the gossip strategies can quarantine.
    fn quarantined(&self) -> u64 {
        0
    }
}

/// Record-weighted metrics over the **honest sub-fleet** when a Byzantine
/// attack is active (DESIGN.md §14).  An attacker node is adversarial
/// software, not a hospital: its parameter row is arbitrary (sign-flip, for
/// one, makes the attacker's own state grow geometrically, since its row
/// mixes the poison it broadcast), so folding it into the global metric
/// would let the adversary report any loss it likes.  Robustness is judged
/// on what honest sites actually serve — attacker records are excluded from
/// the weighting, and consensus is measured across honest rows.  DP-only
/// pipelines (no attack plan) and the honest defaults keep the full-fleet
/// metric bitwise-unchanged.  Runs at the eval cadence, off the
/// zero-allocation round path, shared by all three drivers.
pub fn eval_honest_subset(
    attack: Option<&AttackSchedule>,
    theta: &[f32],
    shards: &[Shard],
    p: usize,
    compute: &dyn Compute,
) -> Result<(f64, f64, f64, f64)> {
    let Some(a) = attack.filter(|a| a.active()) else {
        return compute.eval_full(theta, shards);
    };
    let n = shards.len();
    let keep: Vec<usize> = (0..n).filter(|&i| !a.is_attacker(i)).collect();
    if keep.len() == n || keep.is_empty() {
        // nothing to mask — or a fully Byzantine fleet, which has no honest
        // metric to report; fall back to the whole stack rather than NaN
        return compute.eval_full(theta, shards);
    }
    let mut th = Vec::with_capacity(keep.len() * p);
    let mut sh = Vec::with_capacity(keep.len());
    for &i in &keep {
        th.extend_from_slice(&theta[i * p..(i + 1) * p]);
        sh.push(shards[i].clone());
    }
    compute.eval_full(&th, &sh)
}

// --------------------------------------------------------------- DSGD ----

/// Eq. 2: `θ_i ← Σ_j w_ij θ_j − α ∇g_i(θ_i)` (covers DSGD and FD-DSGD —
/// the local period lives in the engine, not here; the round's `W` arrives
/// through [`RoundNet`]).  With a configured compressor the round runs the
/// difference-form update over the decoded stack (see the module docs).
pub struct DsgdStrategy {
    comm: GossipComm,
    msg_bytes: u64,
    /// Active adversary/DP pipeline (None on the pinned honest path).
    perturb: Option<MsgPerturb>,
    /// Cumulative quarantined-payload count (non-finite ingest guard).
    quarantined: u64,
}

impl DsgdStrategy {
    /// Build for parameter size `p` under the given compression context.
    pub fn new(comm: GossipComm, p: usize) -> Self {
        let msg_bytes = comm.msg_bytes(p);
        DsgdStrategy { comm, msg_bytes, perturb: None, quarantined: 0 }
    }

    /// Install a per-message perturbation pipeline (attack and/or DP).  The
    /// driver routes perturbed runs through the compressed path (installing
    /// an `Identity` compressor when none is configured) so the pipeline
    /// always sits at the encode boundary.
    pub fn with_perturb(mut self, perturb: Option<MsgPerturb>) -> Self {
        self.perturb = perturb;
        self
    }
}

impl CommStrategy for DsgdStrategy {
    fn cost(&self) -> CommCost {
        CommCost::Gossip { kinds: 1, kind_bytes: [self.msg_bytes, 0] }
    }

    fn eval(&self, st: &EngineState, compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        eval_honest_subset(
            self.perturb.as_ref().map(|pb| &pb.attack),
            &st.theta,
            &st.shards,
            st.p,
            compute,
        )
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        round: usize,
        lr: f32,
    ) -> Result<()> {
        // Every row draws its batch every round — the sampler streams stay
        // keyed by (seed, row) alone (§7), independent of the network plan;
        // offline rows discard theirs below.
        st.draw_comm_batches();
        if let Some(comp) = &self.comm.comp {
            let ef = self.comm.error_feedback;
            ef_compress_stack(
                comp.as_ref(),
                ef,
                self.comm.seed,
                round,
                PayloadKind::Params,
                &st.theta,
                net.online,
                st.p,
                &st.ef_theta,
                &mut st.ef_theta_back,
                &mut st.xhat,
                &mut st.vbuf,
                self.perturb.as_mut(),
            )?;
            if ef {
                std::mem::swap(&mut st.ef_theta, &mut st.ef_theta_back);
            }
            let q = quarantine_compact(net, &[&st.xhat], st.p)?;
            if let Some((_, d)) = &q {
                self.quarantined += d;
            }
            let mix =
                q.as_ref().map(|(wq, _)| MixView { dense: net.w, sparse: wq }).unwrap_or_else(
                    || net.mix(),
                );
            compute.dsgd_round_compressed_into(
                &mix,
                &st.xhat,
                &st.theta,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut st.comm_losses,
            )?;
        } else {
            ensure!(
                self.perturb.is_none(),
                "perturbation pipeline requires the encode path; the driver must \
                 install an Identity compressor for perturbed uncompressed runs"
            );
            let q = quarantine_compact(net, &[&st.theta], st.p)?;
            if let Some((_, d)) = &q {
                self.quarantined += d;
            }
            let mix =
                q.as_ref().map(|(wq, _)| MixView { dense: net.w, sparse: wq }).unwrap_or_else(
                    || net.mix(),
                );
            compute.dsgd_round_into(
                &mix,
                &st.theta,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut st.comm_losses,
            )?;
        }
        if !net.all_online() {
            restore_offline_rows(&mut st.theta_back, &st.theta, net.online, st.p);
        }
        if let Some(pb) = &self.perturb {
            restore_attacker_rows(&mut st.theta_back, &st.theta, &pb.attack, st.p);
        }
        std::mem::swap(&mut st.theta, &mut st.theta_back);
        Ok(())
    }

    fn quarantined(&self) -> u64 {
        self.quarantined
    }
}

// --------------------------------------------------------------- DSGT ----

/// Eq. 3 with gradient tracking: mixes θ and the tracker ϑ, then refreshes
/// the tracker with the gradient difference (covers DSGT and FD-DSGT).
/// Offline rounds leave a node's θ, ϑ, and G untouched.  The tracker and
/// gradient stacks are double-buffered like the engine's θ stack, so a
/// steady-state round allocates nothing.  Under compression both payload
/// streams (θ and ϑ) are encoded independently, each with its own
/// `(seed, round, node, kind)` noise stream, difference-form correction,
/// and (when EF is opted in) residual slabs.
pub struct DsgtStrategy {
    /// Tracker stack Y `[n, p]` + its back buffer.
    y: Vec<f32>,
    y_back: Vec<f32>,
    /// Previous-gradient stack G `[n, p]` + its back buffer.
    g: Vec<f32>,
    g_back: Vec<f32>,
    /// Decoded tracker stack Ŷ `[n, p]` (compressed runs only).
    yhat: Vec<f32>,
    /// Tracker-stream EF residuals + back buffer (compressed + EF only).
    ef_y: Vec<f32>,
    ef_y_back: Vec<f32>,
    comm: GossipComm,
    msg_bytes: u64,
    /// Active adversary/DP pipeline (None on the pinned honest path).
    perturb: Option<MsgPerturb>,
    /// Cumulative quarantined-payload count (non-finite ingest guard).
    quarantined: u64,
}

impl DsgtStrategy {
    /// Build for parameter size `p` under the given compression context.
    pub fn new(comm: GossipComm, p: usize) -> Self {
        let msg_bytes = comm.msg_bytes(p);
        DsgtStrategy {
            y: Vec::new(),
            y_back: Vec::new(),
            g: Vec::new(),
            g_back: Vec::new(),
            yhat: Vec::new(),
            ef_y: Vec::new(),
            ef_y_back: Vec::new(),
            comm,
            msg_bytes,
            perturb: None,
            quarantined: 0,
        }
    }

    /// Install a per-message perturbation pipeline (attack and/or DP); see
    /// [`DsgdStrategy::with_perturb`].  Both payload streams (θ and ϑ) run
    /// through the pipeline, each under its own kind-keyed noise stream.
    pub fn with_perturb(mut self, perturb: Option<MsgPerturb>) -> Self {
        self.perturb = perturb;
        self
    }
}

impl CommStrategy for DsgtStrategy {
    fn cost(&self) -> CommCost {
        // θ and ϑ, each charged at its own encoded size
        CommCost::Gossip { kinds: 2, kind_bytes: [self.msg_bytes, self.msg_bytes] }
    }

    fn eval(&self, st: &EngineState, compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        eval_honest_subset(
            self.perturb.as_ref().map(|pb| &pb.attack),
            &st.theta,
            &st.shards,
            st.p,
            compute,
        )
    }

    fn init(&mut self, st: &mut EngineState, compute: &dyn Compute) -> Result<()> {
        st.draw_comm_batches();
        let (n, p) = (st.n, st.p);
        let mut g0 = vec![0.0f32; n * p];
        for i in 0..n {
            let (bx, by) = st.comm_batch(i);
            let (_, gi) = compute.grad_step(st.theta_row(i), bx, by)?;
            g0[i * p..(i + 1) * p].copy_from_slice(&gi);
        }
        self.y = g0.clone();
        self.g = g0;
        self.y_back = vec![0.0f32; n * p];
        self.g_back = vec![0.0f32; n * p];
        if self.comm.enabled() {
            self.yhat = vec![0.0f32; n * p];
            if self.comm.error_feedback {
                self.ef_y = vec![0.0f32; n * p];
                self.ef_y_back = vec![0.0f32; n * p];
            }
        }
        Ok(())
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        round: usize,
        lr: f32,
    ) -> Result<()> {
        st.draw_comm_batches();
        if let Some(comp) = &self.comm.comp {
            let ef = self.comm.error_feedback;
            ef_compress_stack(
                comp.as_ref(),
                ef,
                self.comm.seed,
                round,
                PayloadKind::Params,
                &st.theta,
                net.online,
                st.p,
                &st.ef_theta,
                &mut st.ef_theta_back,
                &mut st.xhat,
                &mut st.vbuf,
                self.perturb.as_mut(),
            )?;
            ef_compress_stack(
                comp.as_ref(),
                ef,
                self.comm.seed,
                round,
                PayloadKind::Tracker,
                &self.y,
                net.online,
                st.p,
                &self.ef_y,
                &mut self.ef_y_back,
                &mut self.yhat,
                &mut st.vbuf,
                self.perturb.as_mut(),
            )?;
            if ef {
                std::mem::swap(&mut st.ef_theta, &mut st.ef_theta_back);
                std::mem::swap(&mut self.ef_y, &mut self.ef_y_back);
            }
            let q = quarantine_compact(net, &[&st.xhat, &self.yhat], st.p)?;
            if let Some((_, d)) = &q {
                self.quarantined += d;
            }
            let mix =
                q.as_ref().map(|(wq, _)| MixView { dense: net.w, sparse: wq }).unwrap_or_else(
                    || net.mix(),
                );
            compute.dsgt_round_compressed_into(
                &mix,
                &st.xhat,
                &self.yhat,
                &st.theta,
                &self.y,
                &self.g,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut self.y_back,
                &mut self.g_back,
                &mut st.comm_losses,
            )?;
        } else {
            ensure!(
                self.perturb.is_none(),
                "perturbation pipeline requires the encode path; the driver must \
                 install an Identity compressor for perturbed uncompressed runs"
            );
            let q = quarantine_compact(net, &[&st.theta, &self.y], st.p)?;
            if let Some((_, d)) = &q {
                self.quarantined += d;
            }
            let mix =
                q.as_ref().map(|(wq, _)| MixView { dense: net.w, sparse: wq }).unwrap_or_else(
                    || net.mix(),
                );
            compute.dsgt_round_into(
                &mix,
                &st.theta,
                &self.y,
                &self.g,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut self.y_back,
                &mut self.g_back,
                &mut st.comm_losses,
            )?;
        }
        if !net.all_online() {
            restore_offline_rows(&mut st.theta_back, &st.theta, net.online, st.p);
            restore_offline_rows(&mut self.y_back, &self.y, net.online, st.p);
            restore_offline_rows(&mut self.g_back, &self.g, net.online, st.p);
        }
        if let Some(pb) = &self.perturb {
            restore_attacker_rows(&mut st.theta_back, &st.theta, &pb.attack, st.p);
            restore_attacker_rows(&mut self.y_back, &self.y, &pb.attack, st.p);
            restore_attacker_rows(&mut self.g_back, &self.g, &pb.attack, st.p);
        }
        std::mem::swap(&mut st.theta, &mut st.theta_back);
        std::mem::swap(&mut self.y, &mut self.y_back);
        std::mem::swap(&mut self.g, &mut self.g_back);
        Ok(())
    }

    fn quarantined(&self) -> u64 {
        self.quarantined
    }
}

// ------------------------------------------------------------- FedAvg ----

/// Star-network FedAvg (McMahan et al., 2017): the engine's local phase runs
/// every client from the server parameters (all stack rows are identical
/// after each round); this update takes the final local gradient and
/// replaces every row with the client average.
pub struct FedAvgStrategy;

impl FedAvgStrategy {
    /// The (stateless) FedAvg update.
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        FedAvgStrategy
    }
}

impl CommStrategy for FedAvgStrategy {
    fn cost(&self) -> CommCost {
        CommCost::Star
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        _net: &RoundNet,
        _round: usize,
        lr: f32,
    ) -> Result<()> {
        let (n, p) = (st.n, st.p);
        let mut mean = vec![0.0f64; p];
        for i in 0..n {
            // final local step of the round (keeps total gradient count = Q)
            {
                let (m, d) = (st.m, st.d);
                let shard = &st.shards[i];
                st.samplers[i].batch(
                    shard,
                    &mut st.cx[i * m * d..(i + 1) * m * d],
                    &mut st.cy[i * m..(i + 1) * m],
                );
            }
            let (bx, by) = st.comm_batch(i);
            let (_, grad) = compute.grad_step(st.theta_row(i), bx, by)?;
            let row = &mut st.theta[i * p..(i + 1) * p];
            axpy(row, -lr, &grad);
            for (acc, &t) in mean.iter_mut().zip(row.iter()) {
                *acc += t as f64;
            }
        }
        let server: Vec<f32> = mean.into_iter().map(|acc| (acc / n as f64) as f32).collect();
        for i in 0..n {
            st.theta[i * p..(i + 1) * p].copy_from_slice(&server);
        }
        Ok(())
    }
}

// -------------------------------------------------------- centralized ----

/// The fictitious fusion center the paper argues is infeasible: plain SGD
/// on the pooled cohort.  One stack row, no communication; the engine's
/// round axis advances every Q steps so curves align with FD runs.
pub struct CentralizedStrategy {
    /// Native twin for metrics — the pooled shard does not match the AOT
    /// artifacts' per-hospital eval shapes, so eval runs in-process.
    model: NativeModel,
}

impl CentralizedStrategy {
    /// Fusion-center SGD evaluated through the given native twin.
    pub fn new(model: NativeModel) -> Self {
        CentralizedStrategy { model }
    }
}

impl CommStrategy for CentralizedStrategy {
    fn cost(&self) -> CommCost {
        CommCost::None
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        _net: &RoundNet,
        _round: usize,
        lr: f32,
    ) -> Result<()> {
        st.draw_comm_batches();
        let (bx, by) = st.comm_batch(0);
        let (_, grad) = compute.grad_step(&st.theta, bx, by)?;
        axpy(&mut st.theta, -lr, &grad);
        Ok(())
    }

    fn eval(&self, st: &EngineState, _compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        Ok(self.model.eval_full(&st.theta, &st.shards))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Spec;

    #[test]
    fn costs_match_payload_kinds_at_encoded_sizes() {
        let p = 100usize;
        let dsgd = DsgdStrategy::new(GossipComm::none(0), p);
        assert_eq!(dsgd.cost(), CommCost::Gossip { kinds: 1, kind_bytes: [400, 0] });
        let dsgt = DsgtStrategy::new(GossipComm::none(0), p);
        assert_eq!(dsgt.cost(), CommCost::Gossip { kinds: 2, kind_bytes: [400, 400] });
        assert_eq!(FedAvgStrategy::new().cost(), CommCost::Star);
        assert_eq!(CentralizedStrategy::new(NativeModel::new(4, 2)).cost(), CommCost::None);
        // compressed strategies charge the encoded wire size per kind
        let q4 = GossipComm { comp: Spec::Q4.build(), error_feedback: true, seed: 0 };
        let dsgd_q4 = DsgdStrategy::new(q4, p);
        assert_eq!(dsgd_q4.cost(), CommCost::Gossip { kinds: 1, kind_bytes: [54, 0] });
        let tk = GossipComm {
            comp: Spec::TopK { frac: 0.1 }.build(),
            error_feedback: true,
            seed: 0,
        };
        let dsgt_tk = DsgtStrategy::new(tk, p);
        assert_eq!(dsgt_tk.cost(), CommCost::Gossip { kinds: 2, kind_bytes: [80, 80] });
    }

    #[test]
    fn restore_offline_rows_is_row_exact() {
        let prev = vec![1.0f32, 1.0, 2.0, 2.0, 3.0, 3.0];
        let mut next = vec![9.0f32, 9.0, 8.0, 8.0, 7.0, 7.0];
        restore_offline_rows(&mut next, &prev, &[true, false, true], 2);
        assert_eq!(next, vec![9.0, 9.0, 2.0, 2.0, 7.0, 7.0]);
    }

    #[test]
    fn ef_compress_stack_identity_reconstructs_and_zeroes_residual() {
        use crate::compress::Identity;
        let (n, p) = (3usize, 4usize);
        let stack: Vec<f32> = (0..n * p).map(|i| i as f32 * 0.25 - 1.0).collect();
        let online = vec![true, false, true];
        let e: Vec<f32> = vec![0.5f32; n * p];
        let mut e_back = vec![0.0f32; n * p];
        let mut xhat = vec![0.0f32; n * p];
        let mut vbuf = vec![0.0f32; p];
        ef_compress_stack(
            &Identity, true, 7, 2, PayloadKind::Params, &stack, &online, p, &e, &mut e_back,
            &mut xhat, &mut vbuf, None,
        )
        .unwrap();
        // online rows: x̂ = θ + e exactly, residual collapses to zero
        for i in [0usize, 2] {
            for j in 0..p {
                assert_eq!(xhat[i * p + j], stack[i * p + j] + 0.5);
                assert_eq!(e_back[i * p + j], 0.0);
            }
        }
        // offline row: residual carried forward untouched
        assert!(e_back[p..2 * p].iter().all(|&r| r == 0.5));
    }

    #[test]
    fn ef_compress_stack_applies_the_perturbation_at_the_encode_boundary() {
        use crate::compress::Identity;
        use crate::config::ExperimentConfig;
        let (n, p) = (4usize, 3usize);
        let stack = vec![1.0f32; n * p];
        let online = vec![true; n];
        let e = vec![0.0f32; n * p];
        let mut e_back = vec![0.0f32; n * p];
        let mut xhat = vec![0.0f32; n * p];
        let mut vbuf = vec![0.0f32; p];
        let cfg = ExperimentConfig {
            n,
            attack_plan: "sign-flip".into(),
            attack_frac: 0.25,
            ..ExperimentConfig::default()
        };
        let mut pb = MsgPerturb::from_config(&cfg).unwrap().unwrap();
        let attacker = (0..n).find(|&i| pb.attack.is_attacker(i)).unwrap();
        ef_compress_stack(
            &Identity,
            false,
            cfg.seed,
            1,
            PayloadKind::Params,
            &stack,
            &online,
            p,
            &e,
            &mut e_back,
            &mut xhat,
            &mut vbuf,
            Some(&mut pb),
        )
        .unwrap();
        for i in 0..n {
            let want = if i == attacker { -1.0 } else { 1.0 };
            assert!(xhat[i * p..(i + 1) * p].iter().all(|&v| v == want), "row {i}");
        }
    }

    #[test]
    fn quarantine_folds_bad_senders_into_self_weight() {
        // 3-node path: W rows sum to 1
        #[rustfmt::skip]
        let dense = vec![
            0.5,  0.5, 0.0,
            0.25, 0.5, 0.25,
            0.0,  0.5, 0.5,
        ];
        let w = SparseW::from_dense(3, &dense);
        let online = [true, true, true];
        let p = 2usize;
        let clean = vec![0.0f32; 6];
        let mut poisoned = clean.clone();
        poisoned[2] = f32::NAN; // node 1's row
        let net = RoundNet { w: None, sparse: &w, online: &online };
        // clean path: no compaction, no allocation
        assert!(quarantine_compact(&net, &[&clean], p).unwrap().is_none());
        let (wq, dropped) = quarantine_compact(&net, &[&poisoned], p).unwrap().unwrap();
        assert_eq!(dropped, 2, "rows 0 and 2 each drop their node-1 entry");
        #[rustfmt::skip]
        let want = vec![
            1.0,  0.0, 0.0,
            0.25, 0.5, 0.25, // the bad node's own row is untouched
            0.0,  0.0, 1.0,
        ];
        assert_eq!(wq.to_dense(), want);
        // a second payload kind can trigger the quarantine on its own
        let (wq2, d2) = quarantine_compact(&net, &[&clean, &poisoned], p).unwrap().unwrap();
        assert_eq!((wq2.to_dense(), d2), (want, 2));
        // dense-W backends cannot compact rows: loud error, not silence
        let dnet = RoundNet { w: Some(&dense), sparse: &w, online: &online };
        let err = quarantine_compact(&dnet, &[&poisoned], p).unwrap_err().to_string();
        assert!(err.contains("sparse-native"), "{err}");
    }

    #[test]
    fn quarantine_materializes_a_missing_self_weight() {
        // node 0 has no diagonal entry: the folded mass must create one,
        // keeping columns ascending
        #[rustfmt::skip]
        let dense = vec![
            0.0, 1.0, 0.0,
            0.5, 0.0, 0.5,
            0.0, 1.0, 0.0,
        ];
        let w = SparseW::from_dense(3, &dense);
        let online = [true, true, true];
        let mut poisoned = vec![0.0f32; 3];
        poisoned[1] = f32::INFINITY; // p = 1, node 1 bad
        let net = RoundNet { w: None, sparse: &w, online: &online };
        let (wq, dropped) = quarantine_compact(&net, &[&poisoned], 1).unwrap().unwrap();
        assert_eq!(dropped, 2);
        #[rustfmt::skip]
        let want = vec![
            1.0, 0.0, 0.0,
            0.5, 0.0, 0.5,
            0.0, 0.0, 1.0,
        ];
        assert_eq!(wq.to_dense(), want);
        // offline senders are never scanned (their weights are already 0)
        let offline = [true, false, true];
        let onet = RoundNet { w: None, sparse: &w, online: &offline };
        assert!(quarantine_compact(&onet, &[&poisoned], 1).unwrap().is_none());
    }
}
