//! Communication strategies — the pluggable eq.-2/3/averaging/SGD update
//! the [`RoundEngine`](super::RoundEngine) applies once per round.
//!
//! A strategy owns the algorithm-specific auxiliary state (the DSGT tracker,
//! nothing for the others) and performs the whole-network communication
//! update on the shared [`EngineState`] through the [`Compute`] backend.
//! The network is NOT captured at construction: every round the driver hands
//! the strategy a [`RoundNet`] — that round's mixing matrix and online mask
//! from the `graph::schedule` layer — so time-varying topologies (rewire,
//! edge dropout, node churn) flow through without the strategy changing.
//! Gossip strategies also carry the run's [`GossipComm`] compression
//! context: when a compressor is configured every outgoing row is encoded
//! under its `(seed, round, node, kind)` key and the round applies the
//! **difference-form** update — mix the *decoded* stack, then add back each
//! node's own full-precision correction (DESIGN.md §10) — exactly mirroring
//! what the actor driver puts on the channel netsim, so fused and actor
//! trajectories stay bitwise-equal under every compressor.  The opt-in
//! error-feedback residual (`comm.error_feedback`) additionally
//! error-compensates the outgoing messages.
//! What a strategy does NOT own: the round loop, the lr schedule, batch
//! sampling streams, or metrics — those are engine machinery, identical for
//! every algorithm.  Adding an algorithm = implementing this trait; the
//! loop, both drivers, the CLI, and the benches pick it up unchanged.

use super::adversary::MsgPerturb;
use super::pipeline::{
    ef_compress_stack, eval_honest_subset, quarantine_compact, restore_attacker_rows,
    restore_offline_rows,
};
use super::EngineState;
use crate::algo::axpy;
use crate::algo::native::NativeModel;
use crate::compress::GossipComm;
use crate::coordinator::compute::{Compute, MixView};
use crate::netsim::PayloadKind;
use super::pipeline::RoundNet;
use anyhow::{ensure, Result};

/// What one communication round costs on the wire (drives the analytic
/// accountant of the sync driver; the actor driver measures instead).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommCost {
    /// Synchronous gossip over every *active* edge of the round's network
    /// view.  `kinds` payloads per edge (1 = θ only, 2 = θ and the DSGT
    /// tracker ϑ); `kind_bytes[..kinds]` holds each payload's *encoded*
    /// wire size, so compressed runs are charged at what actually crosses
    /// the wire.  The per-round edge count comes from the schedule.
    Gossip {
        /// Payload kinds per edge (1 = θ, 2 = θ + ϑ).
        kinds: u32,
        /// Encoded bytes of each kind (entries past `kinds` are unused).
        kind_bytes: [u64; 2],
    },
    /// Star-network client↑/server↓ exchange (FedAvg).
    Star,
    /// No communication (fusion-center baseline).
    None,
}

/// The communication update of Algorithm 1 — eq. 2, eq. 3, a server
/// average, or a plain SGD step — plus its wire cost and the metric eval.
/// (The run-log label is the driver's concern — `cfg.algo.name()` — so
/// strategies carry no display name.)
///
/// # Examples
///
/// Strategies are selected by the config's algorithm and run through the
/// engine's entry points — a minimal end-to-end DSGD round sequence:
///
/// ```
/// use decfl::config::{AlgoKind, Backend, ExperimentConfig};
/// use decfl::coordinator::{assemble, run_on};
///
/// let mut cfg = ExperimentConfig::default();
/// cfg.backend = Backend::Native;
/// cfg.algo = AlgoKind::FdDsgd;   // → DsgdStrategy under the round engine
/// cfg.n = 4;
/// cfg.hidden = 8;
/// cfg.m = 4;
/// cfg.q = 2;
/// cfg.total_steps = 4;           // two communication rounds
/// cfg.records_per_hospital = 40;
/// let asm = assemble(&cfg).unwrap();
/// let log = run_on(&cfg, &asm).unwrap();
/// assert!(log.rows.last().unwrap().loss.is_finite());
/// ```
pub trait CommStrategy {
    /// Wire cost of one communication round (per-kind encoded sizes).
    fn cost(&self) -> CommCost;

    /// Pre-loop initialization (e.g. DSGT's Y⁰ = G⁰ = ∇g(θ⁰) on a fresh
    /// batch).  Default: nothing.
    fn init(&mut self, _st: &mut EngineState, _compute: &dyn Compute) -> Result<()> {
        Ok(())
    }

    /// Apply the communication update of round `round` (1-based) at learning
    /// rate `lr` over this round's network view, consuming one gradient per
    /// stack row.  The round index keys the deterministic compression
    /// streams (`compress::MsgKey`).
    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        round: usize,
        lr: f32,
    ) -> Result<()>;

    /// Full-shard metrics → (loss, accuracy, stationarity, consensus).
    /// Default: whole-stack eval over the training shards.
    fn eval(&self, st: &EngineState, compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        compute.eval_full(&st.theta, &st.shards)
    }

    /// Cumulative count of quarantined neighbor payloads (non-finite rows
    /// folded into the receiver's self-weight) across all rounds so far.
    /// Default 0 — only the gossip strategies can quarantine.
    fn quarantined(&self) -> u64 {
        0
    }
}

// --------------------------------------------------------------- DSGD ----

/// Eq. 2: `θ_i ← Σ_j w_ij θ_j − α ∇g_i(θ_i)` (covers DSGD and FD-DSGD —
/// the local period lives in the engine, not here; the round's `W` arrives
/// through [`RoundNet`]).  With a configured compressor the round runs the
/// difference-form update over the decoded stack (see the module docs).
pub struct DsgdStrategy {
    comm: GossipComm,
    msg_bytes: u64,
    /// Active adversary/DP pipeline (None on the pinned honest path).
    perturb: Option<MsgPerturb>,
    /// Cumulative quarantined-payload count (non-finite ingest guard).
    quarantined: u64,
}

impl DsgdStrategy {
    /// Build for parameter size `p` under the given compression context.
    pub fn new(comm: GossipComm, p: usize) -> Self {
        let msg_bytes = comm.msg_bytes(p);
        DsgdStrategy { comm, msg_bytes, perturb: None, quarantined: 0 }
    }

    /// Install a per-message perturbation pipeline (attack and/or DP).  The
    /// driver routes perturbed runs through the compressed path (installing
    /// an `Identity` compressor when none is configured) so the pipeline
    /// always sits at the encode boundary.
    pub fn with_perturb(mut self, perturb: Option<MsgPerturb>) -> Self {
        self.perturb = perturb;
        self
    }
}

impl CommStrategy for DsgdStrategy {
    fn cost(&self) -> CommCost {
        CommCost::Gossip { kinds: 1, kind_bytes: [self.msg_bytes, 0] }
    }

    fn eval(&self, st: &EngineState, compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        eval_honest_subset(
            self.perturb.as_ref().map(|pb| &pb.attack),
            &st.theta,
            &st.shards,
            st.p,
            compute,
        )
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        round: usize,
        lr: f32,
    ) -> Result<()> {
        // Every row draws its batch every round — the sampler streams stay
        // keyed by (seed, row) alone (§7), independent of the network plan;
        // offline rows discard theirs below.
        st.draw_comm_batches();
        if let Some(comp) = &self.comm.comp {
            let ef = self.comm.error_feedback;
            ef_compress_stack(
                comp.as_ref(),
                ef,
                self.comm.seed,
                round,
                PayloadKind::Params,
                &st.theta,
                net.online,
                st.p,
                &st.ef_theta,
                &mut st.ef_theta_back,
                &mut st.xhat,
                &mut st.vbuf,
                self.perturb.as_mut(),
            )?;
            if ef {
                std::mem::swap(&mut st.ef_theta, &mut st.ef_theta_back);
            }
            let q = quarantine_compact(net, &[&st.xhat], st.p)?;
            if let Some((_, d)) = &q {
                self.quarantined += d;
            }
            let mix =
                q.as_ref().map(|(wq, _)| MixView { dense: net.w, sparse: wq }).unwrap_or_else(
                    || net.mix(),
                );
            compute.dsgd_round_compressed_into(
                &mix,
                &st.xhat,
                &st.theta,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut st.comm_losses,
            )?;
        } else {
            ensure!(
                self.perturb.is_none(),
                "perturbation pipeline requires the encode path; the driver must \
                 install an Identity compressor for perturbed uncompressed runs"
            );
            let q = quarantine_compact(net, &[&st.theta], st.p)?;
            if let Some((_, d)) = &q {
                self.quarantined += d;
            }
            let mix =
                q.as_ref().map(|(wq, _)| MixView { dense: net.w, sparse: wq }).unwrap_or_else(
                    || net.mix(),
                );
            compute.dsgd_round_into(
                &mix,
                &st.theta,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut st.comm_losses,
            )?;
        }
        if !net.all_online() {
            restore_offline_rows(&mut st.theta_back, &st.theta, net.online, st.p);
        }
        if let Some(pb) = &self.perturb {
            restore_attacker_rows(&mut st.theta_back, &st.theta, &pb.attack, st.p);
        }
        std::mem::swap(&mut st.theta, &mut st.theta_back);
        Ok(())
    }

    fn quarantined(&self) -> u64 {
        self.quarantined
    }
}

// --------------------------------------------------------------- DSGT ----

/// Eq. 3 with gradient tracking: mixes θ and the tracker ϑ, then refreshes
/// the tracker with the gradient difference (covers DSGT and FD-DSGT).
/// Offline rounds leave a node's θ, ϑ, and G untouched.  The tracker and
/// gradient stacks are double-buffered like the engine's θ stack, so a
/// steady-state round allocates nothing.  Under compression both payload
/// streams (θ and ϑ) are encoded independently, each with its own
/// `(seed, round, node, kind)` noise stream, difference-form correction,
/// and (when EF is opted in) residual slabs.
pub struct DsgtStrategy {
    /// Tracker stack Y `[n, p]` + its back buffer.
    y: Vec<f32>,
    y_back: Vec<f32>,
    /// Previous-gradient stack G `[n, p]` + its back buffer.
    g: Vec<f32>,
    g_back: Vec<f32>,
    /// Decoded tracker stack Ŷ `[n, p]` (compressed runs only).
    yhat: Vec<f32>,
    /// Tracker-stream EF residuals + back buffer (compressed + EF only).
    ef_y: Vec<f32>,
    ef_y_back: Vec<f32>,
    comm: GossipComm,
    msg_bytes: u64,
    /// Active adversary/DP pipeline (None on the pinned honest path).
    perturb: Option<MsgPerturb>,
    /// Cumulative quarantined-payload count (non-finite ingest guard).
    quarantined: u64,
}

impl DsgtStrategy {
    /// Build for parameter size `p` under the given compression context.
    pub fn new(comm: GossipComm, p: usize) -> Self {
        let msg_bytes = comm.msg_bytes(p);
        DsgtStrategy {
            y: Vec::new(),
            y_back: Vec::new(),
            g: Vec::new(),
            g_back: Vec::new(),
            yhat: Vec::new(),
            ef_y: Vec::new(),
            ef_y_back: Vec::new(),
            comm,
            msg_bytes,
            perturb: None,
            quarantined: 0,
        }
    }

    /// Install a per-message perturbation pipeline (attack and/or DP); see
    /// [`DsgdStrategy::with_perturb`].  Both payload streams (θ and ϑ) run
    /// through the pipeline, each under its own kind-keyed noise stream.
    pub fn with_perturb(mut self, perturb: Option<MsgPerturb>) -> Self {
        self.perturb = perturb;
        self
    }
}

impl CommStrategy for DsgtStrategy {
    fn cost(&self) -> CommCost {
        // θ and ϑ, each charged at its own encoded size
        CommCost::Gossip { kinds: 2, kind_bytes: [self.msg_bytes, self.msg_bytes] }
    }

    fn eval(&self, st: &EngineState, compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        eval_honest_subset(
            self.perturb.as_ref().map(|pb| &pb.attack),
            &st.theta,
            &st.shards,
            st.p,
            compute,
        )
    }

    fn init(&mut self, st: &mut EngineState, compute: &dyn Compute) -> Result<()> {
        st.draw_comm_batches();
        let (n, p) = (st.n, st.p);
        let mut g0 = vec![0.0f32; n * p];
        for i in 0..n {
            let (bx, by) = st.comm_batch(i);
            let (_, gi) = compute.grad_step(st.theta_row(i), bx, by)?;
            g0[i * p..(i + 1) * p].copy_from_slice(&gi);
        }
        self.y = g0.clone();
        self.g = g0;
        self.y_back = vec![0.0f32; n * p];
        self.g_back = vec![0.0f32; n * p];
        if self.comm.enabled() {
            self.yhat = vec![0.0f32; n * p];
            if self.comm.error_feedback {
                self.ef_y = vec![0.0f32; n * p];
                self.ef_y_back = vec![0.0f32; n * p];
            }
        }
        Ok(())
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        net: &RoundNet,
        round: usize,
        lr: f32,
    ) -> Result<()> {
        st.draw_comm_batches();
        if let Some(comp) = &self.comm.comp {
            let ef = self.comm.error_feedback;
            ef_compress_stack(
                comp.as_ref(),
                ef,
                self.comm.seed,
                round,
                PayloadKind::Params,
                &st.theta,
                net.online,
                st.p,
                &st.ef_theta,
                &mut st.ef_theta_back,
                &mut st.xhat,
                &mut st.vbuf,
                self.perturb.as_mut(),
            )?;
            ef_compress_stack(
                comp.as_ref(),
                ef,
                self.comm.seed,
                round,
                PayloadKind::Tracker,
                &self.y,
                net.online,
                st.p,
                &self.ef_y,
                &mut self.ef_y_back,
                &mut self.yhat,
                &mut st.vbuf,
                self.perturb.as_mut(),
            )?;
            if ef {
                std::mem::swap(&mut st.ef_theta, &mut st.ef_theta_back);
                std::mem::swap(&mut self.ef_y, &mut self.ef_y_back);
            }
            let q = quarantine_compact(net, &[&st.xhat, &self.yhat], st.p)?;
            if let Some((_, d)) = &q {
                self.quarantined += d;
            }
            let mix =
                q.as_ref().map(|(wq, _)| MixView { dense: net.w, sparse: wq }).unwrap_or_else(
                    || net.mix(),
                );
            compute.dsgt_round_compressed_into(
                &mix,
                &st.xhat,
                &self.yhat,
                &st.theta,
                &self.y,
                &self.g,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut self.y_back,
                &mut self.g_back,
                &mut st.comm_losses,
            )?;
        } else {
            ensure!(
                self.perturb.is_none(),
                "perturbation pipeline requires the encode path; the driver must \
                 install an Identity compressor for perturbed uncompressed runs"
            );
            let q = quarantine_compact(net, &[&st.theta, &self.y], st.p)?;
            if let Some((_, d)) = &q {
                self.quarantined += d;
            }
            let mix =
                q.as_ref().map(|(wq, _)| MixView { dense: net.w, sparse: wq }).unwrap_or_else(
                    || net.mix(),
                );
            compute.dsgt_round_into(
                &mix,
                &st.theta,
                &self.y,
                &self.g,
                &st.cx,
                &st.cy,
                lr,
                &mut st.theta_back,
                &mut self.y_back,
                &mut self.g_back,
                &mut st.comm_losses,
            )?;
        }
        if !net.all_online() {
            restore_offline_rows(&mut st.theta_back, &st.theta, net.online, st.p);
            restore_offline_rows(&mut self.y_back, &self.y, net.online, st.p);
            restore_offline_rows(&mut self.g_back, &self.g, net.online, st.p);
        }
        if let Some(pb) = &self.perturb {
            restore_attacker_rows(&mut st.theta_back, &st.theta, &pb.attack, st.p);
            restore_attacker_rows(&mut self.y_back, &self.y, &pb.attack, st.p);
            restore_attacker_rows(&mut self.g_back, &self.g, &pb.attack, st.p);
        }
        std::mem::swap(&mut st.theta, &mut st.theta_back);
        std::mem::swap(&mut self.y, &mut self.y_back);
        std::mem::swap(&mut self.g, &mut self.g_back);
        Ok(())
    }

    fn quarantined(&self) -> u64 {
        self.quarantined
    }
}

// ------------------------------------------------------------- FedAvg ----

/// Star-network FedAvg (McMahan et al., 2017): the engine's local phase runs
/// every client from the server parameters (all stack rows are identical
/// after each round); this update takes the final local gradient and
/// replaces every row with the client average.
pub struct FedAvgStrategy;

impl FedAvgStrategy {
    /// The (stateless) FedAvg update.
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        FedAvgStrategy
    }
}

impl CommStrategy for FedAvgStrategy {
    fn cost(&self) -> CommCost {
        CommCost::Star
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        _net: &RoundNet,
        _round: usize,
        lr: f32,
    ) -> Result<()> {
        let (n, p) = (st.n, st.p);
        let mut mean = vec![0.0f64; p];
        for i in 0..n {
            // final local step of the round (keeps total gradient count = Q)
            {
                let (m, d) = (st.m, st.d);
                let shard = &st.shards[i];
                st.samplers[i].batch(
                    shard,
                    &mut st.cx[i * m * d..(i + 1) * m * d],
                    &mut st.cy[i * m..(i + 1) * m],
                );
            }
            let (bx, by) = st.comm_batch(i);
            let (_, grad) = compute.grad_step(st.theta_row(i), bx, by)?;
            let row = &mut st.theta[i * p..(i + 1) * p];
            axpy(row, -lr, &grad);
            for (acc, &t) in mean.iter_mut().zip(row.iter()) {
                *acc += t as f64;
            }
        }
        let server: Vec<f32> = mean.into_iter().map(|acc| (acc / n as f64) as f32).collect();
        for i in 0..n {
            st.theta[i * p..(i + 1) * p].copy_from_slice(&server);
        }
        Ok(())
    }
}

// -------------------------------------------------------- centralized ----

/// The fictitious fusion center the paper argues is infeasible: plain SGD
/// on the pooled cohort.  One stack row, no communication; the engine's
/// round axis advances every Q steps so curves align with FD runs.
pub struct CentralizedStrategy {
    /// Native twin for metrics — the pooled shard does not match the AOT
    /// artifacts' per-hospital eval shapes, so eval runs in-process.
    model: NativeModel,
}

impl CentralizedStrategy {
    /// Fusion-center SGD evaluated through the given native twin.
    pub fn new(model: NativeModel) -> Self {
        CentralizedStrategy { model }
    }
}

impl CommStrategy for CentralizedStrategy {
    fn cost(&self) -> CommCost {
        CommCost::None
    }

    fn comm_update(
        &mut self,
        st: &mut EngineState,
        compute: &dyn Compute,
        _net: &RoundNet,
        _round: usize,
        lr: f32,
    ) -> Result<()> {
        st.draw_comm_batches();
        let (bx, by) = st.comm_batch(0);
        let (_, grad) = compute.grad_step(&st.theta, bx, by)?;
        axpy(&mut st.theta, -lr, &grad);
        Ok(())
    }

    fn eval(&self, st: &EngineState, _compute: &dyn Compute) -> Result<(f64, f64, f64, f64)> {
        Ok(self.model.eval_full(&st.theta, &st.shards))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Spec;

    #[test]
    fn costs_match_payload_kinds_at_encoded_sizes() {
        let p = 100usize;
        let dsgd = DsgdStrategy::new(GossipComm::none(0), p);
        assert_eq!(dsgd.cost(), CommCost::Gossip { kinds: 1, kind_bytes: [400, 0] });
        let dsgt = DsgtStrategy::new(GossipComm::none(0), p);
        assert_eq!(dsgt.cost(), CommCost::Gossip { kinds: 2, kind_bytes: [400, 400] });
        assert_eq!(FedAvgStrategy::new().cost(), CommCost::Star);
        assert_eq!(CentralizedStrategy::new(NativeModel::new(4, 2)).cost(), CommCost::None);
        // compressed strategies charge the encoded wire size per kind
        let q4 = GossipComm { comp: Spec::Q4.build(), error_feedback: true, seed: 0 };
        let dsgd_q4 = DsgdStrategy::new(q4, p);
        assert_eq!(dsgd_q4.cost(), CommCost::Gossip { kinds: 1, kind_bytes: [54, 0] });
        let tk = GossipComm {
            comp: Spec::TopK { frac: 0.1 }.build(),
            error_feedback: true,
            seed: 0,
        };
        let dsgt_tk = DsgtStrategy::new(tk, p);
        assert_eq!(dsgt_tk.cost(), CommCost::Gossip { kinds: 2, kind_bytes: [80, 80] });
    }
}
