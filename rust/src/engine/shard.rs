//! Sharded node state — 10⁵–10⁶-node fleets without co-resident slabs.
//!
//! PR 6 made the *graph* axis sparse-native, but training state (θ, the
//! DSGT tracker ϑ and gradient stacks) was still one flat resident array
//! per quantity, so fleet size was capped by RAM long before the algorithm
//! was.  This module shards the per-node quantity slabs into fixed-size
//! node blocks backed by a spill file, keeps an LRU hot-set of
//! [`ExperimentConfig::hot_shards`] blocks resident, and sweeps a
//! communication round shard-by-shard in CSR-block order: each shard's pass
//! gathers a compact stack of its own rows plus the halo rows its cut edges
//! reference (a boundary exchange over the spill file — halo reads never
//! load a shard), remaps the CSR columns onto that stack *preserving entry
//! order*, and runs the exact per-node kernels the resident driver fans out
//! (`NativeModel::{local_steps_into, dsgd_node_into, dsgt_node_into}`).
//!
//! Bitwise contract (pinned by `tests/shard_pins.rs`): because
//! `combine_sparse_into` folds its f64 accumulator in CSR **entry order**
//! and the remap is order-preserving, because the per-node sampler streams
//! are `(seed, node)`-keyed and therefore shard-oblivious, and because
//! evaluation is the same [`crate::metrics::StreamingEval`] left fold the
//! resident `eval_reduce` runs, the sharded trajectory is bitwise identical
//! to the resident fused driver at every shard count — 1 shard == k shards
//! == unsharded.  The default (`state.shard_nodes = 0`) never constructs
//! this driver at all, so the resident path stays byte-for-byte untouched.
//!
//! Scope: the sharded driver covers the honest gossip matrix — native
//! backend, fused sync driver, mean combine, no compression, no
//! attack/DP, uniform compute plan — under **any** network plan
//! (static/rewire/edge-drop/churn).  Everything else bails loudly
//! (DESIGN.md §15 has the full matrix and the rationale: those axes keep
//! per-node side state whose residency is exactly what this module exists
//! to avoid co-locating; they stay on the resident drivers).  Honest
//! convergent runs never trip the non-finite quarantine scan, so the sweep
//! skips it (§15).  Per-node samplers stay resident: their state is O(1)
//! plus a lazily grown index permutation — orders of magnitude below one
//! parameter row.

use crate::algo::native::{NativeModel, Workspace};
use crate::algo::RoundPlan;
use crate::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use crate::coordinator::sampler::{init_theta, NodeSampler};
use crate::data::{FederatedDataset, Shard};
use crate::graph::{Graph, NetworkSchedule, ViewScratch};
use crate::metrics::{round_metrics, RunLog, StreamingEval};
use crate::mixing::SparseW;
use crate::netsim::{analytic::Accountant, LinkModel};
use anyhow::{bail, Result};
use std::os::unix::fs::FileExt;
use std::sync::atomic::{AtomicU64, Ordering};

// ------------------------------------------------------------ layout ----

/// Logical quantity slots in a [`NodeSlabPool`].  Front/back pairs swap via
/// the pool's quantity map — no data movement, exactly like the resident
/// driver's `std::mem::swap` of whole stacks.
pub mod quantity {
    /// Parameters θ (front).
    pub const THETA: usize = 0;
    /// Parameters θ (back buffer).
    pub const THETA_BACK: usize = 1;
    /// DSGT tracker ϑ (front).
    pub const Y: usize = 2;
    /// DSGT tracker ϑ (back buffer).
    pub const Y_BACK: usize = 3;
    /// DSGT previous gradient G (front).
    pub const G: usize = 4;
    /// DSGT previous gradient G (back buffer).
    pub const G_BACK: usize = 5;
}

/// Fixed-size partition of `n` nodes into shards of `shard_nodes` rows
/// (the last shard may be partial).
#[derive(Clone, Copy, Debug)]
pub struct ShardSpec {
    /// Fleet size.
    pub n: usize,
    /// Nodes per shard.
    pub shard_nodes: usize,
}

impl ShardSpec {
    /// Number of shards covering the fleet.
    pub fn n_shards(&self) -> usize {
        self.n.div_ceil(self.shard_nodes)
    }

    /// Shard owning `node`.
    pub fn shard_of(&self, node: usize) -> usize {
        node / self.shard_nodes
    }

    /// Node range `[start, end)` of shard `s`.
    pub fn range(&self, s: usize) -> (usize, usize) {
        let start = s * self.shard_nodes;
        (start, ((s + 1) * self.shard_nodes).min(self.n))
    }
}

// -------------------------------------------------------------- pool ----

/// Counters a [`NodeSlabPool`] keeps about its own traffic, for benches,
/// the EXP-SH1 experiment, and the hot-set-bound tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Shard loads from the spill file (cold acquires).
    pub loads: u64,
    /// Dirty-frame writebacks to the spill file (evictions).
    pub spills: u64,
    /// Acquires served by a resident frame.
    pub hits: u64,
}

/// One resident shard frame: `shard_nodes · nq · p` floats.
struct Frame {
    /// Which shard this frame holds (`usize::MAX` = empty).
    shard: usize,
    /// LRU clock value of the last acquire.
    last_use: u64,
    /// Frame has row writes the spill file hasn't seen.
    dirty: bool,
    data: Vec<f32>,
}

static POOL_ID: AtomicU64 = AtomicU64::new(0);

/// Spill-file-backed pool of per-node quantity slabs with an LRU hot-set.
///
/// Layout: node-major, quantity-minor — node `i`'s `nq` rows of `p` floats
/// are contiguous in its shard frame and at the mirrored offset in the
/// spill file, so one shard is one contiguous file extent.  The file is
/// created sparse (`set_len`) in the system temp directory, so untouched
/// shards cost no disk, and it is removed on drop.  Front/back quantity
/// swaps go through a logical→physical quantity map ([`Self::swap_quantities`]):
/// a swap is two index writes, never a data move.
///
/// All frames are allocated up front, file I/O goes through preallocated
/// byte buffers (`read_at`/`write_at`, little-endian f32), and the row
/// accessors copy through caller buffers — warm sweeps allocate nothing
/// (`tests/alloc_free.rs` pins this with a counting allocator).
pub struct NodeSlabPool {
    spec: ShardSpec,
    /// Parameter row length.
    p: usize,
    /// Quantity rows per node.
    nq: usize,
    /// Logical quantity → physical slot.
    qmap: Vec<usize>,
    frames: Vec<Frame>,
    /// shard → resident frame index.
    map: Vec<Option<usize>>,
    tick: u64,
    file: std::fs::File,
    path: std::path::PathBuf,
    /// Whole-frame I/O staging (`frame_len · 4` bytes).
    io_buf: Vec<u8>,
    /// Single-row I/O staging (`p · 4` bytes) for halo reads.
    row_buf: Vec<u8>,
    stats: PoolStats,
}

impl NodeSlabPool {
    /// Create a pool for `n` nodes in shards of `shard_nodes`, keeping at
    /// most `hot_shards` frames resident, with `nq` quantity rows of `p`
    /// floats per node.  The spill file starts all-zero (sparse).
    pub fn new(n: usize, shard_nodes: usize, hot_shards: usize, p: usize, nq: usize) -> Result<Self> {
        if n == 0 || shard_nodes == 0 || hot_shards == 0 || p == 0 || nq == 0 {
            bail!("NodeSlabPool: n, shard_nodes, hot_shards, p, nq must all be positive");
        }
        let spec = ShardSpec { n, shard_nodes };
        let n_shards = spec.n_shards();
        let frame_len = shard_nodes * nq * p;
        let path = std::env::temp_dir().join(format!(
            "decfl_slab_{}_{}.bin",
            std::process::id(),
            POOL_ID.fetch_add(1, Ordering::Relaxed)
        ));
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)?;
        file.set_len((n_shards * frame_len * 4) as u64)?;
        let frames = (0..hot_shards.min(n_shards))
            .map(|_| Frame {
                shard: usize::MAX,
                last_use: 0,
                dirty: false,
                data: vec![0.0f32; frame_len],
            })
            .collect();
        Ok(NodeSlabPool {
            spec,
            p,
            nq,
            qmap: (0..nq).collect(),
            frames,
            map: vec![None; n_shards],
            tick: 0,
            file,
            path,
            io_buf: vec![0u8; frame_len * 4],
            row_buf: vec![0u8; p * 4],
            stats: PoolStats::default(),
        })
    }

    /// The node→shard partition.
    pub fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    /// Traffic counters so far.
    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Currently resident slab rows (node rows with ≥ 1 quantity in RAM) —
    /// bounded by `hot_shards · shard_nodes` by construction; the
    /// `alloc_free` test pins this.
    pub fn resident_rows(&self) -> usize {
        self.frames.iter().filter(|f| f.shard != usize::MAX).count() * self.spec.shard_nodes
    }

    /// Float offset of `(slot, quantity)` inside a frame / shard extent.
    fn offset(&self, slot: usize, q: usize) -> usize {
        (slot * self.nq + self.qmap[q]) * self.p
    }

    fn frame_len(&self) -> usize {
        self.spec.shard_nodes * self.nq * self.p
    }

    /// Make `shard` resident (LRU-evicting if needed) and return its frame.
    fn acquire(&mut self, shard: usize) -> Result<usize> {
        self.tick += 1;
        if let Some(fi) = self.map[shard] {
            self.frames[fi].last_use = self.tick;
            self.stats.hits += 1;
            return Ok(fi);
        }
        // victim: an empty frame if any, else the least recently used
        let fi = self
            .frames
            .iter()
            .enumerate()
            .min_by_key(|(_, f)| if f.shard == usize::MAX { (0, 0) } else { (1, f.last_use) })
            .map(|(i, _)| i)
            .expect("pool holds at least one frame");
        let old = self.frames[fi].shard;
        if old != usize::MAX {
            if self.frames[fi].dirty {
                self.write_frame(fi)?;
                self.stats.spills += 1;
            }
            self.map[old] = None;
        }
        self.read_frame(fi, shard)?;
        self.stats.loads += 1;
        let f = &mut self.frames[fi];
        f.shard = shard;
        f.dirty = false;
        f.last_use = self.tick;
        self.map[shard] = Some(fi);
        Ok(fi)
    }

    fn write_frame(&mut self, fi: usize) -> Result<()> {
        let frame_len = self.frame_len();
        let Self { frames, io_buf, file, .. } = self;
        let f = &frames[fi];
        for (b, v) in io_buf.chunks_exact_mut(4).zip(&f.data) {
            b.copy_from_slice(&v.to_le_bytes());
        }
        file.write_all_at(io_buf, (f.shard * frame_len * 4) as u64)?;
        Ok(())
    }

    fn read_frame(&mut self, fi: usize, shard: usize) -> Result<()> {
        let frame_len = self.frame_len();
        let Self { frames, io_buf, file, .. } = self;
        file.read_exact_at(io_buf, (shard * frame_len * 4) as u64)?;
        for (v, b) in frames[fi].data.iter_mut().zip(io_buf.chunks_exact(4)) {
            *v = f32::from_le_bytes(b.try_into().expect("4-byte chunk"));
        }
        Ok(())
    }

    /// Copy quantity `q` of `node` into `out` — from the resident frame if
    /// the owning shard is hot, else straight from the spill file *without*
    /// loading the shard (this is the halo gather: boundary rows of other
    /// shards are read, never made resident).
    pub fn read_row_into(&mut self, node: usize, q: usize, out: &mut [f32]) -> Result<()> {
        debug_assert_eq!(out.len(), self.p);
        let shard = self.spec.shard_of(node);
        let slot = node % self.spec.shard_nodes;
        let off = self.offset(slot, q);
        if let Some(fi) = self.map[shard] {
            out.copy_from_slice(&self.frames[fi].data[off..off + self.p]);
            return Ok(());
        }
        let byte_off = ((shard * self.frame_len() + off) * 4) as u64;
        let Self { file, row_buf, .. } = self;
        file.read_exact_at(row_buf, byte_off)?;
        for (v, b) in out.iter_mut().zip(row_buf.chunks_exact(4)) {
            *v = f32::from_le_bytes(b.try_into().expect("4-byte chunk"));
        }
        Ok(())
    }

    /// Overwrite quantity `q` of `node`, making its shard resident first.
    pub fn write_row(&mut self, node: usize, q: usize, data: &[f32]) -> Result<()> {
        debug_assert_eq!(data.len(), self.p);
        let shard = self.spec.shard_of(node);
        let slot = node % self.spec.shard_nodes;
        let off = self.offset(slot, q);
        let fi = self.acquire(shard)?;
        let f = &mut self.frames[fi];
        f.data[off..off + self.p].copy_from_slice(data);
        f.dirty = true;
        Ok(())
    }

    /// Swap two logical quantities (e.g. θ front/back) across the WHOLE
    /// fleet — two index writes, no data movement, the sharded twin of the
    /// resident driver's stack swap.
    pub fn swap_quantities(&mut self, a: usize, b: usize) {
        self.qmap.swap(a, b);
    }
}

impl Drop for NodeSlabPool {
    fn drop(&mut self) {
        std::fs::remove_file(&self.path).ok();
    }
}

// ------------------------------------------------------------ driver ----

/// The honest-matrix axes the sharded driver refuses (loudly): each keeps
/// per-node side state whose residency is the very thing sharding avoids.
fn reject_unsupported(cfg: &ExperimentConfig) -> Result<()> {
    if !matches!(
        cfg.algo,
        AlgoKind::Dsgd | AlgoKind::Dsgt | AlgoKind::FdDsgd | AlgoKind::FdDsgt
    ) {
        bail!(
            "state.shard_nodes applies to gossip algorithms (dsgd|dsgt|fd-dsgd|fd-dsgt); \
             `{}` has no per-node gossip state to shard",
            cfg.algo.name()
        );
    }
    if cfg.backend != Backend::Native {
        bail!(
            "state.shard_nodes requires --backend native: the PJRT artifacts are lowered \
             for whole-stack calls and would need the full θ stack resident anyway"
        );
    }
    if cfg.mode != Mode::Fused || cfg.driver != "sync" {
        bail!(
            "state.shard_nodes requires the fused sync driver (--mode fused, run.driver \
             sync): the actor and async drivers keep per-node inbox state resident by \
             construction; drop --shard-nodes or switch drivers"
        );
    }
    if cfg.compress != "none" {
        bail!(
            "compress `{}` requested with state.shard_nodes: compression carries decoded \
             and error-feedback slabs the sharded sweep does not partition yet; drop one",
            cfg.compress
        );
    }
    if crate::engine::adversary::perturb_active(cfg) || cfg.robust_rule != "mean" {
        bail!(
            "adversarial settings (attack.plan={}, robust.rule={}, dp={}) requested with \
             state.shard_nodes: the adversarial axis runs on the resident drivers; drop one",
            cfg.attack_plan,
            cfg.robust_rule,
            cfg.dp
        );
    }
    if cfg.compute_plan != "uniform" {
        bail!(
            "compute plan `{}` requested with state.shard_nodes: straggler plans carry \
             per-round τ slabs on the resident drivers; drop one",
            cfg.compute_plan
        );
    }
    if cfg.drop_prob > 0.0 {
        bail!(
            "drop_prob={} requested, but sharded execution charges communication \
             analytically over lossless links; use `--mode actors` for loss injection",
            cfg.drop_prob
        );
    }
    Ok(())
}

/// Sharded synchronous gossip driver — implements [`super::Driver`] so
/// [`super::RoundEngine::run`] drives it with the exact round structure of
/// the resident paths, but every phase is a shard sweep over a
/// [`NodeSlabPool`] instead of a whole-stack call.  Serial by design: the
/// sweep is I/O-shaped, and serial per-node kernels are bitwise identical
/// to the resident parallel fan-out at every thread count anyway.
pub struct ShardedSync<'a> {
    model: NativeModel,
    dsgt: bool,
    pool: NodeSlabPool,
    samplers: Vec<NodeSampler>,
    shards: &'a [Shard],
    n: usize,
    p: usize,
    local: usize,
    compute_s_per_step: f64,
    // per-round network view (mirrors SyncDriver::refresh_net)
    net: NetworkSchedule,
    scratch: ViewScratch,
    wsp: SparseW,
    online: Vec<bool>,
    round_edges: u64,
    net_key: Option<u64>,
    acct: Accountant,
    // sweep scratch, all grow-only: warm rounds allocate nothing
    ws: Workspace,
    lx: Vec<f32>,
    ly: Vec<f32>,
    cx: Vec<f32>,
    cy: Vec<f32>,
    step_losses: Vec<f64>,
    stack_t: Vec<f32>,
    stack_y: Vec<f32>,
    ridx: Vec<u32>,
    roff: Vec<usize>,
    /// Global→compact-stack column map, `u32::MAX` = unmapped.  O(n) at 4
    /// bytes/node (4 MB at 10⁶) — the one full-fleet array the sweep keeps,
    /// reset per shard via the halo list rather than a full clear.
    g2l: Vec<u32>,
    halo: Vec<u32>,
    t_out: Vec<f32>,
    y_out: Vec<f32>,
    g_out: Vec<f32>,
    g_row: Vec<f32>,
    log: RunLog,
    started: std::time::Instant,
}

impl<'a> ShardedSync<'a> {
    /// Build the sharded driver for an honest gossip config with
    /// `cfg.shard_nodes > 0`.  Seeds θ row-by-row through the pool — the
    /// full stack is never materialized.
    pub fn new(
        cfg: &ExperimentConfig,
        ds: &'a FederatedDataset,
        graph: &Graph,
        w: &SparseW,
    ) -> Result<Self> {
        reject_unsupported(cfg)?;
        if cfg.d != ds.d {
            bail!("config d={} vs dataset d={}", cfg.d, ds.d);
        }
        if cfg.shard_nodes == 0 {
            bail!("ShardedSync requires state.shard_nodes > 0 (0 = resident path)");
        }
        let n = ds.n_hospitals();
        let model = NativeModel::new(cfg.d, cfg.hidden);
        let p = model.p();
        let dsgt = matches!(cfg.algo, AlgoKind::Dsgt | AlgoKind::FdDsgt);
        let nq = if dsgt { 6 } else { 2 };
        let mut pool =
            NodeSlabPool::new(n, cfg.shard_nodes.min(n), cfg.hot_shards, p, nq)?;
        for i in 0..n {
            let row = init_theta(cfg.seed, i, &model);
            pool.write_row(i, quantity::THETA, &row)?;
        }
        let net = NetworkSchedule::from_config(cfg, graph.clone(), w.clone())?;
        let local = RoundPlan::new(cfg.algo.effective_q(cfg.q)).local_per_round;
        let link = LinkModel {
            latency_s: cfg.latency_s,
            bandwidth_bps: cfg.bandwidth_bps,
            drop_prob: 0.0,
        };
        let (m, d) = (cfg.m, cfg.d);
        Ok(ShardedSync {
            model,
            dsgt,
            pool,
            samplers: (0..n).map(|i| NodeSampler::new(cfg.seed, i, m)).collect(),
            shards: &ds.shards[..],
            n,
            p,
            local,
            compute_s_per_step: cfg.compute_s_per_step,
            net,
            scratch: ViewScratch::new(),
            wsp: SparseW::empty(),
            online: vec![true; n],
            round_edges: 0,
            net_key: None,
            acct: Accountant::new(link),
            ws: Workspace::new(),
            lx: vec![0.0f32; local * m * d],
            ly: vec![0.0f32; local * m],
            cx: vec![0.0f32; m * d],
            cy: vec![0.0f32; m],
            step_losses: vec![0.0f64; local],
            stack_t: Vec::new(),
            stack_y: Vec::new(),
            ridx: Vec::new(),
            roff: Vec::new(),
            g2l: vec![u32::MAX; n],
            halo: Vec::new(),
            t_out: vec![0.0f32; p],
            y_out: vec![0.0f32; if dsgt { p } else { 0 }],
            g_out: vec![0.0f32; if dsgt { p } else { 0 }],
            g_row: vec![0.0f32; if dsgt { p } else { 0 }],
            log: RunLog::new(cfg.algo.name()),
            started: std::time::Instant::now(),
        })
    }

    /// Per-round network view refresh — the same key-cached, grow-only
    /// materialization as the resident sync driver (no dense scatter: the
    /// sweep is CSR-native at any n).
    fn refresh_net(&mut self, round: usize) -> Result<()> {
        let key = self.net.view_key(round);
        if self.net_key == Some(key) {
            return Ok(());
        }
        self.wsp.reserve_rows_nnz(self.net.n(), self.net.base_nnz());
        let view = self.net.view_into(round, &mut self.scratch)?;
        self.wsp.copy_from(view.w);
        self.round_edges = view.active_directed_edges();
        self.online.clear();
        self.online.extend_from_slice(view.online);
        self.net_key = Some(key);
        Ok(())
    }

    /// Build the compact gather for shard `s`: own rows map to `[0,
    /// own_len)`, halo columns (cut-edge endpoints of *online* own rows) to
    /// `[own_len, ..)` in first-appearance order, and `ridx`/`roff` hold
    /// the entry-order-preserving CSR remap per own row.
    fn build_halo(&mut self, s0: usize, s1: usize) {
        let own_len = s1 - s0;
        self.halo.clear();
        self.ridx.clear();
        self.roff.clear();
        for (k, v) in self.g2l[s0..s1].iter_mut().enumerate() {
            *v = k as u32;
        }
        for i in s0..s1 {
            self.roff.push(self.ridx.len());
            if !self.online[i] {
                continue; // kernel skipped; empty remap range
            }
            let (idx, _) = self.wsp.row(i);
            for &c in idx {
                let cu = c as usize;
                if self.g2l[cu] == u32::MAX {
                    self.g2l[cu] = (own_len + self.halo.len()) as u32;
                    self.halo.push(c);
                }
                self.ridx.push(self.g2l[cu]);
            }
        }
        self.roff.push(self.ridx.len());
    }

    /// Undo [`Self::build_halo`]'s map entries (sentinel reset via the halo
    /// list — never a full O(n) clear).
    fn reset_halo(&mut self, s0: usize, s1: usize) {
        self.g2l[s0..s1].fill(u32::MAX);
        for &j in &self.halo {
            self.g2l[j as usize] = u32::MAX;
        }
    }

    /// Pool traffic counters (benches / EXP-SH1).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Currently resident slab rows — the hot-set bound under test.
    pub fn resident_rows(&self) -> usize {
        self.pool.resident_rows()
    }

    /// Consume the driver into its metric log (the scale path: θ is never
    /// materialized).
    pub fn into_log(self) -> RunLog {
        self.log
    }

    /// Consume the driver into (log, final θ stack) — small-n use only;
    /// this is the one call that materializes `n · p` floats.
    pub fn into_result(mut self) -> Result<(RunLog, Vec<f32>)> {
        let (n, p) = (self.n, self.p);
        let mut theta = vec![0.0f32; n * p];
        for i in 0..n {
            self.pool.read_row_into(i, quantity::THETA, &mut theta[i * p..(i + 1) * p])?;
        }
        Ok((self.log, theta))
    }
}

/// Gather quantity `q` rows for shard `[s0, s1)`'s compact stack
/// `[own rows; halo rows]` into `stack` (grow-only buffer).  Free function
/// so the caller can borrow the pool, the halo list, and the stack buffer
/// as disjoint fields.
fn gather_stack(
    pool: &mut NodeSlabPool,
    halo: &[u32],
    s0: usize,
    s1: usize,
    q: usize,
    p: usize,
    stack: &mut Vec<f32>,
) -> Result<()> {
    let own_len = s1 - s0;
    let need = (own_len + halo.len()) * p;
    if stack.len() < need {
        stack.resize(need, 0.0);
    }
    for i in s0..s1 {
        let li = i - s0;
        pool.read_row_into(i, q, &mut stack[li * p..(li + 1) * p])?;
    }
    for (k, &j) in halo.iter().enumerate() {
        let li = own_len + k;
        pool.read_row_into(j as usize, q, &mut stack[li * p..(li + 1) * p])?;
    }
    Ok(())
}

impl super::Driver for ShardedSync<'_> {
    fn begin(&mut self) -> Result<()> {
        if self.dsgt {
            // DSGT init sweep: Y⁰ = G⁰ = ∇g(θ⁰) on one fresh comm batch per
            // node — the same (seed, node)-keyed draw the resident
            // `DsgtStrategy::init` makes, in the same per-node stream order
            let spec = *self.pool.spec();
            for s in 0..spec.n_shards() {
                let (s0, s1) = spec.range(s);
                for i in s0..s1 {
                    self.samplers[i].batch(&self.shards[i], &mut self.cx, &mut self.cy);
                    self.pool.read_row_into(i, quantity::THETA, &mut self.t_out)?;
                    let (_, gi) = self.model.loss_and_grad(&self.t_out, &self.cx, &self.cy);
                    self.pool.write_row(i, quantity::Y, &gi)?;
                    self.pool.write_row(i, quantity::G, &gi)?;
                }
            }
        }
        self.observe(0, 0)
    }

    fn local_phase(&mut self, _round: usize, lrs: &[f32]) -> Result<()> {
        let spec = *self.pool.spec();
        let local = lrs.len();
        for s in 0..spec.n_shards() {
            let (s0, s1) = spec.range(s);
            for i in s0..s1 {
                // per-node streams are independent, so drawing node-by-node
                // inside the shard sweep yields the identical batches the
                // resident whole-fleet draw loop does
                self.samplers[i].batches(&self.shards[i], local, &mut self.lx, &mut self.ly);
                self.pool.read_row_into(i, quantity::THETA, &mut self.t_out)?;
                self.model.local_steps_into(
                    &mut self.t_out,
                    &self.lx,
                    &self.ly,
                    lrs,
                    &mut self.step_losses[..local],
                    &mut self.ws,
                );
                // local steps touch no cross-node state: the in-place front
                // write equals the resident back-buffer write + swap
                self.pool.write_row(i, quantity::THETA, &self.t_out)?;
            }
        }
        self.acct.local_compute(local as u64, self.compute_s_per_step);
        Ok(())
    }

    fn comm_phase(&mut self, round: usize, lr: f32) -> Result<()> {
        self.refresh_net(round)?;
        let spec = *self.pool.spec();
        let p = self.p;
        for s in 0..spec.n_shards() {
            let (s0, s1) = spec.range(s);
            self.build_halo(s0, s1);
            gather_stack(
                &mut self.pool,
                &self.halo,
                s0,
                s1,
                quantity::THETA,
                p,
                &mut self.stack_t,
            )?;
            if self.dsgt {
                gather_stack(
                    &mut self.pool,
                    &self.halo,
                    s0,
                    s1,
                    quantity::Y,
                    p,
                    &mut self.stack_y,
                )?;
            }
            for i in s0..s1 {
                let li = i - s0;
                // every row draws its batch every round — (seed, node)-keyed
                // streams stay plan- and shard-independent; offline rows
                // discard theirs, exactly like the resident strategies
                self.samplers[i].batch(&self.shards[i], &mut self.cx, &mut self.cy);
                if !self.online[i] {
                    // offline: next = previous (the resident
                    // restore_offline_rows), for every front quantity
                    self.pool.read_row_into(i, quantity::THETA, &mut self.t_out)?;
                    self.pool.write_row(i, quantity::THETA_BACK, &self.t_out)?;
                    if self.dsgt {
                        self.pool.read_row_into(i, quantity::Y, &mut self.y_out)?;
                        self.pool.write_row(i, quantity::Y_BACK, &self.y_out)?;
                        self.pool.read_row_into(i, quantity::G, &mut self.g_out)?;
                        self.pool.write_row(i, quantity::G_BACK, &self.g_out)?;
                    }
                    continue;
                }
                let (idx, val) = self.wsp.row(i);
                let r = self.roff[li]..self.roff[li + 1];
                debug_assert_eq!(idx.len(), r.len());
                if self.dsgt {
                    self.pool.read_row_into(i, quantity::G, &mut self.g_row)?;
                    self.model.dsgt_node_into(
                        &self.ridx[r],
                        val,
                        &self.stack_t,
                        &self.stack_y,
                        &self.stack_y[li * p..(li + 1) * p],
                        &self.g_row,
                        &self.cx,
                        &self.cy,
                        lr,
                        &mut self.t_out,
                        &mut self.y_out,
                        &mut self.g_out,
                        &mut self.ws,
                    );
                    self.pool.write_row(i, quantity::THETA_BACK, &self.t_out)?;
                    self.pool.write_row(i, quantity::Y_BACK, &self.y_out)?;
                    self.pool.write_row(i, quantity::G_BACK, &self.g_out)?;
                } else {
                    self.model.dsgd_node_into(
                        &self.ridx[r],
                        val,
                        &self.stack_t,
                        &self.stack_t[li * p..(li + 1) * p],
                        &self.cx,
                        &self.cy,
                        lr,
                        &mut self.t_out,
                        &mut self.ws,
                    );
                    self.pool.write_row(i, quantity::THETA_BACK, &self.t_out)?;
                }
            }
            self.reset_halo(s0, s1);
        }
        self.pool.swap_quantities(quantity::THETA, quantity::THETA_BACK);
        if self.dsgt {
            self.pool.swap_quantities(quantity::Y, quantity::Y_BACK);
            self.pool.swap_quantities(quantity::G, quantity::G_BACK);
        }
        // analytic accounting, byte-for-byte the resident fused charges:
        // one comm gradient of compute, then per kind (θ; DSGT adds ϑ) one
        // dense-f32 message per active directed edge
        self.acct.local_compute(1, self.compute_s_per_step);
        let kind_bytes = [4 * p as u64, 4 * p as u64];
        let kinds = if self.dsgt { 2 } else { 1 };
        self.acct.comm_round(self.round_edges, &kind_bytes[..kinds]);
        Ok(())
    }

    fn observe(&mut self, round: u64, local_steps: u64) -> Result<()> {
        // pass 1: per-node eval folded shard-by-shard through StreamingEval
        // — the identical left fold the resident eval_reduce runs, so the
        // metrics agree bitwise with the resident path
        let mut se = StreamingEval::new(self.p);
        for i in 0..self.n {
            self.pool.read_row_into(i, quantity::THETA, &mut self.t_out)?;
            let (loss, grad, correct, total) = self.model.eval_node(&self.t_out, &self.shards[i]);
            se.push_node(loss, &grad, correct, total, &self.t_out);
        }
        // pass 2: consensus against the pass-1 mean, same sweep order
        let mut cp = se.into_consensus_pass();
        for i in 0..self.n {
            self.pool.read_row_into(i, quantity::THETA, &mut self.t_out)?;
            cp.push_row(&self.t_out);
        }
        let eval = cp.finish();
        self.log.push(round_metrics(
            round,
            local_steps,
            eval,
            self.acct.snapshot(),
            self.started.elapsed().as_secs_f64(),
        ));
        Ok(())
    }
}

// ------------------------------------------------------ entry points ----

/// Train an honest gossip config through the sharded driver; returns the
/// metric log and the final θ stack (materialized once, at the end — for
/// the pinned-equivalence tests and small-n callers).
pub fn train(
    cfg: &ExperimentConfig,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &SparseW,
) -> Result<(RunLog, Vec<f32>)> {
    let engine = super::RoundEngine::from_config(cfg);
    let mut driver = ShardedSync::new(cfg, ds, graph, w)?;
    engine.run(&mut driver)?;
    driver.into_result()
}

/// Train through the sharded driver, log only — the 10⁵⁺-node path: the
/// full θ stack is never materialized, before, during, or after the run.
pub fn train_log(
    cfg: &ExperimentConfig,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &SparseW,
) -> Result<RunLog> {
    let engine = super::RoundEngine::from_config(cfg);
    let mut driver = ShardedSync::new(cfg, ds, graph, w)?;
    engine.run(&mut driver)?;
    Ok(driver.into_log())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_partitions_exactly() {
        let s = ShardSpec { n: 10, shard_nodes: 4 };
        assert_eq!(s.n_shards(), 3);
        assert_eq!(s.range(0), (0, 4));
        assert_eq!(s.range(2), (8, 10));
        assert_eq!(s.shard_of(7), 1);
        assert_eq!(s.shard_of(9), 2);
    }

    #[test]
    fn pool_roundtrips_rows_through_eviction() {
        // 6 nodes, shards of 2 (3 shards), hot-set of 1 frame: every write
        // to a new shard evicts the previous one, so reads exercise both
        // the resident-frame and the spill-file paths
        let p = 5;
        let mut pool = NodeSlabPool::new(6, 2, 1, p, 2).unwrap();
        let row = |i: usize, q: usize| -> Vec<f32> {
            (0..p).map(|k| (i * 100 + q * 10 + k) as f32).collect()
        };
        for i in 0..6 {
            pool.write_row(i, 0, &row(i, 0)).unwrap();
            pool.write_row(i, 1, &row(i, 1)).unwrap();
        }
        assert!(pool.resident_rows() <= 2, "hot-set bound: 1 frame × 2 nodes");
        let mut buf = vec![0.0f32; p];
        for i in 0..6 {
            for q in 0..2 {
                pool.read_row_into(i, q, &mut buf).unwrap();
                assert_eq!(buf, row(i, q), "node {i} q {q}");
            }
        }
        let st = pool.stats();
        assert!(st.spills > 0, "a 1-frame pool over 3 shards must spill");
        assert!(st.loads > 0);
    }

    #[test]
    fn quantity_swap_moves_no_data() {
        let p = 3;
        let mut pool = NodeSlabPool::new(2, 2, 1, p, 2).unwrap();
        pool.write_row(0, 0, &[1.0; 3]).unwrap();
        pool.write_row(0, 1, &[2.0; 3]).unwrap();
        pool.swap_quantities(0, 1);
        let mut buf = vec![0.0f32; p];
        pool.read_row_into(0, 0, &mut buf).unwrap();
        assert_eq!(buf, [2.0; 3]);
        pool.read_row_into(0, 1, &mut buf).unwrap();
        assert_eq!(buf, [1.0; 3]);
        // and the swap survives a spill/reload cycle (offsets go through
        // the same qmap on the file side)
        pool.write_row(1, 0, &[9.0; 3]).unwrap(); // same shard — stays hot
        let mut other = NodeSlabPool::new(2, 1, 1, p, 2).unwrap();
        other.write_row(0, 0, &[5.0; 3]).unwrap();
        other.swap_quantities(0, 1);
        other.write_row(1, 0, &[7.0; 3]).unwrap(); // evicts shard 0
        other.read_row_into(0, 1, &mut buf).unwrap(); // file path
        assert_eq!(buf, [5.0; 3]);
    }

    #[test]
    fn spill_file_is_removed_on_drop() {
        let pool = NodeSlabPool::new(4, 2, 1, 3, 2).unwrap();
        let path = pool.path.clone();
        assert!(path.exists());
        drop(pool);
        assert!(!path.exists());
    }

    #[test]
    fn unsupported_axes_bail_loudly() {
        let base = || {
            let mut cfg = ExperimentConfig::default();
            cfg.backend = Backend::Native;
            cfg.shard_nodes = 4;
            cfg
        };
        let ds = crate::data::generate(&crate::data::DataConfig {
            n_hospitals: 4,
            records_per_hospital: 30,
            records_jitter: 0,
            ..crate::data::DataConfig::default()
        })
        .unwrap();
        let graph =
            Graph::build(&crate::graph::Topology::Ring, 4, &mut crate::rng::Pcg64::seed(0))
                .unwrap();
        let w = crate::mixing::build_sparse(&graph, crate::mixing::Scheme::Metropolis);
        for (patch, needle) in [
            (
                Box::new(|c: &mut ExperimentConfig| c.compress = "q8".into())
                    as Box<dyn Fn(&mut ExperimentConfig)>,
                "compress",
            ),
            (Box::new(|c: &mut ExperimentConfig| c.backend = Backend::Pjrt), "native"),
            (Box::new(|c: &mut ExperimentConfig| c.driver = "async".into()), "sync"),
            (Box::new(|c: &mut ExperimentConfig| c.mode = Mode::Actors), "fused"),
            (
                Box::new(|c: &mut ExperimentConfig| {
                    c.attack_plan = "sign-flip".into();
                    c.attack_frac = 0.25;
                }),
                "adversarial",
            ),
            (
                Box::new(|c: &mut ExperimentConfig| c.robust_rule = "median".into()),
                "adversarial",
            ),
            (
                Box::new(|c: &mut ExperimentConfig| c.compute_plan = "dropout".into()),
                "compute plan",
            ),
            (Box::new(|c: &mut ExperimentConfig| c.drop_prob = 0.1), "lossless"),
            (
                Box::new(|c: &mut ExperimentConfig| c.algo = AlgoKind::FedAvg),
                "gossip",
            ),
        ] {
            let mut cfg = base();
            patch(&mut cfg);
            let err = train(&cfg, &ds, &graph, &w).unwrap_err().to_string();
            assert!(err.contains(needle), "wanted `{needle}` in: {err}");
        }
    }
}
