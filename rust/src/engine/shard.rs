//! Sharded node state — 10⁵–10⁶-node fleets without co-resident slabs.
//!
//! PR 6 made the *graph* axis sparse-native, but training state (θ, the
//! DSGT tracker ϑ and gradient stacks) was still one flat resident array
//! per quantity, so fleet size was capped by RAM long before the algorithm
//! was.  This module shards the per-node quantity slabs into fixed-size
//! node blocks backed by a spill file, keeps an LRU hot-set of
//! [`ExperimentConfig::hot_shards`] blocks resident, and sweeps a
//! communication round shard-by-shard in CSR-block order: each shard's pass
//! gathers a compact stack of its own rows plus the halo rows its cut edges
//! reference (a boundary exchange over the spill file — halo reads never
//! load a shard), remaps the CSR columns onto that stack *preserving entry
//! order*, and runs the exact per-node kernels the resident driver fans out.
//!
//! The pool is **quantity-agnostic**: any per-node row of `p` floats
//! registers in a [`QuantityRegistry`] and gets the same LRU/spill/halo/swap
//! semantics — θ and the DSGT pair, but also the compression axis's decoded
//! rows X̂/Ŷ, the error-feedback residuals, and the stale-replay attacker
//! rows.  [`QuantitySet::for_config`] derives the registration from the
//! config, so a run only pays for the quantities its axes actually carry.
//!
//! Bitwise contract (pinned by `tests/shard_pins.rs`): because
//! `combine_sparse_into` folds its f64 accumulator in CSR **entry order**
//! and the remap is order-preserving, because the per-node sampler streams
//! are `(seed, node)`-keyed and therefore shard-oblivious, because every
//! outgoing message runs through the same [`super::pipeline::encode_row`]
//! under the same `(seed, round, node, kind)` key the resident strategies
//! use, and because evaluation is the same [`crate::metrics::StreamingEval`]
//! left fold the resident `eval_reduce` runs, the sharded trajectory is
//! bitwise identical to the resident fused driver at every shard count —
//! 1 shard == k shards == unsharded.  The default (`state.shard_nodes = 0`)
//! never constructs this driver at all, so the resident path stays
//! byte-for-byte untouched.
//!
//! Scope: the sharded driver covers the full gossip scenario matrix —
//! compression (q8/q4/top-k, with or without error feedback), Byzantine
//! attack plans, robust combine rules, the DP layer, straggler compute
//! plans, and **any** network plan (static/rewire/edge-drop/churn) — on the
//! native backend under the fused sync driver.  Only structural
//! incompatibilities refuse: non-gossip algorithms (no per-node gossip
//! state to shard), the PJRT backend (whole-stack artifact calls), the
//! actor/async drivers (resident per-node inbox state by construction), and
//! `drop_prob > 0` (fused accounting is analytically lossless).  Honest
//! uncompressed runs never produce a non-finite θ row, so the uncompressed
//! sweep skips the quarantine scan (DESIGN.md §15); the encode sweep scans
//! its decoded rows exactly like the resident strategies.  Per-node
//! samplers stay resident: their state is O(1) plus a lazily grown index
//! permutation — orders of magnitude below one parameter row.

use super::adversary::{self, AttackPlan, DpPlan, MsgPerturb};
use super::pipeline::{compact_from_bad, encode_row, RowPerturb};
use super::stragglers::ComputeSchedule;
use crate::algo::native::{NativeModel, Workspace};
use crate::algo::{scale_displacement, RobustRule, RoundPlan};
use crate::compress::{Encoded, GossipComm, Identity};
use crate::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use crate::coordinator::sampler::{init_theta, NodeSampler};
use crate::data::{FederatedDataset, Shard};
use crate::graph::{Graph, NetworkSchedule, ViewScratch};
use crate::metrics::{round_metrics, RunLog, StreamingEval};
use crate::mixing::SparseW;
use crate::netsim::{analytic::Accountant, LinkModel, PayloadKind};
use anyhow::{bail, Result};
use std::os::unix::fs::FileExt;
use std::sync::atomic::{AtomicU64, Ordering};

// --------------------------------------------------------- registry ----

/// Sentinel for a quantity a run's axes did not register.
pub const UNREGISTERED: usize = usize::MAX;

/// Registry of named per-node row quantities backing a [`NodeSlabPool`].
/// Registration order defines the physical row layout inside each node's
/// slab; the returned id is the handle every pool accessor takes.  Front/
/// back pairs are just two registered quantities swapped via
/// [`NodeSlabPool::swap_quantities`].
#[derive(Clone, Debug, Default)]
pub struct QuantityRegistry {
    names: Vec<&'static str>,
}

impl QuantityRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        QuantityRegistry { names: Vec::new() }
    }

    /// Register one per-node quantity; the returned id is dense (0, 1, …)
    /// in registration order.
    pub fn register(&mut self, name: &'static str) -> usize {
        self.names.push(name);
        self.names.len() - 1
    }

    /// Registered quantity rows per node.
    pub fn count(&self) -> usize {
        self.names.len()
    }

    /// Display name of quantity `q`.
    pub fn name(&self, q: usize) -> &'static str {
        self.names[q]
    }
}

/// The quantity ids a sharded run registers, derived from the config's
/// axes.  Ids of axes a run does not carry are [`UNREGISTERED`] — the
/// driver consults its axis flags before touching them, so a run only
/// spills the rows it actually uses.
#[derive(Clone, Copy, Debug)]
pub struct QuantitySet {
    /// Parameters θ (front).
    pub theta: usize,
    /// Parameters θ (back buffer).
    pub theta_back: usize,
    /// DSGT tracker ϑ (front; DSGT only).
    pub y: usize,
    /// DSGT tracker ϑ (back buffer; DSGT only).
    pub y_back: usize,
    /// DSGT previous gradient G (front; DSGT only).
    pub g: usize,
    /// DSGT previous gradient G (back buffer; DSGT only).
    pub g_back: usize,
    /// Decoded parameter row X̂ (compressed/perturbed runs; persistent,
    /// single-buffered — re-encoded in place every online round).
    pub xhat: usize,
    /// Decoded tracker row Ŷ (compressed/perturbed DSGT runs).
    pub yhat: usize,
    /// Error-feedback residual for the θ stream (EF runs; single-buffered:
    /// `residual_update` fully overwrites the row, so in-place equals the
    /// resident front/back swap bit for bit).
    pub ef_t: usize,
    /// Error-feedback residual for the ϑ stream (EF DSGT runs).
    pub ef_y: usize,
    /// Stale-replay attacker slot for the θ stream (replay plans).
    pub replay_t: usize,
    /// Stale-replay attacker slot for the ϑ stream (replay DSGT plans).
    pub replay_y: usize,
}

impl QuantitySet {
    /// Register the quantities `cfg`'s axes need and return the registry
    /// (row layout + count) with the id set.  The same derivation the
    /// resident drivers make implicitly by allocating their side slabs:
    /// θ front/back always; the tracker/gradient pairs for DSGT; decoded
    /// rows whenever the run routes through the encode path (a compressor
    /// or an active attack/DP pipeline — the driver installs `Identity`
    /// for the latter); EF residuals when error feedback is opted in; and
    /// replay slots under a stale-replay attack plan.
    pub fn for_config(cfg: &ExperimentConfig) -> Result<(QuantityRegistry, QuantitySet)> {
        let dsgt = matches!(cfg.algo, AlgoKind::Dsgt | AlgoKind::FdDsgt);
        let compressing = cfg.compress != "none" || adversary::perturb_active(cfg);
        let ef = compressing && cfg.error_feedback;
        let attack = adversary::AttackSchedule::from_config(cfg)?;
        let replay = attack.active() && matches!(attack.plan(), AttackPlan::StaleReplay { .. });
        let mut reg = QuantityRegistry::new();
        let mut qs = QuantitySet {
            theta: UNREGISTERED,
            theta_back: UNREGISTERED,
            y: UNREGISTERED,
            y_back: UNREGISTERED,
            g: UNREGISTERED,
            g_back: UNREGISTERED,
            xhat: UNREGISTERED,
            yhat: UNREGISTERED,
            ef_t: UNREGISTERED,
            ef_y: UNREGISTERED,
            replay_t: UNREGISTERED,
            replay_y: UNREGISTERED,
        };
        qs.theta = reg.register("theta");
        qs.theta_back = reg.register("theta_back");
        if dsgt {
            qs.y = reg.register("y");
            qs.y_back = reg.register("y_back");
            qs.g = reg.register("g");
            qs.g_back = reg.register("g_back");
        }
        if compressing {
            qs.xhat = reg.register("xhat");
            if dsgt {
                qs.yhat = reg.register("yhat");
            }
        }
        if ef {
            qs.ef_t = reg.register("ef_theta");
            if dsgt {
                qs.ef_y = reg.register("ef_y");
            }
        }
        if replay {
            qs.replay_t = reg.register("replay_theta");
            if dsgt {
                qs.replay_y = reg.register("replay_y");
            }
        }
        Ok((reg, qs))
    }
}

// ------------------------------------------------------------ layout ----

/// Fixed-size partition of `n` nodes into shards of `shard_nodes` rows
/// (the last shard may be partial).
#[derive(Clone, Copy, Debug)]
pub struct ShardSpec {
    /// Fleet size.
    pub n: usize,
    /// Nodes per shard.
    pub shard_nodes: usize,
}

impl ShardSpec {
    /// Number of shards covering the fleet.
    pub fn n_shards(&self) -> usize {
        self.n.div_ceil(self.shard_nodes)
    }

    /// Shard owning `node`.
    pub fn shard_of(&self, node: usize) -> usize {
        node / self.shard_nodes
    }

    /// Node range `[start, end)` of shard `s`.
    pub fn range(&self, s: usize) -> (usize, usize) {
        let start = s * self.shard_nodes;
        (start, ((s + 1) * self.shard_nodes).min(self.n))
    }
}

// -------------------------------------------------------------- pool ----

/// Counters a [`NodeSlabPool`] keeps about its own traffic, for benches,
/// the EXP-SH1 experiment, the `decfl shard` table, the run log
/// (`RoundMetrics::pool_*`), and the hot-set-bound tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Shard loads from the spill file (cold acquires).
    pub loads: u64,
    /// Frames evicted to make room (hot-set pressure; every cold acquire
    /// on a full pool evicts exactly one frame).
    pub spills: u64,
    /// Evicted frames that were dirty and had to be written back to the
    /// spill file (`writebacks ≤ spills`; a clean eviction costs no I/O).
    pub writebacks: u64,
    /// Acquires served by a resident frame.
    pub hits: u64,
}

/// One resident shard frame: `shard_nodes · nq · p` floats.
struct Frame {
    /// Which shard this frame holds (`usize::MAX` = empty).
    shard: usize,
    /// LRU clock value of the last acquire.
    last_use: u64,
    /// Frame has row writes the spill file hasn't seen.
    dirty: bool,
    data: Vec<f32>,
}

static POOL_ID: AtomicU64 = AtomicU64::new(0);

/// Spill-file-backed pool of per-node quantity slabs with an LRU hot-set.
///
/// Layout: node-major, quantity-minor — node `i`'s registered rows of `p`
/// floats are contiguous in its shard frame and at the mirrored offset in
/// the spill file, so one shard is one contiguous file extent.  The file is
/// created sparse (`set_len`) in the system temp directory, so untouched
/// shards cost no disk (a registered-but-never-written quantity reads back
/// all-zero — exactly the resident drivers' zero-initialized side slabs),
/// and it is removed on drop.  Front/back quantity swaps go through a
/// logical→physical quantity map ([`Self::swap_quantities`]): a swap is two
/// index writes, never a data move.
///
/// All frames are allocated up front, file I/O goes through preallocated
/// byte buffers (`read_at`/`write_at`, little-endian f32), and the row
/// accessors copy through caller buffers — warm sweeps allocate nothing
/// (`tests/alloc_free.rs` pins this with a counting allocator).
pub struct NodeSlabPool {
    spec: ShardSpec,
    /// Parameter row length.
    p: usize,
    /// The quantity layout (row count + names).
    reg: QuantityRegistry,
    /// Logical quantity → physical slot.
    qmap: Vec<usize>,
    frames: Vec<Frame>,
    /// shard → resident frame index.
    map: Vec<Option<usize>>,
    tick: u64,
    file: std::fs::File,
    path: std::path::PathBuf,
    /// Whole-frame I/O staging (`frame_len · 4` bytes).
    io_buf: Vec<u8>,
    /// Single-row I/O staging (`p · 4` bytes) for halo reads.
    row_buf: Vec<u8>,
    stats: PoolStats,
}

impl NodeSlabPool {
    /// Create a pool for `n` nodes in shards of `shard_nodes`, keeping at
    /// most `hot_shards` frames resident, with the registry's quantity rows
    /// of `p` floats per node.  The spill file starts all-zero (sparse).
    pub fn new(
        n: usize,
        shard_nodes: usize,
        hot_shards: usize,
        p: usize,
        reg: QuantityRegistry,
    ) -> Result<Self> {
        let nq = reg.count();
        if n == 0 || shard_nodes == 0 || hot_shards == 0 || p == 0 || nq == 0 {
            bail!(
                "NodeSlabPool: n, shard_nodes, hot_shards, p, and the registered \
                 quantity count must all be positive"
            );
        }
        let spec = ShardSpec { n, shard_nodes };
        let n_shards = spec.n_shards();
        let frame_len = shard_nodes * nq * p;
        let path = std::env::temp_dir().join(format!(
            "decfl_slab_{}_{}.bin",
            std::process::id(),
            POOL_ID.fetch_add(1, Ordering::Relaxed)
        ));
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)?;
        file.set_len((n_shards * frame_len * 4) as u64)?;
        let frames = (0..hot_shards.min(n_shards))
            .map(|_| Frame {
                shard: usize::MAX,
                last_use: 0,
                dirty: false,
                data: vec![0.0f32; frame_len],
            })
            .collect();
        Ok(NodeSlabPool {
            spec,
            p,
            qmap: (0..nq).collect(),
            reg,
            frames,
            map: vec![None; n_shards],
            tick: 0,
            file,
            path,
            io_buf: vec![0u8; frame_len * 4],
            row_buf: vec![0u8; p * 4],
            stats: PoolStats::default(),
        })
    }

    /// The node→shard partition.
    pub fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    /// The registered quantity layout.
    pub fn registry(&self) -> &QuantityRegistry {
        &self.reg
    }

    /// Registered quantity rows per node.
    pub fn nq(&self) -> usize {
        self.reg.count()
    }

    /// Traffic counters so far.
    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Currently resident slab rows (node rows with ≥ 1 quantity in RAM) —
    /// bounded by `hot_shards · shard_nodes` by construction; the
    /// `alloc_free` test pins this.
    pub fn resident_rows(&self) -> usize {
        self.frames.iter().filter(|f| f.shard != usize::MAX).count() * self.spec.shard_nodes
    }

    /// Float offset of `(slot, quantity)` inside a frame / shard extent.
    fn offset(&self, slot: usize, q: usize) -> usize {
        (slot * self.reg.count() + self.qmap[q]) * self.p
    }

    fn frame_len(&self) -> usize {
        self.spec.shard_nodes * self.reg.count() * self.p
    }

    /// Make `shard` resident (LRU-evicting if needed) and return its frame.
    fn acquire(&mut self, shard: usize) -> Result<usize> {
        self.tick += 1;
        if let Some(fi) = self.map[shard] {
            self.frames[fi].last_use = self.tick;
            self.stats.hits += 1;
            return Ok(fi);
        }
        // victim: an empty frame if any, else the least recently used
        let fi = self
            .frames
            .iter()
            .enumerate()
            .min_by_key(|(_, f)| if f.shard == usize::MAX { (0, 0) } else { (1, f.last_use) })
            .map(|(i, _)| i)
            .expect("pool holds at least one frame");
        let old = self.frames[fi].shard;
        if old != usize::MAX {
            if self.frames[fi].dirty {
                self.write_frame(fi)?;
                self.stats.writebacks += 1;
            }
            self.stats.spills += 1;
            self.map[old] = None;
        }
        self.read_frame(fi, shard)?;
        self.stats.loads += 1;
        let f = &mut self.frames[fi];
        f.shard = shard;
        f.dirty = false;
        f.last_use = self.tick;
        self.map[shard] = Some(fi);
        Ok(fi)
    }

    fn write_frame(&mut self, fi: usize) -> Result<()> {
        let frame_len = self.frame_len();
        let Self { frames, io_buf, file, .. } = self;
        let f = &frames[fi];
        for (b, v) in io_buf.chunks_exact_mut(4).zip(&f.data) {
            b.copy_from_slice(&v.to_le_bytes());
        }
        file.write_all_at(io_buf, (f.shard * frame_len * 4) as u64)?;
        Ok(())
    }

    fn read_frame(&mut self, fi: usize, shard: usize) -> Result<()> {
        let frame_len = self.frame_len();
        let Self { frames, io_buf, file, .. } = self;
        file.read_exact_at(io_buf, (shard * frame_len * 4) as u64)?;
        for (v, b) in frames[fi].data.iter_mut().zip(io_buf.chunks_exact(4)) {
            *v = f32::from_le_bytes(b.try_into().expect("4-byte chunk"));
        }
        Ok(())
    }

    /// Copy quantity `q` of `node` into `out` — from the resident frame if
    /// the owning shard is hot, else straight from the spill file *without*
    /// loading the shard (this is the halo gather: boundary rows of other
    /// shards are read, never made resident).
    pub fn read_row_into(&mut self, node: usize, q: usize, out: &mut [f32]) -> Result<()> {
        debug_assert_eq!(out.len(), self.p);
        let shard = self.spec.shard_of(node);
        let slot = node % self.spec.shard_nodes;
        let off = self.offset(slot, q);
        if let Some(fi) = self.map[shard] {
            out.copy_from_slice(&self.frames[fi].data[off..off + self.p]);
            return Ok(());
        }
        let byte_off = ((shard * self.frame_len() + off) * 4) as u64;
        let Self { file, row_buf, .. } = self;
        file.read_exact_at(row_buf, byte_off)?;
        for (v, b) in out.iter_mut().zip(row_buf.chunks_exact(4)) {
            *v = f32::from_le_bytes(b.try_into().expect("4-byte chunk"));
        }
        Ok(())
    }

    /// Overwrite quantity `q` of `node`, making its shard resident first.
    pub fn write_row(&mut self, node: usize, q: usize, data: &[f32]) -> Result<()> {
        debug_assert_eq!(data.len(), self.p);
        let shard = self.spec.shard_of(node);
        let slot = node % self.spec.shard_nodes;
        let off = self.offset(slot, q);
        let fi = self.acquire(shard)?;
        let f = &mut self.frames[fi];
        f.data[off..off + self.p].copy_from_slice(data);
        f.dirty = true;
        Ok(())
    }

    /// Swap two logical quantities (e.g. θ front/back) across the WHOLE
    /// fleet — two index writes, no data movement, the sharded twin of the
    /// resident driver's stack swap.
    pub fn swap_quantities(&mut self, a: usize, b: usize) {
        self.qmap.swap(a, b);
    }
}

impl Drop for NodeSlabPool {
    fn drop(&mut self) {
        std::fs::remove_file(&self.path).ok();
    }
}

// ------------------------------------------------------------ driver ----

/// The structural incompatibilities the sharded driver refuses (loudly);
/// everything else — compression, error feedback, attacks, robust rules,
/// DP, straggler plans, every network plan — is shard-native.
fn reject_unsupported(cfg: &ExperimentConfig) -> Result<()> {
    if !matches!(
        cfg.algo,
        AlgoKind::Dsgd | AlgoKind::Dsgt | AlgoKind::FdDsgd | AlgoKind::FdDsgt
    ) {
        bail!(
            "state.shard_nodes applies to gossip algorithms (dsgd|dsgt|fd-dsgd|fd-dsgt); \
             `{}` has no per-node gossip state to shard",
            cfg.algo.name()
        );
    }
    if cfg.backend != Backend::Native {
        bail!(
            "state.shard_nodes requires --backend native: the PJRT artifacts are lowered \
             for whole-stack calls and would need the full θ stack resident anyway"
        );
    }
    if cfg.mode != Mode::Fused || cfg.driver != "sync" {
        bail!(
            "state.shard_nodes requires the fused sync driver (--mode fused, run.driver \
             sync): the actor and async drivers keep per-node inbox state resident by \
             construction; drop --shard-nodes or switch drivers"
        );
    }
    if cfg.drop_prob > 0.0 {
        bail!(
            "drop_prob={} requested, but sharded execution charges communication \
             analytically over lossless links; use `--mode actors` for loss injection",
            cfg.drop_prob
        );
    }
    Ok(())
}

/// Sharded synchronous gossip driver — implements [`super::Driver`] so
/// [`super::RoundEngine::run`] drives it with the exact round structure of
/// the resident paths, but every phase is a shard sweep over a
/// [`NodeSlabPool`] instead of a whole-stack call.  All message-shaping
/// (EF compensation, attack/DP perturbation, encode/decode, quarantine
/// compaction) routes through [`super::pipeline`] — the same functions the
/// resident strategies call, which is what keeps the sharded trajectory
/// bitwise-equal on every axis.  Serial by design: the sweep is I/O-shaped,
/// and serial per-node kernels are bitwise identical to the resident
/// parallel fan-out at every thread count anyway.
pub struct ShardedSync<'a> {
    model: NativeModel,
    dsgt: bool,
    /// Routed through the encode path (compressor configured, or an active
    /// attack/DP pipeline behind an installed `Identity`).
    compressing: bool,
    /// Error-feedback residuals registered and updated per encode.
    ef: bool,
    rule: RobustRule,
    comm: GossipComm,
    /// Active adversary/DP pipeline (None on the pinned honest path).
    perturb: Option<MsgPerturb>,
    /// Per-node has-a-replay-copy flags, one per payload stream (empty
    /// unless a stale-replay plan is active).
    replay_stored_t: Vec<bool>,
    replay_stored_y: Vec<bool>,
    /// Per-node non-finite flags of the latest *encoded* rows, one per
    /// payload stream.  Persistent across rounds — offline rows keep stale
    /// flags, exactly as the resident scan never visits them (the scan
    /// masks with the online bit).
    bad_t: Vec<bool>,
    bad_y: Vec<bool>,
    /// Combined per-sender bad mask scratch (filled only on poisoned rounds).
    bad_all: Vec<bool>,
    /// Quarantine-compacted W (grow-only; re-filled when `wq_active`).
    wq: SparseW,
    wq_active: bool,
    /// Cumulative quarantined-payload count (non-finite ingest guard).
    quarantined: u64,
    /// Quarantine events already forwarded to the accountant.
    q_reported: u64,
    dp: DpPlan,
    /// Gaussian releases per node per round (1 = θ, 2 = θ + ϑ).
    dp_kinds: u64,
    /// Per-round, per-node local-work schedule (`engine::stragglers`).
    csched: ComputeSchedule,
    /// Per-round τ scratch `[n]` (non-uniform plans only).
    taus: Vec<usize>,
    /// Per-round τ-weight scratch `[n]` (non-uniform plans only).
    tau_ws: Vec<f32>,
    /// Cumulative Σ_i τ_i over completed rounds (non-uniform plans only).
    work_done: u64,
    qs: QuantitySet,
    pool: NodeSlabPool,
    samplers: Vec<NodeSampler>,
    shards: &'a [Shard],
    n: usize,
    p: usize,
    m: usize,
    d: usize,
    local: usize,
    compute_s_per_step: f64,
    // per-round network view (mirrors SyncDriver::refresh_net)
    net: NetworkSchedule,
    scratch: ViewScratch,
    wsp: SparseW,
    online: Vec<bool>,
    round_edges: u64,
    net_key: Option<u64>,
    acct: Accountant,
    // sweep scratch, all grow-only: warm rounds allocate nothing
    ws: Workspace,
    lx: Vec<f32>,
    ly: Vec<f32>,
    cx: Vec<f32>,
    cy: Vec<f32>,
    step_losses: Vec<f64>,
    stack_t: Vec<f32>,
    stack_y: Vec<f32>,
    ridx: Vec<u32>,
    roff: Vec<usize>,
    /// Global→compact-stack column map, `u32::MAX` = unmapped.  O(n) at 4
    /// bytes/node (4 MB at 10⁶) — the one full-fleet array the sweep keeps,
    /// reset per shard via the halo list rather than a full clear.
    g2l: Vec<u32>,
    halo: Vec<u32>,
    t_out: Vec<f32>,
    y_out: Vec<f32>,
    g_out: Vec<f32>,
    y_row: Vec<f32>,
    g_row: Vec<f32>,
    /// Pre-update own θ row (compressed kernels' full-precision input; also
    /// the hetero local phase's pre-step copy for the τ-weight rescale).
    t_prev: Vec<f32>,
    // encode-sweep scratch (compressed/perturbed runs only)
    x_row: Vec<f32>,
    e_row: Vec<f32>,
    v_row: Vec<f32>,
    hat_row: Vec<f32>,
    replay_row: Vec<f32>,
    enc: Encoded,
    log: RunLog,
    started: std::time::Instant,
}

impl<'a> ShardedSync<'a> {
    /// Build the sharded driver for a gossip config with
    /// `cfg.shard_nodes > 0`.  Seeds θ row-by-row through the pool — the
    /// full stack is never materialized.
    pub fn new(
        cfg: &ExperimentConfig,
        ds: &'a FederatedDataset,
        graph: &Graph,
        w: &SparseW,
    ) -> Result<Self> {
        reject_unsupported(cfg)?;
        if cfg.d != ds.d {
            bail!("config d={} vs dataset d={}", cfg.d, ds.d);
        }
        if cfg.shard_nodes == 0 {
            bail!("ShardedSync requires state.shard_nodes > 0 (0 = resident path)");
        }
        let n = ds.n_hospitals();
        let model = NativeModel::new(cfg.d, cfg.hidden);
        let p = model.p();
        let dsgt = matches!(cfg.algo, AlgoKind::Dsgt | AlgoKind::FdDsgt);
        let (reg, qs) = QuantitySet::for_config(cfg)?;
        let mut pool = NodeSlabPool::new(n, cfg.shard_nodes.min(n), cfg.hot_shards, p, reg)?;
        for i in 0..n {
            let row = init_theta(cfg.seed, i, &model);
            pool.write_row(i, qs.theta, &row)?;
        }
        let net = NetworkSchedule::from_config(cfg, graph.clone(), w.clone())?;
        let local = RoundPlan::new(cfg.algo.effective_q(cfg.q)).local_per_round;
        let csched = ComputeSchedule::from_config(cfg)?;
        csched.ensure_runnable(n, None)?;
        // the same perturbation/compression wiring the resident sync driver
        // makes: perturbed runs route through the encode path even when no
        // compressor is configured (Identity installed, bitwise-equal to
        // dense and charged at the same 4p wire bytes)
        let perturb = MsgPerturb::from_config(cfg)?;
        let dp = adversary::dp_from_config(cfg)?;
        let mut comm = GossipComm::from_config(cfg)?;
        if perturb.is_some() && comm.comp.is_none() {
            comm.comp = Some(Box::new(Identity));
        }
        let rule = RobustRule::parse(&cfg.robust_rule, cfg.robust_trim)?;
        let compressing = comm.comp.is_some();
        let ef = compressing && cfg.error_feedback;
        let replay = qs.replay_t != UNREGISTERED;
        let link = LinkModel {
            latency_s: cfg.latency_s,
            bandwidth_bps: cfg.bandwidth_bps,
            drop_prob: 0.0,
        };
        let uniform = csched.is_uniform();
        let (m, d) = (cfg.m, cfg.d);
        Ok(ShardedSync {
            model,
            dsgt,
            compressing,
            ef,
            rule,
            comm,
            perturb,
            replay_stored_t: vec![false; if replay { n } else { 0 }],
            replay_stored_y: vec![false; if replay && dsgt { n } else { 0 }],
            bad_t: vec![false; if compressing { n } else { 0 }],
            bad_y: vec![false; if compressing && dsgt { n } else { 0 }],
            bad_all: Vec::new(),
            wq: SparseW::empty(),
            wq_active: false,
            quarantined: 0,
            q_reported: 0,
            dp,
            dp_kinds: if dsgt { 2 } else { 1 },
            taus: vec![0; if uniform { 0 } else { n }],
            tau_ws: vec![0.0; if uniform { 0 } else { n }],
            work_done: 0,
            csched,
            qs,
            pool,
            samplers: (0..n).map(|i| NodeSampler::new(cfg.seed, i, m)).collect(),
            shards: &ds.shards[..],
            n,
            p,
            m,
            d,
            local,
            compute_s_per_step: cfg.compute_s_per_step,
            net,
            scratch: ViewScratch::new(),
            wsp: SparseW::empty(),
            online: vec![true; n],
            round_edges: 0,
            net_key: None,
            acct: Accountant::new(link),
            ws: Workspace::new(),
            lx: vec![0.0f32; local * m * d],
            ly: vec![0.0f32; local * m],
            cx: vec![0.0f32; m * d],
            cy: vec![0.0f32; m],
            step_losses: vec![0.0f64; local],
            stack_t: Vec::new(),
            stack_y: Vec::new(),
            ridx: Vec::new(),
            roff: Vec::new(),
            g2l: vec![u32::MAX; n],
            halo: Vec::new(),
            t_out: vec![0.0f32; p],
            y_out: vec![0.0f32; if dsgt { p } else { 0 }],
            g_out: vec![0.0f32; if dsgt { p } else { 0 }],
            y_row: vec![0.0f32; if dsgt { p } else { 0 }],
            g_row: vec![0.0f32; if dsgt { p } else { 0 }],
            t_prev: vec![0.0f32; p],
            x_row: vec![0.0f32; if compressing { p } else { 0 }],
            e_row: vec![0.0f32; if compressing { p } else { 0 }],
            v_row: vec![0.0f32; if compressing { p } else { 0 }],
            hat_row: vec![0.0f32; if compressing { p } else { 0 }],
            replay_row: vec![0.0f32; if compressing { p } else { 0 }],
            enc: Encoded::Dense(Vec::new()),
            log: RunLog::new(cfg.algo.name()),
            started: std::time::Instant::now(),
        })
    }

    /// Per-round network view refresh — the same key-cached, grow-only
    /// materialization as the resident sync driver (no dense scatter: the
    /// sweep is CSR-native at any n).
    fn refresh_net(&mut self, round: usize) -> Result<()> {
        let key = self.net.view_key(round);
        if self.net_key == Some(key) {
            return Ok(());
        }
        self.wsp.reserve_rows_nnz(self.net.n(), self.net.base_nnz());
        let view = self.net.view_into(round, &mut self.scratch)?;
        self.wsp.copy_from(view.w);
        self.round_edges = view.active_directed_edges();
        self.online.clear();
        self.online.extend_from_slice(view.online);
        self.net_key = Some(key);
        Ok(())
    }

    /// Undo [`build_halo`]'s map entries (sentinel reset via the halo
    /// list — never a full O(n) clear).
    fn reset_halo(&mut self, s0: usize, s1: usize) {
        self.g2l[s0..s1].fill(u32::MAX);
        for &j in &self.halo {
            self.g2l[j as usize] = u32::MAX;
        }
    }

    /// Is node `i` a Byzantine attacker under the active perturbation plan?
    fn is_attacker(&self, i: usize) -> bool {
        self.perturb.as_ref().is_some_and(|pb| pb.attack.is_attacker(i))
    }

    /// One node's one payload through the driver-agnostic message pipeline
    /// (`pipeline::encode_row`): EF compensation, the attack/DP stage (with
    /// the stale-replay slot living in the slab pool), deterministic
    /// encode/decode into the pooled X̂/Ŷ row, and the in-place residual
    /// update.  Also refreshes the per-sender non-finite flag the
    /// quarantine scan reads.
    fn encode_node(&mut self, round: usize, i: usize, kind: PayloadKind) -> Result<()> {
        let (q_src, q_hat, q_ef, q_replay) = match kind {
            PayloadKind::Params => (self.qs.theta, self.qs.xhat, self.qs.ef_t, self.qs.replay_t),
            PayloadKind::Tracker => (self.qs.y, self.qs.yhat, self.qs.ef_y, self.qs.replay_y),
        };
        self.pool.read_row_into(i, q_src, &mut self.x_row)?;
        if self.ef {
            self.pool.read_row_into(i, q_ef, &mut self.e_row)?;
        }
        let wants_replay = self.perturb.as_ref().is_some_and(|pb| pb.wants_replay(i));
        if wants_replay {
            self.pool.read_row_into(i, q_replay, &mut self.replay_row)?;
        }
        {
            let comp = self.comm.comp.as_deref().expect("encode sweep requires a compressor");
            let mut scratch_stored = false;
            let stored = if wants_replay {
                match kind {
                    PayloadKind::Params => &mut self.replay_stored_t[i],
                    PayloadKind::Tracker => &mut self.replay_stored_y[i],
                }
            } else {
                &mut scratch_stored
            };
            let rp = match self.perturb.as_ref() {
                Some(pb) => {
                    RowPerturb::Pooled { pb, slot: &mut self.replay_row, stored }
                }
                None => RowPerturb::Off,
            };
            encode_row(
                comp,
                self.ef,
                self.comm.seed,
                round,
                i,
                kind,
                &self.x_row,
                &mut self.e_row,
                &mut self.v_row,
                &mut self.hat_row,
                rp,
                &mut self.enc,
            )?;
        }
        let bad = self.hat_row.iter().any(|v| !v.is_finite());
        match kind {
            PayloadKind::Params => self.bad_t[i] = bad,
            PayloadKind::Tracker => self.bad_y[i] = bad,
        }
        self.pool.write_row(i, q_hat, &self.hat_row)?;
        if self.ef {
            self.pool.write_row(i, q_ef, &self.e_row)?;
        }
        if wants_replay {
            self.pool.write_row(i, q_replay, &self.replay_row)?;
        }
        Ok(())
    }

    /// The encode sweep (compressed/perturbed runs): every *online* node's
    /// payload streams through [`Self::encode_node`], shard by shard.
    /// Per-message keys are `(seed, round, node, kind)` — stateless across
    /// rows and kinds — so the per-node interleaved order (node `i`'s θ
    /// then ϑ) is bitwise-equal to the resident all-θ-then-all-ϑ stack
    /// loops.  Offline rows are skipped: their EF residual carries forward
    /// and their decoded row stays stale, exactly like the resident
    /// `ef_compress_stack`.
    fn encode_sweep(&mut self, round: usize) -> Result<()> {
        let spec = *self.pool.spec();
        for s in 0..spec.n_shards() {
            let (s0, s1) = spec.range(s);
            for i in s0..s1 {
                if !self.online[i] {
                    continue;
                }
                self.encode_node(round, i, PayloadKind::Params)?;
                if self.dsgt {
                    self.encode_node(round, i, PayloadKind::Tracker)?;
                }
            }
        }
        Ok(())
    }

    /// Post-encode non-finite ingest scan (DESIGN.md §14): combine the
    /// per-stream bad flags under the online mask — exactly the resident
    /// `bad_sender` predicate over the decoded stacks — and, on a poisoned
    /// round, rebuild the quarantine-compacted W via the shared
    /// [`compact_from_bad`].  The clean path is a flag scan: no writes, no
    /// allocation.
    fn refresh_quarantine(&mut self) {
        self.wq_active = false;
        let bad_at = |this: &Self, i: usize| {
            this.online[i] && (this.bad_t[i] || (this.dsgt && this.bad_y[i]))
        };
        if !(0..self.n).any(|i| bad_at(self, i)) {
            return;
        }
        self.bad_all.clear();
        for i in 0..self.n {
            let b = bad_at(self, i);
            self.bad_all.push(b);
        }
        let dropped = compact_from_bad(&self.wsp, &self.bad_all, &mut self.wq);
        self.quarantined += dropped;
        self.wq_active = true;
    }

    /// Pool traffic counters (benches / EXP-SH1 / run log).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Currently resident slab rows — the hot-set bound under test.
    pub fn resident_rows(&self) -> usize {
        self.pool.resident_rows()
    }

    /// Consume the driver into its metric log (the scale path: θ is never
    /// materialized).
    pub fn into_log(self) -> RunLog {
        self.log
    }

    /// Consume the driver into (log, final θ stack) — small-n use only;
    /// this is the one call that materializes `n · p` floats.
    pub fn into_result(mut self) -> Result<(RunLog, Vec<f32>)> {
        let (n, p) = (self.n, self.p);
        let mut theta = vec![0.0f32; n * p];
        for i in 0..n {
            self.pool.read_row_into(i, self.qs.theta, &mut theta[i * p..(i + 1) * p])?;
        }
        Ok((self.log, theta))
    }
}

/// Build the compact gather for shard `[s0, s1)` over the round's (possibly
/// quarantine-compacted) W: own rows map to `[0, own_len)`, halo columns
/// (cut-edge endpoints of *online* own rows) to `[own_len, ..)` in
/// first-appearance order, and `ridx`/`roff` hold the entry-order-preserving
/// CSR remap per own row.  Free function so the caller can hand in either
/// of its W fields while borrowing the scratch buffers disjointly.
#[allow(clippy::too_many_arguments)]
fn build_halo(
    w: &SparseW,
    online: &[bool],
    s0: usize,
    s1: usize,
    g2l: &mut [u32],
    halo: &mut Vec<u32>,
    ridx: &mut Vec<u32>,
    roff: &mut Vec<usize>,
) {
    let own_len = s1 - s0;
    halo.clear();
    ridx.clear();
    roff.clear();
    for (k, v) in g2l[s0..s1].iter_mut().enumerate() {
        *v = k as u32;
    }
    for i in s0..s1 {
        roff.push(ridx.len());
        if !online[i] {
            continue; // kernel skipped; empty remap range
        }
        let (idx, _) = w.row(i);
        for &c in idx {
            let cu = c as usize;
            if g2l[cu] == u32::MAX {
                g2l[cu] = (own_len + halo.len()) as u32;
                halo.push(c);
            }
            ridx.push(g2l[cu]);
        }
    }
    roff.push(ridx.len());
}

/// Gather quantity `q` rows for shard `[s0, s1)`'s compact stack
/// `[own rows; halo rows]` into `stack` (grow-only buffer).  Free function
/// so the caller can borrow the pool, the halo list, and the stack buffer
/// as disjoint fields.
fn gather_stack(
    pool: &mut NodeSlabPool,
    halo: &[u32],
    s0: usize,
    s1: usize,
    q: usize,
    p: usize,
    stack: &mut Vec<f32>,
) -> Result<()> {
    let own_len = s1 - s0;
    let need = (own_len + halo.len()) * p;
    if stack.len() < need {
        stack.resize(need, 0.0);
    }
    for i in s0..s1 {
        let li = i - s0;
        pool.read_row_into(i, q, &mut stack[li * p..(li + 1) * p])?;
    }
    for (k, &j) in halo.iter().enumerate() {
        let li = own_len + k;
        pool.read_row_into(j as usize, q, &mut stack[li * p..(li + 1) * p])?;
    }
    Ok(())
}

impl super::Driver for ShardedSync<'_> {
    fn begin(&mut self) -> Result<()> {
        if self.dsgt {
            // DSGT init sweep: Y⁰ = G⁰ = ∇g(θ⁰) on one fresh comm batch per
            // node — the same (seed, node)-keyed draw the resident
            // `DsgtStrategy::init` makes, in the same per-node stream order
            let spec = *self.pool.spec();
            for s in 0..spec.n_shards() {
                let (s0, s1) = spec.range(s);
                for i in s0..s1 {
                    self.samplers[i].batch(&self.shards[i], &mut self.cx, &mut self.cy);
                    self.pool.read_row_into(i, self.qs.theta, &mut self.t_out)?;
                    let (_, gi) = self.model.loss_and_grad(&self.t_out, &self.cx, &self.cy);
                    self.pool.write_row(i, self.qs.y, &gi)?;
                    self.pool.write_row(i, self.qs.g, &gi)?;
                }
            }
        }
        self.observe(0, 0)
    }

    fn local_phase(&mut self, round: usize, lrs: &[f32]) -> Result<()> {
        let spec = *self.pool.spec();
        let local = lrs.len();
        if self.csched.is_uniform() {
            for s in 0..spec.n_shards() {
                let (s0, s1) = spec.range(s);
                for i in s0..s1 {
                    // per-node streams are independent, so drawing
                    // node-by-node inside the shard sweep yields the
                    // identical batches the resident whole-fleet draw does
                    self.samplers[i].batches(&self.shards[i], local, &mut self.lx, &mut self.ly);
                    self.pool.read_row_into(i, self.qs.theta, &mut self.t_out)?;
                    self.model.local_steps_into(
                        &mut self.t_out,
                        &self.lx,
                        &self.ly,
                        lrs,
                        &mut self.step_losses[..local],
                        &mut self.ws,
                    );
                    // local steps touch no cross-node state: the in-place
                    // front write equals the resident back write + swap
                    self.pool.write_row(i, self.qs.theta, &self.t_out)?;
                }
            }
            self.acct.local_compute(local as u64, self.compute_s_per_step);
            return Ok(());
        }
        // heterogeneous plan: per-node τ-truncated local steps, then the
        // FedNova-style τ-weighted displacement rescale, exactly mirroring
        // the resident `local_steps_hetero_into` fan-out; the round's
        // compute time is charged once in comm_phase (slowest participant)
        self.csched.taus_into(round, &mut self.taus);
        self.csched.tau_weights_into(&self.taus, &mut self.tau_ws);
        let (m, d) = (self.m, self.d);
        for s in 0..spec.n_shards() {
            let (s0, s1) = spec.range(s);
            for i in s0..s1 {
                // every row draws its full Q−1 batches regardless of τ —
                // stragglers use only their prefix, so the (seed, row)-keyed
                // sampler streams stay plan-independent (§7)
                self.samplers[i].batches(&self.shards[i], local, &mut self.lx, &mut self.ly);
                let li = self.taus[i].saturating_sub(1).min(local);
                if li == 0 {
                    continue; // θ unchanged, displacement zero
                }
                self.pool.read_row_into(i, self.qs.theta, &mut self.t_prev)?;
                self.t_out.copy_from_slice(&self.t_prev);
                self.model.local_steps_into(
                    &mut self.t_out,
                    &self.lx[..li * m * d],
                    &self.ly[..li * m],
                    &lrs[..li],
                    &mut self.step_losses[..li],
                    &mut self.ws,
                );
                let w = self.tau_ws[i];
                if w != 1.0 {
                    scale_displacement(&mut self.t_out, &self.t_prev, w);
                }
                self.pool.write_row(i, self.qs.theta, &self.t_out)?;
            }
        }
        Ok(())
    }

    fn comm_phase(&mut self, round: usize, lr: f32) -> Result<()> {
        self.refresh_net(round)?;
        if self.compressing {
            self.encode_sweep(round)?;
            self.refresh_quarantine();
        }
        // honest uncompressed runs never produce a non-finite θ row, so the
        // plain path skips the ingest scan (DESIGN.md §15); every attacked
        // or DP'd run is routed through the encode sweep above
        let spec = *self.pool.spec();
        let p = self.p;
        for s in 0..spec.n_shards() {
            let (s0, s1) = spec.range(s);
            build_halo(
                if self.wq_active { &self.wq } else { &self.wsp },
                &self.online,
                s0,
                s1,
                &mut self.g2l,
                &mut self.halo,
                &mut self.ridx,
                &mut self.roff,
            );
            // compressed rounds mix the decoded stacks; plain rounds mix
            // the raw quantities — same stacks the resident strategies hand
            // their round kernels
            let (q_mix_t, q_mix_y) = if self.compressing {
                (self.qs.xhat, self.qs.yhat)
            } else {
                (self.qs.theta, self.qs.y)
            };
            gather_stack(&mut self.pool, &self.halo, s0, s1, q_mix_t, p, &mut self.stack_t)?;
            if self.dsgt {
                gather_stack(&mut self.pool, &self.halo, s0, s1, q_mix_y, p, &mut self.stack_y)?;
            }
            for i in s0..s1 {
                let li = i - s0;
                // every row draws its batch every round — (seed, node)-keyed
                // streams stay plan- and shard-independent; skipped rows
                // discard theirs, exactly like the resident strategies
                self.samplers[i].batch(&self.shards[i], &mut self.cx, &mut self.cy);
                if !self.online[i] || self.is_attacker(i) {
                    // offline: next = previous (restore_offline_rows);
                    // attacker: broadcasts poison but never applies the
                    // update (restore_attacker_rows) — either way the front
                    // quantities copy straight to their back buffers
                    self.pool.read_row_into(i, self.qs.theta, &mut self.t_out)?;
                    self.pool.write_row(i, self.qs.theta_back, &self.t_out)?;
                    if self.dsgt {
                        self.pool.read_row_into(i, self.qs.y, &mut self.y_out)?;
                        self.pool.write_row(i, self.qs.y_back, &self.y_out)?;
                        self.pool.read_row_into(i, self.qs.g, &mut self.g_out)?;
                        self.pool.write_row(i, self.qs.g_back, &self.g_out)?;
                    }
                    continue;
                }
                let (idx, val) =
                    if self.wq_active { self.wq.row(i) } else { self.wsp.row(i) };
                let r = self.roff[li]..self.roff[li + 1];
                debug_assert_eq!(idx.len(), r.len());
                // self_col is the row's compact-stack position: the k<3
                // keep-self guard and the Krum/trim tie-breaks key on the
                // participant's position among the row's entries, which the
                // order-preserving remap leaves invariant
                if self.compressing {
                    self.pool.read_row_into(i, self.qs.theta, &mut self.t_prev)?;
                    if self.dsgt {
                        self.pool.read_row_into(i, self.qs.y, &mut self.y_row)?;
                        self.pool.read_row_into(i, self.qs.g, &mut self.g_row)?;
                        self.model.dsgt_node_compressed_rule_into(
                            self.rule,
                            li as u32,
                            &self.ridx[r],
                            val,
                            &self.stack_t,
                            &self.stack_y,
                            &self.stack_t[li * p..(li + 1) * p],
                            &self.stack_y[li * p..(li + 1) * p],
                            &self.t_prev,
                            &self.y_row,
                            &self.g_row,
                            &self.cx,
                            &self.cy,
                            lr,
                            &mut self.t_out,
                            &mut self.y_out,
                            &mut self.g_out,
                            &mut self.ws,
                        );
                        self.pool.write_row(i, self.qs.theta_back, &self.t_out)?;
                        self.pool.write_row(i, self.qs.y_back, &self.y_out)?;
                        self.pool.write_row(i, self.qs.g_back, &self.g_out)?;
                    } else {
                        self.model.dsgd_node_compressed_rule_into(
                            self.rule,
                            li as u32,
                            &self.ridx[r],
                            val,
                            &self.stack_t,
                            &self.stack_t[li * p..(li + 1) * p],
                            &self.t_prev,
                            &self.cx,
                            &self.cy,
                            lr,
                            &mut self.t_out,
                            &mut self.ws,
                        );
                        self.pool.write_row(i, self.qs.theta_back, &self.t_out)?;
                    }
                } else if self.dsgt {
                    self.pool.read_row_into(i, self.qs.g, &mut self.g_row)?;
                    self.model.dsgt_node_rule_into(
                        self.rule,
                        li as u32,
                        &self.ridx[r],
                        val,
                        &self.stack_t,
                        &self.stack_y,
                        &self.stack_y[li * p..(li + 1) * p],
                        &self.g_row,
                        &self.cx,
                        &self.cy,
                        lr,
                        &mut self.t_out,
                        &mut self.y_out,
                        &mut self.g_out,
                        &mut self.ws,
                    );
                    self.pool.write_row(i, self.qs.theta_back, &self.t_out)?;
                    self.pool.write_row(i, self.qs.y_back, &self.y_out)?;
                    self.pool.write_row(i, self.qs.g_back, &self.g_out)?;
                } else {
                    self.model.dsgd_node_rule_into(
                        self.rule,
                        li as u32,
                        &self.ridx[r],
                        val,
                        &self.stack_t,
                        &self.stack_t[li * p..(li + 1) * p],
                        &self.cx,
                        &self.cy,
                        lr,
                        &mut self.t_out,
                        &mut self.ws,
                    );
                    self.pool.write_row(i, self.qs.theta_back, &self.t_out)?;
                }
            }
            self.reset_halo(s0, s1);
        }
        self.pool.swap_quantities(self.qs.theta, self.qs.theta_back);
        if self.dsgt {
            self.pool.swap_quantities(self.qs.y, self.qs.y_back);
            self.pool.swap_quantities(self.qs.g, self.qs.g_back);
        }
        // analytic accounting, byte-for-byte the resident fused charges:
        // forward this round's quarantine events (the counter is
        // cumulative; the accountant wants the delta) ...
        if self.quarantined > self.q_reported {
            self.acct.report_quarantine(self.quarantined - self.q_reported);
            self.q_reported = self.quarantined;
        }
        // ... then the compute phase (one comm gradient under the uniform
        // plan; the straggler-aware slowest participant otherwise) and per
        // kind (θ; DSGT adds ϑ) one encoded message per active directed edge
        if self.csched.is_uniform() {
            self.acct.local_compute(1, self.compute_s_per_step);
        } else {
            self.work_done += self.taus.iter().map(|&t| t as u64).sum::<u64>();
            self.acct.compute_seconds(self.csched.round_compute_s_from(
                round,
                &self.taus,
                self.compute_s_per_step,
            ));
        }
        let msg = self.comm.msg_bytes(p);
        let kind_bytes = [msg, msg];
        let kinds = if self.dsgt { 2 } else { 1 };
        self.acct.comm_round(self.round_edges, &kind_bytes[..kinds]);
        Ok(())
    }

    fn observe(&mut self, round: u64, local_steps: u64) -> Result<()> {
        // honest-subfleet filter (DESIGN.md §14): under an active attack
        // with 0 < honest < n, both eval passes skip attacker rows — the
        // ascending left fold over the honest subset is bitwise what the
        // resident `eval_honest_subset` computes over its compacted stack
        let attackers =
            self.perturb.as_ref().filter(|pb| pb.attack.active()).map_or(0, |pb| pb.attack.attackers());
        let subset = attackers > 0 && attackers < self.n;
        // pass 1: per-node eval folded shard-by-shard through StreamingEval
        // — the identical left fold the resident eval_reduce runs, so the
        // metrics agree bitwise with the resident path
        let mut se = StreamingEval::new(self.p);
        for i in 0..self.n {
            if subset && self.is_attacker(i) {
                continue;
            }
            self.pool.read_row_into(i, self.qs.theta, &mut self.t_out)?;
            let (loss, grad, correct, total) = self.model.eval_node(&self.t_out, &self.shards[i]);
            se.push_node(loss, &grad, correct, total, &self.t_out);
        }
        // pass 2: consensus against the pass-1 mean, same sweep order
        let mut cp = se.into_consensus_pass();
        for i in 0..self.n {
            if subset && self.is_attacker(i) {
                continue;
            }
            self.pool.read_row_into(i, self.qs.theta, &mut self.t_out)?;
            cp.push_row(&self.t_out);
        }
        let eval = cp.finish();
        // heterogeneous plans report the TRUE mean per-node work done
        let steps = if self.csched.is_uniform() {
            local_steps
        } else {
            self.work_done / self.csched.n() as u64
        };
        let mut m = round_metrics(
            round,
            steps,
            eval,
            self.acct.snapshot(),
            self.started.elapsed().as_secs_f64(),
        );
        m.dp_epsilon = self.dp.epsilon(self.dp_kinds * round);
        let st = self.pool.stats();
        m.pool_loads = st.loads;
        m.pool_spills = st.spills;
        m.pool_writebacks = st.writebacks;
        m.pool_hits = st.hits;
        self.log.push(m);
        Ok(())
    }
}

// ------------------------------------------------------ entry points ----

/// Train a gossip config through the sharded driver; returns the metric
/// log and the final θ stack (materialized once, at the end — for the
/// pinned-equivalence tests and small-n callers).
pub fn train(
    cfg: &ExperimentConfig,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &SparseW,
) -> Result<(RunLog, Vec<f32>)> {
    let engine = super::RoundEngine::from_config(cfg);
    let mut driver = ShardedSync::new(cfg, ds, graph, w)?;
    engine.run(&mut driver)?;
    driver.into_result()
}

/// Train through the sharded driver, log only — the 10⁵⁺-node path: the
/// full θ stack is never materialized, before, during, or after the run.
pub fn train_log(
    cfg: &ExperimentConfig,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &SparseW,
) -> Result<RunLog> {
    let engine = super::RoundEngine::from_config(cfg);
    let mut driver = ShardedSync::new(cfg, ds, graph, w)?;
    engine.run(&mut driver)?;
    Ok(driver.into_log())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg_of(names: &[&'static str]) -> QuantityRegistry {
        let mut reg = QuantityRegistry::new();
        for n in names {
            reg.register(n);
        }
        reg
    }

    #[test]
    fn spec_partitions_exactly() {
        let s = ShardSpec { n: 10, shard_nodes: 4 };
        assert_eq!(s.n_shards(), 3);
        assert_eq!(s.range(0), (0, 4));
        assert_eq!(s.range(2), (8, 10));
        assert_eq!(s.shard_of(7), 1);
        assert_eq!(s.shard_of(9), 2);
    }

    #[test]
    fn registry_assigns_dense_ids_in_order() {
        let mut reg = QuantityRegistry::new();
        assert_eq!(reg.register("theta"), 0);
        assert_eq!(reg.register("theta_back"), 1);
        assert_eq!(reg.register("xhat"), 2);
        assert_eq!(reg.count(), 3);
        assert_eq!(reg.name(2), "xhat");
    }

    #[test]
    fn quantity_set_tracks_config_axes() {
        use crate::config::AlgoKind;
        let base = || {
            let mut cfg = ExperimentConfig::default();
            cfg.algo = AlgoKind::FdDsgd;
            cfg
        };
        // honest DSGD: θ front/back only
        let (reg, qs) = QuantitySet::for_config(&base()).unwrap();
        assert_eq!(reg.count(), 2);
        assert_eq!(qs.xhat, UNREGISTERED);
        // honest DSGT: + tracker/gradient pairs
        let mut cfg = base();
        cfg.algo = AlgoKind::FdDsgt;
        let (reg, qs) = QuantitySet::for_config(&cfg).unwrap();
        assert_eq!(reg.count(), 6);
        assert_eq!((qs.y, qs.g_back), (2, 5));
        // q8 + EF DSGT: + decoded rows + residuals
        cfg.compress = "q8".into();
        cfg.error_feedback = true;
        let (reg, qs) = QuantitySet::for_config(&cfg).unwrap();
        assert_eq!(reg.count(), 10);
        assert_ne!(qs.xhat, UNREGISTERED);
        assert_ne!(qs.ef_y, UNREGISTERED);
        assert_eq!(qs.replay_t, UNREGISTERED);
        // stale-replay attack on uncompressed DSGD: decoded rows appear
        // (Identity install) plus the pooled replay slot, but no EF
        let mut cfg = base();
        cfg.attack_plan = "stale-replay".into();
        cfg.attack_frac = 0.25;
        let (reg, qs) = QuantitySet::for_config(&cfg).unwrap();
        assert_eq!(reg.count(), 4);
        assert_ne!(qs.xhat, UNREGISTERED);
        assert_ne!(qs.replay_t, UNREGISTERED);
        assert_eq!(qs.ef_t, UNREGISTERED);
    }

    #[test]
    fn pool_roundtrips_rows_through_eviction() {
        // 6 nodes, shards of 2 (3 shards), hot-set of 1 frame: every write
        // to a new shard evicts the previous one, so reads exercise both
        // the resident-frame and the spill-file paths
        let p = 5;
        let mut pool = NodeSlabPool::new(6, 2, 1, p, reg_of(&["a", "b"])).unwrap();
        let row = |i: usize, q: usize| -> Vec<f32> {
            (0..p).map(|k| (i * 100 + q * 10 + k) as f32).collect()
        };
        for i in 0..6 {
            pool.write_row(i, 0, &row(i, 0)).unwrap();
            pool.write_row(i, 1, &row(i, 1)).unwrap();
        }
        assert!(pool.resident_rows() <= 2, "hot-set bound: 1 frame × 2 nodes");
        let mut buf = vec![0.0f32; p];
        for i in 0..6 {
            for q in 0..2 {
                pool.read_row_into(i, q, &mut buf).unwrap();
                assert_eq!(buf, row(i, q), "node {i} q {q}");
            }
        }
        let st = pool.stats();
        assert!(st.spills > 0, "a 1-frame pool over 3 shards must evict");
        assert!(st.writebacks > 0, "dirty frames must hit the spill file");
        assert!(st.writebacks <= st.spills, "clean evictions cost no I/O");
        assert!(st.loads > 0);
    }

    #[test]
    fn write_path_evictions_are_dirty_and_halo_reads_bypass_the_pool() {
        // `acquire` is only reachable through `write_row`, which dirties the
        // frame immediately — so every eviction in the write path costs a
        // writeback (writebacks == spills), while `read_row_into` of a cold
        // shard goes straight to the file: no acquire, no eviction, no
        // residency change.  (The writebacks < spills case needs a read-only
        // acquiring accessor, which the sweep deliberately does not have.)
        let p = 3;
        let mut pool = NodeSlabPool::new(6, 2, 1, p, reg_of(&["a"])).unwrap();
        pool.write_row(0, 0, &[1.0; 3]).unwrap(); // shard 0 hot
        pool.write_row(2, 0, &[2.0; 3]).unwrap(); // evicts dirty shard 0
        pool.write_row(0, 0, &[3.0; 3]).unwrap(); // evicts dirty shard 1
        pool.write_row(4, 0, &[4.0; 3]).unwrap(); // evicts dirty shard 0
        let mut buf = vec![0.0f32; p];
        pool.read_row_into(0, 0, &mut buf).unwrap(); // cold: file path
        assert_eq!(buf, [3.0; 3]);
        let st = pool.stats();
        assert!(st.spills > 0);
        assert_eq!(st.spills, st.writebacks, "every write-path eviction is dirty");
        // halo-style read of a cold shard never evicts anything
        let spills_before = st.spills;
        pool.read_row_into(2, 0, &mut buf).unwrap();
        assert_eq!(buf, [2.0; 3]);
        assert_eq!(pool.stats().spills, spills_before, "halo reads bypass the pool");
        assert_eq!(pool.resident_rows(), 2, "shard 2 alone stays resident");
    }

    #[test]
    fn quantity_swap_moves_no_data() {
        let p = 3;
        let mut pool = NodeSlabPool::new(2, 2, 1, p, reg_of(&["front", "back"])).unwrap();
        pool.write_row(0, 0, &[1.0; 3]).unwrap();
        pool.write_row(0, 1, &[2.0; 3]).unwrap();
        pool.swap_quantities(0, 1);
        let mut buf = vec![0.0f32; p];
        pool.read_row_into(0, 0, &mut buf).unwrap();
        assert_eq!(buf, [2.0; 3]);
        pool.read_row_into(0, 1, &mut buf).unwrap();
        assert_eq!(buf, [1.0; 3]);
        // and the swap survives a spill/reload cycle (offsets go through
        // the same qmap on the file side)
        pool.write_row(1, 0, &[9.0; 3]).unwrap(); // same shard — stays hot
        let mut other = NodeSlabPool::new(2, 1, 1, p, reg_of(&["front", "back"])).unwrap();
        other.write_row(0, 0, &[5.0; 3]).unwrap();
        other.swap_quantities(0, 1);
        other.write_row(1, 0, &[7.0; 3]).unwrap(); // evicts shard 0
        other.read_row_into(0, 1, &mut buf).unwrap(); // file path
        assert_eq!(buf, [5.0; 3]);
    }

    #[test]
    fn spill_file_is_removed_on_drop() {
        let pool = NodeSlabPool::new(4, 2, 1, 3, reg_of(&["a", "b"])).unwrap();
        let path = pool.path.clone();
        assert!(path.exists());
        drop(pool);
        assert!(!path.exists());
    }

    fn tiny_assembly() -> (FederatedDataset, Graph, SparseW) {
        let ds = crate::data::generate(&crate::data::DataConfig {
            n_hospitals: 4,
            records_per_hospital: 30,
            records_jitter: 0,
            ..crate::data::DataConfig::default()
        })
        .unwrap();
        let graph =
            Graph::build(&crate::graph::Topology::Ring, 4, &mut crate::rng::Pcg64::seed(0))
                .unwrap();
        let w = crate::mixing::build_sparse(&graph, crate::mixing::Scheme::Metropolis);
        (ds, graph, w)
    }

    #[test]
    fn unsupported_axes_bail_loudly() {
        // the EXHAUSTIVE refusal set: only structural incompatibilities
        // remain — non-gossip algorithms, the PJRT backend, the actor/async
        // drivers, and loss injection.  Compression, attacks, robust rules,
        // DP, and straggler plans are shard-native (tests/shard_pins.rs
        // pins them bitwise against the resident driver).
        let base = || {
            let mut cfg = ExperimentConfig::default();
            cfg.backend = Backend::Native;
            cfg.shard_nodes = 4;
            cfg
        };
        let (ds, graph, w) = tiny_assembly();
        for (patch, needle) in [
            (
                Box::new(|c: &mut ExperimentConfig| c.algo = AlgoKind::FedAvg)
                    as Box<dyn Fn(&mut ExperimentConfig)>,
                "gossip",
            ),
            (
                Box::new(|c: &mut ExperimentConfig| c.algo = AlgoKind::Centralized),
                "gossip",
            ),
            (Box::new(|c: &mut ExperimentConfig| c.backend = Backend::Pjrt), "native"),
            (Box::new(|c: &mut ExperimentConfig| c.driver = "async".into()), "sync"),
            (Box::new(|c: &mut ExperimentConfig| c.mode = Mode::Actors), "fused"),
            (Box::new(|c: &mut ExperimentConfig| c.drop_prob = 0.1), "lossless"),
        ] {
            let mut cfg = base();
            patch(&mut cfg);
            let err = train(&cfg, &ds, &graph, &w).unwrap_err().to_string();
            assert!(err.contains(needle), "wanted `{needle}` in: {err}");
        }
    }

    #[test]
    fn previously_refused_axes_now_run() {
        // the axes PR 10 made shard-native construct and train: one tiny
        // run per axis family (the full sharded==resident bitwise matrix
        // lives in tests/shard_pins.rs)
        let (ds, graph, w) = tiny_assembly();
        for patch in [
            Box::new(|c: &mut ExperimentConfig| c.compress = "q8".into())
                as Box<dyn Fn(&mut ExperimentConfig)>,
            Box::new(|c: &mut ExperimentConfig| {
                c.compress = "top-k".into();
                c.topk_frac = 0.25;
                c.error_feedback = true;
            }),
            Box::new(|c: &mut ExperimentConfig| c.robust_rule = "median".into()),
            Box::new(|c: &mut ExperimentConfig| {
                c.attack_plan = "sign-flip".into();
                c.attack_frac = 0.25;
                c.robust_rule = "trimmed-mean".into();
                c.robust_trim = 0.25;
            }),
            Box::new(|c: &mut ExperimentConfig| {
                c.attack_plan = "stale-replay".into();
                c.attack_frac = 0.25;
            }),
            Box::new(|c: &mut ExperimentConfig| c.dp = "gaussian".into()),
            Box::new(|c: &mut ExperimentConfig| c.compute_plan = "fixed-tiers".into()),
        ] {
            let mut cfg = ExperimentConfig::default();
            cfg.backend = Backend::Native;
            cfg.algo = AlgoKind::FdDsgt;
            cfg.n = 4;
            cfg.hidden = 4;
            cfg.m = 4;
            cfg.q = 3;
            cfg.total_steps = 12;
            cfg.eval_every = 2;
            cfg.records_per_hospital = 30;
            cfg.shard_nodes = 2;
            cfg.hot_shards = 1;
            patch(&mut cfg);
            let (log, theta) = train(&cfg, &ds, &graph, &w)
                .unwrap_or_else(|e| panic!("axis run failed: {e}"));
            assert!(log.rows.last().unwrap().loss.is_finite());
            assert!(theta.iter().all(|v| v.is_finite()));
        }
    }
}
