//! The driver-agnostic message pipeline — every byte a node sends passes
//! through here, in every driver.
//!
//! Encode → attack → DP-noise → decode → quarantine is the same sequence in
//! the fused sync driver (`strategy.rs`), the actor runtime
//! (`coordinator::actors`), the async event queue (`engine::asynchrony`),
//! and the spill-backed sharded sweep (`engine::shard`).  It used to be
//! duplicated per driver; this module is the single implementation, so
//! poisoned/compressed/noised wire bytes are identical everywhere **by
//! construction** — the fused==actors==async==sharded bitwise pins reduce to
//! "everyone calls the same function with the same `(seed, round, node,
//! kind)` key".
//!
//! The pieces, in wire order:
//!
//! 1. [`encode_row`] — one node's one payload: error-feedback compensation
//!    (`v = x + e`), the [`MsgPerturb`] attack/DP stage at the encode
//!    boundary (via [`RowPerturb`], which lets a pooled driver keep the
//!    stale-replay state in its slab pool), deterministic encode under the
//!    `(seed, round, node, kind)` key, decode into x̂, and the residual
//!    update `e ← v − x̂`.  [`ef_compress_stack`] is the whole-stack loop
//!    the fused strategies run; [`encode_row_owned`] the per-message form
//!    the actor/async runtimes send.
//! 2. [`quarantine_compact`] / [`compact_from_bad`] — the non-finite ingest
//!    guard (DESIGN.md §14): drop entries from poisoned senders and fold
//!    their weights into each receiver's self-weight, preserving row sums
//!    and CSR entry order (robust combine rules key on entry counts, so the
//!    compaction must be byte-stable across drivers).
//! 3. [`restore_offline_rows`] / [`restore_attacker_rows`] — the post-mix
//!    row semantics: offline nodes skip the update; Byzantine nodes
//!    broadcast poison but never apply the update themselves.
//! 4. [`eval_honest_subset`] — the honest-sub-fleet metric filter shared by
//!    every driver's observe step.
//!
//! [`RoundNet`] — the per-round network view the schedule emits — lives
//! here too: it is the pipeline's graph-side input, common to all drivers.

use super::adversary::{AttackSchedule, MsgPerturb};
use crate::compress::{add_residual, decode_into, residual_update, Compressor, Encoded, MsgKey};
use crate::coordinator::compute::{Compute, MixView};
use crate::data::Shard;
use crate::mixing::SparseW;
use crate::netsim::PayloadKind;
use anyhow::{ensure, Result};

/// The network of ONE communication round, as the schedule emitted it.
pub struct RoundNet<'a> {
    /// Row-major dense f32 mixing matrix `[n, n]` for this round — present
    /// only when the backend asked for it (`Compute::wants_dense_w`); the
    /// sparse-native path never materializes it (n×n is 40 GB at n = 10⁵).
    pub w: Option<&'a [f32]>,
    /// Degree-sparse CSR view of the round's mixing matrix (per-node
    /// `(neighbor, weight)` rows, ascending) — always present; what the
    /// native gossip kernels consume.
    pub sparse: &'a SparseW,
    /// Per-node participation mask (all `true` except under node churn).
    pub online: &'a [bool],
}

impl RoundNet<'_> {
    /// Is every node participating this round (no churn)?
    pub fn all_online(&self) -> bool {
        self.online.iter().all(|&b| b)
    }

    /// Both W forms, packaged for the compute layer.
    pub fn mix(&self) -> MixView<'_> {
        MixView { dense: self.w, sparse: self.sparse }
    }
}

/// Overwrite the stack rows of offline nodes with their previous values —
/// an offline node skips the communication update entirely (exactly what
/// its actor-driver counterpart does by not gossiping that round).
pub fn restore_offline_rows(next: &mut [f32], prev: &[f32], online: &[bool], p: usize) {
    for (i, &on) in online.iter().enumerate() {
        if !on {
            next[i * p..(i + 1) * p].copy_from_slice(&prev[i * p..(i + 1) * p]);
        }
    }
}

/// Byzantine nodes follow their own protocol, not ours: they train honestly
/// on their local shard (the engine's local phase) and broadcast perturbed
/// payloads, but never *apply* the communication update — their row reverts
/// to its pre-comm state after every round (DESIGN.md §14).  This keeps the
/// attack calibrated: a sign-flip attacker broadcasts `−θ` at the honest
/// parameter scale, instead of mixing its own poison back in and growing
/// its state by `(2 − w_ii)` per round until it overflows — an attacker
/// whose payload dwarfs the fleet by 10²⁰ is trivially screened and says
/// nothing about a rule's robustness.  No-op when the attack plan is off.
pub fn restore_attacker_rows(next: &mut [f32], prev: &[f32], attack: &AttackSchedule, p: usize) {
    if !attack.active() {
        return;
    }
    for i in 0..next.len() / p {
        if attack.is_attacker(i) {
            next[i * p..(i + 1) * p].copy_from_slice(&prev[i * p..(i + 1) * p]);
        }
    }
}

/// Is *online* sender `i`'s row non-finite in any of the given payload
/// stacks?  (A sender poisons all its payload kinds at once — one bad kind
/// quarantines the node from both θ and ϑ mixing.)
pub fn bad_sender(stacks: &[&[f32]], online: &[bool], p: usize, i: usize) -> bool {
    online[i] && stacks.iter().any(|s| s[i * p..(i + 1) * p].iter().any(|v| !v.is_finite()))
}

/// Quarantine-compact `src` given the per-sender `bad` mask, into `wq`
/// (reset and refilled): every receiver drops its entries from bad senders
/// and folds their weights into its self-weight, materializing a diagonal
/// entry when the source row had none.  Entry order (ascending columns) and
/// zero-weight entries are preserved — robust combine rules derive their
/// trim/median counts from entry counts, so the compaction must not change
/// them for clean neighbors.  Returns the number of dropped directed
/// entries.  Shared verbatim by the resident fused path and the sharded
/// sweep; `wq` is grow-only, so a warm caller re-compacts allocation-free.
pub fn compact_from_bad(src: &SparseW, bad: &[bool], wq: &mut SparseW) -> u64 {
    let n = bad.len();
    wq.reset(n);
    wq.reserve_rows_nnz(n, src.nnz());
    let mut dropped = 0u64;
    for i in 0..n {
        let (idx, val) = src.row(i);
        // Fold the quarantined neighbors' weights in CSR (ascending-column)
        // order — the actor driver sums in the same order, so the
        // fused==actors bitwise pin survives an active quarantine.
        let mut folded = 0.0f32;
        for (&j, &v) in idx.iter().zip(val) {
            if j as usize != i && bad[j as usize] {
                folded += v;
                dropped += 1;
            }
        }
        let mut diag_done = false;
        for (&j, &v) in idx.iter().zip(val) {
            let ju = j as usize;
            if !diag_done && ju > i {
                // the source row had no self-weight: materialize one to
                // receive the folded mass, keeping columns ascending
                wq.push_entry(i as u32, folded);
                diag_done = true;
            }
            if ju == i {
                wq.push_entry(j, v + folded);
                diag_done = true;
            } else if !bad[ju] {
                wq.push_entry(j, v);
            }
        }
        if !diag_done {
            wq.push_entry(i as u32, folded);
        }
        wq.seal_row();
    }
    dropped
}

/// Non-finite ingest guard (DESIGN.md §14): if any online sender's payload
/// row carries NaN/Inf, build a quarantine-compacted copy of the round's
/// CSR mixing matrix via [`compact_from_bad`], so honest nodes never mix a
/// non-finite value and row sums are preserved.  Returns the compacted W
/// plus the number of dropped directed entries, or `None` on the clean
/// path — which scans allocation-free, preserving the steady-state
/// zero-alloc contract (`tests/alloc_free.rs`).
pub fn quarantine_compact(
    net: &RoundNet,
    stacks: &[&[f32]],
    p: usize,
) -> Result<Option<(SparseW, u64)>> {
    let n = net.online.len();
    if !(0..n).any(|i| bad_sender(stacks, net.online, p, i)) {
        return Ok(None);
    }
    ensure!(
        net.w.is_none(),
        "non-finite neighbor payloads detected, but this backend mixes a dense W; \
         quarantine (folding bad senders into the self-weight, DESIGN.md §14) is \
         sparse-native only — rerun on the native backend"
    );
    let bad: Vec<bool> = (0..n).map(|i| bad_sender(stacks, net.online, p, i)).collect();
    let mut wq = SparseW::empty();
    let dropped = compact_from_bad(net.sparse, &bad, &mut wq);
    Ok(Some((wq, dropped)))
}

/// How the attack/DP stage stores its per-sender stale-replay state inside
/// [`encode_row`]: not at all, inside the [`MsgPerturb`]'s own cache, or in
/// a caller-owned slot (a spill-backed driver registers the replay row as a
/// pooled quantity).  All three produce identical wire bytes.
pub enum RowPerturb<'a> {
    /// Honest run — no perturbation pipeline was built.
    Off,
    /// The driver-owned pipeline with its internal replay cache (fused
    /// strategies, actor nodes, the async simulator).
    Inline(&'a mut MsgPerturb),
    /// Pool-backed: the replay slot is caller storage
    /// ([`MsgPerturb::apply_pooled`]).
    Pooled {
        /// The shared (immutable) perturbation pipeline.
        pb: &'a MsgPerturb,
        /// This sender's persistent replay row for this payload kind.
        slot: &'a mut [f32],
        /// Has `slot` been written at least once?
        stored: &'a mut bool,
    },
}

impl RowPerturb<'_> {
    /// Apply the attack/DP stage to one outgoing message (no-op for `Off`).
    fn apply(&mut self, round: usize, node: usize, kind: u8, data: &mut [f32]) {
        match self {
            RowPerturb::Off => {}
            RowPerturb::Inline(pb) => pb.apply(round, node, kind, data),
            RowPerturb::Pooled { pb, slot, stored } => {
                pb.apply_pooled(round, node, kind, data, slot, stored);
            }
        }
    }
}

/// The per-message pipeline, start to finish, for ONE sender's ONE payload:
/// build the error-compensated message `v = x + e` (or a plain copy when EF
/// is off), run the attack/DP stage on it, encode under the deterministic
/// `(seed, round, node, kind)` key, decode the wire message into `hat`
/// (what every receiver — and the sender itself — mixes), and update the
/// residual in place (`e ← v − x̂`; untouched when EF is off).
///
/// `enc` is a reusable output buffer ([`Compressor::encode_into`] salvages
/// its allocation), so a warm caller encodes allocation-free.  Every driver
/// routes through this function, which is what makes their wire bytes
/// bitwise-identical by construction.
#[allow(clippy::too_many_arguments)]
pub fn encode_row(
    comp: &dyn Compressor,
    ef: bool,
    seed: u64,
    round: usize,
    node: usize,
    kind: PayloadKind,
    x: &[f32],
    e: &mut [f32],
    vbuf: &mut [f32],
    hat: &mut [f32],
    mut perturb: RowPerturb<'_>,
    enc: &mut Encoded,
) -> Result<()> {
    if ef {
        add_residual(x, e, vbuf);
    } else {
        vbuf.copy_from_slice(x);
    }
    perturb.apply(round, node, kind.tag(), vbuf);
    comp.encode_into(vbuf, MsgKey::new(seed, round, node, kind), enc);
    decode_into(enc, hat)?;
    if ef {
        residual_update(vbuf, hat, e);
    }
    Ok(())
}

/// [`encode_row`] returning an owned message — the form the actor and async
/// runtimes use, whose payloads leave the sender (an `Arc`/`Rc` on a
/// channel) rather than staying in a driver slab.
#[allow(clippy::too_many_arguments)]
pub fn encode_row_owned(
    comp: &dyn Compressor,
    ef: bool,
    seed: u64,
    round: usize,
    node: usize,
    kind: PayloadKind,
    x: &[f32],
    e: &mut [f32],
    vbuf: &mut [f32],
    hat: &mut [f32],
    perturb: RowPerturb<'_>,
) -> Result<Encoded> {
    let mut enc = Encoded::Dense(Vec::new());
    encode_row(comp, ef, seed, round, node, kind, x, e, vbuf, hat, perturb, &mut enc)?;
    Ok(enc)
}

/// Error-feedback-compress one whole payload stack for this round: per
/// *online* row `i`, run [`encode_row`] — the error-compensated message
/// `v = x_i + e_i`, the perturbation stage, the deterministic
/// encode/decode into the `xhat` row, and the new residual `v − x̂` written
/// into the residual back slab.  Offline rows carry their residual forward
/// untouched; their `xhat` row is left stale — online neighbors never mix
/// it (absorbed weights are zero), and while the offline node's own kernel
/// row does read it through its identity self-weight, that whole output row
/// is discarded by `restore_offline_rows` right after the round.
///
/// This is the fused twin of the per-node EF step the actor driver runs
/// before broadcasting — both are [`encode_row`], so the decoded stacks
/// (and therefore the trajectories) agree bitwise.
///
/// When a [`MsgPerturb`] pipeline is active (Byzantine attack and/or DP,
/// `engine::adversary`), it is applied to the error-compensated message
/// *before* encoding — the attacker/DP layer corrupts what actually hits
/// the wire, pre-quantization.  The sender's own `xhat` row decodes the
/// corrupted copy too, but an attacker's comm-update output is discarded
/// afterwards ([`restore_attacker_rows`]): Byzantine nodes broadcast
/// poison, they don't follow the update rule.
#[allow(clippy::too_many_arguments)]
pub fn ef_compress_stack(
    comp: &dyn Compressor,
    ef: bool,
    seed: u64,
    round: usize,
    kind: PayloadKind,
    stack: &[f32],
    online: &[bool],
    p: usize,
    e: &[f32],
    e_back: &mut [f32],
    xhat: &mut [f32],
    vbuf: &mut [f32],
    mut perturb: Option<&mut MsgPerturb>,
) -> Result<()> {
    let n = stack.len() / p;
    let mut enc = Encoded::Dense(Vec::new());
    for i in 0..n {
        let row = i * p..(i + 1) * p;
        if ef {
            // seed the in-place residual row with the front copy; offline
            // rows stop here (residual carried forward untouched)
            e_back[row.clone()].copy_from_slice(&e[row.clone()]);
        }
        if !online[i] {
            continue;
        }
        let rp = match perturb.as_deref_mut() {
            Some(pb) => RowPerturb::Inline(pb),
            None => RowPerturb::Off,
        };
        encode_row(
            comp,
            ef,
            seed,
            round,
            i,
            kind,
            &stack[row.clone()],
            &mut e_back[row.clone()],
            vbuf,
            &mut xhat[row],
            rp,
            &mut enc,
        )?;
    }
    Ok(())
}

/// Record-weighted metrics over the **honest sub-fleet** when a Byzantine
/// attack is active (DESIGN.md §14).  An attacker node is adversarial
/// software, not a hospital: its parameter row is arbitrary (sign-flip, for
/// one, makes the attacker's own state grow geometrically, since its row
/// mixes the poison it broadcast), so folding it into the global metric
/// would let the adversary report any loss it likes.  Robustness is judged
/// on what honest sites actually serve — attacker records are excluded from
/// the weighting, and consensus is measured across honest rows.  DP-only
/// pipelines (no attack plan) and the honest defaults keep the full-fleet
/// metric bitwise-unchanged.  Runs at the eval cadence, off the
/// zero-allocation round path, shared by all drivers.
pub fn eval_honest_subset(
    attack: Option<&AttackSchedule>,
    theta: &[f32],
    shards: &[Shard],
    p: usize,
    compute: &dyn Compute,
) -> Result<(f64, f64, f64, f64)> {
    let Some(a) = attack.filter(|a| a.active()) else {
        return compute.eval_full(theta, shards);
    };
    let n = shards.len();
    let keep: Vec<usize> = (0..n).filter(|&i| !a.is_attacker(i)).collect();
    if keep.len() == n || keep.is_empty() {
        // nothing to mask — or a fully Byzantine fleet, which has no honest
        // metric to report; fall back to the whole stack rather than NaN
        return compute.eval_full(theta, shards);
    }
    let mut th = Vec::with_capacity(keep.len() * p);
    let mut sh = Vec::with_capacity(keep.len());
    for &i in &keep {
        th.extend_from_slice(&theta[i * p..(i + 1) * p]);
        sh.push(shards[i].clone());
    }
    compute.eval_full(&th, &sh)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, QuantizeQ8};
    use crate::config::ExperimentConfig;

    #[test]
    fn restore_offline_rows_is_row_exact() {
        let prev = vec![1.0f32, 1.0, 2.0, 2.0, 3.0, 3.0];
        let mut next = vec![9.0f32, 9.0, 8.0, 8.0, 7.0, 7.0];
        restore_offline_rows(&mut next, &prev, &[true, false, true], 2);
        assert_eq!(next, vec![9.0, 9.0, 2.0, 2.0, 7.0, 7.0]);
    }

    #[test]
    fn ef_compress_stack_identity_reconstructs_and_zeroes_residual() {
        let (n, p) = (3usize, 4usize);
        let stack: Vec<f32> = (0..n * p).map(|i| i as f32 * 0.25 - 1.0).collect();
        let online = vec![true, false, true];
        let e: Vec<f32> = vec![0.5f32; n * p];
        let mut e_back = vec![0.0f32; n * p];
        let mut xhat = vec![0.0f32; n * p];
        let mut vbuf = vec![0.0f32; p];
        ef_compress_stack(
            &Identity, true, 7, 2, PayloadKind::Params, &stack, &online, p, &e, &mut e_back,
            &mut xhat, &mut vbuf, None,
        )
        .unwrap();
        // online rows: x̂ = θ + e exactly, residual collapses to zero
        for i in [0usize, 2] {
            for j in 0..p {
                assert_eq!(xhat[i * p + j], stack[i * p + j] + 0.5);
                assert_eq!(e_back[i * p + j], 0.0);
            }
        }
        // offline row: residual carried forward untouched
        assert!(e_back[p..2 * p].iter().all(|&r| r == 0.5));
    }

    #[test]
    fn ef_compress_stack_applies_the_perturbation_at_the_encode_boundary() {
        let (n, p) = (4usize, 3usize);
        let stack = vec![1.0f32; n * p];
        let online = vec![true; n];
        let e = vec![0.0f32; n * p];
        let mut e_back = vec![0.0f32; n * p];
        let mut xhat = vec![0.0f32; n * p];
        let mut vbuf = vec![0.0f32; p];
        let cfg = ExperimentConfig {
            n,
            attack_plan: "sign-flip".into(),
            attack_frac: 0.25,
            ..ExperimentConfig::default()
        };
        let mut pb = MsgPerturb::from_config(&cfg).unwrap().unwrap();
        let attacker = (0..n).find(|&i| pb.attack.is_attacker(i)).unwrap();
        ef_compress_stack(
            &Identity,
            false,
            cfg.seed,
            1,
            PayloadKind::Params,
            &stack,
            &online,
            p,
            &e,
            &mut e_back,
            &mut xhat,
            &mut vbuf,
            Some(&mut pb),
        )
        .unwrap();
        for i in 0..n {
            let want = if i == attacker { -1.0 } else { 1.0 };
            assert!(xhat[i * p..(i + 1) * p].iter().all(|&v| v == want), "row {i}");
        }
    }

    #[test]
    fn encode_row_matches_the_stack_loop_bitwise_per_row() {
        // the sharded driver encodes row by row through encode_row; the
        // fused strategies run the whole-stack loop — the per-row outputs
        // (x̂, residual, wire message) must agree exactly, including under a
        // lossy quantizer and an active perturbation
        let (n, p) = (5usize, 9usize);
        let stack: Vec<f32> = (0..n * p).map(|i| (i as f32 * 0.37).sin()).collect();
        let online = vec![true, true, false, true, true];
        let e: Vec<f32> = (0..n * p).map(|i| (i as f32 * 0.11).cos() * 0.1).collect();
        let cfg = ExperimentConfig {
            n,
            attack_plan: "scaled-noise".into(),
            attack_frac: 0.4,
            attack_scale: 1.5,
            ..ExperimentConfig::default()
        };
        for ef in [false, true] {
            let mut pb_stack = MsgPerturb::from_config(&cfg).unwrap().unwrap();
            let pb_row = MsgPerturb::from_config(&cfg).unwrap().unwrap();
            let mut e_back = vec![0.0f32; n * p];
            let mut xhat = vec![0.0f32; n * p];
            let mut vbuf = vec![0.0f32; p];
            ef_compress_stack(
                &QuantizeQ8,
                ef,
                7,
                3,
                PayloadKind::Params,
                &stack,
                &online,
                p,
                &e,
                &mut e_back,
                &mut xhat,
                &mut vbuf,
                Some(&mut pb_stack),
            )
            .unwrap();
            let mut enc = Encoded::Dense(Vec::new());
            for i in 0..n {
                if !online[i] {
                    continue;
                }
                let mut e_row = e[i * p..(i + 1) * p].to_vec();
                let mut hat = vec![0.0f32; p];
                let mut v = vec![0.0f32; p];
                let mut slot = vec![0.0f32; p];
                let mut stored = false;
                encode_row(
                    &QuantizeQ8,
                    ef,
                    7,
                    3,
                    i,
                    PayloadKind::Params,
                    &stack[i * p..(i + 1) * p],
                    &mut e_row,
                    &mut v,
                    &mut hat,
                    RowPerturb::Pooled { pb: &pb_row, slot: &mut slot, stored: &mut stored },
                    &mut enc,
                )
                .unwrap();
                assert_eq!(hat, xhat[i * p..(i + 1) * p], "ef={ef} row {i}: x̂");
                if ef {
                    assert_eq!(e_row, e_back[i * p..(i + 1) * p], "ef={ef} row {i}: residual");
                }
            }
        }
    }

    #[test]
    fn quarantine_folds_bad_senders_into_self_weight() {
        // 3-node path: W rows sum to 1
        #[rustfmt::skip]
        let dense = vec![
            0.5,  0.5, 0.0,
            0.25, 0.5, 0.25,
            0.0,  0.5, 0.5,
        ];
        let w = SparseW::from_dense(3, &dense);
        let online = [true, true, true];
        let p = 2usize;
        let clean = vec![0.0f32; 6];
        let mut poisoned = clean.clone();
        poisoned[2] = f32::NAN; // node 1's row
        let net = RoundNet { w: None, sparse: &w, online: &online };
        // clean path: no compaction, no allocation
        assert!(quarantine_compact(&net, &[&clean], p).unwrap().is_none());
        let (wq, dropped) = quarantine_compact(&net, &[&poisoned], p).unwrap().unwrap();
        assert_eq!(dropped, 2, "rows 0 and 2 each drop their node-1 entry");
        #[rustfmt::skip]
        let want = vec![
            1.0,  0.0, 0.0,
            0.25, 0.5, 0.25, // the bad node's own row is untouched
            0.0,  0.0, 1.0,
        ];
        assert_eq!(wq.to_dense(), want);
        // a second payload kind can trigger the quarantine on its own
        let (wq2, d2) = quarantine_compact(&net, &[&clean, &poisoned], p).unwrap().unwrap();
        assert_eq!((wq2.to_dense(), d2), (want, 2));
        // dense-W backends cannot compact rows: loud error, not silence
        let dnet = RoundNet { w: Some(&dense), sparse: &w, online: &online };
        let err = quarantine_compact(&dnet, &[&poisoned], p).unwrap_err().to_string();
        assert!(err.contains("sparse-native"), "{err}");
    }

    #[test]
    fn quarantine_materializes_a_missing_self_weight() {
        // node 0 has no diagonal entry: the folded mass must create one,
        // keeping columns ascending
        #[rustfmt::skip]
        let dense = vec![
            0.0, 1.0, 0.0,
            0.5, 0.0, 0.5,
            0.0, 1.0, 0.0,
        ];
        let w = SparseW::from_dense(3, &dense);
        let online = [true, true, true];
        let mut poisoned = vec![0.0f32; 3];
        poisoned[1] = f32::INFINITY; // p = 1, node 1 bad
        let net = RoundNet { w: None, sparse: &w, online: &online };
        let (wq, dropped) = quarantine_compact(&net, &[&poisoned], 1).unwrap().unwrap();
        assert_eq!(dropped, 2);
        #[rustfmt::skip]
        let want = vec![
            1.0, 0.0, 0.0,
            0.5, 0.0, 0.5,
            0.0, 0.0, 1.0,
        ];
        assert_eq!(wq.to_dense(), want);
        // offline senders are never scanned (their weights are already 0)
        let offline = [true, false, true];
        let onet = RoundNet { w: None, sparse: &w, online: &offline };
        assert!(quarantine_compact(&onet, &[&poisoned], 1).unwrap().is_none());
    }

    #[test]
    fn compacting_into_a_warm_buffer_matches_a_fresh_one() {
        // the sharded sweep keeps a persistent wq and re-compacts in place;
        // a dirty buffer must produce the identical matrix
        #[rustfmt::skip]
        let dense = vec![
            0.5,  0.5, 0.0,
            0.25, 0.5, 0.25,
            0.0,  0.5, 0.5,
        ];
        let w = SparseW::from_dense(3, &dense);
        let bad = vec![false, true, false];
        let mut fresh = SparseW::empty();
        let d1 = compact_from_bad(&w, &bad, &mut fresh);
        let mut warm = SparseW::empty();
        compact_from_bad(&w, &[true, false, false], &mut warm); // dirty it
        let d2 = compact_from_bad(&w, &bad, &mut warm);
        assert_eq!(d1, d2);
        assert_eq!(fresh.to_dense(), warm.to_dense());
    }
}
