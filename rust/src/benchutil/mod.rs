//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and call [`bench`] /
//! [`bench_n`]: warmup, then timed iterations with mean / p50 / p95 and
//! throughput reporting.  Deliberately simple — wall-clock medians over
//! enough iterations are adequate for the size of effects the §Perf log
//! tracks (2x-100x, not 2%).

use std::time::Instant;

/// Timing summary of one benched closure.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    /// Measured iterations.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub p50_s: f64,
    /// 95th-percentile seconds per iteration.
    pub p95_s: f64,
    /// Fastest iteration, seconds.
    pub min_s: f64,
}

impl Timing {
    /// Median throughput (iterations per second).
    pub fn per_sec(&self) -> f64 {
        if self.p50_s > 0.0 {
            1.0 / self.p50_s
        } else {
            f64::INFINITY
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench_n<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Timing {
        iters,
        mean_s: mean,
        p50_s: samples[samples.len() / 2],
        p95_s: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        min_s: samples[0],
    }
}

/// Time with auto-chosen iteration count targeting ~`budget_s` seconds.
pub fn bench<F: FnMut()>(budget_s: f64, mut f: F) -> Timing {
    // one probe run to size the loop
    let t = Instant::now();
    f();
    let probe = t.elapsed().as_secs_f64().max(1e-7);
    let iters = ((budget_s / probe) as usize).clamp(3, 10_000);
    bench_n(1, iters, f)
}

/// Pretty row: name, median, mean, throughput.
pub fn report(name: &str, t: &Timing) {
    println!(
        "{name:<36} p50 {:>10} mean {:>10} p95 {:>10}  ({:>8.1}/s, n={})",
        fmt_s(t.p50_s),
        fmt_s(t.mean_s),
        fmt_s(t.p95_s),
        t.per_sec(),
        t.iters
    );
}

/// Human-readable seconds (ns/µs/ms/s auto-scaling).
pub fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// `DECFL_FULL=1 cargo bench` switches to paper-scale parameters.
pub fn full_scale() -> bool {
    std::env::var("DECFL_FULL").map(|v| v == "1").unwrap_or(false)
}

/// `DECFL_SMOKE=1 cargo bench` shrinks workloads to a seconds-long
/// compile-and-run check — the CI bench-smoke step uses this so bench
/// targets can neither bit-rot uncompiled nor panic at runtime.
pub fn smoke() -> bool {
    std::env::var("DECFL_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Timing budget helper: the smoke budget under `DECFL_SMOKE=1`, the given
/// default otherwise.
pub fn budget(default_s: f64) -> f64 {
    if smoke() {
        default_s.min(0.05)
    } else {
        default_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_n_collects_stats() {
        let mut x = 0u64;
        let t = bench_n(1, 10, || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert_eq!(t.iters, 10);
        assert!(t.p50_s >= 0.0 && t.mean_s >= 0.0);
        assert!(t.min_s <= t.p50_s && t.p50_s <= t.p95_s);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_s(2e-9).ends_with("ns"));
        assert!(fmt_s(2e-5).ends_with("µs"));
        assert!(fmt_s(2e-2).ends_with("ms"));
        assert!(fmt_s(2.0).ends_with('s'));
    }
}
