//! Training metrics: the quantities the paper's figures plot, plus the
//! communication accounting the netsim produces.
//!
//! Per evaluated round we record the two Theorem-1 terms (stationarity gap
//! `||(1/N) Σ ∇f_i(θ_i)||²` and consensus error `(1/N) Σ ||θ_i - θ̄||²`),
//! global training loss and accuracy, and the cumulative communication cost
//! (rounds / messages / bytes / simulated seconds).  Fig. 2's x-axis is
//! `comm_rounds`; the comm-cost benches read `bytes`.

use crate::jsonl::{self, Json};
use crate::netsim::NetSnapshot;
use anyhow::Result;

/// One evaluation point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundMetrics {
    /// Communication rounds completed so far (Fig. 2 x-axis).
    pub comm_rounds: u64,
    /// Local SGD iterations completed so far, per node: `round · Q` under
    /// the uniform compute plan; under a straggler plan
    /// (`engine::stragglers`) the TRUE mean work `Σ_r Σ_i τ_i(r) / N`, so
    /// Fig.-1-style x-axes stay honest when stragglers contribute less.
    pub local_steps: u64,
    /// Record-weighted training loss over the pooled records (each node's
    /// mean loss weighted by its shard size — same population as
    /// [`RoundMetrics::accuracy`]).
    pub loss: f64,
    /// Record-weighted training accuracy (correct / total records).
    pub accuracy: f64,
    /// `|| (1/N) Σ_i ∇f_i(θ_i) ||²` on full shards.
    pub stationarity: f64,
    /// `(1/N) Σ_i ||θ_i − θ̄||²`.
    pub consensus: f64,
    /// Cumulative bytes on the wire (encoded sizes).
    pub bytes: u64,
    /// Cumulative messages sent.
    pub messages: u64,
    /// Simulated wall time, seconds.
    pub sim_time_s: f64,
    /// Real wall time since the run started, seconds.
    pub wall_time_s: f64,
    /// Cumulative neighbor payloads quarantined at ingest — malformed or
    /// non-finite messages folded into the self-weight (DESIGN.md §14).
    pub quarantined: u64,
    /// Privacy spent so far: the (ε, δ)-accountant's ε at the configured δ
    /// (`dp.delta`); 0 when the DP layer is off.
    pub dp_epsilon: f64,
}

impl RoundMetrics {
    /// The combined Theorem-1 left-hand side.
    pub fn optimality_gap(&self) -> f64 {
        self.stationarity + self.consensus
    }
}

/// Metric log for one training run.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    /// Algorithm display name.
    pub algo: String,
    /// One row per evaluated round.
    pub rows: Vec<RoundMetrics>,
}

impl RunLog {
    /// Empty log for `algo`.
    pub fn new(algo: &str) -> Self {
        RunLog { algo: algo.to_string(), rows: Vec::new() }
    }

    /// Append an evaluation row.
    pub fn push(&mut self, m: RoundMetrics) {
        self.rows.push(m);
    }

    /// Last evaluation row, if any.
    pub fn last(&self) -> Option<&RoundMetrics> {
        self.rows.last()
    }

    /// First comm-round index at which loss drops to `target` (None = never).
    /// The Q-sweep bench uses this as "rounds to target".
    pub fn rounds_to_loss(&self, target: f64) -> Option<u64> {
        self.rows.iter().find(|r| r.loss <= target).map(|r| r.comm_rounds)
    }

    /// Minimum optimality gap achieved.
    pub fn best_gap(&self) -> f64 {
        self.rows.iter().map(RoundMetrics::optimality_gap).fold(f64::INFINITY, f64::min)
    }

    /// Column-oriented JSON dump.
    pub fn to_json(&self) -> Json {
        let col = |f: &dyn Fn(&RoundMetrics) -> f64| {
            jsonl::arr_f64(&self.rows.iter().map(|r| f(r)).collect::<Vec<_>>())
        };
        jsonl::obj(vec![
            ("algo", jsonl::s(&self.algo)),
            ("comm_rounds", col(&|r| r.comm_rounds as f64)),
            ("local_steps", col(&|r| r.local_steps as f64)),
            ("loss", col(&|r| r.loss)),
            ("accuracy", col(&|r| r.accuracy)),
            ("stationarity", col(&|r| r.stationarity)),
            ("consensus", col(&|r| r.consensus)),
            ("bytes", col(&|r| r.bytes as f64)),
            ("sim_time_s", col(&|r| r.sim_time_s)),
            ("wall_time_s", col(&|r| r.wall_time_s)),
            ("quarantined", col(&|r| r.quarantined as f64)),
            ("dp_epsilon", col(&|r| r.dp_epsilon)),
        ])
    }

    /// CSV with a header, one row per evaluation.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "comm_rounds,local_steps,loss,accuracy,stationarity,consensus,bytes,messages,sim_time_s,wall_time_s,quarantined,dp_epsilon\n",
        );
        for r in &self.rows {
            out.push_str(&format!(
                "{},{},{:.6},{:.4},{:.6e},{:.6e},{},{},{:.4},{:.3},{},{:.4}\n",
                r.comm_rounds,
                r.local_steps,
                r.loss,
                r.accuracy,
                r.stationarity,
                r.consensus,
                r.bytes,
                r.messages,
                r.sim_time_s,
                r.wall_time_s,
                r.quarantined,
                r.dp_epsilon
            ));
        }
        out
    }

    /// Write the JSON dump to `path`.
    pub fn save_json(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

/// Assemble a [`RoundMetrics`] from eval outputs + net accounting.
#[allow(clippy::too_many_arguments)]
pub fn round_metrics(
    comm_rounds: u64,
    local_steps: u64,
    eval: (f64, f64, f64, f64),
    net: NetSnapshot,
    wall_time_s: f64,
) -> RoundMetrics {
    let (loss, accuracy, stationarity, consensus) = eval;
    RoundMetrics {
        comm_rounds,
        local_steps,
        loss,
        accuracy,
        stationarity,
        consensus,
        bytes: net.bytes,
        messages: net.messages,
        sim_time_s: net.sim_time_s,
        wall_time_s,
        quarantined: net.quarantined,
        dp_epsilon: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(cr: u64, loss: f64) -> RoundMetrics {
        RoundMetrics {
            comm_rounds: cr,
            local_steps: cr * 100,
            loss,
            accuracy: 0.8,
            stationarity: 1e-3,
            consensus: 2e-3,
            bytes: cr * 1000,
            messages: cr * 10,
            sim_time_s: cr as f64 * 0.1,
            wall_time_s: cr as f64 * 0.01,
            quarantined: 0,
            dp_epsilon: 0.0,
        }
    }

    #[test]
    fn gap_is_sum_of_terms() {
        let r = row(1, 0.5);
        assert!((r.optimality_gap() - 3e-3).abs() < 1e-12);
    }

    #[test]
    fn rounds_to_loss_finds_first_crossing() {
        let mut log = RunLog::new("dsgt");
        for (cr, l) in [(1, 0.7), (2, 0.55), (3, 0.49), (4, 0.2)] {
            log.push(row(cr, l));
        }
        assert_eq!(log.rounds_to_loss(0.5), Some(3));
        assert_eq!(log.rounds_to_loss(0.1), None);
    }

    #[test]
    fn best_gap_min() {
        let mut log = RunLog::new("x");
        log.push(row(1, 0.7));
        let mut better = row(2, 0.6);
        better.stationarity = 1e-5;
        better.consensus = 1e-5;
        log.push(better);
        assert!((log.best_gap() - 2e-5).abs() < 1e-12);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = RunLog::new("x");
        log.push(row(1, 0.7));
        log.push(row(2, 0.6));
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("comm_rounds,"));
    }

    #[test]
    fn json_roundtrips_and_has_columns() {
        let mut log = RunLog::new("fd-dsgt");
        log.push(row(1, 0.7));
        let j = crate::jsonl::Json::parse(&log.to_json().to_string()).unwrap();
        assert_eq!(j.get("algo").unwrap().as_str().unwrap(), "fd-dsgt");
        assert_eq!(j.get("loss").unwrap().as_f64_vec().unwrap(), vec![0.7]);
    }
}
