//! Training metrics: the quantities the paper's figures plot, plus the
//! communication accounting the netsim produces.
//!
//! Per evaluated round we record the two Theorem-1 terms (stationarity gap
//! `||(1/N) Σ ∇f_i(θ_i)||²` and consensus error `(1/N) Σ ||θ_i - θ̄||²`),
//! global training loss and accuracy, and the cumulative communication cost
//! (rounds / messages / bytes / simulated seconds).  Fig. 2's x-axis is
//! `comm_rounds`; the comm-cost benches read `bytes`.

use crate::algo::l2_dist_sq;
use crate::jsonl::{self, Json};
use crate::netsim::NetSnapshot;
use anyhow::Result;

// --------------------------------------------------- streaming eval ----

/// Kahan-compensated f64 accumulator — one running sum plus its
/// compensation term, so long folds (10⁵–10⁶ nodes) keep full f64
/// accuracy while remaining a pure left fold: the result depends only on
/// the push order, never on how the pushes were batched into shards.
#[derive(Clone, Copy, Debug, Default)]
pub struct Kahan {
    sum: f64,
    c: f64,
}

impl Kahan {
    /// Fold one value into the compensated sum.
    #[inline]
    pub fn add(&mut self, v: f64) {
        let y = v - self.c;
        let t = self.sum + y;
        self.c = (t - self.sum) - y;
        self.sum = t;
    }

    /// The compensated sum so far.
    #[inline]
    pub fn value(&self) -> f64 {
        self.sum
    }
}

/// Streaming two-pass metric reduction over per-node eval partials.
///
/// This is THE eval arithmetic of the crate: `NativeModel::eval_reduce`
/// (and through it every resident `eval_full`, both drivers, and the
/// honest-subfleet filter) folds its partials through this type, and the
/// sharded sweep (`engine::shard`) folds shard by shard through the same
/// type — so resident and sharded metrics are bitwise-equal *by
/// construction*, not by tolerance (pinned in `tests/shard_pins.rs`).
///
/// Pass 1 ([`StreamingEval::push_node`], strictly ascending node order)
/// accumulates the record-weighted loss/accuracy numerators, the
/// Kahan-compensated per-coordinate gradient sums behind the Theorem-1
/// stationarity term, and the per-coordinate θ column sums behind θ̄.
/// [`StreamingEval::into_consensus_pass`] then fixes θ̄ (column mean,
/// rounded to f32 exactly like the resident `row_mean`) and pass 2
/// ([`ConsensusPass::push_row`], same node order) folds each row's
/// squared distance to θ̄.  Because every global quantity is a pure left
/// fold in node order, shard boundaries cannot change a single bit —
/// 1 shard, k shards, and the unsharded path all agree exactly.
#[derive(Clone, Debug)]
pub struct StreamingEval {
    p: usize,
    rows: usize,
    loss_w: Kahan,
    correct: u64,
    total: u64,
    gsum: Vec<Kahan>,
    tsum: Vec<Kahan>,
}

impl StreamingEval {
    /// Fresh accumulator for parameter size `p`.
    pub fn new(p: usize) -> Self {
        StreamingEval {
            p,
            rows: 0,
            loss_w: Kahan::default(),
            correct: 0,
            total: 0,
            gsum: vec![Kahan::default(); p],
            tsum: vec![Kahan::default(); p],
        }
    }

    /// Fold node `i`'s eval partial: its mean shard loss, full-shard
    /// gradient, correct/total record counts, and parameter row.  Nodes
    /// MUST be pushed in ascending node order — the fold order is the
    /// determinism contract.
    pub fn push_node(
        &mut self,
        loss: f64,
        grad: &[f32],
        correct: usize,
        total: usize,
        theta_row: &[f32],
    ) {
        debug_assert_eq!(grad.len(), self.p);
        debug_assert_eq!(theta_row.len(), self.p);
        self.loss_w.add(loss * total as f64);
        for (acc, &g) in self.gsum.iter_mut().zip(grad) {
            acc.add(g as f64);
        }
        for (acc, &t) in self.tsum.iter_mut().zip(theta_row) {
            acc.add(t as f64);
        }
        self.correct += correct as u64;
        self.total += total as u64;
        self.rows += 1;
    }

    /// Close pass 1: fix θ̄ and the pass-1 metrics, returning the
    /// consensus-pass folder that re-visits every row.
    pub fn into_consensus_pass(self) -> ConsensusPass {
        let n = self.rows.max(1) as f64;
        let mut stat = Kahan::default();
        let mut theta_bar = vec![0.0f32; self.p];
        for (j, tb) in theta_bar.iter_mut().enumerate() {
            let m = self.gsum[j].value() / n;
            stat.add(m * m);
            *tb = (self.tsum[j].value() / n) as f32;
        }
        let total = self.total.max(1) as f64;
        ConsensusPass {
            loss: self.loss_w.value() / total,
            accuracy: self.correct as f64 / total,
            stationarity: stat.value(),
            theta_bar,
            rows: self.rows,
            cons: Kahan::default(),
        }
    }
}

/// Pass 2 of [`StreamingEval`]: folds `‖θ_i − θ̄‖²` row by row (same node
/// order as pass 1) and finishes into the metric 4-tuple.
#[derive(Clone, Debug)]
pub struct ConsensusPass {
    loss: f64,
    accuracy: f64,
    stationarity: f64,
    theta_bar: Vec<f32>,
    rows: usize,
    cons: Kahan,
}

impl ConsensusPass {
    /// The fleet-mean parameter vector θ̄ fixed by pass 1.
    pub fn theta_bar(&self) -> &[f32] {
        &self.theta_bar
    }

    /// Fold one node's squared distance to θ̄ (ascending node order, the
    /// same rows pass 1 saw).
    pub fn push_row(&mut self, theta_row: &[f32]) {
        self.cons.add(l2_dist_sq(theta_row, &self.theta_bar));
    }

    /// → (record-weighted loss, record-weighted accuracy, stationarity,
    /// consensus).
    pub fn finish(self) -> (f64, f64, f64, f64) {
        (
            self.loss,
            self.accuracy,
            self.stationarity,
            self.cons.value() / self.rows.max(1) as f64,
        )
    }
}

/// One evaluation point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundMetrics {
    /// Communication rounds completed so far (Fig. 2 x-axis).
    pub comm_rounds: u64,
    /// Local SGD iterations completed so far, per node: `round · Q` under
    /// the uniform compute plan; under a straggler plan
    /// (`engine::stragglers`) the TRUE mean work `Σ_r Σ_i τ_i(r) / N`, so
    /// Fig.-1-style x-axes stay honest when stragglers contribute less.
    pub local_steps: u64,
    /// Record-weighted training loss over the pooled records (each node's
    /// mean loss weighted by its shard size — same population as
    /// [`RoundMetrics::accuracy`]).
    pub loss: f64,
    /// Record-weighted training accuracy (correct / total records).
    pub accuracy: f64,
    /// `|| (1/N) Σ_i ∇f_i(θ_i) ||²` on full shards.
    pub stationarity: f64,
    /// `(1/N) Σ_i ||θ_i − θ̄||²`.
    pub consensus: f64,
    /// Cumulative bytes on the wire (encoded sizes).
    pub bytes: u64,
    /// Cumulative messages sent.
    pub messages: u64,
    /// Simulated wall time, seconds.
    pub sim_time_s: f64,
    /// Real wall time since the run started, seconds.
    pub wall_time_s: f64,
    /// Cumulative neighbor payloads quarantined at ingest — malformed or
    /// non-finite messages folded into the self-weight (DESIGN.md §14).
    pub quarantined: u64,
    /// Privacy spent so far: the (ε, δ)-accountant's ε at the configured δ
    /// (`dp.delta`); 0 when the DP layer is off.
    pub dp_epsilon: f64,
    /// Cumulative slab-pool shard loads from the spill file (sharded runs
    /// only; 0 on the resident path — see `engine::shard::PoolStats`).
    pub pool_loads: u64,
    /// Cumulative slab-pool frame evictions (hot-set pressure).
    pub pool_spills: u64,
    /// Cumulative dirty evictions written back to the spill file
    /// (`pool_writebacks ≤ pool_spills`).
    pub pool_writebacks: u64,
    /// Cumulative slab-pool acquires served by a resident frame.
    pub pool_hits: u64,
}

impl RoundMetrics {
    /// The combined Theorem-1 left-hand side.
    pub fn optimality_gap(&self) -> f64 {
        self.stationarity + self.consensus
    }
}

/// Metric log for one training run.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    /// Algorithm display name.
    pub algo: String,
    /// One row per evaluated round.
    pub rows: Vec<RoundMetrics>,
}

impl RunLog {
    /// Empty log for `algo`.
    pub fn new(algo: &str) -> Self {
        RunLog { algo: algo.to_string(), rows: Vec::new() }
    }

    /// Append an evaluation row.
    pub fn push(&mut self, m: RoundMetrics) {
        self.rows.push(m);
    }

    /// Last evaluation row, if any.
    pub fn last(&self) -> Option<&RoundMetrics> {
        self.rows.last()
    }

    /// First comm-round index at which loss drops to `target` (None = never).
    /// The Q-sweep bench uses this as "rounds to target".
    pub fn rounds_to_loss(&self, target: f64) -> Option<u64> {
        self.rows.iter().find(|r| r.loss <= target).map(|r| r.comm_rounds)
    }

    /// Minimum optimality gap achieved.
    pub fn best_gap(&self) -> f64 {
        self.rows.iter().map(RoundMetrics::optimality_gap).fold(f64::INFINITY, f64::min)
    }

    /// Column-oriented JSON dump.
    pub fn to_json(&self) -> Json {
        let col = |f: &dyn Fn(&RoundMetrics) -> f64| {
            jsonl::arr_f64(&self.rows.iter().map(|r| f(r)).collect::<Vec<_>>())
        };
        jsonl::obj(vec![
            ("algo", jsonl::s(&self.algo)),
            ("comm_rounds", col(&|r| r.comm_rounds as f64)),
            ("local_steps", col(&|r| r.local_steps as f64)),
            ("loss", col(&|r| r.loss)),
            ("accuracy", col(&|r| r.accuracy)),
            ("stationarity", col(&|r| r.stationarity)),
            ("consensus", col(&|r| r.consensus)),
            ("bytes", col(&|r| r.bytes as f64)),
            ("messages", col(&|r| r.messages as f64)),
            ("sim_time_s", col(&|r| r.sim_time_s)),
            ("wall_time_s", col(&|r| r.wall_time_s)),
            ("quarantined", col(&|r| r.quarantined as f64)),
            ("dp_epsilon", col(&|r| r.dp_epsilon)),
            ("pool_loads", col(&|r| r.pool_loads as f64)),
            ("pool_spills", col(&|r| r.pool_spills as f64)),
            ("pool_writebacks", col(&|r| r.pool_writebacks as f64)),
            ("pool_hits", col(&|r| r.pool_hits as f64)),
        ])
    }

    /// CSV with a header, one row per evaluation.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "comm_rounds,local_steps,loss,accuracy,stationarity,consensus,bytes,messages,sim_time_s,wall_time_s,quarantined,dp_epsilon,pool_loads,pool_spills,pool_writebacks,pool_hits\n",
        );
        for r in &self.rows {
            out.push_str(&format!(
                "{},{},{:.6},{:.4},{:.6e},{:.6e},{},{},{:.4},{:.3},{},{:.4},{},{},{},{}\n",
                r.comm_rounds,
                r.local_steps,
                r.loss,
                r.accuracy,
                r.stationarity,
                r.consensus,
                r.bytes,
                r.messages,
                r.sim_time_s,
                r.wall_time_s,
                r.quarantined,
                r.dp_epsilon,
                r.pool_loads,
                r.pool_spills,
                r.pool_writebacks,
                r.pool_hits
            ));
        }
        out
    }

    /// Write the JSON dump to `path`.
    pub fn save_json(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

/// Assemble a [`RoundMetrics`] from eval outputs + net accounting.
#[allow(clippy::too_many_arguments)]
pub fn round_metrics(
    comm_rounds: u64,
    local_steps: u64,
    eval: (f64, f64, f64, f64),
    net: NetSnapshot,
    wall_time_s: f64,
) -> RoundMetrics {
    let (loss, accuracy, stationarity, consensus) = eval;
    RoundMetrics {
        comm_rounds,
        local_steps,
        loss,
        accuracy,
        stationarity,
        consensus,
        bytes: net.bytes,
        messages: net.messages,
        sim_time_s: net.sim_time_s,
        wall_time_s,
        quarantined: net.quarantined,
        dp_epsilon: 0.0,
        pool_loads: 0,
        pool_spills: 0,
        pool_writebacks: 0,
        pool_hits: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(cr: u64, loss: f64) -> RoundMetrics {
        RoundMetrics {
            comm_rounds: cr,
            local_steps: cr * 100,
            loss,
            accuracy: 0.8,
            stationarity: 1e-3,
            consensus: 2e-3,
            bytes: cr * 1000,
            messages: cr * 10,
            sim_time_s: cr as f64 * 0.1,
            wall_time_s: cr as f64 * 0.01,
            quarantined: 0,
            dp_epsilon: 0.0,
            pool_loads: 0,
            pool_spills: 0,
            pool_writebacks: 0,
            pool_hits: 0,
        }
    }

    #[test]
    fn gap_is_sum_of_terms() {
        let r = row(1, 0.5);
        assert!((r.optimality_gap() - 3e-3).abs() < 1e-12);
    }

    #[test]
    fn rounds_to_loss_finds_first_crossing() {
        let mut log = RunLog::new("dsgt");
        for (cr, l) in [(1, 0.7), (2, 0.55), (3, 0.49), (4, 0.2)] {
            log.push(row(cr, l));
        }
        assert_eq!(log.rounds_to_loss(0.5), Some(3));
        assert_eq!(log.rounds_to_loss(0.1), None);
    }

    #[test]
    fn best_gap_min() {
        let mut log = RunLog::new("x");
        log.push(row(1, 0.7));
        let mut better = row(2, 0.6);
        better.stationarity = 1e-5;
        better.consensus = 1e-5;
        log.push(better);
        assert!((log.best_gap() - 2e-5).abs() < 1e-12);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = RunLog::new("x");
        log.push(row(1, 0.7));
        log.push(row(2, 0.6));
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("comm_rounds,"));
    }

    #[test]
    fn json_roundtrips_and_has_columns() {
        let mut log = RunLog::new("fd-dsgt");
        log.push(row(1, 0.7));
        let j = crate::jsonl::Json::parse(&log.to_json().to_string()).unwrap();
        assert_eq!(j.get("algo").unwrap().as_str().unwrap(), "fd-dsgt");
        assert_eq!(j.get("loss").unwrap().as_f64_vec().unwrap(), vec![0.7]);
    }

    #[test]
    fn json_reports_messages_and_quarantined_columns() {
        // regression: `messages` was in the CSV but silently missing from the
        // JSON dump, and the PR-8 quarantine counter must survive into rows
        let mut log = RunLog::new("fd-dsgd");
        let mut r = row(1, 0.7);
        r.quarantined = 3;
        log.push(r);
        let j = crate::jsonl::Json::parse(&log.to_json().to_string()).unwrap();
        assert_eq!(j.get("messages").unwrap().as_f64_vec().unwrap(), vec![10.0]);
        assert_eq!(j.get("quarantined").unwrap().as_f64_vec().unwrap(), vec![3.0]);
    }

    #[test]
    fn json_and_csv_report_pool_columns() {
        // PR-10: sharded runs surface the slab-pool traffic in the run log
        let mut log = RunLog::new("fd-dsgt");
        let mut r = row(1, 0.7);
        r.pool_loads = 5;
        r.pool_spills = 2;
        r.pool_writebacks = 1;
        r.pool_hits = 9;
        log.push(r);
        let j = crate::jsonl::Json::parse(&log.to_json().to_string()).unwrap();
        assert_eq!(j.get("pool_loads").unwrap().as_f64_vec().unwrap(), vec![5.0]);
        assert_eq!(j.get("pool_spills").unwrap().as_f64_vec().unwrap(), vec![2.0]);
        assert_eq!(j.get("pool_writebacks").unwrap().as_f64_vec().unwrap(), vec![1.0]);
        assert_eq!(j.get("pool_hits").unwrap().as_f64_vec().unwrap(), vec![9.0]);
        let csv = log.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.ends_with("pool_loads,pool_spills,pool_writebacks,pool_hits"));
    }

    #[test]
    fn kahan_beats_plain_sum_on_cancellation() {
        let mut k = Kahan::default();
        let vals = [1.0e16, 1.0, -1.0e16, 1.0];
        let mut plain = 0.0f64;
        for v in vals {
            k.add(v);
            plain += v;
        }
        assert_eq!(k.value(), 2.0);
        assert_ne!(plain, 2.0, "plain f64 loses the small addends");
    }

    #[test]
    fn streaming_eval_record_weights_a_1_vs_999_skew() {
        // two "nodes", one record vs 999: the global loss must be the
        // record-weighted mean, bitwise
        let p = 3;
        let mut se = StreamingEval::new(p);
        let g = vec![0.0f32; p];
        let row_a = vec![1.0f32; p];
        let row_b = vec![1.0f32; p];
        se.push_node(10.0, &g, 1, 1, &row_a);
        se.push_node(0.5, &g, 500, 999, &row_b);
        let mut cp = se.into_consensus_pass();
        cp.push_row(&row_a);
        cp.push_row(&row_b);
        let (loss, acc, stat, cons) = cp.finish();
        assert_eq!(loss, (10.0 * 1.0 + 0.5 * 999.0) / 1000.0);
        assert_eq!(acc, 501.0 / 1000.0);
        assert_eq!(stat, 0.0);
        assert_eq!(cons, 0.0, "identical rows have zero consensus error");
    }
}
