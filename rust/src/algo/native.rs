//! Native (pure-rust) compute backend — the in-process twin of the AOT
//! artifacts.
//!
//! Implements exactly the same functions as `python/compile/model.py`
//! (flat-parameter shallow MLP, logistic loss, eq. 2/3/4 updates, full-shard
//! metrics) in plain rust with f64 accumulation.  Three jobs:
//!
//! 1. **correctness oracle** — integration tests run the PJRT artifacts and
//!    this backend on identical inputs and require agreement to f32 noise;
//! 2. **shape-free sweeps** — the Theorem-1 speedup bench varies N and the
//!    Q-sweep varies Q, which would otherwise need one AOT artifact set per
//!    configuration;
//! 3. **driver property tests** — coordinator invariants are tested without
//!    artifacts on disk.
//!
//! The PJRT path remains the production path; this backend exists so the
//! system is *testable and sweepable*, mirroring what e.g. a CPU-reference
//! backend is to a TPU runtime.

use super::{axpy, RobustRule};

/// Samples per cache tile of the blocked forward/backward kernels.  Inside a
/// tile every `w1` row is loaded once and applied to all tile samples, so the
/// weight matrix stays hot while the inner strides are all 1.  The value only
/// moves work between loop levels — per-element f64 accumulation order is
/// sample-ascending regardless, so results are bitwise-independent of it.
pub const BATCH_BLOCK: usize = 16;

/// Caller-owned scratch for the `_into` kernels (§Perf in DESIGN.md).
///
/// Owns every buffer the forward/backward/combine kernels need between the
/// f32 inputs and f32 outputs: the f64 hidden slab for one batch tile, the
/// f64 gradient and combine accumulators, and an f32 gradient staging buffer.
/// Buffers grow on demand ([`Workspace::ensure`]) and are NEVER shrunk, so a
/// workspace reused across rounds of one model performs zero allocations
/// after its first use — the steady-state contract the allocation-counting
/// test pins.  One workspace serves one thread; the threaded fan-out gives
/// each worker its own.
#[derive(Debug, Default)]
pub struct Workspace {
    /// f64 hidden activations, one batch tile: `[BATCH_BLOCK, h]`.
    hid: Vec<f64>,
    /// f64 ∂loss/∂hidden for the tile: `[BATCH_BLOCK, h]`.
    dhid: Vec<f64>,
    /// f64 logits for the tile: `[BATCH_BLOCK]`.
    z: Vec<f64>,
    /// f64 gradient accumulator: `[p]`.
    grad: Vec<f64>,
    /// f64 combine accumulator: `[p]`.
    acc: Vec<f64>,
    /// f32 gradient staging for update kernels: `[p]`.
    gbuf: Vec<f32>,
    /// Robust-combine coordinate gather: `[k]` row participants (grown on
    /// demand by the robust rules only — the default mean path never
    /// touches it, preserving the zero-alloc steady-state pin).
    rvals: Vec<f64>,
    /// Krum scratch: pairwise squared distances `[k·k]`, then scores.
    rdist: Vec<f64>,
    /// Krum scratch: participant order by (score, index).
    rord: Vec<usize>,
}

impl Workspace {
    /// Empty workspace; buffers grow on first use.
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Grow every buffer to `model`'s sizes (no-op once sized — buffers only
    /// ever grow, so alternating models reuses the larger allocation).
    pub fn ensure(&mut self, model: &NativeModel) {
        let (h, p) = (model.h, model.p());
        grow(&mut self.hid, BATCH_BLOCK * h);
        grow(&mut self.dhid, BATCH_BLOCK * h);
        grow(&mut self.z, BATCH_BLOCK);
        grow(&mut self.grad, p);
        grow(&mut self.acc, p);
        grow(&mut self.gbuf, p);
    }
}

fn grow<T: Clone + Default>(v: &mut Vec<T>, len: usize) {
    if v.len() < len {
        v.resize(len, T::default());
    }
}

/// Model dimensions (matches `ModelShapes` minus the artifact-bound fields).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NativeModel {
    /// Input feature dimension.
    pub d: usize,
    /// Hidden-layer width.
    pub h: usize,
}

impl NativeModel {
    /// Model with `d` input features and `h` hidden units (both positive).
    pub fn new(d: usize, h: usize) -> Self {
        assert!(d > 0 && h > 0);
        NativeModel { d, h }
    }

    /// Flat parameter count `d*h + h + h + 1`.
    pub fn p(&self) -> usize {
        self.d * self.h + 2 * self.h + 1
    }

    /// He-style init matching a small random start (std 0.2/sqrt(d)).
    pub fn init(&self, rng: &mut crate::rng::Pcg64) -> Vec<f32> {
        let std1 = (1.0 / self.d as f64).sqrt();
        let std2 = (1.0 / self.h as f64).sqrt();
        let mut theta = vec![0.0f32; self.p()];
        let (dh, h) = (self.d * self.h, self.h);
        for v in &mut theta[..dh] {
            *v = (rng.normal() * std1) as f32;
        }
        // b1 zeros
        for v in &mut theta[dh + h..dh + 2 * h] {
            *v = (rng.normal() * std2) as f32;
        }
        // b2 zero
        theta
    }

    /// Hidden activations + logits for one batch tile (`blk <= BATCH_BLOCK`
    /// rows of `x`): `hid[s,k] = tanh(b1_k + Σ_j x[s,j]·w1[j,k])`,
    /// `z[s] = b2 + Σ_k hid[s,k]·w2[k]`.
    ///
    /// Tiled j-outer / k-inner: every inner stride is 1 (`w1[j*h..]` rows,
    /// `hid[s*h..]` rows) and each `w1` row is loaded once per tile instead
    /// of once per sample.  Per-(s,k) f64 accumulation is still j-ascending
    /// and the z dot is k-ascending, so the numbers are bitwise-identical to
    /// the pre-tiling per-sample kernel.
    fn forward_tile(&self, theta: &[f32], x: &[f32], blk: usize, hid: &mut [f64], z: &mut [f64]) {
        let (d, h) = (self.d, self.h);
        debug_assert!(blk <= BATCH_BLOCK && x.len() == blk * d);
        let w1 = &theta[..d * h];
        let b1 = &theta[d * h..d * h + h];
        let w2 = &theta[d * h + h..d * h + 2 * h];
        let b2 = theta[d * h + 2 * h] as f64;
        for s in 0..blk {
            let hs = &mut hid[s * h..(s + 1) * h];
            for (hk, &bk) in hs.iter_mut().zip(b1) {
                *hk = bk as f64;
            }
        }
        for j in 0..d {
            let w1j = &w1[j * h..(j + 1) * h];
            for s in 0..blk {
                let xj = x[s * d + j] as f64;
                let hs = &mut hid[s * h..(s + 1) * h];
                for (hk, &wk) in hs.iter_mut().zip(w1j) {
                    *hk += xj * wk as f64;
                }
            }
        }
        for s in 0..blk {
            let hs = &mut hid[s * h..(s + 1) * h];
            let mut acc = b2;
            for (hk, &wk) in hs.iter_mut().zip(w2) {
                *hk = hk.tanh();
                acc += *hk * wk as f64;
            }
            z[s] = acc;
        }
    }

    /// Forward pass into a caller buffer: logits for each of the `n` rows of
    /// `x` (row-major n×d) written to `out[n]`.
    pub fn logits_into(&self, theta: &[f32], x: &[f32], out: &mut [f64], ws: &mut Workspace) {
        let d = self.d;
        assert_eq!(theta.len(), self.p());
        let n = x.len() / d;
        assert_eq!(x.len(), n * d);
        assert_eq!(out.len(), n);
        ws.ensure(self);
        let Workspace { hid, z, .. } = ws;
        let mut i0 = 0;
        while i0 < n {
            let blk = (n - i0).min(BATCH_BLOCK);
            self.forward_tile(theta, &x[i0 * d..(i0 + blk) * d], blk, hid, z);
            out[i0..i0 + blk].copy_from_slice(&z[..blk]);
            i0 += blk;
        }
    }

    /// Forward pass: logits for each of the `n` rows of `x` (row-major n×d).
    /// Allocating wrapper over [`Self::logits_into`].
    pub fn logits(&self, theta: &[f32], x: &[f32]) -> Vec<f64> {
        let mut out = vec![0.0f64; x.len() / self.d];
        self.logits_into(theta, x, &mut out, &mut Workspace::new());
        out
    }

    /// The blocked forward+backward kernel behind `loss_and_grad[_into]` and
    /// `local_steps[_into]`: mean logistic loss returned, flat f32 gradient
    /// written to `grad_out[p]`.  The scratch slices come from a
    /// [`Workspace`] (callers destructure it so `local_steps_into` can also
    /// hold the f32 staging buffer).
    ///
    /// Per-element accumulation order across samples is ascending exactly as
    /// in the pre-tiling kernel (within a sample each gradient element gets
    /// one contribution), so outputs are bitwise-identical to it.
    #[allow(clippy::too_many_arguments)]
    fn loss_grad_kernel(
        &self,
        theta: &[f32],
        x: &[f32],
        y: &[f32],
        grad_out: &mut [f32],
        hid: &mut [f64],
        dhid: &mut [f64],
        z: &mut [f64],
        gacc: &mut [f64],
    ) -> f64 {
        let (d, h, p) = (self.d, self.h, self.p());
        let n = y.len();
        assert_eq!(x.len(), n * d);
        assert_eq!(theta.len(), p);
        assert_eq!(grad_out.len(), p);
        let w2 = &theta[d * h + h..d * h + 2 * h];
        let gacc = &mut gacc[..p];
        for g in gacc.iter_mut() {
            *g = 0.0;
        }
        let inv_n = 1.0 / n as f64;
        let mut loss = 0.0f64;
        let mut i0 = 0;
        while i0 < n {
            let blk = (n - i0).min(BATCH_BLOCK);
            let xb = &x[i0 * d..(i0 + blk) * d];
            self.forward_tile(theta, xb, blk, hid, z);
            for s in 0..blk {
                let zs = z[s];
                let yi = y[i0 + s] as f64;
                // loss: log(1 + e^z) - y z, numerically stable
                loss +=
                    if zs > 0.0 { zs + (-zs).exp().ln_1p() } else { zs.exp().ln_1p() } - yi * zs;
                // dL/dz = sigmoid(z) - y, pre-scaled by 1/n
                let gz = (1.0 / (1.0 + (-zs).exp()) - yi) * inv_n;
                gacc[d * h + 2 * h] += gz; // b2
                let hs = &hid[s * h..(s + 1) * h];
                let ds = &mut dhid[s * h..(s + 1) * h];
                for (((dk, &hk), &wk), gw2) in
                    ds.iter_mut().zip(hs).zip(w2).zip(&mut gacc[d * h + h..d * h + 2 * h])
                {
                    *gw2 += gz * hk; // w2
                    *dk = gz * wk as f64 * (1.0 - hk * hk);
                }
                for (gb1, &dk) in gacc[d * h..d * h + h].iter_mut().zip(&*ds) {
                    *gb1 += dk; // b1
                }
            }
            // w1 gradient, tiled like the forward pass: j-outer so each
            // `gacc` row streams once per tile with unit stride.
            for j in 0..d {
                let gj = &mut gacc[j * h..(j + 1) * h];
                for s in 0..blk {
                    let xj = xb[s * d + j] as f64;
                    let ds = &dhid[s * h..(s + 1) * h];
                    for (gk, &dk) in gj.iter_mut().zip(ds) {
                        *gk += dk * xj;
                    }
                }
            }
            i0 += blk;
        }
        for (o, &g) in grad_out.iter_mut().zip(&*gacc) {
            *o = g as f32;
        }
        loss * inv_n
    }

    /// Mean logistic loss (labels in {0,1}); flat gradient written to
    /// `grad_out[p]` — the zero-allocation twin of [`Self::loss_and_grad`].
    pub fn loss_and_grad_into(
        &self,
        theta: &[f32],
        x: &[f32],
        y: &[f32],
        grad_out: &mut [f32],
        ws: &mut Workspace,
    ) -> f64 {
        ws.ensure(self);
        let Workspace { hid, dhid, z, grad, .. } = ws;
        self.loss_grad_kernel(theta, x, y, grad_out, hid, dhid, z, grad)
    }

    /// Mean logistic loss (labels in {0,1}) and flat gradient — the
    /// `grad_step` artifact's twin.  Allocating wrapper over
    /// [`Self::loss_and_grad_into`].
    pub fn loss_and_grad(&self, theta: &[f32], x: &[f32], y: &[f32]) -> (f64, Vec<f32>) {
        let mut grad = vec![0.0f32; self.p()];
        let loss = self.loss_and_grad_into(theta, x, y, &mut grad, &mut Workspace::new());
        (loss, grad)
    }

    /// `count` eq.-4 SGD steps on pre-sampled batches, per-step losses
    /// written to `losses[count]` — the zero-allocation `local_steps` twin.
    pub fn local_steps_into(
        &self,
        theta: &mut [f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
        losses: &mut [f64],
        ws: &mut Workspace,
    ) {
        let count = lrs.len();
        assert_eq!(losses.len(), count);
        if count == 0 {
            return;
        }
        let m = by.len() / count;
        assert_eq!(bx.len(), count * m * self.d);
        ws.ensure(self);
        let p = self.p();
        let Workspace { hid, dhid, z, grad, gbuf, .. } = ws;
        let gbuf = &mut gbuf[..p];
        for (qi, (&lr, loss)) in lrs.iter().zip(losses.iter_mut()).enumerate() {
            let x = &bx[qi * m * self.d..(qi + 1) * m * self.d];
            let yb = &by[qi * m..(qi + 1) * m];
            *loss = self.loss_grad_kernel(theta, x, yb, gbuf, hid, dhid, z, grad);
            axpy(theta, -lr, gbuf);
        }
    }

    /// `count` eq.-4 SGD steps on pre-sampled batches — `local_steps` twin.
    /// `bx` is `[count, m, d]`, `by` `[count, m]`, `lrs` `[count]`.
    /// Allocating wrapper over [`Self::local_steps_into`].
    pub fn local_steps(
        &self,
        theta: &mut Vec<f32>,
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
    ) -> Vec<f64> {
        let mut losses = vec![0.0f64; lrs.len()];
        self.local_steps_into(theta, bx, by, lrs, &mut losses, &mut Workspace::new());
        losses
    }

    /// Dense combine into a caller buffer: `Σ_j w_j θ_j` over stacked
    /// `thetas` (n×p), skipping zero weights.  The skip makes the dense loop
    /// visit exactly the nonzero entries in ascending-j order — the same
    /// visit order as [`Self::combine_sparse_into`], which is why the two
    /// are bitwise-identical.
    pub fn combine_into(
        &self,
        wrow: &[f32],
        thetas: &[f32],
        out: &mut [f32],
        ws: &mut Workspace,
    ) {
        let p = self.p();
        let n = wrow.len();
        assert_eq!(thetas.len(), n * p);
        assert_eq!(out.len(), p);
        ws.ensure(self);
        let acc = &mut ws.acc[..p];
        for a in acc.iter_mut() {
            *a = 0.0;
        }
        for (j, &wj) in wrow.iter().enumerate() {
            if wj == 0.0 {
                continue;
            }
            for (a, &t) in acc.iter_mut().zip(&thetas[j * p..(j + 1) * p]) {
                *a += wj as f64 * t as f64;
            }
        }
        for (o, &a) in out.iter_mut().zip(&*acc) {
            *o = a as f32;
        }
    }

    /// Degree-sparse combine into a caller buffer: `Σ_k val[k]·θ_{idx[k]}`
    /// over the `(neighbor, weight)` pairs of one mixing-matrix row, `idx`
    /// ascending and nonzeros only (`graph::schedule::NetView::sparse_row` /
    /// `mixing::SparseW`).  Visits the same nonzero entries in the same
    /// order as the zero-skipping dense loop, so the result is
    /// bitwise-identical to [`Self::combine_into`] while the per-node cost
    /// drops from O(n·p) to O(deg·p).
    pub fn combine_sparse_into(
        &self,
        idx: &[u32],
        val: &[f32],
        thetas: &[f32],
        out: &mut [f32],
        ws: &mut Workspace,
    ) {
        let p = self.p();
        assert_eq!(idx.len(), val.len());
        assert_eq!(out.len(), p);
        ws.ensure(self);
        let acc = &mut ws.acc[..p];
        for a in acc.iter_mut() {
            *a = 0.0;
        }
        for (&j, &wj) in idx.iter().zip(val) {
            let j = j as usize;
            for (a, &t) in acc.iter_mut().zip(&thetas[j * p..(j + 1) * p]) {
                *a += wj as f64 * t as f64;
            }
        }
        for (o, &a) in out.iter_mut().zip(&*acc) {
            *o = a as f32;
        }
    }

    /// `Σ_j w_j θ_j` over stacked `thetas` (n×p) — `combine` twin.
    /// Allocating wrapper over [`Self::combine_into`].
    pub fn combine(&self, wrow: &[f32], thetas: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.p()];
        self.combine_into(wrow, thetas, &mut out, &mut Workspace::new());
        out
    }

    /// Rule-dispatched combine over one degree-sparse row (DESIGN.md §14).
    /// [`RobustRule::Mean`] routes to [`Self::combine_sparse_into`] — the
    /// identical code path, so mean-rule runs stay bitwise-pinned.  The
    /// robust rules aggregate the row's participants as an *unweighted*
    /// sample (a Byzantine neighbor's mixing weight is exactly what must
    /// not matter) and therefore forfeit mean preservation:
    ///
    /// - `TrimmedMean`: per coordinate, sort the k participant values, drop
    ///   `min(⌊trim·k⌋, ⌊(k−1)/2⌋)` from each end, average the rest.
    /// - `Median`: per coordinate, the middle value (even k averages the
    ///   two middles) — the trim-to-the-limit special case.
    /// - `Krum`: screen whole vectors, not coordinates — score participant
    ///   `j` by the sum of its `max(1, k−f−2)` smallest squared distances
    ///   to the other participants (`f = ⌈trim·k⌉` assumed attackers),
    ///   drop the `f` highest-scoring, and average the survivors.  Ties
    ///   break by participant index, so the screen is deterministic.
    ///
    /// Rows with fewer than 3 participants (`self_col` names the node's
    /// own stack row) keep their own value under every non-mean rule: a
    /// 2-participant sample is 50% attacker-capturable — no screen can
    /// tell self from adversary — so the only robust combine is no
    /// combine.  Churn-compacted k = 1 rows hit the same path.
    ///
    /// All accumulation is f64, like the mean path.
    pub fn combine_rule_into(
        &self,
        rule: RobustRule,
        self_col: u32,
        idx: &[u32],
        val: &[f32],
        stacked: &[f32],
        out: &mut [f32],
        ws: &mut Workspace,
    ) {
        let k = idx.len();
        if !rule.is_mean() && k < 3 {
            let p = self.p();
            debug_assert!(idx.contains(&self_col), "row must include its own node");
            out.copy_from_slice(&stacked[self_col as usize * p..(self_col as usize + 1) * p]);
            return;
        }
        match rule {
            RobustRule::Mean => self.combine_sparse_into(idx, val, stacked, out, ws),
            RobustRule::TrimmedMean { trim } => {
                let t = ((trim * k as f64).floor() as usize).min((k - 1) / 2);
                self.combine_trimmed_into(idx, stacked, t, out, ws);
            }
            RobustRule::Median => {
                self.combine_trimmed_into(idx, stacked, (k - 1) / 2, out, ws);
            }
            RobustRule::Krum { trim } => {
                self.combine_krum_into(idx, stacked, trim, out, ws);
            }
        }
    }

    /// Coordinate-wise t-trimmed unweighted mean over the row participants
    /// (`t` from each end; `t = ⌊(k−1)/2⌋` is the coordinate-wise median:
    /// odd k leaves the middle value, even k averages the two middles).
    fn combine_trimmed_into(
        &self,
        idx: &[u32],
        stacked: &[f32],
        t: usize,
        out: &mut [f32],
        ws: &mut Workspace,
    ) {
        let p = self.p();
        let k = idx.len();
        assert!(k >= 1, "trimmed combine over an empty row");
        assert_eq!(out.len(), p);
        assert!(2 * t < k);
        grow(&mut ws.rvals, k);
        let vals = &mut ws.rvals[..k];
        for (c, o) in out.iter_mut().enumerate() {
            for (v, &j) in vals.iter_mut().zip(idx) {
                *v = stacked[j as usize * p + c] as f64;
            }
            vals.sort_unstable_by(f64::total_cmp);
            let kept = &vals[t..k - t];
            *o = (kept.iter().sum::<f64>() / kept.len() as f64) as f32;
        }
    }

    /// Krum-style screening over whole participant vectors (see
    /// [`Self::combine_rule_into`] for the scoring rule).
    fn combine_krum_into(
        &self,
        idx: &[u32],
        stacked: &[f32],
        trim: f64,
        out: &mut [f32],
        ws: &mut Workspace,
    ) {
        let p = self.p();
        let k = idx.len();
        assert!(k >= 1, "krum combine over an empty row");
        assert_eq!(out.len(), p);
        ws.ensure(self);
        let f = ((trim * k as f64).ceil() as usize).min(k - 1);
        grow(&mut ws.rdist, k * k + k);
        grow(&mut ws.rord, k);
        let (dist, scores) = ws.rdist.split_at_mut(k * k);
        let scores = &mut scores[..k];
        let row = |j: usize| {
            let b = idx[j] as usize * p;
            &stacked[b..b + p]
        };
        for a in 0..k {
            dist[a * k + a] = 0.0;
            for b in (a + 1)..k {
                let d = crate::algo::l2_dist_sq(row(a), row(b));
                dist[a * k + b] = d;
                dist[b * k + a] = d;
            }
        }
        let closest = (k.saturating_sub(f + 2)).max(1).min(k.saturating_sub(1));
        for a in 0..k {
            if k == 1 {
                scores[a] = 0.0;
                continue;
            }
            // a's distances to the other k−1 participants, smallest first
            let others = &mut ws.rord[..k - 1];
            let mut w = 0;
            for b in 0..k {
                if b != a {
                    others[w] = b;
                    w += 1;
                }
            }
            others.sort_unstable_by(|&x, &y| {
                dist[a * k + x].total_cmp(&dist[a * k + y]).then(x.cmp(&y))
            });
            scores[a] = others[..closest].iter().map(|&b| dist[a * k + b]).sum();
        }
        // survivors: the k − f lowest-scoring participants (ties by index)
        let ord = &mut ws.rord[..k];
        for (o, v) in ord.iter_mut().enumerate() {
            *v = o;
        }
        ord.sort_unstable_by(|&x, &y| scores[x].total_cmp(&scores[y]).then(x.cmp(&y)));
        let survivors = &ord[..k - f];
        let acc = &mut ws.acc[..p];
        for a in acc.iter_mut() {
            *a = 0.0;
        }
        for &s in survivors {
            for (a, &v) in acc.iter_mut().zip(row(s)) {
                *a += v as f64;
            }
        }
        let inv = 1.0 / survivors.len() as f64;
        for (o, &a) in out.iter_mut().zip(&*acc) {
            *o = (a * inv) as f32;
        }
    }

    /// Node `i`'s eq.-2 update given the whole stacked Θ: `(W Θ)_i − lr ∇g_i`
    /// → (θ′_i, loss).  The ONLY implementation of the DSGD node update —
    /// the serial round below and the threaded `NativeCompute` fan-out both
    /// call it, so the math cannot desync between paths.
    pub fn dsgd_node(
        &self,
        wrow: &[f32],
        theta: &[f32],
        theta_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
    ) -> (Vec<f32>, f64) {
        let mut t_next = self.combine(wrow, theta);
        let (loss, grad) = self.loss_and_grad(theta_i, bx_i, by_i);
        axpy(&mut t_next, -lr, &grad);
        (t_next, loss)
    }

    /// Node `i`'s eq.-3 update given the stacked Θ and tracker Y:
    /// `θ′_i = (W Θ)_i − lr y_i`, `g′_i = ∇g_i(θ′_i)`,
    /// `y′_i = (W Y)_i + g′_i − g_i` → (θ′_i, y′_i, g′_i, loss).
    /// Single source of the DSGT node math for serial and threaded paths.
    #[allow(clippy::too_many_arguments)]
    pub fn dsgt_node(
        &self,
        wrow: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        y_i: &[f32],
        g_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, f64) {
        let mut t_next = self.combine(wrow, theta);
        axpy(&mut t_next, -lr, y_i);
        let (loss, grad) = self.loss_and_grad(&t_next, bx_i, by_i);
        let mut y_next = self.combine(wrow, y_tr);
        axpy(&mut y_next, 1.0, &grad);
        axpy(&mut y_next, -1.0, g_i);
        (t_next, y_next, grad, loss)
    }

    /// Eq.-2 node update over a degree-sparse W row, written into `out[p]`;
    /// returns the node loss.  Bitwise-identical to [`Self::dsgd_node`] on
    /// the dense row whose nonzeros are `(idx, val)`.
    #[allow(clippy::too_many_arguments)]
    pub fn dsgd_node_into(
        &self,
        idx: &[u32],
        val: &[f32],
        theta: &[f32],
        theta_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
        out: &mut [f32],
        ws: &mut Workspace,
    ) -> f64 {
        self.combine_sparse_into(idx, val, theta, out, ws);
        let p = self.p();
        let Workspace { hid, dhid, z, grad, gbuf, .. } = ws;
        let gbuf = &mut gbuf[..p];
        let loss = self.loss_grad_kernel(theta_i, bx_i, by_i, gbuf, hid, dhid, z, grad);
        axpy(out, -lr, gbuf);
        loss
    }

    /// Eq.-2 node update under **compressed gossip** (difference form,
    /// DESIGN.md §10): mix the *decoded* stack, add back the node's own
    /// full-precision correction `θ_i − x̂_i`, then take the gradient step
    /// at the true θ_i:
    /// `θ′_i = (W X̂)_i + (θ_i − x̂_i) − lr ∇g_i(θ_i)`.
    /// With the identity compressor (x̂ ≡ θ) this is bitwise-identical to
    /// [`Self::dsgd_node_into`] — the correction adds exact `+0.0`s.
    #[allow(clippy::too_many_arguments)]
    pub fn dsgd_node_compressed_into(
        &self,
        idx: &[u32],
        val: &[f32],
        xhat: &[f32],
        xhat_i: &[f32],
        theta_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
        out: &mut [f32],
        ws: &mut Workspace,
    ) -> f64 {
        self.combine_sparse_into(idx, val, xhat, out, ws);
        super::add_diff(out, theta_i, xhat_i);
        let p = self.p();
        let Workspace { hid, dhid, z, grad, gbuf, .. } = ws;
        let gbuf = &mut gbuf[..p];
        let loss = self.loss_grad_kernel(theta_i, bx_i, by_i, gbuf, hid, dhid, z, grad);
        axpy(out, -lr, gbuf);
        loss
    }

    /// Eq.-3 node update under **compressed gossip** (difference form):
    /// both mixes read decoded stacks with the node's own full-precision
    /// corrections added back:
    /// `θ′_i = (W X̂)_i + (θ_i − x̂_i) − lr ϑ_i`,
    /// `ϑ′_i = (W Ŷ)_i + (ϑ_i − ŷ_i) + ∇g(θ′_i) − ∇g(θ_i)`.
    /// Identity-compressed runs are bitwise-identical to
    /// [`Self::dsgt_node_into`].
    #[allow(clippy::too_many_arguments)]
    pub fn dsgt_node_compressed_into(
        &self,
        idx: &[u32],
        val: &[f32],
        xhat: &[f32],
        yhat: &[f32],
        xhat_i: &[f32],
        yhat_i: &[f32],
        theta_i: &[f32],
        y_i: &[f32],
        g_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
        t_out: &mut [f32],
        y_out: &mut [f32],
        g_out: &mut [f32],
        ws: &mut Workspace,
    ) -> f64 {
        self.combine_sparse_into(idx, val, xhat, t_out, ws);
        super::add_diff(t_out, theta_i, xhat_i);
        axpy(t_out, -lr, y_i);
        let loss = {
            let p = self.p();
            let Workspace { hid, dhid, z, grad, .. } = &mut *ws;
            debug_assert_eq!(g_out.len(), p);
            self.loss_grad_kernel(t_out, bx_i, by_i, g_out, hid, dhid, z, grad)
        };
        self.combine_sparse_into(idx, val, yhat, y_out, ws);
        super::add_diff(y_out, y_i, yhat_i);
        axpy(y_out, 1.0, g_out);
        axpy(y_out, -1.0, g_i);
        loss
    }

    /// Eq.-3 node update over a degree-sparse W row, written into
    /// `t_out`/`y_out`/`g_out` (each `[p]`, disjoint); returns the node
    /// loss.  Bitwise-identical to [`Self::dsgt_node`] on the dense row.
    #[allow(clippy::too_many_arguments)]
    pub fn dsgt_node_into(
        &self,
        idx: &[u32],
        val: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        y_i: &[f32],
        g_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
        t_out: &mut [f32],
        y_out: &mut [f32],
        g_out: &mut [f32],
        ws: &mut Workspace,
    ) -> f64 {
        self.combine_sparse_into(idx, val, theta, t_out, ws);
        axpy(t_out, -lr, y_i);
        let loss = {
            let p = self.p();
            let Workspace { hid, dhid, z, grad, .. } = &mut *ws;
            debug_assert_eq!(g_out.len(), p);
            self.loss_grad_kernel(t_out, bx_i, by_i, g_out, hid, dhid, z, grad)
        };
        self.combine_sparse_into(idx, val, y_tr, y_out, ws);
        axpy(y_out, 1.0, g_out);
        axpy(y_out, -1.0, g_i);
        loss
    }

    /// [`Self::dsgd_node_into`] with a rule-dispatched mixing term:
    /// `combine_rule(row) − lr ∇g_i(θ_i)`.  [`RobustRule::Mean`] delegates
    /// to the pinned kernel, so the dispatch itself costs no bits.
    #[allow(clippy::too_many_arguments)]
    pub fn dsgd_node_rule_into(
        &self,
        rule: RobustRule,
        self_col: u32,
        idx: &[u32],
        val: &[f32],
        theta: &[f32],
        theta_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
        out: &mut [f32],
        ws: &mut Workspace,
    ) -> f64 {
        if rule.is_mean() {
            return self.dsgd_node_into(idx, val, theta, theta_i, bx_i, by_i, lr, out, ws);
        }
        self.combine_rule_into(rule, self_col, idx, val, theta, out, ws);
        let p = self.p();
        let Workspace { hid, dhid, z, grad, gbuf, .. } = ws;
        let gbuf = &mut gbuf[..p];
        let loss = self.loss_grad_kernel(theta_i, bx_i, by_i, gbuf, hid, dhid, z, grad);
        axpy(out, -lr, gbuf);
        loss
    }

    /// [`Self::dsgd_node_compressed_into`] with a rule-dispatched mixing
    /// term over the decoded stack X̂.
    #[allow(clippy::too_many_arguments)]
    pub fn dsgd_node_compressed_rule_into(
        &self,
        rule: RobustRule,
        self_col: u32,
        idx: &[u32],
        val: &[f32],
        xhat: &[f32],
        xhat_i: &[f32],
        theta_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
        out: &mut [f32],
        ws: &mut Workspace,
    ) -> f64 {
        if rule.is_mean() {
            return self.dsgd_node_compressed_into(
                idx, val, xhat, xhat_i, theta_i, bx_i, by_i, lr, out, ws,
            );
        }
        self.combine_rule_into(rule, self_col, idx, val, xhat, out, ws);
        super::add_diff(out, theta_i, xhat_i);
        let p = self.p();
        let Workspace { hid, dhid, z, grad, gbuf, .. } = ws;
        let gbuf = &mut gbuf[..p];
        let loss = self.loss_grad_kernel(theta_i, bx_i, by_i, gbuf, hid, dhid, z, grad);
        axpy(out, -lr, gbuf);
        loss
    }

    /// [`Self::dsgt_node_into`] with rule-dispatched mixing terms for both
    /// the parameter and the tracker rows.
    #[allow(clippy::too_many_arguments)]
    pub fn dsgt_node_rule_into(
        &self,
        rule: RobustRule,
        self_col: u32,
        idx: &[u32],
        val: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        y_i: &[f32],
        g_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
        t_out: &mut [f32],
        y_out: &mut [f32],
        g_out: &mut [f32],
        ws: &mut Workspace,
    ) -> f64 {
        if rule.is_mean() {
            return self.dsgt_node_into(
                idx, val, theta, y_tr, y_i, g_i, bx_i, by_i, lr, t_out, y_out, g_out, ws,
            );
        }
        self.combine_rule_into(rule, self_col, idx, val, theta, t_out, ws);
        axpy(t_out, -lr, y_i);
        let loss = {
            let p = self.p();
            let Workspace { hid, dhid, z, grad, .. } = &mut *ws;
            debug_assert_eq!(g_out.len(), p);
            self.loss_grad_kernel(t_out, bx_i, by_i, g_out, hid, dhid, z, grad)
        };
        self.combine_rule_into(rule, self_col, idx, val, y_tr, y_out, ws);
        axpy(y_out, 1.0, g_out);
        axpy(y_out, -1.0, g_i);
        loss
    }

    /// [`Self::dsgt_node_compressed_into`] with rule-dispatched mixing
    /// terms over the decoded stacks X̂ and Ŷ.
    #[allow(clippy::too_many_arguments)]
    pub fn dsgt_node_compressed_rule_into(
        &self,
        rule: RobustRule,
        self_col: u32,
        idx: &[u32],
        val: &[f32],
        xhat: &[f32],
        yhat: &[f32],
        xhat_i: &[f32],
        yhat_i: &[f32],
        theta_i: &[f32],
        y_i: &[f32],
        g_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
        t_out: &mut [f32],
        y_out: &mut [f32],
        g_out: &mut [f32],
        ws: &mut Workspace,
    ) -> f64 {
        if rule.is_mean() {
            return self.dsgt_node_compressed_into(
                idx, val, xhat, yhat, xhat_i, yhat_i, theta_i, y_i, g_i, bx_i, by_i, lr, t_out,
                y_out, g_out, ws,
            );
        }
        self.combine_rule_into(rule, self_col, idx, val, xhat, t_out, ws);
        super::add_diff(t_out, theta_i, xhat_i);
        axpy(t_out, -lr, y_i);
        let loss = {
            let p = self.p();
            let Workspace { hid, dhid, z, grad, .. } = &mut *ws;
            debug_assert_eq!(g_out.len(), p);
            self.loss_grad_kernel(t_out, bx_i, by_i, g_out, hid, dhid, z, grad)
        };
        self.combine_rule_into(rule, self_col, idx, val, yhat, y_out, ws);
        super::add_diff(y_out, y_i, yhat_i);
        axpy(y_out, 1.0, g_out);
        axpy(y_out, -1.0, g_i);
        loss
    }

    /// Node `i`'s eval partial: (loss, grad, correct, total) on its shard.
    /// `eval_full` (serial and threaded) reduces these in node order.
    pub fn eval_node(&self, theta_i: &[f32], shard: &crate::data::Shard) -> (f64, Vec<f32>, usize, usize) {
        let (loss, grad) = self.loss_and_grad(theta_i, &shard.x, &shard.y);
        let zs = self.logits(theta_i, &shard.x);
        let correct = zs
            .iter()
            .zip(&shard.y)
            .filter(|(z, &yv)| ((**z > 0.0) as u32 as f32) == yv)
            .count();
        (loss, grad, correct, shard.y.len())
    }

    /// Whole-network eq. 2 — `dsgd_round` twin.
    /// Returns (Θ′ `[n,p]`, per-node losses).
    pub fn dsgd_round(
        &self,
        w: &[f32],
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        n: usize,
        m: usize,
    ) -> (Vec<f32>, Vec<f64>) {
        let p = self.p();
        let mut out = Vec::with_capacity(n * p);
        let mut losses = Vec::with_capacity(n);
        for i in 0..n {
            let (t, loss) = self.dsgd_node(
                &w[i * n..(i + 1) * n],
                theta,
                &theta[i * p..(i + 1) * p],
                &bx[i * m * self.d..(i + 1) * m * self.d],
                &by[i * m..(i + 1) * m],
                lr,
            );
            out.extend_from_slice(&t);
            losses.push(loss);
        }
        (out, losses)
    }

    /// Whole-network eq. 3 — `dsgt_round` twin.
    /// Returns (Θ′, Y′, G′, losses).  Node `i` depends only on its own rows
    /// of Y/G plus the shared Θ/Y stacks, so the round is a straight loop
    /// over [`Self::dsgt_node`].
    #[allow(clippy::too_many_arguments)]
    pub fn dsgt_round(
        &self,
        w: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        n: usize,
        m: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f64>) {
        let p = self.p();
        let mut theta_next = Vec::with_capacity(n * p);
        let mut y_next = Vec::with_capacity(n * p);
        let mut g_new = Vec::with_capacity(n * p);
        let mut losses = Vec::with_capacity(n);
        for i in 0..n {
            let (t, y, g, loss) = self.dsgt_node(
                &w[i * n..(i + 1) * n],
                theta,
                y_tr,
                &y_tr[i * p..(i + 1) * p],
                &g_old[i * p..(i + 1) * p],
                &bx[i * m * self.d..(i + 1) * m * self.d],
                &by[i * m..(i + 1) * m],
                lr,
            );
            theta_next.extend_from_slice(&t);
            y_next.extend_from_slice(&y);
            g_new.extend_from_slice(&g);
            losses.push(loss);
        }
        (theta_next, y_next, g_new, losses)
    }

    /// Full-shard metrics — `eval_full` twin:
    /// (record-weighted loss, record-weighted accuracy, `||mean grad||²`,
    /// consensus).  A straight loop over [`Self::eval_node`] followed by the
    /// node-order reduction in [`Self::eval_reduce`].
    pub fn eval_full(&self, theta: &[f32], shards: &[crate::data::Shard]) -> (f64, f64, f64, f64) {
        let p = self.p();
        let n = shards.len();
        assert_eq!(theta.len(), n * p);
        let per: Vec<(f64, Vec<f32>, usize, usize)> = shards
            .iter()
            .enumerate()
            .map(|(i, s)| self.eval_node(&theta[i * p..(i + 1) * p], s))
            .collect();
        self.eval_reduce(theta, &per)
    }

    /// Reduce per-node eval partials in node order (the ONLY eval reduction —
    /// serial and threaded `eval_full` both call it, so the metric formulas
    /// exist once and cannot desync).
    ///
    /// Global loss and accuracy are **record-weighted**: each node's mean is
    /// weighted by its shard size, so both metrics describe the same
    /// population — the pooled records — and a 1-record shard cannot swing
    /// the global loss the way the old unweighted node-mean let it (under
    /// even shards the two weightings coincide).  Stationarity and consensus
    /// stay node-mean quantities exactly as Theorem 1 defines them: the
    /// theorem's bounds are over `(1/N) Σ_i`, not over records.
    ///
    /// The reduction is a [`crate::metrics::StreamingEval`] fold — the same
    /// Kahan-compensated left fold the sharded sweep (`engine::shard`) runs
    /// shard by shard — so resident and sharded metrics agree bitwise by
    /// construction at any shard count (`tests/shard_pins.rs`).
    pub fn eval_reduce(
        &self,
        theta: &[f32],
        per: &[(f64, Vec<f32>, usize, usize)],
    ) -> (f64, f64, f64, f64) {
        let p = self.p();
        let mut se = crate::metrics::StreamingEval::new(p);
        for (i, (loss, grad, c, t)) in per.iter().enumerate() {
            se.push_node(*loss, grad, *c, *t, &theta[i * p..(i + 1) * p]);
        }
        let mut cp = se.into_consensus_pass();
        for i in 0..per.len() {
            cp.push_row(&theta[i * p..(i + 1) * p]);
        }
        cp.finish()
    }

    /// `P(AD|x)` per row — `predict` twin.
    pub fn predict(&self, theta: &[f32], x: &[f32]) -> Vec<f32> {
        self.logits(theta, x)
            .into_iter()
            .map(|z| (1.0 / (1.0 + (-z).exp())) as f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::row_mean;
    use crate::rng::Pcg64;
    use crate::testutil;

    fn model() -> NativeModel {
        NativeModel::new(6, 4)
    }

    fn rand_vec(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }

    fn rand_labels(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 }).collect()
    }

    #[test]
    fn p_matches_formula() {
        assert_eq!(model().p(), 6 * 4 + 4 + 4 + 1);
        assert_eq!(NativeModel::new(42, 32).p(), 1409);
    }

    #[test]
    fn loss_at_zero_is_log2() {
        let m = model();
        let mut rng = Pcg64::seed(0);
        let x = rand_vec(&mut rng, 10 * m.d, 1.0);
        let y = rand_labels(&mut rng, 10);
        let (loss, _) = m.loss_and_grad(&vec![0.0; m.p()], &x, &y);
        assert!((loss - std::f64::consts::LN_2).abs() < 1e-9, "{loss}");
    }

    #[test]
    fn grad_matches_finite_differences_property() {
        testutil::check("native grad vs fd", 12, 3, |rng| {
            let m = model();
            let theta = rand_vec(rng, m.p(), 0.3);
            let x = rand_vec(rng, 8 * m.d, 1.0);
            let y = rand_labels(rng, 8);
            let (_, g) = m.loss_and_grad(&theta, &x, &y);
            let eps = 1e-3f32;
            for &idx in &[0usize, m.p() / 2, m.p() - 1] {
                let mut tp = theta.clone();
                tp[idx] += eps;
                let mut tm = theta.clone();
                tm[idx] -= eps;
                let (lp, _) = m.loss_and_grad(&tp, &x, &y);
                let (lm, _) = m.loss_and_grad(&tm, &x, &y);
                let fd = (lp - lm) / (2.0 * eps as f64);
                if (g[idx] as f64 - fd).abs() > 1e-3 * (1.0 + fd.abs()) {
                    return Err(format!("idx {idx}: grad {} vs fd {fd}", g[idx]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sgd_decreases_loss() {
        let m = model();
        let mut rng = Pcg64::seed(4);
        let mut theta = m.init(&mut rng);
        let x = rand_vec(&mut rng, 50 * m.d, 1.0);
        let y = rand_labels(&mut rng, 50);
        let (l0, g) = m.loss_and_grad(&theta, &x, &y);
        axpy(&mut theta, -0.5, &g);
        let (l1, _) = m.loss_and_grad(&theta, &x, &y);
        assert!(l1 < l0, "{l1} !< {l0}");
    }

    #[test]
    fn local_steps_match_manual_unroll() {
        let m = model();
        let mut rng = Pcg64::seed(5);
        let theta0 = m.init(&mut rng);
        let q = 4;
        let batch = 5;
        let bx = rand_vec(&mut rng, q * batch * m.d, 1.0);
        let by = rand_labels(&mut rng, q * batch);
        let lrs: Vec<f32> = (1..=q).map(|r| 0.02 / (r as f32).sqrt()).collect();

        let mut theta_scan = theta0.clone();
        let losses = m.local_steps(&mut theta_scan, &bx, &by, &lrs);

        let mut theta_manual = theta0;
        for qi in 0..q {
            let x = &bx[qi * batch * m.d..(qi + 1) * batch * m.d];
            let yb = &by[qi * batch..(qi + 1) * batch];
            let (loss, g) = m.loss_and_grad(&theta_manual, x, yb);
            assert!((loss - losses[qi]).abs() < 1e-12);
            axpy(&mut theta_manual, -lrs[qi], &g);
        }
        assert_eq!(theta_scan, theta_manual);
    }

    #[test]
    fn combine_uniform_is_mean() {
        let m = model();
        let mut rng = Pcg64::seed(6);
        let n = 5;
        let thetas = rand_vec(&mut rng, n * m.p(), 0.5);
        let wrow = vec![1.0 / n as f32; n];
        let mixed = m.combine(&wrow, &thetas);
        let mean = row_mean(&thetas, n, m.p());
        testutil::assert_close(&mixed, &mean, 1e-5).unwrap();
    }

    #[test]
    fn dsgt_preserves_tracker_mean_property() {
        // key GT invariant: mean(Y^{r+1}) = mean(G^{r+1}) when Y^0 = G^0
        testutil::check("tracker mean", 8, 7, |rng| {
            let m = model();
            let n = 4;
            let batch = 6;
            let p = m.p();
            // metropolis ring weights
            let g = crate::graph::Graph::build(&crate::graph::Topology::Ring, n, rng)
                .map_err(|e| e.to_string())?;
            let w = crate::mixing::to_f32(&crate::mixing::build(&g, crate::mixing::Scheme::Metropolis));
            let theta = rand_vec(rng, n * p, 0.3);
            let bx0 = rand_vec(rng, n * batch * m.d, 1.0);
            let by0 = rand_labels(rng, n * batch);
            // init: G0 = grads at theta, Y0 = G0
            let mut g0 = vec![0.0f32; n * p];
            for i in 0..n {
                let (_, gi) = m.loss_and_grad(
                    &theta[i * p..(i + 1) * p],
                    &bx0[i * batch * m.d..(i + 1) * batch * m.d],
                    &by0[i * batch..(i + 1) * batch],
                );
                g0[i * p..(i + 1) * p].copy_from_slice(&gi);
            }
            let bx1 = rand_vec(rng, n * batch * m.d, 1.0);
            let by1 = rand_labels(rng, n * batch);
            let (_t1, y1, g1, _) =
                m.dsgt_round(&w, &theta, &g0, &g0, &bx1, &by1, 0.05, n, batch);
            let my = row_mean(&y1, n, p);
            let mg = row_mean(&g1, n, p);
            testutil::assert_close(&my, &mg, 1e-4)
        });
    }

    #[test]
    fn dsgd_round_at_consensus_with_zero_lr_is_noop() {
        let m = model();
        let mut rng = Pcg64::seed(8);
        let n = 3;
        let batch = 4;
        let p = m.p();
        let one = m.init(&mut rng);
        let mut theta = Vec::new();
        for _ in 0..n {
            theta.extend_from_slice(&one);
        }
        let g = crate::graph::Graph::build(&crate::graph::Topology::Complete, n, &mut rng).unwrap();
        let w = crate::mixing::to_f32(&crate::mixing::build(&g, crate::mixing::Scheme::Metropolis));
        let bx = rand_vec(&mut rng, n * batch * m.d, 1.0);
        let by = rand_labels(&mut rng, n * batch);
        let (next, _) = m.dsgd_round(&w, &theta, &bx, &by, 0.0, n, batch);
        testutil::assert_close(&next, &theta, 1e-5).unwrap();
    }

    #[test]
    fn eval_loss_is_the_record_mean_on_skewed_shards() {
        // the archetype bugfix: shard sizes 1 and 999 with different
        // per-record losses must reduce to the RECORD mean, not the node
        // mean — and loss and accuracy must weight the same population
        let m = model();
        let mut rng = Pcg64::seed(13);
        let t0 = m.init(&mut rng);
        let t1 = m.init(&mut rng);
        let mut theta = t0.clone();
        theta.extend_from_slice(&t1);
        let tiny = crate::data::Shard {
            n: 1,
            d: m.d,
            x: rand_vec(&mut rng, m.d, 2.0),
            y: vec![1.0],
        };
        let big = crate::data::Shard {
            n: 999,
            d: m.d,
            x: rand_vec(&mut rng, 999 * m.d, 1.0),
            y: rand_labels(&mut rng, 999),
        };
        let (l_tiny, _) = m.loss_and_grad(&t0, &tiny.x, &tiny.y);
        let (l_big, _) = m.loss_and_grad(&t1, &big.x, &big.y);
        let (loss, acc, _, _) = m.eval_full(&theta, &[tiny.clone(), big.clone()]);
        let record_mean = (l_tiny * 1.0 + l_big * 999.0) / 1000.0;
        assert_eq!(loss.to_bits(), record_mean.to_bits(), "{loss} vs {record_mean}");
        let node_mean = (l_tiny + l_big) / 2.0;
        assert!(
            (loss - node_mean).abs() > 1e-9,
            "shards differ, so record and node means must differ: {loss} vs {node_mean}"
        );
        // accuracy uses the identical population: correct / 1000
        let (_, _, c0, t0n) = m.eval_node(&t0, &tiny);
        let (_, _, c1, t1n) = m.eval_node(&t1, &big);
        assert_eq!(t0n + t1n, 1000);
        assert_eq!(acc, (c0 + c1) as f64 / 1000.0);
    }

    #[test]
    fn eval_consensus_zero_when_equal() {
        let m = model();
        let mut rng = Pcg64::seed(9);
        let one = m.init(&mut rng);
        let mut theta = Vec::new();
        for _ in 0..3 {
            theta.extend_from_slice(&one);
        }
        let shard = crate::data::Shard {
            n: 6,
            d: m.d,
            x: rand_vec(&mut rng, 6 * m.d, 1.0),
            y: rand_labels(&mut rng, 6),
        };
        let (_, acc, _, cons) = m.eval_full(&theta, &[shard.clone(), shard.clone(), shard]);
        assert!(cons < 1e-12, "{cons}");
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn into_kernels_bitwise_equal_allocating_wrappers_property() {
        // one Workspace reused across every case (different d/h/n shapes)
        // exercises the grow-only buffer contract as well
        let mut ws = Workspace::new();
        testutil::check("into == wrappers", 12, 11, |rng| {
            let d = rng.range(1, 20);
            let h = rng.range(1, 12);
            let m = NativeModel::new(d, h);
            let n = rng.range(1, 3 * BATCH_BLOCK); // crosses tile boundaries
            let theta = rand_vec(rng, m.p(), 0.3);
            let x = rand_vec(rng, n * d, 1.0);
            let y = rand_labels(rng, n);

            let a = m.logits(&theta, &x);
            let mut b = vec![0.0f64; n];
            m.logits_into(&theta, &x, &mut b, &mut ws);
            if a != b {
                return Err("logits_into differs from logits".into());
            }

            let (l1, g1) = m.loss_and_grad(&theta, &x, &y);
            let mut g2 = vec![0.0f32; m.p()];
            let l2 = m.loss_and_grad_into(&theta, &x, &y, &mut g2, &mut ws);
            if l1.to_bits() != l2.to_bits() || g1 != g2 {
                return Err("loss_and_grad_into differs from loss_and_grad".into());
            }

            let q = rng.range(1, 4);
            let bx = rand_vec(rng, q * n * d, 1.0);
            let by = rand_labels(rng, q * n);
            let lrs: Vec<f32> = (1..=q).map(|r| 0.05 / (r as f32).sqrt()).collect();
            let mut ta = theta.clone();
            let la = m.local_steps(&mut ta, &bx, &by, &lrs);
            let mut tb = theta.clone();
            let mut lb = vec![0.0f64; q];
            m.local_steps_into(&mut tb, &bx, &by, &lrs, &mut lb, &mut ws);
            if ta != tb || la != lb {
                return Err("local_steps_into differs from local_steps".into());
            }

            let nn = rng.range(1, 8);
            let thetas = rand_vec(rng, nn * m.p(), 0.5);
            let wrow: Vec<f32> =
                (0..nn).map(|_| if rng.bernoulli(0.6) { rng.next_f32() } else { 0.0 }).collect();
            let dense = m.combine(&wrow, &thetas);
            let mut out = vec![0.0f32; m.p()];
            m.combine_into(&wrow, &thetas, &mut out, &mut ws);
            if dense != out {
                return Err("combine_into differs from combine".into());
            }
            Ok(())
        });
    }

    #[test]
    fn sparse_node_updates_bitwise_equal_dense_property() {
        testutil::check("sparse node == dense node", 12, 17, |rng| {
            let m = model();
            let p = m.p();
            let n = rng.range(3, 10);
            let batch = 5;
            let g = crate::graph::Graph::build(&crate::graph::Topology::Ring, n, rng)
                .map_err(|e| e.to_string())?;
            let w =
                crate::mixing::to_f32(&crate::mixing::build(&g, crate::mixing::Scheme::Metropolis));
            let theta = rand_vec(rng, n * p, 0.3);
            let y_tr = rand_vec(rng, n * p, 0.1);
            let g_old = rand_vec(rng, n * p, 0.1);
            let bx = rand_vec(rng, n * batch * m.d, 1.0);
            let by = rand_labels(rng, n * batch);
            let mut ws = Workspace::new();
            for i in 0..n {
                let wrow = &w[i * n..(i + 1) * n];
                let mut idx = Vec::new();
                let mut val = Vec::new();
                for (j, &wj) in wrow.iter().enumerate() {
                    if wj != 0.0 {
                        idx.push(j as u32);
                        val.push(wj);
                    }
                }
                let (bx_i, by_i) =
                    (&bx[i * batch * m.d..(i + 1) * batch * m.d], &by[i * batch..(i + 1) * batch]);
                let theta_i = &theta[i * p..(i + 1) * p];

                let (td, ld) = m.dsgd_node(wrow, &theta, theta_i, bx_i, by_i, 0.05);
                let mut ts = vec![0.0f32; p];
                let ls = m.dsgd_node_into(
                    &idx, &val, &theta, theta_i, bx_i, by_i, 0.05, &mut ts, &mut ws,
                );
                if td != ts || ld.to_bits() != ls.to_bits() {
                    return Err(format!("dsgd node {i} differs"));
                }

                let (y_i, g_i) = (&y_tr[i * p..(i + 1) * p], &g_old[i * p..(i + 1) * p]);
                let (t1, y1, g1, l1) =
                    m.dsgt_node(wrow, &theta, &y_tr, y_i, g_i, bx_i, by_i, 0.05);
                let (mut t2, mut y2, mut g2) =
                    (vec![0.0f32; p], vec![0.0f32; p], vec![0.0f32; p]);
                let l2 = m.dsgt_node_into(
                    &idx, &val, &theta, &y_tr, y_i, g_i, bx_i, by_i, 0.05, &mut t2, &mut y2,
                    &mut g2, &mut ws,
                );
                if t1 != t2 || y1 != y2 || g1 != g2 || l1.to_bits() != l2.to_bits() {
                    return Err(format!("dsgt node {i} differs"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn robust_combines_have_the_expected_fixed_points() {
        // four participants with constant rows 1, 2, 3, 100 (one outlier),
        // uniform weights — every rule's output is a constant vector whose
        // value we can compute by hand
        let m = model();
        let p = m.p();
        let mut stacked = vec![0.0f32; 4 * p];
        for (j, c) in [1.0f32, 2.0, 3.0, 100.0].iter().enumerate() {
            stacked[j * p..(j + 1) * p].fill(*c);
        }
        let idx: Vec<u32> = (0..4).collect();
        let val = vec![0.25f32; 4];
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; p];
        let run = |rule: RobustRule, out: &mut Vec<f32>, ws: &mut Workspace| {
            m.combine_rule_into(rule, 0, &idx, &val, &stacked, out, ws);
            out[0]
        };
        assert_eq!(run(RobustRule::Mean, &mut out, &mut ws), 26.5);
        assert!(out.iter().all(|&v| v == 26.5));
        // t = ⌊0.25·4⌋ = 1 from each end → mean(2, 3)
        assert_eq!(run(RobustRule::TrimmedMean { trim: 0.25 }, &mut out, &mut ws), 2.5);
        // even k: median averages the two middles
        assert_eq!(run(RobustRule::Median, &mut out, &mut ws), 2.5);
        // f = ⌈0.25·4⌉ = 1: the outlier scores worst and is screened out
        assert_eq!(run(RobustRule::Krum { trim: 0.25 }, &mut out, &mut ws), 2.0);

        // odd k: median picks the middle value exactly
        let idx3: Vec<u32> = (0..3).collect();
        m.combine_rule_into(RobustRule::Median, 0, &idx3, &val[..3], &stacked, &mut out, &mut ws);
        assert!(out.iter().all(|&v| v == 2.0));

        // an isolated row (k = 1) passes through under every rule
        let solo = [2u32];
        for rule in [
            RobustRule::TrimmedMean { trim: 0.4 },
            RobustRule::Median,
            RobustRule::Krum { trim: 0.4 },
        ] {
            m.combine_rule_into(rule, 2, &solo, &val[..1], &stacked, &mut out, &mut ws);
            assert!(out.iter().all(|&v| v == 3.0), "{rule:?}");
        }

        // a 2-participant row is 50% attacker-capturable — no screen can
        // tell self from adversary, so the row keeps its own value (and a
        // pendant node whose only neighbor is Byzantine trains solo
        // instead of averaging with poison)
        let pair = [0u32, 3];
        for rule in [
            RobustRule::TrimmedMean { trim: 0.4 },
            RobustRule::Median,
            RobustRule::Krum { trim: 0.4 },
        ] {
            m.combine_rule_into(rule, 0, &pair, &val[..2], &stacked, &mut out, &mut ws);
            assert!(out.iter().all(|&v| v == 1.0), "{rule:?} must keep self");
            m.combine_rule_into(rule, 3, &pair, &val[..2], &stacked, &mut out, &mut ws);
            assert!(out.iter().all(|&v| v == 100.0), "{rule:?} must keep self");
        }
        // ... while the mean path still averages a 2-participant row
        m.combine_rule_into(RobustRule::Mean, 0, &pair, &val[..2], &stacked, &mut out, &mut ws);
        assert!(out.iter().all(|&v| v == 0.25 * (1.0 + 100.0)));
    }

    #[test]
    fn mean_rule_kernels_bitwise_equal_pinned_kernels_property() {
        // RobustRule::Mean must route through the identical code paths —
        // the robust dispatch costs no bits on the pinned default
        testutil::check("rule mean == pinned", 10, 23, |rng| {
            let m = model();
            let p = m.p();
            let n = rng.range(3, 8);
            let batch = 5;
            let g = crate::graph::Graph::build(&crate::graph::Topology::Ring, n, rng)
                .map_err(|e| e.to_string())?;
            let w =
                crate::mixing::to_f32(&crate::mixing::build(&g, crate::mixing::Scheme::Metropolis));
            let theta = rand_vec(rng, n * p, 0.3);
            let y_tr = rand_vec(rng, n * p, 0.1);
            let g_old = rand_vec(rng, n * p, 0.1);
            let bx = rand_vec(rng, n * batch * m.d, 1.0);
            let by = rand_labels(rng, n * batch);
            let mut ws = Workspace::new();
            for i in 0..n {
                let wrow = &w[i * n..(i + 1) * n];
                let (mut idx, mut val) = (Vec::new(), Vec::new());
                for (j, &wj) in wrow.iter().enumerate() {
                    if wj != 0.0 {
                        idx.push(j as u32);
                        val.push(wj);
                    }
                }
                let (bx_i, by_i) =
                    (&bx[i * batch * m.d..(i + 1) * batch * m.d], &by[i * batch..(i + 1) * batch]);
                let theta_i = &theta[i * p..(i + 1) * p];

                let (mut a, mut b) = (vec![0.0f32; p], vec![0.0f32; p]);
                m.combine_sparse_into(&idx, &val, &theta, &mut a, &mut ws);
                m.combine_rule_into(RobustRule::Mean, i as u32, &idx, &val, &theta, &mut b, &mut ws);
                if a != b {
                    return Err(format!("combine rule-mean differs at node {i}"));
                }

                let la = m.dsgd_node_into(
                    &idx, &val, &theta, theta_i, bx_i, by_i, 0.05, &mut a, &mut ws,
                );
                let lb = m.dsgd_node_rule_into(
                    RobustRule::Mean, i as u32, &idx, &val, &theta, theta_i, bx_i, by_i, 0.05, &mut b,
                    &mut ws,
                );
                if a != b || la.to_bits() != lb.to_bits() {
                    return Err(format!("dsgd rule-mean differs at node {i}"));
                }

                let (y_i, g_i) = (&y_tr[i * p..(i + 1) * p], &g_old[i * p..(i + 1) * p]);
                let (mut t1, mut y1, mut g1) =
                    (vec![0.0f32; p], vec![0.0f32; p], vec![0.0f32; p]);
                let (mut t2, mut y2, mut g2) =
                    (vec![0.0f32; p], vec![0.0f32; p], vec![0.0f32; p]);
                let l1 = m.dsgt_node_into(
                    &idx, &val, &theta, &y_tr, y_i, g_i, bx_i, by_i, 0.05, &mut t1, &mut y1,
                    &mut g1, &mut ws,
                );
                let l2 = m.dsgt_node_rule_into(
                    RobustRule::Mean, i as u32, &idx, &val, &theta, &y_tr, y_i, g_i, bx_i, by_i, 0.05,
                    &mut t2, &mut y2, &mut g2, &mut ws,
                );
                if t1 != t2 || y1 != y2 || g1 != g2 || l1.to_bits() != l2.to_bits() {
                    return Err(format!("dsgt rule-mean differs at node {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn robust_rules_shrug_off_a_poisoned_row() {
        // one Byzantine participant broadcasts a huge row; mean is dragged
        // away while trimmed/median/krum stay near the honest values
        let m = model();
        let p = m.p();
        let mut rng = Pcg64::seed(31);
        let n = 5;
        let mut stacked = rand_vec(&mut rng, n * p, 0.3);
        for v in &mut stacked[2 * p..3 * p] {
            *v = 1e4;
        }
        let idx: Vec<u32> = (0..n as u32).collect();
        let val = vec![1.0 / n as f32; n];
        let mut ws = Workspace::new();
        let mut honest = vec![0.0f32; p];
        // honest reference: unweighted mean of the four clean rows
        for c in 0..p {
            let mut acc = 0.0f64;
            for j in 0..n {
                if j != 2 {
                    acc += stacked[j * p + c] as f64;
                }
            }
            honest[c] = (acc / 4.0) as f32;
        }
        let mut out = vec![0.0f32; p];
        m.combine_rule_into(RobustRule::Mean, 0, &idx, &val, &stacked, &mut out, &mut ws);
        let mean_err = crate::algo::l2_dist_sq(&out, &honest).sqrt();
        assert!(mean_err > 100.0, "mean should be dragged: {mean_err}");
        for rule in [
            RobustRule::TrimmedMean { trim: 0.2 },
            RobustRule::Median,
            RobustRule::Krum { trim: 0.2 },
        ] {
            m.combine_rule_into(rule, 0, &idx, &val, &stacked, &mut out, &mut ws);
            let err = crate::algo::l2_dist_sq(&out, &honest).sqrt();
            // trimmed/median re-center within the honest sample's spread
            // (~O(1) over p coords); krum recovers the honest mean exactly
            assert!(err < 5.0, "{rule:?} dragged by the outlier: {err}");
            assert!(out.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn predict_probabilities() {
        let m = model();
        let mut rng = Pcg64::seed(10);
        let theta = m.init(&mut rng);
        let x = rand_vec(&mut rng, 7 * m.d, 1.0);
        let pr = m.predict(&theta, &x);
        assert_eq!(pr.len(), 7);
        assert!(pr.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
}
