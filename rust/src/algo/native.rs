//! Native (pure-rust) compute backend — the in-process twin of the AOT
//! artifacts.
//!
//! Implements exactly the same functions as `python/compile/model.py`
//! (flat-parameter shallow MLP, logistic loss, eq. 2/3/4 updates, full-shard
//! metrics) in plain rust with f64 accumulation.  Three jobs:
//!
//! 1. **correctness oracle** — integration tests run the PJRT artifacts and
//!    this backend on identical inputs and require agreement to f32 noise;
//! 2. **shape-free sweeps** — the Theorem-1 speedup bench varies N and the
//!    Q-sweep varies Q, which would otherwise need one AOT artifact set per
//!    configuration;
//! 3. **driver property tests** — coordinator invariants are tested without
//!    artifacts on disk.
//!
//! The PJRT path remains the production path; this backend exists so the
//! system is *testable and sweepable*, mirroring what e.g. a CPU-reference
//! backend is to a TPU runtime.

use super::{axpy, l2_dist_sq, row_mean};

/// Model dimensions (matches `ModelShapes` minus the artifact-bound fields).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NativeModel {
    pub d: usize,
    pub h: usize,
}

impl NativeModel {
    pub fn new(d: usize, h: usize) -> Self {
        assert!(d > 0 && h > 0);
        NativeModel { d, h }
    }

    /// Flat parameter count `d*h + h + h + 1`.
    pub fn p(&self) -> usize {
        self.d * self.h + 2 * self.h + 1
    }

    /// He-style init matching a small random start (std 0.2/sqrt(d)).
    pub fn init(&self, rng: &mut crate::rng::Pcg64) -> Vec<f32> {
        let std1 = (1.0 / self.d as f64).sqrt();
        let std2 = (1.0 / self.h as f64).sqrt();
        let mut theta = vec![0.0f32; self.p()];
        let (dh, h) = (self.d * self.h, self.h);
        for v in &mut theta[..dh] {
            *v = (rng.normal() * std1) as f32;
        }
        // b1 zeros
        for v in &mut theta[dh + h..dh + 2 * h] {
            *v = (rng.normal() * std2) as f32;
        }
        // b2 zero
        theta
    }

    /// Forward pass: logits for each of the `n` rows of `x` (row-major n×d).
    pub fn logits(&self, theta: &[f32], x: &[f32]) -> Vec<f64> {
        let (d, h) = (self.d, self.h);
        assert_eq!(theta.len(), self.p());
        let n = x.len() / d;
        assert_eq!(x.len(), n * d);
        let w1 = &theta[..d * h];
        let b1 = &theta[d * h..d * h + h];
        let w2 = &theta[d * h + h..d * h + 2 * h];
        let b2 = theta[d * h + 2 * h] as f64;
        let mut out = Vec::with_capacity(n);
        let mut hid = vec![0.0f64; h];
        for i in 0..n {
            let row = &x[i * d..(i + 1) * d];
            for (k, hk) in hid.iter_mut().enumerate() {
                let mut acc = b1[k] as f64;
                // w1 is [d, h] row-major: w1[j*h + k]
                for (j, &xj) in row.iter().enumerate() {
                    acc += xj as f64 * w1[j * h + k] as f64;
                }
                *hk = acc.tanh();
            }
            let mut z = b2;
            for (k, &hk) in hid.iter().enumerate() {
                z += hk * w2[k] as f64;
            }
            out.push(z);
        }
        out
    }

    /// Mean logistic loss (labels in {0,1}) and flat gradient — the
    /// `grad_step` artifact's twin.
    pub fn loss_and_grad(&self, theta: &[f32], x: &[f32], y: &[f32]) -> (f64, Vec<f32>) {
        let (d, h) = (self.d, self.h);
        let n = y.len();
        assert_eq!(x.len(), n * d);
        let w1 = &theta[..d * h];
        let b1 = &theta[d * h..d * h + h];
        let w2 = &theta[d * h + h..d * h + 2 * h];
        let b2 = theta[d * h + 2 * h] as f64;

        let mut g = vec![0.0f64; self.p()];
        let mut loss = 0.0f64;
        let mut hid = vec![0.0f64; h];
        let inv_n = 1.0 / n as f64;

        for i in 0..n {
            let row = &x[i * d..(i + 1) * d];
            for (k, hk) in hid.iter_mut().enumerate() {
                let mut acc = b1[k] as f64;
                for (j, &xj) in row.iter().enumerate() {
                    acc += xj as f64 * w1[j * h + k] as f64;
                }
                *hk = acc.tanh();
            }
            let mut z = b2;
            for (k, &hk) in hid.iter().enumerate() {
                z += hk * w2[k] as f64;
            }
            let yi = y[i] as f64;
            // loss: log(1 + e^z) - y z, numerically stable
            loss += if z > 0.0 { z + (-z).exp().ln_1p() } else { z.exp().ln_1p() } - yi * z;
            // dL/dz = sigmoid(z) - y
            let dz = 1.0 / (1.0 + (-z).exp()) - yi;
            let gz = dz * inv_n;
            // grads
            g[d * h + 2 * h] += gz; // b2
            for k in 0..h {
                g[d * h + h + k] += gz * hid[k]; // w2
                let dh = gz * w2[k] as f64 * (1.0 - hid[k] * hid[k]);
                g[d * h + k] += dh; // b1
                for (j, &xj) in row.iter().enumerate() {
                    g[j * h + k] += dh * xj as f64;
                }
            }
        }
        (loss * inv_n, g.into_iter().map(|v| v as f32).collect())
    }

    /// `count` eq.-4 SGD steps on pre-sampled batches — `local_steps` twin.
    /// `bx` is `[count, m, d]`, `by` `[count, m]`, `lrs` `[count]`.
    pub fn local_steps(
        &self,
        theta: &mut Vec<f32>,
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
    ) -> Vec<f64> {
        let count = lrs.len();
        if count == 0 {
            return Vec::new();
        }
        let m = by.len() / count;
        assert_eq!(bx.len(), count * m * self.d);
        let mut losses = Vec::with_capacity(count);
        for qi in 0..count {
            let x = &bx[qi * m * self.d..(qi + 1) * m * self.d];
            let yb = &by[qi * m..(qi + 1) * m];
            let (loss, grad) = self.loss_and_grad(theta, x, yb);
            axpy(theta, -lrs[qi], &grad);
            losses.push(loss);
        }
        losses
    }

    /// `Σ_j w_j θ_j` over stacked `thetas` (n×p) — `combine` twin.
    pub fn combine(&self, wrow: &[f32], thetas: &[f32]) -> Vec<f32> {
        let p = self.p();
        let n = wrow.len();
        assert_eq!(thetas.len(), n * p);
        let mut out = vec![0.0f64; p];
        for (j, &wj) in wrow.iter().enumerate() {
            if wj == 0.0 {
                continue;
            }
            for (o, &t) in out.iter_mut().zip(&thetas[j * p..(j + 1) * p]) {
                *o += wj as f64 * t as f64;
            }
        }
        out.into_iter().map(|v| v as f32).collect()
    }

    /// Node `i`'s eq.-2 update given the whole stacked Θ: `(W Θ)_i − lr ∇g_i`
    /// → (θ′_i, loss).  The ONLY implementation of the DSGD node update —
    /// the serial round below and the threaded `NativeCompute` fan-out both
    /// call it, so the math cannot desync between paths.
    pub fn dsgd_node(
        &self,
        wrow: &[f32],
        theta: &[f32],
        theta_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
    ) -> (Vec<f32>, f64) {
        let mut t_next = self.combine(wrow, theta);
        let (loss, grad) = self.loss_and_grad(theta_i, bx_i, by_i);
        axpy(&mut t_next, -lr, &grad);
        (t_next, loss)
    }

    /// Node `i`'s eq.-3 update given the stacked Θ and tracker Y:
    /// `θ′_i = (W Θ)_i − lr y_i`, `g′_i = ∇g_i(θ′_i)`,
    /// `y′_i = (W Y)_i + g′_i − g_i` → (θ′_i, y′_i, g′_i, loss).
    /// Single source of the DSGT node math for serial and threaded paths.
    #[allow(clippy::too_many_arguments)]
    pub fn dsgt_node(
        &self,
        wrow: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        y_i: &[f32],
        g_i: &[f32],
        bx_i: &[f32],
        by_i: &[f32],
        lr: f32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, f64) {
        let mut t_next = self.combine(wrow, theta);
        axpy(&mut t_next, -lr, y_i);
        let (loss, grad) = self.loss_and_grad(&t_next, bx_i, by_i);
        let mut y_next = self.combine(wrow, y_tr);
        axpy(&mut y_next, 1.0, &grad);
        axpy(&mut y_next, -1.0, g_i);
        (t_next, y_next, grad, loss)
    }

    /// Node `i`'s eval partial: (loss, grad, correct, total) on its shard.
    /// `eval_full` (serial and threaded) reduces these in node order.
    pub fn eval_node(&self, theta_i: &[f32], shard: &crate::data::Shard) -> (f64, Vec<f32>, usize, usize) {
        let (loss, grad) = self.loss_and_grad(theta_i, &shard.x, &shard.y);
        let zs = self.logits(theta_i, &shard.x);
        let correct = zs
            .iter()
            .zip(&shard.y)
            .filter(|(z, &yv)| ((**z > 0.0) as u32 as f32) == yv)
            .count();
        (loss, grad, correct, shard.y.len())
    }

    /// Whole-network eq. 2 — `dsgd_round` twin.
    /// Returns (Θ′ `[n,p]`, per-node losses).
    pub fn dsgd_round(
        &self,
        w: &[f32],
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        n: usize,
        m: usize,
    ) -> (Vec<f32>, Vec<f64>) {
        let p = self.p();
        let mut out = Vec::with_capacity(n * p);
        let mut losses = Vec::with_capacity(n);
        for i in 0..n {
            let (t, loss) = self.dsgd_node(
                &w[i * n..(i + 1) * n],
                theta,
                &theta[i * p..(i + 1) * p],
                &bx[i * m * self.d..(i + 1) * m * self.d],
                &by[i * m..(i + 1) * m],
                lr,
            );
            out.extend_from_slice(&t);
            losses.push(loss);
        }
        (out, losses)
    }

    /// Whole-network eq. 3 — `dsgt_round` twin.
    /// Returns (Θ′, Y′, G′, losses).  Node `i` depends only on its own rows
    /// of Y/G plus the shared Θ/Y stacks, so the round is a straight loop
    /// over [`Self::dsgt_node`].
    #[allow(clippy::too_many_arguments)]
    pub fn dsgt_round(
        &self,
        w: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        n: usize,
        m: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f64>) {
        let p = self.p();
        let mut theta_next = Vec::with_capacity(n * p);
        let mut y_next = Vec::with_capacity(n * p);
        let mut g_new = Vec::with_capacity(n * p);
        let mut losses = Vec::with_capacity(n);
        for i in 0..n {
            let (t, y, g, loss) = self.dsgt_node(
                &w[i * n..(i + 1) * n],
                theta,
                y_tr,
                &y_tr[i * p..(i + 1) * p],
                &g_old[i * p..(i + 1) * p],
                &bx[i * m * self.d..(i + 1) * m * self.d],
                &by[i * m..(i + 1) * m],
                lr,
            );
            theta_next.extend_from_slice(&t);
            y_next.extend_from_slice(&y);
            g_new.extend_from_slice(&g);
            losses.push(loss);
        }
        (theta_next, y_next, g_new, losses)
    }

    /// Full-shard metrics — `eval_full` twin:
    /// (mean loss, accuracy, `||mean grad||²`, consensus).
    /// A straight loop over [`Self::eval_node`] followed by the node-order
    /// reduction in [`Self::eval_reduce`].
    pub fn eval_full(&self, theta: &[f32], shards: &[crate::data::Shard]) -> (f64, f64, f64, f64) {
        let p = self.p();
        let n = shards.len();
        assert_eq!(theta.len(), n * p);
        let per: Vec<(f64, Vec<f32>, usize, usize)> = shards
            .iter()
            .enumerate()
            .map(|(i, s)| self.eval_node(&theta[i * p..(i + 1) * p], s))
            .collect();
        self.eval_reduce(theta, &per)
    }

    /// Reduce per-node eval partials in node order (the ONLY eval reduction —
    /// serial and threaded `eval_full` both call it, so the metric formulas
    /// exist once and cannot desync).
    pub fn eval_reduce(
        &self,
        theta: &[f32],
        per: &[(f64, Vec<f32>, usize, usize)],
    ) -> (f64, f64, f64, f64) {
        let p = self.p();
        let n = per.len();
        let mut mean_grad = vec![0.0f64; p];
        let mut loss_sum = 0.0;
        let mut correct = 0usize;
        let mut total = 0usize;
        for (loss, grad, c, t) in per {
            loss_sum += loss;
            for (acc, &g) in mean_grad.iter_mut().zip(grad) {
                *acc += g as f64;
            }
            correct += c;
            total += t;
        }
        let stat: f64 = mean_grad.iter().map(|g| (g / n as f64) * (g / n as f64)).sum();
        let theta_bar = row_mean(theta, n, p);
        let cons: f64 = (0..n)
            .map(|i| l2_dist_sq(&theta[i * p..(i + 1) * p], &theta_bar))
            .sum::<f64>()
            / n as f64;
        (loss_sum / n as f64, correct as f64 / total.max(1) as f64, stat, cons)
    }

    /// `P(AD|x)` per row — `predict` twin.
    pub fn predict(&self, theta: &[f32], x: &[f32]) -> Vec<f32> {
        self.logits(theta, x)
            .into_iter()
            .map(|z| (1.0 / (1.0 + (-z).exp())) as f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testutil;

    fn model() -> NativeModel {
        NativeModel::new(6, 4)
    }

    fn rand_vec(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }

    fn rand_labels(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 }).collect()
    }

    #[test]
    fn p_matches_formula() {
        assert_eq!(model().p(), 6 * 4 + 4 + 4 + 1);
        assert_eq!(NativeModel::new(42, 32).p(), 1409);
    }

    #[test]
    fn loss_at_zero_is_log2() {
        let m = model();
        let mut rng = Pcg64::seed(0);
        let x = rand_vec(&mut rng, 10 * m.d, 1.0);
        let y = rand_labels(&mut rng, 10);
        let (loss, _) = m.loss_and_grad(&vec![0.0; m.p()], &x, &y);
        assert!((loss - std::f64::consts::LN_2).abs() < 1e-9, "{loss}");
    }

    #[test]
    fn grad_matches_finite_differences_property() {
        testutil::check("native grad vs fd", 12, 3, |rng| {
            let m = model();
            let theta = rand_vec(rng, m.p(), 0.3);
            let x = rand_vec(rng, 8 * m.d, 1.0);
            let y = rand_labels(rng, 8);
            let (_, g) = m.loss_and_grad(&theta, &x, &y);
            let eps = 1e-3f32;
            for &idx in &[0usize, m.p() / 2, m.p() - 1] {
                let mut tp = theta.clone();
                tp[idx] += eps;
                let mut tm = theta.clone();
                tm[idx] -= eps;
                let (lp, _) = m.loss_and_grad(&tp, &x, &y);
                let (lm, _) = m.loss_and_grad(&tm, &x, &y);
                let fd = (lp - lm) / (2.0 * eps as f64);
                if (g[idx] as f64 - fd).abs() > 1e-3 * (1.0 + fd.abs()) {
                    return Err(format!("idx {idx}: grad {} vs fd {fd}", g[idx]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sgd_decreases_loss() {
        let m = model();
        let mut rng = Pcg64::seed(4);
        let mut theta = m.init(&mut rng);
        let x = rand_vec(&mut rng, 50 * m.d, 1.0);
        let y = rand_labels(&mut rng, 50);
        let (l0, g) = m.loss_and_grad(&theta, &x, &y);
        axpy(&mut theta, -0.5, &g);
        let (l1, _) = m.loss_and_grad(&theta, &x, &y);
        assert!(l1 < l0, "{l1} !< {l0}");
    }

    #[test]
    fn local_steps_match_manual_unroll() {
        let m = model();
        let mut rng = Pcg64::seed(5);
        let theta0 = m.init(&mut rng);
        let q = 4;
        let batch = 5;
        let bx = rand_vec(&mut rng, q * batch * m.d, 1.0);
        let by = rand_labels(&mut rng, q * batch);
        let lrs: Vec<f32> = (1..=q).map(|r| 0.02 / (r as f32).sqrt()).collect();

        let mut theta_scan = theta0.clone();
        let losses = m.local_steps(&mut theta_scan, &bx, &by, &lrs);

        let mut theta_manual = theta0;
        for qi in 0..q {
            let x = &bx[qi * batch * m.d..(qi + 1) * batch * m.d];
            let yb = &by[qi * batch..(qi + 1) * batch];
            let (loss, g) = m.loss_and_grad(&theta_manual, x, yb);
            assert!((loss - losses[qi]).abs() < 1e-12);
            axpy(&mut theta_manual, -lrs[qi], &g);
        }
        assert_eq!(theta_scan, theta_manual);
    }

    #[test]
    fn combine_uniform_is_mean() {
        let m = model();
        let mut rng = Pcg64::seed(6);
        let n = 5;
        let thetas = rand_vec(&mut rng, n * m.p(), 0.5);
        let wrow = vec![1.0 / n as f32; n];
        let mixed = m.combine(&wrow, &thetas);
        let mean = row_mean(&thetas, n, m.p());
        testutil::assert_close(&mixed, &mean, 1e-5).unwrap();
    }

    #[test]
    fn dsgt_preserves_tracker_mean_property() {
        // key GT invariant: mean(Y^{r+1}) = mean(G^{r+1}) when Y^0 = G^0
        testutil::check("tracker mean", 8, 7, |rng| {
            let m = model();
            let n = 4;
            let batch = 6;
            let p = m.p();
            // metropolis ring weights
            let g = crate::graph::Graph::build(&crate::graph::Topology::Ring, n, rng)
                .map_err(|e| e.to_string())?;
            let w = crate::mixing::to_f32(&crate::mixing::build(&g, crate::mixing::Scheme::Metropolis));
            let theta = rand_vec(rng, n * p, 0.3);
            let bx0 = rand_vec(rng, n * batch * m.d, 1.0);
            let by0 = rand_labels(rng, n * batch);
            // init: G0 = grads at theta, Y0 = G0
            let mut g0 = vec![0.0f32; n * p];
            for i in 0..n {
                let (_, gi) = m.loss_and_grad(
                    &theta[i * p..(i + 1) * p],
                    &bx0[i * batch * m.d..(i + 1) * batch * m.d],
                    &by0[i * batch..(i + 1) * batch],
                );
                g0[i * p..(i + 1) * p].copy_from_slice(&gi);
            }
            let bx1 = rand_vec(rng, n * batch * m.d, 1.0);
            let by1 = rand_labels(rng, n * batch);
            let (_t1, y1, g1, _) =
                m.dsgt_round(&w, &theta, &g0, &g0, &bx1, &by1, 0.05, n, batch);
            let my = row_mean(&y1, n, p);
            let mg = row_mean(&g1, n, p);
            testutil::assert_close(&my, &mg, 1e-4)
        });
    }

    #[test]
    fn dsgd_round_at_consensus_with_zero_lr_is_noop() {
        let m = model();
        let mut rng = Pcg64::seed(8);
        let n = 3;
        let batch = 4;
        let p = m.p();
        let one = m.init(&mut rng);
        let mut theta = Vec::new();
        for _ in 0..n {
            theta.extend_from_slice(&one);
        }
        let g = crate::graph::Graph::build(&crate::graph::Topology::Complete, n, &mut rng).unwrap();
        let w = crate::mixing::to_f32(&crate::mixing::build(&g, crate::mixing::Scheme::Metropolis));
        let bx = rand_vec(&mut rng, n * batch * m.d, 1.0);
        let by = rand_labels(&mut rng, n * batch);
        let (next, _) = m.dsgd_round(&w, &theta, &bx, &by, 0.0, n, batch);
        testutil::assert_close(&next, &theta, 1e-5).unwrap();
    }

    #[test]
    fn eval_consensus_zero_when_equal() {
        let m = model();
        let mut rng = Pcg64::seed(9);
        let one = m.init(&mut rng);
        let mut theta = Vec::new();
        for _ in 0..3 {
            theta.extend_from_slice(&one);
        }
        let shard = crate::data::Shard {
            n: 6,
            d: m.d,
            x: rand_vec(&mut rng, 6 * m.d, 1.0),
            y: rand_labels(&mut rng, 6),
        };
        let (_, acc, _, cons) = m.eval_full(&theta, &[shard.clone(), shard.clone(), shard]);
        assert!(cons < 1e-12, "{cons}");
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn predict_probabilities() {
        let m = model();
        let mut rng = Pcg64::seed(10);
        let theta = m.init(&mut rng);
        let x = rand_vec(&mut rng, 7 * m.d, 1.0);
        let pr = m.predict(&theta, &x);
        assert_eq!(pr.len(), 7);
        assert!(pr.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
}
