//! Algorithm-level building blocks shared by both execution drivers.
//!
//! The update rules themselves (paper eqs. 2–4) live in the AOT artifacts
//! (L2 jax, `python/compile/model.py`) and in the bit-mirroring native
//! backend (`native.rs`).  This module holds what remains above that level:
//! the paper's learning-rate schedule, the round structure implied by
//! Algorithm 1 (Q−1 local updates, then one communication update which
//! itself consumes a gradient), and the flat-vector helpers the drivers use.

pub mod native;

use anyhow::{bail, Result};

/// How a node aggregates its neighborhood's gossip payloads (its CSR row
/// of W) into the mixing term of eq. 2/3.
///
/// `Mean` is the paper's update — the W-weighted average — and keeps the
/// doubly-stochastic mean-preservation contract (DESIGN.md §14): it is the
/// pinned default, bitwise-identical to the pre-robust engine.  The robust
/// rules deliberately forfeit that contract to buy Byzantine tolerance:
/// they ignore the mixing weights (an attacker's weight is exactly what
/// must not matter) and aggregate the neighborhood as an unweighted sample,
/// so the network average is no longer invariant under gossip.  All three
/// are deterministic, so non-mean runs stay replay-identical.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RobustRule {
    /// W-weighted mean — the paper's combine, the pinned honest default.
    Mean,
    /// Coordinate-wise trimmed mean: drop the `⌊trim·k⌋` largest and
    /// smallest values per coordinate over the row's k participants, then
    /// average the rest.
    TrimmedMean {
        /// Fraction trimmed from *each* end, in [0, 0.5).
        trim: f64,
    },
    /// Coordinate-wise median over the row's participants (even counts
    /// average the two middle values).
    Median,
    /// Krum-style neighbor screening: score each participant by its summed
    /// squared distance to its closest peers, drop the `⌈trim·k⌉` highest
    /// scorers (the outliers), and average the survivors.
    Krum {
        /// Assumed attacker fraction to screen out, in [0, 0.5).
        trim: f64,
    },
}

impl RobustRule {
    /// Parse a `robust.rule` config string with its `robust.trim` knob.
    pub fn parse(rule: &str, trim: f64) -> Result<Self> {
        let needs_trim = matches!(rule, "trimmed-mean" | "trimmed" | "krum");
        if needs_trim && !(0.0..0.5).contains(&trim) {
            bail!("robust.trim must be in [0, 0.5), got {trim}");
        }
        match rule {
            "mean" => Ok(RobustRule::Mean),
            "trimmed-mean" | "trimmed" => Ok(RobustRule::TrimmedMean { trim }),
            "median" => Ok(RobustRule::Median),
            "krum" => Ok(RobustRule::Krum { trim }),
            other => bail!("unknown robust rule `{other}` (mean|trimmed-mean|median|krum)"),
        }
    }

    /// Short display label (experiment tables, logs).
    pub fn label(&self) -> String {
        match self {
            RobustRule::Mean => "mean".into(),
            RobustRule::TrimmedMean { trim } => format!("trimmed {trim:.2}"),
            RobustRule::Median => "median".into(),
            RobustRule::Krum { trim } => format!("krum {trim:.2}"),
        }
    }

    /// Is this the pinned W-weighted mean (the legacy bitwise path)?
    pub fn is_mean(&self) -> bool {
        matches!(self, RobustRule::Mean)
    }
}

/// The paper's diminishing step size `α_r = α₀ / √r` (§3: α₀ = 0.02).
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    /// The scale α₀ of the diminishing schedule.
    pub alpha0: f64,
}

impl LrSchedule {
    /// Schedule with scale `alpha0` (must be positive).
    pub fn new(alpha0: f64) -> Self {
        assert!(alpha0 > 0.0, "alpha0 must be positive");
        LrSchedule { alpha0 }
    }

    /// Step sizes are 1-indexed; `lr(0)` is clamped to `lr(1)`.
    pub fn lr(&self, step: usize) -> f32 {
        (self.alpha0 / (step.max(1) as f64).sqrt()) as f32
    }

    /// Learning rates for the local phase of communication round `round`
    /// (1-based): global steps `(round-1)*q + 1 ..= (round-1)*q + count`.
    pub fn local_lrs(&self, round: usize, q: usize, count: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; count];
        self.local_lrs_into(round, q, &mut out);
        out
    }

    /// [`Self::local_lrs`] into a caller buffer (`out.len()` steps) — the
    /// round engine reuses one buffer so steady-state rounds allocate
    /// nothing.
    pub fn local_lrs_into(&self, round: usize, q: usize, out: &mut [f32]) {
        let base = (round - 1) * q;
        for (k, o) in out.iter_mut().enumerate() {
            *o = self.lr(base + k + 1);
        }
    }

    /// Learning rate for the communication update of round `round`
    /// (global step `round * q`).
    pub fn comm_lr(&self, round: usize, q: usize) -> f32 {
        self.lr(round * q)
    }
}

/// Round structure of Algorithm 1 for a given local period Q:
/// `local_per_round` eq.-4 updates followed by one eq.-2/3 update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoundPlan {
    /// Local period Q.
    pub q: usize,
    /// Q − 1 (0 when Q = 1, i.e. classic DSGD/DSGT).
    pub local_per_round: usize,
}

impl RoundPlan {
    /// Round structure for local period `q` (≥ 1).
    pub fn new(q: usize) -> Self {
        assert!(q >= 1);
        RoundPlan { q, local_per_round: q - 1 }
    }

    /// Total gradient evaluations per communication round.
    pub fn steps_per_round(&self) -> usize {
        self.q
    }

    /// Communication rounds needed to spend `total_steps` local iterations.
    pub fn rounds_for(&self, total_steps: usize) -> usize {
        total_steps.div_ceil(self.q)
    }
}

// ---- flat f32 vector helpers (the gossip payload math) ----

/// `y += a * x`
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// `y = a*x + b*y`
pub fn axpby(y: &mut [f32], a: f32, x: &[f32], b: f32) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = a * xi + b * *yi;
    }
}

/// `y += a − b` elementwise — the decoded-self correction of the
/// difference-form compressed gossip update (DESIGN.md §10): the mixing
/// term reads decoded values, so the node adds back `θ_i − x̂_i` to keep its
/// own parameters at full precision.  When `a == b` bitwise (the identity
/// compressor) every addend is exactly `+0.0`, which leaves `y` unchanged
/// bit for bit for any `y` that carries no negative zeros — true of every
/// combine output, whose f64 accumulator never produces `−0.0`; the
/// lossless-plumbing pin relies on this.
pub fn add_diff(y: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(y.len(), a.len());
    assert_eq!(y.len(), b.len());
    for ((yi, &ai), &bi) in y.iter_mut().zip(a).zip(b) {
        *yi += ai - bi;
    }
}

/// `next = prev + s·(next − prev)` elementwise — the FedNova-style τ-weighted
/// rescale of one node's local-phase displacement under a heterogeneous
/// compute plan (`engine::stragglers`): a node that ran `L_i` local steps has
/// its displacement scaled to represent the round's mean local work `L̄`, so
/// gossip mixes unbiased contributions.  Callers skip the call entirely when
/// `s == 1.0` (uniform plans, or `L_i == L̄`): `prev + 1.0·(next − prev)` is
/// NOT a bitwise identity in f32, and the determinism contract requires the
/// fused and actor drivers to take the identical branch — both derive `s`
/// from the same `ComputeSchedule`, so they do.
pub fn scale_displacement(next: &mut [f32], prev: &[f32], s: f32) {
    assert_eq!(next.len(), prev.len());
    for (n, &p) in next.iter_mut().zip(prev) {
        *n = p + s * (*n - p);
    }
}

/// `y *= a`
pub fn scale(y: &mut [f32], a: f32) {
    for yi in y.iter_mut() {
        *yi *= a;
    }
}

/// Euclidean norm with f64 accumulation.
pub fn l2_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Squared Euclidean distance with f64 accumulation.
pub fn l2_dist_sq(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum()
}

/// Row-mean of a flat row-major `[n x p]` matrix.
pub fn row_mean(flat: &[f32], n: usize, p: usize) -> Vec<f32> {
    assert_eq!(flat.len(), n * p);
    let mut out = vec![0.0f64; p];
    for i in 0..n {
        for (acc, &v) in out.iter_mut().zip(&flat[i * p..(i + 1) * p]) {
            *acc += v as f64;
        }
    }
    out.into_iter().map(|v| (v / n as f64) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_matches_paper() {
        let s = LrSchedule::new(0.02);
        assert!((s.lr(1) - 0.02).abs() < 1e-9);
        assert!((s.lr(100) - 0.002).abs() < 1e-9);
        assert_eq!(s.lr(0), s.lr(1));
    }

    #[test]
    fn local_lrs_cover_round_prefix() {
        let s = LrSchedule::new(0.02);
        // round 2, q = 5: local steps are global steps 6..=9, comm step 10
        let lrs = s.local_lrs(2, 5, 4);
        assert_eq!(lrs.len(), 4);
        assert!((lrs[0] - s.lr(6)).abs() < 1e-9);
        assert!((lrs[3] - s.lr(9)).abs() < 1e-9);
        assert!((s.comm_lr(2, 5) - s.lr(10)).abs() < 1e-9);
    }

    #[test]
    fn round_plan() {
        let p = RoundPlan::new(100);
        assert_eq!(p.local_per_round, 99);
        assert_eq!(p.steps_per_round(), 100);
        assert_eq!(p.rounds_for(10_000), 100);
        assert_eq!(p.rounds_for(10_001), 101);
        let classic = RoundPlan::new(1);
        assert_eq!(classic.local_per_round, 0);
        assert_eq!(classic.rounds_for(500), 500);
    }

    #[test]
    fn vector_helpers() {
        let mut y = vec![1.0f32, 2.0];
        axpy(&mut y, 2.0, &[10.0, 20.0]);
        assert_eq!(y, vec![21.0, 42.0]);
        axpby(&mut y, 1.0, &[1.0, 1.0], 0.0);
        assert_eq!(y, vec![1.0, 1.0]);
        scale(&mut y, 3.0);
        assert_eq!(y, vec![3.0, 3.0]);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert_eq!(l2_dist_sq(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn add_diff_is_exact_noop_on_equal_inputs() {
        let a = vec![1.5f32, -2.0, 0.0, -0.0];
        let mut y = vec![7.0f32, 8.0, -9.0, 0.5];
        let y0 = y.clone();
        add_diff(&mut y, &a, &a);
        // every addend is a − a = +0.0 → y unchanged bit for bit
        for (before, after) in y0.iter().zip(&y) {
            assert_eq!(before.to_bits(), after.to_bits());
        }
        add_diff(&mut y, &[2.0, 2.0, 2.0, 2.0], &[0.5, 0.5, 0.5, 0.5]);
        assert_eq!(y, vec![8.5, 9.5, -7.5, 2.0]);
    }

    #[test]
    fn scale_displacement_rescales_the_delta() {
        let prev = vec![1.0f32, -2.0, 0.5];
        let mut next = vec![3.0f32, -2.0, -0.5];
        scale_displacement(&mut next, &prev, 0.5);
        assert_eq!(next, vec![2.0, -2.0, 0.0]);
        // s = 0 collapses to the pre-phase parameters exactly
        let mut next = vec![3.0f32, -2.0, -0.5];
        scale_displacement(&mut next, &prev, 0.0);
        assert_eq!(next, prev);
    }

    #[test]
    fn row_mean_small() {
        let flat = [1.0f32, 2.0, 3.0, 5.0];
        assert_eq!(row_mean(&flat, 2, 2), vec![2.0, 3.5]);
    }

    #[test]
    fn robust_rule_parsing() {
        assert_eq!(RobustRule::parse("mean", 0.0).unwrap(), RobustRule::Mean);
        assert!(RobustRule::parse("mean", 0.0).unwrap().is_mean());
        assert_eq!(
            RobustRule::parse("trimmed-mean", 0.2).unwrap(),
            RobustRule::TrimmedMean { trim: 0.2 }
        );
        assert_eq!(RobustRule::parse("median", 0.2).unwrap(), RobustRule::Median);
        assert_eq!(RobustRule::parse("krum", 0.25).unwrap(), RobustRule::Krum { trim: 0.25 });
        assert!(RobustRule::parse("trimmed-mean", 0.5).is_err());
        assert!(RobustRule::parse("krum", -0.1).is_err());
        assert!(RobustRule::parse("bogus", 0.0).is_err());
        assert!(!RobustRule::parse("median", 0.0).unwrap().is_mean());
    }
}
