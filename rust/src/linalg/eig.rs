//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Used for mixing-matrix spectra (the second-largest eigenvalue magnitude
//! drives consensus speed — Assumption 1), spectral-gap reporting in the
//! topology benches, and PCA.  Jacobi is O(n^3) per sweep but unconditionally
//! stable and exact enough (off-diagonal Frobenius norm < 1e-12) for the
//! small matrices this system handles.

use super::Mat;

/// Eigendecomposition of a symmetric matrix: `A = V diag(values) V^T`.
/// `values` are sorted ascending; `vectors.col(k)` is the k-th eigenvector.
#[derive(Clone, Debug)]
pub struct SymEig {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Column k is the eigenvector for values[k].
    pub vectors: Mat,
}

/// Cyclic Jacobi eigensolver. Panics if `a` is not square; symmetry is the
/// caller's contract (use `Mat::is_symmetric` to validate first).
pub fn sym_eig(a: &Mat) -> SymEig {
    assert_eq!(a.rows, a.cols, "sym_eig needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);

    let off = |m: &Mat| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                s += m[(i, j)] * m[(i, j)];
            }
        }
        s
    };

    const MAX_SWEEPS: usize = 100;
    let scale = a.frob_norm().max(1e-300);
    for _ in 0..MAX_SWEEPS {
        if off(&m).sqrt() <= 1e-13 * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // rotation angle
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // apply rotation J(p,q,theta) on both sides: m = J^T m J
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // extract + sort ascending
    let mut idx: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&i, &j| diag[i].partial_cmp(&diag[j]).unwrap());

    let values: Vec<f64> = idx.iter().map(|&i| diag[i]).collect();
    let mut vectors = Mat::zeros(n, n);
    for (new_col, &old_col) in idx.iter().enumerate() {
        for r in 0..n {
            vectors[(r, new_col)] = v[(r, old_col)];
        }
    }
    SymEig { values, vectors }
}

/// Magnitude of the second-largest-in-magnitude eigenvalue of a (symmetric,
/// stochastic) mixing matrix — the consensus contraction factor.  For a
/// doubly stochastic W, the largest eigenvalue is exactly 1 with eigenvector
/// 1/sqrt(n); this returns max |λ_k| over the remaining spectrum.
pub fn second_eigenvalue_magnitude(w: &Mat) -> f64 {
    let eig = sym_eig(w);
    let n = eig.values.len();
    if n < 2 {
        return 0.0;
    }
    // drop the eigenvalue closest to 1 (the consensus mode), take max |.| of rest
    let mut vals = eig.values.clone();
    let one_idx = (0..n)
        .min_by(|&i, &j| {
            (vals[i] - 1.0)
                .abs()
                .partial_cmp(&(vals[j] - 1.0).abs())
                .unwrap()
        })
        .unwrap();
    vals.remove(one_idx);
    vals.into_iter().map(f64::abs).fold(0.0, f64::max)
}

/// Power-iteration estimate of [`second_eigenvalue_magnitude`] needing only
/// a matvec `apply(x, out)` (out = W·x) — the large-n path where Jacobi's
/// O(n³) dense sweeps are unaffordable.  The consensus mode is deflated by
/// subtracting the mean after every application (1/√n is the known
/// eigenvector of a symmetric doubly stochastic W), and the iteration runs
/// on W² so negative eigenvalues cannot cancel: the Rayleigh quotient
/// converges to λ₂² and the result is its square root.  Deterministic
/// (fixed-seed start vector, residual-based stop); agreement with the Jacobi
/// oracle is pinned to 1e-9 for n ≤ 200 in the property tests.
pub fn second_eig_magnitude_power(n: usize, apply: impl FnMut(&[f64], &mut [f64])) -> f64 {
    second_eig_magnitude_power_opts(n, PowerIterOpts::default(), apply)
}

/// Budget for [`second_eig_magnitude_power_opts`].  The defaults are the
/// exact constants the un-parameterized entry point has always used, so the
/// Jacobi-oracle 1e-9 pins are untouched; `net.validate = approx` trades
/// them down (BENCH_6: the full iteration costs 581 s at n = 10⁵, almost all
/// of it tail iterations squeezing the last digits of an already-converged
/// estimate).
#[derive(Clone, Copy, Debug)]
pub struct PowerIterOpts {
    /// Hard cap on W² iterations.
    pub max_iters: usize,
    /// Relative residual stop: iterate until `res ≤ tol · max(|ρ|, 1e-6)`.
    pub tol: f64,
}

impl Default for PowerIterOpts {
    fn default() -> Self {
        PowerIterOpts { max_iters: 200_000, tol: 1e-13 }
    }
}

impl PowerIterOpts {
    /// The loose budget behind `net.validate = approx`: enough digits to
    /// decide λ₂ < 1 and report a usable spectral gap, orders of magnitude
    /// fewer tail iterations at large n.
    pub fn approx() -> Self {
        PowerIterOpts { max_iters: 500, tol: 1e-6 }
    }
}

/// [`second_eig_magnitude_power`] with an explicit iteration/tolerance
/// budget.  Same deterministic start vector and update; only the stopping
/// rule moves.
pub fn second_eig_magnitude_power_opts(
    n: usize,
    opts: PowerIterOpts,
    mut apply: impl FnMut(&[f64], &mut [f64]),
) -> f64 {
    if n < 2 {
        return 0.0;
    }
    let deflate = |v: &mut [f64]| {
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        for e in v.iter_mut() {
            *e -= mean;
        }
    };
    let mut rng = crate::rng::Pcg64::seed(0x5EC0_0E16);
    let mut x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    deflate(&mut x);
    let nx = x.iter().map(|e| e * e).sum::<f64>().sqrt();
    if nx <= f64::MIN_POSITIVE {
        return 0.0;
    }
    for e in x.iter_mut() {
        *e /= nx;
    }
    let mut tmp = vec![0.0; n];
    let mut y = vec![0.0; n];
    let mut rho = 0.0;
    for _ in 0..opts.max_iters {
        apply(&x, &mut tmp);
        deflate(&mut tmp);
        apply(&tmp, &mut y);
        deflate(&mut y); // re-deflate: guards f64 drift back into consensus
        // Rayleigh quotient of W² at the unit vector x
        rho = x.iter().zip(&y).map(|(a, b)| a * b).sum::<f64>();
        let res = x
            .iter()
            .zip(&y)
            .map(|(a, b)| (b - rho * a) * (b - rho * a))
            .sum::<f64>()
            .sqrt();
        let ny = y.iter().map(|e| e * e).sum::<f64>().sqrt();
        if ny <= 1e-150 {
            return 0.0; // W² annihilates the deflated space (λ₂ = 0)
        }
        for (xi, yi) in x.iter_mut().zip(&y) {
            *xi = yi / ny;
        }
        // |ρ - λ₂²| ≤ residual for symmetric operators
        if res <= opts.tol * rho.abs().max(1e-6) {
            break;
        }
    }
    rho.max(0.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testutil;

    fn random_symmetric(rng: &mut Pcg64, n: usize) -> Mat {
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let x = rng.normal();
                a[(i, j)] = x;
                a[(j, i)] = x;
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = -1.0;
        a[(2, 2)] = 2.0;
        let e = sym_eig(&a);
        assert!((e.values[0] + 1.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_property() {
        testutil::check("A = V D V^T", 24, 7, |rng| {
            let n = rng.range(2, 12);
            let a = random_symmetric(rng, n);
            let e = sym_eig(&a);
            // rebuild A
            let mut d = Mat::zeros(n, n);
            for i in 0..n {
                d[(i, i)] = e.values[i];
            }
            let rebuilt = e.vectors.matmul(&d).matmul(&e.vectors.t());
            let err = a.sub(&rebuilt).frob_norm() / a.frob_norm().max(1.0);
            if err < 1e-10 {
                Ok(())
            } else {
                Err(format!("reconstruction err {err}"))
            }
        });
    }

    #[test]
    fn vectors_orthonormal_property() {
        testutil::check("V^T V = I", 24, 8, |rng| {
            let n = rng.range(2, 12);
            let a = random_symmetric(rng, n);
            let e = sym_eig(&a);
            let vtv = e.vectors.t().matmul(&e.vectors);
            let err = vtv.sub(&Mat::eye(n)).frob_norm();
            if err < 1e-10 {
                Ok(())
            } else {
                Err(format!("orthonormality err {err}"))
            }
        });
    }

    #[test]
    fn trace_equals_eigenvalue_sum() {
        testutil::check("trace = sum eig", 24, 9, |rng| {
            let n = rng.range(2, 10);
            let a = random_symmetric(rng, n);
            let e = sym_eig(&a);
            let tr: f64 = (0..n).map(|i| a[(i, i)]).sum();
            let sum: f64 = e.values.iter().sum();
            if (tr - sum).abs() < 1e-9 * (1.0 + tr.abs()) {
                Ok(())
            } else {
                Err(format!("trace {tr} vs sum {sum}"))
            }
        });
    }

    #[test]
    fn second_eig_of_complete_graph_metropolis() {
        // complete graph metropolis: W = (1/n) 11^T → second eigenvalue 0
        let n = 6;
        let w = Mat::from_vec(n, n, vec![1.0 / n as f64; n * n]);
        assert!(second_eigenvalue_magnitude(&w) < 1e-10);
    }

    #[test]
    fn second_eig_of_identity_is_one() {
        // identity = no mixing → contraction factor 1 (never converges)
        assert!((second_eigenvalue_magnitude(&Mat::eye(5)) - 1.0).abs() < 1e-12);
    }

    fn ring_metropolis(n: usize) -> Mat {
        // ring, metropolis: 1/3 to each neighbor, 1/3 self
        let mut w = Mat::zeros(n, n);
        for i in 0..n {
            w[(i, i)] = 1.0 / 3.0;
            w[(i, (i + 1) % n)] = 1.0 / 3.0;
            w[(i, (i + n - 1) % n)] = 1.0 / 3.0;
        }
        w
    }

    #[test]
    fn default_opts_are_the_pinned_constants() {
        // the un-parameterized entry point must keep its historical budget
        // bit for bit — the Jacobi 1e-9 pins depend on it
        let o = PowerIterOpts::default();
        assert_eq!(o.max_iters, 200_000);
        assert_eq!(o.tol.to_bits(), 1e-13f64.to_bits());

        let w = ring_metropolis(24);
        let apply = |x: &[f64], out: &mut [f64]| {
            for i in 0..24 {
                out[i] = (0..24).map(|j| w[(i, j)] * x[j]).sum();
            }
        };
        let full = second_eig_magnitude_power(24, apply);
        let via_opts = second_eig_magnitude_power_opts(24, PowerIterOpts::default(), apply);
        assert_eq!(full.to_bits(), via_opts.to_bits());
    }

    #[test]
    fn approx_budget_agrees_on_mixing_spectra() {
        // approx keeps enough digits to decide λ₂ < 1 and report the gap
        for n in [8usize, 32, 100] {
            let w = ring_metropolis(n);
            let apply = |x: &[f64], out: &mut [f64]| {
                for i in 0..n {
                    out[i] = (0..n).map(|j| w[(i, j)] * x[j]).sum();
                }
            };
            let full = second_eig_magnitude_power(n, apply);
            let loose = second_eig_magnitude_power_opts(n, PowerIterOpts::approx(), apply);
            assert!(
                (full - loose).abs() < 1e-3,
                "n={n}: full {full} vs approx {loose}"
            );
            assert!(loose < 1.0);
        }
    }
}
