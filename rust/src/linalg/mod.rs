//! Dense linear algebra (f64, row-major), built from scratch for the
//! analysis substrates: mixing-matrix spectra (Assumption 1), PCA
//! initialization for t-SNE, and general experiment math.
//!
//! Scope is deliberately "small dense": the largest matrices in this system
//! are N x N mixing matrices (N ≤ a few hundred) and sample covariance
//! matrices (42 x 42), so an O(n^3) Jacobi eigensolver is simple, robust and
//! fast enough.

pub mod eig;

pub use eig::{
    second_eig_magnitude_power, second_eig_magnitude_power_opts, sym_eig, PowerIterOpts, SymEig,
};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major storage, length `rows * cols`.
    pub data: Vec<f64>,
}

impl Mat {
    /// All-zero `rows x cols` matrix.
    ///
    /// Debug builds refuse huge *square* allocations: an n×n matrix at
    /// network scale is always a bug (the sparse-native stack never
    /// materializes one), while tall-skinny record matrices stay legal.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        debug_assert!(
            !(rows == cols && rows > 8192),
            "Mat::zeros({rows}, {cols}): dense square matrices this large are gated — \
             the network axis must stay sparse (SparseW / power iteration)"
        );
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a list of equal-length rows.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        Mat { rows: r, cols: c, data: rows.concat() }
    }

    /// Wrap a flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "size mismatch");
        Mat { rows, cols, data }
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Matrix product `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        // ikj loop order for cache-friendly row-major access
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[k * other.cols..(k + 1) * other.cols];
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Scalar multiple.
    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Elementwise sum.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    /// Elementwise difference.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Is the matrix symmetric to within `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Column means (used by PCA / standardization).
    pub fn col_means(&self) -> Vec<f64> {
        let mut m = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (acc, &v) in m.iter_mut().zip(self.row(i)) {
                *acc += v;
            }
        }
        for v in &mut m {
            *v /= self.rows as f64;
        }
        m
    }

    /// Sample covariance (rows = observations).
    pub fn covariance(&self) -> Mat {
        assert!(self.rows > 1, "covariance needs > 1 row");
        let means = self.col_means();
        let mut cov = Mat::zeros(self.cols, self.cols);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..self.cols {
                let di = row[i] - means[i];
                for j in i..self.cols {
                    cov[(i, j)] += di * (row[j] - means[j]);
                }
            }
        }
        let denom = (self.rows - 1) as f64;
        for i in 0..self.cols {
            for j in i..self.cols {
                cov[(i, j)] /= denom;
                cov[(j, i)] = cov[(i, j)];
            }
        }
        cov
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

// ---- vector helpers ----

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Euclidean distance.
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Project rows of `x` onto the top-`k` principal components.
pub fn pca(x: &Mat, k: usize) -> Mat {
    assert!(k <= x.cols, "pca k > cols");
    let cov = x.covariance();
    let eig = sym_eig(&cov);
    // eigenvalues ascending → take last k columns, largest first
    let means = x.col_means();
    let mut out = Mat::zeros(x.rows, k);
    for r in 0..x.rows {
        for (kk, out_col) in (0..k).enumerate() {
            let col = x.cols - 1 - kk; // descending eigenvalue order
            let mut acc = 0.0;
            for j in 0..x.cols {
                acc += (x[(r, j)] - means[j]) * eig.vectors[(j, col)];
            }
            out[(r, out_col)] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testutil;

    #[test]
    fn eye_matmul_identity() {
        let mut rng = Pcg64::seed(0);
        let a = Mat::from_vec(4, 4, (0..16).map(|_| rng.normal()).collect());
        let i = Mat::eye(4);
        let prod = a.matmul(&i);
        assert!(a.sub(&prod).frob_norm() < 1e-12);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_associative_property() {
        testutil::check("matmul assoc", 16, 1, |rng| {
            let n = rng.range(1, 8);
            let m = rng.range(1, 8);
            let k = rng.range(1, 8);
            let l = rng.range(1, 8);
            let a = Mat::from_vec(n, m, (0..n * m).map(|_| rng.normal()).collect());
            let b = Mat::from_vec(m, k, (0..m * k).map(|_| rng.normal()).collect());
            let c = Mat::from_vec(k, l, (0..k * l).map(|_| rng.normal()).collect());
            let left = a.matmul(&b).matmul(&c);
            let right = a.matmul(&b.matmul(&c));
            if left.sub(&right).frob_norm() < 1e-9 {
                Ok(())
            } else {
                Err("assoc violated".into())
            }
        });
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seed(2);
        let a = Mat::from_vec(3, 5, (0..15).map(|_| rng.normal()).collect());
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::seed(3);
        let a = Mat::from_vec(4, 6, (0..24).map(|_| rng.normal()).collect());
        let x: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let xm = Mat::from_vec(6, 1, x.clone());
        let via_matmul = a.matmul(&xm);
        let via_matvec = a.matvec(&x);
        for i in 0..4 {
            assert!((via_matmul[(i, 0)] - via_matvec[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn covariance_of_known_data() {
        // perfectly correlated columns → cov = [[1,1],[1,1]] * var
        let x = Mat::from_rows(&[vec![0.0, 0.0], vec![1.0, 1.0], vec![2.0, 2.0]]);
        let c = x.covariance();
        assert!((c[(0, 0)] - 1.0).abs() < 1e-12);
        assert!((c[(0, 1)] - 1.0).abs() < 1e-12);
        assert!(c.is_symmetric(1e-14));
    }

    #[test]
    fn covariance_psd_property() {
        testutil::check("cov psd", 16, 4, |rng| {
            let n = rng.range(3, 20);
            let d = rng.range(2, 6);
            let x = Mat::from_vec(n, d, (0..n * d).map(|_| rng.normal()).collect());
            let cov = x.covariance();
            let eig = sym_eig(&cov);
            if eig.values.iter().all(|&v| v > -1e-9) {
                Ok(())
            } else {
                Err(format!("negative eigenvalue: {:?}", eig.values))
            }
        });
    }

    #[test]
    fn pca_recovers_dominant_direction() {
        // data stretched along (1,1)/sqrt(2): first PC must align with it
        let mut rng = Pcg64::seed(5);
        let mut rows = Vec::new();
        for _ in 0..200 {
            let t = rng.normal() * 10.0;
            let e = rng.normal() * 0.1;
            rows.push(vec![t + e, t - e]);
        }
        let x = Mat::from_rows(&rows);
        let proj = pca(&x, 1);
        // variance along PC1 should be ~ 2 * 100 (t appears in both coords)
        let col: Vec<f64> = (0..proj.rows).map(|i| proj[(i, 0)]).collect();
        assert!(variance(&col) > 150.0, "pc1 var {}", variance(&col));
    }

    #[test]
    fn vector_helpers() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(dist2(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        Mat::zeros(2, 3).matmul(&Mat::zeros(4, 2));
    }
}
