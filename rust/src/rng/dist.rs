//! Reusable distribution objects built on [`super::Pcg64`].

use super::Pcg64;

/// Normal distribution with fixed mean and standard deviation.
#[derive(Clone, Copy, Debug)]
pub struct Normal {
    /// Mean.
    pub mean: f64,
    /// Standard deviation.
    pub std: f64,
}

impl Normal {
    /// Normal with the given moments.
    pub fn new(mean: f64, std: f64) -> Self {
        assert!(std >= 0.0, "negative std");
        Normal { mean, std }
    }

    /// Draw one value.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        rng.normal_ms(self.mean, self.std)
    }

    /// Sample truncated to [lo, hi] by rejection (used for clinically
    /// plausible vitals/labs in the EHR generator).
    pub fn sample_clamped(&self, rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
        self.sample(rng).clamp(lo, hi)
    }
}

/// Symmetric Dirichlet over `k` categories (label-skew heterogeneity knob:
/// small alpha → highly non-identical shards, large alpha → near-iid).
#[derive(Clone, Debug)]
pub struct Dirichlet {
    /// Concentration parameters (all positive).
    pub alpha: Vec<f64>,
}

impl Dirichlet {
    /// Symmetric Dirichlet over `k` categories.
    pub fn symmetric(k: usize, alpha: f64) -> Self {
        assert!(k > 0 && alpha > 0.0);
        Dirichlet { alpha: vec![alpha; k] }
    }

    /// Dirichlet with the given concentrations.
    pub fn new(alpha: Vec<f64>) -> Self {
        assert!(!alpha.is_empty() && alpha.iter().all(|&a| a > 0.0));
        Dirichlet { alpha }
    }

    /// Draw one probability vector.
    pub fn sample(&self, rng: &mut Pcg64) -> Vec<f64> {
        let gs: Vec<f64> = self.alpha.iter().map(|&a| rng.gamma(a).max(1e-300)).collect();
        let total: f64 = gs.iter().sum();
        gs.into_iter().map(|g| g / total).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_clamped_stays_in_bounds() {
        let mut rng = Pcg64::seed(1);
        let d = Normal::new(0.0, 10.0);
        for _ in 0..1000 {
            let x = d.sample_clamped(&mut rng, -1.0, 1.0);
            assert!((-1.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Pcg64::seed(2);
        let d = Dirichlet::symmetric(5, 0.3);
        for _ in 0..100 {
            let p = d.sample(&mut rng);
            assert_eq!(p.len(), 5);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_small_alpha_is_spiky() {
        let mut rng = Pcg64::seed(3);
        let spiky = Dirichlet::symmetric(10, 0.05);
        let flat = Dirichlet::symmetric(10, 100.0);
        let max_spiky: f64 = (0..200)
            .map(|_| spiky.sample(&mut rng).into_iter().fold(0.0, f64::max))
            .sum::<f64>()
            / 200.0;
        let max_flat: f64 = (0..200)
            .map(|_| flat.sample(&mut rng).into_iter().fold(0.0, f64::max))
            .sum::<f64>()
            / 200.0;
        assert!(max_spiky > 0.7, "spiky mean-max {max_spiky}");
        assert!(max_flat < 0.2, "flat mean-max {max_flat}");
    }

    #[test]
    fn dirichlet_mean_proportional_to_alpha() {
        let mut rng = Pcg64::seed(4);
        let d = Dirichlet::new(vec![1.0, 2.0, 7.0]);
        let n = 20_000;
        let mut acc = [0.0f64; 3];
        for _ in 0..n {
            let p = d.sample(&mut rng);
            for (a, x) in acc.iter_mut().zip(&p) {
                *a += x;
            }
        }
        for (a, expect) in acc.iter().zip([0.1, 0.2, 0.7]) {
            assert!((a / n as f64 - expect).abs() < 0.01);
        }
    }
}
