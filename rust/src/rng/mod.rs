//! Deterministic pseudo-random number generation and sampling.
//!
//! The offline build has no `rand` crate, so this module implements the
//! generators the system needs from scratch: a PCG64 (DXSM) core generator
//! plus the samplers used by the data generator, the graph generators, and
//! the training loop (normal, Bernoulli, gamma/Dirichlet, categorical,
//! Fisher–Yates shuffling, and without-replacement batch sampling).
//!
//! Every consumer takes an explicit seed so whole experiments are exactly
//! reproducible from the config file; independent subsystems derive
//! decorrelated streams via [`Pcg64::split`].

pub mod dist;

pub use dist::{Dirichlet, Normal};

/// PCG64-DXSM: 128-bit LCG state, 64-bit output.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014);
/// DXSM output function as adopted by numpy's default generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed the generator. `seed` selects the starting state, `stream`
    /// selects one of 2^127 distinct sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    /// Convenience single-argument constructor (stream 0).
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive a decorrelated child generator (distinct stream).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), tag.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }

    /// Next raw 64-bit output (DXSM output function).
    pub fn next_u64(&mut self) -> u64 {
        let state = self.state;
        self.state = state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let mut hi = (state >> 64) as u64;
        let lo = (state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda94_2042_e4dd_58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform f64 in [0, 1) with 53 random bits.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
            // reject and redraw
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli draw.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (cached second value discarded for
    /// statelessness; fine at our call volumes).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean / std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; valid for any shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be positive");
        if shape < 1.0 {
            // boost: G(a) = G(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u: f64 = self.next_f64().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Categorical draw from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical weights sum to zero");
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_same_seed() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_decorrelated() {
        let mut root = Pcg64::seed(7);
        let mut c1 = root.split(1);
        let mut c2 = root.split(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seed(3);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::seed(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut rng = Pcg64::seed(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts {counts:?}");
        }
    }

    #[test]
    fn below_never_exceeds() {
        let mut rng = Pcg64::seed(6);
        for n in [1u64, 2, 7, 1000] {
            for _ in 0..1000 {
                assert!(rng.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(8);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut rng = Pcg64::seed(9);
        for shape in [0.5, 1.0, 2.5, 10.0] {
            let n = 50_000;
            let mean = (0..n).map(|_| rng.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(1.0),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg64::seed(10);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        let n = 50_000;
        for _ in 0..n {
            counts[rng.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / n as f64 - 0.6).abs() < 0.02);
        assert!((counts[1] as f64 / n as f64 - 0.3).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(11);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Pcg64::seed(12);
        for _ in 0..100 {
            let v = rng.sample_indices(50, 20);
            assert_eq!(v.len(), 20);
            let mut s = v.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 20);
            assert!(v.iter().all(|&i| i < 50));
        }
    }

    #[test]
    #[should_panic]
    fn sample_more_than_n_panics() {
        Pcg64::seed(0).sample_indices(3, 4);
    }
}
