//! # decfl — fully decentralized federated learning for EHR
//!
//! Production-shaped reproduction of *Learn Electronic Health Records by
//! Fully Decentralized Federated Learning* (Lu, Zhang, Wang, Mack; 2019).
//!
//! N hospital nodes connected by an undirected graph collaboratively train a
//! shallow neural network on non-identical EHR shards, exchanging parameters
//! only with graph neighbors (DSGD / DSGT), with `Q` local SGD steps between
//! communication rounds (the paper's federated variant).
//!
//! Three-layer architecture (see DESIGN.md):
//! - L1/L2 (build-time python): Pallas kernels + jax model, AOT-lowered to
//!   HLO-text artifacts in `artifacts/` by `make artifacts`.
//! - L3 (this crate): the decentralized runtime — graph topologies, mixing
//!   matrices, synthetic EHR data, the gossip network simulator, the
//!   unified round engine (`engine`) with its pluggable communication
//!   strategies, node actors, metrics, and every experiment harness that
//!   regenerates the paper's figures.
//!
//! Quickstart: `make artifacts && cargo run --release -- train --algo fd-dsgt`.

#![warn(missing_docs)]

pub mod algo;
pub mod benchutil;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod graph;
pub mod jsonl;
pub mod linalg;
pub mod metrics;
pub mod mixing;
pub mod netsim;
pub mod rng;
pub mod runtime;
pub mod testutil;
pub mod tsne;
