//! The 42-feature EHR schema (paper §3: "problem dimension of 42").
//!
//! Feature families mirror what an AD/MCI cohort extract from claims + EHR
//! typically carries: demographics, vitals, laboratory panels, comorbidity
//! flags, medication exposure, and cognition/utilization scores.  Each
//! feature declares its raw distribution and the fixed standardization
//! parameters; sampling emits *standardized* values directly (raw value
//! drawn, then `(v - mean)/std`), with the per-hospital site shift added in
//! standardized units.  `ad_weight` is the feature's loading in the teacher's
//! clinical linear risk term (positive = pushes toward AD).

use crate::rng::Pcg64;

/// Number of features — the paper's problem dimension.
pub const N_FEATURES: usize = 42;

/// Raw distribution family of a feature.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FeatureKind {
    /// Gaussian with (mean, std), truncated to [lo, hi].
    Continuous { mean: f64, std: f64, lo: f64, hi: f64 },
    /// Bernoulli(p) flag.
    Binary { p: f64 },
    /// Poisson-ish non-negative count, approximated by a truncated Gaussian
    /// with std = sqrt(mean) (adequate for the simulator's purposes).
    Count { mean: f64, max: f64 },
}

/// One feature's spec.
#[derive(Clone, Copy, Debug)]
pub struct FeatureSpec {
    /// Column name (CSV export header).
    pub name: &'static str,
    /// Raw distribution family.
    pub kind: FeatureKind,
    /// Teacher loading (standardized units).
    pub ad_weight: f64,
}

impl FeatureSpec {
    /// (mean, std) used for standardization — fixed, site-independent.
    fn moments(&self) -> (f64, f64) {
        match self.kind {
            FeatureKind::Continuous { mean, std, .. } => (mean, std),
            FeatureKind::Binary { p } => (p, (p * (1.0 - p)).sqrt().max(1e-6)),
            FeatureKind::Count { mean, .. } => (mean, mean.sqrt().max(1e-6)),
        }
    }

    /// Draw one standardized value with a site shift (standardized units).
    ///
    /// Binary flags shift in probability space (logit shift) so they stay in
    /// {0,1}; continuous/count features shift their mean.
    pub fn sample_standardized(&self, rng: &mut Pcg64, site_shift: f64) -> f64 {
        let (mean, std) = self.moments();
        match self.kind {
            FeatureKind::Continuous { lo, hi, .. } => {
                let raw = rng.normal_ms(mean + site_shift * std, std).clamp(lo, hi);
                (raw - mean) / std
            }
            FeatureKind::Binary { p } => {
                // logit-shift the prevalence by the site effect
                let logit = (p / (1.0 - p)).ln() + site_shift;
                let p_site = 1.0 / (1.0 + (-logit).exp());
                let v = if rng.bernoulli(p_site) { 1.0 } else { 0.0 };
                (v - mean) / std
            }
            FeatureKind::Count { max, .. } => {
                let raw = rng.normal_ms(mean + site_shift * std, std).clamp(0.0, max);
                (raw - mean) / std
            }
        }
    }
}

/// The full 42-feature schema.
pub fn ehr_schema() -> &'static [FeatureSpec] {
    use FeatureKind::*;
    const S: [FeatureSpec; N_FEATURES] = [
        // --- demographics (6) ---
        FeatureSpec { name: "age", kind: Continuous { mean: 74.0, std: 7.5, lo: 50.0, hi: 95.0 }, ad_weight: 0.55 },
        FeatureSpec { name: "sex_female", kind: Binary { p: 0.58 }, ad_weight: 0.10 },
        FeatureSpec { name: "race_white", kind: Binary { p: 0.72 }, ad_weight: 0.0 },
        FeatureSpec { name: "race_black", kind: Binary { p: 0.14 }, ad_weight: 0.05 },
        FeatureSpec { name: "race_other", kind: Binary { p: 0.14 }, ad_weight: 0.0 },
        FeatureSpec { name: "years_education", kind: Continuous { mean: 13.0, std: 3.0, lo: 0.0, hi: 22.0 }, ad_weight: -0.25 },
        // --- vitals (5) ---
        FeatureSpec { name: "bmi", kind: Continuous { mean: 27.0, std: 4.5, lo: 14.0, hi: 50.0 }, ad_weight: -0.10 },
        FeatureSpec { name: "systolic_bp", kind: Continuous { mean: 132.0, std: 15.0, lo: 85.0, hi: 200.0 }, ad_weight: 0.08 },
        FeatureSpec { name: "diastolic_bp", kind: Continuous { mean: 76.0, std: 10.0, lo: 45.0, hi: 120.0 }, ad_weight: 0.02 },
        FeatureSpec { name: "heart_rate", kind: Continuous { mean: 72.0, std: 11.0, lo: 40.0, hi: 140.0 }, ad_weight: 0.0 },
        FeatureSpec { name: "weight_kg", kind: Continuous { mean: 75.0, std: 14.0, lo: 35.0, hi: 160.0 }, ad_weight: -0.06 },
        // --- labs (10) ---
        FeatureSpec { name: "glucose", kind: Continuous { mean: 104.0, std: 22.0, lo: 55.0, hi: 300.0 }, ad_weight: 0.06 },
        FeatureSpec { name: "hba1c", kind: Continuous { mean: 6.0, std: 0.9, lo: 4.0, hi: 13.0 }, ad_weight: 0.08 },
        FeatureSpec { name: "ldl", kind: Continuous { mean: 112.0, std: 30.0, lo: 30.0, hi: 250.0 }, ad_weight: 0.04 },
        FeatureSpec { name: "hdl", kind: Continuous { mean: 54.0, std: 14.0, lo: 15.0, hi: 110.0 }, ad_weight: -0.05 },
        FeatureSpec { name: "triglycerides", kind: Continuous { mean: 140.0, std: 60.0, lo: 30.0, hi: 500.0 }, ad_weight: 0.02 },
        FeatureSpec { name: "creatinine", kind: Continuous { mean: 1.0, std: 0.3, lo: 0.3, hi: 4.0 }, ad_weight: 0.03 },
        FeatureSpec { name: "egfr", kind: Continuous { mean: 72.0, std: 18.0, lo: 10.0, hi: 120.0 }, ad_weight: -0.04 },
        FeatureSpec { name: "vitamin_b12", kind: Continuous { mean: 480.0, std: 170.0, lo: 100.0, hi: 1200.0 }, ad_weight: -0.08 },
        FeatureSpec { name: "tsh", kind: Continuous { mean: 2.1, std: 1.1, lo: 0.1, hi: 10.0 }, ad_weight: 0.02 },
        FeatureSpec { name: "crp", kind: Continuous { mean: 3.0, std: 2.5, lo: 0.0, hi: 25.0 }, ad_weight: 0.07 },
        // --- comorbidity flags (10) ---
        FeatureSpec { name: "hypertension", kind: Binary { p: 0.62 }, ad_weight: 0.10 },
        FeatureSpec { name: "diabetes", kind: Binary { p: 0.28 }, ad_weight: 0.12 },
        FeatureSpec { name: "stroke_history", kind: Binary { p: 0.09 }, ad_weight: 0.22 },
        FeatureSpec { name: "depression", kind: Binary { p: 0.31 }, ad_weight: 0.18 },
        FeatureSpec { name: "anxiety", kind: Binary { p: 0.22 }, ad_weight: 0.08 },
        FeatureSpec { name: "ckd", kind: Binary { p: 0.15 }, ad_weight: 0.06 },
        FeatureSpec { name: "copd", kind: Binary { p: 0.12 }, ad_weight: 0.03 },
        FeatureSpec { name: "cad", kind: Binary { p: 0.21 }, ad_weight: 0.07 },
        FeatureSpec { name: "afib", kind: Binary { p: 0.11 }, ad_weight: 0.09 },
        FeatureSpec { name: "hyperlipidemia", kind: Binary { p: 0.55 }, ad_weight: 0.02 },
        // --- medication exposure (6) ---
        FeatureSpec { name: "n_active_meds", kind: Count { mean: 7.0, max: 30.0 }, ad_weight: 0.12 },
        FeatureSpec { name: "rx_donepezil", kind: Binary { p: 0.18 }, ad_weight: 0.45 },
        FeatureSpec { name: "rx_memantine", kind: Binary { p: 0.08 }, ad_weight: 0.40 },
        FeatureSpec { name: "rx_antidepressant", kind: Binary { p: 0.26 }, ad_weight: 0.10 },
        FeatureSpec { name: "rx_antihypertensive", kind: Binary { p: 0.55 }, ad_weight: 0.04 },
        FeatureSpec { name: "rx_statin", kind: Binary { p: 0.48 }, ad_weight: 0.00 },
        // --- cognition / utilization (5) ---
        FeatureSpec { name: "cognitive_score", kind: Continuous { mean: 24.0, std: 4.0, lo: 0.0, hi: 30.0 }, ad_weight: -0.65 },
        FeatureSpec { name: "outpatient_visits_yr", kind: Count { mean: 9.0, max: 60.0 }, ad_weight: 0.08 },
        FeatureSpec { name: "inpatient_days_yr", kind: Count { mean: 1.5, max: 40.0 }, ad_weight: 0.12 },
        FeatureSpec { name: "er_visits_yr", kind: Count { mean: 0.8, max: 15.0 }, ad_weight: 0.10 },
        FeatureSpec { name: "years_since_mci_dx", kind: Continuous { mean: 2.5, std: 1.6, lo: 0.0, hi: 12.0 }, ad_weight: 0.30 },
    ];
    &S
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{mean, variance};

    #[test]
    fn schema_has_42_features() {
        assert_eq!(ehr_schema().len(), N_FEATURES);
        assert_eq!(N_FEATURES, 42);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<&str> = ehr_schema().iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), N_FEATURES);
    }

    #[test]
    fn standardized_samples_near_zero_mean_unit_var() {
        let mut rng = Pcg64::seed(0);
        for spec in ehr_schema() {
            let xs: Vec<f64> = (0..20_000).map(|_| spec.sample_standardized(&mut rng, 0.0)).collect();
            let m = mean(&xs);
            let v = variance(&xs);
            // truncation biases some features slightly; generous bounds
            assert!(m.abs() < 0.15, "{}: mean {m}", spec.name);
            assert!((0.5..1.5).contains(&v), "{}: var {v}", spec.name);
        }
    }

    #[test]
    fn site_shift_moves_continuous_mean() {
        let mut rng = Pcg64::seed(1);
        let spec = &ehr_schema()[0]; // age
        let base: f64 = (0..5000).map(|_| spec.sample_standardized(&mut rng, 0.0)).sum::<f64>() / 5000.0;
        let shifted: f64 = (0..5000).map(|_| spec.sample_standardized(&mut rng, 1.0)).sum::<f64>() / 5000.0;
        assert!(shifted - base > 0.6, "base {base} shifted {shifted}");
    }

    #[test]
    fn site_shift_moves_binary_prevalence() {
        let mut rng = Pcg64::seed(2);
        let spec = ehr_schema().iter().find(|s| s.name == "diabetes").unwrap();
        let (mean_p, std_p) = match spec.kind {
            FeatureKind::Binary { p } => (p, (p * (1.0 - p)).sqrt()),
            _ => unreachable!(),
        };
        let count_ones = |rng: &mut Pcg64, shift: f64| -> f64 {
            (0..5000)
                .filter(|_| {
                    let v = spec.sample_standardized(rng, shift);
                    // destandardize: v*std + mean ≈ 1.0?
                    (v * std_p + mean_p) > 0.5
                })
                .count() as f64
                / 5000.0
        };
        let base = count_ones(&mut rng, 0.0);
        let up = count_ones(&mut rng, 1.5);
        assert!(up > base + 0.1, "base {base} up {up}");
    }

    #[test]
    fn counts_nonnegative_raw() {
        let mut rng = Pcg64::seed(3);
        for spec in ehr_schema() {
            if let FeatureKind::Count { mean: m, .. } = spec.kind {
                let std = m.sqrt();
                for _ in 0..2000 {
                    let v = spec.sample_standardized(&mut rng, 0.0);
                    let raw = v * std + m;
                    assert!(raw >= -1e-9, "{}: raw {raw}", spec.name);
                }
            }
        }
    }

    #[test]
    fn clinical_signs_sane() {
        // cognition protects, age and AD meds indicate
        let by_name = |n: &str| ehr_schema().iter().find(|s| s.name == n).unwrap().ad_weight;
        assert!(by_name("cognitive_score") < 0.0);
        assert!(by_name("years_education") < 0.0);
        assert!(by_name("age") > 0.0);
        assert!(by_name("rx_donepezil") > 0.0);
    }
}
